"""Quickstart: train a reduced assigned-architecture LM for a few steps,
checkpoint it, and run a short greedy decode.  Pure public API.

    PYTHONPATH=src python examples/quickstart.py [--arch phi4-mini-3.8b]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs.base import get_config
from repro.data.pipeline import LMDataConfig, synthetic_batch
from repro.launch import steps
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch {cfg.name}: {cfg.num_layers}L d{cfg.d_model} "
          f"V{cfg.vocab_size}")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"params {n/1e6:.2f}M")

    opt = steps.make_opt(cfg)
    opt_state = opt.init(params)
    train_step = jax.jit(steps.make_train_step(cfg))
    dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                        global_batch=8)
    step = jnp.int32(0)
    first = last = None
    for i in range(args.steps):
        batch = jax.tree.map(jnp.asarray, synthetic_batch(dcfg, i, cfg))
        params, opt_state, step, metrics = train_step(params, opt_state,
                                                      step, batch)
        last = float(metrics["loss"])
        first = first if first is not None else last
        print(f"step {i}: loss {last:.4f}")
    assert last < first, "loss did not decrease"

    with tempfile.TemporaryDirectory() as td:
        nb = ckpt.save(f"{td}/model.ckpt", params, step=int(step))
        print(f"checkpointed {nb/1e6:.1f} MB; restoring...")
        params = ckpt.restore(f"{td}/model.ckpt", params)

    # greedy decode a few tokens
    prompt = jnp.asarray(synthetic_batch(dcfg, 999, cfg)["tokens"][:1, :16])
    logits, cache = M.prefill(cfg, params, prompt, cache_len=32)
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
    out = [int(tok[0, 0])]
    for t in range(8):
        logits, cache = M.decode_step(cfg, params, cache, tok,
                                      jnp.int32(16 + t))
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
        out.append(int(tok[0, 0]))
    print("greedy continuation:", out)
    print("OK")


if __name__ == "__main__":
    main()
