"""End-to-end driver: PPO learns active flow control on the cylinder
(the paper's Fig. 5 experiment at reduced scale).

Defaults fit a single CPU core in ~20-40 min: coarse grid, short episodes.
Increase --res/--episodes to approach the paper's setup.

    PYTHONPATH=src python examples/drl_cylinder.py --episodes 60
"""
import argparse
import json
from pathlib import Path

import numpy as np

from repro.cfd.env import EnvConfig
from repro.cfd.grid import GridConfig
from repro.drl.engine import SinkSpec
from repro.drl.ppo import PPOConfig
from repro.drl.train import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=60)
    ap.add_argument("--n-envs", type=int, default=4)
    ap.add_argument("--res", type=int, default=8)
    ap.add_argument("--actions", type=int, default=40)
    ap.add_argument("--steps-per-action", type=int, default=25)
    ap.add_argument("--warmup", type=float, default=20.0)
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated scenario names (see "
                         "repro.cfd.scenarios.list_scenarios(), e.g. "
                         "'cyl_re100,cyl_re200,cyl_re100_rotary') assigned "
                         "round-robin over the env batch; default: the "
                         "single Re=100 jets case")
    ap.add_argument("--policy", default="mlp",
                    choices=["mlp", "attention"],
                    help="policy architecture: 'mlp' (the paper's 2x512 "
                         "tanh MLP, default) or 'attention' (permutation-"
                         "invariant set encoder over (coord, value) probe "
                         "tokens — recommended for mixed or multi-body "
                         "batches, e.g. --scenarios pinball_re100)")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="print the scenario registry and exit")
    ap.add_argument("--plan", default=None,
                    help="hybrid placement: 'auto' (measure this host and "
                         "optimize, core.autotune) or 'N_ENVSxN_RANKS' "
                         "(e.g. '2x2' = 2 envs x 2 spatial CFD shards, "
                         "runs the halo Poisson backend); default: plain "
                         "single-host vmap")
    ap.add_argument("--sink", default=None,
                    help="trajectory sink spec 'kind[:root]': 'none', "
                         "'memory', 'binary:/path', 'zstd:/path' (one file "
                         "per episode, paper §IV I/O), or 'dataset:/path' "
                         "(sharded files + manifest, replayable via "
                         "tools/replay_smoke.py)")
    ap.add_argument("--spill", default=None,
                    choices=["none", "memory", "binary", "zstd"],
                    help="deprecated alias for --sink KIND:--spill-dir")
    ap.add_argument("--spill-dir", default="artifacts/traj_spill")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory: save the full TrainState "
                         "(params, optimizer, PRNG carry, env batch, "
                         "history) every --ckpt-every episodes with async "
                         "background writes; required for --resume")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="retain only the newest N checkpoints")
    ap.add_argument("--resume", nargs="?", const="auto", default=None,
                    help="resume training: bare --resume restarts from the "
                         "latest valid checkpoint in --ckpt-dir (fresh run "
                         "when none exists yet); or pass an explicit .ckpt "
                         "path / checkpoint directory.  --episodes is the "
                         "TOTAL target, so an interrupted run rerun with "
                         "the same flags just continues")
    ap.add_argument("--out", default="artifacts/drl_cylinder.json")
    args = ap.parse_args()

    if args.list_scenarios:
        from repro.cfd.scenarios import get_scenario, list_scenarios
        for name in list_scenarios():
            s = get_scenario(name)
            print(f"{name:22s} Re={s.re:<6g} {s.actuation:7s} "
                  f"{s.geometry:9s} {s.probes:9s} {s.description}")
        return

    plan = args.plan
    if plan and plan != "auto":
        n_envs, n_ranks = (int(v) for v in plan.lower().split("x"))
        plan = (n_envs, n_ranks)

    if args.sink is not None and args.spill is not None:
        ap.error("--spill is a deprecated alias for --sink; pass only one")
    if args.spill is not None:
        print(f"note: --spill is deprecated; use "
              f"--sink {args.spill}:{args.spill_dir}")
        spec = SinkSpec(kind=args.spill, root=args.spill_dir
                        if args.spill in ("binary", "zstd") else None)
    else:
        spec = SinkSpec.parse(args.sink)

    cfg = TrainConfig(
        env=EnvConfig(
            grid=GridConfig(res=args.res, dt=0.01, poisson_iters=50),
            steps_per_action=args.steps_per_action,
            actions_per_episode=args.actions,
            warmup_time=args.warmup,
        ),
        ppo=PPOConfig(lr=3e-4, epochs=6, minibatches=4,
                      entropy_coef=0.005),
        n_envs=args.n_envs,
        episodes=args.episodes,
        scenarios=(tuple(s.strip() for s in args.scenarios.split(",")
                         if s.strip())
                   if args.scenarios else None),
        policy=args.policy,
        plan=plan,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        ckpt_keep=args.ckpt_keep,
        resume=args.resume,
        sink=spec,
    )
    sink = spec.build()
    hist, params = train(cfg, sink=sink)
    if sink is not None:
        print(f"sink[{spec.kind}]: {sink.episodes} episodes, "
              f"{sink.bytes_written / 1e6:.2f} MB, "
              f"{sink.time_spent:.2f}s interface time")
    # report drag reduction: mean CD of last episodes vs uncontrolled CD0
    first5 = float(np.mean(hist["cd"][:5]))
    last5 = float(np.mean(hist["cd"][-5:]))
    r_first = float(np.mean(hist["reward"][:5]))
    r_last = float(np.mean(hist["reward"][-5:]))
    print(f"\nreturn: {r_first:+.2f} -> {r_last:+.2f}")
    print(f"tail CD: {first5:.3f} -> {last5:.3f} "
          f"({100*(last5-first5)/first5:+.1f}% change; paper: -8%)")
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({k: np.asarray(v).tolist()
                               for k, v in hist.items()}, indent=1))
    print(f"history -> {out}")


if __name__ == "__main__":
    main()
