"""Batched policy-inference front end: the serving half of a deployed AFC
controller.

Loads a trained ``TrainState`` checkpoint (``repro.drl.train`` +
``AsyncCheckpointer`` layout; falls back to freshly initialized params so
the demo runs standalone) and serves batched probe-observation -> jet-action
requests through one jitted program — the shape a flow-control deployment
sees: many cylinder instances stream probe readings, one host answers with
actuation commands inside the actuation deadline.

Reports per-request p50 / p99 latency and aggregate actions/sec over a
burst of batches, plus the actuation-period budget the paper's envs give
the controller (steps_per_action x dt in simulated seconds).

    PYTHONPATH=src python examples/serve_batch.py [--ckpt runs/ckpt] \
        [--batch 16] [--requests 200] [--deterministic]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd.probes import layout_size
from repro.drl import networks


def load_params(ckpt: str, obs_dim: int):
    """Params from the newest valid checkpoint, or fresh ones (demo mode)."""
    if ckpt:
        from repro.ckpt.checkpoint import latest_checkpoint
        from repro.drl.train_state import load_train_state
        path = latest_checkpoint(ckpt) if not ckpt.endswith(".ckpt") else ckpt
        if path is None:
            raise SystemExit(f"no valid checkpoint under {ckpt!r}")
        ts, meta = load_train_state(path)
        params = jax.tree.map(jnp.asarray, ts.params)
        dim = int(meta.get("obs_dim", obs_dim))
        return params, dim, f"checkpoint {path} (episode {meta['episode']})"
    pcfg = networks.PolicyConfig(obs_dim=obs_dim)
    params = networks.init_actor_critic(pcfg, jax.random.PRNGKey(0))
    return params, obs_dim, "fresh params (no --ckpt given)"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir (newest valid is served) or a "
                         "specific .ckpt file; default: fresh params")
    ap.add_argument("--batch", type=int, default=16,
                    help="envs per inference request")
    ap.add_argument("--requests", type=int, default=200,
                    help="timed requests after warmup")
    ap.add_argument("--probe-layout", default="ring149",
                    help="probe layout naming the obs dim (cfd.probes)")
    ap.add_argument("--deterministic", action="store_true",
                    help="serve the policy mean (deployment), not samples "
                         "(training-style exploration)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    obs_dim = layout_size(args.probe_layout)
    params, obs_dim, src = load_params(args.ckpt, obs_dim)

    # the serving program: one jitted batched forward per request
    if args.deterministic:
        infer = jax.jit(lambda p, o, k: networks.policy_dist(p, o)[0])
    else:
        infer = jax.jit(lambda p, o, k: jax.vmap(
            networks.sample_action, in_axes=(None, 0, 0))(p, o, k)[0])

    key = jax.random.PRNGKey(args.seed)
    obs = jax.random.normal(key, (args.batch, obs_dim))
    keys = jax.random.split(key, args.batch)
    jax.block_until_ready(infer(params, obs, keys))       # compile (warmup)

    lat = []
    t_all = time.perf_counter()
    for i in range(args.requests):
        key, ko = jax.random.split(key)
        obs = jax.random.normal(ko, (args.batch, obs_dim))
        keys = jax.random.split(ko, args.batch)
        t0 = time.perf_counter()
        act = infer(params, obs, keys)
        jax.block_until_ready(act)
        lat.append(time.perf_counter() - t0)
    wall = time.perf_counter() - t_all

    act = np.asarray(act)
    assert act.shape[0] == args.batch and not np.isnan(act).any()
    p50, p99 = np.percentile(lat, [50, 99])
    print(f"serving {src}")
    print(f"batch {args.batch} x obs_dim {obs_dim} "
          f"({'mean' if args.deterministic else 'sampled'} actions)")
    print(f"latency: p50 {p50 * 1e3:.2f} ms  p99 {p99 * 1e3:.2f} ms  "
          f"({args.requests} requests)")
    print(f"throughput: {args.requests * args.batch / wall:.0f} actions/s")
    print("OK")


if __name__ == "__main__":
    main()
