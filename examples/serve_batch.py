"""Batched serving demo: prefill a batch of prompts, then decode tokens with
the KV cache (the decode_32k shape at reduced scale).

    PYTHONPATH=src python examples/serve_batch.py --arch qwen2-vl-2b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import frontend as fe_mod
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, P = args.batch, args.prompt_len
    cache_len = P + args.new_tokens
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)
    fe = None
    if cfg.frontend:
        t = fe_mod.num_frontend_tokens(cfg, P)
        fe = jax.random.normal(jax.random.PRNGKey(2),
                               (B, t, fe_mod.frontend_dim(cfg)))

    prefill = jax.jit(lambda p, t: M.prefill(cfg, p, t, cache_len=cache_len,
                                             frontend_embeds=fe))
    decode = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]

    outs = [np.asarray(tok)[:, 0]]
    t0 = time.perf_counter()
    for i in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(P + i))
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
        outs.append(np.asarray(tok)[:, 0])
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.stack(outs, axis=1)
    print(f"arch {cfg.name}  batch {B}  prompt {P}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_decode/(args.new_tokens-1)*1e3:.2f} ms/token")
    for b in range(min(B, 2)):
        print(f"  seq{b}: {gen[b].tolist()}")
    assert not np.isnan(gen).any()
    print("OK")


if __name__ == "__main__":
    main()
