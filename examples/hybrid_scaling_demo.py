"""The paper's contribution as a 5-minute demo: given a worker budget, the
hybrid planner picks (N_envs, N_ranks), shows why, and maps it to a TPU mesh.

    PYTHONPATH=src python examples/hybrid_scaling_demo.py --workers 60
"""
import argparse

from repro.core.plan import CostModel, ParallelPlan, enumerate_plans, \
    optimize_plan
from repro.core.scaling_model import calibrate_to_paper


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=60)
    ap.add_argument("--episodes", type=int, default=3000)
    ap.add_argument("--io-bytes", type=float, default=5.0e6,
                    help="interface bytes per env per actuation")
    args = ap.parse_args()

    m = calibrate_to_paper()
    print(f"cost model (calibrated to the paper's Table II):")
    print(f"  t_step(1) = {m.t_step_1*1e3:.1f} ms   "
          f"CFD eff @16 ranks = {m.cfd_efficiency(16)*100:.0f}%")
    print(f"\nall splits of {args.workers} workers "
          f"({args.episodes} episodes, io={args.io_bytes/1e6:.1f} MB):")
    print(f"  {'n_envs':>7s} {'n_ranks':>8s} {'T_hours':>9s} "
          f"{'speedup':>8s} {'eff':>6s}")
    ref = m.t_training(ParallelPlan(1, 1, 1), args.episodes, args.io_bytes)
    plans = [p for p in enumerate_plans(args.workers)
             if p.n_envs * p.n_ranks == args.workers]
    for p in plans:
        t = m.t_training(p, args.episodes, args.io_bytes)
        print(f"  {p.n_envs:7d} {p.n_ranks:8d} {t/3600:9.1f} "
              f"{ref/t:8.1f} {ref/t/args.workers*100:5.1f}%")
    best = optimize_plan(args.workers, m, args.episodes, args.io_bytes)
    print(f"\noptimal: n_envs={best.n_envs}, n_ranks={best.n_ranks} "
          f"(paper: 60 x 1)")
    print(f"TPU mesh mapping: data axis = {best.n_envs} (env batch), "
          f"model axis = {best.n_ranks} (spatial CFD shards)")
    opt = m.t_training(best, args.episodes, io_bytes=1.2e6)
    print(f"with optimized 1.2 MB interface: {opt/3600:.1f} h "
          f"({ref/opt:.1f}x vs single worker; paper: 47x)")


if __name__ == "__main__":
    main()
