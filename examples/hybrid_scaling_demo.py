"""The paper's contribution as a 5-minute demo: given a worker budget, the
hybrid planner picks (N_envs, N_ranks), shows why, and maps it to a TPU mesh.

Two modes:

    # paper mode — cost model calibrated to the paper's Table II
    PYTHONPATH=src python examples/hybrid_scaling_demo.py --workers 60

    # measured mode — time THIS host's solver/halo/PPO/sink components,
    # refit the model, and pick the executable plan (JSON artifact included);
    # force a multi-device CPU host to see the halo candidates:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python examples/hybrid_scaling_demo.py --auto

The chosen plan is directly executable:  ``train(TrainConfig(plan="auto"))``
builds the mesh, picks the Poisson backend ("halo" when n_ranks > 1) and
runs it — see README "Choosing a parallel plan".
"""
import argparse

from repro.core.plan import CostModel, ParallelPlan, enumerate_plans, \
    optimize_plan
from repro.core.scaling_model import calibrate_to_paper


def show_lattice(m: CostModel, workers: int, episodes: int,
                 io_bytes: float) -> ParallelPlan:
    print(f"\nall full-utilization splits of {workers} workers "
          f"({episodes} episodes, io={io_bytes / 1e6:.1f} MB):")
    print(f"  {'n_envs':>7s} {'n_ranks':>8s} {'T_hours':>9s} "
          f"{'speedup':>8s} {'eff':>6s}")
    ref = m.t_training(ParallelPlan(1, 1, 1), episodes, io_bytes)
    for p in enumerate_plans(workers):
        if p.utilization < 1.0:
            continue
        t = m.t_training(p, episodes, io_bytes)
        print(f"  {p.n_envs:7d} {p.n_ranks:8d} {t / 3600:9.1f} "
              f"{ref / t:8.1f} {ref / t / workers * 100:5.1f}%")
    best = optimize_plan(workers, m, episodes, io_bytes)
    print(f"\noptimal: n_envs={best.n_envs}, n_ranks={best.n_ranks} "
          f"(utilization {best.utilization:.0%})")
    print(f"TPU mesh mapping: data axis = {best.n_envs} (env batch), "
          f"model axis = {best.n_ranks} (spatial CFD shards)")
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=60)
    ap.add_argument("--episodes", type=int, default=3000)
    ap.add_argument("--io-bytes", type=float, default=None,
                    help="interface bytes per env per actuation "
                         "(default: paper baseline 5.0 MB; in --auto mode "
                         "the measured per-actuation volume)")
    ap.add_argument("--auto", action="store_true",
                    help="measure this host (core.autotune) instead of "
                         "using the paper-calibrated constants; the worker "
                         "budget becomes the host's device count")
    ap.add_argument("--artifact", default="artifacts/autotune_demo.json",
                    help="--auto mode: measured-vs-predicted JSON record")
    args = ap.parse_args()

    if args.auto:
        from repro.core.autotune import autotune
        rp = autotune(n_episodes=args.episodes,
                      io_bytes=args.io_bytes, artifact=args.artifact)
        rec = rp.measurements
        print("measured on this host (median of 3):")
        for r, t in sorted(rec["measured"]["t_step_ranks"].items(),
                           key=lambda kv: int(kv[0])):
            pred = rec["predicted"]["t_step_ranks"][r]
            err = rec["predicted"]["rel_err_t_step"][r]
            print(f"  t_step(n_ranks={r}) = {t * 1e3:7.2f} ms   "
                  f"refit model: {pred * 1e3:7.2f} ms ({err:+.1%})")
        print(f"  t_update = {rec['measured']['t_update'] * 1e3:.1f} ms   "
              f"sink write = "
              f"{rec['measured']['io']['write_seconds'] * 1e3:.2f} ms")
        io_bytes = (args.io_bytes if args.io_bytes is not None
                    else rp.model.io_bytes_per_actuation)
        best = show_lattice(rp.model, rec["plan"]["n_total"], args.episodes,
                            io_bytes)
        print(f"\n{rp.describe()}")
        print(f"artifact -> {args.artifact}")
        print("execute it:  train(TrainConfig(plan='auto', ...))  "
              "or plan=ParallelPlan"
              f"({best.n_total}, {best.n_envs}, {best.n_ranks})")
        return

    m = calibrate_to_paper()
    io_bytes = 5.0e6 if args.io_bytes is None else args.io_bytes
    print("cost model (calibrated to the paper's Table II):")
    print(f"  t_step(1) = {m.t_step_1 * 1e3:.1f} ms   "
          f"CFD eff @16 ranks = {m.cfd_efficiency(16) * 100:.0f}%")
    best = show_lattice(m, args.workers, args.episodes, io_bytes)
    print(f"paper: 60 x 1 — matches" if (best.n_envs, best.n_ranks)
          == (60, 1) and args.workers == 60 else "")
    opt = m.t_training(best, args.episodes, io_bytes=1.2e6)
    ref = m.t_training(ParallelPlan(1, 1, 1), args.episodes, io_bytes)
    print(f"with optimized 1.2 MB interface: {opt / 3600:.1f} h "
          f"({ref / opt:.1f}x vs single worker; paper: 47x)")


if __name__ == "__main__":
    main()
