"""CI replay smoke: record -> replay -> bitwise match.

Trains a few episodes with the sharded dataset sink
(``SinkSpec(kind='dataset')``), then replays the recorded trajectories
offline through ``RolloutEngine.replay_sync`` — rebuilding the engine and
PRNG stream purely from the dataset's own manifest metadata — and asserts
the replayed parameter updates and per-episode returns are EXACTLY those of
the live run.  Also spot-checks the durability contract: a truncated shard
and a flipped byte must be detected, never silently replayed.  Exits
non-zero on any mismatch.

    PYTHONPATH=src python tools/replay_smoke.py
"""
import argparse
import os
import shutil
import sys
import tempfile
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")
sys.path.insert(0, SRC)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax                                                  # noqa: E402
import numpy as np                                          # noqa: E402

from repro.cfd.env import CylinderEnv, EnvConfig            # noqa: E402
from repro.cfd.grid import GridConfig                       # noqa: E402
from repro.data.trajectory_dataset import (DatasetError,    # noqa: E402
                                           TrajectoryReader)
from repro.drl import networks                              # noqa: E402
from repro.drl.engine import (EngineConfig, RolloutEngine,  # noqa: E402
                              SinkSpec)
from repro.drl.ppo import PPOConfig                         # noqa: E402
from repro.drl.train import TrainConfig, train              # noqa: E402


def _cfg(episodes, root):
    return TrainConfig(
        env=EnvConfig(grid=GridConfig(res=6, dt=0.012, poisson_iters=30),
                      steps_per_action=3, actions_per_episode=3,
                      warmup_time=1.0),
        ppo=PPOConfig(epochs=2, minibatches=2),
        n_envs=2, episodes=episodes, seed=0,
        sink=SinkSpec(kind="dataset", root=root))


def check_corruption_detected(root: str) -> None:
    """Damaged copies of the dataset must fail loudly, not replay garbage."""
    shard = sorted(Path(root).glob("shard_*.bin"))[-1]

    truncated = tempfile.mkdtemp(prefix="replay_smoke_trunc_")
    shutil.copytree(root, truncated, dirs_exist_ok=True)
    with open(Path(truncated) / shard.name, "r+b") as f:
        f.truncate(shard.stat().st_size - 8)
    try:
        TrajectoryReader(truncated)
    except DatasetError as exc:
        assert "truncated shard" in str(exc), exc
    else:
        sys.exit("truncated shard was NOT detected")

    flipped = tempfile.mkdtemp(prefix="replay_smoke_flip_")
    shutil.copytree(root, flipped, dirs_exist_ok=True)
    with open(Path(flipped) / shard.name, "r+b") as f:
        f.seek(shard.stat().st_size // 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    reader = TrajectoryReader(flipped)   # sizes intact: validate() passes
    try:
        for ep in reader.episodes:
            reader.read(ep)
    except DatasetError as exc:
        # crc catch, or the header check if the flip landed in a length field
        assert ("crc32 mismatch" in str(exc)
                or "corrupted shard" in str(exc)), exc
    else:
        sys.exit("flipped shard byte was NOT detected")
    shutil.rmtree(truncated, ignore_errors=True)
    shutil.rmtree(flipped, ignore_errors=True)
    print("corruption checks: truncation + bit-flip both detected")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=4)
    args = ap.parse_args()

    root = tempfile.mkdtemp(prefix="replay_smoke_ds_")
    cfg = _cfg(args.episodes, root)
    hist, params_live = train(cfg, log_fn=None)
    print(f"recorded {args.episodes} episodes -> {root}")

    # the dataset is self-describing: engine shape, obs_dim and seed come
    # from the manifest the sink annotated, not from the config above
    reader = TrajectoryReader(root)
    meta = reader.metadata
    assert len(reader) == args.episodes, (len(reader), args.episodes)
    assert meta["code"]["state_schema"], meta

    env = CylinderEnv(cfg.env)
    engine = RolloutEngine.for_env(
        env, EngineConfig(n_envs=int(meta["n_envs"]),
                          horizon=int(meta["horizon"]),
                          gamma=cfg.ppo.gamma, lam=cfg.ppo.lam))
    pcfg = networks.PolicyConfig(obs_dim=int(meta["obs_dim"]))
    params0, optimizer, opt_state0, key = engine.init(pcfg, cfg.ppo,
                                                      int(meta["seed"]))
    params_replay, _, returns_replay = engine.replay_sync(
        reader, params0, opt_state0, cfg.ppo, optimizer, key, len(reader))

    for a, b in zip(jax.tree.leaves(params_live),
                    jax.tree.leaves(params_replay)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(hist["reward"]),
                                  returns_replay)
    print(f"replay of {len(reader)} episodes reproduced the live params "
          f"and returns bitwise")

    check_corruption_detected(root)
    shutil.rmtree(root, ignore_errors=True)
    print(f"REPLAY_SMOKE_OK: {args.episodes} episodes recorded, replayed "
          f"offline, params + returns bitwise equal to the live run")


if __name__ == "__main__":
    main()
