import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (must precede jax import — same rule as launch/dryrun.py)

"""Beyond-paper: lower the paper's own DRL x CFD workload on the production
TPU mesh — 256 environments on the "data" axis (the paper's N_envs) with the
cylinder grid optionally sharded over "model" (the paper's N_ranks).

    PYTHONPATH=src python tools/dryrun_drl.py [--n-ranks 16]
"""
import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import GridConfig
from repro.core import runner
from repro.drl import networks
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-envs", type=int, default=256)
    ap.add_argument("--n-ranks", type=int, default=1)
    ap.add_argument("--actions", type=int, default=100)
    ap.add_argument("--res", type=int, default=16)
    ap.add_argument("--out", default="artifacts/dryrun_drl.json")
    args = ap.parse_args()

    mesh = make_production_mesh()
    env = CylinderEnv(EnvConfig(
        grid=GridConfig(res=args.res, dt=0.005, poisson_iters=60),
        steps_per_action=50, actions_per_episode=args.actions,
        warmup_time=0.0))
    # abstract env state batch (no warmup on 512 fake devices)
    from repro.cfd import solver
    ny, nx = env.cfg.grid.ny, env.cfg.grid.nx
    N = args.n_envs
    st_b = jax.eval_shape(
        lambda: runner.jax.tree.map(
            lambda a: jnp.zeros((N,) + a.shape, a.dtype),
            __import__("repro.cfd.env", fromlist=["EnvState"]).EnvState(
                flow=solver.FlowState(
                    u=jnp.zeros((ny, nx + 1), jnp.float32),
                    v=jnp.zeros((ny + 1, nx), jnp.float32),
                    p=jnp.zeros((ny, nx), jnp.float32)),
                jet_vel=jnp.float32(0), t=jnp.int32(0))))
    obs_b = jax.ShapeDtypeStruct((N, 149), jnp.float32)
    pcfg = networks.PolicyConfig()
    params = jax.eval_shape(
        lambda: networks.init_actor_critic(pcfg, jax.random.PRNGKey(0)))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    jitted, _ = runner.make_distributed_collect(
        env, mesh, N, args.actions, n_ranks=args.n_ranks)
    t0 = time.time()
    with mesh:
        lowered = jitted.lower(params, st_b, obs_b, key)
        compiled = lowered.compile()
    t = time.time() - t0
    m = compiled.memory_analysis()
    a = hlo_analysis.analyze(compiled.as_text())
    rec = {
        "n_envs": N, "n_ranks": args.n_ranks, "grid": [ny, nx],
        "actions": args.actions, "compile_s": t,
        "peak_per_device_bytes": (m.argument_size_in_bytes
                                  + m.temp_size_in_bytes
                                  + m.output_size_in_bytes
                                  - m.alias_size_in_bytes),
        "hlo": a,
        "terms_s": {"compute": a["flops"] / 197e12,
                    "memory": a["bytes"] / 819e9,
                    "collective": a["coll_bytes"] / 50e9},
    }
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(rec, indent=1, default=float))
    print(json.dumps(rec["terms_s"], indent=1))
    print(f"peak/dev {rec['peak_per_device_bytes']/2**20:.1f} MiB  "
          f"compile {t:.1f}s -> {args.out}")


if __name__ == "__main__":
    main()
