"""CI resume smoke: train -> SIGKILL mid-run -> resume -> bitwise match.

Launches a training child that checkpoints every episode, kills it once a
few checkpoints exist (wherever the signal lands — mid-episode, mid-write),
resumes in this process from the latest valid checkpoint, and asserts the
resumed run's final params and history are EXACTLY those of a run that was
never interrupted.  Exits non-zero on any mismatch.

    PYTHONPATH=src python tools/resume_smoke.py
"""
import argparse
import os
import signal
import subprocess
import sys
import tempfile
import time
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")
sys.path.insert(0, SRC)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax                                                  # noqa: E402
import numpy as np                                          # noqa: E402

from repro.ckpt import checkpoint as ck                     # noqa: E402
from repro.drl import train_state as ts_mod                 # noqa: E402

_CHILD = """
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
from repro.cfd.env import EnvConfig
from repro.cfd.grid import GridConfig
from repro.drl.ppo import PPOConfig
from repro.drl.train import TrainConfig, train
train(TrainConfig(
    env=EnvConfig(grid=GridConfig(res=6, dt=0.012, poisson_iters=30),
                  steps_per_action=3, actions_per_episode=3,
                  warmup_time=1.0),
    ppo=PPOConfig(epochs=2, minibatches=2),
    n_envs=2, episodes=10**6, seed=0,
    ckpt_dir={ckpt_dir!r}, ckpt_every=1), log_fn=None)
"""


def _cfg(episodes, ckpt_dir, resume=None, ckpt_every=1):
    from repro.cfd.env import EnvConfig
    from repro.cfd.grid import GridConfig
    from repro.drl.ppo import PPOConfig
    from repro.drl.train import TrainConfig
    return TrainConfig(
        env=EnvConfig(grid=GridConfig(res=6, dt=0.012, poisson_iters=30),
                      steps_per_action=3, actions_per_episode=3,
                      warmup_time=1.0),
        ppo=PPOConfig(epochs=2, minibatches=2),
        n_envs=2, episodes=episodes, seed=0,
        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, resume=resume)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-ckpts", type=int, default=3,
                    help="checkpoints to wait for before the kill")
    ap.add_argument("--extra-episodes", type=int, default=3,
                    help="episodes to train past the crash point")
    ap.add_argument("--timeout", type=float, default=600.0)
    args = ap.parse_args()
    from repro.drl.train import train

    d = tempfile.mkdtemp(prefix="resume_smoke_")
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    # stderr to a file, NOT a pipe: an undrained pipe would block a chatty
    # child (jax warnings) before it ever reaches the first checkpoint
    errlog = Path(d) / "child_stderr.log"
    with open(errlog, "wb") as errf:
        proc = subprocess.Popen(
            [sys.executable, "-c", _CHILD.format(ckpt_dir=d)], env=env,
            stdout=subprocess.DEVNULL, stderr=errf)
        try:
            deadline = time.time() + args.timeout
            while len(list(Path(d).glob("step_*.ckpt"))) < args.min_ckpts:
                if proc.poll() is not None:
                    sys.exit("child exited early:\n"
                             + errlog.read_text()[-3000:])
                if time.time() > deadline:
                    sys.exit(f"no {args.min_ckpts} checkpoints in "
                             f"{args.timeout}s")
                time.sleep(0.1)
        finally:
            proc.send_signal(signal.SIGKILL)
            proc.wait()
    print(f"killed training child after "
          f"{len(list(Path(d).glob('step_*.ckpt')))} checkpoints")

    latest = ck.latest_checkpoint(d)
    assert latest is not None, "no valid checkpoint survived the kill"
    _, meta = ts_mod.load_train_state(latest)
    k = meta["episode"]
    target = k + args.extra_episodes
    print(f"latest valid checkpoint: {latest} (episode {k}); "
          f"resuming to {target}")

    hist_r, params_r = train(_cfg(target, d, resume=True), log_fn=None)
    assert len(hist_r["reward"]) == target, len(hist_r["reward"])

    straight_dir = tempfile.mkdtemp(prefix="resume_smoke_straight_")
    hist_s, params_s = train(_cfg(target, straight_dir, ckpt_every=target),
                             log_fn=None)

    for a, b in zip(jax.tree.leaves(params_s), jax.tree.leaves(params_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for f in ("reward", "cd", "cl"):
        np.testing.assert_array_equal(hist_s[f], hist_r[f])
    print(f"RESUME_SMOKE_OK: {target} episodes, params + history bitwise "
          f"equal to the uninterrupted run")


if __name__ == "__main__":
    main()
