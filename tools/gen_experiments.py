"""Generate EXPERIMENTS.md from dry-run artifacts + benchmark/training logs.

    PYTHONPATH=src python tools/gen_experiments.py > EXPERIMENTS.md
"""
import glob
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
ART = ROOT / "artifacts" / "dryrun"

HEADER = """# EXPERIMENTS

Reproduction of Jia & Xu (2024), *Optimal Parallelization Strategies for
Active Flow Control in DRL-Based CFD*, plus the assigned-architecture matrix.
All dry-run numbers regenerate with ``python -m repro.launch.dryrun --all
--both-meshes``; this file regenerates with ``python tools/gen_experiments.py``.

Hardware target: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Host: 1 CPU core (Pallas kernels validated in interpret mode; multi-core
wall-clock scaling is modeled, not measured — DESIGN.md §2).
"""

VALIDATION = """
## §Validation — the paper's own experiment

* **Cost-model fit.** `core/scaling_model.calibrate_to_paper()` least-squares
  fits 5 constants to the paper's Table II (33 points): **4.7% mean / 13.1%
  max relative error** (tests/test_core.py::test_calibration_fits_paper_tables).
* **Paper findings reproduced by the calibrated model** (benchmarks/bench_hybrid):
  - CFD intra-instance efficiency: 86% @ 2 ranks → 19% @ 16 ranks
    (paper Fig. 7: ~90% → <20%).
  - Optimal 60-worker split: **N_envs=60, N_ranks=1** (paper: same).
  - Baseline interface 60-worker efficiency ≈ 55%, optimized (1.2 MB binary)
    ≈ 67%, headline speedup with optimized I/O **47.5× (paper: 47×)**.
* **Real measured I/O on this host** (benchmarks/bench_io): ascii baseline
  ≈ 5.2 MB & ~290 ms per actuation round-trip vs optimized binary ≈ 1.20 MB
  & ~1.7 ms — a 0.23 size ratio (paper: 0.24) and the entire basis of the
  paper's §III.D bottleneck.
* **DRL control (reduced Fig. 5)**: see §DRL-training below.
* **The paper's finding derived from TPU roofline terms**
  (tools/dryrun_drl.py — the full 256-env × 100-actuation episode lowered on
  the 16×16 mesh):

  | config | memory term | collective term | bound |
  |---|---|---|---|
  | N_envs=256, N_ranks=1 (env axis only)  | 16.3 s | **0.000 s** | 16.3 s |
  | N_envs=256, N_ranks=16 (CFD sharded)   | 3.2 s  | 5.40 s      | ~8.6 s |

  Sharding one CFD instance 16 ways buys only ~1.9× per-episode (12%
  efficiency — paper Fig. 7: <20%), while the environment axis is perfectly
  collective-free.  The paper's conclusion falls out of the compiled HLO.
"""

PERF = """
## §Perf — hypothesis → change → measure log

The three hillclimbed pairs: worst decode memory (qwen1.5-32b × decode_32k),
worst roofline fraction among big dense (llama3-405b × train_4k), and the most
technique-representative (deepseek-v3-671b × train_4k, where "choose the right
parallel axis" = expert parallelism).  Baselines for the other 37 pairs are in
§Roofline.

### deepseek-v3-671b × train_4k (MoE expert parallelism)
1. **H: GSPMD can auto-partition the gather/scatter MoE dispatch.**
   Measured: 115 GiB/device, collective term **994 s**, memory term 485 s —
   GSPMD all-gathers the full token array per layer.  *Refuted.*
2. **Change: explicit shard_map two-hop all-to-all expert parallel
   (models/moe_shard_map.py), experts on "model", tokens on (dp×model).**
   994 s → **84 s collective** (−92%), 115 → 58 GiB.  *Confirmed.*
3. **H: grad-accum fp32 transients + unsharded one-hot/vocab paths dominate
   the rest.** Fixes: one-hot embedding with vocab on "model", logsumexp+
   one-hot loss (no take_along_axis gather), grad sharding constraints,
   optimizer clip in native dtype, bf16 adafactor update, per-chunk remat of
   attention q-chunks, MTP remat. 58 → **38.9 GiB**.  *Confirmed (each change
   removed an identified full-size buffer; XLA-CPU loop widening still pins
   some fp32 stacks that a TPU compile streams — see Dry-run notes).*
4. **H: FSDP weight-regather + a2a traffic scale with microbatch count.**
   mb 16→8→4: collective 125→83→**62 s** (−50%), memory 190→138→**113 s**
   (−41%), peak 38.9→43.6 GiB (+12%).  *Confirmed; shipped mb=4.*

### llama3-405b × train_4k (dense FSDP×TP)
1. **H: per-microbatch ZeRO-3 weight regathers dominate the collective term.**
   mb sweep: 16 / 8 / 4 / 2 → X = 829 / 421 / **217** / 115 s and
   M = 760 / 443 / **284** / 205 s, peak 42.6 / 44.8 / 49.2 / 58.0 GiB.
   *Confirmed — traffic ∝ mb count.*  Shipped mb=4 (X −48% vs baseline 8).
2. **H: extending FSDP over the pod axis (512-way ZeRO-3) halves persistent
   state on the multi-pod mesh.** Change: `fsdp_axes_for` shards dense-arch
   params over ("pod","data").  llama multi-pod train 65.2 → **37.5 GiB**
   (−42%), mistral-123b 22.0 → **15.2 GiB (fits v5e)**.  *Confirmed for
   dense; REFUTED for MoE* — deepseek went 38.9 → 49.5 GiB (the shard_map
   expert layers re-gather weights per layer and the pod-gather transients
   outweigh the savings), so MoE keeps pod-replicated params.
3. Note: 405B training still exceeds one 256×v5e pod's HBM under any mb
   (params+grads+opt ≥ 11 GiB before activations); the 2-pod mesh with
   pod-FSDP or pipeline parallelism is required — recorded as a deployment
   constraint, not hidden by the dry-run.

### qwen1.5-32b × decode_32k / long_500k (serving memory)
1. **H: the bf16 KV cache (64L × 128seqs × 32k × 40h × 128d = 2.7 TB global)
   is the peak driver.** Change: fp8 (e4m3) cache with bf16 attention math
   (`kv_cache_dtype`): memory term 13.9 → **7.1 s**, peak 71.7 → 36.5 GiB.
   *Confirmed.*
2. **H: the layer-scan double-buffers the cache (xs + ys stacks).**
   Change: fori_loop with in-place dynamic-update carry (model._scan_decode):
   36.5 → **21.3 GiB** (−42%).  *Confirmed.*
3. **H (long_500k, 324 GiB!): GSPMD's "involuntary full rematerialization"
   replicates the cache at the dynamic-update-slice cache write** — a traced
   write position on the 256-way-sharded sequence axis cannot be partitioned,
   so SPMD replicates the whole cache per layer.  Change: masked elementwise
   write (`attention.cache_write`: `where(iota==pos, new, cache)`), which
   partitions trivially.  long_500k peak **324 → 3.3 GiB**, memory term
   83 → **0.58 s**.  *Confirmed — the single largest win of the hillclimb;
   applied to GQA and MLA caches, all decode rows benefit.*
4. **Measurement fix (affects all decode rows):** the HLO bytes proxy counted
   dynamic-update-slice as rewriting the whole cache; now counts the touched
   slice ×2.  Memory term 7.1 → 5.3 s (closer to the ~10 GiB/device/step
   cache-read floor; the proxy still over-counts fusion-chain intermediates —
   stated as an upper bound).
5. Remaining decode_32k peak (21.3 GiB) ≈ in+out fp8 cache under XLA-CPU's
   conservative while-loop buffer reuse; the cache-size floor at this batch
   is 10.7 GiB/device — serving 128 concurrent 32k streams of a 40-head MHA
   model on 256 chips is inherently cache-bound.

### Paper-workload optimizations (beyond-paper)
* zstd-compressed binary interface: 1.20 MB → ~1.1 MB and ~1.7 → ~4.9 ms
  per actuation (CPU compression dominates at this size → **not** shipped as
  default; recorded as a refuted hypothesis).
* Chunked WKV6 (matmul form of the RWKV recurrence, mirrors the Pallas
  kernel): rwkv6-3b train_4k memory term 134,000 s → **14.5 s**, peak
  153 → 4.8 GiB.  Chunk+remat mamba scan: hymba train 38 → 7.4 GiB.
  (These ship as the *baseline* jnp path; the Pallas kernel is the TPU path.)
"""


def fmt_bytes(b):
    return f"{b/2**30:7.2f}"


def roofline_section():
    rows = []
    for f in sorted(glob.glob(str(ART / "*.json"))):
        r = json.loads(Path(f).read_text())
        if r.get("status") != "ok":
            rows.append((r, None))
            continue
        rows.append((r, r["roofline"]))
    ok = sum(1 for _, rl in rows if rl is not None)
    out = [f"\n## §Dry-run — {ok}/{len(rows)} (arch × shape × mesh) lower + "
           "compile\n"]
    out.append(
        "Every pair compiles on both meshes; artifacts in artifacts/dryrun/. "
        "`peak` = argument+temp+output−aliased bytes per device from "
        "`memory_analysis()` under the **XLA-CPU** backend, whose loop "
        "widening/scheduling over-allocates vs a TPU compile (isolated "
        "evidence: a single expert tensor's optimizer update alone reports "
        "6.4 GiB temp on CPU in any loop form); rows >16 GiB flag real "
        "deployment pressure for the 100B+ archs and are discussed in §Perf.\n")
    out.append("\n## §Roofline — single-pod (16×16) baseline, all 40 pairs\n")
    out.append("| arch | shape | peak GiB | dominant | compute s | memory s |"
               " collective s | useful | MFU bound |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r, rl in rows:
        if rl is None or r["mesh"] != "pod16x16":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{r['memory']['peak_per_device_bytes']/2**30:.2f} | "
            f"{rl['dominant']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"{rl['useful_ratio']:.2f} | {rl['mfu_bound']:.3f} |")
    out.append(
        "\nNotes: `useful` = 6·N·D (2·N·D inference) over trip-count-scaled "
        "HLO FLOPs — the gap is masked-causal attention (2×), MoE dispatch, "
        "remat recompute, and router/aux overheads.  Decode compute terms are "
        "tiny by construction (1 token); their bound is the cache-read memory "
        "term.  `memory s` is a post-fusion operand+output proxy (upper "
        "bound), not a measured HBM trace.\n")
    out.append("\n### Multi-pod (2×16×16) deltas\n")
    out.append("| arch | shape | peak GiB (1 pod → 2 pods) | collective s |")
    out.append("|---|---|---|---|")
    by_key = {}
    for r, rl in rows:
        if rl is None:
            continue
        by_key.setdefault((r["arch"], r["shape"]), {})[r["mesh"]] = (r, rl)
    for (arch, shape), d in sorted(by_key.items()):
        if "pod16x16" in d and "pod2x16x16" in d:
            r1, rl1 = d["pod16x16"]
            r2, rl2 = d["pod2x16x16"]
            out.append(
                f"| {arch} | {shape} | "
                f"{r1['memory']['peak_per_device_bytes']/2**30:.1f} → "
                f"{r2['memory']['peak_per_device_bytes']/2**30:.1f} | "
                f"{rl1['collective_s']:.2f} → {rl2['collective_s']:.2f} |")
    out.append(
        "\nThe pod axis is pure DP (params replicated across pods, gradient "
        "all-reduce only — the paper's keep-the-outer-axis-embarrassing "
        "principle), so per-device peaks drop ~2× for inference shapes and "
        "collective terms stay near the single-pod value plus one cross-pod "
        "gradient reduction for training.\n")
    return "\n".join(out)


def fig6_section():
    p = ROOT / "artifacts" / "fig6.json"
    if not p.exists():
        return ""
    import numpy as np
    res = json.loads(p.read_text())
    # compare at MATCHED consumed-episode counts (the paper's x-axis):
    # n_envs envs consume n per round, so align windows on n * round.
    budget = min(int(n) * len(h["reward"]) for n, h in res.items())
    out = ["\n### Fig. 6 — convergence invariance to N_envs\n"]
    out.append(f"(matched training budget: {budget} consumed episodes)\n")
    out.append("| n_envs | return @ start | return @ matched budget |")
    out.append("|---|---|---|")
    finals = []
    for n, h in sorted(res.items(), key=lambda kv: int(kv[0])):
        r = np.asarray(h["reward"])
        end = budget // int(n)
        k = max(2, end // 6)
        out.append(f"| {n} | {np.mean(r[:k]):+.2f} | "
                   f"{np.mean(r[end - k:end]):+.2f} |")
        finals.append(np.mean(r[end - k:end]))
    spread = max(finals) - min(finals)
    out.append(
        f"\nMatched-budget return spread across env counts: {spread:.2f}. "
        "Scaling the environment count never *hurts* convergence per "
        "consumed episode — the paper's Fig. 6 claim — and at this reduced "
        "scale MORE envs actually converge faster per episode because each "
        "PPO update sees a larger batch (80 samples/update at n_envs=2 is "
        "below PPO's useful batch scale).  Full per-round curves in "
        "artifacts/fig6.json.\n")
    return "\n".join(out)


def drl_section():
    p = ROOT / "artifacts" / "drl_cylinder.json"
    if not p.exists():
        return ("\n## §DRL-training\n\n(artifacts/drl_cylinder.json missing — "
                "run examples/drl_cylinder.py)\n")
    h = json.loads(p.read_text())
    import numpy as np
    r = np.asarray(h["reward"]) ; cd = np.asarray(h["cd"])
    n = len(r)
    k = max(3, n // 10)
    out = [f"\n## §DRL-training — reduced Fig. 5 (end-to-end, this host)\n"]
    out.append(f"{n} episodes × 6 envs, res=8 grid (176x34), 40 actuations × "
               "25 steps — a ~25× reduced version of the paper's setup "
               "(res/episode length/episodes), same physics, reward (eq. 12), "
               "action smoothing (eq. 11) and PPO.\n")
    out.append(f"* episode return: {np.mean(r[:k]):+.2f} (first {k}) → "
               f"**{np.mean(r[-k:]):+.2f}** (last {k})")
    out.append(f"* tail drag coefficient: {np.mean(cd[:k]):.3f} → "
               f"**{np.mean(cd[-k:]):.3f}** "
               f"({100*(np.mean(cd[-k:])-np.mean(cd[:k]))/np.mean(cd[:k]):+.1f}%; "
               "paper: −8% at full scale/600 episodes)")
    out.append(f"* mean wall time {np.mean(h['wall']):.1f} s/episode on one "
               "CPU core (paper's single-core OpenFOAM: ~270 s/episode)\n")
    return "\n".join(out)


def main():
    print(HEADER)
    print(VALIDATION)
    print(roofline_section())
    print(PERF)
    print(drl_section())
    print(fig6_section())
    print("""
## §Beyond-paper extensions

* **Async training prototype** (drl/async_train.py — the paper's §IV future
  work): stale-gradient PPO (update on episode e-1 while collecting e) still
  learns (tests/test_drl_async.py) and the calibrated cost model puts the
  systems gain at ~1.0-1.2x for this workload (the update is small relative
  to an episode; it grows as episodes shrink).
* **Explicit MPI-style domain decomposition** (cfd/decomp.py): the pressure
  Poisson solve under shard_map with lax.ppermute halo exchange — exactly 2
  collective-permutes per outer iteration (the paper's per-rank message
  pattern), converging like the global solve (tests/test_distributed.py).
* **Expert-parallel MoE via explicit all-to-all** (models/moe_shard_map.py),
  **fp8 KV caches**, **chunked WKV6/mamba**, **pod-axis FSDP** — see §Perf.
""")
    print("""
## §Repro commands

```bash
export PYTHONPATH=src
pytest tests/                                  # full suite
python -m benchmarks.run                       # all paper tables/figures
python -m repro.launch.dryrun --all --both-meshes
python tools/gen_experiments.py > EXPERIMENTS.md
python examples/drl_cylinder.py --episodes 80  # §DRL-training
```
""")


if __name__ == "__main__":
    main()
