"""Aggregate the per-suite benchmark artifacts into one perf-trajectory file.

Every benchmark that measures something durable writes an
``artifacts/BENCH_<name>.json`` (``bench_hybrid.py`` -> BENCH_hybrid,
``bench_kernels.py`` -> BENCH_poisson, ...).  This tool collects them into
``artifacts/BENCH_summary.json`` — one flat record per artifact with its
schema tag and every scalar it contains (nested keys dotted) — so the perf
trajectory across PRs is a single diffable file, and CI can upload the lot
as workflow artifacts.

    PYTHONPATH=src python tools/bench_report.py \
        [--dir artifacts] [--out artifacts/BENCH_summary.json]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

SUMMARY_SCHEMA = "repro.bench_summary/v1"


def flatten_scalars(obj, prefix: str = "", max_depth: int = 4) -> dict:
    """Dotted-key view of every scalar (number / short string / bool) in a
    nested JSON object.  Lists are summarized by length — per-candidate
    tables stay in the source artifact, the summary tracks the headlines."""
    out = {}
    if max_depth < 0:
        return out
    if isinstance(obj, dict):
        for k, v in obj.items():
            key = f"{prefix}.{k}" if prefix else str(k)
            out.update(flatten_scalars(v, key, max_depth - 1))
    elif isinstance(obj, list):
        if prefix:
            out[f"{prefix}.len"] = len(obj)
    elif isinstance(obj, (int, float, bool)):
        out[prefix] = obj
    elif isinstance(obj, str) and len(obj) <= 80:
        out[prefix] = obj
    return out


def summarize(art_dir: Path, include_smoke: bool = False) -> dict:
    entries = {}
    for path in sorted(art_dir.glob("BENCH_*.json")):
        # smoke artifacts (tiny-shape CI runs) never enter the committed
        # trajectory: they would overwrite real measurements with noise
        if path.name == "BENCH_summary.json" or \
                (path.name.endswith("_smoke.json") and not include_smoke):
            continue
        try:
            record = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as exc:
            entries[path.stem] = {"error": f"{type(exc).__name__}: {exc}"}
            continue
        entries[path.stem] = {
            "file": path.name,
            "schema": record.get("schema", "<untagged>"),
            "scalars": flatten_scalars(record),
        }
    return {"schema": SUMMARY_SCHEMA,
            "n_artifacts": len(entries),
            "entries": entries}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    root = Path(__file__).resolve().parent.parent
    ap.add_argument("--dir", default=str(root / "artifacts"))
    ap.add_argument("--out", default=None,
                    help="default: <dir>/BENCH_summary.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero when no artifacts were found or any "
                         "failed to parse (CI mode)")
    ap.add_argument("--include-smoke", action="store_true",
                    help="also aggregate BENCH_*_smoke.json (excluded by "
                         "default so CI smoke noise never enters the "
                         "committed trajectory)")
    args = ap.parse_args()

    art_dir = Path(args.dir)
    summary = summarize(art_dir, include_smoke=args.include_smoke)
    out = Path(args.out) if args.out else art_dir / "BENCH_summary.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(summary, indent=1, sort_keys=True))

    for name, entry in summary["entries"].items():
        if "error" in entry:
            print(f"{name}: UNREADABLE ({entry['error']})")
            continue
        scalars = entry["scalars"]
        headline = {k: v for k, v in sorted(scalars.items())
                    if "speedup" in k or k.endswith("plan.n_envs")
                    or k.endswith("plan.n_ranks") or k.endswith("backend")
                    or k.endswith("layout")}
        print(f"{name} [{entry['schema']}]: {len(scalars)} scalars"
              + (f" | {headline}" if headline else ""))
    print(f"summary -> {out} ({summary['n_artifacts']} artifacts)")

    if args.check:
        bad = [n for n, e in summary["entries"].items() if "error" in e]
        if bad or not summary["entries"]:
            raise SystemExit(f"bench summary check failed: "
                             f"{'unreadable ' + str(bad) if bad else 'no artifacts found'}")


if __name__ == "__main__":
    main()
