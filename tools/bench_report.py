"""Aggregate the per-suite benchmark artifacts into one perf dashboard.

Every benchmark that measures something durable writes an
``artifacts/BENCH_<name>.json`` (``bench_hybrid.py`` -> BENCH_hybrid,
``bench_kernels.py`` -> BENCH_poisson, ``bench_train.py`` -> BENCH_train,
...).  This tool collects them into ``artifacts/BENCH_summary.json`` — one
flat record per artifact with its schema tag and every scalar it contains
(nested keys dotted) — plus a human-readable ``BENCH_summary.md`` dashboard:
headline throughput/phase-share numbers, the projected parallel efficiency
against the paper's measured 78% / 47x at 60 cores, and the golden-physics
drift (Strouhal / C_D / C_L vs the checked-in reference).  The perf
trajectory across PRs is a single diffable file, and CI can upload the lot
as workflow artifacts.

``--check`` (CI mode) exits nonzero when no artifacts were found, any is
unreadable/untagged, a present golden-drift measurement exceeds the
golden-physics test tolerances — perf artifacts must not paper over a
physics regression — or an artifact's self-declared perf gate failed
(``gate.passed`` false, e.g. bench_megakernel's required speedup vs the
committed training baseline).

    PYTHONPATH=src python tools/bench_report.py \
        [--dir artifacts] [--out artifacts/BENCH_summary.json] [--check]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

SUMMARY_SCHEMA = "repro.bench_summary/v1"

# paper reference points the dashboard pins every run against
PAPER_TARGETS = {"efficiency_60cores": 0.78, "speedup_60cores": 47.0}
# --check fails when measured golden drift exceeds the golden-physics test
# tolerances (tests/test_golden_physics.py TOL_ST / TOL_CD / TOL_AMP)
DRIFT_TOLERANCES = {"strouhal_rel_drift": 0.015,
                    "cd_mean_rel_drift": 0.01,
                    "cl_amp_rel_drift": 0.05}

# dotted scalar keys promoted to the dashboard's headline table, with the
# format to render them in (missing keys are simply skipped per artifact)
HEADLINES = (
    ("env_steps_per_s", "{:.1f}"),
    ("gate.speedup_vs_baseline", "{:.2f}x"),
    ("gate.passed", "{}"),
    ("shares.collect", "{:.1%}"),
    ("shares.update", "{:.1%}"),
    ("shares.sink_write", "{:.1%}"),
    ("scaling_projection.projected_efficiency_60", "{:.1%}"),
    ("speedup_packed_vs_full", "{:.2f}x"),
    ("gate.measured_efficiency", "{:.1%}"),
    ("plan.n_envs", "{}"),
    ("plan.n_ranks", "{}"),
    ("plan.backend", "{}"),
    ("plan.layout", "{}"),
)


def flatten_scalars(obj, prefix: str = "", max_depth: int = 4) -> dict:
    """Dotted-key view of every scalar (number / short string / bool) in a
    nested JSON object.  Lists are summarized by length — per-candidate
    tables stay in the source artifact, the summary tracks the headlines."""
    out = {}
    if max_depth < 0:
        return out
    if isinstance(obj, dict):
        for k, v in obj.items():
            key = f"{prefix}.{k}" if prefix else str(k)
            out.update(flatten_scalars(v, key, max_depth - 1))
    elif isinstance(obj, list):
        if prefix:
            out[f"{prefix}.len"] = len(obj)
    elif isinstance(obj, (int, float, bool)):
        out[prefix] = obj
    elif isinstance(obj, str) and len(obj) <= 80:
        out[prefix] = obj
    return out


def summarize(art_dir: Path, include_smoke: bool = False) -> dict:
    entries = {}
    for path in sorted(art_dir.glob("BENCH_*.json")):
        # smoke artifacts (tiny-shape CI runs) never enter the committed
        # trajectory: they would overwrite real measurements with noise
        if path.name == "BENCH_summary.json" or \
                (path.name.endswith("_smoke.json") and not include_smoke):
            continue
        try:
            record = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as exc:
            entries[path.stem] = {"error": f"{type(exc).__name__}: {exc}"}
            continue
        entries[path.stem] = {
            "file": path.name,
            "schema": record.get("schema", "<untagged>"),
            "scalars": flatten_scalars(record),
        }
    return {"schema": SUMMARY_SCHEMA,
            "n_artifacts": len(entries),
            "paper_targets": PAPER_TARGETS,
            "entries": entries}


def gate_failures(summary: dict) -> list:
    """Artifacts whose self-declared perf gate failed (``gate.passed``
    false) — e.g. bench_megakernel's required speedup vs the committed
    training baseline.  Artifacts without a gate are simply not gated."""
    out = []
    for name, entry in summary["entries"].items():
        scalars = entry.get("scalars", {})
        if scalars.get("gate.passed") is False:
            detail = ", ".join(f"{k.split('.', 1)[1]}={v}"
                               for k, v in sorted(scalars.items())
                               if k.startswith("gate.") and k != "gate.passed")
            out.append(f"{name}: gate.passed=false ({detail})")
    return out


def drift_violations(summary: dict) -> list:
    """Golden-physics drift scalars (any artifact) beyond test tolerance."""
    out = []
    for name, entry in summary["entries"].items():
        scalars = entry.get("scalars", {})
        for key, tol in DRIFT_TOLERANCES.items():
            val = scalars.get(f"golden_drift.{key}")
            if isinstance(val, (int, float)) and abs(val) > tol:
                out.append(f"{name}: golden_drift.{key}={val:+.4f} "
                           f"(|tol|={tol})")
    return out


def render_markdown(summary: dict) -> str:
    """The dashboard: headline table, paper-target comparison, physics
    drift — one glanceable file beside the machine-readable summary."""
    lines = ["# Benchmark dashboard", "",
             f"{summary['n_artifacts']} artifacts aggregated "
             f"(schema `{summary['schema']}`).", "",
             "| artifact | schema | headline |", "|---|---|---|"]
    for name, entry in sorted(summary["entries"].items()):
        if "error" in entry:
            lines.append(f"| {name} | — | UNREADABLE: {entry['error']} |")
            continue
        scalars = entry["scalars"]
        cells = [f"{key.split('.')[-1]}={fmt.format(scalars[key])}"
                 for key, fmt in HEADLINES if key in scalars]
        lines.append(f"| {name} | `{entry['schema']}` | "
                     f"{', '.join(cells) or f'{len(scalars)} scalars'} |")

    train = next((e["scalars"] for n, e in summary["entries"].items()
                  if e.get("schema") == "repro.bench_train/v1"), None)
    lines += ["", "## Paper targets (arXiv 2402.11515)", ""]
    eff = (train or {}).get("scaling_projection.projected_efficiency_60")
    spd = (train or {}).get("scaling_projection.projected_speedup_60")
    lines.append(f"- parallel efficiency @ 60 cores: paper "
                 f"{PAPER_TARGETS['efficiency_60cores']:.0%} "
                 f"({PAPER_TARGETS['speedup_60cores']:.0f}x) | projected "
                 + (f"from this host's phase split: {eff:.1%} ({spd:.1f}x)"
                    if eff is not None else "from this host: not measured "
                    "(run benchmarks/bench_train.py)"))
    if train:
        for k in ("shares.collect", "shares.update", "shares.sink_write"):
            if k in train:
                lines.append(f"- {k}: {train[k]:.1%}")

    mega = next((e["scalars"] for n, e in summary["entries"].items()
                 if e.get("schema", "").startswith("repro.bench_megakernel/")),
                None)
    if mega:
        lines += ["", "## Fused megakernel (measured vs roofline)", ""]
        hw = mega.get("roofline.hw.name", "?")
        lines.append(
            f"- fused interval: {mega.get('env_steps_per_s', 0):.1f} "
            f"env-steps/s, {mega.get('gate.speedup_vs_baseline', 0):.2f}x "
            f"vs training baseline (gate "
            f"{'PASS' if mega.get('gate.passed') else 'FAIL'}, requires "
            f"{mega.get('gate.required_speedup', 0):.1f}x)")
        if "roofline.measured_s" in mega:
            lines.append(
                f"- roofline[{hw}]: measured "
                f"{mega['roofline.measured_s']*1e3:.1f} ms/interval vs "
                f"bound {mega.get('roofline.bound_s', 0)*1e3:.1f} ms "
                f"({mega.get('roofline.dominant', '?')}-dominated); gap "
                f"{mega.get('roofline.gap', 0):.2f}x, vs compute term "
                f"{mega.get('roofline.gap_vs_compute', 0):.2f}x")
        if "parity.u_maxabs" in mega:
            lines.append(
                f"- fused-vs-reference parity (mixed vmapped batch): "
                f"max|du|={mega['parity.u_maxabs']:.1e}, "
                f"max|dp|={mega.get('parity.p_maxabs', 0):.1e}, "
                f"max|dCd|={mega.get('parity.cd_maxabs', 0):.1e}")

    fleet_entry = next(
        (e for n, e in summary["entries"].items()
         if e.get("schema", "").startswith("repro.bench_fleet/")), None)
    if fleet_entry:
        fl = fleet_entry["scalars"]
        lines += ["", "## Fleet parallel efficiency (multi-process)", ""]
        lines.append(
            f"- measured through tools/launch_fleet.py on "
            f"{fl.get('host.cores', '?')} core(s); paper: "
            f"{PAPER_TARGETS['efficiency_60cores']:.0%} at 60 cores")
        lines.append(
            f"- gate [{fl.get('gate.metric', '?')} at "
            f"{fl.get('gate.processes', '?')} processes]: "
            f"{fl.get('gate.measured_efficiency', 0):.1%} measured vs "
            f">= {fl.get('gate.required_efficiency', 0):.0%} required -> "
            f"{'PASS' if fl.get('gate.passed') else 'FAIL'}")

    lines += ["", "## Golden-physics drift", ""]
    drifted = False
    for name, entry in sorted(summary["entries"].items()):
        scalars = entry.get("scalars", {})
        row = {k: scalars.get(f"golden_drift.{k}")
               for k in DRIFT_TOLERANCES}
        if any(v is not None for v in row.values()):
            drifted = True
            lines.append(f"- {name}: " + ", ".join(
                f"{k.replace('_rel_drift', '')} {v:+.3%}"
                for k, v in row.items() if v is not None))
    if not drifted:
        lines.append("- no drift measurements in the aggregated artifacts")
    for v in drift_violations(summary):
        lines.append(f"- **OVER TOLERANCE**: {v}")
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    root = Path(__file__).resolve().parent.parent
    ap.add_argument("--dir", default=str(root / "artifacts"))
    ap.add_argument("--out", default=None,
                    help="default: <dir>/BENCH_summary.json")
    ap.add_argument("--markdown", default=None,
                    help="dashboard output (default: <dir>/BENCH_summary.md)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero when no artifacts were found, any "
                         "failed to parse / lacks a schema tag, golden "
                         "drift exceeds test tolerance, or a perf gate "
                         "(gate.passed) failed (CI mode)")
    ap.add_argument("--include-smoke", action="store_true",
                    help="also aggregate BENCH_*_smoke.json (excluded by "
                         "default so CI smoke noise never enters the "
                         "committed trajectory)")
    args = ap.parse_args()

    art_dir = Path(args.dir)
    summary = summarize(art_dir, include_smoke=args.include_smoke)
    out = Path(args.out) if args.out else art_dir / "BENCH_summary.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(summary, indent=1, sort_keys=True))
    md = Path(args.markdown) if args.markdown else art_dir / "BENCH_summary.md"
    md.write_text(render_markdown(summary))

    for name, entry in summary["entries"].items():
        if "error" in entry:
            print(f"{name}: UNREADABLE ({entry['error']})")
            continue
        scalars = entry["scalars"]
        headline = {k: v for k, v in sorted(scalars.items())
                    if "speedup" in k or k.endswith("plan.n_envs")
                    or k.endswith("plan.n_ranks") or k.endswith("backend")
                    or k.endswith("layout")}
        print(f"{name} [{entry['schema']}]: {len(scalars)} scalars"
              + (f" | {headline}" if headline else ""))
    print(f"summary -> {out} ({summary['n_artifacts']} artifacts), "
          f"dashboard -> {md}")

    if args.check:
        problems = []
        if not summary["entries"]:
            problems.append("no artifacts found")
        problems += [f"unreadable: {n} ({e['error']})"
                     for n, e in summary["entries"].items() if "error" in e]
        problems += [f"untagged (no schema field): {n}"
                     for n, e in summary["entries"].items()
                     if e.get("schema") == "<untagged>"]
        problems += [f"golden drift over tolerance: {v}"
                     for v in drift_violations(summary)]
        problems += [f"perf gate failed: {v}"
                     for v in gate_failures(summary)]
        if problems:
            raise SystemExit("bench summary check failed:\n  "
                             + "\n  ".join(problems))


if __name__ == "__main__":
    main()
