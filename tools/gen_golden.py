"""Generate the golden physics reference for the regression tests.

Runs the uncontrolled Re=100 cylinder to developed vortex shedding, then
measures Strouhal number, mean C_D and C_L oscillation amplitude over a
fixed window, and stores BOTH the developed flow state and the reference
stats in ``tests/golden/``.  The test restarts from the stored state and
re-measures the same window, so it stays fast (~1k solver steps) while
pinning the solver's physics.

Update procedure (after an INTENTIONAL physics change — see README):

    PYTHONPATH=src python tools/gen_golden.py
    PYTHONPATH=src python tools/gen_golden.py --geometry pinball
    git add tests/golden/*.npz
    # quote old -> new St / C_D / amplitude in the commit message
"""
import argparse
from pathlib import Path

import numpy as np

from repro.cfd import solver
from repro.cfd.grid import GridConfig, build_geometry, geometry_names
from repro.cfd.validation import measure_shedding, run_uncontrolled

GOLDEN_DIR = Path(__file__).resolve().parent.parent / "tests" / "golden"

# development time (t.u.) to a saturated limit cycle.  The cylinder locks
# in by t~60; the pinball first drifts through the asymmetric deflected
# state (mean C_L ~ -0.25 around t=100) before symmetric shedding saturates
# near t~400 — measured, not guessed (amp/upcrossings flat from t=380 on)
DEVELOP_DEFAULTS = {"cylinder": 60.0, "pinball": 440.0, "tandem": 440.0}


def default_out(geometry: str, res: int) -> Path:
    stem = "cyl" if geometry == "cylinder" else geometry
    return GOLDEN_DIR / f"{stem}_re100_res{res}.npz"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--res", type=int, default=8)
    ap.add_argument("--dt", type=float, default=0.01)
    ap.add_argument("--poisson-iters", type=int, default=60)
    ap.add_argument("--geometry", default="cylinder",
                    choices=list(geometry_names()),
                    help="obstacle set to pin (grid.GEOMETRIES); the "
                         "fixture stores total forces over all bodies")
    ap.add_argument("--develop", type=float, default=None,
                    help="t.u. of uncontrolled flow before the window "
                         "(default: per-geometry saturation time, "
                         f"{DEVELOP_DEFAULTS})")
    ap.add_argument("--measure", type=float, default=10.0,
                    help="t.u. of the measurement window (stored in the npz)")
    ap.add_argument("--out", type=Path, default=None)
    args = ap.parse_args()
    out = args.out or default_out(args.geometry, args.res)
    develop = args.develop if args.develop is not None \
        else DEVELOP_DEFAULTS[args.geometry]

    cfg = GridConfig(res=args.res, dt=args.dt,
                     poisson_iters=args.poisson_iters)
    geom = build_geometry(cfg, args.geometry)
    state = solver.init_state(cfg, geom)

    n_dev = int(round(develop / cfg.dt))
    print(f"developing shedding ({args.geometry}): {n_dev} steps ...")
    state, cds, cls = run_uncontrolled(cfg, state, n_dev,
                                       geometry=args.geometry)
    print(f"  tail CD={cds[-500:].mean():.4f}  "
          f"CL range=({cls[-500:].min():+.3f}, {cls[-500:].max():+.3f})")

    n_meas = int(round(args.measure / cfg.dt))
    _, cds, cls = run_uncontrolled(cfg, state, n_meas,
                                   geometry=args.geometry)
    stats = measure_shedding(cds, cls, cfg.dt)
    print(f"  St={stats['strouhal']:.4f}  CD={stats['cd_mean']:.4f}  "
          f"CL_amp={stats['cl_amp']:.4f}  ({stats['n_periods']:.0f} periods)")

    out.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(
        out,
        u=np.asarray(state.u), v=np.asarray(state.v), p=np.asarray(state.p),
        res=args.res, dt=args.dt, poisson_iters=args.poisson_iters,
        geometry=args.geometry, meas_steps=n_meas, **stats)
    print(f"golden reference -> {out} "
          f"({out.stat().st_size / 1024:.0f} KiB)")


if __name__ == "__main__":
    main()
