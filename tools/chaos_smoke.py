"""CI chaos smoke: train end-to-end under a deterministic fault schedule.

Arms every injector the self-healing stack ships (repro.testing.faults) on
one short training run and asserts the run COMPLETES with exactly the
health counters the schedule predicts — no NaN params, no lost episodes:

  nan_env {env 1, step 1}   poisons env 1 each episode (the within-episode
                            step counter restarts per episode), so the
                            sentinel must quarantine once per episode
  grad_nan {step 5}         poisons one PPO minibatch gradient; the learner
                            guard must reject exactly that update
  sink_oserror {times 1}    the first trajectory spill fails once; the
                            bounded retry must absorb it
  watchdog {episode 1}      forces one watchdog trip; training must roll
                            back to the last healthy checkpoint and replay

Exits non-zero with a diff when any counter deviates from the schedule.

    PYTHONPATH=src python tools/chaos_smoke.py
"""
import os
import sys
import tempfile
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")
sys.path.insert(0, SRC)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax                                                  # noqa: E402
import numpy as np                                          # noqa: E402

from repro.cfd.env import EnvConfig                         # noqa: E402
from repro.cfd.grid import GridConfig                       # noqa: E402
from repro.ckpt import checkpoint as ck                     # noqa: E402
from repro.drl.engine import SinkSpec                       # noqa: E402
from repro.drl.ppo import PPOConfig                         # noqa: E402
from repro.drl.train import TrainConfig, train              # noqa: E402
from repro.testing import faults                            # noqa: E402

EPISODES = 3
# with epochs=2 x minibatches=2 the PPO step counter advances 4 per
# episode: step 5 lands in episode 1, so the skip survives the watchdog
# rollback replay of that same episode
SCHEDULE = {
    "nan_env": {"env": 1, "step": 1},
    "grad_nan": {"step": 5},
    "sink_oserror": {"times": 1},
    "watchdog": {"episode": 1},
}
EXPECTED = {
    "quarantines": EPISODES,    # nan_env fires once per episode
    "grad_skips": 1,
    "rollbacks": 1,
    "sink_retries": 1,
}


def main() -> int:
    tmp = tempfile.mkdtemp(prefix="chaos_smoke_")
    faults.configure(SCHEDULE)
    cfg = TrainConfig(
        env=EnvConfig(grid=GridConfig(res=6, dt=0.012, poisson_iters=30),
                      steps_per_action=3, actions_per_episode=3,
                      warmup_time=1.0),
        ppo=PPOConfig(epochs=2, minibatches=2),
        n_envs=2, episodes=EPISODES, seed=0,
        ckpt_dir=os.path.join(tmp, "ckpt"), ckpt_every=1,
        sink=SinkSpec(kind="binary", root=os.path.join(tmp, "spill")))

    health = {}
    hist, params = train(cfg, log_fn=print, health=health)

    errors = []
    if len(hist["reward"]) != EPISODES:
        errors.append(f"training lost episodes: {len(hist['reward'])} "
                      f"of {EPISODES} in the history")
    for k in ("reward", "cd", "cl"):
        if not np.isfinite(hist[k]).all():
            errors.append(f"non-finite history column {k!r}: {hist[k]}")
    bad = [k for k, v in health.items()
           if k in EXPECTED and v != EXPECTED[k]]
    for k in bad:
        errors.append(f"health counter {k!r}: got {health[k]}, "
                      f"schedule predicts {EXPECTED[k]}")
    if any(not np.isfinite(np.asarray(x)).all()
           for x in jax.tree.leaves(params)):
        errors.append("trained params contain non-finite values")

    # the counters must also land in the checkpoint metadata (the numbers
    # an operator sees post-mortem, without the training process)
    meta = ck.read_manifest(ck.latest_checkpoint(cfg.ckpt_dir))["metadata"]
    if meta.get("health") != health:
        errors.append(f"checkpoint metadata health {meta.get('health')} "
                      f"!= returned health {health}")

    if errors:
        print("CHAOS_SMOKE_FAILED")
        for e in errors:
            print("  -", e)
        return 1
    print(f"health counters match the fault schedule: {health}")
    print("CHAOS_SMOKE_OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
