"""CI smoke for the measured autotuner: tiny shapes, fixed seed, forced
4-device CPU host, hard assertions on the JSON artifact schema.

    python tools/autotune_smoke.py [--out artifacts/autotune_smoke.json]

Forces the device count BEFORE importing jax so the halo backend is
exercised (candidate ranks 2 and 4) even on a single-core CI runner.
"""
import argparse
import json
import os
import sys
from pathlib import Path

os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="/tmp/autotune_smoke.json")
    args = ap.parse_args()

    import jax
    from repro.cfd.grid import GridConfig
    from repro.core.autotune import AUTOTUNE_SCHEMA, autotune, \
        validate_artifact

    n_dev = len(jax.devices())
    assert n_dev == 4, f"expected 4 forced host devices, got {n_dev}"

    grid = GridConfig(res=4, dt=0.01, poisson_iters=20)   # nx=88: 2|4 slabs
    rp = autotune(grid=grid, smoke=True, seed=0, artifact=args.out)
    rec = json.loads(Path(args.out).read_text())
    validate_artifact(rec)

    assert rec["schema"] == AUTOTUNE_SCHEMA
    ranks = sorted(int(r) for r in rec["measured"]["t_step_ranks"])
    assert ranks == [1, 2, 4], f"expected halo ranks 1/2/4 measured: {ranks}"
    assert all(v > 0 for v in rec["measured"]["t_step_ranks"].values())
    assert rec["plan"]["n_envs"] * rec["plan"]["n_ranks"] <= 4
    assert rec["plan"]["utilization"] == 1.0, rec["plan"]
    assert len(rec["candidates"]) >= 3
    # v4: the fleet cost term is always present; a standalone smoke run is
    # single-process, so the gather timing is the flagged estimate and the
    # optimizer must not plan hosts it cannot execute
    assert rec["measured"]["t_interhost"]["estimated"] is True
    assert rec["plan"]["n_processes"] == 1, rec["plan"]
    print(f"autotune smoke OK: {rp.describe()}")
    print(f"artifact -> {args.out}")


if __name__ == "__main__":
    main()
