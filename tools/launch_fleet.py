"""Single-command local fleet launcher with elastic shrink + resume.

Forks N runner processes on this box, each a full jax process of one
``jax.distributed`` fleet (``repro.launch.distributed``), so the engine's
"data" axis spans processes exactly as it would span hosts on a cluster.
Every runner gets the PINNED ``--xla_force_host_platform_device_count`` =
the plan's ``n_total`` (the bitwise contract: XLA CPU codegen differs by
forced device count, so the count must not change with the fleet size).

The launcher doubles as the elastic supervisor: runners heartbeat once per
episode, and when one dies (SIGKILL fast path: child exit) or hangs
(heartbeat older than ``--heartbeat-timeout``), the supervisor kills the
survivors, shrinks the fleet to the next process count that still divides
the plan, and relaunches with ``resume="auto"`` — training continues from
the latest durable checkpoint on the smaller fleet, same plan, same bits.

    PYTHONPATH=src python tools/launch_fleet.py --processes 2 --episodes 4
    PYTHONPATH=src python tools/launch_fleet.py --smoke      # CI gate

Machine-readable lines on stdout (tests/bench parse these):
    FLEET_SHRINK gen=<g> procs=<old>-><new> reason=<exit|stale>
    FLEET_STATS {json}          (from process 0: bench throughput in
                                 --mode bench; train health counters —
                                 quarantines / grad_skips / rollbacks /
                                 sink_retries — in --mode train)
    FLEET_TIMING process=<p> rollout_s=<s> gather_s=<s>
                                (--mode bench with REPRO_FLEET_TIMING=1:
                                 per-process rollout/gather wall split)
    FLEET_DONE episodes=<E>     (supervisor, after the fleet finishes)
"""
import argparse
import json
import os
import signal
import socket
import subprocess
import sys
import time
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")
sys.path.insert(0, SRC)

# the test hook: a runner whose env carries this SIGKILLs ITSELF after that
# many episodes — a deterministic stand-in for a preempted/OOM-killed host
ENV_DIE_AFTER = "REPRO_TEST_DIE_AFTER"


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--processes", type=int, default=2,
                    help="fleet size to start with")
    ap.add_argument("--plan", default="4,4,1", metavar="NT,NE,NR",
                    help="ParallelPlan n_total,n_envs,n_ranks (the forced "
                         "device count is pinned to n_total on EVERY runner)")
    ap.add_argument("--n-envs", type=int, default=None,
                    help="env batch size (default: the plan's n_envs)")
    ap.add_argument("--episodes", type=int, default=4)
    ap.add_argument("--mode", choices=("train", "bench"), default="train")
    ap.add_argument("--measure-episodes", type=int, default=3,
                    help="bench mode: timed collects after one warmup")
    ap.add_argument("--no-gather", action="store_true",
                    help="bench mode: time the distributed rollout WITHOUT "
                         "the trajectory all-gather — the no-comms "
                         "oversubscription baseline benchmarks divide by")
    ap.add_argument("--res", type=int, default=6, help="grid resolution")
    ap.add_argument("--dt", type=float, default=0.012)
    ap.add_argument("--poisson-iters", type=int, default=30)
    ap.add_argument("--steps-per-action", type=int, default=3)
    ap.add_argument("--actions-per-episode", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workdir", default=None,
                    help="heartbeats/checkpoints/logs root (default: tmp)")
    ap.add_argument("--sink-root", default=None,
                    help="dataset sink root: each runner writes its env "
                         "shard into part{NNN}/ (trajectory_dataset)")
    ap.add_argument("--heartbeat-timeout", type=float, default=120.0,
                    help="seconds without a heartbeat before a runner "
                         "counts as hung")
    ap.add_argument("--launch-timeout", type=float, default=900.0,
                    help="hard wall-clock cap per fleet generation")
    ap.add_argument("--max-generations", type=int, default=4,
                    help="shrink-and-resume attempts before giving up")
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: tiny 2-process train, asserts "
                         "completion (overrides the knobs above)")
    ap.add_argument("--kill-process", type=int, default=None,
                    help="test hook: this runner id self-SIGKILLs ...")
    ap.add_argument("--kill-episode", type=int, default=None,
                    help="... after completing this many episodes")
    ap.add_argument("--role", choices=("supervisor", "runner"),
                    default="supervisor", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.smoke:
        args.processes = min(args.processes, 2)
        args.mode = "train"
        args.episodes = 2
    args.plan_tuple = tuple(int(x) for x in args.plan.split(","))
    if len(args.plan_tuple) != 3:
        ap.error(f"--plan must be n_total,n_envs,n_ranks (got {args.plan!r})")
    if args.n_envs is None:
        args.n_envs = args.plan_tuple[1]
    return args


# ---------------------------------------------------------------------------
# runner role — executes inside each fleet process
# ---------------------------------------------------------------------------

def run_runner(args) -> None:
    from repro.launch import distributed as dist

    info = dist.initialize_fleet()       # from the REPRO_* env vars

    from repro.cfd.env import EnvConfig
    from repro.cfd.grid import GridConfig
    from repro.core.plan import ParallelPlan
    from repro.drl.engine import SinkSpec
    from repro.drl.ppo import PPOConfig
    from repro.drl.train import TrainConfig, train

    die_after = int(os.environ.get(ENV_DIE_AFTER, "0"))
    hb = dist.HeartbeatReporter(info.process_id)

    def on_episode(traj, metrics):
        hb(traj, metrics)
        if die_after and hb.episodes >= die_after:
            os.kill(os.getpid(), signal.SIGKILL)    # never returns

    plan = ParallelPlan(*args.plan_tuple)
    workdir = Path(args.workdir)
    sink = None
    if args.sink_root:
        sink = SinkSpec(kind="dataset", root=args.sink_root)
    cfg = TrainConfig(
        env=EnvConfig(grid=GridConfig(res=args.res, dt=args.dt,
                                      poisson_iters=args.poisson_iters),
                      steps_per_action=args.steps_per_action,
                      actions_per_episode=args.actions_per_episode,
                      warmup_time=1.0),
        ppo=PPOConfig(epochs=2, minibatches=2),
        n_envs=args.n_envs, episodes=args.episodes, seed=args.seed,
        plan=plan, ckpt_dir=str(workdir / "ckpt"), ckpt_every=1,
        ckpt_async=False, resume="auto", sink=sink)

    if args.mode == "bench":
        run_runner_bench(args, cfg, info, on_episode)
        return
    health = {}
    hist, _ = train(cfg, log_fn=print if info.is_coordinator else None,
                    on_episode=on_episode, health=health)
    if info.is_coordinator:
        print("FLEET_STATS " + json.dumps({
            "mode": "train",
            "processes": info.num_processes,
            "episodes": len(hist["reward"]),
            "health": health,
        }), flush=True)
    print(f"RUNNER_DONE process={info.process_id} "
          f"episodes={len(hist['reward'])}", flush=True)


def run_runner_bench(args, cfg, info, on_episode) -> None:
    """Rollout-throughput probe: one warmup collect (compile), then
    ``--measure-episodes`` timed collects.  Process 0 prints FLEET_STATS."""
    import jax

    from repro.cfd.env import CylinderEnv
    from repro.drl import networks
    from repro.drl.engine import (EngineConfig, RolloutEngine,
                                  broadcast_env_state, place_env_batch)
    from repro.drl.ppo import PPOConfig

    from repro.core.autotune import resolve_plan
    resolved = resolve_plan(cfg.plan, grid=cfg.env.grid, smoke=True)
    mesh = resolved.build_mesh()
    env = CylinderEnv(cfg.env, backend=resolved.backend, mesh=mesh)
    st0, obs0 = env.reset()
    st_b, obs_b = broadcast_env_state(st0, obs0, cfg.n_envs)
    engine = RolloutEngine.for_env(
        env, EngineConfig(n_envs=cfg.n_envs,
                          horizon=cfg.env.actions_per_episode,
                          n_ranks=resolved.n_ranks, fleet=True), mesh=mesh)
    pcfg = networks.PolicyConfig(obs_dim=int(obs_b.shape[-1]))
    params, _, _, key = engine.init(pcfg, PPOConfig(), cfg.seed)
    st_b = place_env_batch(mesh, st_b, engine.cfg.n_ranks)
    obs_b = place_env_batch(mesh, obs_b, 1)

    key, kw = jax.random.split(key)
    if args.no_gather:
        engine.rollout_local(params, st_b, obs_b, kw)   # warmup: compile
    else:
        engine.collect(params, st_b, obs_b, kw)         # warmup: compile
    engine.stats.pop("rollout_s", None)
    engine.stats.pop("gather_s", None)
    t0 = time.perf_counter()
    for _ in range(args.measure_episodes):
        key, kr = jax.random.split(key)
        if args.no_gather:
            traj = engine.rollout_local(params, st_b, obs_b, kr)
            on_episode(traj, None)
        else:
            batch, traj = engine.collect(params, st_b, obs_b, kr)
            on_episode(traj, None)
            jax.block_until_ready(batch)
    elapsed = time.perf_counter() - t0
    if os.environ.get("REPRO_FLEET_TIMING"):
        print(f"FLEET_TIMING process={info.process_id} "
              f"rollout_s={engine.stats.get('rollout_s', 0.0):.4f} "
              f"gather_s={engine.stats.get('gather_s', 0.0):.4f}",
              flush=True)
    env_steps = (args.measure_episodes * cfg.n_envs
                 * cfg.env.actions_per_episode * cfg.env.steps_per_action)
    if info.is_coordinator:
        print("FLEET_STATS " + json.dumps({
            "processes": info.num_processes,
            "episodes": args.measure_episodes,
            "n_envs": cfg.n_envs,
            "gather": not args.no_gather,
            "env_steps": env_steps,
            "elapsed_s": elapsed,
            "env_steps_per_sec": env_steps / elapsed,
        }), flush=True)


# ---------------------------------------------------------------------------
# supervisor role — fork, watch, shrink, resume
# ---------------------------------------------------------------------------

def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _shrink(n_total: int, n_ranks: int, procs: int) -> int:
    """Next viable fleet size below ``procs``: must divide n_total with
    each process holding whole envs (halo stays intra-host)."""
    for p in range(procs - 1, 0, -1):
        if n_total % p == 0 and (n_total // p) % n_ranks == 0:
            return p
    return 0


def _spawn(args, procs: int, gen: int, workdir: Path):
    from repro.launch.distributed import fleet_env
    port = _free_port()
    hb_dir = workdir / f"hb_gen{gen}"
    hb_dir.mkdir(parents=True, exist_ok=True)
    runner_argv = [
        sys.executable, os.path.abspath(__file__), "--role", "runner",
        "--plan", args.plan, "--n-envs", str(args.n_envs),
        "--episodes", str(args.episodes), "--mode", args.mode,
        "--measure-episodes", str(args.measure_episodes),
        "--res", str(args.res), "--dt", str(args.dt),
        "--poisson-iters", str(args.poisson_iters),
        "--steps-per-action", str(args.steps_per_action),
        "--actions-per-episode", str(args.actions_per_episode),
        "--seed", str(args.seed), "--workdir", str(workdir),
    ]
    if args.sink_root:
        runner_argv += ["--sink-root", args.sink_root]
    if args.no_gather:
        runner_argv += ["--no-gather"]
    children = []
    for pid in range(procs):
        env = fleet_env(coordinator=f"127.0.0.1:{port}",
                        num_processes=procs, process_id=pid,
                        n_total_devices=args.plan_tuple[0],
                        heartbeat_dir=str(hb_dir))
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        env.setdefault("PYTHONUNBUFFERED", "1")
        if (gen == 0 and args.kill_process == pid
                and args.kill_episode is not None):
            env[ENV_DIE_AFTER] = str(args.kill_episode)
        log = open(workdir / f"runner_gen{gen}_p{pid:03d}.log", "wb")
        children.append((subprocess.Popen(
            runner_argv, env=env,
            stdout=subprocess.PIPE if pid == 0 else log,
            stderr=subprocess.STDOUT if pid == 0 else log), log))
    return children, hb_dir


def _drain_proc0(children, sink):
    """Forward process 0's buffered stdout lines (non-blockingly sized
    reads are overkill here: proc 0's pipe is drained after exit, and
    FLEET_STATS/train logs are tiny)."""
    p0 = children[0][0]
    out, _ = p0.communicate()
    for line in (out or b"").decode(errors="replace").splitlines():
        print(line, flush=True)
        sink.append(line)


def run_supervisor(args) -> int:
    import tempfile
    from repro.launch.distributed import stale_processes

    workdir = Path(args.workdir or tempfile.mkdtemp(prefix="fleet_"))
    workdir.mkdir(parents=True, exist_ok=True)
    n_total, _, n_ranks = args.plan_tuple
    procs, gen = args.processes, 0
    if n_total % procs or (n_total // procs) % n_ranks:
        sys.exit(f"--processes {procs} does not divide plan {args.plan} "
                 f"with intra-host halos; viable sizes divide n_total="
                 f"{n_total} with whole envs per process")
    lines: list = []

    while True:
        print(f"fleet gen={gen}: {procs} process(es), plan {args.plan}, "
              f"mode {args.mode} -> {workdir}", flush=True)
        children, hb_dir = _spawn(args, procs, gen, workdir)
        deadline = time.time() + args.launch_timeout
        reason = None
        while reason is None:
            states = [c.poll() for c, _ in children]
            if all(s == 0 for s in states):
                break                                   # clean finish
            if any(s not in (None, 0) for s in states):
                reason = "exit"
            elif stale_processes(str(hb_dir), procs,
                                 args.heartbeat_timeout):
                reason = "stale"
            elif time.time() > deadline:
                reason = "timeout"
            else:
                time.sleep(0.2)
        if reason is None:                              # success
            _drain_proc0(children, lines)
            for _, log in children:
                log.close()
            break
        for c, log in children:                         # kill survivors
            if c.poll() is None:
                c.kill()
            c.wait()
            log.close()
        dead = [i for i, (c, _) in enumerate(children) if c.returncode != 0]
        nxt = _shrink(n_total, n_ranks, procs)
        gen += 1
        if nxt == 0 or gen >= args.max_generations or reason == "timeout":
            sys.exit(f"fleet failed (reason={reason}, dead runners {dead}) "
                     f"and cannot shrink further; logs in {workdir}")
        print(f"FLEET_SHRINK gen={gen} procs={procs}->{nxt} "
              f"reason={reason}", flush=True)
        procs = nxt
        # resume="auto" in every runner picks up the latest checkpoint

    done = [line for line in lines if line.startswith("RUNNER_DONE")]
    episodes = (int(done[-1].rsplit("=", 1)[1]) if done
                else args.episodes if args.mode == "train" else 0)
    print(f"FLEET_DONE episodes={episodes}", flush=True)
    if args.smoke:
        assert episodes >= args.episodes, (episodes, args.episodes)
        print("FLEET_SMOKE_OK", flush=True)
    return 0


def main(argv=None) -> int:
    args = parse_args(argv)
    if args.role == "runner":
        run_runner(args)
        return 0
    return run_supervisor(args)


if __name__ == "__main__":
    sys.exit(main())
