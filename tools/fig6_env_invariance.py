"""Paper Fig. 6: reward convergence is invariant to the number of parallel
environments.  Trains the same reduced AFC problem with different N_envs and
the SAME number of policy updates; writes artifacts/fig6.json.

    PYTHONPATH=src python tools/fig6_env_invariance.py --episodes 30
"""
import argparse
import json
from pathlib import Path

import numpy as np

from repro.cfd.env import EnvConfig
from repro.cfd.grid import GridConfig
from repro.drl.ppo import PPOConfig
from repro.drl.train import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=30)
    ap.add_argument("--envs", type=int, nargs="+", default=[2, 6])
    ap.add_argument("--out", default="artifacts/fig6.json")
    args = ap.parse_args()

    results = {}
    for n in args.envs:
        cfg = TrainConfig(
            env=EnvConfig(grid=GridConfig(res=8, dt=0.01, poisson_iters=50),
                          steps_per_action=25, actions_per_episode=40,
                          warmup_time=20.0),
            ppo=PPOConfig(lr=3e-4, epochs=6, minibatches=4,
                          entropy_coef=0.005),
            n_envs=n, episodes=args.episodes, seed=0)
        hist, _ = train(cfg, log_fn=lambda s: print(f"[envs={n}] {s}",
                                                    flush=True))
        results[str(n)] = {k: np.asarray(v).tolist() for k, v in hist.items()}
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(results, indent=1))
    for n, h in results.items():
        r = np.asarray(h["reward"])
        k = max(3, len(r) // 6)
        print(f"n_envs={n}: return {np.mean(r[:k]):+.2f} -> "
              f"{np.mean(r[-k:]):+.2f}")
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
