"""Mixture-of-Experts: top-k router + capacity-bounded sort-based dispatch.

Two dispatch implementations (cfg.moe.impl):
  'gspmd'     — gather/scatter into an expert-major buffer; experts are sharded
                on the "model" axis and XLA/GSPMD inserts the cross-device
                movement.  Baseline.
  'shard_map' — explicit lax.all_to_all expert parallelism (optimized path,
                §Perf); see core/runner.py for how it is swapped in.

Router: softmax gate, top-k, probs renormalized over the selected experts
(DeepSeek-V3 style), plus the standard load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import act_sharding
from repro.models.layers import dense_init, dtype_of


def init_moe(cfg: ModelConfig, key):
    m = cfg.moe
    dt = dtype_of(cfg.param_dtype)
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    E, D, F = m.num_experts, cfg.d_model, m.d_ff_expert
    p = {
        "w_router": dense_init(kr, (D, E), jnp.float32),  # router in fp32
        "we1": dense_init(k1, (E, D, F), dt),
        "we3": dense_init(k3, (E, D, F), dt),
        "we2": dense_init(k2, (E, F, D), dt),
    }
    if m.num_shared_experts:
        Fs = F * m.num_shared_experts
        p["shared"] = {
            "w1": dense_init(jax.random.fold_in(ks, 0), (D, Fs), dt),
            "w3": dense_init(jax.random.fold_in(ks, 1), (D, Fs), dt),
            "w2": dense_init(jax.random.fold_in(ks, 2), (Fs, D), dt),
        }
    return p


def router(cfg: ModelConfig, p, xf) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """xf: (T, D) -> (top_p (T,K), top_idx (T,K), aux_loss scalar)."""
    m = cfg.moe
    logits = xf.astype(jnp.float32) @ p["w_router"]
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    top_p, top_idx = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / (jnp.sum(top_p, axis=-1, keepdims=True) + 1e-9)
    # load-balance auxiliary loss (Switch-style): E * sum_e f_e * P_e
    E = m.num_experts
    one_hot = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)  # (T, K, E)
    f_e = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)         # fraction routed
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * p_e) * m.router_aux_weight
    return top_p, top_idx, aux


def _capacity(cfg: ModelConfig, T: int) -> int:
    m = cfg.moe
    c = int(m.top_k * T * m.capacity_factor / m.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


MOE_TOKEN_WAVE = 65_536  # max tokens dispatched at once (buffer HBM bound)


def apply_moe(cfg: ModelConfig, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss).

    Long inputs (32k prefill = 1M tokens) are processed in token *waves* of
    MOE_TOKEN_WAVE via lax.scan so the (E, C, D) dispatch buffer stays
    HBM-bounded — the grouped-GEMM-in-waves pattern of production MoE stacks.
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    if m.impl == "shard_map":
        from repro.models.moe_shard_map import apply_moe_expert_parallel
        return apply_moe_expert_parallel(cfg, p, x)
    s_wave = max(1, MOE_TOKEN_WAVE // B)
    if T > MOE_TOKEN_WAVE and S % s_wave == 0 and S > s_wave:
        nw = S // s_wave
        # wave along the sequence dim: batch sharding (dp) is preserved
        xw = jnp.moveaxis(x.reshape(B, nw, s_wave, D), 1, 0)

        def wave(_, xc):
            out, aux = _moe_dispatch(cfg, p, xc)
            return None, (out, aux)

        _, (outs, auxs) = jax.lax.scan(wave, None, xw)
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, D)
        return out, jnp.mean(auxs)
    return _moe_dispatch(cfg, p, x)


def _moe_dispatch(cfg: ModelConfig, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    m = cfg.moe
    cd = dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    top_p, top_idx, aux = router(cfg, p, xf)
    K = m.top_k
    E = m.num_experts
    C = _capacity(cfg, T)

    flat_expert = top_idx.reshape(T * K)
    flat_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_prob = top_p.reshape(T * K)

    order = jnp.argsort(flat_expert)                         # stable
    e_s = flat_expert[order]
    t_s = flat_token[order]
    p_s = flat_prob[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_expert].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * K, dtype=jnp.int32) - starts[e_s]
    keep = pos < C
    pos_c = jnp.where(keep, pos, 0)

    buf = jnp.zeros((E, C, D), cd)
    gathered = xf.astype(cd)[t_s] * keep[:, None].astype(cd)
    buf = buf.at[e_s, pos_c].add(gathered)                  # scatter-dispatch
    # expert-parallel: the E axis lives on "model" (GSPMD inserts the
    # token movement; the explicit all-to-all variant is the §Perf path)
    buf = act_sharding.constrain(buf, "model", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["we1"].astype(cd)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["we3"].astype(cd))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["we2"].astype(cd))

    contrib = out_buf[e_s, pos_c] * (p_s * keep).astype(cd)[:, None]
    y = jnp.zeros((T, D), cd).at[t_s].add(contrib)

    if m.num_shared_experts and "shared" in p:
        sp = p["shared"]
        hs = jax.nn.silu(xf.astype(cd) @ sp["w1"].astype(cd)) * (
            xf.astype(cd) @ sp["w3"].astype(cd))
        y = y + hs @ sp["w2"].astype(cd)
    return y.reshape(B, S, D), aux
