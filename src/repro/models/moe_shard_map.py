"""Expert-parallel MoE via shard_map + lax.all_to_all (the production path).

GSPMD cannot partition the gather/scatter dispatch of moe.py: it all-gathers
the full token array per layer (measured: deepseek-v3 train_4k baseline hits
88 GiB/device and a 994 s collective term — artifacts/dryrun).  This module
implements the classic two-hop expert-parallel dispatch explicitly:

  1. tokens live sharded over (dp x "model"); experts over "model" (E/EP each)
  2. each device packs its tokens into per-target-rank capacity buckets
  3. lax.all_to_all along "model" delivers tokens to expert owners
  4. local sort-dispatch -> grouped GEMMs over the E/EP local experts
  5. results return through the inverse all_to_all; probs applied at origin

Weights stay FSDP-sharded over "data" and are all-gathered per layer
(ZeRO-style).  Numerics match moe._moe_dispatch up to capacity-drop patterns;
tests use generous capacity for exact comparison.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.models import act_sharding
from repro.models.layers import dtype_of


def _pack(x, groups, n_groups, capacity, payload):
    """Pack payload rows into (n_groups, capacity, ...) buckets by group id.

    Returns (buckets, slot_group, slot_pos, keep) so the caller can route
    results back to the original rows."""
    n = groups.shape[0]
    order = jnp.argsort(groups)
    g_s = groups[order]
    counts = jnp.zeros((n_groups,), jnp.int32).at[groups].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(n, dtype=jnp.int32) - starts[g_s]
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, 0)
    buckets = jnp.zeros((n_groups, capacity) + payload.shape[1:],
                        payload.dtype)
    buckets = buckets.at[g_s, pos_c].add(
        payload[order] * keep.reshape((-1,) + (1,) * (payload.ndim - 1)
                                      ).astype(payload.dtype))
    return buckets, order, g_s, pos_c, keep


def _unpack(buckets, order, g_s, pos_c, keep, n):
    out = buckets[g_s, pos_c] * keep.reshape(
        (-1,) + (1,) * (buckets.ndim - 2)).astype(buckets.dtype)
    return jnp.zeros((n,) + buckets.shape[2:], buckets.dtype
                     ).at[order].add(out)


def apply_moe_expert_parallel(cfg: ModelConfig, p, x
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux).  Requires an active mesh with a "model"
    axis dividing num_experts; otherwise falls back to the gather/scatter
    implementation."""
    from repro.models.moe import _moe_dispatch, router
    mesh = act_sharding.current_mesh()
    m = cfg.moe
    if (mesh is None or "model" not in mesh.shape
            or m.num_experts % mesh.shape["model"]):
        return _moe_dispatch(cfg, p, x)
    EP = mesh.shape["model"]
    if EP == 1:
        return _moe_dispatch(cfg, p, x)
    E_loc = m.num_experts // EP
    cd = dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    dp = act_sharding.dp(mesh)
    dp_t = dp if isinstance(dp, tuple) else (dp,)
    n_dp = 1
    for a in dp_t:
        n_dp *= mesh.shape[a]
    # tokens per device after (dp x model) sharding of (B, S)
    if B % n_dp or S % EP:
        return _moe_dispatch(cfg, p, x)
    T_loc = (B // n_dp) * (S // EP)
    K = m.top_k
    c_send = max(8, -(-int(T_loc * K / EP * m.capacity_factor) // 8) * 8)
    c_exp = max(8, -(-int(EP * c_send / E_loc * m.capacity_factor) // 8) * 8)

    has_shared = m.num_shared_experts and "shared" in p

    def body(x_loc, w_router, we1, we3, we2, *shared_w):
        # x_loc: (B_loc, S_loc, D); weights FSDP-sharded on "data"
        Bl, Sl, _ = x_loc.shape
        xf = x_loc.reshape(-1, D).astype(cd)
        n = xf.shape[0]
        wr = jax.lax.all_gather(w_router, "data", axis=0, tiled=True)
        logits = xf.astype(jnp.float32) @ wr
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_idx = jax.lax.top_k(probs, K)
        top_p = top_p / (jnp.sum(top_p, axis=-1, keepdims=True) + 1e-9)
        # load-balance aux (local estimate, averaged over the mesh)
        one_hot = jax.nn.one_hot(top_idx, m.num_experts, dtype=jnp.float32)
        f_e = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)
        p_e = jnp.mean(probs, axis=0)
        aux = m.num_experts * jnp.sum(f_e * p_e) * m.router_aux_weight
        aux = jax.lax.pmean(aux, "model")
        for a in dp_t:
            aux = jax.lax.pmean(aux, a)

        flat_e = top_idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(n, dtype=jnp.int32), K)
        flat_p = top_p.reshape(-1).astype(cd)
        target = flat_e // E_loc                       # owning model-rank
        payload = xf[flat_t]
        send, order, g_s, pos_c, keep = _pack(payload, target, EP, c_send,
                                              payload)
        eid_payload = (flat_e % E_loc).astype(jnp.float32)[:, None]
        send_eid, *_ = _pack(eid_payload, target, EP, c_send, eid_payload)
        # two-hop: deliver buckets to expert owners
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=True)
        recv_eid = jax.lax.all_to_all(send_eid, "model", split_axis=0,
                                      concat_axis=0, tiled=True)
        rx = recv.reshape(EP * c_send, D)
        re = recv_eid.reshape(EP * c_send).astype(jnp.int32)
        # local dispatch over E_loc experts
        buf, order2, e2_s, pos2_c, keep2 = _pack(rx, re, E_loc, c_exp, rx)
        # FSDP gather of local expert weights along "data"
        w1 = jax.lax.all_gather(we1, "data", axis=1, tiled=True)  # (E_loc,D,F)
        w3 = jax.lax.all_gather(we3, "data", axis=1, tiled=True)
        w2 = jax.lax.all_gather(we2, "data", axis=2, tiled=True)  # (E_loc,F,D)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1.astype(cd)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, w3.astype(cd))
        out_buf = jnp.einsum("ecf,efd->ecd", h, w2.astype(cd))
        back_tokens = _unpack(out_buf, order2, e2_s, pos2_c, keep2,
                              EP * c_send)
        back = jax.lax.all_to_all(back_tokens.reshape(EP, c_send, D),
                                  "model", split_axis=0, concat_axis=0,
                                  tiled=True)
        contrib = _unpack(back, order, g_s, pos_c, keep, n * K)
        y = jnp.zeros((n, D), cd).at[flat_t].add(contrib * flat_p[:, None])
        if has_shared:
            sw1 = jax.lax.all_gather(shared_w[0], "data", axis=0, tiled=True)
            sw3 = jax.lax.all_gather(shared_w[1], "data", axis=0, tiled=True)
            sw2 = jax.lax.all_gather(shared_w[2], "data", axis=1, tiled=True)
            hs = jax.nn.silu(xf @ sw1.astype(cd)) * (xf @ sw3.astype(cd))
            y = y + hs @ sw2.astype(cd)
        return y.reshape(Bl, Sl, D), aux

    x_spec = P(dp, "model", None)
    in_specs = [x_spec, P("data", None),
                P("model", "data", None), P("model", "data", None),
                P("model", None, "data")]
    args = [x, p["w_router"], p["we1"], p["we3"], p["we2"]]
    if has_shared:
        in_specs += [P("data", None), P("data", None), P(None, "data")]
        args += [p["shared"]["w1"], p["shared"]["w3"], p["shared"]["w2"]]
    fn = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=(x_spec, P()), check_vma=False)
    return fn(*args)
