"""DeepSeek-V3 Multi-head Latent Attention (MLA).  [arXiv:2412.19437]

Prefill/train uses the expanded form; decode uses the *absorbed* form against a
compressed cache (c_kv latent + shared rope key), which is what makes the
decode KV cache tiny: (kv_lora_rank + rope_dim) per token instead of
2*H*dh — 576 vs 32768 floats/token for the 671B config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_norm, apply_rope, dense_init, dtype_of


def init_mla(cfg: ModelConfig, key):
    m = cfg.mla
    dt = dtype_of(cfg.param_dtype)
    H = cfg.num_heads
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": dense_init(k1, (cfg.d_model, m.q_lora_rank), dt),
        "q_norm": {"scale": jnp.ones((m.q_lora_rank,), dt)},
        "wq_b": dense_init(k2, (m.q_lora_rank, H * qk), dt),
        "wkv_a": dense_init(k3, (cfg.d_model, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), dt)},
        "wkv_b": dense_init(k4, (m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim)), dt),
        "wo": dense_init(k5, (H * m.v_head_dim, cfg.d_model), dt),
    }


def _rms(cfg, p, x):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)).astype(x.dtype)


def _project(cfg: ModelConfig, p, x):
    """-> q_nope (B,S,H,dn), q_rope (B,S,H,dr), c_kv (B,S,c), k_rope (B,S,dr)."""
    m = cfg.mla
    cd = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    H = cfg.num_heads
    x = x.astype(cd)
    q = _rms(cfg, p["q_norm"], x @ p["wq_a"].astype(cd)) @ p["wq_b"].astype(cd)
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    kv = x @ p["wkv_a"].astype(cd)
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = _rms(cfg, p["kv_norm"], c_kv)
    return q_nope, q_rope, c_kv, k_rope


def apply_mla(cfg: ModelConfig, p, x, positions) -> jnp.ndarray:
    """Train/prefill, expanded form.

    The expanded MLA is MHA with per-head keys [k_nope ; shared k_rope]; the
    softmax scale 1/sqrt(dn + dr) coincides with the concatenated head dim,
    so the memory-safe chunked attention core from attention.py applies
    directly (no (B,H,S,S) materialization)."""
    from repro.models.attention import chunked_gqa_attend
    m = cfg.mla
    cd = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = _project(cfg, p, x)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,dr)
    kv = (c_kv @ p["wkv_b"].astype(cd)).reshape(
        B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)       # (B,S,H,dn+dr)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))],
        axis=-1)
    out = chunked_gqa_attend(q_full, k_full, v)               # causal
    out = out.reshape(B, S, -1)
    return out @ p["wo"].astype(cd)


def init_mla_cache(cfg: ModelConfig, batch: int, seq_len: int, layers: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((layers, batch, seq_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((layers, batch, seq_len, m.qk_rope_head_dim), dtype),
    }


def decode_mla(cfg: ModelConfig, p, x, c_cache, r_cache, pos):
    """Absorbed-form decode.  x: (B,1,D); c_cache: (B,S,c); r_cache: (B,S,dr)."""
    m = cfg.mla
    cd = dtype_of(cfg.compute_dtype)
    B = x.shape[0]
    H = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = _project(cfg, p, x)      # S==1
    pvec = jnp.full((B, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope, pvec, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], pvec, cfg.rope_theta)[:, :, 0, :]
    from repro.models.attention import cache_write
    c_cache = cache_write(c_cache, c_kv, pos)
    r_cache = cache_write(r_cache, k_rope, pos)
    # absorb W_uk into the query: q_lat (B,1,H,c)
    w_uk = p["wkv_b"].astype(cd).reshape(
        m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)[..., :m.qk_nope_head_dim]
    w_uv = p["wkv_b"].astype(cd).reshape(
        m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)[..., m.qk_nope_head_dim:]
    q_lat = jnp.einsum("bshd,chd->bshc", q_nope, w_uk)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    S = c_cache.shape[1]
    logits = (jnp.einsum("bshc,btc->bhst", q_lat, c_cache.astype(cd))
              + jnp.einsum("bshd,btd->bhst", q_rope, r_cache.astype(cd)))
    logits = logits.astype(jnp.float32) * scale
    mask = (jnp.arange(S)[None, None, None, :] <= pos)
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out_lat = jnp.einsum("bhst,btc->bshc", w.astype(cd), c_cache.astype(cd))
    out = jnp.einsum("bshc,chd->bshd", out_lat, w_uv).reshape(B, 1, -1)
    return out @ p["wo"].astype(cd), c_cache, r_cache
