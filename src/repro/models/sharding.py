"""Sharding rules: map parameter/activation logical dims onto the production mesh.

Mesh axes (launch/mesh.py):
  "pod"   — pure data parallelism across pods.  Parameters are REPLICATED across
            pods on purpose: the paper's central finding is that the outer
            (environment/data) axis should stay embarrassingly parallel; the only
            cross-pod collective in training is the gradient all-reduce.
  "data"  — batch sharding + FSDP parameter sharding (ZeRO-style).
  "model" — tensor parallelism (heads / FFN / experts) + sequence sharding of
            decode KV caches (distributed flash-decode).

All helpers degrade gracefully: an axis is only used if the dim is divisible by
the mesh axis size (GSPMD could pad, but divisible shardings keep the roofline
arithmetic exact).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= axis_size(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.shape else 1


def _fits(dim: int, mesh: Mesh, name) -> bool:
    n = axis_size(mesh, name)
    return n > 1 and dim % n == 0


def spec_for(mesh: Mesh, shape, *axes) -> P:
    """Build a PartitionSpec, dropping axes that don't divide the dim."""
    out = []
    for dim, ax in zip(shape, axes):
        if ax is None:
            out.append(None)
        elif isinstance(ax, (tuple, list)):
            keep = tuple(a for a in ax if a in mesh.shape)
            if keep and dim % axis_size(mesh, keep) == 0:
                out.append(keep if len(keep) > 1 else keep[0])
            else:
                out.append(None)
        else:
            out.append(ax if _fits(dim, mesh, ax) else None)
    return P(*out)


def dp_axes(mesh: Mesh):
    """Batch-sharding axes: ('pod','data') when the pod axis exists."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


# ---------------------------------------------------------------------------
# Parameter shardings.  Parameter trees are dicts whose leaves are arrays with
# a known logical role, identified by key path.  Rules:
#   - TP dim (heads*dh / d_ff / experts / vocab)     -> "model"
#   - FSDP dim (the other large dim)                 -> "data"
#   - pod                                            -> replicated
# ---------------------------------------------------------------------------

_RULES = [
    # (key-suffix, axes per dim) — stacked-layer arrays have a leading L dim
    # which is always unsharded (rule applies to trailing dims).
    ("wq",       ("data", "model")),
    ("wq_a",     ("data", None)),       # MLA q down-proj (D, q_lora)
    ("wq_b",     (None, "model")),      # MLA q up-proj (q_lora, H*dh)
    ("wkv_a",    ("data", None)),       # MLA kv down-proj (D, c_kv + rope)
    ("wkv_b",    (None, "model")),      # MLA kv up-proj (c_kv, H*(nope+v))
    ("wk",       ("data", "model")),
    ("wv",       ("data", "model")),
    ("wo",       ("model", "data")),
    ("bq",       ("model",)),
    ("bk",       ("model",)),
    ("bv",       ("model",)),
    ("w1",       ("data", "model")),
    ("w3",       ("data", "model")),
    ("w2",       ("model", "data")),
    ("w_router", ("data", None)),
    # experts: (E, D, F) / (E, F, D): experts on "model" (expert parallel)
    ("we1",      ("model", "data", None)),
    ("we3",      ("model", "data", None)),
    ("we2",      ("model", None, "data")),
    ("embed",    ("model", "data")),    # vocab-parallel embedding
    ("lm_head",  ("data", "model")),
    ("pos_embed", (None, None)),
    # rwkv6 / mamba params — channel dims on "model" where divisible
    ("w_in",     ("data", "model")),
    ("w_out",    ("model", "data")),
    ("w_state",  (None, "model")),
]


def _spec_for_leaf(mesh: Mesh, path: str, shape, fsdp_axes=("data",)) -> P:
    fsdp = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]

    def xlate(ax):
        return fsdp if ax == "data" else ax

    for suffix, axes in _RULES:
        if path.endswith(suffix):
            ndim = len(shape)
            axes = tuple(xlate(a) for a in axes)
            if len(axes) < ndim:  # stacked-layer leading dims -> unsharded
                axes = (None,) * (ndim - len(axes)) + tuple(axes)
            elif len(axes) > ndim:
                axes = axes[-ndim:]
            return spec_for(mesh, shape, *axes)
    # default: FSDP the largest dim if it fits and is large
    if shape:
        big = int(np.argmax(shape))
        if shape[big] >= 1024 and _fits(shape[big], mesh, fsdp):
            axes = [None] * len(shape)
            axes[big] = fsdp
            return P(*axes)
    return P()


def _key_path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(mesh: Mesh, params_shape: Any, fsdp_axes=("data",)):
    """PartitionSpec pytree for a params shape-tree (from jax.eval_shape).

    ``fsdp_axes=("pod","data")`` extends ZeRO-3 sharding across pods (used by
    the 100B+ configs on the multi-pod mesh — DESIGN.md §8)."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: _spec_for_leaf(mesh, _key_path_str(kp), leaf.shape,
                                        fsdp_axes),
        params_shape)


def param_shardings(mesh: Mesh, params_shape: Any):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(mesh, params_shape))


# ---------------------------------------------------------------------------
# Activation / cache specs
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, batch: int, *rest_dims) -> P:
    """(B, ...) with batch over the dp axes when divisible."""
    dp = dp_axes(mesh)
    if batch % axis_size(mesh, dp) == 0:
        return P(dp if len(dp) > 1 else dp[0], *rest_dims)
    if batch % axis_size(mesh, "data") == 0:
        return P("data", *rest_dims)
    return P(None, *rest_dims)


def kv_cache_spec(mesh: Mesh, batch: int, seq: int) -> P:
    """(L, B, S, H_kv, dh).  Distributed flash-decode: shard the cache sequence.

    batch >= data-axis: batch on dp axes, seq on "model".
    batch == 1 (long_500k): seq on ("data","model") — 256-way sequence shard.
    """
    dp = dp_axes(mesh)
    ndp = axis_size(mesh, dp)
    if batch % ndp == 0:
        bs = dp if len(dp) > 1 else dp[0]
        seq_ax = "model" if seq % axis_size(mesh, "model") == 0 else None
        return P(None, bs, seq_ax, None, None)
    # tiny batch: give the sequence everything
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    if seq % axis_size(mesh, axes) == 0:
        return P(None, None, axes, None, None)
    return P(None, None, None, None, None)


def state_spec(mesh: Mesh, batch: int, heads: int) -> P:
    """(L, B, H, d, d) recurrent state (rwkv/mamba)."""
    b = batch_spec(mesh, batch)
    h_ax = "model" if heads % axis_size(mesh, "model") == 0 else None
    return P(None, b[0], h_ax, None, None)


def cache_leaf_spec(mesh: Mesh, key: str, shape) -> P:
    """Decode-cache leaf spec by key name (shared by launch/steps.py and the
    in-loop constraints of model._scan_decode).

    Stacked-layer leaves: (L, B, S, ...) for kv-likes, (L, B, ...) for
    recurrent states."""
    if len(shape) < 3:
        return P()
    L, B = shape[0], shape[1]
    if key in ("k", "v", "xk", "xv"):          # (L, B, S, Hkv, dh)
        kv5 = kv_cache_spec(mesh, B, shape[2])
        return spec_for(mesh, shape, *kv5)
    if key in ("c_kv", "k_rope"):              # (L, B, S, c)
        kv5 = kv_cache_spec(mesh, B, shape[2])
        return spec_for(mesh, shape, kv5[0], kv5[1], kv5[2], None)
    bs = batch_spec(mesh, B)
    if key == "state":                          # (L, B, H, N, N)
        return spec_for(mesh, shape, None, bs[0], "model", None, None)
    if key in ("xprev_t", "xprev_c"):           # (L, B, D)
        return spec_for(mesh, shape, None, bs[0], None)
    if key == "conv":                           # (L, B, W-1, di)
        return spec_for(mesh, shape, None, bs[0], None, "model")
    if key == "ssm":                            # (L, B, di, n)
        return spec_for(mesh, shape, None, bs[0], "model", None)
    return P()
