"""GQA attention: train/prefill (optionally Pallas flash) + cached decode.

Decode attends one query token against a length-``S`` KV cache; cost is O(S)
per token (linear, never quadratic) and the cache sequence axis is sharded
across devices (distributed flash-decode) — see models/sharding.kv_cache_spec.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import backend as backend_mod
from repro.models.layers import apply_rope, dense_init, dtype_of

_MODELS_DIR = os.path.dirname(__file__)


def init_attention(cfg: ModelConfig, key):
    dt = dtype_of(cfg.param_dtype)
    dh = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, (cfg.d_model, cfg.num_heads * dh), dt),
        "wk": dense_init(kk, (cfg.d_model, cfg.num_kv_heads * dh), dt),
        "wv": dense_init(kv, (cfg.d_model, cfg.num_kv_heads * dh), dt),
        "wo": dense_init(ko, (cfg.num_heads * dh, cfg.d_model), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * dh,), dt)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * dh,), dt)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * dh,), dt)
    return p


def _project_qkv(cfg: ModelConfig, p, x):
    cd = dtype_of(cfg.compute_dtype)
    dh = cfg.resolved_head_dim
    B, S, _ = x.shape
    x = x.astype(cd)
    q = x @ p["wq"].astype(cd)
    k = x @ p["wk"].astype(cd)
    v = x @ p["wv"].astype(cd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = q.reshape(B, S, cfg.num_heads, dh)
    k = k.reshape(B, S, cfg.num_kv_heads, dh)
    v = v.reshape(B, S, cfg.num_kv_heads, dh)
    return q, k, v


def gqa_attend(q, k, v, mask, *, scale: Optional[float] = None):
    """q: (B,Sq,H,dh)  k,v: (B,Sk,Hkv,dh)  mask: broadcastable (B,1,Sq,Sk) bool.

    Grouped einsum keeps the repeated KV heads virtual (no materialized repeat).
    """
    B, Sq, H, dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else dh ** -0.5
    # standard GQA grouping: q head h attends kv head h // G
    qg = q.reshape(B, Sq, Hkv, G, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, v.shape[-1])   # v dim may differ (MLA)


def chunked_gqa_attend(q, k, v, *, sliding_window: int = 0,
                       chunk: int = 1024):
    """Memory-safe causal attention: lax.scan over query chunks.

    Keeps the materialized logits at (B, H, chunk, S) instead of (B, H, S, S).
    Baseline masks the full key range per chunk (2x causal FLOPs — the Pallas
    flash kernel removes this on TPU; see EXPERIMENTS.md §Perf).
    """
    B, S, H, dh = q.shape
    if S <= chunk:
        return gqa_attend(q, k, v, causal_mask(S, S, sliding_window))
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = q.reshape(B, n, chunk, H, dh)
    kpos = jnp.arange(S)[None, :]

    def body(_, inp):
        qi, i = inp
        qpos = i * chunk + jnp.arange(chunk)[:, None]
        m = kpos[None] <= qpos                              # (chunk, S) -> bcast
        if sliding_window:
            m = m & (kpos[None] > qpos - sliding_window)
        out = gqa_attend(qi, k, v, m.reshape(1, chunk, S))
        return None, out

    # remat per chunk: scan backward otherwise stacks every chunk's
    # attention probs (chunks x B x H x chunk x S fp32) as residuals
    body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None,
                           (jnp.moveaxis(qc, 1, 0), jnp.arange(n)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, n * chunk, H, outs.shape[-1])
    return out[:, :S]


def causal_mask(Sq: int, Sk: int, sliding_window: int = 0):
    """(1, Sq, Sk) boolean; True == attend."""
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if sliding_window:
        m = m & (kpos > qpos - sliding_window)
    return m[None]


def apply_attention(cfg: ModelConfig, p, x, positions, *,
                    causal: bool = True, backend: Optional[str] = None,
                    use_pallas: Optional[bool] = None,
                    chunk: int = 1024, return_kv: bool = False):
    """Train/prefill self-attention (causal by default; encoder passes False).

    ``backend="pallas"`` routes the causal path through the flash kernel;
    ``use_pallas=`` is a deprecated alias (see ``repro.core.backend``).
    With ``return_kv`` also returns the post-RoPE K/V for KV-cache population.
    """
    backend = backend_mod.resolve_backend(backend, use_pallas,
                                          skip_dirs=(_MODELS_DIR,))
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    if cfg.rope_kind in ("rope", "mrope"):
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    if backend == "pallas" and causal:
        from repro.kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(q, k, v, causal=True,
                                        sliding_window=cfg.sliding_window)
    elif causal:
        out = chunked_gqa_attend(q, k, v, sliding_window=cfg.sliding_window,
                                 chunk=chunk)
    else:
        out = gqa_attend(q, k, v, None)
    cd = dtype_of(cfg.compute_dtype)
    out = out.reshape(B, S, -1) @ p["wo"].astype(cd)
    if return_kv:
        return out, k, v
    return out


def apply_cross_attention(cfg: ModelConfig, p, x, kv_src) -> jnp.ndarray:
    """Encoder-decoder cross attention (no mask, no rope)."""
    cd = dtype_of(cfg.compute_dtype)
    dh = cfg.resolved_head_dim
    B, S, _ = x.shape
    Sk = kv_src.shape[1]
    q = (x.astype(cd) @ p["wq"].astype(cd)).reshape(B, S, cfg.num_heads, dh)
    k = (kv_src.astype(cd) @ p["wk"].astype(cd)).reshape(B, Sk, cfg.num_kv_heads, dh)
    v = (kv_src.astype(cd) @ p["wv"].astype(cd)).reshape(B, Sk, cfg.num_kv_heads, dh)
    out = gqa_attend(q, k, v, None)
    return out.reshape(B, S, -1) @ p["wo"].astype(cd)


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, seq_len: int, layers: int,
                  dtype) -> dict:
    dh = cfg.resolved_head_dim
    shape = (layers, batch, seq_len, cfg.num_kv_heads, dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_write(cache, new, pos):
    """Write one token's K/V at ``pos`` (axis 1).

    Under a mesh, a dynamic-update-slice at a traced position on the
    256-way-sharded sequence axis triggers GSPMD "involuntary full
    rematerialization" (the cache replicates: +322 GiB/device on qwen
    long_500k); the masked elementwise write partitions cleanly."""
    from repro.models import act_sharding
    if act_sharding.current_mesh() is not None:
        S = cache.shape[1]
        onehot = (jnp.arange(S) == pos)
        shape = (1, S) + (1,) * (cache.ndim - 2)
        return jnp.where(onehot.reshape(shape), new.astype(cache.dtype),
                         cache)
    return jax.lax.dynamic_update_slice_in_dim(
        cache, new.astype(cache.dtype), pos, axis=1)


def decode_attention(cfg: ModelConfig, p, x, cache_k, cache_v, pos):
    """One-token decode.  x: (B,1,D); cache_k/v: (B,S,Hkv,dh); pos: () int32.

    Returns (out (B,1,D), new_k, new_v).  The new token's K/V are written at
    ``pos`` and attention is masked to positions <= pos.
    """
    B, _, _ = x.shape
    S = cache_k.shape[1]
    q, k, v = _project_qkv(cfg, p, x)                       # (B,1,·,dh)
    if cfg.rope_kind in ("rope", "mrope"):
        pvec = jnp.full((B, 1), pos, jnp.int32)
        if cfg.rope_kind == "mrope":
            pvec = jnp.broadcast_to(pvec[..., None], (B, 1, 3))
        q = apply_rope(q, pvec, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, pvec, cfg.rope_theta, cfg.mrope_sections)
    cache_k = cache_write(cache_k, k, pos)
    cache_v = cache_write(cache_v, v, pos)
    kpos = jnp.arange(S)[None, None, :]                     # (1,1,S)
    mask = kpos <= pos
    if cfg.sliding_window:
        mask = mask & (kpos > pos - cfg.sliding_window)
    # distributed flash-decode: keep the whole attention chain on the cache's
    # sequence sharding — left unconstrained, GSPMD re-partitions to a
    # heads-major layout via "involuntary full rematerialization"
    # (replicates the cache; measured 322 GiB/device on qwen long_500k)
    from repro.models import act_sharding
    from repro.models.sharding import kv_cache_spec
    mesh = act_sharding.current_mesh()
    if mesh is not None:
        spec = kv_cache_spec(mesh, B, S)[1:]                # (B, S, H, dh)
        seq_ax = spec[1]
        k_att = act_sharding.constrain(cache_k.astype(q.dtype), *spec)
        v_att = act_sharding.constrain(cache_v.astype(q.dtype), *spec)
        Hkv = k_att.shape[2]
        dh_ = q.shape[-1]
        G = q.shape[2] // Hkv
        qg = q.reshape(B, 1, Hkv, G, dh_)
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, k_att
                            ).astype(jnp.float32) * dh_ ** -0.5
        # (B, Hkv, G, 1, S): pin S to the cache's sequence axes
        logits = act_sharding.constrain(logits, spec[0], None, None, None,
                                        seq_ax)
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)   # psum over sharded S
        w = act_sharding.constrain(w, spec[0], None, None, None, seq_ax)
        out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v_att.dtype), v_att)
        out = out.reshape(B, 1, -1)
    else:
        out = gqa_attend(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
                         mask)
    cd = dtype_of(cfg.compute_dtype)
    out = out.reshape(B, 1, -1) @ p["wo"].astype(cd)
    return out, cache_k, cache_v
