"""State-space layers: RWKV-6 "Finch" time/channel mix and Mamba selective SSM.

RWKV-6 recurrence (per head, key-dim N x value-dim N state S):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (diag(u) k_t^T v_t + S_{t-1})
with data-dependent per-channel decay w_t (the Finch novelty, arXiv:2404.05892).

The sequential form here is the reference; kernels/rwkv6 provides the chunked
Pallas kernel that exposes MXU matmuls within chunks.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import backend as backend_mod
from repro.models.layers import dense_init, dtype_of

_MODELS_DIR = os.path.dirname(__file__)


# ---------------------------------------------------------------------------
# RWKV-6 time mix
# ---------------------------------------------------------------------------

def init_rwkv_tmix(cfg: ModelConfig, key):
    dt = dtype_of(cfg.param_dtype)
    D = cfg.d_model
    H = cfg.num_heads
    N = cfg.ssm.head_dim
    assert H * N == D, (H, N, D)
    ks = jax.random.split(key, 8)
    lora = max(32, D // 16)
    return {
        # static token-shift mixes for r,k,v,g + data-dependent decay LoRA
        "mu_r": jnp.full((D,), 0.5, dt), "mu_k": jnp.full((D,), 0.5, dt),
        "mu_v": jnp.full((D,), 0.5, dt), "mu_g": jnp.full((D,), 0.5, dt),
        "mu_w": jnp.full((D,), 0.5, dt),
        "w_in": dense_init(ks[0], (D, 4 * D), dt),   # fused r,k,v,g projection
        "w_decay_a": dense_init(ks[1], (D, lora), dt),
        "w_decay_b": dense_init(ks[2], (lora, D), dt, scale=0.1),
        "w0": jnp.full((D,), -6.0, dt),              # base decay bias
        "u": (jax.random.normal(ks[3], (H, N), jnp.float32) * 0.1).astype(dt),
        "w_out": dense_init(ks[4], (D, D), dt),
        "ln_x_scale": jnp.ones((D,), dt),            # per-head group-norm scale
    }


def _tshift(x, x_prev):
    """x: (B,S,D). shift right by one; x_prev fills position 0."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def rwkv6_project(cfg: ModelConfig, p, x, x_prev):
    """-> r,k,v,g (B,S,H,N), w (B,S,H,N) decay in (0,1), plus last x for shift."""
    cd = dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    H, N = cfg.num_heads, cfg.ssm.head_dim
    x = x.astype(cd)
    xs = _tshift(x, x_prev.astype(cd))
    def mix(mu):
        return x + (xs - x) * mu.astype(cd)
    rkvg = mix(p["mu_r"])  # shared mix for the fused projection (simplified ddlerp)
    rkvg = rkvg @ p["w_in"].astype(cd)
    r, k, v, g = jnp.split(rkvg, 4, axis=-1)
    xw = mix(p["mu_w"])
    dec = (xw @ p["w_decay_a"].astype(cd))
    dec = jnp.tanh(dec) @ p["w_decay_b"].astype(cd)
    w = jnp.exp(-jnp.exp((p["w0"].astype(jnp.float32)
                          + dec.astype(jnp.float32))))      # (B,S,D) in (0,1)
    shp = (B, S, H, N)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp),
            g.reshape(B, S, D), w.reshape(shp).astype(jnp.float32), x[:, -1, :])


def wkv6_scan(r, k, v, w, u, state):
    """Sequential WKV6.  r,k,v,w: (B,S,H,N) — w fp32 decay; u: (H,N);
    state: (B,H,N,N).  Returns (out (B,S,H,N), new_state)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                                 # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]             # (B,H,N,N)
        out = jnp.einsum("bhn,bhnm->bhm", rt, S + uf[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    new_state, outs = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(outs, 0, 1), new_state


def wkv6_chunked(r, k, v, w, u, state, *, chunk: int = 64):
    """Chunked WKV6: same math as kernels/rwkv6 but in pure jnp.

    Within a chunk everything is matmuls; the per-token scan only carries the
    (B,H,N,N) state across chunk boundaries, so backward saves O(S/chunk)
    states instead of O(S) (the per-step scan costs 153 GiB/device on the
    rwkv6-3b train_4k dry-run).  See kernels/rwkv6/kernel.py for the algebra.
    """
    B, S, H, N = r.shape
    if S % chunk or S <= chunk:
        return wkv6_scan(r, k, v, w, u, state)
    nc = S // chunk
    # keep xs in the input dtype; upcast per chunk inside the body (a global
    # fp32 copy of r,k,v,w at 32k prefill is ~4x the activation budget)
    rf, kf, vf = (jnp.moveaxis(a, 1, 0).reshape(nc, chunk, B, H, N)
                  for a in (r, k, v))
    wf = jnp.moveaxis(w, 1, 0).reshape(nc, chunk, B, H, N).astype(r.dtype)
    uf = u.astype(jnp.float32)
    ti = jnp.arange(chunk)[:, None]
    si = jnp.arange(chunk)[None, :]
    tril = (si < ti).astype(jnp.float32)

    def one_chunk(S0, inp):
        # (C, B, H, N) -> (B, H, C, N), fp32 per chunk
        rc, kc, vc, wc = (jnp.transpose(a, (1, 2, 0, 3)).astype(jnp.float32)
                          for a in inp)
        lw = jnp.log(jnp.maximum(wc, 1e-30))
        lp = jnp.cumsum(lw, axis=2)
        r_t = rc * jnp.exp(lp - lw)                 # r * P_{t-1}
        k_t = kc * jnp.exp(-lp)                     # k / P_t
        inter = jnp.einsum("bhcn,bhnm->bhcm", r_t, S0)
        A = jnp.einsum("bhcn,bhsn->bhcs", r_t, k_t) * tril[None, None]
        intra = jnp.einsum("bhcs,bhsm->bhcm", A, vc)
        diag = jnp.sum(rc * uf[None, :, None, :] * kc, axis=-1,
                       keepdims=True)
        out = inter + intra + diag * vc             # (B,H,C,N)
        decay = jnp.exp(lp[:, :, -1, :])            # (B,H,N)
        kv = jnp.einsum("bhsn,bhsm->bhnm", k_t, vc)
        S1 = decay[..., None] * (S0 + kv)
        return S1, jnp.transpose(out, (2, 0, 1, 3))  # (C,B,H,N)

    one_chunk = jax.checkpoint(one_chunk)
    S_fin, outs = jax.lax.scan(one_chunk, state.astype(jnp.float32),
                               (rf, kf, vf, wf))
    out = outs.reshape(S, B, H, N)
    return jnp.moveaxis(out, 0, 1), S_fin


def apply_rwkv_tmix(cfg: ModelConfig, p, x, x_prev, state, *,
                    backend: Optional[str] = None,
                    use_pallas: Optional[bool] = None):
    """x: (B,S,D) -> (out, new_x_prev, new_state).

    ``backend="pallas"`` uses the chunked kernels/rwkv6 kernel;
    ``use_pallas=`` is a deprecated alias (see ``repro.core.backend``)."""
    backend = backend_mod.resolve_backend(backend, use_pallas,
                                          skip_dirs=(_MODELS_DIR,))
    cd = dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    H, N = cfg.num_heads, cfg.ssm.head_dim
    r, k, v, g, w, x_last = rwkv6_project(cfg, p, x, x_prev)
    if backend == "pallas":
        from repro.kernels.rwkv6 import ops as rwkv_ops
        out, new_state = rwkv_ops.wkv6(r, k, v, w, p["u"], state)
    elif S >= 128:
        out, new_state = wkv6_chunked(r, k, v, w, p["u"], state)
    else:
        out, new_state = wkv6_scan(r, k, v, w, p["u"], state)
    # per-head group norm
    of = out.astype(jnp.float32)
    mu = jnp.mean(of, axis=-1, keepdims=True)
    var = jnp.var(of, axis=-1, keepdims=True)
    of = (of - mu) * jax.lax.rsqrt(var + 1e-5)
    of = of.reshape(B, S, D) * p["ln_x_scale"].astype(jnp.float32)
    out = (of.astype(cd) * jax.nn.silu(g.astype(cd)))
    return out @ p["w_out"].astype(cd), x_last, new_state.astype(state.dtype)


# ---------------------------------------------------------------------------
# RWKV channel mix (token-shifted squared-relu FFN with receptance gate)
# ---------------------------------------------------------------------------

def init_rwkv_cmix(cfg: ModelConfig, key):
    dt = dtype_of(cfg.param_dtype)
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((D,), 0.5, dt), "mu_r": jnp.full((D,), 0.5, dt),
        "w1": dense_init(k1, (D, F), dt), "w2": dense_init(k2, (F, D), dt),
        "wr": dense_init(k3, (D, D), dt),
    }


def apply_rwkv_cmix(cfg: ModelConfig, p, x, x_prev):
    cd = dtype_of(cfg.compute_dtype)
    x = x.astype(cd)
    xs = _tshift(x, x_prev.astype(cd))
    xk = x + (xs - x) * p["mu_k"].astype(cd)
    xr = x + (xs - x) * p["mu_r"].astype(cd)
    h = jnp.square(jax.nn.relu(xk @ p["w1"].astype(cd))) @ p["w2"].astype(cd)
    out = jax.nn.sigmoid(xr @ p["wr"].astype(cd)) * h
    return out, x[:, -1, :]


# ---------------------------------------------------------------------------
# Mamba selective SSM (Hymba's SSM heads)
# ---------------------------------------------------------------------------

def init_mamba(cfg: ModelConfig, key, d_inner: int = 0):
    s = cfg.ssm
    dt = dtype_of(cfg.param_dtype)
    D = cfg.d_model
    di = d_inner or s.expand * D
    n = s.state_size
    dt_rank = s.dt_rank or -(-D // 16)
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], (D, 2 * di), dt),          # x and gate z
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, di), jnp.float32)
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "w_bcdt": dense_init(ks[2], (di, 2 * n + dt_rank), dt),
        "w_dt": dense_init(ks[3], (dt_rank, di), dt),
        "dt_bias": jnp.full((di,), -4.0, dt),                # softplus(-4)~0.018
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n)).copy()),
        "Dskip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[4], (di, D), dt),
    }


def _causal_conv(x, w, b, conv_state=None):
    """x: (B,S,di); w: (W,di) depthwise.  Returns (y, new_conv_state (B,W-1,di))."""
    W = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    return y + b[None, None, :], xp[:, -(W - 1):, :]


def apply_mamba(cfg: ModelConfig, p, x, conv_state=None, ssm_state=None):
    """x: (B,S,D) -> (out, new_conv_state, new_ssm_state (B,di,n))."""
    s = cfg.ssm
    cd = dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    di = p["w_in"].shape[1] // 2
    n = s.state_size
    dt_rank = p["w_bcdt"].shape[1] - 2 * n
    xz = x.astype(cd) @ p["w_in"].astype(cd)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, new_conv = _causal_conv(xi, p["conv_w"].astype(cd), p["conv_b"].astype(cd),
                                None if conv_state is None else conv_state.astype(cd))
    xi = jax.nn.silu(xi)
    bcdt = xi @ p["w_bcdt"].astype(cd)
    Bm, Cm, dt_in = jnp.split(bcdt, [n, 2 * n], axis=-1)
    dt = jax.nn.softplus((dt_in @ p["w_dt"].astype(cd)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,di)
    A = -jnp.exp(p["A_log"])                                  # (di,n)
    # discretize: h_t = exp(dt*A) h + dt * B_t * x_t
    dA = jnp.exp(dt[..., None] * A[None, None])               # (B,S,di,n)
    dBx = (dt * xi.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[:, :, None, :]
    if ssm_state is None:
        ssm_state = jnp.zeros((B, di, n), jnp.float32)

    def step(h, inp):
        dA_t, dBx_t, C_t = inp
        h = dA_t * h + dBx_t
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0))
    chunk = 64
    if S > chunk and S % chunk == 0:
        # chunk + remat: backward saves only chunk-boundary states instead of
        # every step's (B, di, n) state (the naive scan's saved-state stack
        # dominates the hymba train_4k dry-run memory)
        xs_c = jax.tree.map(
            lambda a: a.reshape((S // chunk, chunk) + a.shape[1:]), xs)

        @jax.checkpoint
        def chunk_body(h, inp_c):
            return jax.lax.scan(step, h, inp_c)

        new_state, ys = jax.lax.scan(chunk_body,
                                     ssm_state.astype(jnp.float32), xs_c)
        ys = ys.reshape((S,) + ys.shape[2:])
    else:
        new_state, ys = jax.lax.scan(step, ssm_state.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1)                                # (B,S,di)
    y = y + p["Dskip"][None, None, :] * xi.astype(jnp.float32)
    out = (y.astype(cd) * jax.nn.silu(z)) @ p["w_out"].astype(cd)
    return out, new_conv.astype(x.dtype), new_state
