"""Activation-sharding constraints, threadable into scan bodies.

Model code calls ``constrain(x, axes...)``; the mesh is injected by the step
builders (launch/steps.py) via ``activation_mesh(mesh)``.  Outside a mesh
context the call is a no-op, so smoke tests on 1 CPU device are unaffected.
Axes that don't divide the dim are dropped (models/sharding.spec_for).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.sharding import spec_for

_ACTIVE_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_activation_mesh", default=None)


@contextlib.contextmanager
def activation_mesh(mesh: Optional[Mesh]):
    tok = _ACTIVE_MESH.set(mesh)
    try:
        yield
    finally:
        _ACTIVE_MESH.reset(tok)


def current_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH.get()


def constrain(x, *axes):
    """with_sharding_constraint(x, P(axes...)) if a mesh is active."""
    mesh = _ACTIVE_MESH.get()
    if mesh is None:
        return x
    axes = axes + (None,) * (x.ndim - len(axes))
    spec = spec_for(mesh, x.shape, *axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def dp(mesh: Optional[Mesh] = None):
    mesh = mesh or _ACTIVE_MESH.get()
    if mesh is not None and "pod" in mesh.shape:
        return ("pod", "data")
    return "data"
