"""Composable model definition: LM / enc-dec with scan-over-layers.

Every assigned architecture lowers through the same three entry points:

  ``forward_train``  — (params, tokens[, frontend_embeds]) -> (logits, aux)
  ``prefill``        — forward + populated decode caches
  ``decode_step``    — ONE token against a seq_len KV cache (O(S), never O(S^2))

Layer stacks are ``jax.lax.scan`` over stacked parameters so HLO size and
compile time are O(1) in depth (llama3-405b's 126 layers compile on a 1-core
host).  Heterogeneous stacks (DeepSeek dense prefix + MoE rest) are two scans.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import backend as backend_mod
from repro.models import act_sharding
from repro.models import attention as attn_mod
from repro.models import frontend as fe_mod
from repro.models import hybrid as hyb_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_mlp, apply_norm, dtype_of, embed_init,
                                 init_mlp, init_norm, sinusoidal_positions)

Params = Dict[str, Any]

_MODELS_DIR = os.path.dirname(__file__)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, key, *, is_moe: bool, cross: bool = False,
                causal: bool = True):
    km, kf, kc = jax.random.split(key, 3)
    p: Params = {"ln1": init_norm(cfg, cfg.d_model),
                 "ln2": init_norm(cfg, cfg.d_model)}
    if cfg.attention_kind == "mla":
        p["mla"] = mla_mod.init_mla(cfg, km)
    elif cfg.attention_kind == "hybrid":
        p["hyb"] = hyb_mod.init_hybrid(cfg, km)
    elif cfg.attention_kind == "none":          # rwkv
        p["tmix"] = ssm_mod.init_rwkv_tmix(cfg, km)
    else:
        p["attn"] = attn_mod.init_attention(cfg, km)
    if cfg.attention_kind == "none":
        p["cmix"] = ssm_mod.init_rwkv_cmix(cfg, kf)
    elif is_moe:
        p["moe"] = moe_mod.init_moe(cfg, kf)
    else:
        p["ffn"] = init_mlp(cfg, kf, cfg.d_model, cfg.d_ff)
    if cross:
        p["ln_c"] = init_norm(cfg, cfg.d_model)
        p["cross"] = attn_mod.init_attention(cfg, kc)
    return p


def _stack_blocks(cfg: ModelConfig, key, n: int, **kw):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_block(cfg, k, **kw))(keys)


def init_params(cfg: ModelConfig, key) -> Params:
    dt = dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    p: Params = {"embed": embed_init(keys[0], (cfg.vocab_padded, cfg.d_model), dt)}
    moe_cfg = cfg.moe
    if moe_cfg is not None and moe_cfg.first_dense_layers:
        nd = moe_cfg.first_dense_layers
        p["blocks_dense"] = _stack_blocks(cfg, keys[1], nd, is_moe=False)
        p["blocks"] = _stack_blocks(cfg, keys[2], cfg.num_layers - nd,
                                    is_moe=True)
    else:
        p["blocks"] = _stack_blocks(cfg, keys[1], cfg.num_layers,
                                    is_moe=moe_cfg is not None,
                                    cross=cfg.is_encdec)
    if cfg.is_encdec:
        p["encoder"] = {
            "blocks": _stack_blocks(cfg, keys[3], cfg.encoder_layers,
                                    is_moe=False, causal=False),
            "final_norm": init_norm(cfg, cfg.d_model),
        }
    p["final_norm"] = init_norm(cfg, cfg.d_model)
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(keys[4], (cfg.d_model, cfg.vocab_padded), dt)
    if cfg.frontend:
        p["frontend"] = fe_mod.init_frontend(cfg, keys[5])
    if cfg.mtp:
        p["mtp"] = {"block": _init_block(cfg, keys[6], is_moe=False),
                    "norm": init_norm(cfg, cfg.d_model)}
    return p


def abstract_params(cfg: ModelConfig, key=None):
    """Shape tree without allocation (for dry-run input_specs)."""
    k = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda: init_params(cfg, k))


# ---------------------------------------------------------------------------
# block application (train / prefill)
# ---------------------------------------------------------------------------

def _mixer(cfg: ModelConfig, bp, h, positions, *, causal=True,
           backend="reference", return_kv=False):
    """Apply the sequence mixer of one block.  Returns (out, kv_or_none).

    ``backend`` is the already-resolved kernel selection (the public entry
    points resolve the deprecated ``use_pallas=`` alias exactly once)."""
    hn = apply_norm(cfg, bp["ln1"], h)
    if cfg.attention_kind == "mla":
        out = mla_mod.apply_mla(cfg, bp["mla"], hn, positions)
        return out, None
    if cfg.attention_kind == "hybrid":
        out = hyb_mod.apply_hybrid(cfg, bp["hyb"], hn, positions,
                                   backend=backend)
        return out, None
    if return_kv:
        out, k, v = attn_mod.apply_attention(
            cfg, bp["attn"], hn, positions, causal=causal,
            backend=backend, return_kv=True)
        return out, (k, v)
    out = attn_mod.apply_attention(cfg, bp["attn"], hn, positions,
                                   causal=causal, backend=backend)
    return out, None


def _ffn(cfg: ModelConfig, bp, h):
    hn = apply_norm(cfg, bp["ln2"], h)
    if "moe" in bp:
        out, aux = moe_mod.apply_moe(cfg, bp["moe"], hn)
        return out, aux
    return apply_mlp(cfg, bp["ffn"], hn), jnp.float32(0.0)


def _block_body(cfg: ModelConfig, carry, bp, *, positions, causal=True,
                enc_out=None, backend="reference"):
    """One residual block for the train/prefill scan.  carry = (h, aux)."""
    h, aux = carry
    if cfg.attention_kind == "none":
        # rwkv: time-mix + channel-mix, zero-init shift states per sequence
        B, S, D = h.shape
        hn = apply_norm(cfg, bp["ln1"], h)
        state0 = jnp.zeros((B, cfg.num_heads, cfg.ssm.head_dim,
                            cfg.ssm.head_dim), jnp.float32)
        mix, _, _ = ssm_mod.apply_rwkv_tmix(
            cfg, bp["tmix"], hn, jnp.zeros((B, D), hn.dtype), state0,
            backend=backend)
        h = h + mix
        hn = apply_norm(cfg, bp["ln2"], h)
        cm, _ = ssm_mod.apply_rwkv_cmix(cfg, bp["cmix"], hn,
                                        jnp.zeros((B, D), hn.dtype))
        # channel sharding: the wkv recurrence is sequential over seq
        h = act_sharding.constrain(h + cm, act_sharding.dp(), None, "model")
        return (h, aux), None
    mix, _ = _mixer(cfg, bp, h, positions, causal=causal, backend=backend)
    h = h + mix
    if enc_out is not None and "cross" in bp:
        hc = apply_norm(cfg, bp["ln_c"], h)
        h = h + attn_mod.apply_cross_attention(cfg, bp["cross"], hc, enc_out)
    f, a = _ffn(cfg, bp, h)
    # sequence-parallel residual stream: batch over dp, seq over "model"
    # (keeps the layer-stacked scan carry at 1/(dp*model) per device)
    h = act_sharding.constrain(h + f, act_sharding.dp(), "model", None)
    return (h, aux + a), None


def _scan_blocks(cfg: ModelConfig, blocks, h, *, positions, causal=True,
                 enc_out=None, backend="reference"):
    body = functools.partial(_block_body, cfg, positions=positions,
                             causal=causal, enc_out=enc_out,
                             backend=backend)
    if cfg.remat == "full":
        body = jax.checkpoint(body)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), blocks)
    return h, aux


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def _lookup(cfg: ModelConfig, embed, tokens):
    """Embedding lookup.  Under a mesh, use a one-hot matmul: the gather's
    backward scatter un-shards a vocab-sharded table (measured: full fp32
    (V, D) grad buffers on deepseek-v3); the one-hot dot keeps GSPMD happy."""
    cd = dtype_of(cfg.compute_dtype)
    if act_sharding.current_mesh() is not None:
        oh = jax.nn.one_hot(tokens, cfg.vocab_padded, dtype=cd)
        # vocab axis on "model": forward contraction is vocab-parallel and
        # the backward one_hot^T @ dh dot emits a ("model",...)-sharded grad
        oh = act_sharding.constrain(oh, act_sharding.dp(), None, "model")
        return oh @ embed.astype(cd)
    return jnp.take(embed, tokens, axis=0).astype(cd)


def _embed(cfg: ModelConfig, params, tokens, frontend_embeds=None,
           pos_offset=0):
    cd = dtype_of(cfg.compute_dtype)
    h = _lookup(cfg, params["embed"], tokens)
    if cfg.frontend == "vision" and frontend_embeds is not None:
        proj = fe_mod.project(cfg, params["frontend"], frontend_embeds)
        P = proj.shape[1]
        h = jnp.concatenate([proj.astype(cd), h[:, P:]], axis=1)
    if cfg.rope_kind == "none" and cfg.attention_kind != "none":
        from repro.models.layers import sinusoidal_at
        pos = pos_offset + jnp.arange(h.shape[1])
        h = h + sinusoidal_at(pos, cfg.d_model).astype(cd)[None]
    if cfg.attention_kind == "none":   # rwkv: channel sharding
        return act_sharding.constrain(h, act_sharding.dp(), None, "model")
    return act_sharding.constrain(h, act_sharding.dp(), "model", None)


def _positions(cfg: ModelConfig, tokens):
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.rope_kind == "mrope":
        # text tokens use identical (t,h,w); vision-patch grids come from the
        # (stubbed) frontend — documented in DESIGN.md
        pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
    return pos


def _unembed(cfg: ModelConfig, params, h):
    cd = dtype_of(cfg.compute_dtype)
    # exit sequence parallelism: h's seq axis must leave "model" before the
    # vocab ("model"-sharded) contraction, else GSPMD un-shards the logits
    # and the lm_head grad
    h = act_sharding.constrain(h, act_sharding.dp(), None, None)
    h = apply_norm(cfg, params["final_norm"], h)
    head = (params["embed"].astype(cd).T if cfg.tie_embeddings
            else params["lm_head"].astype(cd))
    return (h.astype(cd) @ head).astype(jnp.float32)


def _run_encoder(cfg: ModelConfig, params, frontend_embeds):
    """Audio encoder over stub frame embeddings -> (B, T_enc, D)."""
    cd = dtype_of(cfg.compute_dtype)
    h = fe_mod.project(cfg, params["frontend"], frontend_embeds).astype(cd)
    pe = sinusoidal_positions(h.shape[1], cfg.d_model).astype(cd)
    h = h + pe[None]
    pos = jnp.broadcast_to(
        jnp.arange(h.shape[1], dtype=jnp.int32)[None], h.shape[:2])
    h, _ = _scan_blocks(cfg, params["encoder"]["blocks"], h,
                        positions=pos, causal=False)
    return apply_norm(cfg, params["encoder"]["final_norm"], h)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward_train(cfg: ModelConfig, params: Params, tokens,
                  frontend_embeds=None, *, backend=None, use_pallas=None):
    """-> (logits (B,S,V) fp32, aux_loss scalar).

    ``backend="reference"|"pallas"`` selects the sequence-mixer kernels;
    ``use_pallas=`` is a deprecated alias (see ``repro.core.backend``)."""
    backend = backend_mod.resolve_backend(backend, use_pallas,
                                          skip_dirs=(_MODELS_DIR,))
    enc_out = None
    if cfg.is_encdec:
        enc_out = _run_encoder(cfg, params, frontend_embeds)
    h = _embed(cfg, params, tokens, frontend_embeds)
    pos = _positions(cfg, tokens)
    moe_cfg = cfg.moe
    aux = jnp.float32(0.0)
    if moe_cfg is not None and moe_cfg.first_dense_layers:
        h, a0 = _scan_blocks(cfg, params["blocks_dense"], h, positions=pos,
                             backend=backend)
        h, a1 = _scan_blocks(cfg, params["blocks"], h, positions=pos,
                             backend=backend)
        aux = a0 + a1
    else:
        h, aux = _scan_blocks(cfg, params["blocks"], h, positions=pos,
                              enc_out=enc_out, backend=backend)
    logits = _unembed(cfg, params, h)
    if cfg.mtp:
        aux = aux + _mtp_loss_placeholder(cfg, params, h, tokens)
    return logits, aux


def _mtp_loss_placeholder(cfg, params, h, tokens):
    """DeepSeek MTP: one extra block predicts token t+2 from (h_t, emb_{t+1}).

    Returns the MTP cross-entropy (weighted) as an aux term.
    """
    cd = dtype_of(cfg.compute_dtype)
    emb_next = _lookup(cfg, params["embed"], jnp.roll(tokens, -1, axis=1))
    hm = apply_norm(cfg, params["mtp"]["norm"], h) + emb_next
    pos = _positions(cfg, tokens)
    mtp_block = jax.checkpoint(            # don't save MTP attention probs
        lambda carry, bp: _block_body(cfg, carry, bp, positions=pos))
    (hm, _), _ = mtp_block((hm, jnp.float32(0.0)), params["mtp"]["block"])
    logits = _unembed(cfg, params, hm)                       # predicts t+2
    targets = jnp.roll(tokens, -2, axis=1)
    nll = _token_nll(cfg, logits, targets)
    return 0.3 * jnp.mean(nll[:, :-2])


def _token_nll(cfg: ModelConfig, logits, labels):
    """Cross entropy as logsumexp - one-hot dot.

    take_along_axis over the vocab axis forces GSPMD to all-gather the
    vocab-sharded logits (and un-shards the lm_head/embed grads); the
    one-hot contraction keeps the "model" sharding end to end."""
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    oh = jax.nn.one_hot(labels, cfg.vocab_padded, dtype=logits.dtype)
    oh = act_sharding.constrain(oh, act_sharding.dp(), None, "model")
    gold = jnp.einsum("...v,...v->...", logits, oh).astype(jnp.float32)
    return lse - gold


def lm_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            *, backend=None, use_pallas=None):
    """batch: {'tokens': (B,S) int32, 'labels': (B,S) int32[, frontend]}.

    ``backend``/deprecated ``use_pallas`` as in :func:`forward_train`."""
    backend = backend_mod.resolve_backend(backend, use_pallas,
                                          skip_dirs=(_MODELS_DIR,))
    logits, aux = forward_train(cfg, params, batch["tokens"],
                                batch.get("frontend_embeds"),
                                backend=backend)
    nll = _token_nll(cfg, logits, batch["labels"])
    mask = batch.get("mask")
    if mask is not None:
        nll = nll * mask
        loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(nll)
    return loss + aux, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None) -> Params:
    dt = dtype or dtype_of(cfg.kv_cache_dtype or cfg.compute_dtype)
    L = cfg.num_layers
    dh = cfg.resolved_head_dim
    if cfg.attention_kind == "mla":
        c = mla_mod.init_mla_cache(cfg, batch, seq_len, L, dt)
    elif cfg.attention_kind == "none":       # rwkv
        N = cfg.ssm.head_dim
        c = {"state": jnp.zeros((L, batch, cfg.num_heads, N, N), jnp.float32),
             "xprev_t": jnp.zeros((L, batch, cfg.d_model), dt),
             "xprev_c": jnp.zeros((L, batch, cfg.d_model), dt)}
    elif cfg.attention_kind == "hybrid":
        c = attn_mod.init_kv_cache(cfg, batch, seq_len, L, dt)
        di = cfg.num_heads * dh
        c["conv"] = jnp.zeros((L, batch, cfg.ssm.conv_width - 1, di), dt)
        c["ssm"] = jnp.zeros((L, batch, di, cfg.ssm.state_size), jnp.float32)
    else:
        c = attn_mod.init_kv_cache(cfg, batch, seq_len, L, dt)
    if cfg.is_encdec:
        T_enc = fe_mod.num_frontend_tokens(cfg, seq_len)
        c["xk"] = jnp.zeros((L, batch, T_enc, cfg.num_kv_heads, dh), dt)
        c["xv"] = jnp.zeros((L, batch, T_enc, cfg.num_kv_heads, dh), dt)
    return c


def _decode_block(cfg: ModelConfig, h, bp, cache_slices, pos):
    """One block of single-token decode.  Returns (h, new_cache_slices)."""
    hn = apply_norm(cfg, bp["ln1"], h)
    new = dict(cache_slices)
    if cfg.attention_kind == "mla":
        mix, new["c_kv"], new["k_rope"] = mla_mod.decode_mla(
            cfg, bp["mla"], hn, cache_slices["c_kv"], cache_slices["k_rope"], pos)
    elif cfg.attention_kind == "none":
        state0 = cache_slices["state"]
        mix, xlast, new_state = ssm_mod.apply_rwkv_tmix(
            cfg, bp["tmix"], hn, cache_slices["xprev_t"], state0)
        new["state"], new["xprev_t"] = new_state, xlast
    elif cfg.attention_kind == "hybrid":
        mix, new["k"], new["v"], new["conv"], new["ssm"] = hyb_mod.decode_hybrid(
            cfg, bp["hyb"], hn, cache_slices["k"], cache_slices["v"],
            cache_slices["conv"], cache_slices["ssm"], pos)
    else:
        mix, new["k"], new["v"] = attn_mod.decode_attention(
            cfg, bp["attn"], hn, cache_slices["k"], cache_slices["v"], pos)
    h = h + mix
    if cfg.is_encdec and "cross" in bp:
        hc = apply_norm(cfg, bp["ln_c"], h)
        out = attn_mod.gqa_attend(
            hc_q := _cross_q(cfg, bp["cross"], hc), cache_slices["xk"],
            cache_slices["xv"], None)
        cd = dtype_of(cfg.compute_dtype)
        h = h + out.reshape(h.shape[0], 1, -1) @ bp["cross"]["wo"].astype(cd)
    if cfg.attention_kind == "none":
        hn = apply_norm(cfg, bp["ln2"], h)
        cm, xlast = ssm_mod.apply_rwkv_cmix(cfg, bp["cmix"], hn,
                                            cache_slices["xprev_c"])
        new["xprev_c"] = xlast
        h = h + cm
    else:
        f, _ = _ffn(cfg, bp, h)
        h = h + f
    return h, new


def _cross_q(cfg, p, x):
    cd = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    dh = cfg.resolved_head_dim
    return (x.astype(cd) @ p["wq"].astype(cd)).reshape(B, S, cfg.num_heads, dh)


def decode_step(cfg: ModelConfig, params: Params, cache: Params, token, pos):
    """token: (B,1) int32; pos: () int32.  -> (logits (B,V) fp32, new cache)."""
    h = _embed(cfg, params, token, pos_offset=pos)
    moe_cfg = cfg.moe
    if moe_cfg is not None and moe_cfg.first_dense_layers:
        nd = moe_cfg.first_dense_layers
        split = {k: (v[:nd], v[nd:]) for k, v in cache.items()}
        cache_d = {k: v[0] for k, v in split.items()}
        cache_m = {k: v[1] for k, v in split.items()}
        h, new_d = _scan_decode(cfg, params["blocks_dense"], h, cache_d, pos)
        h, new_m = _scan_decode(cfg, params["blocks"], h, cache_m, pos)
        new_cache = {k: jnp.concatenate([new_d[k], new_m[k]], axis=0)
                     for k in new_d}
    else:
        h, new_cache = _scan_decode(cfg, params["blocks"], h, cache, pos)
    logits = _unembed(cfg, params, h)[:, 0]
    return logits, new_cache


def _scan_decode(cfg: ModelConfig, blocks, h, cache, pos):
    """Layer loop for decode: fori_loop with the cache as carry.

    A lax.scan with cache as xs AND ys double-buffers the full (L,B,S,...)
    cache stack (measured +16 GiB on qwen1.5-32b decode_32k); the fori_loop
    carry + in-place dynamic_update keeps one buffer, aliased with the
    donated input."""
    L = jax.tree.leaves(blocks)[0].shape[0]

    def constrain_cache(c):
        # GSPMD sharding propagation through the fori while-loop loses the
        # carry's sharding (measured: the qwen1.5 long_500k cache replicates
        # to 324 GiB/device) — pin every leaf to its cache spec each step.
        mesh = act_sharding.current_mesh()
        if mesh is None:
            return c
        from repro.models.sharding import cache_leaf_spec
        return {k: jax.lax.with_sharding_constraint(
            v, jax.sharding.NamedSharding(
                mesh, cache_leaf_spec(mesh, k, v.shape)))
            for k, v in c.items()}

    def body(i, carry):
        h, cache = carry
        bp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            blocks)
        cs = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            cache)
        h, new = _decode_block(cfg, h, bp, cs, pos)
        cache = {k: jax.lax.dynamic_update_index_in_dim(
            cache[k], new[k].astype(cache[k].dtype), i, 0) for k in cache}
        return (h, constrain_cache(cache))

    h, cache = jax.lax.fori_loop(0, L, body, (h, constrain_cache(cache)))
    return h, cache


# ---------------------------------------------------------------------------
# prefill: forward pass that also populates decode caches
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Params, tokens, cache_len: int = 0,
            frontend_embeds=None):
    """-> (logits (B,S,V), cache filled for positions [0, S))."""
    B, S = tokens.shape
    cache_len = cache_len or S
    if cfg.attention_kind == "none":
        # rwkv prefill: one recurrent pass produces both logits and states
        h, cache = _rwkv_prefill_cache(cfg, params, tokens)
        return _unembed(cfg, params, h[:, -1:, :])[:, 0], cache
    enc_out = None
    if cfg.is_encdec:
        enc_out = _run_encoder(cfg, params, frontend_embeds)
    h = _embed(cfg, params, tokens, frontend_embeds)
    pos = _positions(cfg, tokens)
    dt = dtype_of(cfg.compute_dtype)

    def body(carry, bp):
        h, aux = carry
        new_slices = {}
        if cfg.attention_kind == "hybrid":
            hn = apply_norm(cfg, bp["ln1"], h)
            a, k, v = attn_mod.apply_attention(
                cfg, bp["hyb"]["attn"], hn, pos, return_kv=True)
            m, conv, sstate = ssm_mod.apply_mamba(cfg, bp["hyb"]["mamba"], hn)
            mix = 0.5 * (hyb_mod._rms(a, bp["hyb"]["out_norm_attn"])
                         + hyb_mod._rms(m, bp["hyb"]["out_norm_ssm"]))
            h = h + mix
            f, a2 = _ffn(cfg, bp, h)
            h, aux = h + f, aux + a2
            new_slices = {"k": _pad_cache(k.astype(dt), cache_len),
                          "v": _pad_cache(v.astype(dt), cache_len),
                          "conv": conv.astype(dt), "ssm": sstate}
        elif cfg.attention_kind == "mla":
            hn = apply_norm(cfg, bp["ln1"], h)
            _, _, c_kv, k_rope = mla_mod._project(cfg, bp["mla"], hn)
            from repro.models.layers import apply_rope
            k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
            mix = mla_mod.apply_mla(cfg, bp["mla"], hn, pos)
            h = h + mix
            f, a2 = _ffn(cfg, bp, h)
            h, aux = h + f, aux + a2
            new_slices = {"c_kv": _pad_cache(c_kv.astype(dt), cache_len, rank3=True),
                          "k_rope": _pad_cache(k_rope.astype(dt), cache_len, rank3=True)}
        else:
            hn = apply_norm(cfg, bp["ln1"], h)
            a, k, v = attn_mod.apply_attention(cfg, bp["attn"], hn, pos,
                                               return_kv=True)
            h = h + a
            if enc_out is not None and "cross" in bp:
                hc = apply_norm(cfg, bp["ln_c"], h)
                h = h + attn_mod.apply_cross_attention(cfg, bp["cross"], hc, enc_out)
                cd = dt
                xk = (enc_out.astype(cd) @ bp["cross"]["wk"].astype(cd)).reshape(
                    B, -1, cfg.num_kv_heads, cfg.resolved_head_dim)
                xv = (enc_out.astype(cd) @ bp["cross"]["wv"].astype(cd)).reshape(
                    B, -1, cfg.num_kv_heads, cfg.resolved_head_dim)
                new_slices["xk"], new_slices["xv"] = xk, xv
            f, a2 = _ffn(cfg, bp, h)
            h, aux = h + f, aux + a2
            new_slices["k"] = _pad_cache(k.astype(dt), cache_len)
            new_slices["v"] = _pad_cache(v.astype(dt), cache_len)
        return (h, aux), new_slices

    moe_cfg = cfg.moe
    if moe_cfg is not None and moe_cfg.first_dense_layers:
        (h, aux), slices_d = jax.lax.scan(body, (h, jnp.float32(0.0)),
                                          params["blocks_dense"])
        (h, aux2), slices_m = jax.lax.scan(body, (h, aux), params["blocks"])
        cache = {k: jnp.concatenate([slices_d[k], slices_m[k]], axis=0)
                 for k in slices_m}
        aux = aux2
    else:
        (h, aux), cache = jax.lax.scan(body, (h, jnp.float32(0.0)),
                                       params["blocks"])
    # serving prefill emits only the next-token logits (B, V) — the full
    # (B, S, V) tensor at 32k x 256k vocab would be ~1 PB of dead weight
    logits = _unembed(cfg, params, h[:, -1:, :])[:, 0]
    return logits, cache


def _pad_cache(x, cache_len, rank3=False):
    S = x.shape[1]
    if S >= cache_len:
        return x[:, :cache_len]
    pad = [(0, 0), (0, cache_len - S)] + [(0, 0)] * (x.ndim - 2)
    return jnp.pad(x, pad)


def _rwkv_prefill_cache(cfg: ModelConfig, params, tokens):
    """Recurrent pass -> (h, per-layer final states) (prefill for rwkv)."""
    h = _embed(cfg, params, tokens)
    B = tokens.shape[0]
    N = cfg.ssm.head_dim

    def body(h, bp):
        hn = apply_norm(cfg, bp["ln1"], h)
        state0 = jnp.zeros((B, cfg.num_heads, N, N), jnp.float32)
        mix, xlast_t, state = ssm_mod.apply_rwkv_tmix(
            cfg, bp["tmix"], hn, jnp.zeros((B, cfg.d_model), hn.dtype), state0)
        h = h + mix
        hn = apply_norm(cfg, bp["ln2"], h)
        cm, xlast_c = ssm_mod.apply_rwkv_cmix(
            cfg, bp["cmix"], hn, jnp.zeros((B, cfg.d_model), hn.dtype))
        h = act_sharding.constrain(h + cm, act_sharding.dp(), None, "model")
        return h, {"state": state, "xprev_t": xlast_t, "xprev_c": xlast_c}

    h, cache = jax.lax.scan(body, h, params["blocks"])
    return h, cache
