"""Modality frontend STUBS (the one allowed carve-out).

[audio] seamless-m4t: the mel-spectrogram + conv feature extractor is stubbed;
the model consumes precomputed *frame embeddings* (B, T_frames, frontend_dim).
[vlm] qwen2-vl: the ViT/SigLIP encoder is stubbed; the model consumes
precomputed *patch embeddings* (B, n_patches, frontend_dim).

A learned linear projector (frontend_dim -> d_model) is real and trained; only
the upstream encoder is a stub.  ``frontend_spec`` supplies the
ShapeDtypeStruct stand-ins used by launch/dryrun.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, dtype_of

AUDIO_FRONTEND_DIM = 1024      # w2v-BERT 2.0 frame features
VISION_FRONTEND_DIM = 1280     # Qwen2-VL ViT width
AUDIO_DOWNSAMPLE = 8           # frames per decoder token budget (T_enc = S // 8)
VISION_PATCHES = 1024          # stub patch count (dynamic-resolution placeholder)


def frontend_dim(cfg: ModelConfig) -> int:
    return {"audio": AUDIO_FRONTEND_DIM, "vision": VISION_FRONTEND_DIM}[cfg.frontend]


def init_frontend(cfg: ModelConfig, key):
    if not cfg.frontend:
        return {}
    dt = dtype_of(cfg.param_dtype)
    return {"projector": dense_init(key, (frontend_dim(cfg), cfg.d_model), dt)}


def project(cfg: ModelConfig, p, embeds):
    cd = dtype_of(cfg.compute_dtype)
    return embeds.astype(cd) @ p["projector"].astype(cd)


def num_frontend_tokens(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.frontend == "audio":
        return max(8, seq_len // AUDIO_DOWNSAMPLE)
    if cfg.frontend == "vision":
        return min(VISION_PATCHES, max(8, seq_len // 4))
    return 0
