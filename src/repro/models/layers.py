"""Core layers: norms, embeddings, rotary variants, MLPs.  Raw JAX (no flax).

Parameters are plain dict pytrees.  Stacked-layer parameters carry a leading
L dim and are consumed by ``jax.lax.scan`` in model.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16,
            "float8_e4m3fn": jnp.float8_e4m3fn}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: int):
    p = {"scale": jnp.ones((dim,), dtype_of(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype_of(cfg.param_dtype))
    return p


def apply_norm(cfg: ModelConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE and Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections: Tuple[int, ...] = ()) -> jnp.ndarray:
    """x: (B, S, H, dh); positions: (B, S) or (B, S, 3) for M-RoPE."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                           # (dh/2,)
    if mrope_sections and positions.ndim == 3:
        # M-RoPE: split the dh/2 frequency slots into (t, h, w) sections,
        # each driven by its own position component.  [arXiv:2409.12191]
        secs = mrope_sections
        assert sum(secs) == dh // 2, (secs, dh)
        pos_parts = []
        start = 0
        for i, s in enumerate(secs):
            pos_parts.append(jnp.broadcast_to(
                positions[..., i:i + 1].astype(jnp.float32), positions.shape[:2] + (s,)))
            start += s
        pos = jnp.concatenate(pos_parts, axis=-1)           # (B, S, dh/2)
        angles = pos * freqs[None, None, :]
    else:
        if positions.ndim == 3:
            positions = positions[..., 0]
        angles = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_at(pos, dim: int) -> jnp.ndarray:
    """Sinusoidal PE row(s) for arbitrary (traced) positions.  pos: scalar or
    (...,) -> (..., dim)."""
    pos = jnp.asarray(pos, jnp.float32)
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32)
                  * (-jnp.log(10000.0) / dim))
    ang = pos[..., None] * div
    pe = jnp.zeros(pos.shape + (dim,), jnp.float32)
    pe = pe.at[..., 0::2].set(jnp.sin(ang))
    pe = pe.at[..., 1::2].set(jnp.cos(ang))
    return pe


def sinusoidal_positions(seq: int, dim: int) -> jnp.ndarray:
    pos = np.arange(seq)[:, None]
    div = np.exp(np.arange(0, dim, 2) * (-np.log(10000.0) / dim))
    pe = np.zeros((seq, dim), np.float32)
    pe[:, 0::2] = np.sin(pos * div)
    pe[:, 1::2] = np.cos(pos * div)
    return jnp.asarray(pe)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_model: int, d_ff: int):
    dt = dtype_of(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        return {"w1": dense_init(k1, (d_model, d_ff), dt),
                "w3": dense_init(k3, (d_model, d_ff), dt),
                "w2": dense_init(k2, (d_ff, d_model), dt)}
    if cfg.activation == "rwkv_ffn":
        # RWKV channel-mix: relu(x W1)^2 W2 (+ receptance gate handled in ssm.py)
        return {"w1": dense_init(k1, (d_model, d_ff), dt),
                "w2": dense_init(k2, (d_ff, d_model), dt)}
    return {"w1": dense_init(k1, (d_model, d_ff), dt),
            "w2": dense_init(k2, (d_ff, d_model), dt)}


def apply_mlp(cfg: ModelConfig, p, x):
    cd = dtype_of(cfg.compute_dtype)
    x = x.astype(cd)
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ p["w1"].astype(cd)) * (x @ p["w3"].astype(cd))
        return h @ p["w2"].astype(cd)
    if cfg.activation == "rwkv_ffn":
        h = jnp.square(jax.nn.relu(x @ p["w1"].astype(cd)))
        return h @ p["w2"].astype(cd)
    h = jax.nn.gelu(x @ p["w1"].astype(cd))
    return h @ p["w2"].astype(cd)
