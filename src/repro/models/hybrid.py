"""Hymba hybrid block: parallel attention + Mamba heads on the same input,
outputs normalized and averaged.  [arXiv:2411.13676]"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import backend as backend_mod
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import dtype_of

_MODELS_DIR = os.path.dirname(__file__)


def init_hybrid(cfg: ModelConfig, key):
    ka, km = jax.random.split(key)
    dt = dtype_of(cfg.param_dtype)
    # SSM branch sized to the attention branch (d_inner == H * dh == attn width)
    d_inner = cfg.num_heads * cfg.resolved_head_dim
    return {
        "attn": attn_mod.init_attention(cfg, ka),
        "mamba": ssm_mod.init_mamba(cfg, km, d_inner=d_inner),
        "out_norm_attn": jnp.ones((cfg.d_model,), dt),
        "out_norm_ssm": jnp.ones((cfg.d_model,), dt),
    }


def _rms(x, scale):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


def apply_hybrid(cfg: ModelConfig, p, x, positions, *, backend=None,
                 use_pallas=None):
    """Train/prefill.  Returns block mixer output (B,S,D).

    ``backend``/deprecated ``use_pallas`` select the attention-branch kernel
    (see ``repro.core.backend``); the Mamba branch is always reference."""
    backend = backend_mod.resolve_backend(backend, use_pallas,
                                          skip_dirs=(_MODELS_DIR,))
    a = attn_mod.apply_attention(cfg, p["attn"], x, positions,
                                 backend=backend)
    m, _, _ = ssm_mod.apply_mamba(cfg, p["mamba"], x)
    return 0.5 * (_rms(a, p["out_norm_attn"]) + _rms(m, p["out_norm_ssm"]))


def decode_hybrid(cfg: ModelConfig, p, x, cache_k, cache_v, conv_state,
                  ssm_state, pos):
    """One-token decode through both branches."""
    a, cache_k, cache_v = attn_mod.decode_attention(
        cfg, p["attn"], x, cache_k, cache_v, pos)
    m, conv_state, ssm_state = ssm_mod.apply_mamba(
        cfg, p["mamba"], x, conv_state=conv_state, ssm_state=ssm_state)
    out = 0.5 * (_rms(a, p["out_norm_attn"]) + _rms(m, p["out_norm_ssm"]))
    return out, cache_k, cache_v, conv_state, ssm_state
