"""Crash-atomic file I/O primitives shared by the checkpoint layer and the
trajectory dataset (``repro.data.trajectory_dataset``).

The durability contract both consumers rely on:

  * ``atomic_write_bytes``/``atomic_write_text``: data lands in
    ``<path>.tmp`` and is ``os.replace``d into place, so a SIGKILL mid-write
    leaves at most a stray ``.tmp`` — never a truncated destination file.
  * ``byte_view``: zero-copy uint8 view of a C-contiguous array for crc32 /
    file writes (ml_dtypes leaves such as bfloat16 do not export the buffer
    protocol themselves, and ``memoryview.cast`` chokes on 0-sized shapes).
  * ``read_exact``: bounded read that raises the caller's error class with a
    message naming the file and what was being read — never returns a short
    buffer for the caller to trip over later.
"""
from __future__ import annotations

import os
import time
from pathlib import Path
from typing import Callable, Optional, Type

import numpy as np


def retry_io(fn: Callable[[], object], *, path, what: str = "write",
             attempts: int = 4, backoff: float = 0.05,
             retry_on=(OSError,),
             on_retry: Optional[Callable[[int, Exception], None]] = None,
             sleep: Callable[[float], None] = time.sleep):
    """Run ``fn`` with bounded retry + exponential backoff on transient I/O.

    Shields the sinks and the trajectory dataset against one-off disk-full /
    NFS hiccups without papering over persistent failures: after ``attempts``
    tries the last error is re-raised wrapped in an actionable ``OSError``
    naming the path and the attempt count.  ``on_retry(attempt, exc)`` is
    invoked before each re-try so callers can count recoveries."""
    last: Optional[Exception] = None
    for attempt in range(1, attempts + 1):
        try:
            return fn()
        except retry_on as e:          # noqa: PERF203 — bounded, cold path
            last = e
            if attempt == attempts:
                break
            if on_retry is not None:
                on_retry(attempt, e)
            sleep(backoff * (2 ** (attempt - 1)))
    raise OSError(
        f"{what} to {path} failed after {attempts} attempts "
        f"(last error: {last}); check disk space / filesystem health "
        f"before resuming") from last


def atomic_write_bytes(path, blob: bytes) -> int:
    """Write ``blob`` to ``path`` atomically (tmp + ``os.replace``).

    Returns the number of bytes written.  The parent directory is created
    when missing."""
    p = Path(path)
    tmp = Path(str(p) + ".tmp")
    tmp.parent.mkdir(parents=True, exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, p)
    return len(blob)


def atomic_write_text(path, text: str) -> int:
    """Atomic UTF-8 text write (tmp + ``os.replace``)."""
    return atomic_write_bytes(path, text.encode("utf-8"))


def byte_view(a: np.ndarray):
    """Zero-copy byte buffer of a C-contiguous array (crc + file write)."""
    return b"" if a.nbytes == 0 else a.reshape(-1).view(np.uint8).data


def read_exact(f, n: int, path, what: str,
               error: Type[Exception] = ValueError,
               kind: str = "file") -> bytes:
    """Read exactly ``n`` bytes or raise ``error`` naming ``path``/``what``."""
    buf = f.read(n)
    if len(buf) != n:
        raise error(
            f"truncated {kind} {path}: wanted {n} bytes for {what}, "
            f"file ended after {len(buf)}")
    return buf
