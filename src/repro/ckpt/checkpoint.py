"""Checkpointing: msgpack(+zstd) pytree save/restore, no orbax dependency.

Layout: one file per checkpoint containing a manifest (tree structure, shapes,
dtypes) followed by raw array buffers.  Restore validates the manifest against
the target tree structure.  Large arrays stream in chunks to bound memory.
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover
    zstd = None

MAGIC = b"REPRO_CKPT_V1"


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[key] = leaf
    return out


def save(path: str, tree: Any, *, step: int = 0, compress: bool = True,
         metadata: Optional[Dict] = None) -> int:
    """Write a checkpoint; returns bytes written."""
    leaves = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "metadata": metadata or {},
        "arrays": {k: {"shape": list(np.shape(v)),
                       "dtype": str(np.asarray(v).dtype)}
                   for k, v in leaves.items()},
        "compressed": bool(compress and zstd),
    }
    tmp = Path(str(path) + ".tmp")
    tmp.parent.mkdir(parents=True, exist_ok=True)
    cctx = zstd.ZstdCompressor(level=3) if (compress and zstd) else None
    n = 0
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        mb = msgpack.packb(manifest)
        f.write(len(mb).to_bytes(8, "little"))
        f.write(mb)
        n = len(MAGIC) + 8 + len(mb)
        for k in sorted(leaves):
            buf = np.ascontiguousarray(np.asarray(leaves[k])).tobytes()
            if cctx:
                buf = cctx.compress(buf)
            f.write(len(buf).to_bytes(8, "little"))
            f.write(buf)
            n += 8 + len(buf)
    os.replace(tmp, path)
    return n


def restore(path: str, target: Any = None) -> Any:
    """Load a checkpoint.  With ``target``, validates structure and returns a
    tree of the same structure; without, returns {path: array} dict."""
    dctx = zstd.ZstdDecompressor() if zstd else None
    with open(path, "rb") as f:
        assert f.read(len(MAGIC)) == MAGIC, "not a repro checkpoint"
        mlen = int.from_bytes(f.read(8), "little")
        manifest = msgpack.unpackb(f.read(mlen))
        arrays = {}
        for k in sorted(manifest["arrays"]):
            spec = manifest["arrays"][k]
            blen = int.from_bytes(f.read(8), "little")
            buf = f.read(blen)
            if manifest["compressed"] and dctx:
                buf = dctx.decompress(buf)
            arrays[k] = np.frombuffer(buf, dtype=spec["dtype"]).reshape(
                spec["shape"])
    if target is None:
        return arrays, manifest
    tgt_leaves = _flatten_with_paths(target)
    missing = set(tgt_leaves) - set(arrays)
    extra = set(arrays) - set(tgt_leaves)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    flat, tdef = jax.tree_util.tree_flatten(target)
    kp_flat = jax.tree_util.tree_flatten_with_path(target)[0]
    out = []
    for (kp, leaf) in kp_flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = arrays[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"{key}: shape {arr.shape} != {np.shape(leaf)}")
        out.append(jnp.asarray(arr, dtype=np.asarray(leaf).dtype))
    return tdef.unflatten(out)


def latest_step(ckpt_dir: str) -> Optional[str]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    cands = sorted(d.glob("step_*.ckpt"))
    return str(cands[-1]) if cands else None
