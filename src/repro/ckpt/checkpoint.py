"""Checkpointing: msgpack(+zstd) pytree save/restore, no orbax dependency.

Layout: one file per checkpoint containing a manifest (tree structure, shapes,
dtypes, per-leaf crc32) followed by raw array buffers.  Restore validates the
manifest against the target tree structure AND dtypes, streams large arrays in
bounded chunks, and can place leaves directly onto shardings.  All load-time
failures raise ``CheckpointError`` (a ``ValueError``) naming the offending
leaf — never a garbage tree.

Directory layout (``save_step`` / ``latest_checkpoint`` / ``AsyncCheckpointer``):

    ckpt_dir/
      step_00000010.ckpt     one file per retained step
      step_00000020.ckpt
      LATEST                 name of the newest complete checkpoint

Writes are crash-atomic: data lands in ``<path>.tmp`` and is ``os.replace``d
into place, and the ``LATEST`` pointer is updated the same way — a SIGKILL
mid-save leaves at most a stray ``.tmp``, never a truncated ``.ckpt``.
``latest_checkpoint`` still validates candidates (newest first) so an
externally-corrupted file is skipped, not loaded.

``AsyncCheckpointer`` snapshots device arrays to host (``jax.device_get``)
and writes on a background thread, so a save overlaps the next episode's
collection the same way the engine's double-buffered update does.
"""
from __future__ import annotations

import os
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.ckpt.io import atomic_write_text, byte_view, read_exact
from repro.testing import faults

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover
    zstd = None

MAGIC = b"REPRO_CKPT_V1"
LATEST_NAME = "LATEST"
_CHUNK = 1 << 20          # streaming-restore granularity (1 MiB)


class CheckpointError(ValueError):
    """A checkpoint could not be read/matched; the message names the file
    and (when applicable) the offending leaf path."""


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[key] = leaf
    return out


# zero-copy byte buffer of a C-contiguous array — shared with the trajectory
# dataset via repro.ckpt.io (see byte_view's docstring for the ml_dtypes why)
_byte_view = byte_view


def _np_dtype(name: str) -> np.dtype:
    """Resolve a manifest dtype string (ml_dtypes names like 'bfloat16'
    resolve once jax/ml_dtypes registered them)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def save(path: str, tree: Any, *, step: int = 0, compress: bool = True,
         metadata: Optional[Dict] = None) -> int:
    """Write a checkpoint atomically; returns bytes written.

    ``metadata`` must be msgpack-serializable (plain dict/list/str/num); it
    rides in the manifest and comes back from ``restore``/``read_manifest``.
    ``compress`` silently degrades to raw when zstandard is missing (the
    manifest records which was used, so restore never guesses).
    """
    def _host(v):
        a = np.asarray(v)
        # NB: np.ascontiguousarray would silently promote 0-d to (1,)
        return a if a.flags["C_CONTIGUOUS"] else np.ascontiguousarray(a)

    leaves = _flatten_with_paths(tree)
    arrays = {k: _host(v) for k, v in leaves.items()}
    # crc over the array's own buffer — no tobytes copy of large leaves
    manifest = {
        "step": step,
        "metadata": metadata or {},
        "arrays": {k: {"shape": list(a.shape),
                       "dtype": str(a.dtype),
                       "crc32": zlib.crc32(_byte_view(a))}
                   for k, a in arrays.items()},
        "compressed": bool(compress and zstd),
    }
    tmp = Path(str(path) + ".tmp")
    tmp.parent.mkdir(parents=True, exist_ok=True)
    cctx = zstd.ZstdCompressor(level=3) if (compress and zstd) else None
    n = 0
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        mb = msgpack.packb(manifest)
        f.write(len(mb).to_bytes(8, "little"))
        f.write(mb)
        n = len(MAGIC) + 8 + len(mb)
        for k in sorted(arrays):
            buf = _byte_view(arrays[k])     # zero-copy
            if cctx:
                buf = cctx.compress(buf)
            f.write(len(buf).to_bytes(8, "little"))
            f.write(buf)
            n += 8 + len(buf)
    # fault-injection point (repro.testing.faults, "ckpt_crash"): dying
    # HERE leaves a complete .tmp but no destination — the torn-write shape
    # latest_checkpoint's deep validation must skip over
    faults.maybe_crash_ckpt(step if step is not None else -1, str(path))
    os.replace(tmp, path)
    return n


def _read_exact(f, n: int, path, what: str) -> bytes:
    return read_exact(f, n, path, what, error=CheckpointError,
                      kind="checkpoint")


def _read_header(f, path):
    if f.read(len(MAGIC)) != MAGIC:
        raise CheckpointError(f"not a repro checkpoint: {path}")
    mlen = int.from_bytes(_read_exact(f, 8, path, "manifest length"),
                          "little")
    try:
        manifest = msgpack.unpackb(_read_exact(f, mlen, path, "manifest"))
    except Exception as e:
        raise CheckpointError(
            f"corrupted checkpoint {path}: manifest unreadable ({e})") from e
    if not isinstance(manifest, dict) or "arrays" not in manifest:
        raise CheckpointError(
            f"corrupted checkpoint {path}: manifest has no array table")
    return manifest


def _read_leaf(f, path, key: str, spec: Dict, compressed: bool, dctx
               ) -> np.ndarray:
    """Read one array segment, streaming uncompressed data in chunks
    directly into the destination buffer (bounded memory for large leaves)."""
    blen = int.from_bytes(_read_exact(f, 8, path, f"length of {key!r}"),
                          "little")
    shape = tuple(spec["shape"])
    dtype = _np_dtype(spec["dtype"])
    nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    arr = np.empty(shape, dtype)
    dst = memoryview(arr.reshape(-1).view(np.uint8))
    if compressed:
        if dctx is None:
            raise CheckpointError(
                f"checkpoint {path} is zstd-compressed but zstandard is "
                f"not installed")
        raw = _read_exact(f, blen, path, f"data of {key!r}")
        try:
            buf = dctx.decompress(raw, max_output_size=max(nbytes, 1))
        except Exception as e:
            raise CheckpointError(
                f"corrupted checkpoint {path}: leaf {key!r} fails to "
                f"decompress ({e})") from e
        if len(buf) != nbytes:
            raise CheckpointError(
                f"corrupted checkpoint {path}: leaf {key!r} decompressed "
                f"to {len(buf)} bytes, manifest says {nbytes}")
        dst[:] = buf
    else:
        if blen != nbytes:
            raise CheckpointError(
                f"corrupted checkpoint {path}: leaf {key!r} holds {blen} "
                f"bytes, manifest shape/dtype need {nbytes}")
        off = 0
        while off < nbytes:
            got = f.readinto(dst[off:off + _CHUNK])
            if not got:
                raise CheckpointError(
                    f"truncated checkpoint {path}: leaf {key!r} ended "
                    f"after {off}/{nbytes} bytes")
            off += got
    crc = spec.get("crc32")
    if crc is not None and zlib.crc32(dst) != crc:   # buffer view, no copy
        raise CheckpointError(
            f"corrupted checkpoint {path}: leaf {key!r} fails its crc32 "
            f"integrity check")
    return arr


def read_manifest(path: str) -> Dict:
    """Header-only read: the manifest dict (step, metadata, array table)."""
    with open(path, "rb") as f:
        return _read_header(f, path)


def validate(path: str, *, deep: bool = False) -> Dict:
    """Raise ``CheckpointError`` unless ``path`` is a complete checkpoint.

    Shallow (default): header parses and every array segment is fully
    present (length bookkeeping vs. file size).  ``deep=True`` additionally
    reads every leaf and verifies its crc32.  Returns the manifest."""
    size = os.path.getsize(path)
    with open(path, "rb") as f:
        manifest = _read_header(f, path)
        compressed = bool(manifest.get("compressed"))
        dctx = zstd.ZstdDecompressor() if (compressed and zstd) else None
        for k in sorted(manifest["arrays"]):
            if deep:
                _read_leaf(f, path, k, manifest["arrays"][k], compressed,
                           dctx)
                continue
            blen = int.from_bytes(
                _read_exact(f, 8, path, f"length of {k!r}"), "little")
            end = f.seek(blen, os.SEEK_CUR)
            if end > size:
                raise CheckpointError(
                    f"truncated checkpoint {path}: leaf {k!r} extends past "
                    f"end of file")
    return manifest


def restore(path: str, target: Any = None, *, cast: bool = False,
            shardings: Any = None) -> Any:
    """Load a checkpoint.

    Without ``target``: returns ``(arrays, manifest)`` where ``arrays`` maps
    flattened leaf paths to host ndarrays.

    With ``target``: validates structure, per-leaf shape AND dtype against
    the target tree and returns a tree of the same structure.  A dtype
    mismatch raises ``CheckpointError`` naming the leaf unless ``cast=True``
    (explicit opt-in to convert).  ``shardings`` (a pytree of
    ``jax.sharding.Sharding`` / None matching ``target``) places each leaf
    straight onto its sharding as it streams in, instead of a host->default
    device hop."""
    dctx = zstd.ZstdDecompressor() if zstd else None
    with open(path, "rb") as f:
        manifest = _read_header(f, path)
        compressed = bool(manifest.get("compressed"))
        arrays = {}
        for k in sorted(manifest["arrays"]):
            arrays[k] = _read_leaf(f, path, k, manifest["arrays"][k],
                                   compressed, dctx)
    if target is None:
        return arrays, manifest
    tgt_leaves = _flatten_with_paths(target)
    missing = set(tgt_leaves) - set(arrays)
    extra = set(arrays) - set(tgt_leaves)
    if missing or extra:
        raise CheckpointError(
            f"checkpoint {path} does not match the target tree: "
            f"missing={sorted(missing)[:5]} extra={sorted(extra)[:5]}")
    _, tdef = jax.tree_util.tree_flatten(target)
    kp_flat = jax.tree_util.tree_flatten_with_path(target)[0]
    if shardings is None:
        shard_flat = [None] * len(kp_flat)
    elif isinstance(shardings, jax.sharding.Sharding):
        shard_flat = [shardings] * len(kp_flat)   # one sharding for all
    else:
        shard_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: x is None)[0]
    if len(shard_flat) != len(kp_flat):
        raise ValueError(
            f"shardings tree has {len(shard_flat)} leaves, target has "
            f"{len(kp_flat)}")
    out = []
    for (kp, leaf), sh in zip(kp_flat, shard_flat):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = arrays[key]
        want = np.asarray(leaf)
        if tuple(arr.shape) != tuple(want.shape):
            raise CheckpointError(
                f"checkpoint {path}: leaf {key!r} has shape "
                f"{tuple(arr.shape)}, target wants {tuple(want.shape)}")
        if arr.dtype != want.dtype:
            if not cast:
                raise CheckpointError(
                    f"checkpoint {path}: leaf {key!r} has dtype "
                    f"{arr.dtype}, target wants {want.dtype} "
                    f"(pass cast=True to convert)")
            arr = arr.astype(want.dtype)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            dev = jnp.asarray(arr)
            # with jax_enable_x64 off, jnp.asarray would demote 64-bit
            # leaves; keep the host array rather than lose bits silently
            out.append(dev if dev.dtype == arr.dtype else arr)
    return tdef.unflatten(out)


# ---------------------------------------------------------------------------
# directory layout: step files + LATEST pointer + retention
# ---------------------------------------------------------------------------

def step_path(ckpt_dir: str, step: int) -> Path:
    return Path(ckpt_dir) / f"step_{step:08d}.ckpt"


def _point_latest(ckpt_dir: Path, name: str) -> None:
    atomic_write_text(ckpt_dir / LATEST_NAME, name + "\n")


def save_step(ckpt_dir: str, step: int, tree: Any, *,
              keep: Optional[int] = None, compress: bool = True,
              metadata: Optional[Dict] = None) -> str:
    """Write ``step_<step>.ckpt`` under ``ckpt_dir``, repoint ``LATEST``,
    and (with ``keep``) delete all but the newest ``keep`` step files.
    Returns the checkpoint path."""
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    path = step_path(ckpt_dir, step)
    save(str(path), tree, step=step, compress=compress, metadata=metadata)
    _point_latest(d, path.name)
    if keep is not None and keep > 0:
        for old in sorted(d.glob("step_*.ckpt"))[:-keep]:
            if old != path:
                old.unlink(missing_ok=True)
    return str(path)


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    """Path of the newest checkpoint that validates, or None.

    Step files are tried newest-first (their zero-padded names sort
    chronologically), so a crash in ``save_step``'s window between writing
    the step file and repointing ``LATEST`` still resumes from the newest
    complete checkpoint.  The pointer is only a fallback hint for files the
    ``step_*`` glob cannot see.  Candidates get a deep (crc-verifying)
    validation — a resume happens once per restart, and falling back past a
    bit-flipped file beats aborting on it."""
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    cands = sorted(d.glob("step_*.ckpt"), reverse=True)
    ptr = d / LATEST_NAME
    if ptr.exists():
        try:
            p = d / ptr.read_text().strip()
            if p.exists() and p not in cands:
                cands.append(p)
        except OSError:  # pragma: no cover - unreadable pointer
            pass
    for c in cands:
        try:
            validate(str(c), deep=True)
            return str(c)
        except (CheckpointError, OSError):
            continue
    return None


def latest_step(ckpt_dir: str) -> Optional[str]:
    """Back-compat alias: newest *valid* checkpoint path (or None)."""
    return latest_checkpoint(ckpt_dir)


# ---------------------------------------------------------------------------
# async saves: host snapshot now, disk write in the background
# ---------------------------------------------------------------------------

class AsyncCheckpointer:
    """Periodic checkpoint writer whose disk I/O hides behind compute.

    ``save(step, tree)`` blocks only for (a) the previous write to finish
    (at most one in flight, bounding host memory to one snapshot) and
    (b) ``jax.device_get`` — the device->host snapshot, which must complete
    before training mutates the arrays.  Serialization + disk write then run
    on a single worker thread while the caller dispatches the next episode's
    collection, mirroring the engine's double-buffered update overlap.

    A failed background write surfaces as an exception from the NEXT
    ``save``/``wait``/``close`` call — never silently dropped.
    """

    def __init__(self, ckpt_dir: str, *, keep: int = 3,
                 compress: bool = True, background: bool = True):
        self.dir = Path(ckpt_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.compress = compress
        self._pool = (ThreadPoolExecutor(max_workers=1,
                                         thread_name_prefix="ckpt")
                      if background else None)
        self._inflight: Optional[Future] = None
        self.saves = 0
        self.bytes_written = 0
        self.time_blocked = 0.0      # caller-visible stall (snapshot + waits)

    def save(self, step: int, tree: Any,
             metadata: Optional[Dict] = None) -> None:
        import time
        t0 = time.perf_counter()
        self.wait()                        # <=1 write in flight; raise errors
        host = jax.device_get(tree)        # snapshot before training mutates
        if self._pool is not None:
            self._inflight = self._pool.submit(self._write, step, host,
                                               metadata)
        else:
            self._write(step, host, metadata)
        self.time_blocked += time.perf_counter() - t0
        self.saves += 1

    def _write(self, step: int, host_tree: Any,
               metadata: Optional[Dict]) -> None:
        path = save_step(str(self.dir), step, host_tree, keep=self.keep,
                         compress=self.compress, metadata=metadata)
        self.bytes_written += os.path.getsize(path)

    def wait(self) -> None:
        """Block until the in-flight write lands; re-raises its error."""
        if self._inflight is not None:
            fut, self._inflight = self._inflight, None
            fut.result()

    def close(self) -> None:
        try:
            self.wait()
        finally:
            if self._pool is not None:
                self._pool.shutdown(wait=True)

    def __enter__(self) -> "AsyncCheckpointer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
