"""SeamlessM4T-large v2 transformer backbone (enc-dec, audio). [arXiv:2308.11596]

Modality frontend (mel-spectrogram + conv feature extractor) is a STUB per the
assignment carve-out: input_specs() provides precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    source="arXiv:2308.11596",
    num_layers=24,           # text decoder layers
    encoder_layers=24,       # speech encoder layers (consumes stub frame embeddings)
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,         # GQA kv=16 (== MHA)
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    rope_kind="none",        # learned/sinusoidal positions in M4T; we use sinusoidal
    norm="layernorm",
    activation="gelu",
    frontend="audio",
    train_microbatches=4,    # 256k vocab
))
