"""Llama-3.1 405B dense (GQA, 128k vocab). [arXiv:2407.21783]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3-405b",
    family="dense",
    source="arXiv:2407.21783",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,          # GQA kv=8
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    activation="swiglu",
    optimizer="adafactor",   # 405B: HBM-fit policy (DESIGN.md §8)
    train_microbatches=4,    # §Perf: FSDP regather traffic ~ mb count (X 421->217s)
    kv_cache_dtype="float8_e4m3fn",  # serving HBM fit for 32k x big-batch decode
))
