"""Hymba-1.5B — hybrid parallel attention + mamba heads. [arXiv:2411.13676]"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,          # GQA kv=5
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    rope_theta=10_000.0,
    activation="swiglu",
    attention_kind="hybrid",     # parallel attn + SSM heads in every block
    sliding_window=1024,         # Hymba uses SWA in most layers -> long_500k native
    ssm=SSMConfig(kind="mamba", state_size=16, expand=2),
    train_microbatches=4,
))
