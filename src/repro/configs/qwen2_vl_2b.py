"""Qwen2-VL-2B backbone (M-RoPE, dynamic resolution). [arXiv:2409.12191]

Vision frontend (ViT + projector) is a STUB per the assignment carve-out:
input_specs() provides precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,          # GQA kv=2
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),   # temporal/height/width sections of head_dim/2
    rope_theta=1_000_000.0,
    activation="swiglu",
    frontend="vision",
    train_microbatches=4,    # 152k vocab
))
