"""Phi-3.5-MoE (42B total / 6.6B active, 16 experts top-2). [hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,          # GQA kv=8
    head_dim=128,
    d_ff=6400,               # expert FFN width
    vocab_size=32064,
    rope_theta=10_000.0,
    activation="swiglu",
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400,
                  impl="shard_map"),   # explicit all-to-all expert parallel
    train_microbatches=4,
))
