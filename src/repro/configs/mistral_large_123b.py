"""Mistral-Large-Instruct-2407 (123B dense). [hf:mistralai/Mistral-Large-Instruct-2407]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-large-123b",
    family="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,          # GQA kv=8
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1_000_000.0,
    activation="swiglu",
    optimizer="adafactor",   # 123B: HBM-fit policy (DESIGN.md §8)
    train_microbatches=4,
    kv_cache_dtype="float8_e4m3fn",  # serving HBM fit for 32k x big-batch decode
))
