"""DeepSeek-V3 671B — MLA, 1 shared + 256 routed experts top-8, MTP. [arXiv:2412.19437]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,        # MLA: all heads share the compressed latent
    head_dim=128,
    d_ff=18432,              # dense-FFN width for the first_dense_layers prefix
    vocab_size=129280,
    rope_theta=10_000.0,
    activation="swiglu",
    attention_kind="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, first_dense_layers=3,
                  impl="shard_map"),   # explicit all-to-all expert parallel
    mtp=True,
    optimizer="adafactor",   # 671B: HBM-fit policy (DESIGN.md §8)
    train_microbatches=4,   # §Perf: a2a+regather traffic ~ mb count (X 125->62s)
))
