"""RWKV-6 "Finch" 3B — attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=2560,
    num_heads=40,            # 2560 / 64-dim wkv heads
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    attention_kind="none",
    rope_kind="none",
    norm="layernorm",        # RWKV uses LayerNorm
    activation="rwkv_ffn",   # relu^2 channel-mix
    ssm=SSMConfig(kind="rwkv6", head_dim=64),
    train_microbatches=2,
))
