"""Qwen1.5-32B (dense, QKV bias, full MHA kv=40). [hf:Qwen/Qwen1.5-0.5B family card]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,         # GQA kv=40 (== MHA)
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,           # Qwen1.5 uses QKV bias
    rope_theta=1_000_000.0,
    activation="swiglu",
    train_microbatches=2,
    kv_cache_dtype="float8_e4m3fn",  # serving HBM fit for 32k x big-batch decode
))
