"""Config schema for assigned architectures + the paper's own DRL policy.

Every architecture from the public pool is expressed as a ``ModelConfig``;
``reduced()`` derives the CPU-smoke variant (2 layers, d_model<=512, <=4 experts)
required by the spec.  Configs are plain dataclasses — no framework dependency.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    # Dense-FFN prefix (DeepSeek-V3 keeps the first 3 layers dense).
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # 'gspmd'  : gather/scatter dispatch, XLA chooses collectives (baseline)
    # 'shard_map': explicit all-to-all expert parallelism (optimized path)
    impl: str = "gspmd"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "rwkv6"          # 'rwkv6' | 'mamba'
    state_size: int = 16          # mamba ssm state; rwkv uses head_dim x head_dim
    head_dim: int = 64
    expand: int = 2               # mamba inner expansion
    dt_rank: int = 0              # 0 -> ceil(d_model/16)
    conv_width: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    source: str                   # citation for the config numbers
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    qkv_bias: bool = False
    attention_kind: str = "gqa"   # gqa | mla | none | hybrid
    rope_kind: str = "rope"       # rope | mrope | none
    rope_theta: float = 1_000_000.0
    mrope_sections: Tuple[int, ...] = ()
    sliding_window: int = 0       # 0 -> full attention
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    activation: str = "swiglu"    # swiglu | gelu
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (seamless): encoder_layers > 0 enables the encoder stack.
    encoder_layers: int = 0
    frontend: str = ""            # '' | 'audio' | 'vision'  (stub embeddings)
    mtp: bool = False             # DeepSeek multi-token prediction head
    optimizer: str = "adamw"      # adamw | adafactor  (HBM-fit policy, DESIGN.md §8)
    train_microbatches: int = 1   # gradient accumulation (activation HBM fit)
    kv_cache_dtype: str = ""      # '' = compute dtype; 'float8_e4m3fn' for
                                  # the big dense archs (serving HBM fit)
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # remat policy for train_step: '' | 'full' | 'dots'
    remat: str = "full"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding-table size: vocab padded to a multiple of 256 so the
        vocab axis shards on any mesh (Megatron-style).  Only seamless
        (256206) and hymba (32001) actually pad; logits over padded slots
        train toward -inf naturally (never the label)."""
        if self.vocab_size % 256 == 0:
            return self.vocab_size
        return -(-self.vocab_size // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_decode(self) -> bool:
        return True  # no encoder-only archs in this assignment

    def reduced(self) -> "ModelConfig":
        """CPU smoke variant: 2 layers, d_model<=512, <=4 experts, small vocab."""
        d_model = min(self.d_model, 256)
        # keep head structure ratios but shrink
        num_heads = max(2, min(self.num_heads, 4))
        num_kv_heads = max(1, min(self.num_kv_heads, num_heads))
        head_dim = d_model // num_heads
        moe = None
        if self.moe is not None:
            moe = replace(
                self.moe, num_experts=min(4, self.moe.num_experts),
                top_k=min(2, self.moe.top_k), d_ff_expert=128,
                num_shared_experts=min(1, self.moe.num_shared_experts),
                first_dense_layers=min(1, self.moe.first_dense_layers),
                capacity_factor=8.0)   # effectively dropless at smoke scale
        mla = None
        if self.mla is not None:
            mla = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                            qk_nope_head_dim=head_dim, qk_rope_head_dim=16,
                            v_head_dim=head_dim)
        ssm = None
        if self.ssm is not None:
            ssm = replace(self.ssm, state_size=min(8, self.ssm.state_size),
                          head_dim=min(32, self.ssm.head_dim))
            if self.ssm.kind == "rwkv6":
                # rwkv requires H * wkv_head_dim == d_model
                num_heads = d_model // ssm.head_dim
                num_kv_heads = num_heads
                head_dim = ssm.head_dim
        sections = ()
        if self.mrope_sections:
            h = head_dim // 2
            a = h // 3
            sections = (h - 2 * a, a, a)
        return replace(
            self, name=self.name + "-reduced", num_layers=2, d_model=d_model,
            num_heads=num_heads, num_kv_heads=num_kv_heads, head_dim=head_dim,
            d_ff=min(self.d_ff, 512), vocab_size=min(self.vocab_size, 512),
            moe=moe, mla=mla, ssm=ssm, encoder_layers=min(self.encoder_layers, 2),
            mrope_sections=sections, sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else 0, train_microbatches=1,
            kv_cache_dtype="",
            param_dtype="float32", compute_dtype="float32", remat="")


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (public pool).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    # import side-effect registers each config module
    from repro.configs import (  # noqa: F401
        mistral_large_123b, qwen15_32b, rwkv6_3b, phi35_moe_42b, llama3_405b,
        seamless_m4t_large_v2, hymba_15b, deepseek_v3_671b, phi4_mini_38b,
        qwen2_vl_2b,
    )
