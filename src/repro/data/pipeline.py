"""Data pipeline: synthetic LM token stream + device-resident DRL buffers.

The LM stream is deterministic-by-step (seed, step) -> batch, so every data-
parallel worker can slice its own shard without coordination (the standard
multi-pod pattern: no network filesystem dependency in the input path —
the same lesson the paper teaches about interfaces applies to data loading).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models import frontend as fe_mod


@dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # zipf-ish skew so loss curves look like text, not uniform noise
    zipf_alpha: float = 1.1


def synthetic_batch(cfg: LMDataConfig, step: int,
                    model_cfg: Optional[ModelConfig] = None) -> Dict:
    """Deterministic synthetic batch for a given step (host numpy)."""
    rng = np.random.default_rng((cfg.seed, step))
    ranks = rng.zipf(cfg.zipf_alpha,
                     size=(cfg.global_batch, cfg.seq_len + 1))
    tokens = np.minimum(ranks, cfg.vocab_size - 1).astype(np.int32)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if model_cfg is not None and model_cfg.frontend:
        t = fe_mod.num_frontend_tokens(model_cfg, cfg.seq_len)
        d = fe_mod.frontend_dim(model_cfg)
        batch["frontend_embeds"] = rng.standard_normal(
            (cfg.global_batch, t, d)).astype(np.float32)
    return batch


def lm_iterator(cfg: LMDataConfig, model_cfg: Optional[ModelConfig] = None,
                start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, step, model_cfg)
        step += 1


def shard_batch(batch: Dict, sharding_tree) -> Dict:
    """Place a host batch onto the mesh with the given shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jnp.asarray(x), s), batch, sharding_tree)


# ---------------------------------------------------------------------------
# DRL trajectory store (device-resident, the 'optimized interface' data path)
# ---------------------------------------------------------------------------

class TrajectoryStore:
    """Accumulates rollout batches on device; never round-trips the host.

    This is the I/O-optimized counterpart of core.interface.FileInterface:
    the (s, a, r) stream stays in HBM, PPO consumes it in place."""

    def __init__(self, capacity_episodes: int = 8):
        self.capacity = capacity_episodes
        self._buf = []

    def add(self, batch):
        self._buf.append(batch)
        if len(self._buf) > self.capacity:
            self._buf.pop(0)

    def sample_all(self):
        if len(self._buf) == 1:
            return self._buf[0]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                            *self._buf)

    def __len__(self):
        return len(self._buf)
