"""Sharded on-disk trajectory dataset: the durable end of the sink API.

Layout under one dataset root::

    manifest.json      JSON index + run metadata (atomic tmp+os.replace)
    shard_00000.bin    [8-byte LE length][pack_arrays payload] records
    shard_00001.bin    ... (rotated at ``shard_max_bytes``)

The manifest is the single source of truth: it maps episode -> (shard,
offset, length, crc32) and records how many bytes of each shard are
*committed*.  A record is appended and fsync'd BEFORE the manifest is
atomically replaced, so a SIGKILL at any point leaves either a fully
indexed record or ignorable tail garbage past the committed byte count —
never a corrupt dataset (the PR-4 checkpoint durability contract, via
``repro.ckpt.io``).  Payloads reuse the ``core.interface`` msgpack+fp32
codec (optionally zstd, degrading to binary when zstandard is absent,
like ``FileSink``).

``DatasetSink`` is the write side (a ``TrajectorySink``, selected with
``SinkSpec(kind='dataset', root=...)``); ``TrajectoryReader`` is the read
side, feeding recorded episodes back through ``RolloutEngine.replay_sync``
for offline PPO and the record -> replay bitwise gate
(``tools/replay_smoke.py``).
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from pathlib import Path
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.ckpt.io import atomic_write_text, read_exact, retry_io
from repro.core.interface import pack_arrays, unpack_arrays
from repro.drl.engine import SinkReadError, TrajectorySink
from repro.drl.rollout import Trajectory
from repro.testing import faults

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover - optional, gated
    zstd = None

DATASET_SCHEMA = "repro.traj_dataset/v1"
MANIFEST_NAME = "manifest.json"
_LEN = struct.Struct("<Q")          # record framing: 8-byte LE payload length


class DatasetError(ValueError):
    """A trajectory dataset failed validation (missing/truncated/corrupt
    shard, schema or codec mismatch).  Messages name the dataset root and
    the offending shard, ``CheckpointError`` style."""


def _shard_name(i: int) -> str:
    return f"shard_{i:05d}.bin"


class DatasetSink(TrajectorySink):
    """Append-only sharded writer.  Crash-safe by construction: shard bytes
    are fsync'd before the manifest (the index) is atomically replaced, and
    readers never look past the manifest's committed byte counts.

    Reopening an existing dataset root resumes it: committed records are
    kept, any un-indexed tail from a previous crash is overwritten."""

    def __init__(self, root: str, codec: str = "binary",
                 shard_max_bytes: int = 64 * 1024 * 1024,
                 process: Optional[int] = None):
        super().__init__()
        if codec not in ("binary", "zstd"):
            raise ValueError(f"unknown trajectory-sink codec {codec!r}; "
                             f"choose 'binary' or 'zstd'")
        if codec == "zstd" and zstd is None:
            codec = "binary"
        self.codec = codec
        self.shard_max_bytes = int(shard_max_bytes)
        self.process = process
        # fleet mode: each concurrent runner owns a part{NNN} subdirectory
        # (its own shards + manifest-as-truth) under the shared dataset
        # root, so per-host spills never contend on one manifest file
        self.root = Path(root) if process is None \
            else Path(root) / f"part{process:03d}"
        self.root.mkdir(parents=True, exist_ok=True)
        self._cctx = zstd.ZstdCompressor(level=1) if codec == "zstd" else None
        mpath = self.root / MANIFEST_NAME
        if mpath.exists():
            self._man = json.loads(mpath.read_text())
            if self._man.get("schema") != DATASET_SCHEMA:
                raise DatasetError(
                    f"not a trajectory dataset at {self.root}: manifest "
                    f"schema {self._man.get('schema')!r} != {DATASET_SCHEMA!r}")
            self.codec = self._man["codec"]   # resumed datasets keep theirs
            if self.codec == "zstd" and zstd is None:
                raise DatasetError(
                    f"dataset at {self.root} uses codec 'zstd' but "
                    f"zstandard is not installed; cannot append")
        else:
            self._man = {"schema": DATASET_SCHEMA, "codec": self.codec,
                         "metadata": {} if process is None
                         else {"process": process},
                         "episodes": {}, "shards": {}}
            self._flush_manifest()

    # -- manifest ------------------------------------------------------------

    def _flush_manifest(self) -> None:
        def on_retry(attempt_no, exc):
            self.retries += 1

        retry_io(lambda: atomic_write_text(
                     self.root / MANIFEST_NAME,
                     json.dumps(self._man, indent=1, sort_keys=True)),
                 path=self.root / MANIFEST_NAME, what="dataset manifest",
                 on_retry=on_retry)

    def annotate(self, **meta) -> None:
        """Record run-level metadata (``train_state.run_metadata`` + seed)
        into the manifest so the dataset outlives the writing process."""
        self._man["metadata"].update(
            json.loads(json.dumps(meta, default=str)))
        self._flush_manifest()

    @property
    def metadata(self) -> Dict:
        return dict(self._man["metadata"])

    # -- shard append --------------------------------------------------------

    def _current_shard(self) -> str:
        shards = self._man["shards"]
        if shards:
            name = max(shards)
            if shards[name] < self.shard_max_bytes:
                return name
            return _shard_name(len(shards))
        return _shard_name(0)

    def _write(self, episode: int, traj: Trajectory) -> int:
        arrays = {f: np.asarray(a) for f, a in zip(Trajectory._fields, traj)
                  if a is not None}
        blob = pack_arrays(arrays, cctx=self._cctx)
        name = self._current_shard()
        offset = self._man["shards"].get(name, 0)
        path = self.root / name

        def append():
            faults.maybe_fail_io(str(path))
            # r+b at the committed offset (NOT append mode): overwrites any
            # un-indexed tail a previous SIGKILL left behind — which also
            # makes a retried attempt idempotent (it re-seeks and rewrites
            # the same committed offset)
            with open(path, "r+b" if path.exists() else "wb") as f:
                f.seek(offset)
                f.write(_LEN.pack(len(blob)))
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())

        def on_retry(attempt_no, exc):
            self.retries += 1

        retry_io(append, path=path,
                 what=f"dataset shard append (episode {episode})",
                 on_retry=on_retry)
        n = _LEN.size + len(blob)
        self._man["episodes"][str(episode)] = {
            "shard": name, "offset": offset, "length": len(blob),
            "crc32": zlib.crc32(blob) & 0xFFFFFFFF,
            "shape": {f: list(a.shape) for f, a in arrays.items()},
        }
        self._man["shards"][name] = offset + n
        self._flush_manifest()          # record durable BEFORE it is indexed
        return n

    def cleanup(self) -> None:
        import shutil
        shutil.rmtree(self.root, ignore_errors=True)


class TrajectoryReader:
    """Read side of the dataset: validates the manifest against the shard
    files, then serves ``read(episode) -> Trajectory`` (the interface
    ``RolloutEngine.replay_sync`` consumes)."""

    def __init__(self, root: str, *, validate: bool = True):
        self.root = Path(root)
        mpath = self.root / MANIFEST_NAME
        if not mpath.exists():
            raise DatasetError(f"no trajectory dataset at {self.root}: "
                               f"missing {MANIFEST_NAME}")
        self._man = json.loads(mpath.read_text())
        if self._man.get("schema") != DATASET_SCHEMA:
            raise DatasetError(
                f"not a trajectory dataset at {self.root}: manifest schema "
                f"{self._man.get('schema')!r} != {DATASET_SCHEMA!r}")
        self.codec = self._man.get("codec", "binary")
        if self.codec == "zstd" and zstd is None:
            raise DatasetError(
                f"dataset at {self.root} was written with codec 'zstd' but "
                f"zstandard is not installed; install it or re-record with "
                f"codec 'binary'")
        self._dctx = zstd.ZstdDecompressor() if self.codec == "zstd" else None
        if validate:
            self.validate()

    # -- index ---------------------------------------------------------------

    @property
    def episodes(self) -> List[int]:
        return sorted(int(e) for e in self._man["episodes"])

    @property
    def metadata(self) -> Dict:
        return dict(self._man.get("metadata", {}))

    def _range(self) -> str:
        eps = self.episodes
        return (f"episodes {eps[0]}..{eps[-1]} ({len(eps)} recorded)"
                if eps else "no episodes")

    # -- validation ----------------------------------------------------------

    def validate(self) -> None:
        """Cross-check the manifest against the shard files on disk.

        Catches: an episode index referencing a shard absent from the shard
        table (manifest/shard-count mismatch), a shard file the manifest
        commits bytes to that is missing, and a shard shorter than its
        committed byte count (truncation past the atomic-write guarantee,
        e.g. a copied-out dataset)."""
        shards = self._man["shards"]
        for ep, rec in self._man["episodes"].items():
            if rec["shard"] not in shards:
                raise DatasetError(
                    f"manifest/shard-count mismatch in {self.root}: episode "
                    f"{ep} references shard {rec['shard']} absent from the "
                    f"shard table ({len(shards)} shards listed)")
        for name, committed in shards.items():
            path = self.root / name
            if not path.exists():
                raise DatasetError(f"manifest references missing shard "
                                   f"{name} in {self.root}")
            size = path.stat().st_size
            if size < committed:
                raise DatasetError(
                    f"truncated shard {name} in {self.root}: manifest "
                    f"commits {committed} bytes, file has {size}")

    # -- record access -------------------------------------------------------

    def read(self, episode: int) -> Trajectory:
        rec = self._man["episodes"].get(str(episode))
        if rec is None:
            raise SinkReadError(
                f"sink holds no episode {episode}: dataset at {self.root} "
                f"(codec {self.codec!r}) has {self._range()}")
        name = rec["shard"]
        path = self.root / name
        if not path.exists():
            raise DatasetError(f"manifest references missing shard {name} "
                               f"in {self.root}")
        with open(path, "rb") as f:
            f.seek(rec["offset"])
            hdr = read_exact(f, _LEN.size, path,
                             f"episode {episode} record header",
                             error=DatasetError, kind="shard")
            (n,) = _LEN.unpack(hdr)
            if n != rec["length"]:
                raise DatasetError(
                    f"corrupted shard {name} in {self.root}: episode "
                    f"{episode} record header says {n} bytes, manifest "
                    f"says {rec['length']}")
            blob = read_exact(f, n, path, f"episode {episode} payload",
                              error=DatasetError, kind="shard")
        crc = zlib.crc32(blob) & 0xFFFFFFFF
        if crc != rec["crc32"]:
            raise DatasetError(
                f"crc32 mismatch in shard {name} of {self.root}: episode "
                f"{episode} stored {rec['crc32']:#010x}, computed "
                f"{crc:#010x} — shard bytes are corrupt")
        arrays, _ = unpack_arrays(blob, dctx=self._dctx)
        return Trajectory(**{f: arrays[f] for f in Trajectory._fields
                             if f in arrays})

    def __iter__(self) -> Iterator[Trajectory]:
        for ep in self.episodes:
            yield self.read(ep)

    def __len__(self) -> int:
        return len(self._man["episodes"])
