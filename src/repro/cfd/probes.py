"""Pressure-probe layouts + sampler (bilinear interpolation at fixed points).

Layouts are registered by name so scenarios (``repro.cfd.scenarios``) can pick
an observation vector per case:

  ring149   72 probes on three rings + 77 wake grid (Wang et al. 2022 style,
            the repo's historical default)
  sparse24  16-probe ring at r=0.8 + 8 near-wake probes (Tang et al. style
            reduced sensing)
  sparse8   8-probe ring at r=0.8 (minimal sensing)
  pinball   8-probe ring around each of the three pinball cylinders + a
            5x7 wake grid behind the triangle (59 probes)
  tandem    16-probe ring around each tandem cylinder + 8 wake probes (40)

``sample_pressure`` takes the probe coordinates as *data* (not closure
constants), so per-env probe layouts vmap into one program; a probe mask
zeroes padded entries when layouts of different sizes share one batch.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd.grid import CYL_X, CYL_Y, GEOMETRIES, probe_positions


def _ring(n: int, r: float, cx: float = CYL_X, cy: float = CYL_Y) -> np.ndarray:
    a = 2 * np.pi * np.arange(n) / n
    return np.stack([cx + r * np.cos(a), cy + r * np.sin(a)], axis=-1)


def _sparse24() -> np.ndarray:
    wake = np.stack([np.linspace(1.5, 8.0, 8), np.zeros(8)], axis=-1)
    return np.concatenate([_ring(16, 0.8), wake])


def _body_rings(geometry: str, n: int, r: float) -> np.ndarray:
    return np.concatenate([_ring(n, r, b.x, b.y)
                           for b in GEOMETRIES[geometry]])


def _pinball() -> np.ndarray:
    # 8 probes per cylinder ring + a 5x7 wake grid behind the triangle
    rings = _body_rings("pinball", 8, 0.8)
    wx, wy = np.meshgrid(np.linspace(2.0, 8.0, 7), np.linspace(-1.4, 1.4, 5))
    wake = np.stack([wx.ravel(), wy.ravel()], axis=-1)
    return np.concatenate([rings, wake])


def _tandem() -> np.ndarray:
    wake = np.stack([np.linspace(2.5, 9.0, 8),
                     np.full(8, CYL_Y)], axis=-1)
    return np.concatenate([_body_rings("tandem", 16, 0.8), wake])


LAYOUTS: Dict[str, Callable[[], np.ndarray]] = {
    "ring149": probe_positions,
    "sparse24": _sparse24,
    "sparse8": lambda: _ring(8, 0.8),
    "pinball": _pinball,
    "tandem": _tandem,
}


def layout_positions(name: str) -> np.ndarray:
    """(P, 2) physical probe coordinates for a registered layout."""
    try:
        return LAYOUTS[name]()
    except KeyError:
        raise KeyError(f"unknown probe layout {name!r}; "
                       f"known: {sorted(LAYOUTS)}") from None


def layout_size(name: str) -> int:
    return len(layout_positions(name))


def sample_pressure(probe_ij, p, mask=None) -> jnp.ndarray:
    """p: (ny, nx) cell-centered pressure -> (P,) probe values.

    probe_ij: (P, 2) fractional [row, col] coords (see grid.points_to_ij);
    mask: optional (P,) multiplier zeroing padded probe slots."""
    coords = jnp.asarray(probe_ij, jnp.float32).T       # (2, P) [row, col]
    vals = jax.scipy.ndimage.map_coordinates(p, coords, order=1,
                                             mode="nearest")
    if mask is not None:
        vals = vals * jnp.asarray(mask, vals.dtype)
    return vals
