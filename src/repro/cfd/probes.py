"""149-probe pressure sampler (bilinear interpolation at fixed positions)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cfd.grid import Geometry


def sample_pressure(geom_probe_ij, p) -> jnp.ndarray:
    """p: (ny, nx) cell-centered pressure -> (149,) probe values."""
    coords = jnp.asarray(geom_probe_ij, jnp.float32).T  # (2, 149) [row, col]
    return jax.scipy.ndimage.map_coordinates(p, coords, order=1,
                                             mode="nearest")
