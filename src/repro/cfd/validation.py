"""Shared physics-validation helpers: golden-reference measurement.

Used by ``tools/gen_golden.py`` (writes the checked-in reference) and
``tests/test_golden_physics.py`` (re-measures and compares) so both sides
compute Strouhal / mean C_D / C_L amplitude with byte-identical code.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd import solver
from repro.cfd.grid import GridConfig, build_geometry


def run_uncontrolled(cfg: GridConfig, state: solver.FlowState, n: int,
                     *, backend: str = None, mesh=None,
                     geometry: str = "cylinder"
                     ) -> Tuple[solver.FlowState, np.ndarray, np.ndarray]:
    """Advance ``n`` uncontrolled (jet_vel = 0) steps; returns (state, cds,
    cls) with force-coefficient time series as numpy arrays.

    ``backend``/``mesh`` select the Poisson backend (see ``cfd.poisson``),
    so the golden physics window can be re-measured through e.g. the
    ``"halo"`` domain-decomposed path.  ``geometry`` picks the obstacle set
    (``grid.GEOMETRIES``); forces are the total over all bodies, which is
    what the golden fixtures pin."""
    geom_arrays = solver.geom_to_arrays(build_geometry(cfg, geometry))

    def body(flow, _):
        flow, out = solver.step(cfg, geom_arrays, flow, jnp.float32(0.0),
                                backend=backend, mesh=mesh)
        return flow, (out.cd, out.cl)

    state, (cds, cls) = jax.jit(
        lambda s: jax.lax.scan(body, s, None, length=n))(state)
    return state, np.asarray(cds), np.asarray(cls)


def measure_shedding(cds: np.ndarray, cls: np.ndarray, dt: float
                     ) -> Dict[str, float]:
    """Vortex-shedding metrics over a developed window.

    Strouhal from the mean upward-zero-crossing period of the mean-removed
    C_L signal (sub-step resolution via linear interpolation); St = f D / U
    with D = U_mean = 1 in our nondimensionalization.
    """
    cl = cls - cls.mean()
    sgn = cl > 0
    idx = np.flatnonzero(~sgn[:-1] & sgn[1:])
    if len(idx) < 3:
        raise ValueError("window too short: fewer than 3 C_L zero crossings "
                         "(no developed shedding?)")
    t_cross = idx + cl[idx] / (cl[idx] - cl[idx + 1])
    period = float(np.diff(t_cross).mean()) * dt
    return {
        "strouhal": 1.0 / period,
        "cd_mean": float(cds.mean()),
        "cl_amp": float(0.5 * (cls.max() - cls.min())),
        "n_periods": float(len(idx) - 1),
    }
