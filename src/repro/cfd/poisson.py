"""Pressure Poisson solver: red-black SOR with channel boundary conditions.

BCs: Neumann (dp/dn = 0) at inlet and walls, Dirichlet (p = 0) at the outlet.
This is the CFD hot spot (the paper attributes >95% of wall time to CFD; within
our fractional-step solver the pressure solve dominates).  ``solve`` fans out
over three interchangeable backends:

  "reference"  the jnp sweep below — the CPU execution path and the oracle
  "pallas"     kernels/poisson's TPU slab smoother (block-Jacobi slabs)
  "halo"       cfd/decomp's explicit x-slab domain decomposition with
               shard_map + ppermute halo exchange over a mesh axis — the
               paper's N_ranks parallelism, executable inside the vmapped
               env step

``use_pallas=`` is kept as a deprecated alias for backend selection.
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

BACKENDS = ("reference", "pallas", "halo")


def resolve_backend(backend: Optional[str] = None,
                    use_pallas: Optional[bool] = None) -> str:
    """Normalize the (backend, legacy use_pallas) pair to a BACKENDS member.

    ``use_pallas`` is a deprecated alias: True -> "pallas", False ->
    "reference".  Passing both a backend and a conflicting alias is an error.
    """
    if use_pallas is not None:
        alias = "pallas" if use_pallas else "reference"
        if backend is not None and backend != alias:
            raise ValueError(
                f"conflicting solver selection: backend={backend!r} vs "
                f"use_pallas={use_pallas} (alias for {alias!r}); drop the "
                f"deprecated use_pallas= argument")
        warnings.warn("use_pallas= is deprecated; pass backend='pallas' "
                      "(or 'reference') instead", DeprecationWarning,
                      stacklevel=3)
        backend = alias
    backend = backend or "reference"
    if backend not in BACKENDS:
        raise ValueError(f"unknown Poisson backend {backend!r}; "
                         f"choose from {BACKENDS}")
    return backend


def _pad_pressure(p):
    """Ghost cells: Neumann left/top/bottom, Dirichlet 0 at right (outlet)."""
    left = p[:, :1]              # dp/dx = 0 at inlet
    right = -p[:, -1:]           # p = 0 at the outlet face
    p = jnp.concatenate([left, p, right], axis=1)
    top = p[:1, :]
    bot = p[-1:, :]
    return jnp.concatenate([top, p, bot], axis=0)


def residual(p, rhs, dx, dy):
    pp = _pad_pressure(p)
    lap = ((pp[1:-1, :-2] + pp[1:-1, 2:] - 2 * p) / dx ** 2
           + (pp[:-2, 1:-1] + pp[2:, 1:-1] - 2 * p) / dy ** 2)
    return lap - rhs


@functools.partial(jax.jit, static_argnames=("dx", "dy", "iters", "backend",
                                             "use_pallas", "polish", "mesh",
                                             "halo_axis", "halo_inner"))
def solve(rhs, dx, dy, *, iters: int = 60, omega: float = 1.7,
          p0=None, backend: Optional[str] = None,
          use_pallas: Optional[bool] = None, polish: int = 10,
          mesh=None, halo_axis: str = "model", halo_inner: int = 4):
    """Red-black SOR.  rhs: (ny, nx).  Returns p with mean-free gauge handled
    by the outlet Dirichlet condition.

    The last ``polish`` sweeps run with omega = 1 (plain Gauss-Seidel):
    over-relaxation accelerates the smooth error modes but leaves an
    amplified high-frequency residual, which a few unrelaxed smoothing
    sweeps remove (~4x lower residual norm at equal total iterations).

    ``backend="pallas"`` requires an even nx (checkerboard slab parity); odd
    widths silently fall back to the reference path so callers never crash
    on unusual grids.  ``backend="halo"`` runs cfd/decomp's explicit x-slab
    decomposition over ``mesh``'s ``halo_axis`` (``halo_inner`` local sweeps
    per halo exchange) and is traceable under vmap — the paper's N_ranks > 1
    configuration."""
    backend = resolve_backend(backend, use_pallas)
    ny, nx = rhs.shape
    if backend == "pallas" and nx % 2:
        backend = "reference"
    p = jnp.zeros_like(rhs) if p0 is None else p0

    if backend == "halo":
        if mesh is None:
            raise ValueError(
                "backend='halo' needs a mesh with a spatial axis; pass "
                "mesh= (e.g. launch.mesh.mesh_for_plan(plan)) or choose "
                "backend='reference'")
        from repro.cfd import decomp
        return decomp.decomposed_solve(rhs, p, mesh=mesh, axis=halo_axis,
                                       dx=dx, dy=dy, omega=omega,
                                       iters=iters, inner_iters=halo_inner,
                                       polish=polish)

    jj, ii = jnp.meshgrid(jnp.arange(ny), jnp.arange(nx), indexing="ij")
    red = ((ii + jj) % 2 == 0)
    inv_diag = 1.0 / (2.0 / dx ** 2 + 2.0 / dy ** 2)

    def sweep(p, mask, om):
        pp = _pad_pressure(p)
        nb = ((pp[1:-1, :-2] + pp[1:-1, 2:]) / dx ** 2
              + (pp[:-2, 1:-1] + pp[2:, 1:-1]) / dy ** 2)
        p_gs = (nb - rhs) * inv_diag
        return jnp.where(mask, (1 - om) * p + om * p_gs, p)

    n_polish = min(polish, iters // 2)
    n_sor = iters - n_polish

    if backend == "pallas":
        from repro.kernels.poisson import ops as poisson_ops
        p = poisson_ops.rb_sor(rhs, dx, dy, iters=n_sor, omega=omega, p0=p)

        def gs(_, p):
            p = sweep(p, red, 1.0)
            return sweep(p, ~red, 1.0)

        return jax.lax.fori_loop(0, n_polish, gs, p)

    def body(i, p):
        om = jnp.where(i < n_sor, omega, 1.0)
        p = sweep(p, red, om)
        p = sweep(p, ~red, om)
        return p

    return jax.lax.fori_loop(0, iters, body, p)
