"""Pressure Poisson solver: red-black SOR with channel boundary conditions.

BCs: Neumann (dp/dn = 0) at inlet and walls, Dirichlet (p = 0) at the outlet.
This is the CFD hot spot (the paper attributes >95% of wall time to CFD; within
our fractional-step solver the pressure solve dominates).  ``solve`` fans out
over the interchangeable backends:

  "reference"  the default: dispatches to "packed" on even-width grids and
               to "full" on odd widths — always correct, fastest jnp path
  "packed"     packed-checkerboard storage: red and black points held as two
               (ny, nx//2) planes so every sweep touches exactly the points
               it updates — no masks, no wasted update, ~half the FLOPs and
               memory traffic of the full-grid sweep.  Even nx only.
  "full"       the original full-grid masked sweep — the oracle the packed
               layout is tested against
  "pallas"     kernels/poisson's TPU slab smoother (block-Jacobi slabs,
               packed planes VMEM-resident per slab)
  "halo"       cfd/decomp's explicit x-slab domain decomposition with
               shard_map + ppermute halo exchange over a mesh axis — the
               paper's N_ranks parallelism, executable inside the vmapped
               env step; ships half-width (single-parity) halos
  "fused"      the actuation-interval megakernel (kernels/actuation via
               solver.step_interval): velocity fields and packed pressure
               parity planes stay resident across a whole actuation
               interval.  For a single ``solve`` call it is an alias for
               "reference" (there is no interval to fuse)

``use_pallas=`` is kept as a deprecated alias for backend selection.

Packed-checkerboard index map (nx even; row j, packed column k):

  red[j, k]   = p[j, 2k + j%2]          black[j, k] = p[j, 2k + 1 - j%2]

Vertical neighbours of a point land at the SAME packed index in the other
plane; horizontal neighbours are the other plane's columns (k-1, k) on one
row parity and (k, k+1) on the other, so one shifted add of the opposite
plane plus a per-row-parity select covers west+east.  The boundary ghosts
fall out of the layout: the ghost values a half-sweep needs always carry the
parity of the plane being *updated* (Neumann inlet ghost = own first column,
Dirichlet outlet ghost = negated own last column, wall ghosts = own
boundary rows), so no full-grid padding is ever materialized.
"""
from __future__ import annotations

import functools
import os
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod

BACKENDS = ("reference", "packed", "full", "pallas", "halo", "fused")

# grid shapes already warned about for the pallas -> reference odd-width
# fallback (warn once per shape, not once per traced call site; resettable
# via core.backend.reset_warning_caches for test isolation)
_ODD_NX_WARNED = backend_mod.warn_once_cache()


def resolve_backend(backend: Optional[str] = None,
                    use_pallas: Optional[bool] = None) -> str:
    """Normalize the (backend, legacy use_pallas) pair to a BACKENDS member.

    ``use_pallas`` is a deprecated alias: True -> "pallas", False ->
    "reference".  Passing both a backend and a conflicting alias is an error.
    Delegates to :func:`repro.core.backend.resolve_backend`, skipping this
    cfd layer's frames so the DeprecationWarning blames the user's call site
    even when ``solve``/``step`` are traced under ``jax.jit``.
    """
    return backend_mod.resolve_backend(
        backend, use_pallas, backends=BACKENDS,
        skip_dirs=(os.path.dirname(__file__),), what="solver")


def _pad_pressure(p):
    """Ghost cells: Neumann left/top/bottom, Dirichlet 0 at right (outlet)."""
    left = p[:, :1]              # dp/dx = 0 at inlet
    right = -p[:, -1:]           # p = 0 at the outlet face
    p = jnp.concatenate([left, p, right], axis=1)
    top = p[:1, :]
    bot = p[-1:, :]
    return jnp.concatenate([top, p, bot], axis=0)


def residual(p, rhs, dx, dy):
    pp = _pad_pressure(p)
    lap = ((pp[1:-1, :-2] + pp[1:-1, 2:] - 2 * p) / dx ** 2
           + (pp[:-2, 1:-1] + pp[2:, 1:-1] - 2 * p) / dy ** 2)
    return lap - rhs


# ---------------------------------------------------------------------------
# packed checkerboard layout
# ---------------------------------------------------------------------------

def pack_checkerboard(a):
    """(ny, nx) full grid -> ((ny, nx//2) red, (ny, nx//2) black) planes.

    red[j, k] = a[j, 2k + j%2]; black[j, k] = a[j, 2k + 1 - j%2].
    Requires even nx (each row then holds exactly nx//2 of each color)."""
    ny, nx = a.shape
    if nx % 2:
        raise ValueError(f"packed checkerboard needs an even grid width, "
                         f"got nx={nx}")
    pairs = a.reshape(ny, nx // 2, 2)
    odd = (jnp.arange(ny) % 2 == 1)[:, None]
    red = jnp.where(odd, pairs[..., 1], pairs[..., 0])
    black = jnp.where(odd, pairs[..., 0], pairs[..., 1])
    return red, black


def unpack_checkerboard(red, black):
    """Inverse of ``pack_checkerboard``."""
    ny, w = red.shape
    odd = (jnp.arange(ny) % 2 == 1)[:, None, None]
    pairs = jnp.where(odd, jnp.stack([black, red], axis=-1),
                      jnp.stack([red, black], axis=-1))
    return pairs.reshape(ny, 2 * w)


def packed_half_sweep(active, other, rhs_a, left_g, right_g, north_g, south_g,
                      shift, om, dx2, dy2, inv_diag):
    """One colored Gauss-Seidel half-sweep entirely in packed storage.

    active/other: the plane being updated / the neighbour plane (..., ny, W).
    left_g/right_g: ghost columns (..., ny, 1) in the *update* parity
    (entries on the wrong row parity are never selected).  north_g/south_g:
    wall ghost ROWS (..., 1, W) — the strips :func:`packed_ghost_rows`
    returns; the shifted vertical-neighbour planes are assembled here from
    slices so each operand is a concat-of-slices XLA fuses into the stencil
    (on CPU this slice form measures ~1.8x faster than materializing padded
    planes, bitwise-identical results).  shift: (..., ny, 1) bool — rows
    whose horizontal neighbours sit one packed column to the right (j odd
    for red, j even for black).

    The update association is load-bearing for bitwise compatibility across
    backends: ``p_gs = (nb - rhs) * inv_diag`` first, then
    ``(1 - om) * active + om * p_gs`` — do not refactor into
    ``om * (nb - rhs) * inv_diag``.
    """
    o_west = jnp.concatenate([left_g, other[..., :, :-1]], axis=-1)
    o_east = jnp.concatenate([other[..., :, 1:], right_g], axis=-1)
    horiz = jnp.where(shift, other + o_east, o_west + other)
    north = jnp.concatenate([north_g, other[..., :-1, :]], axis=-2)
    south = jnp.concatenate([other[..., 1:, :], south_g], axis=-2)
    nb = horiz / dx2 + (north + south) / dy2
    p_gs = (nb - rhs_a) * inv_diag
    return (1 - om) * active + om * p_gs


def packed_ghost_rows(active, other):
    """Wall ghost ROW strips (..., 1, W) for the ``active`` half-sweep:
    Neumann walls mean the ghost is a copy of the active plane's own
    boundary row (a wall ghost always carries the parity of the point being
    updated).  ``other`` is accepted for call-site symmetry with the ghost
    columns; the strips themselves only need ``active``."""
    del other
    return active[..., :1, :], active[..., -1:, :]


def packed_sweep_pair(red, black, rhs_r, rhs_b, om, *, dx, dy, row_odd):
    """One red+black Gauss-Seidel pair on packed planes (single domain:
    boundary ghosts derived from the planes themselves)."""
    dx2, dy2 = dx ** 2, dy ** 2
    inv_diag = 1.0 / (2.0 / dx2 + 2.0 / dy2)
    red = packed_half_sweep(
        red, black, rhs_r,
        red[:, :1], -red[:, -1:],          # Neumann inlet / Dirichlet outlet
        *packed_ghost_rows(red, black),
        row_odd, om, dx2, dy2, inv_diag)
    black = packed_half_sweep(
        black, red, rhs_b,
        black[:, :1], -black[:, -1:],
        *packed_ghost_rows(black, red),
        ~row_odd, om, dx2, dy2, inv_diag)
    return red, black


# ---------------------------------------------------------------------------
# solve
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("dx", "dy", "iters", "omega_s",
                                             "backend", "polish", "mesh",
                                             "halo_axis", "halo_inner"))
def _solve_impl(rhs, p0, omega_t, dx, dy, *, iters: int, omega_s, backend: str,
                polish: int, mesh, halo_axis: str, halo_inner: int):
    # omega arrives on exactly one of two lanes: ``omega_s`` (static Python
    # float — the common case, required by the pallas kernel) or ``omega_t``
    # (traced array — kept working for the jnp backends, matching the seed
    # solver which only materialized its omega default at trace time)
    omega = omega_s if omega_t is None else omega_t
    ny, nx = rhs.shape
    p = jnp.zeros_like(rhs) if p0 is None else p0

    if backend == "halo":
        if mesh is None:
            raise ValueError(
                "backend='halo' needs a mesh with a spatial axis; pass "
                "mesh= (e.g. launch.mesh.mesh_for_plan(plan)) or choose "
                "backend='reference'")
        from repro.cfd import decomp
        return decomp.decomposed_solve(rhs, p, mesh=mesh, axis=halo_axis,
                                       dx=dx, dy=dy, omega=omega,
                                       iters=iters, inner_iters=halo_inner,
                                       polish=polish)

    n_polish = min(polish, iters // 2)
    n_sor = iters - n_polish

    if backend in ("packed", "pallas"):
        rhs_r, rhs_b = pack_checkerboard(rhs)
        red, black = pack_checkerboard(p)
        row_odd = (jnp.arange(ny) % 2 == 1)[:, None]

        if backend == "pallas":
            from repro.kernels.poisson import ops as poisson_ops
            red, black = poisson_ops.rb_sor_planes(red, black, rhs_r, rhs_b,
                                                   dx, dy, iters=n_sor,
                                                   omega=omega_s)
            for_polish = n_polish
        else:
            def body(i, planes):
                om = jnp.where(i < n_sor, omega, 1.0)
                return packed_sweep_pair(*planes, rhs_r, rhs_b, om,
                                         dx=dx, dy=dy, row_odd=row_odd)
            red, black = jax.lax.fori_loop(0, iters, body, (red, black))
            for_polish = 0

        def gs(_, planes):
            return packed_sweep_pair(*planes, rhs_r, rhs_b, 1.0,
                                     dx=dx, dy=dy, row_odd=row_odd)
        red, black = jax.lax.fori_loop(0, for_polish, gs, (red, black))
        return unpack_checkerboard(red, black)

    # backend == "full": the original masked full-grid sweep (the oracle)
    jj, ii = jnp.meshgrid(jnp.arange(ny), jnp.arange(nx), indexing="ij")
    red = ((ii + jj) % 2 == 0)
    inv_diag = 1.0 / (2.0 / dx ** 2 + 2.0 / dy ** 2)

    def sweep(p, mask, om):
        pp = _pad_pressure(p)
        nb = ((pp[1:-1, :-2] + pp[1:-1, 2:]) / dx ** 2
              + (pp[:-2, 1:-1] + pp[2:, 1:-1]) / dy ** 2)
        p_gs = (nb - rhs) * inv_diag
        return jnp.where(mask, (1 - om) * p + om * p_gs, p)

    def body(i, p):
        om = jnp.where(i < n_sor, omega, 1.0)
        p = sweep(p, red, om)
        p = sweep(p, ~red, om)
        return p

    return jax.lax.fori_loop(0, iters, body, p)


def solve(rhs, dx, dy, *, iters: int = 60, omega: float = 1.7,
          p0=None, backend: Optional[str] = None,
          use_pallas: Optional[bool] = None, polish: int = 10,
          mesh=None, halo_axis: str = "model", halo_inner: int = 4):
    """Red-black SOR.  rhs: (ny, nx).  Returns p with mean-free gauge handled
    by the outlet Dirichlet condition.

    The last ``polish`` sweeps run with omega = 1 (plain Gauss-Seidel):
    over-relaxation accelerates the smooth error modes but leaves an
    amplified high-frequency residual, which a few unrelaxed smoothing
    sweeps remove (~4x lower residual norm at equal total iterations).

    ``backend=None``/``"reference"`` picks the packed-checkerboard sweep on
    even-width grids (identical iteration to the full-grid oracle at ~half
    the FLOPs and memory traffic) and the full-grid sweep on odd widths.
    ``backend="packed"`` forces the packed layout (ValueError on odd nx);
    ``backend="full"`` forces the full-grid oracle.  ``backend="pallas"``
    requires an even nx (checkerboard parity); odd widths fall back to the
    reference path with a one-time warning naming the grid shape.
    ``backend="halo"`` runs cfd/decomp's explicit x-slab decomposition over
    ``mesh``'s ``halo_axis`` (``halo_inner`` local sweeps per halo exchange)
    and is traceable under vmap — the paper's N_ranks > 1 configuration."""
    backend = resolve_backend(backend, use_pallas)
    ny, nx = rhs.shape[-2:]
    if backend == "fused":
        # "fused" fuses an actuation INTERVAL (kernels/actuation via
        # solver.step_interval); a single pressure solve has nothing to
        # fuse across, so it runs the reference sweep
        backend = "reference"
    if backend == "pallas" and nx % 2:
        if (ny, nx) not in _ODD_NX_WARNED:
            _ODD_NX_WARNED.add((ny, nx))
            warnings.warn(
                f"backend='pallas' needs an even grid width for checkerboard "
                f"slab parity; grid (ny={ny}, nx={nx}) falls back to the "
                f"jnp reference path (this warning fires once per shape)",
                RuntimeWarning, stacklevel=2)
        backend = "reference"
    if backend == "packed" and nx % 2:
        raise ValueError(
            f"backend='packed' needs an even grid width, got nx={nx}; use "
            f"backend='reference' (it falls back to the full-grid sweep on "
            f"odd widths) or an even-nx grid")
    if backend == "reference":
        backend = "full" if nx % 2 else "packed"
    if isinstance(omega, (int, float)):
        omega_s, omega_t = float(omega), None
    elif backend == "pallas":
        raise TypeError(
            f"backend='pallas' needs a concrete Python-float omega (the "
            f"slab kernel specializes on it), got {type(omega).__name__}; "
            f"pass omega as a float or choose a jnp backend")
    else:
        omega_s, omega_t = None, omega
    return _solve_impl(rhs, p0, omega_t, dx, dy, iters=iters, omega_s=omega_s,
                       backend=backend, polish=polish, mesh=mesh,
                       halo_axis=halo_axis, halo_inner=halo_inner)
