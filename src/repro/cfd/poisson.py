"""Pressure Poisson solver: red-black SOR with channel boundary conditions.

BCs: Neumann (dp/dn = 0) at inlet and walls, Dirichlet (p = 0) at the outlet.
This is the CFD hot spot (the paper attributes >95% of wall time to CFD; within
our fractional-step solver the pressure solve dominates) — kernels/poisson
provides the Pallas TPU version of the sweep; this module is the jnp reference
and the CPU execution path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _pad_pressure(p):
    """Ghost cells: Neumann left/top/bottom, Dirichlet 0 at right (outlet)."""
    left = p[:, :1]              # dp/dx = 0 at inlet
    right = -p[:, -1:]           # p = 0 at the outlet face
    p = jnp.concatenate([left, p, right], axis=1)
    top = p[:1, :]
    bot = p[-1:, :]
    return jnp.concatenate([top, p, bot], axis=0)


def residual(p, rhs, dx, dy):
    pp = _pad_pressure(p)
    lap = ((pp[1:-1, :-2] + pp[1:-1, 2:] - 2 * p) / dx ** 2
           + (pp[:-2, 1:-1] + pp[2:, 1:-1] - 2 * p) / dy ** 2)
    return lap - rhs


@functools.partial(jax.jit, static_argnames=("dx", "dy", "iters",
                                             "use_pallas", "polish"))
def solve(rhs, dx, dy, *, iters: int = 60, omega: float = 1.7,
          p0=None, use_pallas: bool = False, polish: int = 10):
    """Red-black SOR.  rhs: (ny, nx).  Returns p with mean-free gauge handled
    by the outlet Dirichlet condition.

    The last ``polish`` sweeps run with omega = 1 (plain Gauss-Seidel):
    over-relaxation accelerates the smooth error modes but leaves an
    amplified high-frequency residual, which a few unrelaxed smoothing
    sweeps remove (~4x lower residual norm at equal total iterations).

    ``use_pallas`` requires an even nx (checkerboard slab parity); odd
    widths silently fall back to the jnp path so callers never crash on
    unusual grids."""
    ny, nx = rhs.shape
    if nx % 2:
        use_pallas = False
    p = jnp.zeros_like(rhs) if p0 is None else p0
    jj, ii = jnp.meshgrid(jnp.arange(ny), jnp.arange(nx), indexing="ij")
    red = ((ii + jj) % 2 == 0)
    inv_diag = 1.0 / (2.0 / dx ** 2 + 2.0 / dy ** 2)

    def sweep(p, mask, om):
        pp = _pad_pressure(p)
        nb = ((pp[1:-1, :-2] + pp[1:-1, 2:]) / dx ** 2
              + (pp[:-2, 1:-1] + pp[2:, 1:-1]) / dy ** 2)
        p_gs = (nb - rhs) * inv_diag
        return jnp.where(mask, (1 - om) * p + om * p_gs, p)

    n_polish = min(polish, iters // 2)
    n_sor = iters - n_polish

    if use_pallas:
        from repro.kernels.poisson import ops as poisson_ops
        p = poisson_ops.rb_sor(rhs, dx, dy, iters=n_sor, omega=omega, p0=p)

        def gs(_, p):
            p = sweep(p, red, 1.0)
            return sweep(p, ~red, 1.0)

        return jax.lax.fori_loop(0, n_polish, gs, p)

    def body(i, p):
        om = jnp.where(i < n_sor, omega, 1.0)
        p = sweep(p, red, om)
        p = sweep(p, ~red, om)
        return p

    return jax.lax.fori_loop(0, iters, body, p)
