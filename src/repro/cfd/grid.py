"""Grid + geometry for the Schäfer cylinder benchmark (22D x 4.1D channel).

TPU-native adaptation (DESIGN.md §2): OpenFOAM's unstructured FVM mesh is
replaced by a uniform staggered MAC grid with an immersed-boundary cylinder.
All geometry (solid masks, jet masks/targets, probe positions) is precomputed
with numpy at construction time and stored as static arrays.

Coordinates: x in [-2, 20] (cylinder center at origin, inlet 2D upstream),
y in [-H/2, H/2] with H = 4.1.  The cylinder is offset +0.05D in y to trigger
vortex shedding (as in the benchmark).  D = 1.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

import numpy as np

H = 4.1                 # channel height / D
LX = 22.0               # channel length / D
X0 = -2.0               # inlet x
CYL_X, CYL_Y = 0.0, 0.05
RADIUS = 0.5
JET_CENTERS_DEG = (90.0, 270.0)
JET_WIDTH_DEG = 10.0


@dataclass(frozen=True)
class Body:
    """One immersed cylinder: center + radius (D = 2r = 1 by default)."""
    x: float
    y: float
    r: float = RADIUS


# Named multi-body configurations.  "cylinder" is the repo's historical
# single-body Schäfer case and MUST stay byte-identical (the golden-physics
# fixtures pin it).  "pinball" is the fluidic pinball (Deng et al. / Vignon
# et al., arXiv 2304.03181): three unit-diameter cylinders on an equilateral
# triangle of side 1.5D, apex upstream — shifted downstream so the front
# cylinder sits 1D from the inlet and the back pair keeps a 0.8D gap to the
# channel walls.  "tandem" is two inline cylinders 1.5D apart.
_PINBALL_BACK_X = -0.5 + 1.5 * np.sqrt(3.0) / 2.0      # ~0.799
GEOMETRIES: dict = {
    "cylinder": (Body(CYL_X, CYL_Y),),
    "pinball": (Body(-0.5, 0.0),
                Body(_PINBALL_BACK_X, 0.75),
                Body(_PINBALL_BACK_X, -0.75)),
    "tandem": (Body(0.0, CYL_Y), Body(1.5, CYL_Y)),
}


def geometry_names() -> Tuple[str, ...]:
    """Registered geometry names in the canonical (sorted) order — the
    order the env's stacked geometry bank uses, so a ``geom_id`` stored in
    a checkpoint resolves to the same geometry in any process."""
    return tuple(sorted(GEOMETRIES))


def geometry_index(name: str) -> int:
    """Canonical bank index of a geometry (see :func:`geometry_names`)."""
    try:
        return geometry_names().index(name)
    except ValueError:
        raise KeyError(f"unknown geometry {name!r}; "
                       f"known: {geometry_names()}") from None


def max_bodies() -> int:
    return max(len(b) for b in GEOMETRIES.values())


@dataclass(frozen=True)
class GridConfig:
    res: int = 16                 # cells per diameter
    re: float = 100.0
    dt: float = 0.005
    u_mean: float = 1.0           # mean inlet velocity (Um = 1.5 * u_mean)
    poisson_iters: int = 60
    poisson_omega: float = 1.7    # SOR relaxation
    penal_eta: float = 2e-4       # volume-penalization time scale
    upwind_blend: float = 0.2     # 0 = central advection, 1 = full upwind

    @property
    def nx(self) -> int:
        return int(round(LX * self.res))

    @property
    def ny(self) -> int:
        # keep even for red-black tiling
        n = int(round(H * self.res))
        return n + (n % 2)

    @property
    def dx(self) -> float:
        return LX / self.nx

    @property
    def dy(self) -> float:
        return H / self.ny

    @property
    def u_max(self) -> float:
        return 1.5 * self.u_mean  # parabolic profile peak


def cell_centers(cfg: GridConfig) -> Tuple[np.ndarray, np.ndarray]:
    x = X0 + (np.arange(cfg.nx) + 0.5) * cfg.dx
    y = -H / 2 + (np.arange(cfg.ny) + 0.5) * cfg.dy
    return x, y


def inlet_profile(cfg: GridConfig, y: np.ndarray) -> np.ndarray:
    """Parabolic U_inlet(y) = Um (H-2y)(H+2y)/H^2, eq. (3)."""
    um = cfg.u_max
    return um * (H - 2 * y) * (H + 2 * y) / H ** 2


def _smoothed_solid(xx, yy, dx, cx=CYL_X, cy=CYL_Y, radius=RADIUS
                    ) -> np.ndarray:
    """chi in [0,1]: 1 inside the cylinder, smoothed over ~1 cell."""
    r = np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2)
    eps = 0.5 * dx
    return np.clip(0.5 * (1 - (r - radius) / eps), 0.0, 1.0)


def _rotary_shell(xx, yy, dx, cx=CYL_X, cy=CYL_Y, radius=RADIUS):
    """Rotary-control target field: rigid-body rotation per unit surface speed.

    Returns (rot_x, rot_y, rmask), each (ny, nx): the x/y components of the
    target velocity per unit surface speed, and the penalization mask in
    [0, 1].  The target is the rigid rotation V(r) = V_s * (r/R) * t_hat
    inside the cylinder; rmask is 1 out to r = R + 0.25 dx and tapers
    linearly to 0 over the next 0.5 dx (so the band reaches R + 0.75 dx),
    imposing the rotating-wall boundary condition on the adjacent fluid
    (Magnus control, cf. rotary AFC in Rabault et al. follow-ups).  Callers
    keep the component matching their staggered face (rot_x at u faces,
    rot_y at v faces).
    """
    rx, ry = xx - cx, yy - cy
    r = np.sqrt(rx ** 2 + ry ** 2) + 1e-12
    # tangential unit vector for counter-clockwise rotation
    tx, ty = -ry / r, rx / r
    # 1 inside / on the surface, linear taper to 0 at R + 0.75 dx
    rmask = np.clip((radius + 0.75 * dx - r) / (0.5 * dx), 0.0, 1.0)
    mag = np.clip(r / radius, 0.0, 1.0) * rmask
    return mag * tx, mag * ty, rmask


def _jet_shell(xx, yy, dx):
    """Jet actuation targets: surface band within each jet arc.

    The physical jet is a 10-degree arc — SUB-CELL at practical resolutions
    (arc length 0.087D < dx for res <= 11), so the discrete arc is widened to
    cover >= 3 cells and the velocity rescaled to conserve the mass flux of
    the physical jet (standard coarse-IB practice; recorded in DESIGN.md).

    Returns (profile (2,ny,nx) signed-normal jet targets per unit jet
    velocity, jmask (ny,nx) in [0,1] where penalization should act).
    """
    rx, ry = xx - CYL_X, yy - CYL_Y
    r = np.sqrt(rx ** 2 + ry ** 2) + 1e-12
    theta = np.degrees(np.arctan2(ry, rx)) % 360.0
    # band biased inward: one cell of outward extent injects into the fluid
    # without thickening the effective body at rest (drag bias)
    shell = ((r - RADIUS) > -1.5 * dx) & ((r - RADIUS) < 0.75 * dx)
    nxv, nyv = rx / r, ry / r
    # effective (numerical) arc width: >= 3 cells along the surface
    width_eff = max(JET_WIDTH_DEG, np.degrees(3.0 * dx / RADIUS))
    flux_scale = JET_WIDTH_DEG / width_eff      # conserve jet mass flux
    profiles, jmask = [], np.zeros_like(r)
    for c in JET_CENTERS_DEG:
        d = np.abs((theta - c + 180.0) % 360.0 - 180.0)   # angular distance
        inside = d < width_eff / 2
        prof = np.clip(1.0 - (d / (width_eff / 2)) ** 2, 0.0, 1.0)
        prof = prof * inside * shell * flux_scale
        profiles.append(prof)
        jmask = np.maximum(jmask, (prof > 0).astype(np.float64))
    return np.stack(profiles), nxv, nyv, jmask


@dataclass(frozen=True)
class Geometry:
    """Static precomputed fields (numpy; converted to jnp lazily).

    The per-body fields (``rotb_*``, ``own_*``) extend the single-cylinder
    layout to N bodies: ``rotb_u[b]`` is body *b*'s rotary target per unit
    surface speed (zero outside its penalization band), and ``own_u[b]`` is
    a nearest-body partition of unity (sums to 1 over bodies at every cell)
    used to split the global penalization force into per-body C_D/C_L.  For
    the classic single cylinder they reduce to the legacy ``rot_*`` fields
    and an all-ones ownership, and every legacy field is byte-identical."""
    chi_u: np.ndarray        # (ny, nx+1) solid fraction at u faces
    chi_v: np.ndarray        # (ny+1, nx) solid fraction at v faces
    jet_u: np.ndarray        # (2, ny, nx+1) jet direction*profile at u faces
    jet_v: np.ndarray        # (2, ny+1, nx) jet direction*profile at v faces
    jmask_u: np.ndarray      # (ny, nx+1) jet penalization mask at u faces
    jmask_v: np.ndarray      # (ny+1, nx) jet penalization mask at v faces
    rot_u: np.ndarray        # (ny, nx+1) rotary target (x comp) per unit speed
    rot_v: np.ndarray        # (ny+1, nx) rotary target (y comp) per unit speed
    rmask_u: np.ndarray      # (ny, nx+1) rotary penalization mask at u faces
    rmask_v: np.ndarray      # (ny+1, nx) rotary penalization mask at v faces
    inlet_u: np.ndarray      # (ny,) parabolic inlet profile at u rows
    probe_ij: np.ndarray     # (149, 2) float cell-index coords of probes
    cell_volume: float
    name: str = "cylinder"   # GEOMETRIES key this was built from
    rotb_u: np.ndarray = None  # (B, ny, nx+1) per-body rotary target (x comp)
    rotb_v: np.ndarray = None  # (B, ny+1, nx) per-body rotary target (y comp)
    own_u: np.ndarray = None   # (B, ny, nx+1) nearest-body partition of unity
    own_v: np.ndarray = None   # (B, ny+1, nx) nearest-body partition of unity

    @property
    def n_bodies(self) -> int:
        return len(GEOMETRIES[self.name])


def _ownership(xx, yy, bodies) -> np.ndarray:
    """(B, ny, nx) nearest-body one-hot partition of unity (ties -> the
    first body, so the stack always sums to exactly 1 at every cell)."""
    d = np.stack([np.sqrt((xx - b.x) ** 2 + (yy - b.y) ** 2) - b.r
                  for b in bodies])
    nearest = np.argmin(d, axis=0)
    return np.stack([(nearest == i).astype(np.float64)
                     for i in range(len(bodies))])


def build_geometry(cfg: GridConfig, geometry: str = "cylinder") -> Geometry:
    if geometry not in GEOMETRIES:
        raise KeyError(f"unknown geometry {geometry!r}; "
                       f"known: {geometry_names()}")
    bodies = GEOMETRIES[geometry]
    dx, dy = cfg.dx, cfg.dy
    xc, yc = cell_centers(cfg)
    # u faces: x at i*dx + X0, y at centers
    xu = X0 + np.arange(cfg.nx + 1) * dx
    yu = yc
    xxu, yyu = np.meshgrid(xu, yu)
    # v faces: x at centers, y at -H/2 + j*dy
    xv = xc
    yv = -H / 2 + np.arange(cfg.ny + 1) * dy
    xxv, yyv = np.meshgrid(xv, yv)

    # solid fraction: union (max) over bodies — identity for one body
    chi_u = np.maximum.reduce([_smoothed_solid(xxu, yyu, dx, b.x, b.y, b.r)
                               for b in bodies])
    chi_v = np.maximum.reduce([_smoothed_solid(xxv, yyv, dx, b.x, b.y, b.r)
                               for b in bodies])

    if geometry == "cylinder":
        # synthetic jets are defined on the classic cylinder only; this
        # branch is byte-identical to the historical single-body build
        ju_prof, nx_u, ny_u, jmask_u = _jet_shell(xxu, yyu, dx)
        jv_prof, nx_v, ny_v, jmask_v = _jet_shell(xxv, yyv, dx)
        # jet target velocity: outward normal component * parabolic profile
        jet_u = ju_prof * nx_u[None]
        jet_v = jv_prof * ny_v[None]
    else:
        jet_u = np.zeros((2,) + xxu.shape)
        jet_v = np.zeros((2,) + xxv.shape)
        jmask_u = np.zeros(xxu.shape)
        jmask_v = np.zeros(xxv.shape)

    # per-body rotary targets; the penalization bands of distinct bodies
    # never overlap (min gap 0.5D >> the ~0.75 dx band), so the union mask
    # plus the summed target reproduces each body's rotating-wall BC
    rotb_u, rotb_v, rmasks_u, rmasks_v = [], [], [], []
    for b in bodies:
        ru, _, rmu = _rotary_shell(xxu, yyu, dx, b.x, b.y, b.r)
        _, rv, rmv = _rotary_shell(xxv, yyv, dx, b.x, b.y, b.r)
        rotb_u.append(ru)
        rotb_v.append(rv)
        rmasks_u.append(rmu)
        rmasks_v.append(rmv)
    rotb_u = np.stack(rotb_u)
    rotb_v = np.stack(rotb_v)
    rmask_u = np.maximum.reduce(rmasks_u)
    rmask_v = np.maximum.reduce(rmasks_v)
    # legacy single-field target: all bodies co-rotating at the same speed
    # (exactly the historical field for the single cylinder)
    rot_u = np.sum(rotb_u, axis=0)
    rot_v = np.sum(rotb_v, axis=0)

    own_u = _ownership(xxu, yyu, bodies)
    own_v = _ownership(xxv, yyv, bodies)

    inlet_u = inlet_profile(cfg, yu)

    probe_ij = points_to_ij(cfg, probe_positions())

    return Geometry(chi_u=chi_u, chi_v=chi_v, jet_u=jet_u, jet_v=jet_v,
                    jmask_u=jmask_u, jmask_v=jmask_v,
                    rot_u=rot_u, rot_v=rot_v,
                    rmask_u=rmask_u, rmask_v=rmask_v,
                    inlet_u=inlet_u, probe_ij=probe_ij, cell_volume=dx * dy,
                    name=geometry, rotb_u=rotb_u, rotb_v=rotb_v,
                    own_u=own_u, own_v=own_v)


def points_to_ij(cfg: GridConfig, pts: np.ndarray) -> np.ndarray:
    """(P, 2) physical (x, y) -> (P, 2) fractional cell-center [row=j, col=i]
    coordinates for ``jax.scipy.ndimage.map_coordinates`` sampling."""
    pi = (pts[:, 0] - (X0 + 0.5 * cfg.dx)) / cfg.dx
    pj = (pts[:, 1] - (-H / 2 + 0.5 * cfg.dy)) / cfg.dy
    return np.stack([pj, pi], axis=-1)


def probe_positions() -> np.ndarray:
    """149 probes: 72 on three rings around the cylinder + 77 wake grid
    (7 x 11), following the layout style of Wang et al. 2022 (Fig. 3)."""
    pts = []
    for r in (0.6, 0.8, 1.0):
        for k in range(24):
            a = 2 * np.pi * k / 24
            pts.append((CYL_X + r * np.cos(a), CYL_Y + r * np.sin(a)))
    xs = np.linspace(1.2, 9.0, 11)
    ys = np.linspace(-1.2, 1.2, 7)
    for x in xs:
        for y in ys:
            pts.append((x, y))
    out = np.asarray(pts, dtype=np.float64)
    assert out.shape == (149, 2), out.shape
    return out
