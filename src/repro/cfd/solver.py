"""Fractional-step (Chorin projection) incompressible Navier-Stokes on a
staggered MAC grid, with volume-penalization immersed-boundary cylinder and
synthetic-jet / rotary actuation.

u: (ny, nx+1) x-velocity at x-faces      v: (ny+1, nx) y-velocity at y-faces
p: (ny, nx)   pressure at cell centers

One ``step`` advances dt: upwind advection + central diffusion -> implicit
volume penalization (cylinder + actuators) -> projection -> force outputs.

Geometry is static (closed over); the Reynolds number and actuation mode can
be *traced* per call so heterogeneous scenario batches vmap into one program
(see ``repro.cfd.scenarios``).
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd import poisson
from repro.cfd.grid import Geometry, GridConfig
from repro.core import backend as backend_mod

# once-per-shape fallback warning for vector jet_vel on backend="fused"
# (registered so tests/conftest.py resets it between tests)
_FUSED_VECTOR_WARNED = backend_mod.warn_once_cache()


class FlowState(NamedTuple):
    u: jnp.ndarray
    v: jnp.ndarray
    p: jnp.ndarray


class GeomArrays(NamedTuple):
    """Static geometry fields as jnp arrays (closed over by env closures).

    These are shared by every scenario on the same grid; everything that
    varies per scenario (Re, actuation mode, probe layout) is traced data so
    mixed-scenario batches vmap into one program.

    The trailing per-body fields (``rotb_*`` per-body rotary targets,
    ``own_*`` nearest-body force-ownership partition; see ``grid.Geometry``)
    default to ``None`` so eleven-field constructions predating the
    multi-body layer keep working; they are only consumed on the vector
    (per-body) actuation branch of ``_momentum``."""
    chi_u: jnp.ndarray
    chi_v: jnp.ndarray
    jet_u: jnp.ndarray
    jet_v: jnp.ndarray
    jmask_u: jnp.ndarray
    jmask_v: jnp.ndarray
    rot_u: jnp.ndarray
    rot_v: jnp.ndarray
    rmask_u: jnp.ndarray
    rmask_v: jnp.ndarray
    inlet_u: jnp.ndarray
    rotb_u: jnp.ndarray = None    # (B, ny, nx+1)
    rotb_v: jnp.ndarray = None    # (B, ny+1, nx)
    own_u: jnp.ndarray = None     # (B, ny, nx+1)
    own_v: jnp.ndarray = None     # (B, ny+1, nx)


class StepOutputs(NamedTuple):
    cd: jnp.ndarray          # drag coefficient (scalar; (B,) per body when
    cl: jnp.ndarray          # the actuation amplitude is a per-body vector)


def init_state(cfg: GridConfig, geom: Geometry) -> FlowState:
    """Start from the inlet profile everywhere (impulsive start)."""
    u = jnp.broadcast_to(jnp.asarray(geom.inlet_u)[:, None],
                         (cfg.ny, cfg.nx + 1)).astype(jnp.float32)
    u = u * (1.0 - jnp.asarray(geom.chi_u, jnp.float32))
    v = jnp.zeros((cfg.ny + 1, cfg.nx), jnp.float32)
    p = jnp.zeros((cfg.ny, cfg.nx), jnp.float32)
    return FlowState(u, v, p)


# ---------------------------------------------------------------------------
# boundary conditions (ghost-cell padding)
# ---------------------------------------------------------------------------

def _apply_bc_u(u, inlet_u):
    """In-array BCs for u: inlet Dirichlet, outlet zero-gradient."""
    u = u.at[:, 0].set(inlet_u)
    u = u.at[:, -1].set(u[:, -2])
    return u


def _apply_bc_v(v):
    v = v.at[:, 0].set(0.0)            # inlet: v = 0
    v = v.at[:, -1].set(v[:, -2])      # outlet: zero-gradient
    v = v.at[0, :].set(0.0)            # bottom wall
    v = v.at[-1, :].set(0.0)           # top wall
    return v


def _pad_u(u):
    """Ghosts for stencils: walls no-slip (reflect), x handled in-array."""
    top = -u[:1, :]
    bot = -u[-1:, :]
    u = jnp.concatenate([top, u, bot], axis=0)          # (ny+2, nx+1)
    left = 2 * u[:, :1] - u[:, 1:2]                     # extrapolate inlet
    right = u[:, -1:]                                   # zero-gradient outlet
    return jnp.concatenate([left, u, right], axis=1)    # (ny+2, nx+3)


def _pad_v(v):
    top = v[-1:, :] * 0.0
    bot = v[:1, :] * 0.0
    v = jnp.concatenate([bot, v, top], axis=0)          # (ny+3, nx) walls
    left = -v[:, :1]                                    # inlet v=0 (reflect)
    right = v[:, -1:]                                   # outlet zero-gradient
    return jnp.concatenate([left, v, right], axis=1)    # (ny+3, nx+2)


# ---------------------------------------------------------------------------
# spatial operators
# ---------------------------------------------------------------------------

def _advect_diffuse_u(up, vp, cfg: GridConfig, re):
    """du/dt = -u du/dx - v du/dy + (1/Re) lap(u) at interior u-faces.

    ``up``/``vp`` are the padded fields from ``_pad_u``/``_pad_v`` — computed
    once per ``step`` and shared with ``_advect_diffuse_v``."""
    dx, dy = cfg.dx, cfg.dy
    uc = up[1:-1, 1:-1]                                  # == u
    # neighbors
    ul, ur = up[1:-1, :-2], up[1:-1, 2:]
    ub, ut = up[:-2, 1:-1], up[2:, 1:-1]
    # v interpolated to u-faces: average 4 surrounding v values
    # v faces adjacent to u face (j, i): v[j, i-1], v[j, i], v[j+1, i-1], v[j+1, i]
    v_at_u = 0.25 * (vp[1:-2, :-1] + vp[1:-2, 1:] + vp[2:-1, :-1] + vp[2:-1, 1:])
    # blended central/upwind advection (upwind share = cfg.upwind_blend)
    b = cfg.upwind_blend
    dudx_up = jnp.where(uc > 0, (uc - ul) / dx, (ur - uc) / dx)
    dudy_up = jnp.where(v_at_u > 0, (uc - ub) / dy, (ut - uc) / dy)
    dudx = b * dudx_up + (1 - b) * (ur - ul) / (2 * dx)
    dudy = b * dudy_up + (1 - b) * (ut - ub) / (2 * dy)
    adv = uc * dudx + v_at_u * dudy
    lap = (ul + ur - 2 * uc) / dx ** 2 + (ub + ut - 2 * uc) / dy ** 2
    return -adv + lap / re


def _advect_diffuse_v(up, vp, cfg: GridConfig, re):
    dx, dy = cfg.dx, cfg.dy
    vc = vp[1:-1, 1:-1]                                  # == v
    vl, vr = vp[1:-1, :-2], vp[1:-1, 2:]
    vb, vt = vp[:-2, 1:-1], vp[2:, 1:-1]
    # u interpolated to v-faces (j, i): u[j-1, i], u[j-1, i+1], u[j, i], u[j, i+1]
    u_at_v = 0.25 * (up[:-1, 1:-2] + up[:-1, 2:-1] + up[1:, 1:-2] + up[1:, 2:-1])
    b = cfg.upwind_blend
    dvdx_up = jnp.where(u_at_v > 0, (vc - vl) / dx, (vr - vc) / dx)
    dvdy_up = jnp.where(vc > 0, (vc - vb) / dy, (vt - vc) / dy)
    dvdx = b * dvdx_up + (1 - b) * (vr - vl) / (2 * dx)
    dvdy = b * dvdy_up + (1 - b) * (vt - vb) / (2 * dy)
    adv = u_at_v * dvdx + vc * dvdy
    lap = (vl + vr - 2 * vc) / dx ** 2 + (vb + vt - 2 * vc) / dy ** 2
    return -adv + lap / re


def divergence(u, v, cfg: GridConfig):
    return ((u[:, 1:] - u[:, :-1]) / cfg.dx
            + (v[1:, :] - v[:-1, :]) / cfg.dy)


# ---------------------------------------------------------------------------
# one time step
# ---------------------------------------------------------------------------

def _momentum(cfg: GridConfig, ga: GeomArrays, u, v, jet_vel, re, act_mode):
    """The momentum half of one dt: explicit advect-diffuse predictor,
    implicit volume penalization, and the fused BC/outlet-mass-correction
    pass.  Returns ``(u_bc, v_bc, fx, fy)`` — the BC'd intermediate fields
    the projection acts on, plus the body force (reaction) components.

    This is the single momentum implementation: ``step`` and the fused
    actuation-interval path (``repro.kernels.actuation``) both call it, so
    the megakernel can never drift from the per-step solver.

    Contract (pinned by tests/test_cfd.py): the body force is the momentum
    the penalization removed, measured against the *predictor* ``u_star``
    BEFORE boundary conditions are applied — the post-BC fields are
    deliberately separate names (``u_bc``/``v_bc``) so a refactor cannot
    silently change ``fx``/``fy``.

    ``jet_vel`` is either the historical scalar amplitude (both scalar
    branches below are byte-identical to the pre-multi-body solver) or a
    per-body ``(A,)`` vector of rotary surface speeds (``A >=`` the
    geometry's body count; extra padded slots are inert because the padded
    ``rotb_*`` planes are zero).  On the vector branch ``fx``/``fy`` come
    back per body ``(B,)``, split by the nearest-body ownership partition —
    their sum equals the global reaction force up to summation order.
    """
    chi_u, chi_v, inlet_u = ga.chi_u, ga.chi_v, ga.inlet_u
    dt = cfg.dt
    # 1. advection-diffusion (explicit Euler).  The padded fields are shared
    # by both momentum updates (each previously re-padded both u and v).
    up, vp = _pad_u(u), _pad_v(v)
    u_star = u + dt * _advect_diffuse_u(up, vp, cfg, re)
    v_star = v + dt * _advect_diffuse_v(up, vp, cfg, re)

    # 2. immersed boundary: implicit volume penalization toward target.
    # Penalization acts on the solid (target 0) AND the actuation band
    # (target = actuation velocity): C = max(chi, band mask).
    lam = dt / cfg.penal_eta
    jet_tgt_u = ga.jet_u[0] - ga.jet_u[1]
    jet_tgt_v = ga.jet_v[0] - ga.jet_v[1]
    per_body = jnp.ndim(jet_vel) > 0          # static: part of the trace
    if act_mode is None:                      # static jets-only path
        tgt_u = jet_vel * jet_tgt_u
        tgt_v = jet_vel * jet_tgt_v
        pen_u = jnp.maximum(chi_u, ga.jmask_u)
        pen_v = jnp.maximum(chi_v, ga.jmask_v)
    elif not per_body:                        # per-scenario traced blend
        m = act_mode
        tgt_u = jet_vel * ((1 - m) * jet_tgt_u + m * ga.rot_u)
        tgt_v = jet_vel * ((1 - m) * jet_tgt_v + m * ga.rot_v)
        pen_u = jnp.maximum(chi_u, (1 - m) * ga.jmask_u + m * ga.rmask_u)
        pen_v = jnp.maximum(chi_v, (1 - m) * ga.jmask_v + m * ga.rmask_v)
    else:                                     # per-body vector actuation
        if ga.rotb_u is None:
            raise ValueError(
                "per-body (vector) jet_vel needs the per-body geometry "
                "fields (rotb_*/own_*); rebuild GeomArrays via "
                "geom_to_arrays(build_geometry(cfg, geometry))")
        nb = ga.rotb_u.shape[0]
        av = jnp.asarray(jet_vel)
        if av.shape[0] < nb:                  # static pad to the body count
            av = jnp.pad(av, (0, nb - av.shape[0]))
        # slot 0 doubles as the jet amplitude so a jets-mode scenario rides
        # the same vector program inside a mixed multi-body batch
        a0 = av[0]
        m = act_mode
        rot_t_u = jnp.einsum("b,byx->yx", av[:nb], ga.rotb_u)
        rot_t_v = jnp.einsum("b,byx->yx", av[:nb], ga.rotb_v)
        tgt_u = (1 - m) * a0 * jet_tgt_u + m * rot_t_u
        tgt_v = (1 - m) * a0 * jet_tgt_v + m * rot_t_v
        pen_u = jnp.maximum(chi_u, (1 - m) * ga.jmask_u + m * ga.rmask_u)
        pen_v = jnp.maximum(chi_v, (1 - m) * ga.jmask_v + m * ga.rmask_v)
    u_pen = (u_star + lam * pen_u * tgt_u) / (1 + lam * pen_u)
    v_pen = (v_star + lam * pen_v * tgt_v) / (1 + lam * pen_v)
    # momentum exchange -> force on the body (reaction), per unit density —
    # measured from the PREDICTOR u_star/v_star, before BCs touch the fields
    if per_body:
        fx = -jnp.einsum("byx,yx->b", ga.own_u,
                         (u_pen - u_star) / dt) * cfg.dx * cfg.dy
        fy = -jnp.einsum("byx,yx->b", ga.own_v,
                         (v_pen - v_star) / dt) * cfg.dx * cfg.dy
    else:
        fx = -jnp.sum((u_pen - u_star) / dt) * cfg.dx * cfg.dy
        fy = -jnp.sum((v_pen - v_star) / dt) * cfg.dx * cfg.dy

    # 3. boundary conditions + global outlet mass correction, fused into one
    # pass over each field: the inlet BC pins column 0 to inlet_u (so the
    # influx is just its sum), the outlet BC copies column -2, and the mass
    # correction shifts that same column — one scatter chain per field
    # instead of penalize -> BC -> correct as three.
    influx = jnp.sum(inlet_u) * cfg.dy
    outflux = jnp.sum(u_pen[:, -2]) * cfg.dy
    out_col = u_pen[:, -2] + (influx - outflux) / (cfg.ny * cfg.dy)
    u_bc = u_pen.at[:, 0].set(inlet_u).at[:, -1].set(out_col)
    v_bc = _apply_bc_v(v_pen)
    return u_bc, v_bc, fx, fy


@functools.partial(jax.jit, static_argnames=("cfg", "backend", "use_pallas",
                                             "mesh", "halo_inner"))
def step(cfg: GridConfig, geom_arrays: GeomArrays, state: FlowState, jet_vel,
         *, re=None, act_mode=None, backend: Optional[str] = None,
         use_pallas: Optional[bool] = None, mesh=None, halo_inner: int = 1
         ) -> Tuple[FlowState, StepOutputs]:
    """Advance one dt.

    jet_vel: scalar actuation amplitude — jet velocity (jet1 = +, jet2 = -)
    in jet mode, cylinder surface speed in rotary mode.
    re: Reynolds number; traced (per-env scenario data) when given, else the
    static ``cfg.re``.
    act_mode: actuation blend in [0, 1] — 0 = synthetic jets, 1 = rotary
    cylinder control; traced when given, else jets.  Intermediate values
    blend the two target fields (only 0/1 are physical scenarios).
    backend: Poisson backend ("reference" | "packed" | "full" | "pallas" |
    "halo" | "fused"); "reference" (the default) runs the packed-checkerboard
    sweep on even-width grids and the full-grid oracle otherwise; "halo"
    needs ``mesh`` and runs the pressure solve as explicit x-slabs with
    ppermute halo exchange over the mesh "model" axis — the paper's
    N_ranks > 1 spatial decomposition; "fused" only changes behaviour at
    the interval level (``step_interval``) and solves a single step with
    the reference sweep.  ``use_pallas`` is a deprecated alias.
    halo_inner: local sweeps per halo exchange on the "halo" backend.  The
    default 1 exchanges the updated parity before every colored half-sweep
    (half-width messages — the MPI-per-iteration pattern whose cost the
    paper's Fig. 7 measures — making the decomposed iteration exactly the
    monolithic sweep); looser coupling leaves slab-boundary pressure error
    that the projection feedback amplifies over hundreds of steps.
    """
    backend = poisson.resolve_backend(backend, use_pallas)
    ga = GeomArrays(*geom_arrays)
    dt = cfg.dt
    if re is None:
        re = cfg.re

    u, v, p = state
    # 1-3. momentum: predictor + penalization (+ forces) + BC/mass pass
    u_bc, v_bc, fx, fy = _momentum(cfg, ga, u, v, jet_vel, re, act_mode)

    # 4. projection ("fused" fuses at the interval level — step_interval —
    # so a single step solves with the reference sweep)
    rhs = divergence(u_bc, v_bc, cfg) / dt
    p = poisson.solve(rhs, cfg.dx, cfg.dy, iters=cfg.poisson_iters,
                      omega=cfg.poisson_omega, p0=p,
                      backend="reference" if backend == "fused" else backend,
                      mesh=mesh, halo_inner=halo_inner)
    u_new = u_bc.at[:, 1:-1].add(-dt * (p[:, 1:] - p[:, :-1]) / cfg.dx)
    v_new = v_bc.at[1:-1, :].add(-dt * (p[1:, :] - p[:-1, :]) / cfg.dy)
    u_new = _apply_bc_u(u_new, ga.inlet_u)
    v_new = _apply_bc_v(v_new)

    # force coefficients: 0.5 * rho * Ubar^2 * D = 0.5
    cd = fx / (0.5 * cfg.u_mean ** 2)
    cl = fy / (0.5 * cfg.u_mean ** 2)
    return FlowState(u_new, v_new, p), StepOutputs(cd=cd, cl=cl)


def step_interval(cfg: GridConfig, geom_arrays: GeomArrays, state: FlowState,
                  jet_vel, n_steps: int, *, re=None, act_mode=None,
                  backend: Optional[str] = None,
                  use_pallas: Optional[bool] = None, mesh=None,
                  halo_inner: int = 1) -> Tuple[FlowState, StepOutputs]:
    """Advance ``n_steps`` dt under one held actuation amplitude — one
    actuation interval, the unit the DRL environment integrates between
    agent actions.

    Returns ``(FlowState, StepOutputs)`` with per-dt ``(n_steps,)`` force
    coefficient arrays.

    ``backend="fused"`` runs the interval through
    ``repro.kernels.actuation``: the velocity fields and both packed
    pressure parity planes are carried across the whole interval (no per-dt
    pack/unpack round-trips), with the per-dt fused body executing as a
    VMEM-resident Pallas megakernel on TPU and as one fused XLA scan body
    elsewhere.  Grids the fused path cannot serve (odd width, or exceeding
    the TPU VMEM budget) fall back to the reference scan with a
    once-per-shape warning.  Every other backend scans :func:`step`.
    """
    backend = poisson.resolve_backend(backend, use_pallas)
    if backend == "fused":
        if jnp.ndim(jet_vel) > 0:
            # The megakernel's penalization body is scalar-actuation only;
            # multi-body vector amplitudes take the reference scan.
            key = ("fused_vector_jet", int(jet_vel.shape[0]))
            if key not in _FUSED_VECTOR_WARNED:
                _FUSED_VECTOR_WARNED.add(key)
                warnings.warn(
                    "backend='fused' does not support per-body (vector) "
                    "jet_vel; falling back to the reference interval scan",
                    RuntimeWarning, stacklevel=2)
            backend = "reference"
        else:
            from repro.kernels.actuation import ops as actuation_ops
            return actuation_ops.fused_interval(cfg, geom_arrays, state,
                                                jet_vel, n_steps, re=re,
                                                act_mode=act_mode)

    def body(flow, _):
        return step(cfg, geom_arrays, flow, jet_vel, re=re,
                    act_mode=act_mode, backend=backend, mesh=mesh,
                    halo_inner=halo_inner)

    return jax.lax.scan(body, state, None, length=n_steps)


def geom_to_arrays(geom: Geometry) -> GeomArrays:
    """Static geometry as a pytree of jnp arrays (closed over, never traced)."""
    as32 = lambda a: jnp.asarray(a, jnp.float32)
    opt = lambda a: None if a is None else as32(a)
    return GeomArrays(chi_u=as32(geom.chi_u), chi_v=as32(geom.chi_v),
                      jet_u=as32(geom.jet_u), jet_v=as32(geom.jet_v),
                      jmask_u=as32(geom.jmask_u), jmask_v=as32(geom.jmask_v),
                      rot_u=as32(geom.rot_u), rot_v=as32(geom.rot_v),
                      rmask_u=as32(geom.rmask_u), rmask_v=as32(geom.rmask_v),
                      inlet_u=as32(geom.inlet_u),
                      rotb_u=opt(getattr(geom, "rotb_u", None)),
                      rotb_v=opt(getattr(geom, "rotb_v", None)),
                      own_u=opt(getattr(geom, "own_u", None)),
                      own_v=opt(getattr(geom, "own_v", None)))
