"""Scenario registry: parameterized, batchable AFC flow cases.

The paper demonstrates its parallelization on one hard-coded cylinder case;
the "data"-axis speedup only pays off at production scale when many
*heterogeneous* cases share one vmapped program (Tang et al. train a single
policy across Reynolds numbers; Rabault & Kuhnle show multi-env DRL speedup).
This module supplies the missing environment layer:

  * ``Scenario`` — a named flow case: Reynolds number, actuation mode
    (synthetic jets vs. rotary cylinder control), probe layout, optional
    fixed reference drag ``cd0``.
  * a process-global registry (``register_scenario`` / ``get_scenario`` /
    ``list_scenarios``) pre-populated with the Re 100/200/500 family.
  * ``ScenarioParams`` — the *traced* per-env parameter pytree.  Geometry
    stays static (closed over, shared across the batch); everything that
    differs between scenarios rides in the env state, so a mixed-Re /
    mixed-actuation / mixed-layout batch is ONE XLA program vmapped over
    the "data" mesh axis.
  * ``batch_params`` — stacks scenarios into a batched ``ScenarioParams``,
    padding probe layouts to a common obs_dim (mask zeroes padded slots).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd import probes as probes_mod
from repro.cfd.grid import (GEOMETRIES, GridConfig, geometry_index,
                            points_to_ij)

ACTUATIONS = ("jets", "rotary")


@dataclass(frozen=True)
class Scenario:
    """One flow case.  ``cd0=None`` means "calibrate from the warmup run"."""
    name: str
    re: float = 100.0
    actuation: str = "jets"        # "jets" | "rotary"
    probes: str = "ring149"        # probe layout name (repro.cfd.probes)
    geometry: str = "cylinder"     # immersed-body set (repro.cfd.grid)
    cd0: Optional[float] = None
    description: str = ""

    def __post_init__(self):
        if self.actuation not in ACTUATIONS:
            raise ValueError(f"unknown actuation {self.actuation!r}; "
                             f"choose from {ACTUATIONS}")
        if self.geometry not in GEOMETRIES:
            raise ValueError(f"unknown geometry {self.geometry!r}; "
                             f"choose from {sorted(GEOMETRIES)}")
        if self.actuation == "jets" and self.geometry != "cylinder":
            raise ValueError(
                f"scenario {self.name!r}: synthetic jets are only carved "
                "into the single-cylinder geometry; multi-body geometries "
                "use actuation='rotary'")
        probes_mod.layout_positions(self.probes)   # validate eagerly

    @property
    def obs_dim(self) -> int:
        return probes_mod.layout_size(self.probes)

    @property
    def act_mode(self) -> float:
        return float(ACTUATIONS.index(self.actuation))

    @property
    def n_bodies(self) -> int:
        return len(GEOMETRIES[self.geometry])

    @property
    def act_dim(self) -> int:
        """Action vector width: one rotary speed per body, one jet amplitude."""
        return self.n_bodies if self.actuation == "rotary" else 1


_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(scn: Scenario, *, overwrite: bool = False) -> Scenario:
    if scn.name in _REGISTRY and not overwrite:
        raise ValueError(f"scenario {scn.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[scn.name] = scn
    return scn


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"known: {list_scenarios()}") from None


def list_scenarios() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def _builtin(name, **kw):
    register_scenario(Scenario(name=name, **kw))


_builtin("cyl_re100", re=100.0,
         description="Schäfer confined cylinder, jets, full 149-probe ring")
_builtin("cyl_re200", re=200.0,
         description="higher-Re shedding, jets, full ring")
_builtin("cyl_re500", re=500.0,
         description="strongly separated regime, jets, full ring")
_builtin("cyl_re100_rotary", re=100.0, actuation="rotary",
         description="rotary (Magnus) control at Re=100")
_builtin("cyl_re200_rotary", re=200.0, actuation="rotary",
         description="rotary control at Re=200")
_builtin("cyl_re100_sparse8", re=100.0, probes="sparse8",
         description="minimal 8-probe sensing at Re=100")
_builtin("cyl_re200_sparse24", re=200.0, probes="sparse24",
         description="reduced 24-probe sensing at Re=200")
_builtin("pinball_re100", re=100.0, actuation="rotary", probes="pinball",
         geometry="pinball",
         description="fluidic pinball: three rotating cylinders, Re=100")
_builtin("pinball_re130", re=130.0, actuation="rotary", probes="pinball",
         geometry="pinball",
         description="fluidic pinball in the chaotic regime, Re=130")
_builtin("tandem_re100", re=100.0, actuation="rotary", probes="tandem",
         geometry="tandem",
         description="tandem cylinders 1.5D apart, per-body rotary control")


# ---------------------------------------------------------------------------
# traced per-env parameters
# ---------------------------------------------------------------------------

class ScenarioParams(NamedTuple):
    """The traced (batchable) half of a scenario.

    Carried inside ``EnvState`` so each env of a vmapped batch can integrate
    different physics through the same program:

      re         ()       Reynolds number (per-env viscosity nu = 1/re)
      act_mode   ()       0 = jets, 1 = rotary (blend of target fields)
      cd0        ()       uncontrolled reference drag for reward eq. (12)
      probe_ij   (P, 2)   fractional [row, col] probe coords (padded)
      probe_mask (P,)     1 for live probes, 0 for padded slots
      geom_id    ()       int32 index into grid.geometry_names() — selects
                          this env's immersed-body set from the geometry bank
      act_mask   (A,)     1 for live action slots, 0 for padding when mixed
                          act_dims share one batch

    The trailing two default to ``None`` so ScenarioParams pytrees serialized
    before the multi-body layer still deserialize (``jax.tree`` treats None
    as an empty subtree).
    """
    re: jnp.ndarray
    act_mode: jnp.ndarray
    cd0: jnp.ndarray
    probe_ij: jnp.ndarray
    probe_mask: jnp.ndarray
    geom_id: jnp.ndarray = None
    act_mask: jnp.ndarray = None


def scenario_params(scn: Scenario, grid: GridConfig, *,
                    obs_dim: Optional[int] = None,
                    act_dim: Optional[int] = None,
                    cd0: Optional[float] = None) -> ScenarioParams:
    """Build the traced parameter pytree for one scenario.

    obs_dim / act_dim pad (and validate) the probe and action vectors to a
    common batch width; cd0 overrides (e.g. with the calibrated warmup value)
    when the scenario does not pin one."""
    pts = probes_mod.layout_positions(scn.probes)
    ij = points_to_ij(grid, pts).astype(np.float32)
    n = len(ij)
    obs_dim = n if obs_dim is None else obs_dim
    if obs_dim < n:
        raise ValueError(f"obs_dim={obs_dim} < layout {scn.probes!r} "
                         f"size {n}")
    pad = obs_dim - n
    ij = np.concatenate([ij, np.zeros((pad, 2), np.float32)])
    mask = np.concatenate([np.ones(n, np.float32), np.zeros(pad, np.float32)])
    a = scn.act_dim
    act_dim = a if act_dim is None else act_dim
    if act_dim < a:
        raise ValueError(f"act_dim={act_dim} < scenario {scn.name!r} "
                         f"action width {a}")
    act_mask = np.concatenate([np.ones(a, np.float32),
                               np.zeros(act_dim - a, np.float32)])
    # no cd0 from either the scenario or the caller is a config error, not
    # a quiet NaN: every downstream reward would be NaN and — under the
    # divergence sentinel — every step quarantined.  Callers that truly
    # want the poisoned baseline (the sentinel's own tests) say so with the
    # explicit cd0="nan" escape hatch.  (CylinderEnv.reset/reset_batch
    # always pass the calibrated warmup value, so env users never hit this.)
    if scn.cd0 is not None:
        cd0 = scn.cd0
    elif cd0 is None:
        raise ValueError(
            f"scenario {scn.name!r} has no cd0 (uncontrolled-drag baseline) "
            f"and no caller override: rewards would be NaN forever.  Pass "
            f"cd0=<calibrated value> (CylinderEnv warmup calibrates it), "
            f"pin one on the Scenario, or pass cd0=\"nan\" explicitly if an "
            f"uncalibrated baseline is intended")
    if isinstance(cd0, str):
        if cd0.lower() != "nan":
            raise ValueError(f"cd0 must be a float or the literal \"nan\", "
                             f"got {cd0!r}")
        cd0 = np.nan
    return ScenarioParams(re=jnp.float32(scn.re),
                          act_mode=jnp.float32(scn.act_mode),
                          cd0=jnp.float32(cd0),
                          probe_ij=jnp.asarray(ij),
                          probe_mask=jnp.asarray(mask),
                          geom_id=jnp.int32(geometry_index(scn.geometry)),
                          act_mask=jnp.asarray(act_mask))


def resolve(scenarios: Sequence) -> Tuple[Scenario, ...]:
    """Names and/or Scenario objects -> Scenario tuple."""
    return tuple(s if isinstance(s, Scenario) else get_scenario(s)
                 for s in scenarios)


def common_obs_dim(scenarios: Sequence) -> int:
    """Padded observation width for a mixed batch (max layout size)."""
    return max(s.obs_dim for s in resolve(scenarios))


def common_act_dim(scenarios: Sequence) -> int:
    """Padded action width for a mixed batch (max per-scenario act_dim)."""
    return max(s.act_dim for s in resolve(scenarios))


def batch_params(scenarios: Sequence, grid: GridConfig, *,
                 obs_dim: Optional[int] = None,
                 act_dim: Optional[int] = None,
                 cd0s: Optional[Sequence[float]] = None) -> ScenarioParams:
    """Stack scenarios into a batched ScenarioParams (leading axis = env).

    Probe layouts (and action vectors) are padded to a common width
    (default: the widest in the batch) so heterogeneous sensing and
    actuation vmap into one program."""
    scns = resolve(scenarios)
    obs_dim = common_obs_dim(scns) if obs_dim is None else obs_dim
    act_dim = common_act_dim(scns) if act_dim is None else act_dim
    cd0s = [None] * len(scns) if cd0s is None else list(cd0s)
    per = [scenario_params(s, grid, obs_dim=obs_dim, act_dim=act_dim, cd0=c)
           for s, c in zip(scns, cd0s)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def assign_envs(scenarios: Sequence, n_envs: int) -> Tuple[Scenario, ...]:
    """Round-robin scenario assignment over the env ("data") axis.

    Raises when the batch is too small to hold every requested scenario —
    silently dropping part of a scenario mix is a misconfiguration."""
    scns = resolve(scenarios)
    if n_envs < len(scns):
        raise ValueError(
            f"n_envs={n_envs} < {len(scns)} requested scenarios "
            f"({[s.name for s in scns]}); raise n_envs or trim the mix")
    return tuple(scns[i % len(scns)] for i in range(n_envs))
