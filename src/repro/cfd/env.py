"""Gym-like cylinder AFC environment (the paper's DRL environment).

One ``env_step`` = one actuation period: the smoothed actuation amplitude
(eq. 11, beta = 0.4) is held while the solver advances ``steps_per_action``
dt's; the reward is eq. (12): r = C_D0 - <C_D> - omega_L |<C_L>|.

Everything is jit/vmap/shard_map-compatible.  The environment splits into a
**static** half (geometry fields, closed over as constants — shared by every
env in a batch) and a **traced** half (``ScenarioParams`` carried inside
``EnvState``: per-env Reynolds number, actuation mode, probe layout, C_D0),
so ``N_envs`` *heterogeneous* scenarios run as a single vmapped program on
the "data" mesh axis (the paper's multi-environment parallelism extended to
the scenario-diversity axis; see ``repro.cfd.scenarios``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd import poisson
from repro.cfd import probes as probes_mod
from repro.cfd import scenarios as scn_mod
from repro.cfd import solver
from repro.cfd.grid import GridConfig, build_geometry
from repro.cfd.scenarios import Scenario, ScenarioParams
from repro.testing import faults


@dataclass(frozen=True)
class EnvConfig:
    """Environment configuration.

    ``cd0`` is the uncontrolled mean drag entering reward eq. (12).  The
    paper's value on its OpenFOAM mesh is 3.205; our IB grid at moderate
    resolution gives ~3.5-3.7 (resolution-dependent).  ``cd0=None`` (the
    default) means "calibrate it from the uncontrolled warmup run"; any
    float — including 0.0 — is used as-is, no calibration.

    ``obs_dim`` is derived from ``probe_layout`` (see ``repro.cfd.probes``),
    not hardcoded; ``actuation`` selects synthetic jets vs. rotary control.
    """
    grid: GridConfig = GridConfig()
    steps_per_action: int = 50
    actions_per_episode: int = 100
    beta: float = 0.4             # action smoothing, eq. (11)
    reward_omega: float = 0.1     # lift penalty weight, eq. (12)
    cd0: Optional[float] = None   # None -> calibrate during warmup
    warmup_time: float = 30.0     # t.u. of uncontrolled flow before training
    probe_layout: str = "ring149"
    actuation: str = "jets"
    geometry: str = "cylinder"    # immersed-body set (repro.cfd.grid)
    guard: bool = True            # divergence sentinel + per-env quarantine
    guard_vel_limit: float = 50.0   # |u|,|v| ceiling (U_m is O(1))
    guard_div_limit: float = 1e3    # max |div(u,v)| ceiling post-projection

    @property
    def obs_dim(self) -> int:
        return probes_mod.layout_size(self.probe_layout)

    @property
    def act_dim(self) -> int:
        return self.scenario().act_dim

    @property
    def action_max(self) -> float:
        return self.grid.u_max    # |V_jet| <= U_m constraint

    def scenario(self, name: str = "__cfg__") -> Scenario:
        """The (anonymous) scenario this config describes."""
        return Scenario(name=name, re=self.grid.re, actuation=self.actuation,
                        probes=self.probe_layout, geometry=self.geometry,
                        cd0=self.cd0)

    @classmethod
    def for_scenario(cls, scn, **overrides) -> "EnvConfig":
        """EnvConfig bound to a registered scenario (or Scenario object)."""
        scn = scn if isinstance(scn, Scenario) else scn_mod.get_scenario(scn)
        grid = overrides.pop("grid", GridConfig())
        grid = dataclasses.replace(grid, re=scn.re)
        return cls(grid=grid, probe_layout=scn.probes,
                   actuation=scn.actuation, geometry=scn.geometry,
                   cd0=scn.cd0, **overrides)


class EnvState(NamedTuple):
    """The trailing ``reset_flow`` field defaults to None (absent): jax.tree
    treats None as an empty subtree, so 4-field states — and every program
    traced before the divergence sentinel existed — keep their structure.
    When present it carries the scenario's cached warmup flow so a diverged
    env can be quarantined (re-initialized) inside the vmapped program."""
    flow: solver.FlowState
    jet_vel: jnp.ndarray          # smoothed actuation amplitude — scalar, or
    #                               (A,) per-body surface speeds (multi-body)
    t: jnp.ndarray                # actuation counter
    scn: ScenarioParams           # traced per-env scenario parameters
    reset_flow: solver.FlowState = None   # warmup flow for quarantine resets


class EnvOutput(NamedTuple):
    obs: jnp.ndarray              # (obs_dim,) pressure probes (padded)
    reward: jnp.ndarray
    cd: jnp.ndarray               # mean C_D over the actuation period
    cl: jnp.ndarray
    valid: jnp.ndarray = None     # 1.0 healthy / 0.0 quarantined (sentinel)


class CylinderEnv:
    """Factory for pure env functions bound to a geometry.

    The geometry (masks, actuation target fields, inlet profile) is built
    once and closed over; ``env_step`` reads all per-scenario physics from
    ``state.scn``, so one CylinderEnv serves an arbitrary scenario mix.

    ``backend``/``mesh`` select the solver backend for the env steps
    training integrates.  ``backend="fused"`` runs each actuation interval
    through ``repro.kernels.actuation`` (fields and packed pressure planes
    carried across all ``steps_per_action`` dt's; VMEM-resident Pallas
    megakernel on TPU, one fused XLA scan elsewhere; odd-width or
    over-VMEM-budget grids fall back to the reference scan with a
    once-per-shape warning).  ``backend="halo"`` with a ("data", "model") mesh
    runs each env's pressure solve as explicit x-slabs over the "model"
    axis (the plan's n_ranks).  Warmup always runs the un-decomposed
    backend: its group batch is too small to tile the mesh "data" axis
    (see decomp's jax 0.4.x caveat), and the two backends solve the same
    equations — the halo path's block-Jacobi boundary lag is a solver
    tolerance, not a different operator, so the developed flow and C_D0
    transfer."""

    def __init__(self, cfg: EnvConfig = EnvConfig(), *,
                 backend: Optional[str] = None, mesh=None):
        self.cfg = cfg
        self.backend = poisson.resolve_backend(backend)
        self.mesh = mesh
        if self.backend == "halo":
            from repro.cfd.decomp import validate_decomposition
            if mesh is None:
                raise ValueError("backend='halo' needs mesh= (e.g. "
                                 "launch.mesh.mesh_for_plan(plan))")
            validate_decomposition(mesh, cfg.grid.nx)
        self.geom = build_geometry(cfg.grid, cfg.geometry)
        self.geom_arrays = solver.geom_to_arrays(self.geom)
        self._reset_flow = None
        self._geom_cache = {cfg.geometry: (self.geom, self.geom_arrays)}
        self._bank = None        # stacked (G, ...) GeomArrays, built lazily
        self._group_cache = {}   # (re, act_mode, geometry) -> (FlowState, cd0)

    # -- uncontrolled warmup to a developed shedding state ------------------

    def warmup(self, verbose: bool = False) -> solver.FlowState:
        """Run (or fetch from the group cache) the uncontrolled warmup for
        this config's own (Re, actuation) group — the zero-amplitude flow
        still depends on the actuation mode because each mode's penalization
        band differs — and calibrate ``cd0`` from its tail when unset."""
        cfg = self.cfg
        group = (cfg.grid.re, cfg.scenario().act_mode, cfg.geometry)
        self._warmup_groups([group])
        flow, cd0 = self._group_cache[group]
        self._reset_flow = flow
        if self.cfg.cd0 is None:  # calibrate C_D0 on the uncontrolled flow
            self.cfg = dataclasses.replace(self.cfg, cd0=cd0)
        if verbose:
            n = max(1, int(round(cfg.warmup_time / cfg.grid.dt)))
            print(f"warmup {n} steps: CD0={self.cfg.cd0:.3f}")
        return solver.FlowState(*jax.tree.map(jnp.asarray, flow))

    def _run_steps(self, n, flow, jet_vel, re=None, act_mode=None,
                   geom_arrays=None):
        # warmup path: un-decomposed backend (see class docstring); the
        # fused interval path serves warmup too (same operator, one scan)
        backend = "reference" if self.backend == "halo" else self.backend
        ga = self.geom_arrays if geom_arrays is None else geom_arrays
        flow, outs = solver.step_interval(self.cfg.grid, ga,
                                          flow, jet_vel, n, re=re,
                                          act_mode=act_mode, backend=backend)
        return flow, (outs.cd, outs.cl)

    # -- multi-geometry support ---------------------------------------------

    def _geometry(self, name: str):
        """(Geometry, GeomArrays) for a named body set, built once."""
        if name not in self._geom_cache:
            geom = build_geometry(self.cfg.grid, name)
            self._geom_cache[name] = (geom, solver.geom_to_arrays(geom))
        return self._geom_cache[name]

    def _ensure_bank(self) -> None:
        """Stack every registered geometry's arrays into one (G, ...) bank.

        Per-body fields are zero-padded to ``grid.max_bodies()`` so all
        geometries share one shape; each env then gathers its own slab with
        ``scn.geom_id`` inside the vmapped program — mixed cylinder+pinball
        batches stay ONE XLA program."""
        if self._bank is not None:
            return
        from repro.cfd import grid as grid_mod
        bmax = grid_mod.max_bodies()

        def padded(ga):
            def pad(a):
                if a.shape[0] == bmax:
                    return a
                fill = jnp.zeros((bmax - a.shape[0],) + a.shape[1:], a.dtype)
                return jnp.concatenate([a, fill])
            return ga._replace(rotb_u=pad(ga.rotb_u), rotb_v=pad(ga.rotb_v),
                               own_u=pad(ga.own_u), own_v=pad(ga.own_v))

        per = [padded(self._geometry(n)[1])
               for n in grid_mod.geometry_names()]
        self._bank = jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    def _env_geom(self, scn: ScenarioParams):
        """This env's geometry arrays: the closed-over static set, or a
        per-env gather from the bank when the batch mixes geometries."""
        if self._bank is None or scn.geom_id is None:
            return self.geom_arrays
        return jax.tree.map(lambda x: x[scn.geom_id], self._bank)

    # -- pure env API --------------------------------------------------------

    def reset(self) -> Tuple[EnvState, jnp.ndarray]:
        if self._reset_flow is None:
            self.warmup()
        flow = jax.tree.map(jnp.asarray, self._reset_flow)
        scn = self.cfg.scenario()
        params = scn_mod.scenario_params(scn, self.cfg.grid,
                                         cd0=self.cfg.cd0)
        jet0 = (jnp.float32(0.0) if scn.act_dim == 1
                else jnp.zeros(scn.act_dim, jnp.float32))
        flow0 = solver.FlowState(*flow)
        st = EnvState(flow=flow0, jet_vel=jet0,
                      t=jnp.int32(0), scn=params,
                      reset_flow=flow0 if self.cfg.guard else None)
        return st, self._observe(st)

    def reset_batch(self, scenarios: Sequence, n_envs: Optional[int] = None,
                    *, obs_dim: Optional[int] = None,
                    act_dim: Optional[int] = None,
                    ) -> Tuple[EnvState, jnp.ndarray]:
        """Mixed-scenario reset: an (N_envs, ...) batch with per-env physics.

        ``scenarios``: names and/or Scenario objects, assigned round-robin
        over ``n_envs`` (default: one env per scenario).  Warmup runs once
        per distinct *(Re, actuation, geometry)* triple, vmapped per
        geometry — the actuation mode matters even at zero amplitude because
        each mode's penalization band differs, so the developed flow and
        C_D0 must come from the same operator ``env_step`` will integrate.
        Per-scenario C_D0 is calibrated from each warmup tail unless the
        scenario pins one; results are cached, so repeated resets with the
        same scenario set re-run nothing.  Probe layouts are padded to a
        common ``obs_dim`` and action vectors to a common ``act_dim``
        (default: widest in the batch; ``act_dim == 1`` keeps the
        historical scalar-amplitude state).  A batch whose geometries stray
        from the config's builds the geometry bank so every env gathers its
        own body set inside one vmapped program.
        """
        cfg = self.cfg
        scns = scn_mod.assign_envs(scenarios, n_envs or len(scenarios))
        groups = sorted({(s.re, s.act_mode, s.geometry) for s in scns})
        self._warmup_groups(groups)
        if any(s.geometry != cfg.geometry for s in scns):
            self._ensure_bank()

        flows, cd0s = [], []
        for s in scns:
            flow, cd0 = self._group_cache[(s.re, s.act_mode, s.geometry)]
            flows.append(flow)
            cd0s.append(s.cd0 if s.cd0 is not None else cd0)
        flow_b = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[jax.tree.map(jnp.asarray, f) for f in flows])
        params_b = scn_mod.batch_params(scns, cfg.grid, obs_dim=obs_dim,
                                        act_dim=act_dim, cd0s=cd0s)
        a_dim = (scn_mod.common_act_dim(scns) if act_dim is None else act_dim)
        jet0 = (jnp.zeros(len(scns), jnp.float32) if a_dim == 1
                else jnp.zeros((len(scns), a_dim), jnp.float32))
        flow0_b = solver.FlowState(*flow_b)
        st_b = EnvState(flow=flow0_b,
                        jet_vel=jet0,
                        t=jnp.zeros(len(scns), jnp.int32), scn=params_b,
                        reset_flow=flow0_b if cfg.guard else None)
        obs_b = jax.vmap(self._observe)(st_b)
        return st_b, obs_b

    def _warmup_groups(self, groups) -> None:
        """Warm up every uncached (re, act_mode, geometry) group, one vmapped
        run per geometry (each geometry's masks are distinct closure
        constants, so they cannot share a trace without banking — and warmup
        runs once per cache lifetime, where compile time dominates anyway)."""
        cfg = self.cfg
        todo = [g for g in groups if g not in self._group_cache]
        if not todo:
            return
        by_geom: dict = {}
        for g in todo:
            by_geom.setdefault(g[2], []).append(g)
        n = max(1, int(round(cfg.warmup_time / cfg.grid.dt)))
        tail = max(1, n // 4)
        for gname, gtodo in sorted(by_geom.items()):
            geom, ga = self._geometry(gname)
            flow0 = solver.init_state(cfg.grid, geom)
            run = jax.jit(jax.vmap(
                lambda re, m: self._run_steps(n, flow0, jnp.float32(0.0),
                                              re=re, act_mode=m,
                                              geom_arrays=ga)))
            flows, (cds, _) = run(
                jnp.asarray([g[0] for g in gtodo], jnp.float32),
                jnp.asarray([g[1] for g in gtodo], jnp.float32))
            cd0s = np.asarray(jnp.mean(cds[:, -tail:], axis=1))
            for i, g in enumerate(gtodo):
                flow = jax.tree.map(lambda a, i=i: np.asarray(a[i]), flows)
                self._group_cache[g] = (solver.FlowState(*flow),
                                        float(cd0s[i]))

    def _observe(self, st: EnvState) -> jnp.ndarray:
        return probes_mod.sample_pressure(st.scn.probe_ij, st.flow.p,
                                          st.scn.probe_mask)

    def obs_aux(self, st: EnvState) -> dict:
        """Observation side-channel for set-structured policies: normalized
        probe coordinates in [-1, 1]^2 plus the live-slot mask.  Constant
        over an episode (the layout rides in ``st.scn``), so rollouts fetch
        it once per reset, not per step."""
        g = self.cfg.grid
        ij = jnp.asarray(st.scn.probe_ij, jnp.float32)
        y = ij[..., 0] / max(g.ny - 1, 1) * 2.0 - 1.0
        x = ij[..., 1] / max(g.nx - 1, 1) * 2.0 - 1.0
        return {"xy": jnp.stack([x, y], axis=-1),
                "mask": jnp.asarray(st.scn.probe_mask, jnp.float32)}

    def env_step(self, st: EnvState, action) -> Tuple[EnvState, EnvOutput]:
        """One actuation period.  action: in [-1, 1], scalar (jet velocity
        or uniform rotary surface speed) or (A,) per-body surface speeds —
        the shape follows ``st.jet_vel``; padded slots beyond a scenario's
        own act_dim are zeroed by ``st.scn.act_mask``."""
        cfg = self.cfg
        a = jnp.clip(action, -1.0, 1.0) * cfg.action_max
        per_body = jnp.ndim(st.jet_vel) > 0          # static (trace-time)
        if per_body and st.scn.act_mask is not None:
            a = a * st.scn.act_mask
        jet = st.jet_vel + cfg.beta * (a - st.jet_vel)        # eq. (11)
        jet = jnp.clip(jet, -cfg.action_max, cfg.action_max)

        flow_in = st.flow
        fz = faults.active("nan_env")
        if fz is not None:       # trace-time gate: absent in production traces
            idx = jax.lax.axis_index("env")
            hit = ((idx == int(fz.get("env", 0)))
                   & (st.t == int(fz.get("step", 0))))
            poison = jnp.where(hit, jnp.float32(jnp.nan), jnp.float32(0.0))
            flow_in = flow_in._replace(u=flow_in.u + poison)

        # the whole actuation interval runs as one unit: backend="fused"
        # carries the fields (and packed pressure planes) across every dt
        # with no per-dt round-trips; other backends scan solver.step
        flow, outs = solver.step_interval(cfg.grid, self._env_geom(st.scn),
                                          flow_in, jet,
                                          cfg.steps_per_action,
                                          re=st.scn.re,
                                          act_mode=st.scn.act_mode,
                                          backend=self.backend,
                                          mesh=self.mesh)
        if outs.cd.ndim > 1:
            # per-body (n_steps, B) coefficients: the reward drag term is the
            # total, but lift is penalized per body — opposite-signed body
            # lifts must not cancel into a spurious zero penalty
            cd_b = jnp.mean(outs.cd, axis=0)
            cl_b = jnp.mean(outs.cl, axis=0)
            cd = jnp.sum(cd_b)
            cl = jnp.sum(cl_b)
            cl_pen = jnp.sum(jnp.abs(cl_b))
        else:
            cd = jnp.mean(outs.cd)
            cl = jnp.mean(outs.cl)
            cl_pen = jnp.abs(cl)
        reward = st.scn.cd0 - cd - cfg.reward_omega * cl_pen   # eq. (12)
        if st.reset_flow is None:     # sentinel off: the pre-guard program
            st2 = EnvState(flow=flow, jet_vel=jet, t=st.t + 1, scn=st.scn)
            return st2, EnvOutput(obs=self._observe(st2), reward=reward,
                                  cd=cd, cl=cl)

        # -- divergence sentinel: quarantine a blown-up env in-place --------
        # ``jnp.where(True, a, b)`` passes ``a`` through exactly, so an
        # all-healthy batch stays bitwise-identical to the unguarded program.
        ok = self._healthy(flow, reward)
        sel = lambda h, q: jnp.where(ok, h, q)                  # noqa: E731
        st2 = EnvState(flow=jax.tree.map(sel, flow, st.reset_flow),
                       jet_vel=sel(jet, jnp.zeros_like(jet)),
                       t=st.t + 1, scn=st.scn, reset_flow=st.reset_flow)
        zero = jnp.float32(0.0)
        return st2, EnvOutput(obs=self._observe(st2),
                              reward=sel(reward, zero),
                              cd=sel(cd, zero), cl=sel(cl, zero),
                              valid=ok.astype(jnp.float32))

    def _healthy(self, flow: solver.FlowState, reward) -> jnp.ndarray:
        """Traced per-env health check: finite fields + physical ceilings.

        NaN/Inf fail the ``<`` comparisons, so a single fused reduction per
        field covers both finiteness and magnitude.  The ceilings are far
        above any physical value (U_m is O(1)): they flag a diverging solve,
        not an unusual flow."""
        cfg = self.cfg
        vmax = jnp.maximum(jnp.max(jnp.abs(flow.u)), jnp.max(jnp.abs(flow.v)))
        divmax = jnp.max(jnp.abs(solver.divergence(flow.u, flow.v, cfg.grid)))
        return ((vmax < cfg.guard_vel_limit)
                & (divmax < cfg.guard_div_limit)
                & jnp.isfinite(jnp.max(jnp.abs(flow.p)))
                & jnp.isfinite(reward))
