"""Gym-like cylinder AFC environment (the paper's DRL environment).

One ``env_step`` = one actuation period: the smoothed actuation amplitude
(eq. 11, beta = 0.4) is held while the solver advances ``steps_per_action``
dt's; the reward is eq. (12): r = C_D0 - <C_D> - omega_L |<C_L>|.

Everything is jit/vmap/shard_map-compatible.  The environment splits into a
**static** half (geometry fields, closed over as constants — shared by every
env in a batch) and a **traced** half (``ScenarioParams`` carried inside
``EnvState``: per-env Reynolds number, actuation mode, probe layout, C_D0),
so ``N_envs`` *heterogeneous* scenarios run as a single vmapped program on
the "data" mesh axis (the paper's multi-environment parallelism extended to
the scenario-diversity axis; see ``repro.cfd.scenarios``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd import poisson
from repro.cfd import probes as probes_mod
from repro.cfd import scenarios as scn_mod
from repro.cfd import solver
from repro.cfd.grid import GridConfig, build_geometry
from repro.cfd.scenarios import Scenario, ScenarioParams


@dataclass(frozen=True)
class EnvConfig:
    """Environment configuration.

    ``cd0`` is the uncontrolled mean drag entering reward eq. (12).  The
    paper's value on its OpenFOAM mesh is 3.205; our IB grid at moderate
    resolution gives ~3.5-3.7 (resolution-dependent).  ``cd0=None`` (the
    default) means "calibrate it from the uncontrolled warmup run"; any
    float — including 0.0 — is used as-is, no calibration.

    ``obs_dim`` is derived from ``probe_layout`` (see ``repro.cfd.probes``),
    not hardcoded; ``actuation`` selects synthetic jets vs. rotary control.
    """
    grid: GridConfig = GridConfig()
    steps_per_action: int = 50
    actions_per_episode: int = 100
    beta: float = 0.4             # action smoothing, eq. (11)
    reward_omega: float = 0.1     # lift penalty weight, eq. (12)
    cd0: Optional[float] = None   # None -> calibrate during warmup
    warmup_time: float = 30.0     # t.u. of uncontrolled flow before training
    probe_layout: str = "ring149"
    actuation: str = "jets"

    @property
    def obs_dim(self) -> int:
        return probes_mod.layout_size(self.probe_layout)

    @property
    def action_max(self) -> float:
        return self.grid.u_max    # |V_jet| <= U_m constraint

    def scenario(self, name: str = "__cfg__") -> Scenario:
        """The (anonymous) scenario this config describes."""
        return Scenario(name=name, re=self.grid.re, actuation=self.actuation,
                        probes=self.probe_layout, cd0=self.cd0)

    @classmethod
    def for_scenario(cls, scn, **overrides) -> "EnvConfig":
        """EnvConfig bound to a registered scenario (or Scenario object)."""
        scn = scn if isinstance(scn, Scenario) else scn_mod.get_scenario(scn)
        grid = overrides.pop("grid", GridConfig())
        grid = dataclasses.replace(grid, re=scn.re)
        return cls(grid=grid, probe_layout=scn.probes,
                   actuation=scn.actuation, cd0=scn.cd0, **overrides)


class EnvState(NamedTuple):
    flow: solver.FlowState
    jet_vel: jnp.ndarray          # smoothed actuation amplitude (scalar)
    t: jnp.ndarray                # actuation counter
    scn: ScenarioParams           # traced per-env scenario parameters


class EnvOutput(NamedTuple):
    obs: jnp.ndarray              # (obs_dim,) pressure probes (padded)
    reward: jnp.ndarray
    cd: jnp.ndarray               # mean C_D over the actuation period
    cl: jnp.ndarray


class CylinderEnv:
    """Factory for pure env functions bound to a geometry.

    The geometry (masks, actuation target fields, inlet profile) is built
    once and closed over; ``env_step`` reads all per-scenario physics from
    ``state.scn``, so one CylinderEnv serves an arbitrary scenario mix.

    ``backend``/``mesh`` select the solver backend for the env steps
    training integrates.  ``backend="fused"`` runs each actuation interval
    through ``repro.kernels.actuation`` (fields and packed pressure planes
    carried across all ``steps_per_action`` dt's; VMEM-resident Pallas
    megakernel on TPU, one fused XLA scan elsewhere; odd-width or
    over-VMEM-budget grids fall back to the reference scan with a
    once-per-shape warning).  ``backend="halo"`` with a ("data", "model") mesh
    runs each env's pressure solve as explicit x-slabs over the "model"
    axis (the plan's n_ranks).  Warmup always runs the un-decomposed
    backend: its group batch is too small to tile the mesh "data" axis
    (see decomp's jax 0.4.x caveat), and the two backends solve the same
    equations — the halo path's block-Jacobi boundary lag is a solver
    tolerance, not a different operator, so the developed flow and C_D0
    transfer."""

    def __init__(self, cfg: EnvConfig = EnvConfig(), *,
                 backend: Optional[str] = None, mesh=None):
        self.cfg = cfg
        self.backend = poisson.resolve_backend(backend)
        self.mesh = mesh
        if self.backend == "halo":
            from repro.cfd.decomp import validate_decomposition
            if mesh is None:
                raise ValueError("backend='halo' needs mesh= (e.g. "
                                 "launch.mesh.mesh_for_plan(plan))")
            validate_decomposition(mesh, cfg.grid.nx)
        self.geom = build_geometry(cfg.grid)
        self.geom_arrays = solver.geom_to_arrays(self.geom)
        self._reset_flow = None
        self._group_cache = {}   # (re, act_mode) -> (FlowState, cd0)

    # -- uncontrolled warmup to a developed shedding state ------------------

    def warmup(self, verbose: bool = False) -> solver.FlowState:
        """Run (or fetch from the group cache) the uncontrolled warmup for
        this config's own (Re, actuation) group — the zero-amplitude flow
        still depends on the actuation mode because each mode's penalization
        band differs — and calibrate ``cd0`` from its tail when unset."""
        cfg = self.cfg
        group = (cfg.grid.re, cfg.scenario().act_mode)
        self._warmup_groups([group])
        flow, cd0 = self._group_cache[group]
        self._reset_flow = flow
        if self.cfg.cd0 is None:  # calibrate C_D0 on the uncontrolled flow
            self.cfg = dataclasses.replace(self.cfg, cd0=cd0)
        if verbose:
            n = max(1, int(round(cfg.warmup_time / cfg.grid.dt)))
            print(f"warmup {n} steps: CD0={self.cfg.cd0:.3f}")
        return solver.FlowState(*jax.tree.map(jnp.asarray, flow))

    def _run_steps(self, n, flow, jet_vel, re=None, act_mode=None):
        # warmup path: un-decomposed backend (see class docstring); the
        # fused interval path serves warmup too (same operator, one scan)
        backend = "reference" if self.backend == "halo" else self.backend
        flow, outs = solver.step_interval(self.cfg.grid, self.geom_arrays,
                                          flow, jet_vel, n, re=re,
                                          act_mode=act_mode, backend=backend)
        return flow, (outs.cd, outs.cl)

    # -- pure env API --------------------------------------------------------

    def reset(self) -> Tuple[EnvState, jnp.ndarray]:
        if self._reset_flow is None:
            self.warmup()
        flow = jax.tree.map(jnp.asarray, self._reset_flow)
        params = scn_mod.scenario_params(self.cfg.scenario(), self.cfg.grid,
                                         cd0=self.cfg.cd0)
        st = EnvState(flow=solver.FlowState(*flow), jet_vel=jnp.float32(0.0),
                      t=jnp.int32(0), scn=params)
        return st, self._observe(st)

    def reset_batch(self, scenarios: Sequence, n_envs: Optional[int] = None,
                    *, obs_dim: Optional[int] = None,
                    ) -> Tuple[EnvState, jnp.ndarray]:
        """Mixed-scenario reset: an (N_envs, ...) batch with per-env physics.

        ``scenarios``: names and/or Scenario objects, assigned round-robin
        over ``n_envs`` (default: one env per scenario).  Warmup runs once
        per distinct *(Re, actuation)* pair as a single vmapped program —
        the actuation mode matters even at zero amplitude because each
        mode's penalization band differs, so the developed flow and C_D0
        must come from the same operator ``env_step`` will integrate.
        Per-scenario C_D0 is calibrated from each warmup tail unless the
        scenario pins one; results are cached, so repeated resets with the
        same scenario set re-run nothing.  Probe layouts are padded to a
        common ``obs_dim`` (default: widest in the batch).
        """
        cfg = self.cfg
        scns = scn_mod.assign_envs(scenarios, n_envs or len(scenarios))
        groups = sorted({(s.re, s.act_mode) for s in scns})
        self._warmup_groups(groups)

        flows, cd0s = [], []
        for s in scns:
            flow, cd0 = self._group_cache[(s.re, s.act_mode)]
            flows.append(flow)
            cd0s.append(s.cd0 if s.cd0 is not None else cd0)
        flow_b = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[jax.tree.map(jnp.asarray, f) for f in flows])
        params_b = scn_mod.batch_params(scns, cfg.grid, obs_dim=obs_dim,
                                        cd0s=cd0s)
        st_b = EnvState(flow=solver.FlowState(*flow_b),
                        jet_vel=jnp.zeros(len(scns), jnp.float32),
                        t=jnp.zeros(len(scns), jnp.int32), scn=params_b)
        obs_b = jax.vmap(self._observe)(st_b)
        return st_b, obs_b

    def _warmup_groups(self, groups) -> None:
        """Warm up every uncached (re, act_mode) group in one vmapped run."""
        cfg = self.cfg
        todo = [g for g in groups if g not in self._group_cache]
        if not todo:
            return
        n = max(1, int(round(cfg.warmup_time / cfg.grid.dt)))
        flow0 = solver.init_state(cfg.grid, self.geom)
        run = jax.jit(jax.vmap(
            lambda re, m: self._run_steps(n, flow0, jnp.float32(0.0),
                                          re=re, act_mode=m)))
        flows, (cds, _) = run(jnp.asarray([g[0] for g in todo], jnp.float32),
                              jnp.asarray([g[1] for g in todo], jnp.float32))
        tail = max(1, n // 4)
        cd0s = np.asarray(jnp.mean(cds[:, -tail:], axis=1))
        for i, g in enumerate(todo):
            flow = jax.tree.map(lambda a, i=i: np.asarray(a[i]), flows)
            self._group_cache[g] = (solver.FlowState(*flow), float(cd0s[i]))

    def _observe(self, st: EnvState) -> jnp.ndarray:
        return probes_mod.sample_pressure(st.scn.probe_ij, st.flow.p,
                                          st.scn.probe_mask)

    def env_step(self, st: EnvState, action) -> Tuple[EnvState, EnvOutput]:
        """One actuation period.  action: scalar in [-1, 1] (scaled to the
        actuator: jet velocity or rotary surface speed, per ``st.scn``)."""
        cfg = self.cfg
        a = jnp.clip(action, -1.0, 1.0) * cfg.action_max
        jet = st.jet_vel + cfg.beta * (a - st.jet_vel)        # eq. (11)
        jet = jnp.clip(jet, -cfg.action_max, cfg.action_max)

        # the whole actuation interval runs as one unit: backend="fused"
        # carries the fields (and packed pressure planes) across every dt
        # with no per-dt round-trips; other backends scan solver.step
        flow, outs = solver.step_interval(cfg.grid, self.geom_arrays,
                                          st.flow, jet,
                                          cfg.steps_per_action,
                                          re=st.scn.re,
                                          act_mode=st.scn.act_mode,
                                          backend=self.backend,
                                          mesh=self.mesh)
        cd = jnp.mean(outs.cd)
        cl = jnp.mean(outs.cl)
        reward = st.scn.cd0 - cd - cfg.reward_omega * jnp.abs(cl)  # eq. (12)
        st2 = EnvState(flow=flow, jet_vel=jet, t=st.t + 1, scn=st.scn)
        return st2, EnvOutput(obs=self._observe(st2), reward=reward,
                              cd=cd, cl=cl)
