"""Gym-like cylinder AFC environment (the paper's DRL environment).

One ``env_step`` = one actuation period: the smoothed jet velocity (eq. 11,
beta = 0.4) is held while the solver advances ``steps_per_action`` dt's; the
reward is eq. (12): r = C_D0 - <C_D> - omega_L |<C_L>|.

Everything is jit/vmap/shard_map-compatible: the environment state is a pytree
and geometry arrays are closed over as constants, so ``N_envs`` environments
run as a single vmapped program on the "data" mesh axis (the paper's
multi-environment parallelism, DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd import probes as probes_mod
from repro.cfd import solver
from repro.cfd.grid import Geometry, GridConfig, build_geometry


@dataclass(frozen=True)
class EnvConfig:
    grid: GridConfig = GridConfig()
    steps_per_action: int = 50
    actions_per_episode: int = 100
    beta: float = 0.4             # action smoothing, eq. (11)
    reward_omega: float = 0.1     # lift penalty weight, eq. (12)
    # Uncontrolled mean drag, eq. (12).  The paper's value on its OpenFOAM mesh
    # is 3.205; 0.0 means "calibrate from the warmup run" (our IB grid at
    # moderate res gives ~3.5-3.7, resolution-dependent).
    cd0: float = 0.0
    warmup_time: float = 30.0     # t.u. of uncontrolled flow before training
    obs_dim: int = 149

    @property
    def action_max(self) -> float:
        return self.grid.u_max    # |V_jet| <= U_m constraint


class EnvState(NamedTuple):
    flow: solver.FlowState
    jet_vel: jnp.ndarray          # smoothed jet velocity (scalar)
    t: jnp.ndarray                # actuation counter


class EnvOutput(NamedTuple):
    obs: jnp.ndarray              # (149,) pressure probes
    reward: jnp.ndarray
    cd: jnp.ndarray               # mean C_D over the actuation period
    cl: jnp.ndarray


class CylinderEnv:
    """Factory for pure env functions bound to a geometry."""

    def __init__(self, cfg: EnvConfig = EnvConfig()):
        self.cfg = cfg
        self.geom = build_geometry(cfg.grid)
        self.geom_arrays = solver.geom_to_arrays(self.geom)
        self.probe_ij = jnp.asarray(self.geom.probe_ij, jnp.float32)
        self._reset_flow = None

    # -- uncontrolled warmup to a developed shedding state ------------------

    def warmup(self, verbose: bool = False) -> solver.FlowState:
        cfg = self.cfg
        n = int(round(cfg.warmup_time / cfg.grid.dt))
        flow = solver.init_state(cfg.grid, self.geom)
        run = jax.jit(functools.partial(self._run_steps, n))
        flow, (cds, cls) = run(flow, jnp.float32(0.0))
        self._reset_flow = jax.tree.map(lambda a: np.asarray(a), flow)
        if not self.cfg.cd0:  # calibrate C_D0 on the uncontrolled flow
            tail = max(1, n // 4)
            self.cfg = dataclasses.replace(
                self.cfg, cd0=float(jnp.mean(cds[-tail:])))
        if verbose:
            print(f"warmup {n} steps: CD0={self.cfg.cd0:.3f} "
                  f"CL[-1]={float(cls[-1]):.3f}")
        return flow

    def _run_steps(self, n, flow, jet_vel):
        def body(flow, _):
            flow, out = solver.step(self.cfg.grid, self.geom_arrays, flow,
                                    jet_vel)
            return flow, (out.cd, out.cl)
        return jax.lax.scan(body, flow, None, length=n)

    # -- pure env API --------------------------------------------------------

    def reset(self) -> Tuple[EnvState, jnp.ndarray]:
        if self._reset_flow is None:
            self.warmup()
        flow = jax.tree.map(jnp.asarray, self._reset_flow)
        st = EnvState(flow=solver.FlowState(*flow), jet_vel=jnp.float32(0.0),
                      t=jnp.int32(0))
        return st, self._observe(st)

    def _observe(self, st: EnvState) -> jnp.ndarray:
        return probes_mod.sample_pressure(self.probe_ij, st.flow.p)

    def env_step(self, st: EnvState, action) -> Tuple[EnvState, EnvOutput]:
        """One actuation period.  action: scalar in [-1, 1] (scaled to jets)."""
        cfg = self.cfg
        a = jnp.clip(action, -1.0, 1.0) * cfg.action_max
        jet = st.jet_vel + cfg.beta * (a - st.jet_vel)        # eq. (11)
        jet = jnp.clip(jet, -cfg.action_max, cfg.action_max)

        def body(flow, _):
            flow, out = solver.step(cfg.grid, self.geom_arrays, flow, jet)
            return flow, (out.cd, out.cl)

        flow, (cds, cls) = jax.lax.scan(body, st.flow, None,
                                        length=cfg.steps_per_action)
        cd = jnp.mean(cds)
        cl = jnp.mean(cls)
        reward = cfg.cd0 - cd - cfg.reward_omega * jnp.abs(cl)  # eq. (12)
        st2 = EnvState(flow=flow, jet_vel=jet, t=st.t + 1)
        return st2, EnvOutput(obs=self._observe(st2), reward=reward,
                              cd=cd, cl=cl)
