"""Explicit spatial domain decomposition of the Poisson solve: shard_map +
lax.ppermute halo exchange — the literal TPU translation of OpenFOAM's MPI
ranks (the paper's N_ranks axis), as opposed to letting GSPMD auto-partition
the global stencil (core/runner.make_sharded_cfd_step).

Each device owns an x-slab of the pressure grid, runs ``inner_iters``
red-black SOR sweeps locally (same block-Jacobi semantics as the Pallas
kernel), then exchanges one halo column with each neighbour — one
collective-permute pair per outer iteration, which is exactly the message
pattern whose cost the paper's Fig. 7 measures.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map


def _local_sweeps(p, rhs, left, right, *, dx, dy, omega, inner_iters,
                  col_offset):
    """inner_iters red-black SOR sweeps on a local slab with fixed halos."""
    ny, bx = p.shape
    dx2, dy2 = dx * dx, dy * dy
    inv_diag = 1.0 / (2.0 / dx2 + 2.0 / dy2)
    jj = jax.lax.broadcasted_iota(jnp.int32, (ny, bx), 0)
    ii = jax.lax.broadcasted_iota(jnp.int32, (ny, bx), 1) + col_offset
    red = ((ii + jj) % 2 == 0)

    def sweep(p, mask):
        pp = jnp.concatenate([left, p, right], axis=1)
        pp = jnp.concatenate([pp[:1], pp, pp[-1:]], axis=0)  # Neumann walls
        nb = ((pp[1:-1, :-2] + pp[1:-1, 2:]) / dx2
              + (pp[:-2, 1:-1] + pp[2:, 1:-1]) / dy2)
        return jnp.where(mask, (1 - omega) * p + omega * (nb - rhs)
                         * inv_diag, p)

    def body(_, p):
        p = sweep(p, red)
        return sweep(p, ~red)

    return jax.lax.fori_loop(0, inner_iters, body, p)


def make_decomposed_poisson(mesh: Mesh, nx: int, *, axis: str = "model",
                            dx: float, dy: float, omega: float = 1.7,
                            inner_iters: int = 4):
    """Returns a jit'd (rhs, p0, iters is static) -> p solver where the grid
    is decomposed into x-slabs over ``axis`` with explicit halo exchange."""
    n_shards = mesh.shape[axis]
    assert nx % n_shards == 0, (nx, n_shards)
    bx = nx // n_shards

    def solve_local(p, rhs, *, outer_iters):
        idx = jax.lax.axis_index(axis)

        def outer(_, p):
            # halo exchange: my rightmost column -> right neighbour's left
            # halo, my leftmost -> left neighbour's right halo (2 ppermutes
            # per outer iteration == 2 MPI messages per rank pair)
            right_from_left = jax.lax.ppermute(
                p[:, -1:], axis, [(i, i + 1) for i in range(n_shards - 1)])
            left_from_right = jax.lax.ppermute(
                p[:, :1], axis, [(i + 1, i) for i in range(n_shards - 1)])
            left = jnp.where(idx == 0, p[:, :1], right_from_left)   # Neumann
            right = jnp.where(idx == n_shards - 1, -p[:, -1:],      # outlet
                              left_from_right)
            return _local_sweeps(p, rhs, left, right, dx=dx, dy=dy,
                                 omega=omega, inner_iters=inner_iters,
                                 col_offset=idx * bx)

        return jax.lax.fori_loop(0, outer_iters, outer, p)

    @functools.partial(jax.jit, static_argnames=("iters",))
    def solve(rhs, p0=None, *, iters: int = 60):
        p = jnp.zeros_like(rhs) if p0 is None else p0
        outer = -(-iters // inner_iters)
        fn = shard_map(
            functools.partial(solve_local, outer_iters=outer),
            mesh=mesh, in_specs=(P(None, axis), P(None, axis)),
            out_specs=P(None, axis), check_vma=False)
        return fn(p, rhs)

    return solve
