"""Explicit spatial domain decomposition of the Poisson solve: shard_map +
lax.ppermute halo exchange — the literal TPU translation of OpenFOAM's MPI
ranks (the paper's N_ranks axis), as opposed to letting GSPMD auto-partition
the global stencil (core/runner.make_sharded_cfd_step).

Each device owns an x-slab of the pressure grid held in packed-checkerboard
storage (red/black planes, see cfd/poisson.py), so local sweeps touch only
the points they update.  Packing also halves the exchange volume: a colored
half-sweep needs only the *opposite*-parity entries of the neighbour's edge
column, so every ppermute ships a half-width (ceil(ny/2)) halo instead of a
full column — the per-message comm cost the paper's Fig. 7 measures, halved.

Two coupling schedules:

  ``inner_iters == 1``  exchange before EVERY colored half-sweep (two
        half-width ppermute pairs per red+black pair).  The black sweep then
        sees fresh red values across rank boundaries, which makes the
        decomposed iteration *exactly* the monolithic red-black sweep — at
        any rank count, not just n_shards == 1.  Same bytes per sweep pair
        as the old full-column exchange, half the bytes per message.
  ``inner_iters > 1``   classic block-Jacobi: one full-edge exchange (both
        parities, packed into one message pair) per outer round, halos
        frozen for ``inner_iters`` local sweep pairs — the loose-coupling
        end of the comm/convergence trade.

``decomposed_solve`` is the traceable entry point (usable inside jit / vmap /
scan — it is the ``backend="halo"`` path of ``cfd.poisson.solve`` and runs
inside the vmapped env step when a plan picks ``n_ranks > 1``);
``make_decomposed_poisson`` wraps it as a standalone jit'd solver.  Grids
whose slab width or height is odd fall back to the legacy full-grid sweeps
(``packed=False`` forces that path; it keeps the old frozen-halo semantics).

The domain-edge ghosts (Neumann at the inlet shard, Dirichlet at the outlet
shard) are recomputed from the live local planes every sweep, exactly like
the monolithic reference.

jax 0.4.x caveat: the result keeps its mesh sharding, and *eager* op-by-op
math on such an array can be silently wrong on the forced-multi-device CPU
backend (observed with concatenate on a ("data">1, "model">1) mesh).  Every
production path here consumes the result inside jit — whole-program
partitioning is correct; ad-hoc analysis code should ``np.asarray`` the
output first.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.cfd import poisson
from repro.compat import shard_map


def validate_decomposition(mesh, nx: int, axis: str = "model") -> int:
    """Number of x-slabs for ``mesh``/``axis``, with actionable errors.

    Raises ``ValueError`` (not assert — asserts vanish under ``python -O``)
    when the axis is missing from the mesh or the grid width does not divide
    into equal slabs.  Works on abstract meshes too (shape-only check).
    """
    axes = tuple(mesh.shape.keys()) if hasattr(mesh.shape, "keys") \
        else tuple(mesh.axis_names)
    if axis not in axes:
        raise ValueError(
            f"mesh has no {axis!r} axis (axes: {axes}); build it with a "
            f"spatial axis — e.g. launch.mesh.mesh_for_plan(plan) or "
            f"make_debug_mesh(n_data, n_model) — or pass axis=<name>")
    n_shards = mesh.shape[axis]
    if nx % n_shards:
        lo, hi = nx - nx % n_shards, nx + (-nx) % n_shards
        raise ValueError(
            f"grid width nx={nx} does not split into {n_shards} equal "
            f"x-slabs over mesh axis {axis!r}; use a grid with "
            f"nx % n_ranks == 0 (e.g. nx={lo} or nx={hi}) or a plan whose "
            f"n_ranks divides {nx}")
    return n_shards


def halo_exchange_values(ny: int, packed: bool = True) -> int:
    """Scalars shipped per ppermute message: a full edge column for the
    legacy path, a single-parity half column for the packed path."""
    return -(-ny // 2) if packed else ny


def ppermute_message_shapes(fn, *args, **kw):
    """Trace ``fn(*args, **kw)`` and return the operand shape of every
    ``ppermute`` in the jaxpr (recursing through scans / shard_map / cond
    bodies).  The halo tests use this to pin the exchanged byte count."""
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kw))(*args)
    shapes = []

    def sub_jaxprs(v):
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for item in v:
                yield from sub_jaxprs(item)

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "ppermute":
                shapes.extend(tuple(v.aval.shape) for v in eqn.invars)
            for v in eqn.params.values():
                for sub in sub_jaxprs(v):
                    walk(sub)

    walk(closed.jaxpr)
    return shapes


# ---------------------------------------------------------------------------
# legacy full-grid path (odd slab width / height fallback + oracle)
# ---------------------------------------------------------------------------

def _local_sweeps(p, rhs, left_h, right_h, *, idx, n_shards, dx, dy, omega,
                  inner_iters, sweep0, n_sor, n_pairs, col_offset):
    """``inner_iters`` full-grid red-black sweep pairs on a local slab.

    ``left_h``/``right_h`` are the exchanged neighbour halos, frozen for the
    whole call; the domain-edge ghosts come from the live local columns.
    ``sweep0`` is the global index of this call's first sweep pair — pairs
    past ``n_sor`` run un-relaxed (the reference solver's Gauss-Seidel
    polish tail), and pairs past ``n_pairs`` are masked to no-ops so the
    total sweep count matches the caller's ``iters`` exactly even when
    ``inner_iters`` does not divide it.
    """
    ny, bx = p.shape
    dx2, dy2 = dx * dx, dy * dy
    inv_diag = 1.0 / (2.0 / dx2 + 2.0 / dy2)
    jj = jax.lax.broadcasted_iota(jnp.int32, (ny, bx), 0)
    ii = jax.lax.broadcasted_iota(jnp.int32, (ny, bx), 1) + col_offset
    red = ((ii + jj) % 2 == 0)

    def sweep(p, mask, om):
        left = jnp.where(idx == 0, p[:, :1], left_h)          # Neumann inlet
        right = jnp.where(idx == n_shards - 1, -p[:, -1:],    # Dirichlet out
                          right_h)
        pp = jnp.concatenate([left, p, right], axis=1)
        pp = jnp.concatenate([pp[:1], pp, pp[-1:]], axis=0)   # Neumann walls
        nb = ((pp[1:-1, :-2] + pp[1:-1, 2:]) / dx2
              + (pp[:-2, 1:-1] + pp[2:, 1:-1]) / dy2)
        p_gs = (nb - rhs) * inv_diag
        return jnp.where(mask, (1 - om) * p + om * p_gs, p)

    def body(j, p):
        om = jnp.where(sweep0 + j < n_sor, omega, 1.0)
        active = sweep0 + j < n_pairs
        p = sweep(p, red & active, om)
        return sweep(p, ~red & active, om)

    return jax.lax.fori_loop(0, inner_iters, body, p)


def _decomposed_solve_full(rhs, p0, *, mesh, axis, dx, dy, omega, iters,
                           inner_iters, polish):
    n_shards = mesh.shape[axis]
    bx = rhs.shape[-1] // n_shards
    outer = -(-iters // inner_iters)
    n_sor = iters - min(polish, iters // 2)

    def solve_local(p, rhs):
        idx = jax.lax.axis_index(axis)

        def outer_body(i, p):
            # halo exchange: my rightmost column -> right neighbour's left
            # halo, my leftmost -> left neighbour's right halo (2 ppermutes
            # per outer iteration == 2 MPI messages per rank pair)
            if n_shards > 1:
                from_left = jax.lax.ppermute(
                    p[:, -1:], axis,
                    [(k, k + 1) for k in range(n_shards - 1)])
                from_right = jax.lax.ppermute(
                    p[:, :1], axis,
                    [(k + 1, k) for k in range(n_shards - 1)])
            else:                      # single shard: edge ghosts cover both
                from_left = from_right = jnp.zeros_like(p[:, :1])
            return _local_sweeps(p, rhs, from_left, from_right, idx=idx,
                                 n_shards=n_shards, dx=dx, dy=dy, omega=omega,
                                 inner_iters=inner_iters,
                                 sweep0=i * inner_iters, n_sor=n_sor,
                                 n_pairs=iters, col_offset=idx * bx)

        return jax.lax.fori_loop(0, outer, outer_body, p)

    # check_vma=True (check_rep on jax 0.4.x) is load-bearing, not a debug
    # aid: with the replication of unmentioned mesh axes UNchecked, jax
    # 0.4.37's partitioner miscompiles this shard_map when it is embedded in
    # a larger jitted program on a mesh whose "data" axis is > 1 (state
    # corruption growing over a lax.scan).  Verified replication makes the
    # same program correct on every mesh shape.
    fn = shard_map(solve_local, mesh=mesh,
                   in_specs=(P(None, axis), P(None, axis)),
                   out_specs=P(None, axis), check_vma=True)
    return fn(p0, rhs)


# ---------------------------------------------------------------------------
# packed-checkerboard path (the default)
# ---------------------------------------------------------------------------

def _decomposed_solve_packed(rhs, p0, *, mesh, axis, dx, dy, omega, iters,
                             inner_iters, polish):
    n_shards = mesh.shape[axis]
    ny = rhs.shape[-2]
    n_sor = iters - min(polish, iters // 2)
    dx2, dy2 = dx * dx, dy * dy
    inv_diag = 1.0 / (2.0 / dx2 + 2.0 / dy2)
    fwd = [(k, k + 1) for k in range(n_shards - 1)]
    bwd = [(k + 1, k) for k in range(n_shards - 1)]

    def solve_local(p, rhs):
        idx = jax.lax.axis_index(axis)
        last = n_shards - 1
        # slab width is even, so every slab starts on an even global column
        # and local packing parity equals global parity
        red, black = poisson.pack_checkerboard(p)
        rhs_r, rhs_b = poisson.pack_checkerboard(rhs)
        row_odd = (jnp.arange(ny) % 2 == 1)[:, None]

        def exchange(col, perm):
            if n_shards == 1:
                return jnp.zeros_like(col)
            return jax.lax.ppermute(col, axis, perm)

        def scatter(half, rows):
            """Half-column ghost: received single-parity values land on their
            row parity; the other rows are never selected by the sweep."""
            return jnp.zeros((ny, 1), half.dtype).at[rows::2, :].set(half)

        def red_half(red, black, lg, rg, om):
            return poisson.packed_half_sweep(
                red, black, rhs_r, lg, rg,
                *poisson.packed_ghost_rows(red, black),
                row_odd, om, dx2, dy2, inv_diag)

        def black_half(red, black, lg, rg, om):
            return poisson.packed_half_sweep(
                black, red, rhs_b, lg, rg,
                *poisson.packed_ghost_rows(black, red),
                ~row_odd, om, dx2, dy2, inv_diag)

        def edge_ghosts(recv_l, rows_l, recv_r, rows_r, own):
            lg = jnp.where(idx == 0, own[:, :1], scatter(recv_l, rows_l))
            rg = jnp.where(idx == last, -own[:, -1:], scatter(recv_r, rows_r))
            return lg, rg

        if inner_iters == 1:
            # tight coupling: half-width exchange before every half-sweep —
            # the decomposed iteration IS the monolithic red-black sweep
            def pair(i, planes):
                red, black = planes
                om = jnp.where(i < n_sor, omega, 1.0)
                # red updates sit on even rows of even columns / odd rows of
                # odd columns, so their west/east ghosts are the neighbour's
                # BLACK edge entries: even rows from the left, odd from the
                # right (and mirrored parities for the black update)
                lg, rg = edge_ghosts(exchange(black[0::2, -1:], fwd), 0,
                                     exchange(black[1::2, :1], bwd), 1, red)
                red = red_half(red, black, lg, rg, om)
                lg, rg = edge_ghosts(exchange(red[1::2, -1:], fwd), 1,
                                     exchange(red[0::2, :1], bwd), 0, black)
                black = black_half(red, black, lg, rg, om)
                return red, black

            red, black = jax.lax.fori_loop(0, iters, pair, (red, black))
        else:
            # block-Jacobi: both parities of the edge columns cross once per
            # outer round (one packed message pair), then stay frozen
            outer = -(-iters // inner_iters)
            h = ny // 2

            def outer_body(i, planes):
                red, black = planes
                from_left = exchange(
                    jnp.concatenate([black[0::2, -1:], red[1::2, -1:]],
                                    axis=0), fwd)
                from_right = exchange(
                    jnp.concatenate([black[1::2, :1], red[0::2, :1]],
                                    axis=0), bwd)

                def body(j, planes):
                    red, black = planes
                    om = jnp.where(i * inner_iters + j < n_sor, omega, 1.0)
                    active = i * inner_iters + j < iters
                    lg, rg = edge_ghosts(from_left[:h], 0,
                                         from_right[:h], 1, red)
                    red_new = red_half(red, black, lg, rg, om)
                    red = jnp.where(active, red_new, red)
                    lg, rg = edge_ghosts(from_left[h:], 1,
                                         from_right[h:], 0, black)
                    black_new = black_half(red, black, lg, rg, om)
                    black = jnp.where(active, black_new, black)
                    return red, black

                return jax.lax.fori_loop(0, inner_iters, body, (red, black))

            red, black = jax.lax.fori_loop(0, outer, outer_body, (red, black))
        return poisson.unpack_checkerboard(red, black)

    # check_vma=True is load-bearing — see _decomposed_solve_full
    fn = shard_map(solve_local, mesh=mesh,
                   in_specs=(P(None, axis), P(None, axis)),
                   out_specs=P(None, axis), check_vma=True)
    return fn(p0, rhs)


def decomposed_solve(rhs, p0=None, *, mesh: Mesh, axis: str = "model",
                     dx: float, dy: float, omega: float = 1.7,
                     iters: int = 60, inner_iters: int = 4,
                     polish: int = 10, packed: bool = None):
    """x-slab + ppermute halo-exchange pressure solve (traceable).

    Exactly ``iters`` red-black sweep pairs run (matching the reference
    solver's work at equal ``iters``); the last ``polish`` pairs run with
    omega = 1, mirroring ``poisson.solve``'s Gauss-Seidel tail.  Sweeps run
    in packed-checkerboard storage with half-width single-parity halos
    whenever the slab width and height are even (``packed=None`` auto;
    ``packed=False`` forces the legacy full-grid frozen-halo path).  See
    the module docstring for the two ``inner_iters`` coupling schedules.
    """
    n_shards = validate_decomposition(mesh, rhs.shape[-1], axis)
    ny = rhs.shape[-2]
    bx = rhs.shape[-1] // n_shards
    if packed is None:
        packed = bx % 2 == 0 and ny % 2 == 0
    elif packed and (bx % 2 or ny % 2):
        raise ValueError(
            f"packed halo sweeps need an even slab width and height, got "
            f"bx={bx}, ny={ny} (nx={rhs.shape[-1]} over {n_shards} ranks); "
            f"pass packed=False or use an even-slab grid")
    p0 = jnp.zeros_like(rhs) if p0 is None else p0
    impl = _decomposed_solve_packed if packed else _decomposed_solve_full
    return impl(rhs, p0, mesh=mesh, axis=axis, dx=dx, dy=dy, omega=omega,
                iters=iters, inner_iters=inner_iters, polish=polish)


def make_decomposed_poisson(mesh: Mesh, nx: int, *, axis: str = "model",
                            dx: float, dy: float, omega: float = 1.7,
                            inner_iters: int = 4, polish: int = 10):
    """Returns a jit'd (rhs, p0, iters is static) -> p solver where the grid
    is decomposed into x-slabs over ``axis`` with explicit halo exchange."""
    validate_decomposition(mesh, nx, axis)

    @functools.partial(jax.jit, static_argnames=("iters",))
    def solve(rhs, p0=None, *, iters: int = 60):
        return decomposed_solve(rhs, p0, mesh=mesh, axis=axis, dx=dx, dy=dy,
                                omega=omega, iters=iters,
                                inner_iters=inner_iters, polish=polish)

    return solve
