"""Explicit spatial domain decomposition of the Poisson solve: shard_map +
lax.ppermute halo exchange — the literal TPU translation of OpenFOAM's MPI
ranks (the paper's N_ranks axis), as opposed to letting GSPMD auto-partition
the global stencil (core/runner.make_sharded_cfd_step).

Each device owns an x-slab of the pressure grid, runs ``inner_iters``
red-black SOR sweeps locally (same block-Jacobi semantics as the Pallas
kernel), then exchanges one halo column with each neighbour — one
collective-permute pair per outer iteration, which is exactly the message
pattern whose cost the paper's Fig. 7 measures.

``decomposed_solve`` is the traceable entry point (usable inside jit / vmap /
scan — it is the ``backend="halo"`` path of ``cfd.poisson.solve`` and runs
inside the vmapped env step when a plan picks ``n_ranks > 1``);
``make_decomposed_poisson`` wraps it as a standalone jit'd solver.

Only the *neighbour* halos are frozen between exchanges (block-Jacobi); the
domain-edge ghosts (Neumann at the inlet shard, Dirichlet at the outlet
shard) are recomputed from the live local columns every sweep, exactly like
the monolithic reference — so at ``n_shards == 1`` with ``inner_iters == 1``
this reproduces ``poisson.solve`` sweep for sweep.

jax 0.4.x caveat: the result keeps its mesh sharding, and *eager* op-by-op
math on such an array can be silently wrong on the forced-multi-device CPU
backend (observed with concatenate on a ("data">1, "model">1) mesh).  Every
production path here consumes the result inside jit — whole-program
partitioning is correct; ad-hoc analysis code should ``np.asarray`` the
output first.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def validate_decomposition(mesh, nx: int, axis: str = "model") -> int:
    """Number of x-slabs for ``mesh``/``axis``, with actionable errors.

    Raises ``ValueError`` (not assert — asserts vanish under ``python -O``)
    when the axis is missing from the mesh or the grid width does not divide
    into equal slabs.  Works on abstract meshes too (shape-only check).
    """
    axes = tuple(mesh.shape.keys()) if hasattr(mesh.shape, "keys") \
        else tuple(mesh.axis_names)
    if axis not in axes:
        raise ValueError(
            f"mesh has no {axis!r} axis (axes: {axes}); build it with a "
            f"spatial axis — e.g. launch.mesh.mesh_for_plan(plan) or "
            f"make_debug_mesh(n_data, n_model) — or pass axis=<name>")
    n_shards = mesh.shape[axis]
    if nx % n_shards:
        lo, hi = nx - nx % n_shards, nx + (-nx) % n_shards
        raise ValueError(
            f"grid width nx={nx} does not split into {n_shards} equal "
            f"x-slabs over mesh axis {axis!r}; use a grid with "
            f"nx % n_ranks == 0 (e.g. nx={lo} or nx={hi}) or a plan whose "
            f"n_ranks divides {nx}")
    return n_shards


def _local_sweeps(p, rhs, left_h, right_h, *, idx, n_shards, dx, dy, omega,
                  inner_iters, sweep0, n_sor, n_pairs, col_offset):
    """``inner_iters`` red-black sweep pairs on a local slab.

    ``left_h``/``right_h`` are the exchanged neighbour halos, frozen for the
    whole call; the domain-edge ghosts come from the live local columns.
    ``sweep0`` is the global index of this call's first sweep pair — pairs
    past ``n_sor`` run un-relaxed (the reference solver's Gauss-Seidel
    polish tail), and pairs past ``n_pairs`` are masked to no-ops so the
    total sweep count matches the caller's ``iters`` exactly even when
    ``inner_iters`` does not divide it.
    """
    ny, bx = p.shape
    dx2, dy2 = dx * dx, dy * dy
    inv_diag = 1.0 / (2.0 / dx2 + 2.0 / dy2)
    jj = jax.lax.broadcasted_iota(jnp.int32, (ny, bx), 0)
    ii = jax.lax.broadcasted_iota(jnp.int32, (ny, bx), 1) + col_offset
    red = ((ii + jj) % 2 == 0)

    def sweep(p, mask, om):
        left = jnp.where(idx == 0, p[:, :1], left_h)          # Neumann inlet
        right = jnp.where(idx == n_shards - 1, -p[:, -1:],    # Dirichlet out
                          right_h)
        pp = jnp.concatenate([left, p, right], axis=1)
        pp = jnp.concatenate([pp[:1], pp, pp[-1:]], axis=0)   # Neumann walls
        nb = ((pp[1:-1, :-2] + pp[1:-1, 2:]) / dx2
              + (pp[:-2, 1:-1] + pp[2:, 1:-1]) / dy2)
        p_gs = (nb - rhs) * inv_diag
        return jnp.where(mask, (1 - om) * p + om * p_gs, p)

    def body(j, p):
        om = jnp.where(sweep0 + j < n_sor, omega, 1.0)
        active = sweep0 + j < n_pairs
        p = sweep(p, red & active, om)
        return sweep(p, ~red & active, om)

    return jax.lax.fori_loop(0, inner_iters, body, p)


def decomposed_solve(rhs, p0=None, *, mesh: Mesh, axis: str = "model",
                     dx: float, dy: float, omega: float = 1.7,
                     iters: int = 60, inner_iters: int = 4,
                     polish: int = 10):
    """x-slab + ppermute halo-exchange pressure solve (traceable).

    Exactly ``iters`` red-black sweep pairs run (matching the reference
    solver's work at equal ``iters``), grouped into outer rounds of
    ``inner_iters`` local sweeps each with one halo-column exchange (two
    ppermutes — the MPI message pair) per round; when ``inner_iters`` does
    not divide ``iters`` the tail of the last round is masked off.  The
    last ``polish`` pairs run with omega = 1, mirroring ``poisson.solve``'s
    Gauss-Seidel tail.
    """
    n_shards = validate_decomposition(mesh, rhs.shape[-1], axis)
    bx = rhs.shape[-1] // n_shards
    p0 = jnp.zeros_like(rhs) if p0 is None else p0
    outer = -(-iters // inner_iters)
    n_sor = iters - min(polish, iters // 2)

    def solve_local(p, rhs):
        idx = jax.lax.axis_index(axis)

        def outer_body(i, p):
            # halo exchange: my rightmost column -> right neighbour's left
            # halo, my leftmost -> left neighbour's right halo (2 ppermutes
            # per outer iteration == 2 MPI messages per rank pair)
            if n_shards > 1:
                from_left = jax.lax.ppermute(
                    p[:, -1:], axis,
                    [(k, k + 1) for k in range(n_shards - 1)])
                from_right = jax.lax.ppermute(
                    p[:, :1], axis,
                    [(k + 1, k) for k in range(n_shards - 1)])
            else:                      # single shard: edge ghosts cover both
                from_left = from_right = jnp.zeros_like(p[:, :1])
            return _local_sweeps(p, rhs, from_left, from_right, idx=idx,
                                 n_shards=n_shards, dx=dx, dy=dy, omega=omega,
                                 inner_iters=inner_iters,
                                 sweep0=i * inner_iters, n_sor=n_sor,
                                 n_pairs=iters, col_offset=idx * bx)

        return jax.lax.fori_loop(0, outer, outer_body, p)

    # check_vma=True (check_rep on jax 0.4.x) is load-bearing, not a debug
    # aid: with the replication of unmentioned mesh axes UNchecked, jax
    # 0.4.37's partitioner miscompiles this shard_map when it is embedded in
    # a larger jitted program on a mesh whose "data" axis is > 1 (state
    # corruption growing over a lax.scan).  Verified replication makes the
    # same program correct on every mesh shape.
    fn = shard_map(solve_local, mesh=mesh,
                   in_specs=(P(None, axis), P(None, axis)),
                   out_specs=P(None, axis), check_vma=True)
    return fn(p0, rhs)


def make_decomposed_poisson(mesh: Mesh, nx: int, *, axis: str = "model",
                            dx: float, dy: float, omega: float = 1.7,
                            inner_iters: int = 4, polish: int = 10):
    """Returns a jit'd (rhs, p0, iters is static) -> p solver where the grid
    is decomposed into x-slabs over ``axis`` with explicit halo exchange."""
    validate_decomposition(mesh, nx, axis)

    @functools.partial(jax.jit, static_argnames=("iters",))
    def solve(rhs, p0=None, *, iters: int = 60):
        return decomposed_solve(rhs, p0, mesh=mesh, axis=axis, dx=dx, dy=dy,
                                omega=omega, iters=iters,
                                inner_iters=inner_iters, polish=polish)

    return solve
