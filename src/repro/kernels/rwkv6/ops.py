"""jit'd wrapper: models/ssm-shaped entry point for the WKV6 kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv6_bhsn


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, state, *, chunk: int = 32, interpret: bool = None):
    """models/ssm layout: r,k,v,w (B,S,H,N); u (H,N); state (B,H,N,N).
    Returns (out (B,S,H,N), new_state (B,H,N,N))."""
    if interpret is None:
        interpret = not _on_tpu()
    B, S, H, N = r.shape
    c = min(chunk, S)
    while S % c:
        c -= 1

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, N)

    ub = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, 1, N)
    out, s_fin = wkv6_bhsn(to_bh(r), to_bh(k), to_bh(v),
                           to_bh(w.astype(r.dtype)), ub.astype(r.dtype),
                           state.reshape(B * H, N, N),
                           chunk=c, interpret=interpret)
    out = out.reshape(B, H, S, N).transpose(0, 2, 1, 3)
    return out, s_fin.reshape(B, H, N, N)
