"""Pallas TPU kernel: chunked WKV6 recurrence (RWKV-6 linear attention).

The sequential per-token recurrence
    S_t = diag(w_t) S_{t-1} + k_t^T v_t,   o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
is reformulated into chunks of C tokens so that within a chunk everything is
MXU matmuls (the TPU adaptation of the CUDA chunked-WKV kernels):

    lp_t   = cumsum(log w)                    (within chunk)
    r~_t   = r_t * exp(lp_{t-1})              (exclusive cumprod decay)
    k~_s   = k_s * exp(-lp_s)
    o      = r~ @ S_prev + strict_tril(r~ k~^T) @ v + (sum(r*u*k, -1)) * v
    S_new  = diag(exp(lp_C)) (S_prev + k~^T v)

Grid: (B*H, num_chunks), chunk axis sequential; the (N, N) state lives in VMEM
scratch across chunk steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 out_ref, sfin_ref, state_scr, *, chunk: int,
                 num_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)               # (C, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)               # decay in (0,1)
    u = u_ref[0].astype(jnp.float32)               # (1, N) bonus

    lw = jnp.log(jnp.maximum(w, 1e-30))
    lp = jnp.cumsum(lw, axis=0)                    # inclusive (C, N)
    lp_excl = lp - lw                              # exclusive
    r_t = r * jnp.exp(lp_excl)
    k_t = k * jnp.exp(-lp)

    S = state_scr[...]                             # (N, N)
    inter = jax.lax.dot_general(r_t, S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    A = jax.lax.dot_general(r_t, k_t, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (C, C)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(si < ti, A, 0.0)                 # strictly lower
    intra = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    diag_c = jnp.sum(r * u * k, axis=-1, keepdims=True)
    out_ref[0] = (inter + intra + diag_c * v).astype(out_ref.dtype)

    decay_c = jnp.exp(lp[-1:])                     # (1, N)
    kv = jax.lax.dot_general(k_t, v, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, N)
    state_scr[...] = decay_c.T * (S + kv)

    @pl.when(ci == num_chunks - 1)
    def _fin():
        sfin_ref[0] = state_scr[...].astype(sfin_ref.dtype)


def wkv6_bhsn(r, k, v, w, u, s0, *, chunk: int = 32,
              interpret: bool = True):
    """r,k,v,w: (BH, S, N); u: (BH, 1, N); s0: (BH, N, N).
    Returns (out (BH, S, N), s_final (BH, N, N))."""
    BH, S, N = r.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    kern = functools.partial(_wkv6_kernel, chunk=chunk, num_chunks=nc)
    seq = pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0))
    bh_only = pl.BlockSpec((1, 1, N), lambda b, c: (b, 0, 0))
    st = pl.BlockSpec((1, N, N), lambda b, c: (b, 0, 0))
    return pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[seq, seq, seq, seq, bh_only, st],
        out_specs=(seq, st),
        out_shape=(jax.ShapeDtypeStruct((BH, S, N), r.dtype),
                   jax.ShapeDtypeStruct((BH, N, N), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
