"""Pure-jnp oracle for the chunked WKV6 kernel: the sequential scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import wkv6_scan  # noqa: F401  (canonical recurrence)


def wkv6_ref(r, k, v, w, u, s0):
    """Same layout as the kernel: r,k,v,w (BH,S,N); u (BH,1,N); s0 (BH,N,N)."""
    BH, S, N = r.shape

    def one(r1, k1, v1, w1, u1, s1):
        def step(S_, inp):
            rt, kt, vt, wt = inp
            kv = kt[:, None] * vt[None, :]
            out = rt @ (S_ + u1[0][:, None] * kv)
            return wt[:, None] * S_ + kv, out
        S_fin, outs = jax.lax.scan(step, s1, (r1, k1, v1, w1))
        return outs, S_fin

    outs, s_fin = jax.vmap(one)(r.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), w.astype(jnp.float32),
                                u.astype(jnp.float32), s0.astype(jnp.float32))
    return outs.astype(r.dtype), s_fin
