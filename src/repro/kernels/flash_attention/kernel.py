"""Pallas TPU kernel: block-tiled causal flash attention (online softmax).

Grid: (batch*heads, q_blocks, k_blocks) with the k axis innermost and
"arbitrary" (sequential) — running max/sum/accumulator live in VMEM scratch
across k steps and the output block is written on the last k step.
Block sizes are MXU-aligned (multiples of 128 on the lane dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  sliding_window: int, num_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (block_q, dh)
    k = k_ref[0]                                   # (block_k, dh)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if sliding_window:
        mask = mask & (kpos > qpos - sliding_window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (block_q, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         sliding_window: int = 0, block_q: int = 128,
                         block_k: int = 128, interpret: bool = True):
    """q, k, v: (BH, S, dh) same head count.  Returns (BH, S, dh)."""
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    scale = dh ** -0.5

    kern = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, sliding_window=sliding_window, num_k_blocks=nk)

    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
