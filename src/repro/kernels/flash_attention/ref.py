"""Pure-jnp oracle for flash attention (naive softmax attention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, sliding_window: int = 0):
    """q, k, v: (BH, S, dh).  fp32 softmax, same masking as the kernel."""
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * dh ** -0.5
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if sliding_window:
        mask = mask & (kpos > qpos - sliding_window)
    s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w.astype(v.dtype), v)
