"""jit'd wrapper: GQA-aware flash attention entry point for models/attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "sliding_window",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, sliding_window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    """q: (B, S, H, dh); k, v: (B, S, Hkv, dh) -> (B, S, H, dh).

    GQA is handled by broadcasting KV heads to the query head count before the
    kernel (the kernel itself is MHA-shaped; a GQA-native kernel that keeps KV
    virtual is a known further optimization, noted in EXPERIMENTS.md §Perf).
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, S, H, dh = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    kb = k.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    vb = v.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    bq = min(block_q, S)
    bk = min(block_k, S)
    out = flash_attention_bhsd(qb, kb, vb, causal=causal,
                               sliding_window=sliding_window,
                               block_q=bq, block_k=bk, interpret=interpret)
    return out.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
