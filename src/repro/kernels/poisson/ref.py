"""Pure-jnp oracle for the Poisson slab smoothers.

``rb_sor_slabs_ref`` reproduces the full-grid kernel's *exact* semantics
(block-Jacobi outer iteration with stale halos, red-black SOR inner sweeps)
for bitwise-level comparison; ``rb_sor_slabs_packed_ref`` is the same oracle
lifted to the packed-checkerboard plane interface (the values a frozen
full-width halo provides to each colored half-sweep are identical to the
packed kernel's single-parity ghosts, so the full-grid oracle doubles as the
packed one); ``solve_ref`` is the globally-coupled solver from cfd/poisson.py
used for solution-level convergence tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cfd.poisson import solve as solve_ref  # noqa: F401  (re-export)


def rb_sor_slabs_ref(p, rhs, *, dx, dy, omega, nslabs, inner_iters):
    ny, nx = p.shape
    bx = nx // nslabs
    dx2, dy2 = dx * dx, dy * dy
    inv_diag = 1.0 / (2.0 / dx2 + 2.0 / dy2)
    jj, ii = jnp.meshgrid(jnp.arange(ny), jnp.arange(bx), indexing="ij")
    red = ((ii + jj) % 2 == 0)

    def slab(i):
        pi = jax.lax.dynamic_slice_in_dim(p, i * bx, bx, axis=1)
        ri = jax.lax.dynamic_slice_in_dim(rhs, i * bx, bx, axis=1)
        if i == 0:
            left = pi[:, :1]
        else:
            left = p[:, i * bx - 1: i * bx]
        if i == nslabs - 1:
            right = -pi[:, -1:]
        else:
            right = p[:, (i + 1) * bx: (i + 1) * bx + 1]

        def sweep(pb, mask):
            pp = jnp.concatenate([left, pb, right], axis=1)
            pp = jnp.concatenate([pp[:1], pp, pp[-1:]], axis=0)
            nb = ((pp[1:-1, :-2] + pp[1:-1, 2:]) / dx2
                  + (pp[:-2, 1:-1] + pp[2:, 1:-1]) / dy2)
            p_gs = (nb - ri) * inv_diag
            return jnp.where(mask, (1 - omega) * pb + omega * p_gs, pb)

        def body(_, pb):
            pb = sweep(pb, red)
            pb = sweep(pb, ~red)
            return pb

        return jax.lax.fori_loop(0, inner_iters, body, pi)

    return jnp.concatenate([slab(i) for i in range(nslabs)], axis=1)


def rb_sor_slabs_packed_ref(red, black, rhs_r, rhs_b, *, dx, dy, omega,
                            nslabs, inner_iters):
    """Plane-level oracle for ``kernel.rb_sor_slabs_packed``: run the
    full-grid slab oracle on the unpacked fields and re-pack."""
    from repro.cfd.poisson import pack_checkerboard, unpack_checkerboard
    p = unpack_checkerboard(red, black)
    rhs = unpack_checkerboard(rhs_r, rhs_b)
    out = rb_sor_slabs_ref(p, rhs, dx=dx, dy=dy, omega=omega, nslabs=nslabs,
                           inner_iters=inner_iters)
    return pack_checkerboard(out)
