"""jit'd wrapper: full pressure solve built from the Pallas slab smoother."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.poisson.kernel import rb_sor_slabs


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("dx", "dy", "iters", "omega", "nslabs",
                                    "inner_iters", "interpret"))
def rb_sor(rhs, dx, dy, *, iters: int = 60, omega: float = 1.7, p0=None,
           nslabs: int = 0, inner_iters: int = 4, interpret: bool = None):
    """Drop-in replacement for cfd.poisson.solve backed by the Pallas kernel.

    ``iters`` global SOR iterations are mapped to outer block-Jacobi rounds of
    ``inner_iters`` VMEM-resident sweeps each.
    """
    ny, nx = rhs.shape
    if nx % 2:
        raise ValueError(
            f"rb_sor requires an even grid width for checkerboard slab "
            f"parity, got nx={nx}; use cfd.poisson.solve (it falls back to "
            f"the jnp path for odd widths)")
    if interpret is None:
        interpret = not _on_tpu()
    if nslabs == 0:
        # pick the widest slab that keeps (ny, bx) around <= 512 lanes
        nslabs = max(1, nx // 512)
        while nx % nslabs or (nx // nslabs) % 2:
            nslabs -= 1
    p = jnp.zeros_like(rhs) if p0 is None else p0
    outer = -(-iters // inner_iters)

    def body(_, p):
        return rb_sor_slabs(p, rhs, dx=float(dx), dy=float(dy),
                            omega=omega, nslabs=nslabs,
                            inner_iters=inner_iters, interpret=interpret)

    return jax.lax.fori_loop(0, outer, body, p)
