"""jit'd wrappers: full pressure solves built from the Pallas slab smoothers.

``rb_sor`` is the drop-in full-grid entry point; since the packed-
checkerboard rewrite it defaults to the packed slab kernel (both planes
VMEM-resident per slab, half the FLOPs/traffic) with ``packed=False``
keeping the original full-grid slab kernel for comparison.  ``rb_sor_planes``
is the plane-level loop ``cfd.poisson.solve`` composes with its packed
polish sweeps, so the pallas backend never round-trips through the full-grid
layout mid-solve.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.poisson.kernel import rb_sor_slabs, rb_sor_slabs_packed


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_nslabs(nx: int) -> int:
    """Widest slab count keeping (ny, bx) around <= 512 lanes with bx even."""
    nslabs = max(1, nx // 512)
    while nx % nslabs or (nx // nslabs) % 2:
        nslabs -= 1
    return nslabs


@functools.partial(jax.jit,
                   static_argnames=("dx", "dy", "iters", "omega", "nslabs",
                                    "inner_iters", "interpret"))
def rb_sor_planes(red, black, rhs_r, rhs_b, dx, dy, *, iters: int = 60,
                  omega: float = 1.7, nslabs: int = 0, inner_iters: int = 4,
                  interpret: bool = None):
    """``iters`` SOR iterations on packed planes via the packed slab kernel.

    Planes come from ``cfd.poisson.pack_checkerboard``; global iterations map
    to outer block-Jacobi rounds of ``inner_iters`` VMEM-resident sweep pairs
    each.  Returns the smoothed (red, black) planes — callers that need the
    full grid unpack at their own boundary."""
    w = red.shape[1]
    if interpret is None:
        interpret = not _on_tpu()
    if nslabs == 0:
        nslabs = _pick_nslabs(2 * w)
    outer = -(-iters // inner_iters) if iters > 0 else 0

    def body(_, planes):
        return rb_sor_slabs_packed(*planes, rhs_r, rhs_b, dx=float(dx),
                                   dy=float(dy), omega=omega, nslabs=nslabs,
                                   inner_iters=inner_iters,
                                   interpret=interpret)

    return jax.lax.fori_loop(0, outer, body, (red, black))


@functools.partial(jax.jit,
                   static_argnames=("dx", "dy", "iters", "omega", "nslabs",
                                    "inner_iters", "interpret", "packed"))
def rb_sor(rhs, dx, dy, *, iters: int = 60, omega: float = 1.7, p0=None,
           nslabs: int = 0, inner_iters: int = 4, interpret: bool = None,
           packed: bool = True):
    """Drop-in replacement for cfd.poisson.solve backed by the Pallas kernel.

    ``iters`` global SOR iterations are mapped to outer block-Jacobi rounds of
    ``inner_iters`` VMEM-resident sweeps each.  ``packed=True`` (default)
    runs the packed-checkerboard slab kernel; ``packed=False`` keeps the
    original full-grid slab kernel (the masked-update oracle).
    """
    nx = rhs.shape[1]
    if nx % 2:
        raise ValueError(
            f"rb_sor requires an even grid width for checkerboard slab "
            f"parity, got nx={nx}; use cfd.poisson.solve (it falls back to "
            f"the jnp path for odd widths)")
    if interpret is None:
        interpret = not _on_tpu()
    if nslabs == 0:
        nslabs = _pick_nslabs(nx)
    p = jnp.zeros_like(rhs) if p0 is None else p0

    if packed:
        from repro.cfd.poisson import pack_checkerboard, unpack_checkerboard
        planes = rb_sor_planes(*pack_checkerboard(p), *pack_checkerboard(rhs),
                               dx, dy, iters=iters, omega=omega,
                               nslabs=nslabs, inner_iters=inner_iters,
                               interpret=interpret)
        return unpack_checkerboard(*planes)

    outer = -(-iters // inner_iters)

    def body(_, p):
        return rb_sor_slabs(p, rhs, dx=float(dx), dy=float(dy),
                            omega=omega, nslabs=nslabs,
                            inner_iters=inner_iters, interpret=interpret)

    return jax.lax.fori_loop(0, outer, body, p)
