"""Pallas TPU kernel: VMEM-resident red-black SOR slab smoother.

TPU-native design (DESIGN.md §5): the pressure grid is split into x-slabs;
each program instance loads its slab (plus one halo column from each
neighbour) into VMEM, runs ``inner_iters`` red-black SOR sweeps entirely
in VMEM (no HBM round-trips between sweeps), and writes the slab back.
Across slabs this is a block-Jacobi outer iteration — the outer loop (and
halo refresh) lives in ops.py.

Neighbour slabs are delivered with the 3-index-map trick: the same array is
passed three times with index maps i, i-1, i+1 (clamped), so every block
stays block-aligned (no unblocked indexing needed).  Boundary conditions
(Neumann inlet/walls, Dirichlet-0 outlet) are applied inside the kernel
based on program_id.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sweep(p, rhs, red_mask, inv_diag, omega, dx2, dy2, left, right):
    """One colored Gauss-Seidel half-sweep on the slab (with halo columns)."""
    pp = jnp.concatenate([left, p, right], axis=1)       # (ny, bx+2)
    top = pp[:1, :]
    bot = pp[-1:, :]
    pp = jnp.concatenate([top, pp, bot], axis=0)         # (ny+2, bx+2) Neumann walls
    nb = ((pp[1:-1, :-2] + pp[1:-1, 2:]) / dx2
          + (pp[:-2, 1:-1] + pp[2:, 1:-1]) / dy2)
    p_gs = (nb - rhs) * inv_diag
    return jnp.where(red_mask, (1 - omega) * p + omega * p_gs, p)


def rb_sor_slab_kernel(p_ref, p_left_ref, p_right_ref, rhs_ref, out_ref, *,
                       nslabs: int, bx: int, dx: float, dy: float,
                       omega: float, inner_iters: int):
    i = pl.program_id(0)
    p = p_ref[...]
    rhs = rhs_ref[...]
    ny = p.shape[0]
    dx2, dy2 = dx * dx, dy * dy
    inv_diag = 1.0 / (2.0 / dx2 + 2.0 / dy2)

    # halo columns (stale during inner sweeps = block-Jacobi)
    left_halo = jnp.where(i == 0, p[:, :1],              # Neumann at inlet
                          p_left_ref[...][:, -1:])
    right_halo = jnp.where(i == nslabs - 1, -p[:, -1:],  # Dirichlet-0 outlet
                           p_right_ref[...][:, :1])

    # global checkerboard parity: slab column offset = i * bx (bx is even)
    jj = jax.lax.broadcasted_iota(jnp.int32, (ny, bx), 0)
    ii = jax.lax.broadcasted_iota(jnp.int32, (ny, bx), 1)
    red = ((ii + jj) % 2 == 0)

    def body(_, p):
        p = _sweep(p, rhs, red, inv_diag, omega, dx2, dy2, left_halo, right_halo)
        p = _sweep(p, rhs, ~red, inv_diag, omega, dx2, dy2, left_halo, right_halo)
        return p

    out_ref[...] = jax.lax.fori_loop(0, inner_iters, body, p)


def rb_sor_slabs(p, rhs, *, dx: float, dy: float, omega: float,
                 nslabs: int, inner_iters: int, interpret: bool = True):
    """One outer block-Jacobi iteration: all slabs smoothed in parallel."""
    ny, nx = p.shape
    assert nx % nslabs == 0, (nx, nslabs)
    bx = nx // nslabs
    assert bx % 2 == 0, "slab width must be even for checkerboard parity"

    kern = functools.partial(rb_sor_slab_kernel, nslabs=nslabs, bx=bx,
                             dx=dx, dy=dy, omega=omega,
                             inner_iters=inner_iters)
    slab = pl.BlockSpec((ny, bx), lambda i: (0, i))
    left = pl.BlockSpec((ny, bx), lambda i: (0, jnp.maximum(i - 1, 0)))
    right = pl.BlockSpec((ny, bx), lambda i: (0, jnp.minimum(i + 1, nslabs - 1)))
    return pl.pallas_call(
        kern,
        grid=(nslabs,),
        in_specs=[slab, left, right, slab],
        out_specs=slab,
        out_shape=jax.ShapeDtypeStruct((ny, nx), p.dtype),
        interpret=interpret,
    )(p, p, p, rhs)


# ---------------------------------------------------------------------------
# packed-checkerboard slab smoother
# ---------------------------------------------------------------------------
#
# Red and black points live as two (ny, nx//2) planes (layout documented in
# cfd/poisson.py: red[j, k] = p[j, 2k + j%2]).  Each program instance keeps
# BOTH planes of its slab VMEM-resident across ``inner_iters`` sweep pairs,
# touching only the points it updates — half the FLOPs and half the VMEM
# traffic of the masked full-grid sweep above.  The single-parity ghost
# columns a half-sweep needs are exactly the neighbour slab's packed edge
# columns (the entries on the unused row parity are never selected), so the
# same 3-index-map halo trick delivers half-width halos for free.  The
# half-sweep body itself is shared with the jnp backends (pure jnp, so it
# lowers inside the kernel unchanged) — one stencil implementation for
# packed reference, halo, and pallas.

def rb_sor_packed_slab_kernel(r_ref, rl_ref, rr_ref, b_ref, bl_ref, br_ref,
                              rhs_r_ref, rhs_b_ref, out_r_ref, out_b_ref, *,
                              nslabs: int, bxp: int, dx: float, dy: float,
                              omega: float, inner_iters: int):
    i = pl.program_id(0)
    red = r_ref[...]
    black = b_ref[...]
    rhs_r = rhs_r_ref[...]
    rhs_b = rhs_b_ref[...]
    ny = red.shape[0]
    dx2, dy2 = dx * dx, dy * dy
    inv_diag = 1.0 / (2.0 / dx2 + 2.0 / dy2)
    jj = jax.lax.broadcasted_iota(jnp.int32, (ny, bxp), 0)
    row_odd = (jj % 2 == 1)

    # Single-parity halo ghost columns, frozen for the call (block-Jacobi).
    # A red update's west/east neighbours are black, so its interior ghosts
    # are the neighbour's BLACK edge columns (and vice versa); at the domain
    # edges the ghost parity equals the update parity (Neumann inlet = own
    # first column, Dirichlet outlet = negated own last column).
    r_lg = jnp.where(i == 0, red[:, :1], bl_ref[...][:, -1:])
    r_rg = jnp.where(i == nslabs - 1, -red[:, -1:], br_ref[...][:, :1])
    b_lg = jnp.where(i == 0, black[:, :1], rl_ref[...][:, -1:])
    b_rg = jnp.where(i == nslabs - 1, -black[:, -1:], rr_ref[...][:, :1])

    from repro.cfd.poisson import packed_ghost_rows, packed_half_sweep

    def body(_, planes):
        red, black = planes
        red = packed_half_sweep(
            red, black, rhs_r, r_lg, r_rg, *packed_ghost_rows(red, black),
            row_odd, omega, dx2, dy2, inv_diag)
        black = packed_half_sweep(
            black, red, rhs_b, b_lg, b_rg, *packed_ghost_rows(black, red),
            ~row_odd, omega, dx2, dy2, inv_diag)
        return red, black

    out_r, out_b = jax.lax.fori_loop(0, inner_iters, body, (red, black))
    out_r_ref[...] = out_r
    out_b_ref[...] = out_b


def rb_sor_slabs_packed(red, black, rhs_r, rhs_b, *, dx: float, dy: float,
                        omega: float, nslabs: int, inner_iters: int,
                        interpret: bool = True):
    """One outer block-Jacobi iteration on packed planes, all slabs parallel.

    red/black/rhs_r/rhs_b: (ny, nx//2) planes from
    ``cfd.poisson.pack_checkerboard``.  The full-grid slab width must be
    even (so every slab starts on an even column and the packed layout
    parity is uniform across slabs)."""
    ny, w = red.shape
    assert w % nslabs == 0, (w, nslabs)
    bxp = w // nslabs           # packed slab width == full slab width // 2
    kern = functools.partial(rb_sor_packed_slab_kernel, nslabs=nslabs,
                             bxp=bxp, dx=dx, dy=dy, omega=omega,
                             inner_iters=inner_iters)
    slab = pl.BlockSpec((ny, bxp), lambda i: (0, i))
    left = pl.BlockSpec((ny, bxp), lambda i: (0, jnp.maximum(i - 1, 0)))
    right = pl.BlockSpec((ny, bxp),
                         lambda i: (0, jnp.minimum(i + 1, nslabs - 1)))
    plane = jax.ShapeDtypeStruct((ny, w), red.dtype)
    return pl.pallas_call(
        kern,
        grid=(nslabs,),
        in_specs=[slab, left, right, slab, left, right, slab, slab],
        out_specs=[slab, slab],
        out_shape=[plane, plane],
        interpret=interpret,
    )(red, red, red, black, black, black, rhs_r, rhs_b)
