"""Fused actuation-interval path: ``backend="fused"`` for the env hot loop.

The DRL environment integrates ``steps_per_action`` (50) solver dt's per
agent action; the per-step solver executes each dt as ~10 separate XLA
computations with full-grid pack/unpack round-trips of the pressure field
between them, so dispatch and memory traffic — not FLOPs — bound env-steps/s
(the paper's core claim, and ROADMAP open item 2).  This module fuses the
whole interval:

- the velocity fields and BOTH packed pressure parity planes are the scan
  carry — packed once before the interval, unpacked once after it, never
  round-tripped per dt;
- one fused per-dt body (:func:`fused_dt`) chains momentum -> packed SOR
  projection -> velocity correction, reusing ``solver._momentum`` and
  ``poisson.packed_half_sweep``/``packed_ghost_rows`` so there is exactly
  one momentum and one stencil implementation in the repo;
- on TPU the per-dt body runs as a Pallas megakernel
  (``kernel.fused_step``) that keeps every field VMEM-resident for the
  whole dt; elsewhere the same body lowers as one fused XLA scan step.

Tier selection (:func:`select_tier`) falls back to the reference scan —
warning once per grid shape, resettable via
``core.backend.reset_warning_caches`` — when the grid width is odd (no
checkerboard parity) or the fields exceed the TPU VMEM budget
(``REPRO_FUSED_VMEM_BUDGET`` bytes, default 16 MiB).
"""
from __future__ import annotations

import functools
import os
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.cfd import poisson, solver
from repro.cfd.grid import GridConfig
from repro.core import backend as backend_mod

# VMEM the megakernel may claim per core (TPU v5e has ~16 MiB; leave the
# default at the full budget — the estimate below already over-counts by
# including double-buffered outputs)
DEFAULT_VMEM_BUDGET = 16 * 2 ** 20
VMEM_BUDGET_ENV = "REPRO_FUSED_VMEM_BUDGET"

# grid shapes already warned about for the fused -> reference fallback
# (once per shape, resettable for test isolation)
_FALLBACK_WARNED = backend_mod.warn_once_cache()


def vmem_budget() -> int:
    return int(os.environ.get(VMEM_BUDGET_ENV, DEFAULT_VMEM_BUDGET))


def vmem_bytes(cfg: GridConfig) -> int:
    """f32 bytes the fused per-dt kernel keeps resident: u/v in+out, both
    pressure parity planes in+out, the packed rhs pair, and the closed-over
    geometry fields (6 u-shaped + 6 v-shaped + the inlet profile)."""
    nu = cfg.ny * (cfg.nx + 1)
    nv = (cfg.ny + 1) * cfg.nx
    plane = cfg.ny * (cfg.nx // 2)
    fields = 2 * nu + 2 * nv + 4 * plane + 2 * plane
    geom = 6 * nu + 6 * nv + cfg.ny
    return 4 * (fields + geom)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def select_tier(cfg: GridConfig) -> str:
    """Which realization serves ``backend="fused"`` on this grid/platform.

    "pallas"     TPU: the VMEM-resident per-dt megakernel under lax.scan
    "jnp"        everywhere else: the same fused per-dt body as one XLA
                 scan step (interval fusion and packed-plane carry intact —
                 Pallas only adds explicit VMEM residency on TPU)
    "reference"  fallback (warns once per grid shape): odd grid width, or
                 the fields exceed the TPU VMEM budget
    """
    ny, nx = cfg.ny, cfg.nx
    if nx % 2:
        if ("odd_nx", ny, nx) not in _FALLBACK_WARNED:
            _FALLBACK_WARNED.add(("odd_nx", ny, nx))
            warnings.warn(
                f"backend='fused' needs an even grid width for packed "
                f"checkerboard parity; grid (ny={ny}, nx={nx}) falls back "
                f"to the reference scan (this warning fires once per shape)",
                RuntimeWarning, stacklevel=3)
        return "reference"
    if _on_tpu():
        need, have = vmem_bytes(cfg), vmem_budget()
        if need > have:
            if ("vmem", ny, nx) not in _FALLBACK_WARNED:
                _FALLBACK_WARNED.add(("vmem", ny, nx))
                warnings.warn(
                    f"backend='fused' grid (ny={ny}, nx={nx}) needs "
                    f"~{need / 2**20:.1f} MiB resident fields, over the "
                    f"{have / 2**20:.1f} MiB VMEM budget "
                    f"(${VMEM_BUDGET_ENV}); falling back to the reference "
                    f"scan (this warning fires once per shape)",
                    RuntimeWarning, stacklevel=3)
            return "reference"
        return "pallas"
    return "jnp"


# ---------------------------------------------------------------------------
# the fused per-dt body (shared by the jnp tier and the Pallas kernel)
# ---------------------------------------------------------------------------

def packed_projection_planes(cfg: GridConfig, red, black, rhs_r, rhs_b):
    """The pressure solve of one dt entirely on packed planes: the same
    omega schedule as ``poisson.solve`` (``polish`` trailing sweeps run
    unrelaxed), built from the shared ``packed_sweep_pair`` stencil."""
    iters = cfg.poisson_iters
    n_polish = min(10, iters // 2)
    n_sor = iters - n_polish
    omega = float(cfg.poisson_omega)
    row_odd = (jnp.arange(cfg.ny) % 2 == 1)[:, None]

    def body(i, planes):
        om = jnp.where(i < n_sor, omega, 1.0)
        return poisson.packed_sweep_pair(*planes, rhs_r, rhs_b, om,
                                         dx=cfg.dx, dy=cfg.dy,
                                         row_odd=row_odd)

    return jax.lax.fori_loop(0, iters, body, (red, black))


def fused_dt(cfg: GridConfig, ga: solver.GeomArrays, u, v, red, black,
             jet_vel, re, act_mode):
    """One dt with the pressure held packed: momentum (via the solver's own
    ``_momentum`` — one implementation) -> packed SOR projection ->
    velocity correction.  Returns ``(u, v, red, black, cd, cl)``."""
    dt = cfg.dt
    u_bc, v_bc, fx, fy = solver._momentum(cfg, ga, u, v, jet_vel, re,
                                          act_mode)
    rhs = solver.divergence(u_bc, v_bc, cfg) / dt
    rhs_r, rhs_b = poisson.pack_checkerboard(rhs)
    red, black = packed_projection_planes(cfg, red, black, rhs_r, rhs_b)
    # the projection gradient needs full-grid adjacency; the planes stay the
    # carry — this unpack is a reshape/select XLA fuses into the correction
    p = poisson.unpack_checkerboard(red, black)
    u_new = u_bc.at[:, 1:-1].add(-dt * (p[:, 1:] - p[:, :-1]) / cfg.dx)
    v_new = v_bc.at[1:-1, :].add(-dt * (p[1:, :] - p[:-1, :]) / cfg.dy)
    u_new = solver._apply_bc_u(u_new, ga.inlet_u)
    v_new = solver._apply_bc_v(v_new)
    cd = fx / (0.5 * cfg.u_mean ** 2)
    cl = fy / (0.5 * cfg.u_mean ** 2)
    return u_new, v_new, red, black, cd, cl


# ---------------------------------------------------------------------------
# the interval
# ---------------------------------------------------------------------------

def fused_interval(cfg: GridConfig, geom_arrays, state: solver.FlowState,
                   jet_vel, n_steps: int, *, re=None, act_mode=None,
                   tier: Optional[str] = None):
    """One actuation interval with fields resident across every dt.

    Drop-in for the ``backend="fused"`` arm of ``solver.step_interval``:
    returns ``(FlowState, StepOutputs)`` with per-dt ``(n_steps,)`` force
    coefficients.  ``tier`` forces a realization ("pallas" | "jnp" |
    "reference") — tests pin pallas-vs-jnp parity through it; the default
    asks :func:`select_tier`.
    """
    ga = solver.GeomArrays(*geom_arrays)
    if re is None:
        re = cfg.re
    # act_mode=0.0 is numerically exact vs the static jets-only branch
    # ((1-0)*jet + 0*rot multiplies through exactly in f32), and keeps the
    # per-dt body a single signature for the Pallas kernel
    if act_mode is None:
        act_mode = jnp.float32(0.0)
    tier = tier or select_tier(cfg)
    if tier == "reference":
        return solver.step_interval(cfg, geom_arrays, state, jet_vel,
                                    n_steps, re=re, act_mode=act_mode,
                                    backend="reference")

    if tier == "pallas":
        from repro.kernels.actuation import kernel as kernel_mod
        dt_fn = functools.partial(kernel_mod.fused_step, cfg, ga,
                                  interpret=not _on_tpu())
    else:
        dt_fn = functools.partial(fused_dt, cfg, ga)

    red, black = poisson.pack_checkerboard(state.p)

    def body(carry, _):
        u, v, red, black = carry
        u, v, red, black, cd, cl = dt_fn(u, v, red, black, jet_vel, re,
                                         act_mode)
        return (u, v, red, black), solver.StepOutputs(cd=cd, cl=cl)

    (u, v, red, black), outs = jax.lax.scan(
        body, (state.u, state.v, red, black), None, length=n_steps)
    return solver.FlowState(u, v, poisson.unpack_checkerboard(red, black)), \
        outs
