"""Pallas megakernel: one fused solver dt with every field VMEM-resident.

One ``pallas_call`` per dt advances momentum (advect-diffuse + penalization
+ fused BC/outlet-mass-correction), the packed red-black SOR projection, and
the velocity correction without the fields ever leaving VMEM — ``u``, ``v``,
both packed pressure parity planes, and the closed-over geometry are kernel
operands held on-chip for the whole dt (~50 SOR sweep pairs included).
``solver.step_interval(backend="fused")`` scans this kernel over the
actuation interval, so across the 50-dt interval the only HBM traffic is
the scan carry hand-off between consecutive kernel launches.

The body is NOT re-implemented here: the kernel calls the same
``ops.fused_dt`` the jnp tier lowers (which itself calls
``solver._momentum`` and the ``poisson.packed_half_sweep`` stencil) — pure
jnp, so it traces inside the kernel unchanged.  One momentum and one
stencil implementation serve reference, packed, halo, pallas-Poisson, and
this megakernel.

On non-TPU hosts the kernel runs in interpret mode for correctness tests
(tests/test_fused_interval.py gates pallas-vs-jnp parity); the production
CPU path is the jnp tier (ops.select_tier), which carries the same fusion
structure without Pallas.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.cfd.grid import GridConfig

# GeomArrays field order (repro.cfd.solver.GeomArrays._fields) — the kernel
# takes them as individual refs so every mask/target lives in VMEM too
_N_GEOM = 11


def _fused_dt_kernel(*refs, cfg: GridConfig):
    from repro.cfd.solver import GeomArrays
    from repro.kernels.actuation.ops import fused_dt

    (u_ref, v_ref, red_ref, black_ref), rest = refs[:4], refs[4:]
    geom_refs, rest = rest[:_N_GEOM], rest[_N_GEOM:]
    (jet_ref, re_ref, mode_ref), outs = rest[:3], rest[3:]
    u_out, v_out, red_out, black_out, cd_out, cl_out = outs

    ga = GeomArrays(*(r[...] for r in geom_refs))
    u2, v2, red2, black2, cd, cl = fused_dt(
        cfg, ga, u_ref[...], v_ref[...], red_ref[...], black_ref[...],
        jet_ref[0, 0], re_ref[0, 0], mode_ref[0, 0])
    u_out[...] = u2
    v_out[...] = v2
    red_out[...] = red2
    black_out[...] = black2
    cd_out[...] = jnp.reshape(cd, (1, 1))
    cl_out[...] = jnp.reshape(cl, (1, 1))


def fused_step(cfg: GridConfig, ga, u, v, red, black, jet_vel, re, act_mode,
               *, interpret: bool = True):
    """One dt through the megakernel.  Mirrors ``ops.fused_dt``'s signature
    and return ``(u, v, red, black, cd, cl)``; scalars ride as (1, 1)
    operands so the whole dt is a single launch."""
    f32 = jnp.float32
    scalar = lambda x: jnp.reshape(jnp.asarray(x, f32), (1, 1))
    # the megakernel serves the scalar-actuation path only (step_interval
    # falls back to the reference backend for per-body vector jets), so the
    # per-body rotation targets / ownership masks never ride as kernel refs
    ga = ga._replace(rotb_u=None, rotb_v=None, own_u=None, own_v=None)
    geom = [g for g in ga if g is not None]
    kern = functools.partial(_fused_dt_kernel, cfg=cfg)
    out_shape = [
        jax.ShapeDtypeStruct(u.shape, u.dtype),
        jax.ShapeDtypeStruct(v.shape, v.dtype),
        jax.ShapeDtypeStruct(red.shape, red.dtype),
        jax.ShapeDtypeStruct(black.shape, black.dtype),
        jax.ShapeDtypeStruct((1, 1), f32),
        jax.ShapeDtypeStruct((1, 1), f32),
    ]
    outs = pl.pallas_call(kern, out_shape=out_shape, interpret=interpret)(
        u, v, red, black, *geom,
        scalar(jet_vel), scalar(re), scalar(act_mode))
    u2, v2, red2, black2, cd, cl = outs
    return u2, v2, red2, black2, cd[0, 0], cl[0, 0]
