"""Optimizers in raw JAX: AdamW and Adafactor (+ grad clip, LR schedules).

Adafactor (factored second moment, no first moment, bf16-friendly) is the
HBM-fit policy for the 123B/405B/671B configs (DESIGN.md §8).  States are
plain pytrees so they shard with the same rules as the parameters they mirror.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    """Norm in fp32; scaling in the native dtype (a tree-wide fp32 upcast
    would materialize a full-precision copy of every gradient — 10 GiB/device
    at 671B scale)."""
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), grads), g


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn


def constant_schedule(lr: float):
    return lambda step: jnp.float32(lr)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr_fn, *, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
          max_grad_norm=0.0) -> Optimizer:
    if not callable(lr_fn):
        lr_fn = constant_schedule(lr_fn)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        if max_grad_norm:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr = lr_fn(step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         state["v"], grads)
        mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), v)

        def upd(p, m_, v_):
            du = m_ / (jnp.sqrt(v_) + eps)
            if weight_decay:
                du = du + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * du).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mh, vh)
        return new_params, {"m": m, "v": v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), simplified: factored v, no first moment
# ---------------------------------------------------------------------------

def adafactor(lr_fn, *, decay=0.8, eps=1e-30, clip_threshold=1.0,
              max_grad_norm=0.0, min_dim_size_to_factor=128,
              update_dtype=jnp.float32) -> Optimizer:
    """``update_dtype=bfloat16`` computes the update direction u in bf16
    (factored stats stay fp32).  Used by the 100B+ configs: the fp32 u would
    be a params-sized fp32 transient, and XLA-CPU's loop widening hoists such
    converts to full-stack buffers (see EXPERIMENTS.md §Dry-run notes)."""
    if not callable(lr_fn):
        lr_fn = constant_schedule(lr_fn)

    def _factored(shape) -> bool:
        return (len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor
                and shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def st(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(st, params,
                            is_leaf=lambda x: isinstance(x, jnp.ndarray))

    def update(grads, state, params, step):
        if max_grad_norm:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr = lr_fn(step)

        def upd_one(p, g, s):
            # barrier: stop XLA from canonicalizing convert(slice(g)) into
            # slice(convert(g)) and hoisting a full-stack fp32 copy out of
            # the chunked-update loop (measured 2x3.2 GiB on deepseek-v3)
            p, g = jax.lax.optimization_barrier((p, g))
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)
                new_s = {"vr": vr, "vc": vc}
                if update_dtype == jnp.float32:
                    vhat = (vr[..., None] * vc[..., None, :]
                            / denom[..., None])
                    u = gf * jax.lax.rsqrt(vhat + eps)
                else:
                    # bf16 update direction, factored rsqrt applied as two
                    # broadcasts — no params-sized fp32 transient
                    inv_r = jax.lax.rsqrt(vr / denom + eps).astype(
                        update_dtype)
                    inv_c = jax.lax.rsqrt(vc + eps).astype(update_dtype)
                    u = (g.astype(update_dtype) * inv_r[..., None]
                         * inv_c[..., None, :])
            else:
                vhat = beta * s["v"] + (1 - beta) * g2
                new_s = {"v": vhat}
                u = gf * jax.lax.rsqrt(vhat + eps)
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u.astype(jnp.float32)))
                           + 1e-30)
            scale = (lr / jnp.maximum(1.0, rms / clip_threshold))
            return (p - (u * scale.astype(u.dtype)).astype(p.dtype)
                    ).astype(p.dtype), new_s

        def upd(p, g, s):
            # layer-stacked params: chunk the fp32 update over the leading
            # dim (lax.map) so transients are 1-layer sized, not L-layer
            if p.ndim >= 3 and p.shape[0] > 4 and "vr" in s \
                    and s["vr"].shape[:1] == p.shape[:1]:
                new_p, new_s = jax.lax.map(
                    lambda args: upd_one(*args), (p, g, s))
                return new_p, new_s
            return upd_one(p, g, s)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state)
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_state = tdef.unflatten([o[1] for o in out])
        return new_params, new_state

    return Optimizer(init, update)


def make_optimizer(name: str, lr_fn, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
