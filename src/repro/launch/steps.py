"""Step builders: train_step / prefill_step / serve_step with mesh shardings.

Shared by launch/train.py (real execution) and launch/dryrun.py (lowering on
the production mesh).  Every (architecture x input shape) lowers through one
of these three entry points:

  train   -> train_step(params, opt_state, step, batch)
  prefill -> prefill_step(params, tokens[, frontend]) -> (logits, cache)
  decode  -> serve_step(params, cache, token, pos)    -> (logits, cache)
"""
from __future__ import annotations

import functools
import os
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.core import backend as backend_mod
from repro.models import act_sharding
from repro.models import frontend as fe_mod
from repro.models import model as M
from repro.models.layers import dtype_of
from repro.models.sharding import (axis_size, batch_spec, dp_axes,
                                   kv_cache_spec, param_specs, spec_for,
                                   state_spec)
from repro.optim.optimizers import make_optimizer

_LAUNCH_DIR = os.path.dirname(__file__)


# ---------------------------------------------------------------------------
# abstract state + shardings
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))


def fsdp_axes_for(cfg: ModelConfig, mesh: Optional[Mesh]):
    """100B+ DENSE archs extend FSDP across the pod axis on the multi-pod
    mesh (llama-405b: 65->37 GiB, mistral-123b: 22->15 GiB).  MoE archs keep
    params replicated across pods: the shard_map expert layers re-gather
    weights per layer and the extra pod-gather transients cost more than the
    parameter savings (deepseek measured 39->49 GiB — refuted).  Everything
    else follows the paper's keep-the-outer-axis-embarrassing principle."""
    if (mesh is not None and "pod" in mesh.shape
            and cfg.optimizer == "adafactor" and cfg.moe is None):
        return ("pod", "data")
    return ("data",)


def make_opt(cfg: ModelConfig):
    kw = {}
    if cfg.optimizer == "adafactor":
        # 100B+ archs: bf16 update direction (see optimizers.adafactor)
        kw["update_dtype"] = jnp.bfloat16
    return make_optimizer(cfg.optimizer, 1e-4, max_grad_norm=1.0, **kw)


def abstract_opt_state(cfg: ModelConfig, params_shape):
    opt = make_opt(cfg)
    return jax.eval_shape(opt.init, params_shape)


def _paths_to_specs(mesh: Mesh, shape_tree, fsdp_axes=("data",)):
    """Flattened {path: spec} for a params shape tree."""
    specs = param_specs(mesh, shape_tree, fsdp_axes)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    out = {}
    for kp, spec in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        out[key] = spec
    return out


def opt_state_specs(mesh: Mesh, params_shape, opt_shape,
                    fsdp_axes=("data",)):
    """Optimizer-state specs derived from the matching parameter's spec.

    adamw m/v mirror the param; adafactor vr drops the last dim, vc drops the
    second-to-last."""
    pspecs = _paths_to_specs(mesh, params_shape, fsdp_axes)

    def spec_for_leaf(kp, leaf):
        parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in kp]
        # adamw: {"m": <params tree>, "v": <params tree>} — stat key at ROOT
        if parts[0] in ("m", "v"):
            base = pspecs.get("/".join(parts[1:]), P())
            return base if len(base) == leaf.ndim else P()
        # adafactor: <params tree> -> {"vr": ..., "vc": ...} or {"v": ...}
        stat = parts[-1]
        base = pspecs.get("/".join(parts[:-1]), P())
        if stat == "v" and len(base) == leaf.ndim:
            return base
        if stat == "vr" and len(base) >= 1:       # param spec minus last dim
            return P(*base[:-1]) if len(base) - 1 == leaf.ndim else P()
        if stat == "vc" and len(base) >= 2:       # minus second-to-last dim
            spec = tuple(base[:-2]) + (base[-1],)
            return P(*spec) if len(spec) == leaf.ndim else P()
        return P()

    return jax.tree_util.tree_map_with_path(spec_for_leaf, opt_shape)


def _sh(mesh: Mesh, tree_of_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# data input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def batch_structs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    dp = dp_axes(mesh)
    bspec = batch_spec(mesh, B)
    sds = lambda shp, dt, spec: jax.ShapeDtypeStruct(
        shp, dt, sharding=NamedSharding(mesh, spec))
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = sds((B, S), jnp.int32, P(bspec[0], None))
        out["labels"] = sds((B, S), jnp.int32, P(bspec[0], None))
        if cfg.frontend:
            t = fe_mod.num_frontend_tokens(cfg, S)
            out["frontend_embeds"] = sds((B, t, fe_mod.frontend_dim(cfg)),
                                         jnp.float32, P(bspec[0], None, None))
    elif shape.kind == "prefill":
        out["tokens"] = sds((B, S), jnp.int32, P(bspec[0], None))
        if cfg.frontend:
            t = fe_mod.num_frontend_tokens(cfg, S)
            out["frontend_embeds"] = sds((B, t, fe_mod.frontend_dim(cfg)),
                                         jnp.float32, P(bspec[0], None, None))
    else:  # decode
        out["token"] = sds((B, 1), jnp.int32, P(bspec[0], None))
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, seq: int):
    """PartitionSpec tree matching M.init_cache's structure."""
    from repro.models.sharding import cache_leaf_spec
    cache_shape = jax.eval_shape(lambda: M.init_cache(cfg, batch, seq))

    def leaf_spec(kp, leaf):
        key = str(getattr(kp[-1], "key", kp[-1]))
        return cache_leaf_spec(mesh, key, leaf.shape)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape), \
        cache_shape


def cache_structs(cfg: ModelConfig, mesh: Mesh, batch: int, seq: int):
    specs, shapes = cache_specs(cfg, mesh, batch, seq)
    return jax.tree.map(
        lambda sds, sp: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, sp)),
        shapes, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                    backend: Optional[str] = None,
                    use_pallas: Optional[bool] = None) -> Callable:
    backend = backend_mod.resolve_backend(backend, use_pallas,
                                          skip_dirs=(_LAUNCH_DIR,))
    opt = make_opt(cfg)
    accum_dtype = jnp.float32 if cfg.optimizer == "adamw" else jnp.bfloat16

    # gradients must carry the parameter sharding explicitly: the backward
    # dots (e.g. one_hot^T @ dh for the embedding) otherwise produce
    # full-size replicated outputs inside the accumulation loop (measured:
    # full fp32 (V, D) embed grads on deepseek-v3)
    if mesh is not None:
        gspecs = param_specs(mesh, abstract_params(cfg),
                             fsdp_axes_for(cfg, mesh))

        def constrain_grads(g):
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, s)), g, gspecs)
    else:
        def constrain_grads(g):
            return g

    def loss_fn(params, mb):
        return M.lm_loss(cfg, params, mb, backend=backend)

    def train_step(params, opt_state, step, batch):
        # clamp microbatches so each microbatch still divides the dp axes
        # (e.g. 16 microbatches of batch 256 breaks on the 32-way multi-pod
        # dp axis: B_mb=16 % 32 != 0 would silently defeat the MoE shard_map)
        B = jax.tree.leaves(batch)[0].shape[0]
        n_dp = axis_size(mesh, dp_axes(mesh)) if mesh is not None else 1
        Mmb = cfg.train_microbatches
        while Mmb > 1 and (B % Mmb or (B // Mmb) % n_dp):
            Mmb //= 2
        with act_sharding.activation_mesh(mesh):
            if Mmb == 1:
                (loss, aux), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
                grads = constrain_grads(grads)
            else:
                mbs = jax.tree.map(
                    lambda x: x.reshape((Mmb, x.shape[0] // Mmb)
                                        + x.shape[1:]), batch)
                g0 = constrain_grads(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, accum_dtype), params))

                def acc(carry, mb):
                    g_acc, l_acc = carry
                    (l, _), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb)
                    g = constrain_grads(g)
                    g_acc = constrain_grads(jax.tree.map(
                        lambda a, b: a + b.astype(accum_dtype), g_acc, g))
                    return (g_acc, l_acc + l), None

                (grads, loss), _ = jax.lax.scan(acc, (g0, jnp.float32(0.0)),
                                                mbs)
                grads = jax.tree.map(lambda g: g / Mmb, grads)
                loss = loss / Mmb
                aux = {"loss": loss}
            params, opt_state = opt.update(grads, opt_state, params, step)
        metrics = {"loss": loss}
        return params, opt_state, step + 1, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                      backend: Optional[str] = None,
                      use_pallas: Optional[bool] = None) -> Callable:
    # prefill always runs the reference kernels (flash is train/causal-only);
    # resolve anyway so deprecated/conflicting selections fail loudly here too
    backend_mod.resolve_backend(backend, use_pallas, skip_dirs=(_LAUNCH_DIR,))

    def prefill_step(params, tokens, frontend_embeds=None):
        with act_sharding.activation_mesh(mesh):
            logits, cache = M.prefill(cfg, params, tokens,
                                      frontend_embeds=frontend_embeds)
        return logits, cache
    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh: Optional[Mesh] = None) -> Callable:
    def serve_step(params, cache, token, pos):
        with act_sharding.activation_mesh(mesh):
            logits, cache = M.decode_step(cfg, params, cache, token, pos)
        return logits, cache
    return serve_step


# ---------------------------------------------------------------------------
# one-stop lowering assembly for (arch x shape x mesh)
# ---------------------------------------------------------------------------

def lowering_for(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                 donate: bool = True):
    """Returns (jitted_fn, example_args) ready for .lower(*args)."""
    params_shape = abstract_params(cfg)
    pspecs = param_specs(mesh, params_shape, fsdp_axes_for(cfg, mesh))
    psh = _sh(mesh, pspecs)
    params_structs = jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                             sharding=sh),
        params_shape, psh,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    data = batch_structs(cfg, shape, mesh)

    if shape.kind == "train":
        opt_shape = abstract_opt_state(cfg, params_shape)
        ospecs = opt_state_specs(mesh, params_shape, opt_shape,
                                 fsdp_axes_for(cfg, mesh))
        osh = _sh(mesh, ospecs)
        opt_structs = jax.tree.map(
            lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                                 sharding=sh),
            opt_shape, osh,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        fn = make_train_step(cfg, mesh)
        step0 = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(
            fn, donate_argnums=(0, 1) if donate else (),
            out_shardings=(psh, osh, None, None))
        args = (params_structs, opt_structs, step0, data)
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg, mesh)
        cspecs, _ = cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        csh = _sh(mesh, cspecs)
        jitted = jax.jit(fn, out_shardings=(None, csh))
        args = ((params_structs, data["tokens"], data["frontend_embeds"])
                if "frontend_embeds" in data
                else (params_structs, data["tokens"]))
    else:
        fn = make_serve_step(cfg, mesh)
        cache_in = cache_structs(cfg, mesh, shape.global_batch,
                                 shape.seq_len)
        cspecs, _ = cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        csh = _sh(mesh, cspecs)
        jitted = jax.jit(fn, donate_argnums=(1,) if donate else (),
                         out_shardings=(None, csh))
        args = (params_structs, cache_in, data["token"], data["pos"])
    return jitted, args
