"""Multi-process fleet bootstrap: span the engine's "data" axis over hosts.

One coordinator/runner shape (drlfoam's ``LocalBuffer``/``SlurmBuffer``
split, ported onto ``jax.distributed``): every runner process calls
:func:`initialize_fleet` before touching any jax device state, the
coordinator (process 0) doubles as the jax distributed-service host, and
``launch/mesh.mesh_for_plan`` then builds one global mesh whose "data" axis
crosses process boundaries while the "model" (halo) axis stays intra-host —
the paper's keep-the-outer-axis-embarrassing principle at fleet scale.

Two launch paths share this module:

* ``tools/launch_fleet.py`` — the single-command local launcher; forks N
  runner processes on one box with a **pinned**
  ``XLA_FLAGS=--xla_force_host_platform_device_count`` (see below) and
  wires the ``REPRO_*`` env vars.
* a cluster scheduler (SLURM sketch in the README) — each task exports the
  same env vars and calls the same entry point.

Bitwise-parity contract (tests/test_fleet.py): the forced host device
count must be **identical in every runner and at every fleet size** (the
plan's ``n_total``, NOT ``n_total // num_processes``).  XLA's CPU codegen
differs between forced device counts even for single-device programs, so a
1-process run with 4 local devices and a 2-process run with 2 local
devices each would disagree in the last ulp of the PPO update.  With the
count pinned, the fleet mesh simply uses the first
``n_total // num_processes`` devices of each process and training is
bitwise-identical across fleet sizes.

Heartbeats: runners touch a per-process JSON file each episode;
``tools/launch_fleet.py`` watches both child liveness (the SIGKILL fast
path) and heartbeat age (the hang path) and elastically shrinks + resumes
via the PR-4 checkpoint layer when a runner dies.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

# env vars the launcher (or a cluster scheduler) exports for every runner
ENV_COORDINATOR = "REPRO_COORDINATOR"      # host:port of process 0
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"
ENV_FLEET = "REPRO_FLEET"                  # "1": fleet engine mode, any size
ENV_HEARTBEAT_DIR = "REPRO_HEARTBEAT_DIR"

_initialized = False


@dataclass(frozen=True)
class FleetInfo:
    """The resolved fleet topology of THIS process."""
    num_processes: int
    process_id: int
    coordinator: Optional[str] = None

    @property
    def is_coordinator(self) -> bool:
        return self.process_id == 0


def fleet_env(coordinator: str, num_processes: int, process_id: int,
              n_total_devices: int, heartbeat_dir: Optional[str] = None,
              base: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """The runner-process environment the launcher exports.

    Pins ``--xla_force_host_platform_device_count`` to the PLAN's total
    device count on every runner regardless of fleet size (the bitwise
    contract in the module docstring)."""
    env = dict(os.environ if base is None else base)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append(f"--xla_force_host_platform_device_count={n_total_devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    env["JAX_PLATFORMS"] = env.get("JAX_PLATFORMS", "cpu")
    env[ENV_COORDINATOR] = coordinator
    env[ENV_NUM_PROCESSES] = str(num_processes)
    env[ENV_PROCESS_ID] = str(process_id)
    env[ENV_FLEET] = "1"
    if heartbeat_dir:
        env[ENV_HEARTBEAT_DIR] = heartbeat_dir
    return env


def initialize_fleet(coordinator_addr: Optional[str] = None,
                     num_processes: Optional[int] = None,
                     process_id: Optional[int] = None) -> FleetInfo:
    """Bootstrap this process into the fleet (idempotent).

    Arguments default to the ``REPRO_*`` env vars the launcher exports; a
    bare call outside any fleet is a harmless single-process no-op.  With
    ``num_processes > 1`` this selects the gloo CPU collectives
    implementation (cross-process computations are unimplemented on the
    default XLA CPU collectives) and calls ``jax.distributed.initialize``
    — so it MUST run before anything initializes a jax backend.
    """
    global _initialized
    import jax

    coordinator_addr = coordinator_addr or os.environ.get(ENV_COORDINATOR)
    if num_processes is None:
        num_processes = int(os.environ.get(ENV_NUM_PROCESSES, "1"))
    if process_id is None:
        process_id = int(os.environ.get(ENV_PROCESS_ID, "0"))
    if num_processes <= 1:
        return FleetInfo(1, 0, coordinator_addr)
    if _initialized:
        return FleetInfo(num_processes, process_id, coordinator_addr)
    if coordinator_addr is None:
        raise ValueError(
            f"initialize_fleet(num_processes={num_processes}) needs a "
            f"coordinator address (pass coordinator_addr= or export "
            f"{ENV_COORDINATOR}=host:port)")
    # gloo BEFORE backend init: XLA's default CPU collectives cannot run
    # cross-process computations at all
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=coordinator_addr,
                               num_processes=num_processes,
                               process_id=process_id)
    _initialized = True
    return FleetInfo(num_processes, process_id, coordinator_addr)


def fleet_info() -> FleetInfo:
    """The live topology as jax sees it (after :func:`initialize_fleet`)."""
    import jax
    return FleetInfo(jax.process_count(), jax.process_index(),
                     os.environ.get(ENV_COORDINATOR))


def fleet_active() -> bool:
    """True when the engine should run its fleet path — either this process
    is part of a real multi-process fleet, or the launcher pinned
    ``REPRO_FLEET=1`` (single-process fleets keep the same code path so a
    1-process run is bitwise-comparable to an N-process one)."""
    import jax
    return jax.process_count() > 1 or os.environ.get(ENV_FLEET) == "1"


def span_devices(n_total: int, devices: Optional[List] = None) -> List:
    """The global device list for a process-spanning mesh.

    Takes ``n_total // num_processes`` devices from EVERY process (sorted
    by process then local id) so consecutive mesh rows map to one host and
    the "data" axis tiles hosts — each host keeps any "model"/halo axis
    internal.  With one process this degrades to ``devices[:n_total]``
    (the classic ``mesh_for_plan`` behaviour)."""
    import jax
    devices = list(jax.devices()) if devices is None else list(devices)
    procs = sorted({d.process_index for d in devices})
    if n_total % len(procs):
        raise ValueError(
            f"plan needs n_total = {n_total} devices but the fleet has "
            f"{len(procs)} processes; n_total must divide evenly "
            f"(got {n_total} % {len(procs)} != 0)")
    per = n_total // len(procs)
    out: List = []
    for p in procs:
        local = sorted((d for d in devices if d.process_index == p),
                       key=lambda d: d.id)
        if len(local) < per:
            raise ValueError(
                f"process {p} has {len(local)} devices but the plan needs "
                f"{per} per process; force more with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n_total} "
                f"(pinned to n_total on EVERY runner — see "
                f"repro.launch.distributed)")
        out.extend(local[:per])
    return out


# ---------------------------------------------------------------------------
# heartbeats — the liveness signal behind elastic shrink
# ---------------------------------------------------------------------------

def heartbeat_path(root: str, process_id: int) -> Path:
    return Path(root) / f"hb_{process_id:03d}.json"


def write_heartbeat(root: str, process_id: int, episode: int) -> None:
    """Atomically (tmp + replace) stamp this runner's liveness file."""
    path = heartbeat_path(root, process_id)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps({"process": process_id, "episode": episode,
                               "pid": os.getpid(), "time": time.time()}))
    os.replace(tmp, path)


def read_heartbeats(root: str) -> Dict[int, Dict]:
    out = {}
    for path in sorted(Path(root).glob("hb_*.json")):
        try:
            rec = json.loads(path.read_text())
            # supervisor-side observation of the write (its own clock) —
            # the skew-tolerant half of the staleness check below
            rec["_mtime"] = path.stat().st_mtime
            out[int(rec["process"])] = rec
        except (OSError, ValueError, KeyError):
            continue          # mid-replace or garbage: treat as absent
    return out


def stale_processes(root: str, num_processes: int, timeout: float,
                    now: Optional[float] = None) -> List[int]:
    """Process ids whose heartbeat is older than ``timeout`` seconds (a
    runner that never heartbeated at all only counts once the fleet has
    been up longer than the timeout — compile time is not a hang).

    Clock-skew tolerant: a beat's age is measured BOTH by the wall time the
    runner stamped into the payload and by the file mtime the supervisor's
    filesystem observed, and the beat is stale only when the *smaller* of
    the two exceeds the timeout.  A runner whose clock lags (payload looks
    ancient) is saved by a fresh mtime; a supervisor whose clock lags
    (mtime looks ancient, e.g. across NFS) is saved by a fresh payload — a
    truly hung runner ages on both."""
    now = time.time() if now is None else now
    beats = read_heartbeats(root)

    def age(rec) -> float:
        payload_age = now - rec["time"]
        mtime_age = now - rec.get("_mtime", rec["time"])
        return min(payload_age, mtime_age)

    return [p for p in range(num_processes)
            if p in beats and age(beats[p]) > timeout]


class HeartbeatReporter:
    """An ``on_episode``-shaped hook that stamps heartbeats; inert when the
    launcher exported no heartbeat dir."""

    def __init__(self, process_id: int, root: Optional[str] = None):
        self.root = root or os.environ.get(ENV_HEARTBEAT_DIR)
        self.process_id = process_id
        self.episodes = 0

    def __call__(self, *_args, **_kw) -> None:
        self.episodes += 1
        if self.root:
            write_heartbeat(self.root, self.process_id, self.episodes)
