"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (required for the dry-run's forced 512-device host platform).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the pod axis is
    pure data parallelism (params replicated across pods; only gradient
    all-reduce crosses pods, per the paper's keep-the-outer-axis-embarrassing
    principle, DESIGN.md §2)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many devices exist (tests / examples)."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
