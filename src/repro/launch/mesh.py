"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (required for the dry-run's forced 512-device host platform).
"""
from __future__ import annotations

import inspect

import jax


def make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and the AxisType
    enum) only exist in newer releases — pass them when available."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if (axis_type is not None
            and "axis_types" in inspect.signature(jax.make_mesh).parameters):
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


_make_mesh = make_mesh


def make_abstract_mesh(shape, axes):
    """Device-less mesh (spec computation only), across jax versions."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.sharding.AbstractMesh(
            tuple(shape), tuple(axes), axis_types=(axis_type.Auto,) * len(axes))
    # jax 0.4.x: AbstractMesh(((name, size), ...))
    return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the pod axis is
    pure data parallelism (params replicated across pods; only gradient
    all-reduce crosses pods, per the paper's keep-the-outer-axis-embarrassing
    principle, DESIGN.md §2)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many devices exist (tests / examples)."""
    return _make_mesh((n_data, n_model), ("data", "model"))


def mesh_for_plan(plan, devices=None):
    """The executable form of a ``core.plan.ParallelPlan``: a ("data",
    "model") mesh shaped (n_envs, n_ranks) over the first ``n_total``
    devices.  Unlike ``jax.make_mesh`` this tolerates a plan smaller than
    the host (the remaining devices simply idle — the plan's utilization
    already accounts for them)."""
    import numpy as np

    devices = list(jax.devices()) if devices is None else list(devices)
    n_envs, n_ranks = plan.mesh_shape if hasattr(plan, "mesh_shape") \
        else tuple(plan)
    n = n_envs * n_ranks
    if n > len(devices):
        raise ValueError(
            f"plan needs n_envs * n_ranks = {n} devices but this host has "
            f"{len(devices)}; shrink the plan or force more host devices "
            f"(XLA_FLAGS=--xla_force_host_platform_device_count={n})")
    arr = np.asarray(devices[:n], dtype=object).reshape(n_envs, n_ranks)
    return jax.sharding.Mesh(arr, ("data", "model"))
