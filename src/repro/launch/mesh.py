"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (required for the dry-run's forced 512-device host platform).
"""
from __future__ import annotations

import inspect

import jax


def make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and the AxisType
    enum) only exist in newer releases — pass them when available."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if (axis_type is not None
            and "axis_types" in inspect.signature(jax.make_mesh).parameters):
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


_make_mesh = make_mesh


def make_abstract_mesh(shape, axes):
    """Device-less mesh (spec computation only), across jax versions."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.sharding.AbstractMesh(
            tuple(shape), tuple(axes), axis_types=(axis_type.Auto,) * len(axes))
    # jax 0.4.x: AbstractMesh(((name, size), ...))
    return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the pod axis is
    pure data parallelism (params replicated across pods; only gradient
    all-reduce crosses pods, per the paper's keep-the-outer-axis-embarrassing
    principle, DESIGN.md §2)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many devices exist (tests / examples)."""
    return _make_mesh((n_data, n_model), ("data", "model"))
