"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (required for the dry-run's forced 512-device host platform).
"""
from __future__ import annotations

import inspect

import jax


def make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and the AxisType
    enum) only exist in newer releases — pass them when available."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if (axis_type is not None
            and "axis_types" in inspect.signature(jax.make_mesh).parameters):
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


_make_mesh = make_mesh


def make_abstract_mesh(shape, axes):
    """Device-less mesh (spec computation only), across jax versions."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.sharding.AbstractMesh(
            tuple(shape), tuple(axes), axis_types=(axis_type.Auto,) * len(axes))
    # jax 0.4.x: AbstractMesh(((name, size), ...))
    return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the pod axis is
    pure data parallelism (params replicated across pods; only gradient
    all-reduce crosses pods, per the paper's keep-the-outer-axis-embarrassing
    principle; see README "Choosing a parallel plan")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many devices exist (tests / examples)."""
    return _make_mesh((n_data, n_model), ("data", "model"))


def mesh_for_plan(plan, devices=None, *, span_processes=None):
    """The executable form of a ``core.plan.ParallelPlan``: a ("data",
    "model") mesh shaped (n_envs, n_ranks) over the first ``n_total``
    devices.  Unlike ``jax.make_mesh`` this tolerates a plan smaller than
    the host (the remaining devices simply idle — the plan's utilization
    already accounts for them).

    Process-spanning mode (``span_processes=True``, or the default ``None``
    when ``jax.process_count() > 1``): the "data" axis crosses process
    boundaries — ``n_total // num_processes`` devices are taken from EVERY
    process (``repro.launch.distributed.span_devices``) — while each env's
    "model"/halo ranks stay on one host, the paper's
    keep-the-outer-axis-embarrassing principle at fleet scale.  Requires
    the per-process device slice to be a multiple of n_ranks so no halo
    exchange ever crosses a host boundary."""
    import numpy as np

    n_envs, n_ranks = plan.mesh_shape if hasattr(plan, "mesh_shape") \
        else tuple(plan)
    n = n_envs * n_ranks
    if span_processes is None:
        span_processes = devices is None and jax.process_count() > 1
    if span_processes:
        from repro.launch.distributed import span_devices
        devices = span_devices(n, devices)
        procs = len({d.process_index for d in devices})
        if (n // procs) % n_ranks:
            raise ValueError(
                f"plan (n_envs, n_ranks) = ({n_envs}, {n_ranks}) cannot "
                f"span {procs} processes: each process's {n // procs} "
                f"devices must hold whole envs (a multiple of n_ranks = "
                f"{n_ranks}) so halo exchanges stay intra-host")
    else:
        devices = list(jax.devices()) if devices is None else list(devices)
        if n > len(devices):
            raise ValueError(
                f"plan needs n_envs * n_ranks = {n} devices but this host "
                f"has {len(devices)}; shrink the plan or force more host "
                f"devices "
                f"(XLA_FLAGS=--xla_force_host_platform_device_count={n})")
    arr = np.asarray(devices[:n], dtype=object).reshape(n_envs, n_ranks)
    return jax.sharding.Mesh(arr, ("data", "model"))
