"""HLO-text analysis: FLOPs / HBM bytes / collective bytes with while-loop
trip-count scaling.

``compiled.cost_analysis()`` counts a while-loop body ONCE (verified on this
jax build), which under-counts scan-over-layers models by ~num_layers x.  This
module parses ``compiled.as_text()`` into a computation call graph and costs it
recursively:

  flops(comp)   = sum dots/convs (2*M*N*K from recorded operand shapes)
                  + while: trip_count * flops(body)
                  + fusion/call: flops(called comp)
  bytes(comp)   = sum over *top-granularity* instructions (fusion boundaries)
                  of operand+output buffer sizes — a post-fusion HBM proxy
  coll(comp)    = operand bytes of all-gather / all-reduce / reduce-scatter /
                  all-to-all / collective-permute, trip-scaled

Trip counts come from the while condition's comparison constant (static for
lax.scan / fori_loop, which is all this codebase emits).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_BODY_ATTR = re.compile(r"body=%?([\w\.\-]+)")
_COND_ATTR = re.compile(r"condition=%?([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """'(f32[8,64]{1,0}, s32[])' -> [('f32', (8,64)), ('s32', ())]."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, shape in _parse_shapes(type_str):
        n = _DTYPE_BYTES[dt]
        for d in shape:
            n *= d
        total += n
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)


def _balanced(s: str, start: int) -> int:
    """Index just past the ')' matching the '(' at s[start]."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_instr(line: str) -> Optional[Instr]:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    # result type: balanced "(...)" tuple or a single space-free token
    if rest.startswith("("):
        tend = _balanced(rest, 0)
        type_str = rest[:tend]
        rest = rest[tend:].lstrip()
    else:
        sp = rest.find(" ")
        type_str = rest[:sp]
        rest = rest[sp + 1:]
    par = rest.find("(")
    if par < 0:
        return None
    op = rest[:par].strip()
    aend = _balanced(rest, par)
    args = rest[par + 1:aend - 1]
    attrs = rest[aend:].lstrip(", ")
    operands = [a.strip().split(" ")[-1].lstrip("%")
                for a in _split_args(args)]
    return Instr(name, type_str, op, operands, attrs)


def _split_args(args: str) -> List[str]:
    """Split top-level commas (tuple types in args contain commas/brackets)."""
    out, depth, cur = [], 0, []
    for ch in args:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped == "}":
            cur = None
            continue
        if stripped.endswith("{") and "->" in stripped and "=" not in \
                stripped.split("(")[0]:
            head = stripped.split("(")[0].strip()
            if head.startswith("ENTRY"):
                entry_name = head[len("ENTRY"):].strip().lstrip("%")
                cur = Computation(entry_name)
                entry = entry_name
            else:
                cur = Computation(head.lstrip("%"))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.instrs.append(ins)
    return comps, entry


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)

    def __add__(self, o: "Cost") -> "Cost":
        kinds = dict(self.coll_by_kind)
        for k, v in o.coll_by_kind.items():
            kinds[k] = kinds.get(k, 0.0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.coll_bytes + o.coll_bytes, kinds)

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                    {kk: v * k for kk, v in self.coll_by_kind.items()})


class HloCostAnalyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_hlo(text)
        self.shapes: Dict[str, str] = {}
        for c in self.comps.values():
            for ins in c.instrs:
                self.shapes[ins.name] = ins.type_str
        self._memo: Dict[str, Cost] = {}

    # -- trip counts ---------------------------------------------------------

    def trip_count(self, cond_name: str) -> int:
        """Largest integer constant in the while condition computation.

        lax.scan / fori_loop conditions are `iter < N` with a literal N;
        constants parse as op='constant' with the literal in the args slot
        (`%c = s32[] constant(61)`)."""
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        ints = []
        for ins in comp.instrs:
            if ins.op == "constant":
                for tok in ins.operands:
                    if re.fullmatch(r"\d+", tok.strip()):
                        ints.append(int(tok))
        return max(ints) if ints else 1

    # -- instruction costs ---------------------------------------------------

    def _dot_flops(self, ins: Instr) -> float:
        out_elems = 1
        for _, shape in _parse_shapes(ins.type_str):
            for d in shape:
                out_elems *= d
        # contracting dims from lhs operand shape
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        k = 1
        if m and ins.operands:
            lhs_type = self.shapes.get(ins.operands[0], "")
            shapes = _parse_shapes(lhs_type)
            if shapes:
                lhs_shape = shapes[0][1]
                for d in (int(x) for x in m.group(1).split(",") if x):
                    if d < len(lhs_shape):
                        k *= lhs_shape[d]
        return 2.0 * out_elems * k

    def _operand_bytes(self, ins: Instr) -> float:
        n = 0
        for o in ins.operands:
            n += _nbytes(self.shapes.get(o, ""))
        return float(n)

    # -- recursive computation cost ------------------------------------------

    def cost(self, comp_name: Optional[str] = None) -> Cost:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return Cost()
        total = Cost()
        for ins in comp.instrs:
            c = Cost()
            if ins.op == "dot" or ins.op == "convolution":
                c.flops = self._dot_flops(ins)
                c.bytes = self._operand_bytes(ins) + _nbytes(ins.type_str)
            elif ins.op == "fusion":
                called = _CALL_ATTR.search(ins.attrs)
                if called:
                    sub = self.cost(called.group(1))
                    c.flops = sub.flops          # dots inside the fusion
                c.bytes = self._operand_bytes(ins) + _nbytes(ins.type_str)
            elif ins.op == "while":
                body = _BODY_ATTR.search(ins.attrs)
                cond = _COND_ATTR.search(ins.attrs)
                trips = self.trip_count(cond.group(1)) if cond else 1
                if body:
                    c = self.cost(body.group(1)).scaled(trips)
            elif ins.op in ("call", "custom-call", "conditional"):
                called = _CALL_ATTR.search(ins.attrs)
                if called:
                    c = self.cost(called.group(1))
                c.bytes += self._operand_bytes(ins) + _nbytes(ins.type_str)
            elif any(ins.op.startswith(k) for k in COLLECTIVES):
                if not ins.op.endswith("-done"):   # avoid start/done dupes
                    kind = next(k for k in COLLECTIVES if ins.op.startswith(k))
                    nb = self._operand_bytes(ins)
                    c.coll_bytes = nb
                    c.coll_by_kind = {kind: nb}
                    c.bytes = nb + _nbytes(ins.type_str)
            elif ins.op == "dynamic-update-slice":
                # in-place semantics: HBM traffic = the updated slice (x2),
                # not the whole buffer (else a KV-cache write per decode
                # token would count as rewriting the full multi-GB cache)
                upd = (_nbytes(self.shapes.get(ins.operands[1], ""))
                       if len(ins.operands) > 1 else 0)
                c.bytes = 2.0 * upd
            elif ins.op == "dynamic-slice":
                c.bytes = 2.0 * _nbytes(ins.type_str)
            elif ins.op in ("copy", "copy-start", "transpose", "reshape",
                            "broadcast", "reduce", "sort", "scatter",
                            "gather", "concatenate", "pad",
                            "slice", "convert", "iota", "select-and-scatter",
                            "reduce-window"):
                c.bytes = self._operand_bytes(ins) + _nbytes(ins.type_str)
            total = total + c
        self._memo[comp_name] = total
        return total


def analyze(text: str) -> Dict[str, float]:
    a = HloCostAnalyzer(text)
    c = a.cost()
    out = {"flops": c.flops, "bytes": c.bytes, "coll_bytes": c.coll_bytes}
    for k, v in c.coll_by_kind.items():
        out[f"coll_{k}"] = v
    return out
