"""LM training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs any assigned architecture (full or --reduced) on whatever devices exist,
with the same step builders the dry-run lowers on the production mesh.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt_mod
from repro.configs.base import get_config
from repro.data.pipeline import LMDataConfig, synthetic_batch
from repro.launch import steps
from repro.launch.mesh import make_debug_mesh
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    B = args.batch or (8 if args.reduced else 32)
    S = args.seq or (64 if args.reduced else 1024)

    mesh = make_debug_mesh(len(jax.devices()), 1)
    key = jax.random.PRNGKey(args.seed)
    print(f"init {cfg.name}: L={cfg.num_layers} d={cfg.d_model} "
          f"V={cfg.vocab_size} devices={len(jax.devices())}")
    params = M.init_params(cfg, key)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f} M")
    opt = steps.make_opt(cfg)
    opt_state = opt.init(params)
    train_step = jax.jit(steps.make_train_step(cfg, mesh))

    dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B,
                        seed=args.seed)
    step = jnp.int32(0)
    losses = []
    for i in range(args.steps):
        t0 = time.time()
        batch = jax.tree.map(jnp.asarray, synthetic_batch(dcfg, i, cfg))
        params, opt_state, step, metrics = train_step(params, opt_state,
                                                      step, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if i % args.log_every == 0:
            print(f"step {i:5d}  loss {loss:8.4f}  {time.time()-t0:6.2f}s",
                  flush=True)
    if args.ckpt_dir:
        p = Path(args.ckpt_dir) / f"step_{int(step):08d}.ckpt"
        n = ckpt_mod.save(str(p), {"params": params}, step=int(step))
        print(f"checkpoint -> {p} ({n/1e6:.1f} MB)")
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    assert losses[-1] < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()
