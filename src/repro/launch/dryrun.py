import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# 512 placeholder host devices back both the 16x16 single-pod and the
# 2x16x16 multi-pod production meshes.  dryrun ONLY — tests/benches see 1.

# Multi-pod dry-run: lower + compile every (architecture x input shape) on
# the production mesh; record memory analysis, HLO cost terms (with while-loop
# trip scaling), and collective bytes for §Roofline.
#
# Usage:
#   python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
#   python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import INPUT_SHAPES, get_config, list_configs
from repro.launch import hlo_analysis, roofline, steps
from repro.launch.mesh import make_production_mesh


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            out_dir: str = "artifacts/dryrun", verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_dev = mesh.size
    t0 = time.time()
    with mesh:
        jitted, args = steps.lowering_for(cfg, shape, mesh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    analyzed = hlo_analysis.analyze(txt)
    params_shape = steps.abstract_params(cfg)
    mf = roofline.model_flops(cfg, shape, params_shape)
    # the dry-run models TPU pods explicitly (production meshes above), so
    # its roofline prices against the TPU preset regardless of the host
    rl = roofline.build(arch, shape_name, mesh_name, n_dev, analyzed, mf,
                        hw="tpu_v5e")

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": n_dev,
        "status": "ok",
        "t_lower_s": t_lower, "t_compile_s": t_compile,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_bytes": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      + mem.output_size_in_bytes
                                      - mem.alias_size_in_bytes),
        },
        "xla_cost_analysis": {k: ca.get(k) for k in ("flops",
                                                     "bytes accessed")},
        "hlo_analysis": analyzed,
        "roofline": rl.to_dict(),
        "params": roofline.param_count(get_config(arch), params_shape),
        "params_active": roofline.active_param_count(get_config(arch),
                                                     params_shape),
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{arch}__{shape_name}__{mesh_name}.json").write_text(
        json.dumps(rec, indent=1, default=float))
    if verbose:
        m = rec["memory"]
        print(f"OK {arch:24s} {shape_name:12s} {mesh_name:10s} "
              f"lower {t_lower:5.1f}s compile {t_compile:6.1f}s  "
              f"mem/dev {m['peak_per_device_bytes']/2**30:6.2f} GiB  "
              f"dom={rl.dominant:10s} "
              f"C/M/X = {rl.compute_s*1e3:.1f}/{rl.memory_s*1e3:.1f}/"
              f"{rl.collective_s*1e3:.1f} ms", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_configs() if (args.all or not args.arch) else [args.arch]
    shapes = (list(INPUT_SHAPES) if (args.all or not args.shape)
              else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = Path(args.out) / f"{arch}__{shape}__{mesh_name}.json"
                if args.skip_existing and path.exists():
                    rec = json.loads(path.read_text())
                    if rec.get("status") == "ok":
                        print(f"SKIP {arch} {shape} {mesh_name} (cached)")
                        continue
                try:
                    run_one(arch, shape, multi_pod=mp, out_dir=args.out)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    Path(args.out).mkdir(parents=True, exist_ok=True)
                    path.write_text(json.dumps({
                        "arch": arch, "shape": shape, "mesh": mesh_name,
                        "status": "fail", "error": repr(e),
                        "traceback": traceback.format_exc()}, indent=1))
                    print(f"FAIL {arch} {shape} {mesh_name}: {e}",
                          flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f[:3], f[3][:120])
        raise SystemExit(1)
    print("\nall dry-runs OK")


if __name__ == "__main__":
    main()
