"""Roofline terms from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / ICI_link_bw

The SPMD-partitioned HLO is per-device, so analyzer outputs plug in directly.
MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE; 2·N·tokens for single-token
decode) anchors the "useful compute" ratio.

The hardware constants the terms divide by are a :class:`HardwareSpec`, NOT
module constants: every roofline is relative to a named device preset
(``tpu_v5e``, ``cpu_generic``, ...), selected explicitly, via the
``$REPRO_HW_SPEC`` environment variable, or detected from the running jax
platform.  An unrecognized platform raises with the preset list instead of
silently pricing the workload at TPU numbers.
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass
from typing import Dict, Optional, Union

import jax
import numpy as np

from repro.configs.base import InputShape, ModelConfig


@dataclass(frozen=True)
class HardwareSpec:
    """Peak rates a roofline prices against — one device (chip or core).

    ``ici_bw`` is the per-link interconnect bandwidth the collective term
    divides by; single-device presets keep a nominal loopback figure so the
    term stays defined (it is zero whenever coll_bytes is zero).
    """
    name: str
    peak_flops: float            # FLOP/s per device
    hbm_bw: float                # main-memory bytes/s per device
    ici_bw: float                # interconnect bytes/s per link
    description: str = ""

    def to_dict(self) -> Dict:
        return {"name": self.name, "peak_flops": self.peak_flops,
                "hbm_bw": self.hbm_bw, "ici_bw": self.ici_bw}


HARDWARE_PRESETS: Dict[str, HardwareSpec] = {
    "tpu_v5e": HardwareSpec(
        name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
        description="TPU v5e chip: bf16 peak, HBM2e, ICI per link "
                    "(~per direction)"),
    "cpu_generic": HardwareSpec(
        name="cpu_generic", peak_flops=5e10, hbm_bw=2e10, ici_bw=1e10,
        description="one generic x86 core: ~50 GFLOP/s sustained f32 FMA, "
                    "~20 GB/s sustained DRAM, loopback interconnect"),
}

# environment override consulted when no spec is passed explicitly
HW_SPEC_ENV = "REPRO_HW_SPEC"


def hardware_spec(name: Union[None, str, HardwareSpec] = None
                  ) -> HardwareSpec:
    """Resolve the hardware a roofline prices against.

    Precedence: explicit ``name`` (a preset name or a HardwareSpec, passed
    through) > the ``$REPRO_HW_SPEC`` preset name > detection from the
    running jax platform (tpu -> ``tpu_v5e``, cpu -> ``cpu_generic``).
    Anything unrecognized raises a ValueError listing the presets — a
    roofline against silently-wrong peak numbers is worse than no roofline.
    """
    if isinstance(name, HardwareSpec):
        return name
    if name is None:
        name = os.environ.get(HW_SPEC_ENV) or None
    if name is not None:
        try:
            return HARDWARE_PRESETS[name]
        except KeyError:
            raise ValueError(
                f"unknown hardware spec {name!r}; choose a preset from "
                f"{sorted(HARDWARE_PRESETS)} (or pass a HardwareSpec with "
                f"your device's peak_flops/hbm_bw/ici_bw)") from None
    platform = jax.default_backend()
    detected = {"tpu": "tpu_v5e", "cpu": "cpu_generic"}.get(platform)
    if detected is None:
        raise ValueError(
            f"no hardware preset for jax platform {platform!r}; pass one of "
            f"{sorted(HARDWARE_PRESETS)} explicitly (hw= / ${HW_SPEC_ENV}) "
            f"or a HardwareSpec with your device's peak numbers")
    return HARDWARE_PRESETS[detected]


def param_count(cfg: ModelConfig, params_shape) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(params_shape)))


def active_param_count(cfg: ModelConfig, params_shape) -> int:
    """MoE: only top_k (+shared) experts per token are active."""
    total = 0
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        n = int(np.prod(leaf.shape))
        if cfg.moe is not None and any(
                path.endswith(s) for s in ("we1", "we2", "we3")):
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    return total


def _encoder_param_count(params_shape) -> int:
    total = 0
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        if path.startswith("encoder/"):
            total += int(np.prod(leaf.shape))
    return total


def model_flops(cfg: ModelConfig, shape: InputShape, params_shape) -> float:
    n_active = active_param_count(cfg, params_shape)
    n_enc = _encoder_param_count(params_shape) if cfg.is_encdec else 0
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    fl = mult * (n_active - n_enc) * tokens
    if n_enc and shape.kind != "decode":
        # encoder runs over the (downsampled) frontend token stream
        from repro.models import frontend as fe_mod
        t_enc = shape.global_batch * fe_mod.num_frontend_tokens(
            cfg, shape.seq_len)
        fl += mult * n_enc * t_enc
    return fl


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops: float
    coll_by_kind: Dict[str, float]
    # the device the terms price against; None resolves through
    # hardware_spec() (explicit > $REPRO_HW_SPEC > platform detection)
    hw: Optional[HardwareSpec] = None

    def __post_init__(self):
        if self.hw is None:
            self.hw = hardware_spec()

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / self.hw.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_dev / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_dev / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops) — remat/masking/dispatch waste."""
        total = self.flops_per_dev * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu_bound(self) -> float:
        """Upper bound on model-FLOPs utilization implied by the terms."""
        ideal = self.model_flops / (self.n_devices * self.hw.peak_flops)
        return ideal / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_devices": self.n_devices,
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "coll_by_kind": self.coll_by_kind,
            "model_flops": self.model_flops,
            "hw": self.hw.to_dict(),
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio, "mfu_bound": self.mfu_bound,
        }


def build(arch: str, shape_name: str, mesh_name: str, n_devices: int,
          analyzed: Dict[str, float], model_fl: float,
          hw: Union[None, str, HardwareSpec] = None) -> Roofline:
    coll_by_kind = {k[len("coll_"):]: v for k, v in analyzed.items()
                    if k.startswith("coll_") and k != "coll_bytes"}
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, n_devices=n_devices,
        flops_per_dev=analyzed.get("flops", 0.0),
        bytes_per_dev=analyzed.get("bytes", 0.0),
        coll_bytes_per_dev=analyzed.get("coll_bytes", 0.0),
        model_flops=model_fl, coll_by_kind=coll_by_kind,
        hw=hardware_spec(hw))
