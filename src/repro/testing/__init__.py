"""Testing utilities: deterministic fault injection for the self-healing
training path (see :mod:`repro.testing.faults`)."""
from repro.testing import faults  # noqa: F401
