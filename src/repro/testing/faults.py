"""Deterministic fault injection for the self-healing training stack.

Every recovery path in the trainer (per-env quarantine, non-finite-gradient
skip, watchdog rollback, sink retry, checkpoint-crash recovery) is exercised
in CI through this module rather than trusted on faith.  Faults are
configured either programmatically (:func:`configure`) or through the
``REPRO_FAULTS`` environment variable holding a JSON object, e.g.::

    REPRO_FAULTS='{"nan_env": {"env": 1, "step": 4}, "grad_nan": {"step": 6}}'

Supported fault kinds:

``nan_env``
    Poison the velocity field of env ``env`` at env-step ``step`` with NaN
    before the solver interval.  Read at trace time by ``env_step``; the
    match itself is traced, so a single jitted program covers both the
    firing and non-firing steps.  ``step`` is the within-episode actuation
    counter (``EnvState.t``), which restarts at 0 every episode — the fault
    therefore fires once per episode (expected quarantines = episodes run
    with the fault armed).
``grad_nan``
    Corrupt the gradients of the PPO minibatch whose update-step counter
    equals ``step``.  Read at trace time by ``ppo_update``.  The PPO step
    counter is monotonic across the whole run (it indexes Adam bias
    correction), so this fires exactly once.
``watchdog``
    Force the training watchdog to trip at episode ``episode`` (host-side,
    consumed once).
``sink_oserror``
    Make the next ``times`` (default 1) sink writes raise ``OSError``
    (host-side, decremented per raise).
``ckpt_crash``
    Crash (``OSError``) the checkpoint write for step ``step`` just before
    its atomic rename, leaving a stale ``*.tmp`` behind — exactly the
    torn-write shape ``latest_checkpoint`` must recover from.  Host-side,
    consumed once.

Trace-time faults (``nan_env``, ``grad_nan``) must be configured *before*
the jitted training program is built — they are baked into the trace.
Host-side faults can be (re)configured at any point.  :func:`reset` clears
everything; the test suite calls it between tests.

This module is stdlib-only on purpose: importing it must never pull in JAX.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

ENV_FAULTS = "REPRO_FAULTS"

_spec: Dict[str, Dict[str, Any]] = {}
_loaded_env = False


def configure(spec: Optional[Dict[str, Dict[str, Any]]]) -> None:
    """Install a fault spec programmatically (replaces any active spec)."""
    global _spec, _loaded_env
    _spec = {k: dict(v) for k, v in (spec or {}).items()}
    _loaded_env = True   # explicit config wins over the environment


def reset() -> None:
    """Clear all faults and re-arm environment-variable loading."""
    global _spec, _loaded_env
    _spec = {}
    _loaded_env = False


def _load() -> Dict[str, Dict[str, Any]]:
    global _spec, _loaded_env
    if not _loaded_env:
        _loaded_env = True
        raw = os.environ.get(ENV_FAULTS)
        if raw:
            try:
                parsed = json.loads(raw)
            except ValueError as e:
                raise ValueError(
                    f"{ENV_FAULTS} is not valid JSON: {raw!r} ({e})") from e
            if not isinstance(parsed, dict):
                raise ValueError(
                    f"{ENV_FAULTS} must be a JSON object mapping fault kind "
                    f"to parameters, got: {raw!r}")
            _spec = {k: dict(v) for k, v in parsed.items()}
    return _spec


def active(kind: str) -> Optional[Dict[str, Any]]:
    """Return the parameters for ``kind`` if armed, else None.

    Used at trace time by the jitted paths; also usable host-side for a
    non-consuming peek.
    """
    return _load().get(kind)


def consume(kind: str, **match: Any) -> bool:
    """Host-side check-and-consume for one-shot faults.

    Returns True when ``kind`` is armed and every keyword matches the spec
    (missing spec keys match anything); the fault is then disarmed.  A
    ``times`` counter in the spec allows multiple firings.
    """
    spec = _load().get(kind)
    if spec is None:
        return False
    for k, v in match.items():
        if k in spec and spec[k] != v:
            return False
    times = int(spec.get("times", 1)) - 1
    if times <= 0:
        _spec.pop(kind, None)
    else:
        spec["times"] = times
    return True


def maybe_fail_io(path: str) -> None:
    """Raise OSError if a ``sink_oserror`` fault is armed (consumes one)."""
    if consume("sink_oserror"):
        raise OSError(f"injected sink_oserror for {path}")


def maybe_crash_ckpt(step: int, path: str) -> None:
    """Raise OSError if a ``ckpt_crash`` fault matches this checkpoint step."""
    if consume("ckpt_crash", step=int(step)):
        raise OSError(f"injected ckpt_crash at step {step} for {path}")
