"""Reproduce the paper's tables/figures from the calibrated cost model.

Each generator returns rows as dicts (one per paper table row) so the
benchmark harness can print CSVs and EXPERIMENTS.md can embed them next to
the paper's own numbers.  Calibration fits the few CostModel constants to
(a) measured single-worker costs on this host and (b) the paper's published
rows; report both.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.plan import CostModel, ParallelPlan

# Paper Table I (N_ranks = 1 block): N_envs -> total duration (hours)
PAPER_TABLE1_R1 = {1: 225.2, 2: 123.7, 4: 64.6, 6: 44.4, 8: 33.9, 10: 26.3,
                   20: 14.2, 30: 9.6, 40: 9.0, 50: 8.1, 60: 7.6}
PAPER_TABLE1_R2 = {1: 289.6, 2: 156.3, 4: 80.0, 6: 53.4, 8: 40.8, 10: 33.2,
                   20: 17.7, 30: 12.4}
PAPER_TABLE1_R5 = {1: 305.8, 2: 170.8, 4: 88.5, 6: 59.7, 8: 47.3, 10: 38.3,
                   12: 32.4}
# Paper Table II: N_envs -> (baseline, io_disabled, optimized) hours
PAPER_TABLE2 = {1: (225.2, 193.1, 200.0), 2: (123.7, 104.7, 103.8),
                4: (64.6, 53.4, 52.1), 6: (44.4, 35.5, 35.7),
                8: (33.9, 26.3, 26.7), 10: (26.3, 21.3, 21.5),
                20: (14.2, 11.3, 11.3), 30: (9.6, 7.9, 8.3),
                40: (9.0, 6.4, 6.3), 50: (8.1, 5.5, 5.3),
                60: (7.6, 4.8, 4.8)}


def least_squares_fit(resid, x0):
    """Shared fitting backend for ``calibrate_to_paper`` and
    ``core.autotune.refit_cost_model``: Levenberg-Marquardt least squares on
    an |x|-parameterization (all CostModel constants are non-negative).
    Returns the fitted |x| vector."""
    import numpy as np
    from scipy.optimize import least_squares

    sol = least_squares(resid, x0, method="lm")
    return np.abs(sol.x)


def calibrate_to_paper(model: Optional[CostModel] = None) -> CostModel:
    """Least-squares fit of the CostModel constants to the paper's Table II
    (33 data points: baseline / io-disabled / optimized x 11 env counts).

    Seed values come from closed-form identities:
      Table I (1 env, 1 rank): 225.2 h / 3000 episodes = 270 s/episode;
      Table II io-disabled at 1 env isolates t_step_1.
    """
    import numpy as np

    m = model or CostModel()
    ep_noio = PAPER_TABLE2[1][1] * 3600 / 3000         # 231.7 s
    t1_seed = (ep_noio - m.t_update) / (
        m.actuations_per_episode * m.steps_per_actuation)

    def build(x):
        t1, mgmt, b_stream, b_agg, v_opt_scale = x
        return dataclasses.replace(
            m, t_step_1=t1, mgmt_log_s=mgmt,
            io_stream_bandwidth=b_stream, io_bandwidth=b_agg), v_opt_scale

    def resid(x):
        mm, v_opt_scale = build(np.abs(x))
        out = []
        for n_envs, (pb, pd, po) in PAPER_TABLE2.items():
            p = ParallelPlan(n_envs, n_envs, 1)
            out.append(mm.t_training(p, 3000) / 3600 / pb - 1)
            out.append(mm.t_training(p, 3000, io_bytes=0.0) / 3600 / pd - 1)
            out.append(mm.t_training(p, 3000,
                                     io_bytes=1.2e6 * v_opt_scale)
                       / 3600 / po - 1)
        return out

    x0 = [t1_seed, 20.0, 2.0e7, 2.0e8, 1.0]
    fitted, _ = build(least_squares_fit(resid, x0))
    return fitted


def table1_rows(model: CostModel, n_episodes: int = 3000) -> List[Dict]:
    """Hybrid-parallelization sweep (paper Table I, all three blocks)."""
    rows = []
    ref = None
    for n_ranks, sweep in ((5, PAPER_TABLE1_R5), (2, PAPER_TABLE1_R2),
                           (1, PAPER_TABLE1_R1)):
        base = ParallelPlan(n_ranks, 1, n_ranks)
        for n_envs, paper_h in sweep.items():
            p = ParallelPlan(n_envs * n_ranks, n_envs, n_ranks)
            t = model.t_training(p, n_episodes)
            t_base = model.t_training(base, n_episodes)
            rows.append({
                "n_episodes": n_episodes, "n_envs": n_envs,
                "n_ranks": n_ranks, "n_cpus": n_envs * n_ranks,
                "t_hours": t / 3600,
                "speedup": t_base / t,
                "efficiency": t_base / t / n_envs,
                "paper_t_hours": paper_h,
            })
    return rows


def table2_rows(model: CostModel, n_episodes: int = 3000,
                optimized_bytes: float = 1.2e6) -> List[Dict]:
    """I/O-strategy sweep (paper Table II)."""
    rows = []
    for n_envs, (pb, pd, po) in PAPER_TABLE2.items():
        p = ParallelPlan(n_envs, n_envs, 1)
        tb = model.t_training(p, n_episodes)
        td = model.t_training(p, n_episodes, io_bytes=0.0)
        to = model.t_training(p, n_episodes, io_bytes=optimized_bytes)
        rows.append({
            "n_envs": n_envs,
            "t_baseline_h": tb / 3600, "t_disabled_h": td / 3600,
            "t_optimized_h": to / 3600,
            "speedup_disabled": (tb - td) / tb,
            "speedup_optimized": (tb - to) / tb,
            "paper": (pb, pd, po),
        })
    return rows


def fig7_rows(model: CostModel, ranks: Sequence[int] = (1, 2, 4, 8, 16)
              ) -> List[Dict]:
    """CFD intra-instance scaling (paper Fig. 7)."""
    return [{"n_ranks": n,
             "speedup": model.t_step(1) / model.t_step(n),
             "efficiency": model.cfd_efficiency(n)} for n in ranks]


def fig10_breakdown(model: CostModel, n_envs_list=(1, 10, 30, 40, 60)
                    ) -> List[Dict]:
    """Per-episode time breakdown (paper Fig. 10)."""
    out = []
    for n in n_envs_list:
        p = ParallelPlan(n, n, 1)
        cfd = (model.actuations_per_episode * model.steps_per_actuation
               * model.t_step(1))
        io = model.actuations_per_episode * model.t_io_per_actuation(n)
        drl = (model.t_update
               + model.actuations_per_episode * model.t_policy)
        out.append({"n_envs": n, "cfd_s": cfd, "io_s": io, "drl_s": drl,
                    "total_s": model.t_episode(p)})
    return out
