"""Distributed multi-env DRL rollout on the production mesh.

The paper's two parallel axes map directly onto mesh axes (DESIGN.md §2):

  n_envs  -> "data" (x "pod")   : env-batch sharding — embarrassingly parallel
  n_ranks -> "model"            : spatial domain decomposition of each CFD grid

The actual collect implementation (vmap rollout, sharding constraints, GAE,
flattening) is ``repro.drl.engine.RolloutEngine`` — this module is the thin
mesh-facing façade kept for the dry-run tools and CFD-only sharded stepping.
XLA's SPMD partitioner inserts the halo exchanges (collective-permutes) for
every stencil — the TPU-native equivalent of OpenFOAM's MPI halo messages —
so the dry-run HLO exposes exactly the collective traffic the roofline
analysis needs.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.cfd.env import CylinderEnv
from repro.cfd.solver import FlowState
from repro.drl.engine import (EngineConfig, RolloutEngine, env_state_specs,
                              place_env_batch, shard_env_batch)

__all__ = ["env_state_specs", "shard_env_batch", "place_env_batch",
           "make_distributed_collect", "make_sharded_cfd_step",
           "restore_env_batch"]


def restore_env_batch(mesh, host_state, n_ranks: int = 1):
    """Place a checkpoint-restored (host-array) env batch onto ``mesh``.

    The cross-plan resume primitive: a ``TrainState`` saved under one
    ``ParallelPlan`` holds plain host ndarrays, and this re-shards them for
    whatever mesh/backend the resuming run resolved — the same
    ``shard_env_batch`` rules the engine applies to a fresh batch (grid
    fields x-sharded over "model" when ``n_ranks > 1``, everything else
    batch-sharded over "data")."""
    return place_env_batch(mesh, host_state, n_ranks)


def make_distributed_collect(env: CylinderEnv, mesh: Mesh, n_envs: int,
                             length: int, n_ranks: int = 1,
                             gamma: float = 0.99, lam: float = 0.95):
    """jit'd (params, st_b, obs_b, key) -> (Batch, traj) with mesh shardings.

    Used both for real execution (1 device: shardings are no-ops) and for the
    dry-run lowering of the paper's own workload on the production mesh.
    Returns (jitted collect, untraced closure) — both from the engine."""
    engine = RolloutEngine.for_env(
        env, EngineConfig(n_envs=n_envs, horizon=length, gamma=gamma,
                          lam=lam, n_ranks=n_ranks),
        mesh=mesh)
    return engine._collect, engine.collect_fn


def make_sharded_cfd_step(env: CylinderEnv, mesh: Mesh):
    """One spatially-sharded CFD solver step (the n_ranks axis alone).

    FlowState fields are sharded along x over "model"; used by the CFD-scaling
    benchmark and the dry-run."""
    from repro.cfd import solver

    spec = NamedSharding(mesh, P(None, "model"))

    def step(state: FlowState, jet):
        state = jax.tree.map(
            lambda a: jax.lax.with_sharding_constraint(a, spec), state)
        return solver.step(env.cfg.grid, env.geom_arrays, state, jet)

    return jax.jit(step)
