"""Distributed multi-env DRL rollout on the production mesh.

The paper's two parallel axes map directly onto mesh axes (DESIGN.md §2):

  n_envs  -> "data" (x "pod")   : env-batch sharding — embarrassingly parallel
  n_ranks -> "model"            : spatial domain decomposition of each CFD grid

Env state arrays are (N_env, ny, nx)-shaped; the batch dim is sharded over
the data axes and the x (streamwise) grid dim over the model axis.  XLA's SPMD
partitioner inserts the halo exchanges (collective-permutes) for every stencil
— the TPU-native equivalent of OpenFOAM's MPI halo messages — so the dry-run
HLO exposes exactly the collective traffic the roofline analysis needs.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.cfd.env import CylinderEnv
from repro.cfd.solver import FlowState
from repro.drl import networks, rollout
from repro.drl.gae import gae_batch
from repro.drl.ppo import Batch
from repro.models.sharding import dp_axes


def env_state_specs(mesh: Mesh, n_envs: int) -> Tuple[P, P]:
    """(batch-only spec, batch+space spec) for env pytrees.

    Grid arrays additionally shard their x (last) dim over "model" when the
    plan uses n_ranks > 1."""
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    batch = P(dp)
    batch_space = P(dp, None, "model")
    return batch, batch_space


def shard_env_batch(mesh: Mesh, st_b, n_ranks: int = 1):
    """Apply shardings to a batched EnvState pytree."""
    batch, batch_space = env_state_specs(mesh, st_b.t.shape[0])

    def spec_of(a):
        if a.ndim == 3 and n_ranks > 1:        # (N, ny, nx) grid field
            return NamedSharding(mesh, batch_space)
        return NamedSharding(mesh, P(batch[0]))

    return jax.tree.map(lambda a: jax.device_put(a, spec_of(a)), st_b)


def make_distributed_collect(env: CylinderEnv, mesh: Mesh, n_envs: int,
                             length: int, n_ranks: int = 1,
                             gamma: float = 0.99, lam: float = 0.95):
    """jit'd (params, st_b, obs_b, key) -> (Batch, traj) with mesh shardings.

    Used both for real execution (1 device: shardings are no-ops) and for the
    dry-run lowering of the paper's own workload on the production mesh."""
    batch, batch_space = env_state_specs(mesh, n_envs)
    dp = batch[0]

    def collect(params, st_b, obs_b, key):
        def constrain(a):
            if a.ndim >= 3 and n_ranks > 1:
                return jax.lax.with_sharding_constraint(
                    a, NamedSharding(mesh, batch_space))
            return jax.lax.with_sharding_constraint(
                a, NamedSharding(mesh, P(dp)))

        st_b = jax.tree.map(constrain, st_b)
        _, traj = rollout.rollout_batch(env.env_step, params, st_b, obs_b,
                                        key, length, n_envs)
        values = networks.value(params, traj.obs)
        last_v = networks.value(params, traj.last_obs)
        adv, ret = gae_batch(traj.reward, values, last_v,
                             gamma=gamma, lam=lam)
        flat = lambda x: x.reshape((-1,) + x.shape[2:])
        return Batch(obs=flat(traj.obs), act=flat(traj.act),
                     logp_old=flat(traj.logp), adv=flat(adv),
                     ret=flat(ret)), traj

    in_shardings = (
        NamedSharding(mesh, P()),                      # params replicated
        None,                                          # st_b: as provided
        NamedSharding(mesh, P(dp)),                    # obs batch-sharded
        NamedSharding(mesh, P()),
    )
    return jax.jit(collect), collect


def make_sharded_cfd_step(env: CylinderEnv, mesh: Mesh):
    """One spatially-sharded CFD solver step (the n_ranks axis alone).

    FlowState fields are sharded along x over "model"; used by the CFD-scaling
    benchmark and the dry-run."""
    from repro.cfd import solver

    spec = NamedSharding(mesh, P(None, "model"))

    def step(state: FlowState, jet):
        state = jax.tree.map(
            lambda a: jax.lax.with_sharding_constraint(a, spec), state)
        return solver.step(env.cfg.grid, env.geom_arrays, state, jet)

    return jax.jit(step)
