"""CFD <-> DRL data interface — the paper's I/O bottleneck, reproduced.

Wang et al.'s DRLinFluids couples OpenFOAM and TensorForce through the file
system: every actuation period each environment dumps probe/force/flow-field
files, Python parses them, and actions are injected back into text config
files via regex.  The paper shows this interface throttles >30-env training
and fixes it with two measures: drop non-essential flow-field dumps and use
binary formats (5.0 MB -> 1.2 MB per actuation).

Three faithful modes (all with REAL file I/O, measurable on this host):

  'file_baseline' — ASCII dumps (OpenFOAM-style), full synthetic flow-field
                    payload, regex-based action injection into a config file.
  'optimized'     — binary (npy-like raw + msgpack header), essential arrays
                    only, optional zstd (beyond-paper, DESIGN.md §9).
  'disabled'      — no-op (the paper's theoretical upper bound).

On TPU the disk analogue is device->host transfer + serialization; the same
class backs both the wall-clock benchmarks (bench_io) and the training-loop
hook (drl/train.py).
"""
from __future__ import annotations

import dataclasses
import io
import os
import re
import shutil
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Optional, Tuple

import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover
    zstd = None

MODES = ("file_baseline", "optimized", "optimized_zstd", "disabled")


# ---------------------------------------------------------------------------
# binary codec (shared by FileInterface and drl.engine.TrajectorySink)
# ---------------------------------------------------------------------------

def pack_arrays(arrays: Dict[str, np.ndarray],
                scalars: Optional[Dict[str, float]] = None,
                cctx=None) -> bytes:
    """msgpack + raw fp32 payload: {name: bytes, name_shape: [...]} per array.

    ``cctx`` is an optional zstd compressor (the 'optimized_zstd' mode)."""
    payload: Dict[str, object] = {"__scalars__": dict(scalars or {})}
    for name, arr in arrays.items():
        a = np.ascontiguousarray(np.asarray(arr), dtype=np.float32)
        payload[name] = a.tobytes()
        payload[name + "_shape"] = list(a.shape)
    blob = msgpack.packb(payload)
    if cctx is not None:
        blob = cctx.compress(blob)
    return blob


def unpack_arrays(blob: bytes, dctx=None):
    """Inverse of ``pack_arrays`` -> (arrays dict, scalars dict)."""
    if dctx is not None:
        blob = dctx.decompress(blob)
    d = msgpack.unpackb(blob)
    scalars = d.pop("__scalars__", {})
    arrays = {}
    for name, raw in d.items():
        if name.endswith("_shape"):
            continue
        arrays[name] = np.frombuffer(raw, np.float32).reshape(
            d[name + "_shape"])
    return arrays, scalars

# Paper: "multiple files with a total size of 5.0 MB ... at the end of each
# instance of CFD simulation"; optimized: 1.2 MB (-76%).
BASELINE_FLOWFIELD_FLOATS = 5_000_000 // 13  # ~5.0 MB as "%.6e" ascii text
OPTIMIZED_FLOWFIELD_FLOATS = 1_200_000 // 4  # ~1.2 MB binary fp32


@dataclass
class ExchangeRecord:
    obs: np.ndarray          # (149,) probe pressures
    forces: np.ndarray       # (T_hist, 2) CD/CL history for reward
    action: float
    flow_field: Optional[np.ndarray] = None   # the redundant payload


class FileInterface:
    """One instance per environment (mirrors one OpenFOAM case directory)."""

    def __init__(self, mode: str, root: str, env_id: int = 0,
                 flowfield_floats: Optional[int] = None):
        assert mode in MODES, mode
        self.mode = mode
        self.env_id = env_id
        self.dir = Path(root) / f"env_{env_id:04d}"
        if mode != "disabled":
            self.dir.mkdir(parents=True, exist_ok=True)
            self._write_config_template()
        if flowfield_floats is None:
            flowfield_floats = (BASELINE_FLOWFIELD_FLOATS
                                if mode == "file_baseline"
                                else OPTIMIZED_FLOWFIELD_FLOATS)
        self.flowfield_floats = flowfield_floats
        self._cctx = zstd.ZstdCompressor(level=1) if zstd else None
        self._dctx = zstd.ZstdDecompressor() if zstd else None

    # -- OpenFOAM-style config with regex action injection -------------------

    def _write_config_template(self):
        (self.dir / "jetVelocity").write_text(
            "/* OpenFOAM-style boundary dictionary */\n"
            "boundaryField\n{\n"
            "    jet1 { type fixedValue; value uniform (0.0 0 0); }\n"
            "    jet2 { type fixedValue; value uniform (0.0 0 0); }\n"
            "}\n")

    _JET_RE = re.compile(r"(jet([12]) \{ type fixedValue; value uniform \()"
                         r"[-0-9.eE+]+")

    def inject_action(self, action: float) -> None:
        """Regex-rewrite the config file (the paper's action path)."""
        if self.mode == "disabled":
            return
        path = self.dir / "jetVelocity"
        text = path.read_text()

        def sub(m):
            sign = 1.0 if m.group(2) == "1" else -1.0
            return f"{m.group(1)}{sign * action:.8f}"

        path.write_text(self._JET_RE.sub(sub, text))

    def read_action(self) -> float:
        if self.mode == "disabled":
            return 0.0
        text = (self.dir / "jetVelocity").read_text()
        m = self._JET_RE.search(text)
        return float(m.group(0).rsplit("(", 1)[-1])

    # -- per-actuation state dump / load -------------------------------------

    def write_actuation(self, period: int, rec: ExchangeRecord) -> int:
        """Write one actuation period's data.  Returns bytes written."""
        if self.mode == "disabled":
            return 0
        if self.mode == "file_baseline":
            return self._write_ascii(period, rec)
        return self._write_binary(period, rec)

    def read_actuation(self, period: int) -> ExchangeRecord:
        if self.mode == "disabled":
            raise RuntimeError("disabled interface holds no data")
        if self.mode == "file_baseline":
            return self._read_ascii(period)
        return self._read_binary(period)

    # ascii (OpenFOAM-ish): one file per field, textual numbers ------------

    def _write_ascii(self, period: int, rec: ExchangeRecord) -> int:
        n = 0
        d = self.dir / f"{period:06d}"
        d.mkdir(exist_ok=True)
        for name, arr in (("p_probes", rec.obs), ("forces", rec.forces)):
            body = "\n".join(" ".join(f"{x:.9e}" for x in np.atleast_1d(row))
                             for row in np.atleast_2d(arr))
            txt = f"// field {name}\n{body}\n"
            (d / name).write_text(txt)
            n += len(txt)
        ff = rec.flow_field
        if ff is None:
            ff = np.zeros(self.flowfield_floats, np.float64)
        # OpenFOAM writes full fields in ascii by default — the redundant dump
        body = "\n".join(f"{x:.6e}" for x in ff[: self.flowfield_floats])
        txt = f"// flowField\n{body}\n"
        (d / "flowField").write_text(txt)
        n += len(txt)
        return n

    def _read_ascii(self, period: int) -> ExchangeRecord:
        d = self.dir / f"{period:06d}"
        def parse(name):
            lines = (d / name).read_text().splitlines()[1:]
            return np.array([[float(x) for x in ln.split()]
                             for ln in lines if ln])
        obs = parse("p_probes").ravel()
        forces = parse("forces")
        _ = (d / "flowField").read_text()          # parsed (cost) but unused
        return ExchangeRecord(obs=obs, forces=forces,
                              action=self.read_action())

    # binary (optimized): single msgpack+raw file, essential arrays only ----

    def _write_binary(self, period: int, rec: ExchangeRecord) -> int:
        arrays = {"obs": rec.obs,
                  "forces": np.atleast_2d(np.asarray(rec.forces))}
        if self.flowfield_floats:
            ff = rec.flow_field
            if ff is None:
                ff = np.zeros(self.flowfield_floats, np.float32)
            arrays["flow"] = np.asarray(ff)[: self.flowfield_floats]
        cctx = self._cctx if self.mode == "optimized_zstd" else None
        blob = pack_arrays(arrays, scalars={"action": float(rec.action)},
                           cctx=cctx)
        path = self.dir / f"{period:06d}.bin"
        path.write_bytes(blob)
        return len(blob)

    def _read_binary(self, period: int) -> ExchangeRecord:
        blob = (self.dir / f"{period:06d}.bin").read_bytes()
        dctx = self._dctx if self.mode == "optimized_zstd" else None
        arrays, scalars = unpack_arrays(blob, dctx=dctx)
        return ExchangeRecord(obs=arrays["obs"], forces=arrays["forces"],
                              action=scalars["action"])

    def cleanup(self):
        if self.dir.exists():
            shutil.rmtree(self.dir, ignore_errors=True)


class MultiEnvInterface:
    """The training-loop hook: routes a whole env batch through the files,
    exactly as DRLinFluids does once per actuation period per env."""

    def __init__(self, mode: str, root: str, n_envs: int,
                 flowfield_floats: Optional[int] = None):
        self.mode = mode
        self.envs = [FileInterface(mode, root, i, flowfield_floats)
                     for i in range(n_envs)]
        self.period = 0
        self.bytes_moved = 0
        self.time_spent = 0.0

    def exchange(self, batch):
        """Round-trip the batch through the interface; returns parsed batch."""
        if self.mode == "disabled":
            return batch
        t0 = time.perf_counter()
        obs = np.asarray(batch.obs)
        n = len(self.envs)
        per_env = obs.reshape(n, -1, obs.shape[-1])
        acts = np.asarray(batch.act).reshape(n, -1)
        for i, fi in enumerate(self.envs):
            rec = ExchangeRecord(obs=per_env[i].ravel(),
                                 forces=np.zeros((10, 2), np.float32),
                                 action=float(acts[i, 0]))
            fi.inject_action(rec.action)
            self.bytes_moved += fi.write_actuation(self.period, rec)
            fi.read_actuation(self.period)
        self.period += 1
        self.time_spent += time.perf_counter() - t0
        return batch

    def cleanup(self):
        for fi in self.envs:
            fi.cleanup()
