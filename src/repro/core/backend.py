"""One kernel-selection convention for the whole repo.

PR 3 standardized the CFD stack on ``backend="reference"|"pallas"|...`` with
``use_pallas=`` kept as a deprecated boolean alias; this module is that
convention factored out so the model stack (attention / rwkv / hybrid and
``launch.steps``) resolves backends through the exact same code path instead
of carrying ~15 scattered ``use_pallas=`` booleans.

``repro.cfd.poisson.resolve_backend`` delegates here with its five-member
backend tuple; the model stack uses :data:`MODEL_BACKENDS` (two members).
The ``DeprecationWarning``'s ``stacklevel`` walks past jax machinery and
this package's forwarding frames so the warning blames the *user's* call
site even when the resolving function is traced under ``jax.jit`` (tests
pin ``w.filename``).
"""
from __future__ import annotations

import os
import sys
import warnings
from typing import Optional, Sequence, Tuple

import jax

MODEL_BACKENDS: Tuple[str, ...] = ("reference", "pallas")

# every warn-once cache in the repo, so test isolation is one call away
_WARN_CACHES: list = []


def warn_once_cache() -> set:
    """A set for warn-once deduplication (``if key not in cache: warn``),
    registered so :func:`reset_warning_caches` can clear it.

    Module-level warn-once sets are process-global state: without a reset
    hook, warning-assertion tests pass or fail depending on execution order.
    Every warn-once site (the poisson odd-nx fallback, the fused-interval
    fallback, ...) allocates its cache here instead of a bare ``set()``."""
    cache: set = set()
    _WARN_CACHES.append(cache)
    return cache


def reset_warning_caches() -> None:
    """Clear every registered warn-once cache (autouse pytest fixture hook):
    after a reset, each warn-once warning fires again on its next trigger."""
    for cache in _WARN_CACHES:
        cache.clear()


def caller_stacklevel(skip_dirs: Sequence[str], *, base: int = 2) -> int:
    """Stacklevel (as counted from the ``warnings.warn`` call inside
    :func:`resolve_backend`) of the nearest frame outside ``skip_dirs`` and
    jax machinery — so deprecation warnings point at the user's call site.

    ``base`` is the stacklevel that would blame ``resolve_backend``'s direct
    caller; each skipped forwarding frame adds one."""
    jax_dir = os.path.dirname(jax.__file__)
    dirs = tuple(skip_dirs) + (jax_dir,)
    # stacklevel ``base`` (counted from resolve_backend's warn) blames the
    # frame at ``sys._getframe(base)`` as seen from here: 0 = this helper,
    # 1 = resolve_backend, 2 = its caller.
    level = base
    frame = sys._getframe(base) if hasattr(sys, "_getframe") else None
    while frame is not None:
        fname = frame.f_code.co_filename
        if not any(fname.startswith(d) for d in dirs):
            return level
        level += 1
        frame = frame.f_back
    return base


def resolve_backend(backend: Optional[str] = None,
                    use_pallas: Optional[bool] = None, *,
                    backends: Sequence[str] = MODEL_BACKENDS,
                    skip_dirs: Sequence[str] = (),
                    what: str = "kernel") -> str:
    """Normalize the (``backend``, legacy ``use_pallas``) pair to a member of
    ``backends``.

    ``use_pallas`` is a deprecated alias: ``True`` -> ``"pallas"``,
    ``False`` -> ``"reference"``.  Passing both a backend and a conflicting
    alias is an error.  ``skip_dirs`` are package directories whose frames
    the warning's stacklevel walks past (forwarding layers)."""
    if use_pallas is not None:
        alias = "pallas" if use_pallas else "reference"
        if backend is not None and backend != alias:
            raise ValueError(
                f"conflicting {what} selection: backend={backend!r} vs "
                f"use_pallas={use_pallas} (alias for {alias!r}); drop the "
                f"deprecated use_pallas= argument")
        warnings.warn("use_pallas= is deprecated; pass backend='pallas' "
                      "(or 'reference') instead", DeprecationWarning,
                      stacklevel=caller_stacklevel(skip_dirs))
        backend = alias
    backend = backend or "reference"
    if backend not in backends:
        raise ValueError(f"unknown {what} backend {backend!r}; "
                         f"choose from {tuple(backends)}")
    return backend
