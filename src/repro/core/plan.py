"""Hybrid parallelization planner — the paper's central contribution.

A DRL x CFD job on ``n_total`` workers can split into ``n_envs`` parallel
environments x ``n_ranks`` workers per CFD instance (paper §II.D):

    n_total = n_envs * n_ranks

``CostModel`` predicts the wall time of one training episode for any split
from a handful of calibrated constants; ``optimize_plan`` brute-forces the
divisor lattice.  The paper's empirical finding — *the optimum is n_ranks = 1
(favor the environment axis) until I/O saturates* — falls out of the model,
and the same planner maps onto the TPU mesh: n_envs -> "data"(x"pod") axis
size, n_ranks -> "model" axis size.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class ParallelPlan:
    n_total: int
    n_envs: int
    n_ranks: int
    # hosts (jax processes) the "data" axis spans; 1 = the classic
    # single-host plan.  Each host holds n_total // n_processes workers and
    # whole envs only (the halo axis never crosses a host boundary).
    n_processes: int = 1

    def __post_init__(self):
        if min(self.n_total, self.n_envs, self.n_ranks,
               self.n_processes) < 1:
            raise ValueError(f"ParallelPlan fields must all be >= 1: {self}")
        if self.n_envs * self.n_ranks > self.n_total:
            raise ValueError(
                f"over-subscribed plan: n_envs * n_ranks = "
                f"{self.n_envs * self.n_ranks} exceeds the worker budget "
                f"n_total = {self.n_total}: {self}")
        if self.n_processes > 1:
            if self.n_total % self.n_processes:
                raise ValueError(
                    f"n_processes = {self.n_processes} must divide n_total "
                    f"= {self.n_total} (equal worker shards per host): "
                    f"{self}")
            if (self.n_total // self.n_processes) % self.n_ranks:
                raise ValueError(
                    f"each host's {self.n_total // self.n_processes} "
                    f"workers must hold whole envs (a multiple of n_ranks "
                    f"= {self.n_ranks}) so halo exchanges stay intra-host: "
                    f"{self}")

    @property
    def mesh_shape(self) -> Tuple[int, int]:
        """(data, model) axis sizes on a TPU mesh."""
        return (self.n_envs, self.n_ranks)

    @property
    def utilization(self) -> float:
        """Fraction of the worker budget actually busy (1.0 = no idle
        workers; < 1 when n_ranks does not divide n_total)."""
        return self.n_envs * self.n_ranks / self.n_total


@dataclass(frozen=True)
class CostModel:
    """Calibrated per-component costs (seconds unless noted).

    CFD intra-instance scaling (paper Fig. 7): Amdahl serial fraction +
    per-exchange halo cost.  Our TPU mapping has the same structure: per-shard
    compute shrinks ~1/n while halo collectives per Poisson sweep are ~flat.
    """
    # single-worker compute time of one CFD solver step
    t_step_1: float = 5.4e-3
    # serial (non-parallelizable) fraction of a step (setup, reductions, BCs)
    serial_frac: float = 0.06
    # communication cost coefficient (fraction of t_step_1 per log2(n_ranks)):
    # halo exchanges + pressure-solver reductions grow with rank count.
    # Fitted to the paper's Fig. 7 (eff ~90% @2 ranks, <20% @16 ranks).
    comm_frac_log2: float = 0.053
    # DRL policy update cost per episode (amortized over envs: one update)
    t_update: float = 2.0
    # policy inference + misc per actuation period
    t_policy: float = 2.0e-3
    # I/O: bytes written+read per env per actuation period, and shared bw
    io_bytes_per_actuation: float = 5.0e6       # paper: 5.0 MB baseline
    io_bandwidth: float = 6.0e8                 # shared disk, bytes/s (aggregate)
    io_stream_bandwidth: float = 1.5e8          # single-stream ceiling, bytes/s
    io_serial: float = 1.0e-3                   # per-file open/parse overhead
    # multi-env management overhead per episode-round (thread scheduling,
    # batching, sync barriers).  Paper Table II's io-DISABLED column still
    # degrades with n_envs — this term captures it; ~log growth fits.
    mgmt_log_s: float = 38.0
    # episode structure (paper: 100 actuation periods x 50 solver steps)
    steps_per_actuation: int = 50
    actuations_per_episode: int = 100
    # inter-host comms (fleet plans, n_processes > 1): the per-episode
    # trajectory all-gather — the replicated learner exchanges trajectories,
    # never gradients, so traffic is the recorded episode volume.  Latency
    # is per collective (one all-gather per episode), bandwidth the
    # host-to-host link.  Defaults model localhost loopback; autotune
    # refits them from a measured cross-process gather when one exists.
    interhost_latency: float = 2.0e-4
    interhost_bandwidth: float = 1.0e9          # bytes/s

    # ---- component models --------------------------------------------------

    def t_interhost(self, plan: "ParallelPlan",
                    io_bytes: Optional[float] = None) -> float:
        """Per-episode inter-host cost: all-gathering every other host's
        env-shard trajectories (zero for single-host plans)."""
        import math
        p = plan.n_processes
        if p <= 1:
            return 0.0
        v = self.io_bytes_per_actuation if io_bytes is None else io_bytes
        remote = (self.actuations_per_episode * v * plan.n_envs
                  * (p - 1) / p)
        return (self.interhost_latency * math.log2(p)
                + remote / self.interhost_bandwidth)

    def t_step(self, n_ranks: int) -> float:
        """One CFD solver step on n_ranks workers (paper Fig. 7 shape)."""
        import math
        if n_ranks <= 1:
            return self.t_step_1
        par = self.t_step_1 * (1 - self.serial_frac) / n_ranks
        ser = self.t_step_1 * self.serial_frac
        comm = self.t_step_1 * self.comm_frac_log2 * math.log2(n_ranks)
        return par + ser + comm

    def cfd_efficiency(self, n_ranks: int) -> float:
        return self.t_step(1) / (n_ranks * self.t_step(n_ranks))

    def t_io_per_actuation(self, n_envs: int, io_bytes: Optional[float] = None
                           ) -> float:
        """File interface cost per actuation per env.

        All envs dump concurrently into shared storage: below saturation the
        cost is per-env volume/bandwidth + serial overhead; past saturation
        the shared bandwidth is divided (paper Fig. 10's blow-up at
        N_envs > 30)."""
        v = self.io_bytes_per_actuation if io_bytes is None else io_bytes
        if v <= 0:
            return 0.0
        per_env_bw = min(self.io_stream_bandwidth,
                         self.io_bandwidth / max(1, n_envs))
        return v / per_env_bw + self.io_serial

    def t_episode(self, plan: ParallelPlan,
                  io_bytes: Optional[float] = None) -> float:
        """Wall time for ALL envs to finish one episode each + one update.

        Envs run concurrently, so episode wall time is per-env time; the
        number of episodes needed for a fixed training volume shrinks with
        n_envs (handled in t_training)."""
        import math
        t_act = (self.steps_per_actuation * self.t_step(plan.n_ranks)
                 + self.t_policy
                 + self.t_io_per_actuation(plan.n_envs, io_bytes))
        mgmt = self.mgmt_log_s * math.log(max(1, plan.n_envs))
        return (self.actuations_per_episode * t_act + self.t_update + mgmt
                + self.t_interhost(plan, io_bytes))

    def t_training(self, plan: ParallelPlan, n_episodes: int,
                   io_bytes: Optional[float] = None) -> float:
        """Total time to train n_episodes (paper Table I: 3000)."""
        rounds = -(-n_episodes // plan.n_envs)
        return rounds * self.t_episode(plan, io_bytes)

    def speedup(self, plan: ParallelPlan, n_episodes: int = 3000,
                reference: Optional[ParallelPlan] = None,
                io_bytes: Optional[float] = None) -> float:
        ref = reference or ParallelPlan(1, 1, 1)
        return (self.t_training(ref, n_episodes, io_bytes)
                / self.t_training(plan, n_episodes, io_bytes))

    def efficiency(self, plan: ParallelPlan, n_episodes: int = 3000,
                   reference: Optional[ParallelPlan] = None,
                   io_bytes: Optional[float] = None) -> float:
        return (self.speedup(plan, n_episodes, reference, io_bytes)
                / (plan.n_envs * plan.n_ranks))


def enumerate_plans(n_total: int,
                    max_processes: int = 1) -> List[ParallelPlan]:
    """All (n_envs = n_total // n_ranks, n_ranks) splits of the budget,
    ordered full-utilization first (then by n_ranks) so that downstream
    stable min()/sort() calls resolve cost ties toward busy workers.

    ``max_processes > 1`` additionally enumerates fleet layouts: every
    process count that divides ``n_total`` with whole envs per host (the
    intra-host halo constraint), fewest hosts first within each split —
    a tie on modeled cost resolves toward not paying inter-host comms."""
    out = []
    for r in range(1, n_total + 1):
        procs = [1] + [p for p in range(2, max(1, max_processes) + 1)
                       if n_total % p == 0 and (n_total // p) % r == 0]
        out.extend(ParallelPlan(n_total, n_total // r, r, p) for p in procs)
    out.sort(key=lambda pl: (-pl.utilization, pl.n_ranks, pl.n_processes))
    return out


def optimize_plan(n_total: int, model: CostModel, n_episodes: int = 3000,
                  io_bytes: Optional[float] = None,
                  max_processes: int = 1) -> ParallelPlan:
    """Brute-force the (n_envs, n_ranks[, n_processes]) divisor lattice;
    minimize train time, breaking exact cost ties toward full utilization
    (no idle workers), then toward fewer ranks per env (the paper's
    default axis), then toward fewer hosts (no inter-host comms)."""
    plans = enumerate_plans(n_total, max_processes)
    return min(plans, key=lambda p: (model.t_training(p, n_episodes,
                                                      io_bytes),
                                     -p.utilization, p.n_ranks,
                                     p.n_processes))
