"""Measured autotuning: turn the paper's advisory cost model into a plan
chosen from timings on THIS host.

The paper picks the hybrid split ``n_total = n_envs x n_ranks`` from
constants calibrated to its own cluster (Tables I/II, Fig. 7).  This module
re-measures those constants where the code actually runs and makes the
result executable:

  1. ``measure_components`` times the real building blocks — one single-env
     solver step (reference backend), the halo-backend step at each feasible
     ``n_ranks``, policy inference, one PPO update, one trajectory-sink
     write.
  2. ``refit_cost_model`` refits ``CostModel``'s constants to those
     measurements with the same least-squares machinery that calibrates to
     the paper (``scaling_model.least_squares_fit``).
  3. ``optimize_plan`` brute-forces the divisor lattice on the refit model.
  4. The result is a ``ResolvedPlan`` — (n_envs, n_ranks, mesh shape,
     Poisson backend) — plus a JSON artifact (schema ``repro.autotune/v3``)
     of measured-vs-predicted component times, the host analogue of the
     paper's Table I / Fig. 7 columns.  Single-rank plans additionally
     compete the fused actuation-interval path (``backend="fused"``)
     against the reference scan on measured whole-interval times.

``resolve_plan`` is the single entry point engines and training loops use to
accept ``plan="auto" | ParallelPlan | ResolvedPlan``.
"""
from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.plan import CostModel, ParallelPlan, enumerate_plans, \
    optimize_plan

# v2: measured.t_poisson_layouts + plan.layout became required fields
# v3: measured.t_interval_backends (fused actuation-interval candidate)
# v4: measured.t_interhost + plan.n_processes (fleet inter-host cost term)
AUTOTUNE_SCHEMA = "repro.autotune/v4"

# dt's per probe interval when timing t_interval_backends: long enough that
# the fused path's per-interval amortization (single pack/unpack, carried
# planes) shows, short enough to keep the probe cheap
INTERVAL_PROBE_STEPS = 10


# ---------------------------------------------------------------------------
# resolved plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ResolvedPlan:
    """An executable hybrid configuration: the chosen split, the Poisson
    backend that realizes its n_ranks, and the cost model behind the
    choice.  ``measurements`` carries the JSON-artifact dict when the plan
    came from ``autotune``."""
    plan: ParallelPlan
    backend: str                       # member of cfd.poisson.BACKENDS
    model: CostModel = field(default_factory=CostModel)
    source: str = "explicit"           # "explicit" | "auto"
    measurements: Optional[Dict[str, Any]] = None
    # single-rank sweep storage layout ("packed" | "full"); autotune sets it
    # from host timings, explicit plans keep the packed default (it is never
    # slower in practice and bit-compatible with the full-grid oracle)
    layout: str = "packed"

    @property
    def n_envs(self) -> int:
        return self.plan.n_envs

    @property
    def n_ranks(self) -> int:
        return self.plan.n_ranks

    @property
    def mesh_shape(self) -> Tuple[int, int]:
        return self.plan.mesh_shape

    @property
    def n_processes(self) -> int:
        return self.plan.n_processes

    def build_mesh(self, devices=None, span_processes=None):
        """The executable mesh.  In a live multi-process fleet
        (``jax.process_count() > 1``) the default spans the "data" axis
        over every process regardless of the plan's *modeled*
        ``n_processes`` — the actual topology always wins."""
        from repro.launch.mesh import mesh_for_plan
        return mesh_for_plan(self.plan, devices=devices,
                             span_processes=span_processes)

    def describe(self) -> str:
        fleet = (f", spanning {self.n_processes} hosts"
                 if self.n_processes > 1 else "")
        return (f"plan[{self.source}]: n_envs x n_ranks = "
                f"{self.n_envs} x {self.n_ranks} of {self.plan.n_total} "
                f"workers (utilization {self.plan.utilization:.0%}), "
                f"poisson backend '{self.backend}' "
                f"(layout '{self.layout}'), mesh "
                f"(data, model) = {self.mesh_shape}{fleet}")


def default_backend(n_ranks: int, nx: Optional[int] = None) -> str:
    """Poisson backend implied by a split: n_ranks > 1 needs the explicit
    halo decomposition; single-rank runs use the Pallas kernel on TPU (even
    widths) and the jnp reference elsewhere.  With ``nx`` unknown (no grid
    in scope — e.g. engine-side resolution) the conservative "reference"
    is chosen: it is correct on every grid."""
    import jax
    if n_ranks > 1:
        return "halo"
    if nx is not None and jax.default_backend() == "tpu" and nx % 2 == 0:
        return "pallas"
    return "reference"


def resolve_plan(plan, *, n_total: Optional[int] = None, grid=None,
                 **autotune_kw) -> ResolvedPlan:
    """Normalize any plan spelling to a ResolvedPlan.

    plan: "auto" (measure + optimize on this host), a ParallelPlan, an
    (n_envs, n_ranks) tuple, or an existing ResolvedPlan (passed through).
    ``grid``/``autotune_kw`` parameterize the "auto" measurement; with no
    grid in scope the backend choice is conservative (never "pallas",
    whose even-nx requirement can't be checked).
    """
    if isinstance(plan, ResolvedPlan):
        return plan
    if isinstance(plan, str):
        if plan != "auto":
            raise ValueError(f"unknown plan spec {plan!r}; expected 'auto', "
                             f"a ParallelPlan, or an (n_envs, n_ranks) pair")
        return autotune(n_total=n_total, grid=grid, **autotune_kw)
    if isinstance(plan, (tuple, list)):
        n_envs, n_ranks = plan
        plan = ParallelPlan(n_total or n_envs * n_ranks, n_envs, n_ranks)
    if not isinstance(plan, ParallelPlan):
        raise ValueError(f"cannot resolve plan from {plan!r}")
    nx = grid.nx if grid is not None else None
    return ResolvedPlan(plan=plan,
                        backend=default_backend(plan.n_ranks, nx),
                        source="explicit")


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

def _time(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall time (s) of a jitted callable (same protocol as
    benchmarks/common.time_fn, importable from the package)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def candidate_ranks(n_total: int, nx: int, n_devices: int) -> List[int]:
    """Rank counts worth timing: divide the worker budget AND the grid
    width, and fit on the host's devices."""
    return [r for r in range(1, n_total + 1)
            if n_total % r == 0 and nx % r == 0 and r <= n_devices]


def measure_components(grid=None, *, n_total: Optional[int] = None,
                       ppo_cfg=None, horizon: int = 32, n_envs_probe: int = 4,
                       iters: int = 3, seed: int = 0,
                       sink_dir: Optional[str] = None) -> Dict[str, Any]:
    """Time the real components of one training episode on this host.

    Returns a dict with per-component times (seconds):
      t_step_ranks   {n_ranks: solver-step time}; n_ranks=1 is the
                     reference backend, >1 the halo backend on a (1, r)
                     mesh — the paper's Fig. 7 measurement
      t_poisson_layouts  {layout: time} for one pressure solve in packed vs
                     full-grid checkerboard storage on this grid — the
                     measured basis for the plan's single-rank layout pick
      t_interval_backends  {backend: time} for one ``INTERVAL_PROBE_STEPS``-dt
                     actuation interval through ``solver.step_interval`` —
                     the reference scan vs the fused interval path; the
                     measured basis for picking backend="fused" on
                     single-rank plans
      t_policy       one policy inference (single obs)
      t_update       one PPO update on an (n_envs_probe * horizon) batch
      io             bytes + seconds for one episode spill through the
                     binary TrajectorySink -> per-actuation volume and
                     single-stream bandwidth
      t_interhost    one episode-sized trajectory all-gather across the
                     fleet — a REAL cross-process timing when this process
                     is part of one (jax.process_count() > 1), otherwise an
                     estimate from the CostModel's loopback defaults
                     (flagged ``estimated: true``)
    """
    import jax
    import jax.numpy as jnp
    from repro.cfd import poisson, solver
    from repro.cfd.grid import GridConfig, build_geometry
    from repro.cfd.probes import layout_size
    from repro.drl import networks
    from repro.drl.engine import FileSink
    from repro.drl.ppo import Batch, PPOConfig, make_optimizer, ppo_update
    from repro.drl.rollout import Trajectory
    from repro.launch.mesh import mesh_for_plan

    grid = grid or GridConfig()
    n_devices = len(jax.devices())
    n_total = n_total or n_devices
    ppo_cfg = ppo_cfg or PPOConfig()
    geom = build_geometry(grid)
    ga = solver.geom_to_arrays(geom)
    st = solver.init_state(grid, geom)
    key = jax.random.PRNGKey(seed)

    # -- CFD solver step per rank count (Fig. 7's axis).  Each rank count is
    # timed with the backend a plan with that n_ranks would actually
    # execute (default_backend), so t_step_1 on TPU measures the Pallas
    # kernel, not the reference path the plan would never run.
    t_step_ranks: Dict[int, float] = {}
    step_backends: Dict[int, str] = {}
    for r in candidate_ranks(n_total, grid.nx, n_devices):
        backend = default_backend(r, grid.nx)
        mesh_r = mesh_for_plan((1, r)) if r > 1 else None
        fn = lambda s, b=backend, m=mesh_r: solver.step(
            grid, ga, s, jnp.float32(0.0), backend=b, mesh=m)
        t_step_ranks[r] = _time(lambda f=fn: f(st), iters=iters)
        step_backends[r] = backend

    # -- sweep storage layout: packed checkerboard vs full-grid oracle ------
    # Timed on the pressure solve alone (the hot spot the layout changes),
    # at the grid's own iteration budget.
    rhs = jax.random.normal(jax.random.PRNGKey(seed), (grid.ny, grid.nx))
    t_poisson_layouts: Dict[str, float] = {}
    for layout in ("packed", "full"):
        if layout == "packed" and grid.nx % 2:
            continue
        t_poisson_layouts[layout] = _time(
            lambda r, b=layout: poisson.solve(r, grid.dx, grid.dy,
                                              iters=grid.poisson_iters,
                                              omega=grid.poisson_omega,
                                              backend=b),
            rhs, iters=iters)

    # -- the actuation interval: reference scan vs fused path ----------------
    # Timed as whole intervals (what the env hot loop actually executes).
    # Odd widths are skipped for "fused": it would fall back to the
    # reference scan anyway (and warn), so the candidate adds nothing.
    t_interval_backends: Dict[str, float] = {}
    interval_candidates = ["reference"] + (["fused"] if grid.nx % 2 == 0
                                           else [])
    for b in interval_candidates:
        fn = jax.jit(lambda s, b=b: solver.step_interval(
            grid, ga, s, jnp.float32(0.0), INTERVAL_PROBE_STEPS, backend=b))
        t_interval_backends[b] = _time(fn, st, iters=iters)

    # -- policy inference + PPO update --------------------------------------
    obs_dim = layout_size("ring149")
    pcfg = networks.PolicyConfig(obs_dim=obs_dim)
    params = networks.init_actor_critic(pcfg, key)
    obs = jnp.zeros((obs_dim,))
    t_policy = _time(jax.jit(lambda p, o, k: networks.sample_action(p, o, k)),
                     params, obs, key, iters=iters)

    n_rows = n_envs_probe * horizon
    batch = Batch(obs=jnp.zeros((n_rows, obs_dim)),
                  act=jnp.zeros((n_rows, 1)),
                  logp_old=jnp.zeros((n_rows,)),
                  adv=jnp.ones((n_rows,)),
                  ret=jnp.zeros((n_rows,)))
    optimizer = make_optimizer(ppo_cfg)
    opt_state = optimizer.init(params)
    upd = jax.jit(lambda p, o, b, k: ppo_update(ppo_cfg, optimizer, p, o, b,
                                                k, jnp.int32(0)))
    t_update = _time(upd, params, opt_state, batch, key, iters=iters)

    # -- trajectory spill (the paper's file-interface axis) ------------------
    import tempfile
    own_dir = sink_dir is None
    root = sink_dir or tempfile.mkdtemp(prefix="autotune_io_")
    sink = FileSink(root, codec="binary")
    traj = Trajectory(obs=np.zeros((n_envs_probe, horizon, obs_dim),
                                   np.float32),
                      act=np.zeros((n_envs_probe, horizon, 1), np.float32),
                      logp=np.zeros((n_envs_probe, horizon), np.float32),
                      reward=np.zeros((n_envs_probe, horizon), np.float32),
                      cd=np.zeros((n_envs_probe, horizon), np.float32),
                      cl=np.zeros((n_envs_probe, horizon), np.float32),
                      last_obs=np.zeros((n_envs_probe, obs_dim), np.float32))
    t0 = time.perf_counter()
    nbytes = sink.write(0, traj)
    t_io = max(time.perf_counter() - t0, 1e-9)
    if own_dir:
        sink.cleanup()

    # -- inter-host all-gather (the fleet cost term) -------------------------
    # Traffic scale: one probe episode's trajectory payload (what the fleet
    # engine all-gathers after every distributed rollout).
    procs = jax.process_count()
    if procs > 1:
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        mesh = mesh_for_plan((procs, 1), span_processes=True)
        m = max(1, int(nbytes) // (4 * procs))
        host = np.zeros((procs, m), np.float32)
        sharded = jax.make_array_from_callback(
            host.shape, NamedSharding(mesh, P("data")),
            lambda idx: host[idx])
        gather = jax.jit(lambda x: x,
                         out_shardings=NamedSharding(mesh, P()))
        t_gather = _time(gather, sharded, iters=iters)
        t_interhost = {"processes": procs, "bytes": float(nbytes),
                       "seconds": t_gather,
                       "bandwidth": float(nbytes) * (procs - 1) / procs
                       / max(t_gather, 1e-9),
                       "estimated": False}
    else:
        base = CostModel()
        t_interhost = {"processes": 1, "bytes": float(nbytes),
                       "seconds": base.interhost_latency
                       + float(nbytes) / base.interhost_bandwidth,
                       "bandwidth": base.interhost_bandwidth,
                       "estimated": True}

    return {
        "n_total": n_total,
        "n_devices": n_devices,
        "grid": {"res": grid.res, "nx": grid.nx, "ny": grid.ny},
        "horizon": horizon,
        "n_envs_probe": n_envs_probe,
        "t_step_ranks": t_step_ranks,
        "t_step_backends": step_backends,
        "t_poisson_layouts": t_poisson_layouts,
        "t_interval_backends": t_interval_backends,
        "interval_probe_steps": INTERVAL_PROBE_STEPS,
        "t_policy": t_policy,
        "t_update": t_update,
        "io": {"bytes_per_episode_env": nbytes / n_envs_probe,
               "bytes_per_actuation": nbytes / (n_envs_probe * horizon),
               "stream_bandwidth": nbytes / t_io,
               "write_seconds": t_io},
        "t_interhost": t_interhost,
    }


# ---------------------------------------------------------------------------
# refit
# ---------------------------------------------------------------------------

def refit_cost_model(measured: Dict[str, Any],
                     base: Optional[CostModel] = None) -> CostModel:
    """CostModel with constants refit to host measurements.

    The CFD scaling constants (t_step_1, serial_frac, comm_frac_log2) come
    from a least-squares fit of the Amdahl + halo-cost shape to the measured
    per-rank step times — the same machinery ``calibrate_to_paper`` uses on
    the paper's tables (``scaling_model.least_squares_fit``).  Directly
    measured components (t_policy, t_update, I/O volume and stream
    bandwidth) replace their constants outright; the aggregate disk
    bandwidth and the per-episode management overhead — unmeasurable from
    one probe — keep the paper-calibrated *ratios*, scaled by the measured
    stream bandwidth and update time respectively.
    """
    from repro.core.scaling_model import least_squares_fit

    base = base or CostModel()
    steps = {int(k): float(v) for k, v in measured["t_step_ranks"].items()}
    t1 = steps.get(1, base.t_step_1)

    if len(steps) >= 3:
        def resid(x):
            t1_, s, c = np.abs(x)
            s = min(s, 0.9)
            m = dataclasses.replace(base, t_step_1=t1_, serial_frac=s,
                                    comm_frac_log2=c)
            return [m.t_step(r) / t - 1.0 for r, t in steps.items()]
        x0 = [t1, base.serial_frac, base.comm_frac_log2]
        t1_f, s_f, c_f = least_squares_fit(resid, x0)
        fit = dict(t_step_1=float(t1_f),
                   serial_frac=float(min(s_f, 0.9)),
                   comm_frac_log2=float(c_f))
    elif len(steps) == 2:
        # two points: pin serial_frac, solve the comm coefficient exactly
        r2 = max(r for r in steps if r > 1)
        m1 = dataclasses.replace(base, t_step_1=t1)
        comm = max(0.0, (steps[r2] - m1.t_step(r2)) / (t1 * np.log2(r2))
                   + base.comm_frac_log2)
        fit = dict(t_step_1=t1, serial_frac=base.serial_frac,
                   comm_frac_log2=float(comm))
    else:
        fit = dict(t_step_1=t1)

    io = measured["io"]
    bw_scale = io["stream_bandwidth"] / base.io_stream_bandwidth
    mgmt_scale = measured["t_update"] / base.t_update
    # a REAL cross-process gather timing refits the inter-host bandwidth;
    # the single-process estimate keeps the model's loopback default
    ih = measured.get("t_interhost") or {}
    interhost = ({"interhost_bandwidth": float(ih["bandwidth"])}
                 if ih and not ih.get("estimated", True) else {})
    return dataclasses.replace(
        base,
        t_policy=measured["t_policy"],
        t_update=measured["t_update"],
        io_bytes_per_actuation=io["bytes_per_actuation"],
        io_stream_bandwidth=io["stream_bandwidth"],
        io_bandwidth=base.io_bandwidth * bw_scale,
        mgmt_log_s=base.mgmt_log_s * mgmt_scale,
        **interhost, **fit)


# ---------------------------------------------------------------------------
# the autotuner
# ---------------------------------------------------------------------------

def autotune(n_total: Optional[int] = None, *, grid=None, ppo_cfg=None,
             n_episodes: int = 3000, io_bytes: Optional[float] = None,
             horizon: int = 32, iters: int = 3, seed: int = 0,
             artifact: Optional[str] = None, base: Optional[CostModel] = None,
             max_processes: Optional[int] = None,
             smoke: bool = False) -> ResolvedPlan:
    """Measure -> refit -> optimize -> ResolvedPlan (+ JSON artifact).

    ``n_total`` defaults to the host's device count (the executable budget).
    ``max_processes`` caps the fleet layouts the optimizer may pick
    (default: however many processes this fleet actually has — a standalone
    run never *plans* hosts it cannot execute).  ``artifact`` writes the
    measured-vs-predicted record; ``smoke`` shrinks the probe (1 timing
    iteration, short horizon) for CI.
    """
    import jax

    from repro.cfd.grid import GridConfig

    grid = grid or GridConfig(res=6)
    if smoke:
        iters, horizon = 1, 8
    if max_processes is None:
        max_processes = jax.process_count()
    measured = measure_components(grid, n_total=n_total, ppo_cfg=ppo_cfg,
                                  horizon=horizon, iters=iters, seed=seed)
    n_total = measured["n_total"]
    model = refit_cost_model(measured, base=base)
    # optimize over the EXECUTABLE lattice only: a rank count that was not
    # measurable (does not divide nx, or exceeds the host's devices) cannot
    # be run by the halo backend either, so picking it would crash at
    # execution time no matter how good the model thinks it is.
    feasible = set(candidate_ranks(n_total, grid.nx,
                                   measured["n_devices"]))
    # fleet feasibility: each host must fit its worker shard — this is what
    # decides how many hosts are WORTH it: a budget that fits one host keeps
    # n_processes = 1 (inter-host comms are pure cost), a larger one takes
    # the fewest hosts whose added t_interhost the model tolerates
    local = jax.local_device_count()
    plans = [p for p in enumerate_plans(n_total, max_processes)
             if p.n_ranks in feasible
             and n_total // p.n_processes <= local]
    if not plans:
        raise ValueError(
            f"no executable plan: n_total = {n_total} workers cannot be "
            f"placed on {max_processes} host(s) x {local} local devices")
    best = min(plans, key=lambda p: (model.t_training(p, n_episodes,
                                                      io_bytes),
                                     -p.utilization, p.n_ranks,
                                     p.n_processes))
    backend = default_backend(best.n_ranks, grid.nx)
    # the measured layout pick: on single-rank CPU plans the chosen layout
    # IS the backend (both are valid poisson.solve backends); halo/pallas
    # plans run packed internally whenever the grid allows it
    layouts = measured["t_poisson_layouts"]
    layout = min(layouts, key=layouts.get) if layouts else "full"
    if backend == "reference":
        backend = layout
    # single-rank plans may upgrade to the fused actuation-interval path when
    # the measured interval time beats the reference scan (multi-rank plans
    # need the halo decomposition, which the fused carry cannot serve)
    intervals = measured.get("t_interval_backends", {})
    if (best.n_ranks == 1 and "fused" in intervals
            and intervals["fused"] <= min(intervals.values())):
        backend = "fused"

    steps = {int(k): float(v) for k, v in measured["t_step_ranks"].items()}
    predicted = {r: model.t_step(r) for r in steps}
    record = {
        "schema": AUTOTUNE_SCHEMA,
        "measured": measured,
        "fitted": {f.name: getattr(model, f.name)
                   for f in dataclasses.fields(model)},
        "predicted": {
            "t_step_ranks": predicted,
            "rel_err_t_step": {r: predicted[r] / steps[r] - 1.0
                               for r in steps},
            "t_episode_s": model.t_episode(best, io_bytes),
        },
        "plan": {
            "n_total": n_total,
            "n_envs": best.n_envs,
            "n_ranks": best.n_ranks,
            "n_processes": best.n_processes,
            "mesh_shape": list(best.mesh_shape),
            "utilization": best.utilization,
            "backend": backend,
            "layout": layout,
        },
        "candidates": [
            {"n_envs": p.n_envs, "n_ranks": p.n_ranks,
             "n_processes": p.n_processes,
             "utilization": p.utilization,
             "t_training_s": model.t_training(p, n_episodes, io_bytes)}
            for p in plans
        ],
    }
    if artifact:
        path = Path(artifact)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(record, indent=1, default=float))
    return ResolvedPlan(plan=best, backend=backend, model=model,
                        source="auto", measurements=record, layout=layout)


def validate_artifact(record: Dict[str, Any]) -> None:
    """Raise ValueError unless ``record`` matches the v1 artifact schema
    (used by the CI autotune smoke and the benchmark harness)."""
    if record.get("schema") != AUTOTUNE_SCHEMA:
        raise ValueError(f"bad schema tag: {record.get('schema')!r} != "
                         f"{AUTOTUNE_SCHEMA!r}")
    for key in ("measured", "fitted", "predicted", "plan", "candidates"):
        if key not in record:
            raise ValueError(f"artifact missing {key!r}")
    for key in ("t_step_ranks", "t_poisson_layouts", "t_interval_backends",
                "t_policy", "t_update", "io", "t_interhost"):
        if key not in record["measured"]:
            raise ValueError(f"artifact.measured missing {key!r}")
    plan = record["plan"]
    for key in ("n_total", "n_envs", "n_ranks", "n_processes", "mesh_shape",
                "utilization", "backend", "layout"):
        if key not in plan:
            raise ValueError(f"artifact.plan missing {key!r}")
    if plan["n_envs"] * plan["n_ranks"] > plan["n_total"]:
        raise ValueError(f"over-subscribed plan in artifact: {plan}")
    if not record["candidates"]:
        raise ValueError("artifact has no candidate plans")
