"""Asynchronous training — the paper's §IV future-work pointer, realized.

The synchronous loop serializes [collect episode] -> [PPO update]; the async
variant overlaps them: episode *e* is collected with the policy from episode
*e-1* while the update for *e-1*'s trajectories runs concurrently.  PPO's
importance ratio r_t(theta) absorbs the one-step staleness (the trajectories
carry their behaviour-policy log-probs).

The double-buffered loop itself is ``RolloutEngine.run_async`` (drl/engine.py)
— JAX async dispatch with the stale batch and optimizer state donated to the
update.  On this 1-core host the overlap cannot reduce wall time, so this
module validates the ALGORITHMIC half (stale-trajectory updates still learn —
tests/test_drl_async.py) and ``async_speedup`` quantifies the SYSTEMS half via
the calibrated cost model: with updates hidden behind collection,
t_episode -> max(t_collect, t_update) + interface costs.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.plan import CostModel, ParallelPlan
from repro.drl import networks
from repro.drl.engine import EngineConfig, RolloutEngine
from repro.drl.ppo import PPOConfig


def train_async(env_step_fn, pcfg: networks.PolicyConfig, ppo_cfg: PPOConfig,
                st0_b, obs0_b, *, n_envs: int, horizon: int, episodes: int,
                seed: int = 0, sink=None):
    """Stale-gradient PPO: updates always consume the PREVIOUS episode's
    trajectories (collected under the then-current policy)."""
    engine = RolloutEngine(
        env_step_fn,
        EngineConfig(n_envs=n_envs, horizon=horizon,
                     gamma=ppo_cfg.gamma, lam=ppo_cfg.lam),
        sink=sink)
    params, optimizer, opt_state, key = engine.init(pcfg, ppo_cfg, seed)
    params, _, returns = engine.run_async(params, opt_state, ppo_cfg,
                                          optimizer, st0_b, obs0_b, key,
                                          episodes)
    return params, returns


def async_speedup(model: CostModel, plan: ParallelPlan,
                  n_episodes: int = 3000,
                  io_bytes: Optional[float] = None) -> Dict[str, float]:
    """Systems gain of hiding the update behind collection (cost model)."""
    t_sync = model.t_training(plan, n_episodes, io_bytes)
    rounds = -(-n_episodes // plan.n_envs)
    t_ep_sync = model.t_episode(plan, io_bytes)
    t_collect = t_ep_sync - model.t_update
    t_ep_async = max(t_collect, model.t_update)
    t_async = rounds * t_ep_async + model.t_update   # drain the last update
    return {"t_sync_h": t_sync / 3600, "t_async_h": t_async / 3600,
            "speedup": t_sync / t_async}
