"""Asynchronous training — the paper's §IV future-work pointer, prototyped.

The synchronous loop serializes [collect episode] -> [PPO update]; the async
variant overlaps them: episode *e* is collected with the policy from episode
*e-1* while the update for *e-1*'s trajectories runs concurrently.  PPO's
importance ratio r_t(theta) absorbs the one-step staleness (the trajectories
carry their behaviour-policy log-probs).

On this 1-core host the overlap cannot reduce wall time, so this module
validates the ALGORITHMIC half (stale-trajectory updates still learn —
tests/test_drl_async.py) and `async_speedup` quantifies the SYSTEMS half via
the calibrated cost model: with updates hidden behind collection,
t_episode -> max(t_collect, t_update) + interface costs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import CostModel, ParallelPlan
from repro.drl import networks, rollout
from repro.drl.gae import gae_batch
from repro.drl.ppo import Batch, PPOConfig, make_optimizer, ppo_update


def train_async(env_step_fn, pcfg: networks.PolicyConfig, ppo_cfg: PPOConfig,
                st0_b, obs0_b, *, n_envs: int, horizon: int, episodes: int,
                seed: int = 0):
    """Stale-gradient PPO: updates always consume the PREVIOUS episode's
    trajectories (collected under the then-current policy)."""
    key = jax.random.PRNGKey(seed)
    key, kp = jax.random.split(key)
    params = networks.init_actor_critic(pcfg, kp)
    opt = make_optimizer(ppo_cfg)
    opt_state = opt.init(params)
    step = jnp.int32(0)

    @jax.jit
    def collect(params, key):
        _, traj = rollout.rollout_batch(env_step_fn, params, st0_b, obs0_b,
                                        key, horizon, n_envs)
        values = networks.value(params, traj.obs)
        last_v = networks.value(params, traj.last_obs)
        adv, ret = gae_batch(traj.reward, values, last_v,
                             gamma=ppo_cfg.gamma, lam=ppo_cfg.lam)
        flat = lambda x: x.reshape((-1,) + x.shape[2:])
        return Batch(flat(traj.obs), flat(traj.act), flat(traj.logp),
                     flat(adv), flat(ret)), traj

    @jax.jit
    def update(params, opt_state, batch, key, step):
        return ppo_update(ppo_cfg, opt, params, opt_state, batch, key, step)

    pending: Optional[Batch] = None     # trajectories awaiting their update
    returns = []
    for ep in range(episodes):
        key, kr, ku = jax.random.split(key, 3)
        # (in a real deployment these two lines run CONCURRENTLY)
        batch, traj = collect(params, kr)        # with the *stale* params
        if pending is not None:
            params, opt_state, step, _ = update(params, opt_state, pending,
                                                ku, step)
        pending = batch
        returns.append(float(jnp.mean(jnp.sum(traj.reward, axis=1))))
    return params, np.asarray(returns)


def async_speedup(model: CostModel, plan: ParallelPlan,
                  n_episodes: int = 3000,
                  io_bytes: Optional[float] = None) -> Dict[str, float]:
    """Systems gain of hiding the update behind collection (cost model)."""
    t_sync = model.t_training(plan, n_episodes, io_bytes)
    rounds = -(-n_episodes // plan.n_envs)
    t_ep_sync = model.t_episode(plan, io_bytes)
    t_collect = t_ep_sync - model.t_update
    t_ep_async = max(t_collect, model.t_update)
    t_async = rounds * t_ep_async + model.t_update   # drain the last update
    return {"t_sync_h": t_sync / 3600, "t_async_h": t_async / 3600,
            "speedup": t_sync / t_async}
