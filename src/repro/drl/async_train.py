"""Asynchronous training — the paper's §IV future-work pointer, realized.

The synchronous loop serializes [collect episode] -> [PPO update]; the async
variant overlaps them: episode *e* is collected with the policy from episode
*e-1* while the update for *e-1*'s trajectories runs concurrently.  PPO's
importance ratio r_t(theta) absorbs the one-step staleness (the trajectories
carry their behaviour-policy log-probs).

The double-buffered loop itself is ``RolloutEngine.run_async`` (drl/engine.py)
— JAX async dispatch with the stale batch and optimizer state donated to the
update.  On this 1-core host the overlap cannot reduce wall time, so this
module validates the ALGORITHMIC half (stale-trajectory updates still learn —
tests/test_drl_async.py) and ``async_speedup`` quantifies the SYSTEMS half via
the calibrated cost model: with updates hidden behind collection,
t_episode -> max(t_collect, t_update) + interface costs.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt_mod
from repro.core.plan import CostModel, ParallelPlan
from repro.drl import networks
from repro.drl import train_state as ts_mod
from repro.drl.engine import EngineConfig, RolloutEngine
from repro.drl.ppo import PPOConfig, make_optimizer


def train_async(env_step_fn, pcfg: networks.PolicyConfig, ppo_cfg: PPOConfig,
                st0_b, obs0_b, *, n_envs: int, horizon: int, episodes: int,
                seed: int = 0, sink=None, ckpt_dir: Optional[str] = None,
                ckpt_every: int = 10, ckpt_keep: int = 3, resume=None,
                watchdog=True, _rollbacks: int = 0):
    """Stale-gradient PPO: updates always consume the PREVIOUS episode's
    trajectories (collected under the then-current policy).

    Fault tolerance mirrors ``train()``: ``ckpt_dir`` enables periodic
    ``AsyncCheckpointer`` saves of the TrainState every ``ckpt_every``
    episodes (without breaking the collect/update overlap — the one
    in-flight update is not part of the snapshot, see
    ``RolloutEngine.run_async``), and ``resume`` restarts from a checkpoint
    path / directory / "auto".  ``episodes`` is the TOTAL target.

    ``watchdog`` mirrors ``TrainConfig.watchdog``: the overlapped loop
    discards update metrics, so the async watchdog screens the per-episode
    return (plus injected faults) and rolls back to the last checkpoint —
    or restarts fresh without ``ckpt_dir`` — bounded by
    ``WatchdogConfig.max_rollbacks``."""
    from repro.drl.health import DivergenceError
    from repro.drl.train import resolve_watchdog
    wd = resolve_watchdog(watchdog)
    engine = RolloutEngine(
        env_step_fn,
        EngineConfig(n_envs=n_envs, horizon=horizon,
                     gamma=ppo_cfg.gamma, lam=ppo_cfg.lam),
        sink=sink)
    src = ts_mod.resolve_resume(resume, ckpt_dir)
    step = None
    rewards: list = []
    if src is None:
        params, optimizer, opt_state, key = engine.init(pcfg, ppo_cfg, seed)
    else:
        optimizer = make_optimizer(ppo_cfg)
        ts, meta = ts_mod.load_train_state(src)
        mismatch = [f"{k}: checkpoint={meta[k]!r} current={v!r}"
                    for k, v in (("n_envs", n_envs), ("horizon", horizon))
                    if meta.get(k) is not None and meta[k] != v]
        if mismatch:
            raise ckpt_mod.CheckpointError(
                "checkpoint is incompatible with this train_async call:\n  "
                + "\n  ".join(mismatch))
        params = jax.tree.map(jnp.asarray, ts.params)
        opt_state = jax.tree.map(jnp.asarray, ts.opt_state)
        key, step = jnp.asarray(ts.key), ts.step
        if ts.env_state is not None:
            st0_b = jax.tree.map(jnp.asarray, ts.env_state)
        if ts.obs is not None:
            obs0_b = jnp.asarray(ts.obs)
        rewards = [float(x) for x in np.asarray(
            ts.history.get("reward", ()))]
        engine.episode = int(ts.episode)

    remaining = episodes - engine.episode
    if remaining <= 0:
        return params, np.asarray(rewards)

    ckpter = (ckpt_mod.AsyncCheckpointer(ckpt_dir, keep=ckpt_keep)
              if ckpt_dir else None)

    def on_episode(traj, metrics):
        r = float(jnp.mean(jnp.sum(traj.reward, axis=1)))
        rewards.append(r)
        if wd is not None:
            ep = len(rewards) - 1
            reason = wd.observe(None, episode=ep)
            if reason is None and not np.isfinite(r):
                reason = f"non-finite episode return ({r})"
            if reason is not None:
                raise DivergenceError(ep, reason)

    def on_state(carry):
        done = engine.episode         # episodes collected so far
        snap = ts_mod.TrainState(
            params=carry.params, opt_state=carry.opt_state, key=carry.key,
            step=carry.step, episode=jnp.int32(done), env_state=st0_b,
            obs=obs0_b, history={"reward": np.asarray(rewards)})
        ckpter.save(done, ts_mod.to_tree(snap),
                    metadata=ts_mod.state_metadata(
                        snap, {"n_envs": n_envs, "horizon": horizon}))

    divergence = None
    try:
        params, _, _ = engine.run_async(
            params, opt_state, ppo_cfg, optimizer, st0_b, obs0_b, key,
            remaining, step=step, on_episode=on_episode,
            on_state=on_state if ckpter is not None else None,
            state_every=ckpt_every)
    except DivergenceError as e:
        divergence = e
    finally:
        if ckpter is not None:
            ckpter.close()

    if divergence is not None:
        max_rb = wd.cfg.max_rollbacks if wd else 0
        if _rollbacks >= max_rb:
            raise RuntimeError(
                f"async training diverged and {_rollbacks} rollback(s) did "
                f"not clear it ({divergence}); a deterministic divergence "
                f"replays identically — adjust the PPO config or raise "
                f"WatchdogConfig.max_rollbacks") from divergence
        return train_async(
            env_step_fn, pcfg, ppo_cfg, st0_b, obs0_b, n_envs=n_envs,
            horizon=horizon, episodes=episodes, seed=seed, sink=sink,
            ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, ckpt_keep=ckpt_keep,
            resume="auto" if ckpt_dir else None, watchdog=watchdog,
            _rollbacks=_rollbacks + 1)
    return params, np.asarray(rewards)


def async_speedup(model: CostModel, plan: ParallelPlan,
                  n_episodes: int = 3000,
                  io_bytes: Optional[float] = None) -> Dict[str, float]:
    """Systems gain of hiding the update behind collection (cost model)."""
    t_sync = model.t_training(plan, n_episodes, io_bytes)
    rounds = -(-n_episodes // plan.n_envs)
    t_ep_sync = model.t_episode(plan, io_bytes)
    t_collect = t_ep_sync - model.t_update
    t_ep_async = max(t_collect, model.t_update)
    t_async = rounds * t_ep_async + model.t_update   # drain the last update
    return {"t_sync_h": t_sync / 3600, "t_async_h": t_async / 3600,
            "speedup": t_sync / t_async}
