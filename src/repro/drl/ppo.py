"""Proximal Policy Optimization (clipped surrogate, eq. 10 of the paper)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.drl import networks
from repro.optim.optimizers import adamw, global_norm
from repro.testing import faults


@dataclass(frozen=True)
class PPOConfig:
    lr: float = 3e-4
    clip_eps: float = 0.2          # epsilon in eq. (10)
    gamma: float = 0.99
    lam: float = 0.95
    epochs: int = 10
    minibatches: int = 4
    value_coef: float = 0.5
    entropy_coef: float = 0.003
    max_grad_norm: float = 0.5
    normalize_adv: bool = True
    skip_nonfinite_grads: bool = True   # reject (don't apply) NaN/Inf updates


class Batch(NamedTuple):
    """The trailing probe-aux fields default to None (absent) so 5-field
    constructions — and pytrees serialized before the attention policy —
    keep their structure; when present they are per-sample rows that shuffle
    and slice with the rest of the batch."""
    obs: jnp.ndarray        # (N, obs_dim)
    act: jnp.ndarray        # (N, act_dim)
    logp_old: jnp.ndarray   # (N,)
    adv: jnp.ndarray        # (N,)
    ret: jnp.ndarray        # (N,)
    probe_xy: jnp.ndarray = None    # (N, obs_dim, 2)
    probe_mask: jnp.ndarray = None  # (N, obs_dim)
    valid: jnp.ndarray = None       # (N,) sentinel mask: 1 = healthy sample


def make_optimizer(cfg: PPOConfig):
    return adamw(cfg.lr, max_grad_norm=cfg.max_grad_norm)


def ppo_loss(cfg: PPOConfig, params, batch: Batch):
    """Clipped-surrogate loss.  When the batch carries a sentinel validity
    mask, the loss is computed BOTH with the historical unmasked reductions
    and with masked ``sum(x*m)/sum(m)`` ones, and ``jnp.where(all_valid,
    healthy, degraded)`` selects per batch.  The dual path is what keeps
    all-healthy batches bitwise-identical to the unguarded program: even an
    all-ones mask changes XLA's reduction fusion enough to drift by an ulp,
    while ``where(True, x, _)`` passes the plain-path bits through exactly
    (forward and backward — the VJP of ``where`` is ``where`` of the VJPs).
    With ``valid=None`` only the historical program is emitted."""
    aux = (None if batch.probe_mask is None
           else {"xy": batch.probe_xy, "mask": batch.probe_mask})
    logp = networks.log_prob(params, batch.obs, batch.act, aux)
    ratio = jnp.exp(logp - batch.logp_old)                  # r_t(theta)
    v = networks.value(params, batch.obs, aux)

    def parts(mean_fn, std_fn):
        adv = batch.adv
        if cfg.normalize_adv:
            adv = (adv - mean_fn(batch.adv)) / (std_fn(batch.adv) + 1e-8)
        unclipped = ratio * adv
        clipped = jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv
        return (-mean_fn(jnp.minimum(unclipped, clipped)),    # eq. (10)
                0.5 * mean_fn((v - batch.ret) ** 2),
                mean_fn(batch.logp_old - logp),
                mean_fn((jnp.abs(ratio - 1)
                         > cfg.clip_eps).astype(jnp.float32)))

    if batch.valid is None:
        policy_loss, value_loss, approx_kl, clip_frac = parts(jnp.mean,
                                                              jnp.std)
    else:
        m = batch.valid
        n = jnp.maximum(jnp.sum(m), 1.0)
        mmean = lambda x: jnp.sum(x * m) / n                # noqa: E731
        mstd = lambda x: jnp.sqrt(mmean((x - mmean(x)) ** 2))  # noqa: E731
        all_ok = jnp.all(m > 0.5)
        policy_loss, value_loss, approx_kl, clip_frac = (
            jnp.where(all_ok, h, d)
            for h, d in zip(parts(jnp.mean, jnp.std), parts(mmean, mstd)))
    ent = networks.entropy(params)
    loss = (policy_loss + cfg.value_coef * value_loss
            - cfg.entropy_coef * ent)
    metrics = {"policy_loss": policy_loss, "value_loss": value_loss,
               "entropy": ent, "approx_kl": approx_kl,
               "clip_frac": clip_frac}
    return loss, metrics


def ppo_update(cfg: PPOConfig, optimizer, params, opt_state, batch: Batch,
               key, step) -> Tuple[Any, Any, Dict[str, jnp.ndarray]]:
    """Full PPO update: ``epochs`` passes of ``minibatches`` shuffled splits."""
    n = batch.obs.shape[0]
    mb = n // cfg.minibatches

    def epoch(carry, ek):
        params, opt_state, step = carry
        perm = jax.random.permutation(ek, n)
        shuffled = jax.tree.map(lambda x: x[perm], batch)

        def mini(carry, i):
            params, opt_state, step = carry
            sl = jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb), shuffled)
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: ppo_loss(cfg, p, sl), has_aux=True)(params)
            fz = faults.active("grad_nan")
            if fz is not None:   # trace-time gate: absent in production traces
                hit = step == int(fz.get("step", 0))
                bad = jnp.where(hit, jnp.float32(jnp.nan), jnp.float32(0.0))
                grads = jax.tree.map(lambda g: g + bad, grads)
            if cfg.skip_nonfinite_grads:
                # reject the whole update when the gradient is non-finite:
                # params/opt_state keep their pre-update values and the skip
                # is counted.  ``where(True, new, old)`` passes ``new``
                # through exactly, so finite updates stay bitwise-identical
                # to the unguarded program.  ``step`` advances either way —
                # it indexes the schedule, not the applied-update count.
                gnorm = global_norm(grads)
                ok = jnp.isfinite(gnorm)
                new_p, new_o = optimizer.update(grads, opt_state, params,
                                                step)
                sel = lambda n_, o_: jnp.where(ok, n_, o_)    # noqa: E731
                params = jax.tree.map(sel, new_p, params)
                opt_state = jax.tree.map(sel, new_o, opt_state)
                # grad_norm reports APPLIED updates (0 when skipped): the
                # rejected gradient is a handled fault, counted in
                # grad_skips — it must not read as a live anomaly to the
                # training watchdog
                metrics = dict(metrics,
                               grad_norm=jnp.where(ok, gnorm, 0.0),
                               grad_skips=1.0 - ok.astype(jnp.float32))
            else:
                params, opt_state = optimizer.update(grads, opt_state,
                                                     params, step)
            return (params, opt_state, step + 1), metrics

        (params, opt_state, step), metrics = jax.lax.scan(
            mini, (params, opt_state, step), jnp.arange(cfg.minibatches))
        return (params, opt_state, step), metrics

    keys = jax.random.split(key, cfg.epochs)
    (params, opt_state, step), metrics = jax.lax.scan(
        epoch, (params, opt_state, step), keys)
    skips = metrics.pop("grad_skips", None)
    metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
    if skips is not None:
        metrics["grad_skips"] = jnp.sum(skips)   # count, not a mean
    return params, opt_state, step, metrics
