"""Proximal Policy Optimization (clipped surrogate, eq. 10 of the paper)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.drl import networks
from repro.optim.optimizers import adamw


@dataclass(frozen=True)
class PPOConfig:
    lr: float = 3e-4
    clip_eps: float = 0.2          # epsilon in eq. (10)
    gamma: float = 0.99
    lam: float = 0.95
    epochs: int = 10
    minibatches: int = 4
    value_coef: float = 0.5
    entropy_coef: float = 0.003
    max_grad_norm: float = 0.5
    normalize_adv: bool = True


class Batch(NamedTuple):
    """The trailing probe-aux fields default to None (absent) so 5-field
    constructions — and pytrees serialized before the attention policy —
    keep their structure; when present they are per-sample rows that shuffle
    and slice with the rest of the batch."""
    obs: jnp.ndarray        # (N, obs_dim)
    act: jnp.ndarray        # (N, act_dim)
    logp_old: jnp.ndarray   # (N,)
    adv: jnp.ndarray        # (N,)
    ret: jnp.ndarray        # (N,)
    probe_xy: jnp.ndarray = None    # (N, obs_dim, 2)
    probe_mask: jnp.ndarray = None  # (N, obs_dim)


def make_optimizer(cfg: PPOConfig):
    return adamw(cfg.lr, max_grad_norm=cfg.max_grad_norm)


def ppo_loss(cfg: PPOConfig, params, batch: Batch):
    aux = (None if batch.probe_mask is None
           else {"xy": batch.probe_xy, "mask": batch.probe_mask})
    logp = networks.log_prob(params, batch.obs, batch.act, aux)
    ratio = jnp.exp(logp - batch.logp_old)                  # r_t(theta)
    adv = batch.adv
    if cfg.normalize_adv:
        adv = (adv - jnp.mean(adv)) / (jnp.std(adv) + 1e-8)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv
    policy_loss = -jnp.mean(jnp.minimum(unclipped, clipped))  # eq. (10)
    v = networks.value(params, batch.obs, aux)
    value_loss = 0.5 * jnp.mean((v - batch.ret) ** 2)
    ent = networks.entropy(params)
    loss = (policy_loss + cfg.value_coef * value_loss
            - cfg.entropy_coef * ent)
    metrics = {"policy_loss": policy_loss, "value_loss": value_loss,
               "entropy": ent,
               "clip_frac": jnp.mean(
                   (jnp.abs(ratio - 1) > cfg.clip_eps).astype(jnp.float32))}
    return loss, metrics


def ppo_update(cfg: PPOConfig, optimizer, params, opt_state, batch: Batch,
               key, step) -> Tuple[Any, Any, Dict[str, jnp.ndarray]]:
    """Full PPO update: ``epochs`` passes of ``minibatches`` shuffled splits."""
    n = batch.obs.shape[0]
    mb = n // cfg.minibatches

    def epoch(carry, ek):
        params, opt_state, step = carry
        perm = jax.random.permutation(ek, n)
        shuffled = jax.tree.map(lambda x: x[perm], batch)

        def mini(carry, i):
            params, opt_state, step = carry
            sl = jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb), shuffled)
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: ppo_loss(cfg, p, sl), has_aux=True)(params)
            params, opt_state = optimizer.update(grads, opt_state, params,
                                                 step)
            return (params, opt_state, step + 1), metrics

        (params, opt_state, step), metrics = jax.lax.scan(
            mini, (params, opt_state, step), jnp.arange(cfg.minibatches))
        return (params, opt_state, step), metrics

    keys = jax.random.split(key, cfg.epochs)
    (params, opt_state, step), metrics = jax.lax.scan(
        epoch, (params, opt_state, step), keys)
    metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
    return params, opt_state, step, metrics
