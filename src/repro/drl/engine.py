"""Unified sharded rollout engine — the single implementation of trajectory
collection shared by every training loop in the repo.

Three concerns that used to be triplicated across ``drl/train.py``,
``drl/async_train.py`` and ``core/runner.py`` live here exactly once:

  * collect -> GAE -> flatten: the vmapped N_envs episode rollout (paper
    Fig. 4), value bootstrap, advantage estimation and batch flattening.
  * mesh placement (paper §II.D): the env batch is sharded over the mesh
    "data" axis (the paper's N_envs) and each env's grid fields optionally
    over "model" (the paper's N_ranks domain decomposition).  XLA's SPMD
    partitioner inserts the halo collective-permutes.
  * overlap: a double-buffered async mode where episode *e* is collected
    while the PPO update for episode *e-1*'s trajectories runs.  JAX async
    dispatch enqueues both computations back to back; the optimizer state is
    donated to the update (params and the stale batch are not — collect still
    reads the params concurrently), so the two in-flight programs never
    contend for the same buffers.  PPO's importance ratio r_t(theta) absorbs
    the one-step staleness (trajectories carry their behaviour-policy
    log-probs).

It also implements the paper's §IV I/O refinement for trajectory spill as a
pluggable ``TrajectorySink``: in-memory, binary (msgpack + raw fp32),
zstd-compressed binary, or the sharded on-disk dataset
(``repro.data.trajectory_dataset``), reusing the ``core.interface`` codecs
that back the measured Table II file-interface modes.  Sinks are selected
with one :class:`SinkSpec` config accepted uniformly by ``EngineConfig``,
``TrainConfig`` and ``examples/drl_cylinder.py --sink``; the old
``make_sink(mode, root)`` survives one release as a deprecated shim.
"""
from __future__ import annotations

import os
import time
import warnings
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.ckpt.io import atomic_write_bytes, retry_io
from repro.testing import faults
from repro.core import backend as backend_mod
from repro.core.interface import pack_arrays, unpack_arrays
from repro.drl import networks, rollout
from repro.drl.gae import gae_batch
from repro.drl.ppo import Batch, PPOConfig, make_optimizer, ppo_update
from repro.drl.rollout import Trajectory

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover - optional, gated
    zstd = None

_DRL_DIR = os.path.dirname(__file__)


# ---------------------------------------------------------------------------
# trajectory sinks — the paper's I/O strategies applied to trajectory spill
# ---------------------------------------------------------------------------

def _host_traj(traj) -> Trajectory:
    """Device trajectory -> host numpy, preserving absent (None) aux fields."""
    return Trajectory(*(None if a is None else np.asarray(a) for a in traj))


class SinkReadError(KeyError):
    """Raised when a sink is asked for an episode it does not hold.

    Subclasses ``KeyError`` so pre-SinkSpec callers that caught the old
    behaviour keep working; the message names the sink, its root/codec and
    the episode range actually present (``CheckpointError`` style)."""


class TrajectorySink:
    """Receives each collected episode's trajectories.  Base class = no-op
    (the paper's io-DISABLED upper bound); subclasses spill to memory or disk.

    Tracks ``bytes_written``/``time_spent`` so training loops and benchmarks
    can report interface cost exactly like ``core.interface``."""

    def __init__(self):
        self.episodes = 0
        self.bytes_written = 0
        self.time_spent = 0.0
        self.retries = 0      # transient write errors recovered by retry

    def write(self, episode: int, traj: Trajectory) -> int:
        t0 = time.perf_counter()
        n = self._write(episode, traj)
        self.bytes_written += n
        self.time_spent += time.perf_counter() - t0
        self.episodes += 1
        return n

    def _write(self, episode: int, traj: Trajectory) -> int:
        return 0

    def read(self, episode: int) -> Trajectory:
        raise SinkReadError(f"sink holds no episode {episode}: "
                            f"{type(self).__name__} does not retain episodes")

    def annotate(self, **meta) -> None:
        """Attach run-level metadata (solver fingerprint, scenario names...).

        No-op for stateless sinks; the dataset sink records it in its
        manifest so recorded trajectories outlive the writing process."""

    def close(self) -> None:
        """Flush and release handles; never destroys spilled data."""

    def cleanup(self) -> None:
        """Delete everything the sink spilled (mirrors FileInterface)."""


class MemorySink(TrajectorySink):
    """Keeps the last ``keep`` episodes on the host (replay / inspection)."""

    def __init__(self, keep: int = 8):
        super().__init__()
        self.keep = keep
        self._store: Dict[int, Trajectory] = {}

    def _write(self, episode: int, traj: Trajectory) -> int:
        host = _host_traj(traj)
        self._store[episode] = host
        while len(self._store) > self.keep:
            del self._store[min(self._store)]
        return sum(a.nbytes for a in host if a is not None)

    def read(self, episode: int) -> Trajectory:
        if episode not in self._store:
            have = (f"episodes {min(self._store)}..{max(self._store)}"
                    if self._store else "no episodes")
            raise SinkReadError(
                f"sink holds no episode {episode}: MemorySink(keep="
                f"{self.keep}) retains {have}")
        return self._store[episode]


class FileSink(TrajectorySink):
    """Spills each episode to one binary file via the ``core.interface``
    codec (paper §III.D: single binary file instead of many ASCII dumps).
    Files land via tmp + ``os.replace`` so a SIGKILL mid-spill never leaves
    a truncated episode.

    codec='binary'  msgpack + raw fp32 (the paper's optimized mode)
    codec='zstd'    the same, zstd-compressed (beyond-paper); silently
                    degrades to 'binary' when zstandard is not installed.

    ``process`` (fleet mode) suffixes every file with the writer's process
    id (``traj_000007.p002.bin``) so N concurrent runners sharing one sink
    root never contend on — or clobber — the same episode file; each
    runner spills its own env shard and reads back only its own files.
    """

    def __init__(self, root: str, codec: str = "binary",
                 process: Optional[int] = None):
        super().__init__()
        if codec not in ("binary", "zstd"):
            raise ValueError(f"unknown trajectory-sink codec {codec!r}; "
                             f"choose 'binary' or 'zstd'")
        if codec == "zstd" and zstd is None:
            codec = "binary"
        self.codec = codec
        self.process = process
        self.dir = Path(root)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._cctx = zstd.ZstdCompressor(level=1) if codec == "zstd" else None
        self._dctx = zstd.ZstdDecompressor() if codec == "zstd" else None

    def _path(self, episode: int) -> Path:
        if self.process is None:
            return self.dir / f"traj_{episode:06d}.bin"
        return self.dir / f"traj_{episode:06d}.p{self.process:03d}.bin"

    def _write(self, episode: int, traj: Trajectory) -> int:
        # optional trailing fields (probe aux) are skipped when absent, so
        # files written by either layout stay readable by both
        arrays = {f: np.asarray(a) for f, a in zip(Trajectory._fields, traj)
                  if a is not None}
        blob = pack_arrays(arrays, cctx=self._cctx)
        path = self._path(episode)

        def attempt():
            faults.maybe_fail_io(str(path))
            return atomic_write_bytes(path, blob)

        def on_retry(attempt_no, exc):
            self.retries += 1

        return retry_io(attempt, path=path,
                        what=f"trajectory spill (episode {episode})",
                        on_retry=on_retry)

    def _available(self) -> str:
        pat = "traj_*.bin" if self.process is None \
            else f"traj_*.p{self.process:03d}.bin"
        eps = sorted(int(p.name.split("_")[1].split(".")[0])
                     for p in self.dir.glob(pat))
        return (f"episodes {eps[0]}..{eps[-1]} ({len(eps)} on disk)"
                if eps else "no episodes on disk")

    def read(self, episode: int) -> Trajectory:
        path = self._path(episode)
        if not path.exists():
            raise SinkReadError(
                f"sink holds no episode {episode}: FileSink(root="
                f"{str(self.dir)!r}, codec={self.codec!r}) has "
                f"{self._available()}")
        arrays, _ = unpack_arrays(path.read_bytes(), dctx=self._dctx)
        return Trajectory(**{f: arrays[f] for f in Trajectory._fields
                             if f in arrays})

    def cleanup(self) -> None:
        import shutil
        shutil.rmtree(self.dir, ignore_errors=True)


@dataclass(frozen=True)
class SinkSpec:
    """One declarative config for every trajectory-spill strategy.

    Replaces the stringly ``make_sink(mode, root)`` + ad-hoc constructor
    kwargs: the same spec is accepted by ``EngineConfig.sink``,
    ``TrainConfig.sink`` and ``examples/drl_cylinder.py --sink``.

      kind='none'     no spill (the paper's io-DISABLED upper bound)
      kind='memory'   MemorySink keeping the last ``keep`` episodes
      kind='binary'   FileSink, one msgpack+fp32 file per episode at ``root``
      kind='zstd'     FileSink, zstd-compressed (degrades without zstandard)
      kind='dataset'  repro.data.trajectory_dataset.DatasetSink: sharded
                      files + JSON manifest, ``codec``/``shard_max_bytes``
                      apply (the durable, replayable format)

    ``process`` makes file-backed sinks multi-process-safe: FileSink files
    get a per-process suffix and the dataset sink writes a per-process
    ``part{NNN}`` subdirectory (its own shards + manifest) under the shared
    root, so N fleet runners spilling concurrently never clobber one
    another.  The default (None) auto-detects: multi-process jax runs use
    ``jax.process_index()``, single-process runs keep the flat layout.
    """

    kind: str = "none"
    root: Optional[str] = None
    keep: int = 8                       # memory: episodes retained
    codec: str = "binary"               # dataset: payload codec
    shard_max_bytes: int = 64 * 1024 * 1024   # dataset: shard rotation
    # per-process shard suffix/subdir; None = auto (process_index when the
    # jax runtime spans processes, flat single-writer layout otherwise)
    process: Optional[int] = None

    KINDS = ("none", "memory", "binary", "zstd", "dataset")

    @classmethod
    def parse(cls, text: Optional[str]) -> "SinkSpec":
        """Parse a CLI-style ``kind[:root]`` string ('dataset:/tmp/ds')."""
        if text in (None, "", "none", "disabled"):
            return cls(kind="none")
        kind, _, root = text.partition(":")
        return cls(kind=kind, root=root or None)

    def _process(self) -> Optional[int]:
        if self.process is not None:
            return self.process
        return jax.process_index() if jax.process_count() > 1 else None

    def build(self) -> Optional[TrajectorySink]:
        if self.kind in (None, "none", "disabled"):
            return None
        if self.kind == "memory":
            return MemorySink(keep=self.keep)
        if self.kind in ("binary", "zstd"):
            if self.root is None:
                raise ValueError(f"file sink {self.kind!r} needs a root "
                                 f"directory")
            return FileSink(self.root, codec=self.kind,
                            process=self._process())
        if self.kind == "dataset":
            if self.root is None:
                raise ValueError("dataset sink needs a root directory")
            from repro.data.trajectory_dataset import DatasetSink
            return DatasetSink(self.root, codec=self.codec,
                               shard_max_bytes=self.shard_max_bytes,
                               process=self._process())
        raise ValueError(f"unknown sink kind {self.kind!r}; "
                         f"choose from {self.KINDS}")


def make_sink(mode: str, root: Optional[str] = None) -> Optional[TrajectorySink]:
    """Deprecated: pass ``SinkSpec(kind=..., root=...)`` (or
    ``SinkSpec.parse('binary:/path')``) instead.

    Kept for one release as a shim over :class:`SinkSpec`; the warning's
    stacklevel blames the caller (PR-5 ``resolve_backend`` pattern)."""
    warnings.warn("make_sink() is deprecated; pass SinkSpec(kind=..., "
                  "root=...) / SinkSpec.parse('binary:/path') instead",
                  DeprecationWarning,
                  stacklevel=backend_mod.caller_stacklevel((_DRL_DIR,)))
    if mode in (None, "none", "disabled"):
        return None
    if mode == "memory":
        return MemorySink()
    if mode not in ("binary", "zstd"):
        raise ValueError(f"unknown sink mode {mode!r}; choose 'none', "
                         f"'memory', 'binary' or 'zstd'")
    if root is None:
        raise ValueError(f"file sink {mode!r} needs a root directory")
    return SinkSpec(kind=mode, root=root).build()


# ---------------------------------------------------------------------------
# mesh placement helpers (absorbed from core/runner.py)
# ---------------------------------------------------------------------------

def env_state_specs(mesh: Mesh) -> Tuple[P, P]:
    """(batch-only spec, batch+space spec) for env pytrees.

    Grid arrays additionally shard their x (last) dim over "model" when the
    plan uses n_ranks > 1."""
    from repro.models.sharding import dp_axes
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    return P(dp), P(dp, None, "model")


def is_grid_field(a, n_ranks: int = 1) -> bool:
    """Heuristic for (N, ny, nx) grid arrays vs. small per-env tables.

    Scenario batches carry (N, P, 2) probe coordinates in the env state;
    only genuine grid fields (trailing dim = nx, always >> 4) should have
    their x dim sharded over "model" — and only when that dim divides into
    the n_ranks x-slabs (staggered u fields are nx+1 wide and stay
    batch-sharded; GSPMD re-shards around them)."""
    return a.ndim == 3 and a.shape[-1] > 4 and a.shape[-1] % n_ranks == 0


def mesh_spans_processes(mesh: Optional[Mesh]) -> bool:
    """True when the mesh's devices live on more than one jax process."""
    if mesh is None:
        return False
    return len({d.process_index for d in mesh.devices.flat}) > 1


def shard_env_batch(mesh: Mesh, st_b, n_ranks: int = 1):
    """device_put a batched env-state pytree with engine shardings.

    Placing the batch on the mesh BEFORE the first collect is load-bearing
    for the halo backend on jax 0.4.x: a batch left replicated over a
    "data" axis of size > 1 trips the same partitioner miscompile the
    decomp module documents.

    On a process-spanning (fleet) mesh ``jax.device_put`` cannot place a
    host array, so each leaf is assembled with
    ``jax.make_array_from_callback`` instead — every process holds the same
    full host value (fleet training computes the batch identically
    everywhere) and contributes its local shards.  Leaves that are already
    global (non-fully-addressable) arrays pass through untouched."""
    batch, batch_space = env_state_specs(mesh)
    spans = mesh_spans_processes(mesh)

    def spec_of(a):
        if n_ranks > 1 and is_grid_field(a, n_ranks):
            return NamedSharding(mesh, batch_space)
        return NamedSharding(mesh, P(batch[0]))

    def put(a):
        if isinstance(a, jax.Array) and not a.is_fully_addressable:
            return a                       # already globally placed
        if spans:
            host = np.asarray(a)
            return jax.make_array_from_callback(
                host.shape, spec_of(a), lambda idx, h=host: h[idx])
        return jax.device_put(a, spec_of(a))

    return jax.tree.map(put, st_b)


def place_env_batch(mesh: Optional[Mesh], st_b, n_ranks: int = 1):
    """Place a (possibly host/checkpoint-restored) env batch for the engine.

    With a mesh this is ``shard_env_batch`` — the cross-plan resume path:
    a TrainState checkpointed under one ParallelPlan round-trips through
    host arrays and is re-sharded here onto whatever mesh the *current*
    plan resolved to.  Without a mesh it is a plain device transfer."""
    if mesh is not None:
        return shard_env_batch(mesh, st_b, n_ranks)
    return jax.tree.map(jnp.asarray, st_b)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class TrainCarry(NamedTuple):
    """The loop-carried tuple the training loops expose to ``on_state``
    after each episode: exactly what a checkpoint must persist for a
    bitwise resume (the env batch and history live with the caller)."""
    params: Any
    opt_state: Any
    step: jnp.ndarray     # PPO minibatch counter (Adam bias correction)
    key: jnp.ndarray      # PRNG carry BEFORE the next episode's splits


@dataclass(frozen=True)
class EngineConfig:
    n_envs: int
    horizon: int              # actuation periods per episode (the paper's T)
    gamma: float = 0.99
    lam: float = 0.95
    n_ranks: int = 1          # grid shards per env over the "model" axis
    donate: bool = True       # donate opt_state to the async-mode update
    # hybrid placement: "auto" (measure + optimize via core.autotune), a
    # core.plan.ParallelPlan / (n_envs, n_ranks) pair, a ResolvedPlan, or
    # None (explicit mesh= / single-host).  When set and no mesh is passed,
    # the engine builds its mesh from the resolved plan and adopts the
    # plan's n_ranks.
    plan: Any = None
    # trajectory spill (SinkSpec); an explicit sink= to the engine wins
    sink: Optional[SinkSpec] = None
    # phase timing: block_until_ready around collect/update so
    # ``engine.stats`` reports real collect/update/sink-write shares
    # (benchmarks opt in; training loops keep async dispatch by default)
    timing: bool = False
    # multi-process fleet mode (repro.launch.distributed): the rollout runs
    # on the process-spanning mesh, trajectories are all-gathered to the
    # host, and postprocess + PPO update run as a REPLICATED local
    # single-device program on every process (the drlfoam runner/learner
    # split: the CFD fan-out is distributed, the tiny MLP learner is
    # redundantly recomputed — no gradient traffic, and training is
    # bitwise-identical at every fleet size under the pinned device count,
    # see launch/distributed.py).  Sinks spill per-process env shards.
    fleet: bool = False


class RolloutEngine:
    """One collect implementation, three consumers.

    ``collect`` is the jitted (params, st_b, obs_b, key) -> (Batch, Trajectory)
    function; ``collect_fn`` is the untraced closure (for ``.lower()`` dry-runs
    on abstract inputs).  With a mesh, inputs are constrained to the paper's
    hybrid placement; with ``mesh=None`` it is the plain single-host vmap path.
    """

    def __init__(self, env_step_fn: Callable, cfg: EngineConfig, *,
                 mesh: Optional[Mesh] = None,
                 sink: Optional[TrajectorySink] = None,
                 obs_aux_fn: Optional[Callable] = None):
        self.env_step_fn = env_step_fn
        self.obs_aux_fn = obs_aux_fn
        self.resolved_plan = None
        if cfg.plan is not None:
            from repro.core.autotune import resolve_plan
            # smoke probe: engine construction must not block on a
            # full-resolution timing sweep (ignored for explicit plans)
            self.resolved_plan = resolve_plan(cfg.plan, smoke=True)
            if mesh is None:
                mesh = self.resolved_plan.build_mesh()
            if self.resolved_plan.n_ranks != cfg.n_ranks:
                import dataclasses as _dc
                cfg = _dc.replace(cfg, n_ranks=self.resolved_plan.n_ranks)
        self.cfg = cfg
        self.mesh = mesh
        if sink is None and cfg.sink is not None:
            sink = cfg.sink.build()
        self.sink = sink
        self.episode = 0
        self.stats = {"collect_s": 0.0, "update_s": 0.0, "episodes": 0}
        rollout_fn = self._build_rollout()
        postprocess_fn = self._build_postprocess()

        def collect_fused(params, st_b, obs_b, key):
            traj = rollout_fn(params, st_b, obs_b, key)
            return postprocess_fn(params, traj), traj

        # the untraced fused closure (runner/dry-run .lower() consumers)
        self.collect_fn = collect_fused
        if mesh is not None:
            batch, _ = env_state_specs(mesh)
            in_shardings = (
                NamedSharding(mesh, P()),              # params replicated
                None,                                  # st_b: as provided
                NamedSharding(mesh, P(batch[0])),      # obs batch-sharded
                NamedSharding(mesh, P()),
            )
            self._collect = jax.jit(self.collect_fn,
                                    in_shardings=in_shardings)
            self._rollout = jax.jit(rollout_fn, in_shardings=in_shardings)
        else:
            self._collect = jax.jit(self.collect_fn)
            self._rollout = jax.jit(rollout_fn)
        # values -> GAE -> flatten as its OWN jitted program, shared verbatim
        # by the live collect path and replay_sync: the record -> replay
        # bitwise gate holds because both feed the same compiled program
        self.postprocess = jax.jit(postprocess_fn)
        if cfg.fleet:
            if mesh is None:
                raise ValueError("EngineConfig(fleet=True) needs a mesh — "
                                 "pass a plan or an explicit mesh=")
            if cfg.n_envs % max(1, jax.process_count()):
                raise ValueError(
                    f"fleet mode needs n_envs = {cfg.n_envs} divisible by "
                    f"the process count {jax.process_count()} (each process "
                    f"owns an equal env shard)")
            # all-gather: every process materializes the full trajectory
            # batch (the inter-host traffic the autotuner's t_interhost
            # term models); postprocess + update then run on the host copy
            self._gather = jax.jit(lambda t: t,
                                   out_shardings=NamedSharding(mesh, P()))

    @classmethod
    def for_env(cls, env, cfg: EngineConfig, **kw) -> "RolloutEngine":
        """Bind a CylinderEnv-like object (anything with ``env_step``).

        Envs exposing ``obs_aux`` (probe coords + live-slot mask) get it
        threaded to the policy automatically."""
        kw.setdefault("obs_aux_fn", getattr(env, "obs_aux", None))
        return cls(env.env_step, cfg, **kw)

    # -- collect -> GAE -> flatten (THE single implementation) --------------

    def _build_rollout(self):
        cfg, mesh = self.cfg, self.mesh

        def collect_traj(params, st_b, obs_b, key):
            if mesh is not None:
                batch_spec, batch_space = env_state_specs(mesh)

                def constrain(a):
                    if cfg.n_ranks > 1 and is_grid_field(a, cfg.n_ranks):
                        return jax.lax.with_sharding_constraint(
                            a, NamedSharding(mesh, batch_space))
                    return jax.lax.with_sharding_constraint(
                        a, NamedSharding(mesh, batch_spec))

                st_b = jax.tree.map(constrain, st_b)
            _, traj = rollout.rollout_batch(self.env_step_fn, params, st_b,
                                            obs_b, key, cfg.horizon,
                                            cfg.n_envs,
                                            obs_aux_fn=self.obs_aux_fn)
            return traj

        return collect_traj

    def _build_postprocess(self):
        cfg = self.cfg

        def postprocess(params, traj):
            if traj.probe_mask is not None:
                # per-env probe layout, constant over the episode: insert a
                # T axis for the (N, T, P) obs, bare for the (N, P) last_obs
                aux_t = {"xy": traj.probe_xy[:, None],
                         "mask": traj.probe_mask[:, None]}
                aux_n = {"xy": traj.probe_xy, "mask": traj.probe_mask}
            else:
                aux_t = aux_n = None
            values = networks.value(params, traj.obs, aux_t)     # (N, T)
            last_v = networks.value(params, traj.last_obs, aux_n)  # (N,)
            adv, ret = gae_batch(traj.reward, values, last_v,
                                 gamma=cfg.gamma, lam=cfg.lam,
                                 valid=traj.valid)
            flat = lambda x: x.reshape((-1,) + x.shape[2:])
            batch = Batch(obs=flat(traj.obs), act=flat(traj.act),
                          logp_old=flat(traj.logp), adv=flat(adv),
                          ret=flat(ret))
            if traj.valid is not None:
                # sentinel mask rides per-sample so PPO's shuffled
                # minibatches keep each row's validity with it
                batch = batch._replace(valid=flat(traj.valid))
            if traj.probe_mask is not None:
                # PPO minibatching permutes rows, so each sample carries its
                # own layout row (broadcast across the episode, then flat)
                N, T = traj.obs.shape[:2]
                xy = jnp.broadcast_to(traj.probe_xy[:, None],
                                      (N, T) + traj.probe_xy.shape[1:])
                m = jnp.broadcast_to(traj.probe_mask[:, None],
                                     (N, T) + traj.probe_mask.shape[1:])
                batch = batch._replace(probe_xy=flat(xy), probe_mask=flat(m))
            return batch

        return postprocess

    def collect(self, params, st_b, obs_b, key, *, record: bool = True
                ) -> Tuple[Batch, Trajectory]:
        """One episode round of all N_envs environments.

        With a mesh, the env batch is pre-placed on it (a no-op when the
        caller already did) — leaving a batch replicated over a "data" axis
        of size > 1 trips the jax 0.4.x partitioner miscompile documented
        in ``shard_env_batch``, so the engine owns the guard rather than
        trusting every caller.

        Fleet mode: params/key arrive as process-local arrays, are
        replicated onto the global mesh for the distributed rollout, and
        the collected trajectories are all-gathered back to the host —
        ``postprocess`` then compiles as a plain local program, identical
        on every process and at every fleet size (the bitwise contract).
        The returned Trajectory is the host copy (full batch)."""
        if self.mesh is not None:
            st_b = shard_env_batch(self.mesh, st_b, self.cfg.n_ranks)
        t0 = time.perf_counter()
        if self.cfg.fleet:
            # REPRO_FLEET_TIMING=1 splits collect into rollout/gather wall
            # time (engine.stats) — the extra local sync it inserts slightly
            # perturbs the overlap, so it stays off outside diagnostics
            _timing = os.environ.get("REPRO_FLEET_TIMING")
            traj = self._rollout(self._replicate(params), st_b, obs_b,
                                 self._replicate(key))
            if _timing:
                jax.block_until_ready(traj)
                self.stats["rollout_s"] = (self.stats.get("rollout_s", 0.0)
                                           + time.perf_counter() - t0)
                t0 = time.perf_counter()
            traj = _host_traj(self._gather(traj))
            if _timing:
                self.stats["gather_s"] = (self.stats.get("gather_s", 0.0)
                                          + time.perf_counter() - t0)
        else:
            traj = self._rollout(params, st_b, obs_b, key)
        batch = self.postprocess(params, traj)
        if self.cfg.timing:
            jax.block_until_ready(batch)
            self.stats["collect_s"] += time.perf_counter() - t0
            self.stats["episodes"] += 1
        if record:
            self._sink_write(self.episode, traj)
        self.episode += 1
        return batch, traj

    def rollout_local(self, params, st_b, obs_b, key):
        """The no-comms twin of ``collect``: the same distributed rollout
        program, but each process blocks only on ITS env shard — no
        trajectory all-gather, no postprocess, no sink.

        Benchmarks use this as the oversubscription baseline: on a host
        with fewer cores than fleet processes, raw throughput conflates
        time-slicing contention (which p independent jobs would also pay)
        with the fleet's actual communication cost.  The ratio
        ``tp(collect) / tp(rollout_local)`` at the same fleet size isolates
        exactly the inter-process communication + sync overhead."""
        if self.mesh is not None:
            st_b = shard_env_batch(self.mesh, st_b, self.cfg.n_ranks)
        traj = self._rollout(self._replicate(params) if self.cfg.fleet
                             else params, st_b, obs_b,
                             self._replicate(key) if self.cfg.fleet else key)
        jax.block_until_ready(traj)
        return traj

    def _replicate(self, tree):
        """Place process-local (or host) arrays fully-replicated on the
        fleet mesh; leaves that are already global pass through."""
        rep = NamedSharding(self.mesh, P())

        def put(a):
            if isinstance(a, jax.Array) and not a.is_fully_addressable:
                return a
            host = np.asarray(a)
            return jax.make_array_from_callback(
                host.shape, rep, lambda idx, h=host: h[idx])

        return jax.tree.map(put, tree)

    def _sink_write(self, episode: int, traj: Trajectory) -> None:
        """Spill one episode; fleet runners write only THEIR env rows (the
        per-host shard — the sink's per-process suffix/part dir keeps
        concurrent writers from clobbering each other)."""
        if self.sink is None:
            return
        if self.cfg.fleet and jax.process_count() > 1:
            per = self.cfg.n_envs // jax.process_count()
            lo = jax.process_index() * per
            traj = Trajectory(*(None if a is None
                                else np.asarray(a)[lo:lo + per]
                                for a in traj))
        self.sink.write(episode, traj)

    # -- PPO update (donation-aware, shared by sync + async loops) -----------

    def make_update(self, ppo_cfg: PPOConfig, optimizer, *,
                    donate: bool = False):
        """jit'd (params, opt_state, batch, key, step) -> updated tuple.

        With ``donate=True`` the optimizer state is donated (it aliases the
        returned opt_state buffers), so in async mode the in-flight update
        never allocates a second moment-buffer set while collect runs.
        Params and the stale batch are NOT donated: the concurrently
        dispatched collect still reads the params, and the batch has no
        output to alias."""

        def update(params, opt_state, batch, key, step):
            return ppo_update(ppo_cfg, optimizer, params, opt_state, batch,
                              key, step)

        kw = {"donate_argnums": (1,)} if donate and self.cfg.donate else {}
        jitted = jax.jit(update, **kw)
        if not self.cfg.timing:
            return jitted

        def timed(params, opt_state, batch, key, step):
            t0 = time.perf_counter()
            out = jitted(params, opt_state, batch, key, step)
            jax.block_until_ready(out[0])
            self.stats["update_s"] += time.perf_counter() - t0
            return out

        return timed

    # -- training loops ------------------------------------------------------

    def run_sync(self, params, opt_state, ppo_cfg: PPOConfig, optimizer,
                 st_b, obs_b, key, episodes: int, *, step=None,
                 on_batch: Optional[Callable] = None,
                 on_episode: Optional[Callable] = None,
                 on_state: Optional[Callable] = None):
        """Sequential [collect] -> [update] (the paper's Fig. 4 loop).

        ``step`` seeds the PPO minibatch counter (resume passes the stored
        one so Adam bias correction continues, fresh runs leave it None);
        ``on_state(TrainCarry)`` fires after every fully-applied episode —
        checkpointing that carry and re-entering with it reproduces the
        remaining episodes bit for bit."""
        update = self.make_update(ppo_cfg, optimizer)
        step = jnp.int32(0) if step is None else jnp.asarray(step, jnp.int32)
        returns = []
        for _ in range(episodes):
            key, kr, ku = jax.random.split(key, 3)
            batch, traj = self.collect(params, st_b, obs_b, kr)
            if on_batch is not None:   # e.g. the CFD<->DRL file interface
                batch = on_batch(batch)
            params, opt_state, step, metrics = update(params, opt_state,
                                                      batch, ku, step)
            returns.append(float(jnp.mean(jnp.sum(traj.reward, axis=1))))
            if on_episode is not None:
                on_episode(traj, metrics)
            if on_state is not None:
                on_state(TrainCarry(params, opt_state, step, key))
        return params, opt_state, np.asarray(returns)

    def replay_sync(self, reader, params, opt_state, ppo_cfg: PPOConfig,
                    optimizer, key, episodes: int, *, step=None, start=0,
                    on_batch: Optional[Callable] = None,
                    on_state: Optional[Callable] = None):
        """Offline PPO: drive the sync update path from recorded episodes.

        ``reader`` is anything with ``read(episode) -> Trajectory`` (a
        ``TrajectoryReader``, ``FileSink`` or ``MemorySink``).  Values and
        GAE are recomputed from the recorded observations with the CURRENT
        (evolving) params through the same jitted postprocess program the
        live collect uses, and the PRNG key discipline mirrors ``run_sync``
        exactly (the collect subkey is split and burned) — so replaying a
        just-recorded dataset from the recorded seed reproduces the live
        run's parameter updates bitwise.  With an older dataset this is the
        offline regression eval: old behaviour policy, current networks."""
        update = self.make_update(ppo_cfg, optimizer)
        step = jnp.int32(0) if step is None else jnp.asarray(step, jnp.int32)
        returns = []
        for ep in range(start, start + episodes):
            key, kr, ku = jax.random.split(key, 3)
            del kr                      # run_sync's collect subkey, burned
            traj = Trajectory(*(None if a is None else jnp.asarray(a)
                                for a in reader.read(ep)))
            batch = self.postprocess(params, traj)
            if on_batch is not None:
                batch = on_batch(batch)
            params, opt_state, step, metrics = update(params, opt_state,
                                                      batch, ku, step)
            returns.append(float(jnp.mean(jnp.sum(traj.reward, axis=1))))
            if on_state is not None:
                on_state(TrainCarry(params, opt_state, step, key))
        return params, opt_state, np.asarray(returns)

    def run_async(self, params, opt_state, ppo_cfg: PPOConfig, optimizer,
                  st_b, obs_b, key, episodes: int, *, step=None,
                  drain: bool = True,
                  on_episode: Optional[Callable] = None,
                  on_state: Optional[Callable] = None,
                  state_every: int = 1):
        """Double-buffered stale-gradient PPO.

        Episode *e* is collected with the params as of episode *e-1* while
        the update consuming episode *e-1*'s trajectories is dispatched; JAX
        async dispatch lets both programs be in flight together (on 1 CPU
        device they serialize — the algorithmic semantics are what the tests
        pin down; ``async_speedup`` models the systems half).

        ``on_state(TrainCarry)`` fires every ``state_every`` episodes with
        the carry as visible at that point — the one in-flight batch (the
        episode just collected, whose update has not been dispatched yet)
        is deliberately NOT part of it, so an async checkpoint never blocks
        the overlap.  A resume from such a checkpoint therefore drops that
        single in-flight update (its episode stays logged); PPO absorbs the
        gap the same way it absorbs the one-step staleness.  One final
        ``on_state`` fires after the drain — that carry has no in-flight
        work, so checkpointing it loses nothing.  Only the sync loop offers
        bitwise resume."""
        update = self.make_update(ppo_cfg, optimizer, donate=True)
        step = jnp.int32(0) if step is None else jnp.asarray(step, jnp.int32)
        pending: Optional[Batch] = None   # awaits its (overlapped) update
        spill = None                      # (episode, traj) awaiting the sink
        returns = []
        for i in range(episodes):
            key, kr, ku = jax.random.split(key, 3)
            ep_id = self.episode
            # both dispatches below can execute concurrently: collect uses
            # the STALE params, and the update only touches the previous
            # episode's batch — never the buffers collect is writing.
            # The sink (host-blocking I/O) only ever sees the PREVIOUS,
            # already-materialized episode, after the update is dispatched,
            # so spilling never serializes the two in-flight programs.
            batch, traj = self.collect(params, st_b, obs_b, kr, record=False)
            if pending is not None:
                params, opt_state, step, _ = update(params, opt_state,
                                                    pending, ku, step)
            if self.sink is not None and spill is not None:
                self._sink_write(*spill)
            pending = batch
            spill = (ep_id, traj)
            returns.append(float(jnp.mean(jnp.sum(traj.reward, axis=1))))
            if on_episode is not None:
                on_episode(traj, None)
            if on_state is not None and (i + 1) % max(1, state_every) == 0:
                on_state(TrainCarry(params, opt_state, step, key))
        if drain and pending is not None:
            key, ku = jax.random.split(key)
            params, opt_state, step, _ = update(params, opt_state, pending,
                                                ku, step)
        if self.sink is not None and spill is not None:
            self._sink_write(*spill)
        if on_state is not None and episodes > 0:
            # final carry AFTER the drain: the one state with no in-flight
            # update, so a checkpoint of it loses nothing
            on_state(TrainCarry(params, opt_state, step, key))
        return params, opt_state, np.asarray(returns)

    # -- convenience ---------------------------------------------------------

    def init(self, pcfg: networks.PolicyConfig, ppo_cfg: PPOConfig, seed: int
             ) -> Tuple[Any, Any, Any, Any]:
        """(params, optimizer, opt_state, key) for a fresh run."""
        key = jax.random.PRNGKey(seed)
        key, kp = jax.random.split(key)
        params = networks.init_actor_critic(pcfg, kp)
        optimizer = make_optimizer(ppo_cfg)
        opt_state = optimizer.init(params)
        return params, optimizer, opt_state, key


def broadcast_env_state(st, obs, n_envs: int):
    """Tile a single reset state/obs into an (N_envs, ...) batch."""
    st_b = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_envs,) + a.shape), st)
    obs_b = jnp.broadcast_to(obs, (n_envs,) + obs.shape)
    return st_b, obs_b
