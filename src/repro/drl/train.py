"""Single-host DRL training driver: multi-env PPO on the cylinder AFC task.

This is the paper's training loop (Fig. 4): N_envs environments roll out one
episode each in parallel, trajectories are batched, and PPO updates the shared
policy.  Collection itself — the vmap/shard path, GAE and flattening — is the
``RolloutEngine``'s single implementation (drl/engine.py); this module owns
the episode loop, logging, the optional CFD<->DRL file interface hook, the
hybrid-plan resolution (``TrainConfig(plan="auto" | ParallelPlan)``, see
``repro.core.autotune``), and **fault tolerance**: with ``ckpt_dir`` set,
an ``AsyncCheckpointer`` persists the full ``TrainState`` (params, optimizer
moments, PRNG carry, PPO step, env batch, history) every ``ckpt_every``
episodes, with the disk write hidden behind the next episode's collection.
``resume=`` restarts from the latest valid checkpoint — bitwise-identically
under the same plan, and across plans by re-sharding the host-round-tripped
env batch onto the new mesh.

Fresh and resumed runs share one code path: both build a ``TrainState``
first (fresh from ``engine.init``, resumed from the checkpoint) and the loop
only ever reads that state — the PRNG key lives in the state, never
re-derived from ``cfg.seed`` mid-run.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd.env import CylinderEnv, EnvConfig
from repro.ckpt import checkpoint as ckpt_mod
from repro.drl import networks
from repro.drl import train_state as ts_mod
from repro.drl.engine import (EngineConfig, RolloutEngine, SinkSpec,
                              TrajectorySink, broadcast_env_state,
                              place_env_batch)
from repro.drl.health import DivergenceError, Watchdog, WatchdogConfig
from repro.drl.ppo import PPOConfig, make_optimizer
from repro.drl.train_state import HISTORY_FIELDS, TrainState
from repro.launch import distributed as dist_mod


def resolve_watchdog(spec) -> Optional[Watchdog]:
    """TrainConfig.watchdog -> Watchdog | None (shared with train_async)."""
    if not spec:
        return None
    return Watchdog(spec if isinstance(spec, WatchdogConfig)
                    else WatchdogConfig())


@dataclass
class TrainConfig:
    env: EnvConfig = field(default_factory=EnvConfig)
    ppo: PPOConfig = field(default_factory=PPOConfig)
    n_envs: int = 4
    episodes: int = 100
    seed: int = 0
    # scenario names (repro.cfd.scenarios) assigned round-robin over the env
    # batch; None = the single case described by ``env`` (historical default)
    scenarios: Optional[Tuple[str, ...]] = None
    # policy architecture: "mlp" (the paper's 2x512 tanh MLP, historical
    # default) | "attention" (permutation-invariant set encoder over
    # (coord, value) probe tokens — serves mixed/variable sensor sets)
    policy: str = "mlp"
    # hybrid placement: None (single-host vmap, historical default),
    # "auto" (measure this host and optimize via core.autotune), a
    # core.plan.ParallelPlan / (n_envs, n_ranks) pair, or a ResolvedPlan.
    # train() builds the mesh from the resolved plan, selects the matching
    # Poisson backend, and logs the chosen split.
    plan: Any = None
    # extra kwargs for the plan="auto" measurement (core.autotune.autotune),
    # e.g. {"smoke": False, "iters": 5} for a careful median-of-5 probe.
    # Default: a quick single-iteration smoke probe.
    plan_args: Optional[Dict[str, Any]] = None
    # fault tolerance: with ckpt_dir set, the TrainState is saved every
    # ckpt_every episodes (and at the final one) via an AsyncCheckpointer
    # (keep newest ckpt_keep; background write unless ckpt_async=False).
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 10
    ckpt_keep: int = 3
    ckpt_async: bool = True
    ckpt_compress: bool = True
    # resume: None (fresh run) | True / "latest" (latest valid checkpoint in
    # ckpt_dir — error when none) | "auto" (same, but fresh when the dir has
    # none yet: the preemptible-job idiom) | an explicit path (.ckpt file or
    # a checkpoint directory).  ``episodes`` is the TOTAL target: resuming a
    # 40-episode checkpoint with episodes=100 runs 60 more.
    resume: Any = None
    # trajectory spill: one SinkSpec for every strategy ('none' | 'memory' |
    # 'binary' | 'zstd' | 'dataset'); an explicit sink= to train() wins.
    # The run fingerprint (run_metadata) is annotated into dataset manifests.
    sink: Optional[SinkSpec] = None
    # multi-process fleet mode (repro.launch.distributed): None = auto
    # (fleet when this process is part of a jax.distributed fleet or the
    # launcher exported REPRO_FLEET=1 — single-process fleets keep the same
    # engine path so runs are bitwise-comparable across fleet sizes).
    # Requires a plan; only process 0 logs and writes checkpoints.
    fleet: Optional[bool] = None
    # training-health watchdog (drl/health.py): True = default thresholds,
    # a WatchdogConfig for custom ones, False/None = off.  On a trip the
    # run rolls back to the last healthy checkpoint (fresh restart when
    # ckpt_dir is unset) and replays, bounded by max_rollbacks.
    watchdog: Any = True


def train(cfg: TrainConfig, *, log_fn: Optional[Callable] = print,
          interface=None, sink: Optional[TrajectorySink] = None,
          on_episode: Optional[Callable] = None,
          health: Optional[Dict[str, Any]] = None,
          _rollbacks: int = 0, _sink_retries0: int = 0,
          ) -> Tuple[Dict[str, np.ndarray], Any]:
    """Returns (history dict of per-episode arrays, trained params).

    ``on_episode(traj, metrics)`` is an extra per-episode hook (fleet
    runners use it for heartbeats); it fires after the built-in logging.
    ``health`` (optional dict, filled in place) receives the self-healing
    counters on return: quarantines, grad_skips, rollbacks, sink_retries —
    the same numbers stored under ``"health"`` in checkpoint metadata.
    ``_rollbacks``/``_sink_retries0`` are internal: the watchdog-rollback
    retry depth and the retries counted by pre-rollback engine sinks."""
    resolved = mesh = None
    backend = None
    n_envs = cfg.n_envs
    fleet = dist_mod.fleet_active() if cfg.fleet is None else cfg.fleet
    proc0 = jax.process_index() == 0
    if fleet and cfg.plan is None:
        raise ValueError("fleet training needs a plan (TrainConfig.plan): "
                         "the process-spanning mesh is built from it")
    if fleet and not proc0:
        log_fn = None                  # one log stream: the coordinator's
    if cfg.plan is not None:
        from repro.core.autotune import resolve_plan
        resolved = resolve_plan(cfg.plan, grid=cfg.env.grid,
                                **{"smoke": True, **(cfg.plan_args or {})})
        mesh = resolved.build_mesh()
        backend = resolved.backend
        if n_envs % resolved.n_envs:
            # batch must tile the mesh "data" axis; round up, never down
            n_envs += resolved.n_envs - n_envs % resolved.n_envs
        if log_fn:
            log_fn(resolved.describe())
            if n_envs != cfg.n_envs:
                log_fn(f"n_envs {cfg.n_envs} -> {n_envs} (rounded up to a "
                       f"multiple of the mesh data axis {resolved.n_envs})")

    env = CylinderEnv(cfg.env, backend=backend, mesh=mesh)

    ts: Optional[TrainState] = None
    src = ts_mod.resolve_resume(cfg.resume, cfg.ckpt_dir)
    if src is not None:
        ts, ckpt_meta = ts_mod.load_train_state(src)

    if ts is not None:
        # resume: the checkpointed env batch IS the developed flow — no
        # warmup, no reset; arrays are host ndarrays until placed below.
        st_b, obs_b = ts.env_state, ts.obs
    elif cfg.scenarios:
        # mixed-scenario batch: per-env physics, one vmapped program
        st_b, obs_b = env.reset_batch(cfg.scenarios, n_envs)
    else:
        st0, obs0 = env.reset()       # warms up + calibrates CD0
        st_b, obs_b = broadcast_env_state(st0, obs0, n_envs)

    # the policy's obs_dim is DERIVED from the resolved batch, never assumed:
    # the PolicyConfig default (149) silently drifts from mixed-scenario
    # padding otherwise, surfacing as an opaque shape error inside jit
    obs_dim = int(obs_b.shape[-1])
    if cfg.scenarios and ts is None:
        from repro.cfd import scenarios as scn_mod
        expect = scn_mod.common_obs_dim(cfg.scenarios)
        if expect != obs_dim:
            raise ValueError(
                f"observation width mismatch: scenarios "
                f"{tuple(cfg.scenarios)} pad to common_obs_dim={expect} but "
                f"the reset batch produced obs_dim={obs_dim}; the env reset "
                f"and the policy must agree on one padded width")
    jv = st_b.jet_vel if ts is None else jnp.asarray(st_b.jet_vel)
    act_dim = int(jv.shape[-1]) if jv.ndim > 1 else 1
    pcfg = networks.PolicyConfig(obs_dim=obs_dim, act_dim=act_dim,
                                 policy=cfg.policy)

    engine = RolloutEngine.for_env(
        env, EngineConfig(n_envs=n_envs,
                          horizon=cfg.env.actions_per_episode,
                          gamma=cfg.ppo.gamma, lam=cfg.ppo.lam,
                          n_ranks=resolved.n_ranks if resolved else 1,
                          sink=cfg.sink, fleet=fleet),
        mesh=mesh, sink=sink)

    run_meta = ts_mod.run_metadata(
        n_envs=n_envs, obs_dim=pcfg.obs_dim, seed=cfg.seed,
        grid=cfg.env.grid, horizon=cfg.env.actions_per_episode,
        steps_per_action=cfg.env.steps_per_action, scenarios=cfg.scenarios,
        plan={"n_envs": resolved.n_envs, "n_ranks": resolved.n_ranks,
              "backend": resolved.backend,
              "n_processes": jax.process_count()} if resolved else None,
        policy={"policy": cfg.policy, "obs_dim": pcfg.obs_dim,
                "act_dim": pcfg.act_dim})
    if engine.sink is not None:
        # durable datasets record which run (and which code) produced them
        engine.sink.annotate(**run_meta)
    if ts is not None:
        for note in ts_mod.check_resume_compatible(ckpt_meta, run_meta):
            if log_fn:
                log_fn(note)
        if log_fn:
            log_fn(f"resume: {src} @ episode {int(ts.episode)}")

    # pre-place the batch on the mesh (see shard_env_batch's docstring —
    # required for correctness of the halo backend on jax 0.4.x).  For a
    # resumed run this is the cross-plan re-sharding step.  Fleet
    # checkpoints snapshot the PRE-placement host copies: a process-spanning
    # global array cannot be pulled back to one host at save time.
    st_host = jax.tree.map(np.asarray, st_b) if fleet else None
    obs_host = np.asarray(obs_b) if fleet else None
    st_b = place_env_batch(mesh, st_b, engine.cfg.n_ranks)
    obs_b = place_env_batch(mesh, obs_b, 1)

    if ts is None:
        params, optimizer, opt_state, key = engine.init(pcfg, cfg.ppo,
                                                        cfg.seed)
        ts = TrainState(params=params, opt_state=opt_state, key=key,
                        step=jnp.int32(0), episode=jnp.int32(0),
                        env_state=st_b, obs=obs_b,
                        history={f: np.zeros((0,)) for f in HISTORY_FIELDS})
    else:
        optimizer = make_optimizer(cfg.ppo)
        ts = ts._replace(
            params=jax.tree.map(jnp.asarray, ts.params),
            opt_state=jax.tree.map(jnp.asarray, ts.opt_state),
            key=jnp.asarray(ts.key), env_state=st_b, obs=obs_b)

    hist = {f: [float(x) for x in np.asarray(ts.history.get(f, ()))]
            for f in HISTORY_FIELDS}
    # checkpoints written before the health counters existed (or truncated
    # by a mid-episode crash) restore with short columns: zero-pad to the
    # reward column's length — healthy episodes logged zeros anyway
    for f in HISTORY_FIELDS:
        if len(hist[f]) < len(hist["reward"]):
            hist[f] += [0.0] * (len(hist["reward"]) - len(hist[f]))
    ep0 = int(ts.episode)
    engine.episode = ep0              # sink episode ids continue, not restart
    watchdog = resolve_watchdog(cfg.watchdog)
    if health is None:
        health = {}

    def fill_health() -> Dict[str, Any]:
        health.update(
            quarantines=int(round(sum(hist["quarantines"]))),
            grad_skips=int(round(sum(hist["grad_skips"]))),
            rollbacks=int(_rollbacks),
            sink_retries=_sink_retries0 + (int(engine.sink.retries)
                                           if engine.sink else 0))
        return dict(health)

    remaining = cfg.episodes - ep0
    if remaining <= 0:
        fill_health()
        if log_fn:
            log_fn(f"checkpoint already has {ep0} episodes >= target "
                   f"{cfg.episodes}; nothing to train")
        return {k: np.asarray(v) for k, v in hist.items()}, ts.params

    ckpter = None
    if cfg.ckpt_dir and proc0:        # one writer: the coordinator
        ckpter = ckpt_mod.AsyncCheckpointer(
            cfg.ckpt_dir, keep=cfg.ckpt_keep, compress=cfg.ckpt_compress,
            background=cfg.ckpt_async)

    t_ep = [time.time()]
    ep_hook = on_episode               # the caller's hook (fleet heartbeats)

    def on_batch(batch):
        # paper's CFD<->DRL interface experiment
        return interface.exchange(batch) if interface is not None else batch

    def on_episode(traj, metrics):
        ep = len(hist["reward"])
        r = float(jnp.mean(jnp.sum(traj.reward, axis=1)))
        cd = float(jnp.mean(traj.cd[:, -10:]))
        cl = float(jnp.mean(jnp.abs(traj.cl[:, -10:])))
        hist["reward"].append(r)
        hist["cd"].append(cd)
        hist["cl"].append(cl)
        now = time.time()
        hist["wall"].append(now - t_ep[0])
        t_ep[0] = now
        # self-healing counters: quarantined env-steps from the sentinel
        # mask, rejected updates from the learner guard
        quar = (0.0 if traj.valid is None
                else float(jnp.sum(1.0 - traj.valid)))
        skips = 0.0 if metrics is None else float(metrics.get("grad_skips",
                                                              0.0))
        hist["quarantines"].append(quar)
        hist["grad_skips"].append(skips)
        if log_fn and (quar or skips):
            log_fn(f"ep {ep:4d}  health: {quar:.0f} env-step(s) "
                   f"quarantined, {skips:.0f} update(s) skipped")
        if log_fn and (ep % max(1, cfg.episodes // 20) == 0
                       or ep == cfg.episodes - 1):
            log_fn(f"ep {ep:4d}  return {r:+8.3f}  CD(tail) {cd:.3f}  "
                   f"|CL| {cl:.3f}  {hist['wall'][-1]:.1f}s")
        if ep_hook is not None:
            ep_hook(traj, metrics)
        if watchdog is not None:
            mf = (None if metrics is None
                  else {k: float(v) for k, v in metrics.items()})
            reason = watchdog.observe(mf, episode=ep)
            if reason is not None:
                # raised BEFORE on_state fires for this episode, so the
                # anomalous state is never checkpointed — the latest
                # checkpoint on disk is by construction a healthy one
                raise DivergenceError(ep, reason)

    def on_state(carry):
        if ckpter is None:
            return
        done = len(hist["reward"])    # episodes completed, incl. resumed
        if done % max(1, cfg.ckpt_every) and done != cfg.episodes:
            return
        snap = TrainState(params=carry.params, opt_state=carry.opt_state,
                          key=carry.key, step=carry.step,
                          episode=jnp.int32(done),
                          env_state=st_host if fleet else st_b,
                          obs=obs_host if fleet else obs_b,
                          history={f: np.asarray(hist[f])
                                   for f in HISTORY_FIELDS})
        ckpter.save(done, ts_mod.to_tree(snap),
                    metadata=ts_mod.state_metadata(
                        snap, {**run_meta, "health": fill_health()}))

    divergence: Optional[DivergenceError] = None
    try:
        params, _, _ = engine.run_sync(ts.params, ts.opt_state, cfg.ppo,
                                       optimizer, ts.env_state, ts.obs,
                                       ts.key, remaining, step=ts.step,
                                       on_batch=on_batch,
                                       on_episode=on_episode,
                                       on_state=on_state)
    except DivergenceError as e:
        divergence = e
    finally:
        if ckpter is not None:
            ckpter.close()            # drain the in-flight write
            if log_fn and ckpter.saves:
                log_fn(f"checkpoints: {ckpter.saves} saves, "
                       f"{ckpter.bytes_written / 1e6:.2f} MB -> "
                       f"{cfg.ckpt_dir} ({ckpter.time_blocked:.2f}s "
                       f"caller-visible)")

    if divergence is not None:
        # roll back to the last healthy checkpoint (the anomalous episode
        # was never saved) and replay; without a ckpt_dir the retry is a
        # fresh restart.  Deterministic divergences replay identically and
        # exhaust the retry budget — the error below says so.
        max_rb = watchdog.cfg.max_rollbacks if watchdog else 0
        if _rollbacks >= max_rb:
            raise RuntimeError(
                f"training diverged and {_rollbacks} rollback(s) to the "
                f"last healthy checkpoint did not clear it ({divergence}); "
                f"a deterministic divergence replays identically — lower "
                f"the learning rate / tighten PPO clipping, or raise "
                f"WatchdogConfig.max_rollbacks if the trigger is transient"
            ) from divergence
        if log_fn:
            log_fn(f"watchdog: {divergence}; rolling back "
                   f"(retry {_rollbacks + 1}/{max_rb})")
        retry_cfg = dataclasses.replace(
            cfg, resume="auto" if cfg.ckpt_dir else None)
        # a cfg-built sink dies with this engine, so its retry count must be
        # carried forward; an explicit ``sink=`` object survives the
        # recursion and keeps its own count (no double-counting)
        prior = (0 if sink is not None
                 else _sink_retries0 + (int(engine.sink.retries)
                                        if engine.sink else 0))
        return train(retry_cfg, log_fn=log_fn, interface=interface,
                     sink=sink, on_episode=ep_hook, health=health,
                     _rollbacks=_rollbacks + 1, _sink_retries0=prior)

    fill_health()
    return {k: np.asarray(v) for k, v in hist.items()}, params
