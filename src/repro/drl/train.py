"""Single-host DRL training driver: multi-env PPO on the cylinder AFC task.

This is the paper's training loop (Fig. 4): N_envs environments roll out one
episode each in parallel, trajectories are batched, and PPO updates the shared
policy.  Collection itself — the vmap/shard path, GAE and flattening — is the
``RolloutEngine``'s single implementation (drl/engine.py); this module only
owns the episode loop, logging and the optional CFD<->DRL file interface hook.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.cfd.env import CylinderEnv, EnvConfig
from repro.drl import networks
from repro.drl.engine import (EngineConfig, RolloutEngine, TrajectorySink,
                              broadcast_env_state)
from repro.drl.ppo import PPOConfig


@dataclass
class TrainConfig:
    env: EnvConfig = field(default_factory=EnvConfig)
    ppo: PPOConfig = field(default_factory=PPOConfig)
    n_envs: int = 4
    episodes: int = 100
    seed: int = 0
    # scenario names (repro.cfd.scenarios) assigned round-robin over the env
    # batch; None = the single case described by ``env`` (historical default)
    scenarios: Optional[Tuple[str, ...]] = None


def train(cfg: TrainConfig, *, log_fn: Optional[Callable] = print,
          interface=None, sink: Optional[TrajectorySink] = None,
          ) -> Tuple[Dict[str, np.ndarray], Any]:
    """Returns (history dict of per-episode arrays, trained params)."""
    env = CylinderEnv(cfg.env)
    if cfg.scenarios:
        # mixed-scenario batch: per-env physics, one vmapped program
        st_b, obs_b = env.reset_batch(cfg.scenarios, cfg.n_envs)
    else:
        st0, obs0 = env.reset()       # warms up + calibrates CD0
        st_b, obs_b = broadcast_env_state(st0, obs0, cfg.n_envs)
    pcfg = networks.PolicyConfig(obs_dim=int(obs_b.shape[-1]))

    engine = RolloutEngine.for_env(
        env, EngineConfig(n_envs=cfg.n_envs,
                          horizon=cfg.env.actions_per_episode,
                          gamma=cfg.ppo.gamma, lam=cfg.ppo.lam),
        sink=sink)
    params, optimizer, opt_state, key = engine.init(pcfg, cfg.ppo, cfg.seed)

    hist = {"reward": [], "cd": [], "cl": [], "wall": []}
    t_ep = [time.time()]

    def on_batch(batch):
        # paper's CFD<->DRL interface experiment
        return interface.exchange(batch) if interface is not None else batch

    def on_episode(traj, metrics):
        ep = len(hist["reward"])
        r = float(jnp.mean(jnp.sum(traj.reward, axis=1)))
        cd = float(jnp.mean(traj.cd[:, -10:]))
        cl = float(jnp.mean(jnp.abs(traj.cl[:, -10:])))
        hist["reward"].append(r)
        hist["cd"].append(cd)
        hist["cl"].append(cl)
        now = time.time()
        hist["wall"].append(now - t_ep[0])
        t_ep[0] = now
        if log_fn and (ep % max(1, cfg.episodes // 20) == 0
                       or ep == cfg.episodes - 1):
            log_fn(f"ep {ep:4d}  return {r:+8.3f}  CD(tail) {cd:.3f}  "
                   f"|CL| {cl:.3f}  {hist['wall'][-1]:.1f}s")

    params, _, _ = engine.run_sync(params, opt_state, cfg.ppo, optimizer,
                                   st_b, obs_b, key, cfg.episodes,
                                   on_batch=on_batch, on_episode=on_episode)
    return {k: np.asarray(v) for k, v in hist.items()}, params
