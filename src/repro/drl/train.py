"""Single-host DRL training driver: multi-env PPO on the cylinder AFC task.

This is the paper's training loop (Fig. 4): N_envs environments roll out one
episode each in parallel, trajectories are batched, and PPO updates the shared
policy.  The distributed (mesh) version lives in core/runner.py; this module
is the plain vmap form used by examples and tests.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd.env import CylinderEnv, EnvConfig
from repro.drl import networks, rollout
from repro.drl.gae import gae_batch
from repro.drl.ppo import Batch, PPOConfig, make_optimizer, ppo_update


@dataclass
class TrainConfig:
    env: EnvConfig = field(default_factory=EnvConfig)
    ppo: PPOConfig = field(default_factory=PPOConfig)
    n_envs: int = 4
    episodes: int = 100
    seed: int = 0


def broadcast_state(st, n):
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), st)


def train(cfg: TrainConfig, *, log_fn: Optional[Callable] = print,
          interface=None) -> Dict[str, np.ndarray]:
    env = CylinderEnv(cfg.env)
    st0, obs0 = env.reset()           # warms up + calibrates CD0
    pcfg = networks.PolicyConfig(obs_dim=cfg.env.obs_dim)
    key = jax.random.PRNGKey(cfg.seed)
    key, kp = jax.random.split(key)
    params = networks.init_actor_critic(pcfg, kp)
    optimizer = make_optimizer(cfg.ppo)
    opt_state = optimizer.init(params)
    step = jnp.int32(0)

    T = cfg.env.actions_per_episode
    st_b = broadcast_state(st0, cfg.n_envs)
    obs_b = jnp.broadcast_to(obs0, (cfg.n_envs,) + obs0.shape)

    @jax.jit
    def collect(params, st_b, obs_b, key):
        _, traj = rollout.rollout_batch(env.env_step, params, st_b, obs_b,
                                        key, T, cfg.n_envs)
        values = networks.value(params, traj.obs)            # (N, T)
        last_v = networks.value(params, traj.last_obs)       # (N,)
        adv, ret = gae_batch(traj.reward, values, last_v,
                             gamma=cfg.ppo.gamma, lam=cfg.ppo.lam)
        flat = lambda x: x.reshape((-1,) + x.shape[2:])
        batch = Batch(obs=flat(traj.obs), act=flat(traj.act),
                      logp_old=flat(traj.logp), adv=flat(adv), ret=flat(ret))
        return batch, traj

    @jax.jit
    def update(params, opt_state, batch, key, step):
        return ppo_update(cfg.ppo, optimizer, params, opt_state, batch, key,
                          step)

    hist = {"reward": [], "cd": [], "cl": [], "wall": []}
    for ep in range(cfg.episodes):
        t0 = time.time()
        key, kr, ku = jax.random.split(key, 3)
        batch, traj = collect(params, st_b, obs_b, kr)
        if interface is not None:     # paper's CFD<->DRL interface experiment
            batch = interface.exchange(batch)
        params, opt_state, step, metrics = update(params, opt_state, batch,
                                                  ku, step)
        r = float(jnp.mean(jnp.sum(traj.reward, axis=1)))
        cd = float(jnp.mean(traj.cd[:, -10:]))
        cl = float(jnp.mean(jnp.abs(traj.cl[:, -10:])))
        hist["reward"].append(r)
        hist["cd"].append(cd)
        hist["cl"].append(cl)
        hist["wall"].append(time.time() - t0)
        if log_fn and (ep % max(1, cfg.episodes // 20) == 0
                       or ep == cfg.episodes - 1):
            log_fn(f"ep {ep:4d}  return {r:+8.3f}  CD(tail) {cd:.3f}  "
                   f"|CL| {cl:.3f}  {hist['wall'][-1]:.1f}s")
    return {k: np.asarray(v) for k, v in hist.items()}, params
