"""Single-host DRL training driver: multi-env PPO on the cylinder AFC task.

This is the paper's training loop (Fig. 4): N_envs environments roll out one
episode each in parallel, trajectories are batched, and PPO updates the shared
policy.  Collection itself — the vmap/shard path, GAE and flattening — is the
``RolloutEngine``'s single implementation (drl/engine.py); this module owns
the episode loop, logging, the optional CFD<->DRL file interface hook, and
the hybrid-plan resolution: ``TrainConfig(plan="auto" | ParallelPlan)`` turns
the paper's n_envs x n_ranks split into a mesh + Poisson backend and executes
it (see ``repro.core.autotune``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.cfd.env import CylinderEnv, EnvConfig
from repro.drl import networks
from repro.drl.engine import (EngineConfig, RolloutEngine, TrajectorySink,
                              broadcast_env_state, env_state_specs,
                              shard_env_batch)
from repro.drl.ppo import PPOConfig


@dataclass
class TrainConfig:
    env: EnvConfig = field(default_factory=EnvConfig)
    ppo: PPOConfig = field(default_factory=PPOConfig)
    n_envs: int = 4
    episodes: int = 100
    seed: int = 0
    # scenario names (repro.cfd.scenarios) assigned round-robin over the env
    # batch; None = the single case described by ``env`` (historical default)
    scenarios: Optional[Tuple[str, ...]] = None
    # hybrid placement: None (single-host vmap, historical default),
    # "auto" (measure this host and optimize via core.autotune), a
    # core.plan.ParallelPlan / (n_envs, n_ranks) pair, or a ResolvedPlan.
    # train() builds the mesh from the resolved plan, selects the matching
    # Poisson backend, and logs the chosen split.
    plan: Any = None
    # extra kwargs for the plan="auto" measurement (core.autotune.autotune),
    # e.g. {"smoke": False, "iters": 5} for a careful median-of-5 probe.
    # Default: a quick single-iteration smoke probe.
    plan_args: Optional[Dict[str, Any]] = None


def train(cfg: TrainConfig, *, log_fn: Optional[Callable] = print,
          interface=None, sink: Optional[TrajectorySink] = None,
          ) -> Tuple[Dict[str, np.ndarray], Any]:
    """Returns (history dict of per-episode arrays, trained params)."""
    resolved = mesh = None
    backend = None
    n_envs = cfg.n_envs
    if cfg.plan is not None:
        from repro.core.autotune import resolve_plan
        resolved = resolve_plan(cfg.plan, grid=cfg.env.grid,
                                **{"smoke": True, **(cfg.plan_args or {})})
        mesh = resolved.build_mesh()
        backend = resolved.backend
        if n_envs % resolved.n_envs:
            # batch must tile the mesh "data" axis; round up, never down
            n_envs += resolved.n_envs - n_envs % resolved.n_envs
        if log_fn:
            log_fn(resolved.describe())
            if n_envs != cfg.n_envs:
                log_fn(f"n_envs {cfg.n_envs} -> {n_envs} (rounded up to a "
                       f"multiple of the mesh data axis {resolved.n_envs})")

    env = CylinderEnv(cfg.env, backend=backend, mesh=mesh)
    if cfg.scenarios:
        # mixed-scenario batch: per-env physics, one vmapped program
        st_b, obs_b = env.reset_batch(cfg.scenarios, n_envs)
    else:
        st0, obs0 = env.reset()       # warms up + calibrates CD0
        st_b, obs_b = broadcast_env_state(st0, obs0, n_envs)
    pcfg = networks.PolicyConfig(obs_dim=int(obs_b.shape[-1]))

    engine = RolloutEngine.for_env(
        env, EngineConfig(n_envs=n_envs,
                          horizon=cfg.env.actions_per_episode,
                          gamma=cfg.ppo.gamma, lam=cfg.ppo.lam,
                          n_ranks=resolved.n_ranks if resolved else 1),
        mesh=mesh, sink=sink)
    if mesh is not None:
        # pre-place the batch on the mesh (see shard_env_batch's docstring —
        # required for correctness of the halo backend on jax 0.4.x)
        st_b = shard_env_batch(mesh, st_b, engine.cfg.n_ranks)
        obs_b = jax.device_put(obs_b,
                               NamedSharding(mesh, env_state_specs(mesh)[0]))
    params, optimizer, opt_state, key = engine.init(pcfg, cfg.ppo, cfg.seed)

    hist = {"reward": [], "cd": [], "cl": [], "wall": []}
    t_ep = [time.time()]

    def on_batch(batch):
        # paper's CFD<->DRL interface experiment
        return interface.exchange(batch) if interface is not None else batch

    def on_episode(traj, metrics):
        ep = len(hist["reward"])
        r = float(jnp.mean(jnp.sum(traj.reward, axis=1)))
        cd = float(jnp.mean(traj.cd[:, -10:]))
        cl = float(jnp.mean(jnp.abs(traj.cl[:, -10:])))
        hist["reward"].append(r)
        hist["cd"].append(cd)
        hist["cl"].append(cl)
        now = time.time()
        hist["wall"].append(now - t_ep[0])
        t_ep[0] = now
        if log_fn and (ep % max(1, cfg.episodes // 20) == 0
                       or ep == cfg.episodes - 1):
            log_fn(f"ep {ep:4d}  return {r:+8.3f}  CD(tail) {cd:.3f}  "
                   f"|CL| {cl:.3f}  {hist['wall'][-1]:.1f}s")

    params, _, _ = engine.run_sync(params, opt_state, cfg.ppo, optimizer,
                                   st_b, obs_b, key, cfg.episodes,
                                   on_batch=on_batch, on_episode=on_episode)
    return {k: np.asarray(v) for k, v in hist.items()}, params
