"""Actor-critic networks.  The paper's policy: 2x512 tanh MLP (Rabault et al.),
Gaussian head with state-independent log-std; separate value MLP.

``policy="attention"`` swaps the fixed-width probe MLP for a
permutation-invariant set encoder over ``(coord, value)`` probe tokens: each
probe becomes a 3-vector ``[x, y, p]``, a small pre-LN transformer encoder
(``models.attention.gqa_attend``, bidirectional) mixes the set, and a masked
mean-pool feeds the actor/critic heads.  Padded probe slots are zeroed at the
token level AND masked out of the attention keys and the pool, so the output
is exactly invariant to garbage in masked slots — the property that lets one
policy serve scenarios with different sensor sets.

Every entry point takes an optional ``aux`` dict (``{"xy": (..., P, 2),
"mask": (..., P)}``, see ``CylinderEnv.obs_aux``).  ``aux=None`` reproduces
the historical MLP program bit-for-bit (the branch is Python-level, so the
traced computation is unchanged)."""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as backend_mod
from repro.models.attention import gqa_attend
from repro.models.layers import dense_init

POLICIES = ("mlp", "attention")


class PolicyConfig(NamedTuple):
    obs_dim: int = 149
    act_dim: int = 1
    hidden: int = 512
    depth: int = 2
    init_log_std: float = -0.5
    # -- attention-policy options (ignored by the MLP) ----------------------
    policy: str = "mlp"           # "mlp" | "attention"
    d_model: int = 64
    heads: int = 4
    kv_heads: int = 2
    layers: int = 2


def _mlp_init(key, sizes):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k = jax.random.fold_in(key, i)
        params.append({"w": dense_init(k, (a, b), jnp.float32),
                       "b": jnp.zeros((b,), jnp.float32)})
    return params


def _mlp_apply(params, x, final_linear=True):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or not final_linear:
            x = jnp.tanh(x)
    return x


def init_actor_critic(cfg: PolicyConfig, key):
    if cfg.policy not in POLICIES:
        raise ValueError(f"unknown policy {cfg.policy!r}; "
                         f"choose from {POLICIES}")
    ka, kc = jax.random.split(key)
    if cfg.policy == "attention":
        return _attn_init(cfg, ka, kc)
    sizes = [cfg.obs_dim] + [cfg.hidden] * cfg.depth
    return {
        "actor": _mlp_init(ka, sizes + [cfg.act_dim]),
        "critic": _mlp_init(kc, sizes + [1]),
        "log_std": jnp.full((cfg.act_dim,), cfg.init_log_std, jnp.float32),
    }


# ---------------------------------------------------------------------------
# permutation-invariant attention encoder (policy="attention")
# ---------------------------------------------------------------------------

def _ln_init(d):
    return {"g": jnp.ones((d,), jnp.float32),
            "b": jnp.zeros((d,), jnp.float32)}


def _layernorm(x, p, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def _attn_init(cfg: PolicyConfig, ka, kc):
    d, dh = cfg.d_model, cfg.d_model // cfg.heads
    if dh * cfg.heads != cfg.d_model or cfg.heads % cfg.kv_heads:
        raise ValueError(f"d_model={cfg.d_model} must split into heads="
                         f"{cfg.heads}, and heads must be a multiple of "
                         f"kv_heads={cfg.kv_heads}")
    ke = jax.random.fold_in(ka, 1000)
    blocks = []
    for i in range(cfg.layers):
        k = jax.random.fold_in(ke, i)
        kq, kk, kv, ko, k1, k2 = jax.random.split(k, 6)
        # q/k/v weights keep the (d, heads, head_dim) factorization in their
        # shape, so the forward pass needs no head-count side channel
        blocks.append({
            "ln1": _ln_init(d),
            "wq": dense_init(kq, (d, cfg.heads * dh), jnp.float32
                             ).reshape(d, cfg.heads, dh),
            "wk": dense_init(kk, (d, cfg.kv_heads * dh), jnp.float32
                             ).reshape(d, cfg.kv_heads, dh),
            "wv": dense_init(kv, (d, cfg.kv_heads * dh), jnp.float32
                             ).reshape(d, cfg.kv_heads, dh),
            "wo": dense_init(ko, (cfg.heads * dh, d), jnp.float32),
            "ln2": _ln_init(d),
            "mlp": [{"w": dense_init(k1, (d, 4 * d), jnp.float32),
                     "b": jnp.zeros((4 * d,), jnp.float32)},
                    {"w": dense_init(k2, (4 * d, d), jnp.float32),
                     "b": jnp.zeros((d,), jnp.float32)}],
        })
    return {
        # token = [x, y, p]; the "embed" key doubles as the dispatch marker
        "embed": {"w": dense_init(jax.random.fold_in(ke, 999), (3, d),
                                  jnp.float32),
                  "b": jnp.zeros((d,), jnp.float32)},
        "blocks": blocks,
        "ln_f": _ln_init(d),
        "actor": _mlp_init(ka, [d, d, cfg.act_dim]),
        "critic": _mlp_init(kc, [d, d, 1]),
        "log_std": jnp.full((cfg.act_dim,), cfg.init_log_std, jnp.float32),
    }


def is_attention(params) -> bool:
    """Param-tree dispatch: attention policies carry the token embedding."""
    return "embed" in params


def _encode(params, obs, aux):
    """Set encoder: (..., P) probe values -> (..., d_model) pooled features.

    Permutation-invariant and exactly invariant to masked slots: tokens are
    zeroed pre-embed, padded keys are masked out of every attend, and the
    pool averages over live tokens only.
    """
    # the kernel-selection convention (repro.core.backend) is resolved for
    # its env-var/deprecation handling, but the encoder attend is
    # bidirectional and the Pallas flash kernel is causal-only, so every
    # backend lowers to the dense gqa_attend
    backend_mod.resolve_backend(None, None, what="attention policy")
    lead = obs.shape[:-1]
    P = obs.shape[-1]
    obs = obs.astype(jnp.float32)
    if aux is not None:
        mask = jnp.broadcast_to(jnp.asarray(aux["mask"], obs.dtype),
                                obs.shape)
        xy = jnp.broadcast_to(jnp.asarray(aux["xy"], obs.dtype),
                              obs.shape + (2,))
    else:
        mask = jnp.ones_like(obs)
        xy = jnp.zeros(obs.shape + (2,), obs.dtype)
    tokens = jnp.concatenate([xy, obs[..., None]], axis=-1)
    tokens = tokens * mask[..., None]                 # garbage-proof padding
    B = 1
    for s in lead:
        B *= s
    h = (tokens.reshape(B, P, 3) @ params["embed"]["w"]
         + params["embed"]["b"])
    kmask = mask.reshape(B, 1, P) > 0                 # key-padding mask
    for blk in params["blocks"]:
        x = _layernorm(h, blk["ln1"])
        q = jnp.einsum("bpd,dhk->bphk", x, blk["wq"])
        k = jnp.einsum("bpd,dhk->bphk", x, blk["wk"])
        v = jnp.einsum("bpd,dhk->bphk", x, blk["wv"])
        att = gqa_attend(q, k, v, kmask)
        h = h + att.reshape(B, P, -1) @ blk["wo"]
        x = _layernorm(h, blk["ln2"])
        h = h + jnp.tanh(x @ blk["mlp"][0]["w"] + blk["mlp"][0]["b"]
                         ) @ blk["mlp"][1]["w"] + blk["mlp"][1]["b"]
    h = _layernorm(h, params["ln_f"])
    m = mask.reshape(B, P, 1)
    pooled = jnp.sum(h * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    return pooled.reshape(lead + (h.shape[-1],))


def _features(params, obs, aux):
    """Policy input features: raw (masked) probes for the MLP, pooled set
    encoding for the attention policy.  ``aux=None`` on the MLP path keeps
    the historical traced program unchanged (the branch is Python-level)."""
    if is_attention(params):
        return _encode(params, obs, aux)
    if aux is not None:
        # satellite fix: zero masked slots explicitly so the MLP cannot
        # read garbage from padded probe entries
        obs = obs * jnp.asarray(aux["mask"], obs.dtype)
    return obs


def policy_dist(params, obs, aux=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (mean (..., act_dim), log_std (act_dim,)); mean squashed to [-1,1]."""
    x = _features(params, obs, aux)
    mean = jnp.tanh(_mlp_apply(params["actor"], x))
    return mean, params["log_std"]


def value(params, obs, aux=None) -> jnp.ndarray:
    return _mlp_apply(params["critic"], _features(params, obs, aux))[..., 0]


def sample_action(params, obs, key, aux=None):
    """-> (action, log_prob)."""
    mean, log_std = policy_dist(params, obs, aux)
    std = jnp.exp(log_std)
    eps = jax.random.normal(key, mean.shape)
    act = mean + std * eps
    logp = _gauss_logp(act, mean, log_std)
    return act, logp


def _gauss_logp(act, mean, log_std):
    var = jnp.exp(2 * log_std)
    lp = -0.5 * ((act - mean) ** 2 / var + 2 * log_std
                 + jnp.log(2 * jnp.pi))
    return jnp.sum(lp, axis=-1)


def log_prob(params, obs, act, aux=None):
    mean, log_std = policy_dist(params, obs, aux)
    return _gauss_logp(act, mean, log_std)


def entropy(params) -> jnp.ndarray:
    log_std = params["log_std"]
    return jnp.sum(0.5 * (1 + jnp.log(2 * jnp.pi)) + log_std)
