"""Actor-critic networks.  The paper's policy: 2x512 tanh MLP (Rabault et al.),
Gaussian head with state-independent log-std; separate value MLP."""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


class PolicyConfig(NamedTuple):
    obs_dim: int = 149
    act_dim: int = 1
    hidden: int = 512
    depth: int = 2
    init_log_std: float = -0.5


def _mlp_init(key, sizes):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k = jax.random.fold_in(key, i)
        params.append({"w": dense_init(k, (a, b), jnp.float32),
                       "b": jnp.zeros((b,), jnp.float32)})
    return params


def _mlp_apply(params, x, final_linear=True):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or not final_linear:
            x = jnp.tanh(x)
    return x


def init_actor_critic(cfg: PolicyConfig, key):
    ka, kc = jax.random.split(key)
    sizes = [cfg.obs_dim] + [cfg.hidden] * cfg.depth
    return {
        "actor": _mlp_init(ka, sizes + [cfg.act_dim]),
        "critic": _mlp_init(kc, sizes + [1]),
        "log_std": jnp.full((cfg.act_dim,), cfg.init_log_std, jnp.float32),
    }


def policy_dist(params, obs) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (mean (..., act_dim), log_std (act_dim,)); mean squashed to [-1,1]."""
    mean = jnp.tanh(_mlp_apply(params["actor"], obs))
    return mean, params["log_std"]


def value(params, obs) -> jnp.ndarray:
    return _mlp_apply(params["critic"], obs)[..., 0]


def sample_action(params, obs, key):
    """-> (action, log_prob)."""
    mean, log_std = policy_dist(params, obs)
    std = jnp.exp(log_std)
    eps = jax.random.normal(key, mean.shape)
    act = mean + std * eps
    logp = _gauss_logp(act, mean, log_std)
    return act, logp


def _gauss_logp(act, mean, log_std):
    var = jnp.exp(2 * log_std)
    lp = -0.5 * ((act - mean) ** 2 / var + 2 * log_std
                 + jnp.log(2 * jnp.pi))
    return jnp.sum(lp, axis=-1)


def log_prob(params, obs, act):
    mean, log_std = policy_dist(params, obs)
    return _gauss_logp(act, mean, log_std)


def entropy(params) -> jnp.ndarray:
    log_std = params["log_std"]
    return jnp.sum(0.5 * (1 + jnp.log(2 * jnp.pi)) + log_std)
