"""Resumable training state: the one bundle ``train()``/``train_async()``
checkpoint and restore.

A ``TrainState`` carries everything a crash would otherwise lose: policy
params, optimizer moments, the PRNG key *carry* (so the stream continues
exactly where it stopped), the PPO minibatch step (Adam bias correction),
the episode counter, the batched env state + observations (so resume skips
the warmup entirely and restarts from the same bits), and the per-episode
history arrays.

Serialization goes through ``repro.ckpt.checkpoint`` as a *plain dict tree*
(NamedTuples like ``EnvState``/``FlowState``/``ScenarioParams`` are converted
to dicts and rebuilt on load), so a checkpoint can be restored without first
constructing a matching target pytree — the manifest alone rebuilds the
state.  That is what makes **cross-plan resume** possible: arrays come back
as host ndarrays and the training loop re-places them onto whatever mesh the
*current* plan resolves to (``engine.place_env_batch``), so a run
checkpointed under one ``ParallelPlan`` restores onto a different
mesh/backend.

The manifest metadata records the run fingerprint (grid, scenarios, n_envs,
horizon, plan); ``check_resume_compatible`` raises an actionable
``CheckpointError`` on any mismatch that would silently change the physics,
while plan changes are explicitly allowed (and reported to the caller).
"""
from __future__ import annotations

from pathlib import Path
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd.env import EnvState
from repro.cfd.scenarios import ScenarioParams
from repro.cfd.solver import FlowState
from repro.ckpt import checkpoint as ckpt

TRAIN_STATE_SCHEMA = "repro.train_state/v1"
# quarantines/grad_skips are the self-healing health counters; checkpoints
# written before they existed restore with zero-filled columns (healthy runs
# logged zeros anyway), see train()'s history padding
HISTORY_FIELDS = ("reward", "cd", "cl", "wall", "quarantines", "grad_skips")

# metadata fields that must match bit-for-bit between checkpoint and config;
# "plan" is deliberately absent (cross-plan resume re-shards the env batch).
# "policy" (architecture fingerprint) is strict but graced for checkpoints
# written before it existed — see check_resume_compatible.
RESUME_STRICT_FIELDS = ("n_envs", "obs_dim", "grid", "horizon",
                        "steps_per_action", "scenarios", "policy")


class TrainState(NamedTuple):
    params: Any                       # policy/value network pytree
    opt_state: Any                    # optimizer moments (mirrors params)
    key: jnp.ndarray                  # PRNG carry BEFORE the next episode
    step: jnp.ndarray                 # int32 PPO minibatch counter
    episode: jnp.ndarray              # int32 episodes completed
    env_state: Any                    # batched EnvState (or None)
    obs: Optional[jnp.ndarray]        # batched observations (or None)
    history: Dict[str, np.ndarray]    # per-episode logs, length == episode


# ---------------------------------------------------------------------------
# (de)serialization
# ---------------------------------------------------------------------------

def _key_data(key):
    """Raw uint32 view of a PRNG key (typed keys unwrapped for storage)."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(key)
    return jnp.asarray(key)


def to_tree(ts: TrainState) -> Dict[str, Any]:
    """TrainState -> pure dict tree (msgpack-manifest friendly paths)."""
    tree: Dict[str, Any] = {
        "params": ts.params,
        "opt_state": ts.opt_state,
        "key": _key_data(ts.key),
        "step": jnp.asarray(ts.step, jnp.int32),
        "episode": jnp.asarray(ts.episode, jnp.int32),
        "history": {k: np.asarray(v) for k, v in ts.history.items()},
    }
    if ts.env_state is not None:
        st = ts.env_state
        if isinstance(st, EnvState):
            tree["env_state"] = {
                "flow": dict(st.flow._asdict()),
                "jet_vel": st.jet_vel,
                "t": st.t,
                # None-valued trailing fields (pre-pinball scenarios) are
                # dropped: the manifest stores arrays only, and the
                # NamedTuple defaults restore them as None on load
                "scn": {k: v for k, v in st.scn._asdict().items()
                        if v is not None},
            }
            if st.reset_flow is not None:   # sentinel quarantine flow
                tree["env_state"]["reset_flow"] = dict(
                    st.reset_flow._asdict())
        else:
            # engine-level loops (toy envs, tests) carry arbitrary pytrees
            tree["env_state"] = st
    if ts.obs is not None:
        tree["obs"] = ts.obs
    return tree


def _nest(arrays: Dict[str, np.ndarray]) -> Dict[str, Any]:
    """'a/b/0/c' path keys -> nested dicts; all-integer levels -> lists."""
    root: Dict[str, Any] = {}
    for path, arr in arrays.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr

    def conv(n):
        if not isinstance(n, dict):
            return n
        out = {k: conv(v) for k, v in n.items()}
        if out and all(k.isdigit() for k in out):
            idx = sorted(out, key=int)
            if [int(i) for i in idx] == list(range(len(idx))):
                return [out[i] for i in idx]
        return out

    return conv(root)


def from_tree(tree: Dict[str, Any], *, typed_key: bool = False) -> TrainState:
    """Rebuild a TrainState (host arrays) from a ``to_tree`` dict."""
    env_state = None
    if "env_state" in tree:
        st = tree["env_state"]
        base = {"flow", "jet_vel", "t", "scn"}
        if isinstance(st, dict) and base <= set(st) <= base | {"reset_flow"}:
            env_state = EnvState(
                flow=FlowState(**st["flow"]),
                jet_vel=st["jet_vel"], t=st["t"],
                scn=ScenarioParams(**st["scn"]),
                reset_flow=(FlowState(**st["reset_flow"])
                            if "reset_flow" in st else None))
        else:
            env_state = st
    key = tree["key"]
    if typed_key:
        key = jax.random.wrap_key_data(jnp.asarray(key))
    return TrainState(params=tree["params"], opt_state=tree["opt_state"],
                      key=key, step=tree["step"], episode=tree["episode"],
                      env_state=env_state, obs=tree.get("obs"),
                      history={k: np.asarray(v)
                               for k, v in tree.get("history", {}).items()})


def state_metadata(ts: TrainState,
                   extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Manifest metadata for one TrainState save."""
    meta = {"schema": TRAIN_STATE_SCHEMA,
            "episode": int(ts.episode),
            "typed_key": bool(jnp.issubdtype(ts.key.dtype,
                                             jax.dtypes.prng_key))}
    meta.update(extra or {})
    return meta


def save_train_state(path: str, ts: TrainState, *,
                     metadata: Optional[Dict[str, Any]] = None,
                     compress: bool = True) -> int:
    """One-shot synchronous save (training loops use ``AsyncCheckpointer``
    with ``to_tree``/``state_metadata`` instead)."""
    return ckpt.save(path, to_tree(ts), step=int(ts.episode),
                     compress=compress, metadata=state_metadata(ts, metadata))


def load_train_state(path: str) -> Tuple[TrainState, Dict[str, Any]]:
    """-> (TrainState of host arrays, manifest metadata).

    The caller re-places arrays onto the current plan's mesh
    (``engine.place_env_batch``) — that host round trip is what makes the
    checkpoint portable across plans/backends."""
    arrays, manifest = ckpt.restore(path)
    meta = manifest.get("metadata", {})
    if meta.get("schema") != TRAIN_STATE_SCHEMA:
        raise ckpt.CheckpointError(
            f"{path} is not a train-state checkpoint (metadata schema "
            f"{meta.get('schema')!r} != {TRAIN_STATE_SCHEMA!r}); it may be "
            f"a raw pytree checkpoint — load it with ckpt.restore instead")
    ts = from_tree(_nest(arrays), typed_key=bool(meta.get("typed_key")))
    return ts, meta


def resolve_resume(resume: Any, ckpt_dir: Optional[str] = None
                   ) -> Optional[str]:
    """Resolve a resume spec to a checkpoint file path (None = fresh run).

    ``True`` / ``"latest"``: the latest valid checkpoint under ``ckpt_dir``
    (error when there is none, or no ``ckpt_dir``).  ``"auto"``: the same,
    but a fresh run when the directory holds no checkpoint yet (the
    preemptible-job idiom).  Anything else: an explicit ``.ckpt`` path or a
    checkpoint directory.  Shared by ``train()`` and ``train_async()`` so
    the two never drift."""
    if not resume:
        return None
    if resume is True or resume in ("latest", "auto"):
        if not ckpt_dir:
            raise ValueError(f"resume={resume!r} needs ckpt_dir to be set "
                             f"(or pass an explicit checkpoint path)")
        path = ckpt.latest_checkpoint(ckpt_dir)
        if path is None:
            if resume == "auto":
                return None               # nothing to resume yet: fresh run
            raise ckpt.CheckpointError(
                f"resume={resume!r} but no valid checkpoint under "
                f"{ckpt_dir!r}")
        return path
    p = Path(str(resume))
    if p.is_dir():
        path = ckpt.latest_checkpoint(str(p))
        if path is None:
            raise ckpt.CheckpointError(
                f"no valid checkpoint under directory {p}")
        return path
    if not p.exists():
        raise ckpt.CheckpointError(f"resume checkpoint not found: {p}")
    return str(p)


# ---------------------------------------------------------------------------
# run fingerprint + compatibility
# ---------------------------------------------------------------------------

def code_fingerprint() -> Dict[str, Any]:
    """Informational solver/commit fingerprint stored beside checkpoints and
    in trajectory-dataset manifests: which code produced this data.  Never a
    strict resume field — offline replay of an OLD dataset under NEW code is
    exactly the regression eval the dataset exists for."""
    commit = "unknown"
    try:
        import subprocess
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=5,
            cwd=Path(__file__).resolve().parent).stdout.strip() or "unknown"
    except Exception:
        pass
    return {"git_commit": commit, "jax": jax.__version__,
            "state_schema": TRAIN_STATE_SCHEMA}


def run_metadata(*, n_envs: int, obs_dim: int, seed: int, grid,
                 horizon: int, steps_per_action: int,
                 scenarios: Optional[Tuple[str, ...]],
                 plan: Optional[Dict[str, Any]],
                 policy: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """The run fingerprint stored beside every checkpoint: everything that
    must match for a bitwise resume (strict fields) plus the plan actually
    executed and the code fingerprint (informational — resume and offline
    replay may change both).  ``policy`` is the architecture fingerprint
    ({"policy", "obs_dim", "act_dim"}): params saved by an MLP run cannot
    restore into an attention run, so it resumes strictly."""
    return {
        "n_envs": int(n_envs),
        "obs_dim": int(obs_dim),
        "seed": int(seed),
        "grid": {"res": int(grid.res), "nx": int(grid.nx),
                 "ny": int(grid.ny), "dt": float(grid.dt)},
        "horizon": int(horizon),
        "steps_per_action": int(steps_per_action),
        "scenarios": list(scenarios) if scenarios else None,
        "plan": plan or {"n_envs": int(n_envs), "n_ranks": 1,
                         "backend": "single-host"},
        "policy": policy or {"policy": "mlp"},
        "code": code_fingerprint(),
    }


def check_resume_compatible(meta: Dict[str, Any], current: Dict[str, Any]
                            ) -> List[str]:
    """Raise ``CheckpointError`` listing every strict-field mismatch between
    a checkpoint's metadata and the current run's fingerprint; returns
    human-readable notes for allowed differences (plan / seed)."""
    errs = []
    notes_grace = []
    for f in RESUME_STRICT_FIELDS:
        if f == "policy" and f not in meta:
            # checkpoints predating the policy fingerprint: those runs could
            # only have been MLP, so restoring is safe iff the current run is
            # too — which the params-tree structure check catches anyway
            notes_grace.append(
                "checkpoint predates the policy fingerprint; assuming the "
                "historical MLP architecture")
            continue
        if meta.get(f) != current.get(f):
            errs.append(f"{f}: checkpoint={meta.get(f)!r} "
                        f"current={current.get(f)!r}")
    if errs:
        raise ckpt.CheckpointError(
            "checkpoint is incompatible with the current TrainConfig "
            "(these change the physics or batch layout, so resuming would "
            "not continue the same run):\n  " + "\n  ".join(errs))
    notes = list(notes_grace)
    if meta.get("plan") != current.get("plan"):
        notes.append(f"cross-plan resume: checkpoint ran {meta.get('plan')}, "
                     f"resuming onto {current.get('plan')} (env batch "
                     f"re-sharded from host arrays)")
    if meta.get("seed") != current.get("seed"):
        notes.append(f"seed differs (checkpoint {meta.get('seed')}, config "
                     f"{current.get('seed')}) — ignored: the restored PRNG "
                     f"key carry is authoritative")
    return notes
