"""Generalized Advantage Estimation (reverse lax.scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gae(rewards, values, last_value, *, gamma: float = 0.99,
        lam: float = 0.95):
    """rewards: (T,), values: (T,), last_value: () -> (advantages, returns).

    Episodes here are fixed-length (the paper's 100 actuation periods), so no
    done-masking is needed; bootstrap with V(s_T).
    """
    v_next = jnp.concatenate([values[1:], last_value[None]])
    deltas = rewards + gamma * v_next - values

    def step(carry, delta):
        adv = delta + gamma * lam * carry
        return adv, adv

    _, advs = jax.lax.scan(step, jnp.float32(0.0), deltas, reverse=True)
    return advs, advs + values


def gae_batch(rewards, values, last_values, **kw):
    """(N_env, T) batched version."""
    return jax.vmap(lambda r, v, lv: gae(r, v, lv, **kw))(
        rewards, values, last_values)
