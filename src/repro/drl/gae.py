"""Generalized Advantage Estimation (reverse lax.scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gae(rewards, values, last_value, *, gamma: float = 0.99,
        lam: float = 0.95, valid=None):
    """rewards: (T,), values: (T,), last_value: () -> (advantages, returns).

    Episodes here are fixed-length (the paper's 100 actuation periods), so no
    done-masking is needed; bootstrap with V(s_T).

    ``valid`` (optional, (T,) of 1.0/0.0 from the divergence sentinel) masks
    quarantined transitions: an invalid step's advantage is zeroed AND the
    recursion is cut through it, so a quarantine reset acts like an episode
    boundary — advantages never propagate across the discontinuity.  An
    all-ones mask multiplies by 1.0 (exact), keeping healthy batches
    bitwise-identical to the unmasked path.
    """
    v_next = jnp.concatenate([values[1:], last_value[None]])
    deltas = rewards + gamma * v_next - values

    if valid is None:
        def step(carry, delta):
            adv = delta + gamma * lam * carry
            return adv, adv

        _, advs = jax.lax.scan(step, jnp.float32(0.0), deltas, reverse=True)
        return advs, advs + values

    def step_masked(carry, dm):
        delta, m = dm
        adv = m * (delta + gamma * lam * carry)
        return adv, adv

    _, advs = jax.lax.scan(step_masked, jnp.float32(0.0),
                           (deltas, valid), reverse=True)
    return advs, advs + values


def gae_batch(rewards, values, last_values, *, valid=None, **kw):
    """(N_env, T) batched version."""
    if valid is None:
        return jax.vmap(lambda r, v, lv: gae(r, v, lv, **kw))(
            rewards, values, last_values)
    return jax.vmap(lambda r, v, lv, m: gae(r, v, lv, valid=m, **kw))(
        rewards, values, last_values, valid)
