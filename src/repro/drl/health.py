"""Training-health watchdog: anomaly detection over the learner's episode
metrics, driving checkpoint rollback in ``train()``/``train_async()``.

The divergence sentinel (``cfd/env.py``) and the non-finite-gradient skip
(``drl/ppo.py``) handle *point* failures inside the jitted program; the
watchdog covers the slower failure mode they cannot — a run whose losses
drift into garbage over several episodes (value-loss explosion, KL blow-up)
while every individual quantity stays finite.  It watches a rolling window
of episode metrics host-side and raises :class:`DivergenceError` when an
episode is anomalous; the training loop catches it, rolls back to the last
healthy checkpoint and replays (bounded retries, then an actionable error).

Thresholds are deliberately loose — the watchdog is a tripwire for
*divergence*, not a convergence critic: a loss must exceed the rolling
median by ``spike_factor`` (default 100x) before it fires.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.testing import faults

# episode metrics the watchdog screens for non-finiteness / spikes
WATCHED = ("policy_loss", "value_loss", "grad_norm")


class DivergenceError(RuntimeError):
    """Training metrics diverged; carries the offending episode + reason."""

    def __init__(self, episode: int, reason: str):
        super().__init__(
            f"training watchdog tripped at episode {episode}: {reason}")
        self.episode = episode
        self.reason = reason


@dataclass(frozen=True)
class WatchdogConfig:
    window: int = 8            # rolling episodes per watched metric
    spike_factor: float = 100.0  # |metric| > factor * rolling median -> trip
    kl_limit: float = 10.0     # |approx_kl| above this is a broken policy
    max_rollbacks: int = 3     # bounded retries before giving up


class Watchdog:
    """Screens one episode's update metrics; remembers a rolling window."""

    def __init__(self, cfg: WatchdogConfig = WatchdogConfig()):
        self.cfg = cfg
        self._hist: Dict[str, deque] = {
            k: deque(maxlen=cfg.window) for k in WATCHED}

    def observe(self, metrics: Optional[Dict[str, float]], *,
                episode: int) -> Optional[str]:
        """Returns a trip reason (str) or None when the episode is healthy.

        Healthy metrics are folded into the rolling window; anomalous ones
        are NOT (a single bad episode must not poison the baseline the next
        comparison uses)."""
        if faults.consume("watchdog", episode=int(episode)):
            return "injected watchdog fault"
        if not metrics:
            return None
        vals = {k: float(metrics[k]) for k in (*WATCHED, "approx_kl")
                if k in metrics}
        for k, v in vals.items():
            if not np.isfinite(v):
                return f"non-finite {k} ({v})"
        kl = vals.get("approx_kl")
        if kl is not None and abs(kl) > self.cfg.kl_limit:
            return (f"approx_kl {kl:.3g} exceeds limit "
                    f"{self.cfg.kl_limit:.3g}")
        for k in WATCHED:
            if k not in vals:
                continue
            hist = self._hist[k]
            if len(hist) == hist.maxlen:   # only with a full baseline window
                med = float(np.median(np.abs(hist)))
                if abs(vals[k]) > self.cfg.spike_factor * max(med, 1e-6):
                    return (f"{k} {vals[k]:.3g} spiked past "
                            f"{self.cfg.spike_factor:.0f}x the rolling "
                            f"median {med:.3g}")
        for k in WATCHED:
            if k in vals:
                self._hist[k].append(vals[k])
        return None
