"""Episode rollout: lax.scan over actuation periods, vmapped over N_envs."""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.drl import networks


class Trajectory(NamedTuple):
    obs: jnp.ndarray      # (T, obs_dim)
    act: jnp.ndarray      # (T, act_dim)
    logp: jnp.ndarray     # (T,)
    reward: jnp.ndarray   # (T,)
    cd: jnp.ndarray       # (T,)
    cl: jnp.ndarray       # (T,)
    last_obs: jnp.ndarray  # (obs_dim,)


def rollout_episode(env_step_fn, params, st0, obs0, key, length: int
                    ) -> Tuple[object, Trajectory]:
    """env_step_fn: (state, action_scalar) -> (state, EnvOutput)."""

    def step(carry, k):
        st, obs = carry
        act, logp = networks.sample_action(params, obs, k)
        st, out = env_step_fn(st, act[0])
        return (st, out.obs), (obs, act, logp, out.reward, out.cd, out.cl)

    keys = jax.random.split(key, length)
    (st, last_obs), (obs, act, logp, rew, cd, cl) = jax.lax.scan(
        step, (st0, obs0), keys)
    return st, Trajectory(obs=obs, act=act, logp=logp, reward=rew,
                          cd=cd, cl=cl, last_obs=last_obs)


def rollout_batch(env_step_fn, params, st0_b, obs0_b, key, length: int,
                  n_envs: int):
    """vmapped over the environment axis (the paper's N_envs parallelism)."""
    keys = jax.random.split(key, n_envs)
    return jax.vmap(
        lambda st, obs, k: rollout_episode(env_step_fn, params, st, obs, k,
                                           length))(st0_b, obs0_b, keys)
