"""Episode rollout: lax.scan over actuation periods, vmapped over N_envs."""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.drl import networks


class Trajectory(NamedTuple):
    """The trailing aux fields default to ``None`` (jax.tree skips None
    subtrees) so sinks/readers written against the 7-field layout keep
    working; they are populated when the env exposes ``obs_aux`` — the
    probe-set side channel a set-structured policy needs to replay the
    trajectory (coords + live-slot mask are constant over an episode)."""
    obs: jnp.ndarray      # (T, obs_dim)
    act: jnp.ndarray      # (T, act_dim)
    logp: jnp.ndarray     # (T,)
    reward: jnp.ndarray   # (T,)
    cd: jnp.ndarray       # (T,)
    cl: jnp.ndarray       # (T,)
    last_obs: jnp.ndarray  # (obs_dim,)
    probe_xy: jnp.ndarray = None    # (obs_dim, 2) normalized probe coords
    probe_mask: jnp.ndarray = None  # (obs_dim,) 1 = live probe slot
    valid: jnp.ndarray = None       # (T,) 1 = healthy step (sentinel mask)


def rollout_episode(env_step_fn, params, st0, obs0, key, length: int,
                    *, obs_aux_fn=None) -> Tuple[object, Trajectory]:
    """env_step_fn: (state, action) -> (state, EnvOutput).

    ``obs_aux_fn(state) -> {"xy", "mask"}`` (optional) is evaluated ONCE on
    the initial state — the probe layout rides in the scenario params and is
    constant over an episode — and fed to every policy evaluation."""
    aux0 = None if obs_aux_fn is None else obs_aux_fn(st0)

    def step(carry, k):
        st, obs = carry
        act, logp = networks.sample_action(params, obs, k, aux=aux0)
        # scalar envs take the bare amplitude (the historical program);
        # vector (multi-body) envs take the whole action vector
        a = act[0] if act.shape[0] == 1 else act
        st, out = env_step_fn(st, a)
        # toy/test envs predating the sentinel carry no ``valid`` at all;
        # None threads through lax.scan as an empty subtree either way
        return (st, out.obs), (obs, act, logp, out.reward, out.cd, out.cl,
                               getattr(out, "valid", None))

    keys = jax.random.split(key, length)
    (st, last_obs), (obs, act, logp, rew, cd, cl, valid) = jax.lax.scan(
        step, (st0, obs0), keys)
    traj = Trajectory(obs=obs, act=act, logp=logp, reward=rew,
                      cd=cd, cl=cl, last_obs=last_obs, valid=valid)
    if aux0 is not None:
        traj = traj._replace(probe_xy=aux0["xy"], probe_mask=aux0["mask"])
    return st, traj


def rollout_batch(env_step_fn, params, st0_b, obs0_b, key, length: int,
                  n_envs: int, *, obs_aux_fn=None):
    """vmapped over the environment axis (the paper's N_envs parallelism)."""
    keys = jax.random.split(key, n_envs)
    # axis_name lets the fault injector address a single env via
    # ``jax.lax.axis_index("env")``; with no collectives in the program it
    # is otherwise inert
    return jax.vmap(
        lambda st, obs, k: rollout_episode(env_step_fn, params, st, obs, k,
                                           length, obs_aux_fn=obs_aux_fn),
        axis_name="env")(st0_b, obs0_b, keys)
