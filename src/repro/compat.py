"""Version-compatibility shims for the jax API surface.

The repo targets current jax but must run on 0.4.x containers:
  * ``jax.shard_map`` became public API after 0.4 (experimental before)
  * its ``check_rep`` kwarg was renamed ``check_vma``
Callers write the NEW spelling; this module adapts downward.
"""
from __future__ import annotations

import inspect

import jax

try:
    _shard_map = jax.shard_map                      # jax >= 0.5
except AttributeError:                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_HAS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map).parameters


def shard_map(f, **kw):
    """jax.shard_map with the modern kwarg spelling on any jax version."""
    if not _HAS_CHECK_VMA and "check_vma" in kw:
        kw["check_rep"] = kw.pop("check_vma")
    return _shard_map(f, **kw)
