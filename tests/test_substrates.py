"""Optimizers, data pipeline, checkpointing, HLO analyzer, MoE parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.ckpt import checkpoint as C
from repro.data.pipeline import LMDataConfig, synthetic_batch
from repro.launch.hlo_analysis import analyze
from repro.optim.optimizers import (adafactor, adamw, clip_by_global_norm,
                                    cosine_schedule, global_norm)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quadratic_convergence(opt):
    target = jnp.array([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for i in range(400):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.int32(i))
    return float(loss(params))


def test_adamw_converges():
    assert _quadratic_convergence(adamw(1e-2)) < 1e-2


def test_adafactor_converges():
    assert _quadratic_convergence(adafactor(5e-2)) < 1e-2


def test_adafactor_factored_state_small():
    opt = adafactor(1e-3)
    params = {"w": jnp.zeros((256, 512))}
    state = opt.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(state))
    assert n_state == 256 + 512           # factored, not full

def test_adafactor_chunked_update_matches_unchunked():
    opt = adafactor(1e-2)
    key = jax.random.PRNGKey(0)
    stacked = {"w": jax.random.normal(key, (8, 130, 140))}
    flat = {"w": stacked["w"].reshape(8 * 130, 140)}
    gs = jax.random.normal(jax.random.fold_in(key, 1), (8, 130, 140))
    st_s = opt.init(stacked)
    new_s, _ = opt.update({"w": gs}, st_s, stacked, jnp.int32(0))
    # chunked path (ndim>=3) must still move params toward -grad direction
    delta = new_s["w"] - stacked["w"]
    assert float(jnp.mean(jnp.sign(delta) == -jnp.sign(gs))) > 0.95


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(min_value=0.1, max_value=100.0))
def test_clip_by_global_norm_property(scale):
    g = {"a": jnp.ones((4,)) * scale, "b": jnp.ones((2, 2)) * scale}
    clipped, norm = clip_by_global_norm(g, 1.0)
    cn = float(global_norm(clipped))
    assert cn <= 1.0 + 1e-4
    if float(norm) <= 1.0:
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(g["a"]), rtol=1e-5)


def test_cosine_schedule_shape():
    fn = cosine_schedule(1.0, warmup=10, total=100)
    assert float(fn(0)) == 0.0
    assert abs(float(fn(10)) - 1.0) < 1e-6
    assert float(fn(100)) < 1e-6
    assert float(fn(55)) < float(fn(11))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_batch_deterministic_and_bounded():
    cfg = LMDataConfig(vocab_size=5000, seq_len=32, global_batch=4, seed=7)
    a = synthetic_batch(cfg, 3)
    b = synthetic_batch(cfg, 3)
    c = synthetic_batch(cfg, 4)
    assert (a["tokens"] == b["tokens"]).all()
    assert not (a["tokens"] == c["tokens"]).all()
    assert a["tokens"].max() < 5000 and a["tokens"].min() >= 0
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)},
            "list": [jnp.zeros((2, 2)), jnp.full((3,), 7.0)]}
    path = str(tmp_path / "x.ckpt")
    C.save(path, tree, step=5, metadata={"note": "test"})
    back = C.restore(path, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "x.ckpt")
    C.save(path, {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        C.restore(path, {"a": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        C.restore(path, {"b": jnp.zeros((3,))})


def test_checkpoint_bf16(tmp_path):
    tree = {"w": jnp.ones((4, 4), jnp.bfloat16) * 1.5}
    path = str(tmp_path / "bf.ckpt")
    C.save(path, tree)
    back = C.restore(path, tree)
    assert back["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

def test_hlo_trip_count_scaling():
    def f(x, w):
        def body(h, w1):
            return jnp.tanh(h @ w1), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    xs = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    txt = jax.jit(f).lower(xs, ws).compile().as_text()
    res = analyze(txt)
    assert abs(res["flops"] - 10 * 2 * 8 * 64 * 64) / (10 * 2 * 8 * 64 * 64) \
        < 0.05


def test_hlo_nested_scan():
    def f(x, w):
        def outer(h, _):
            def inner(h2, w1):
                return h2 @ w1, None
            h, _ = jax.lax.scan(inner, h, w)
            return h, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h

    xs = jax.ShapeDtypeStruct((4, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    txt = jax.jit(f).lower(xs, ws).compile().as_text()
    res = analyze(txt)
    expect = 3 * 5 * 2 * 4 * 32 * 32
    assert abs(res["flops"] - expect) / expect < 0.05


# ---------------------------------------------------------------------------
# MoE dispatch parity (gather/scatter vs reference semantics)
# ---------------------------------------------------------------------------

def test_moe_dispatch_dropless_parity():
    """With generous capacity, dispatch output == dense per-token expert mix."""
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models import moe as moe_mod
    cfg = ModelConfig(name="t", family="moe", source="", num_layers=1,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=10,
                      moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                                    capacity_factor=8.0),
                      param_dtype="float32", compute_dtype="float32")
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    out, aux = moe_mod.apply_moe(cfg, p, x)
    # dense reference: run every expert on every token, combine by router
    xf = x.reshape(-1, 32)
    top_p, top_idx, _ = moe_mod.router(cfg, p, xf)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["we1"]))
    h = h * jnp.einsum("td,edf->tef", xf, p["we3"])
    all_out = jnp.einsum("tef,efd->ted", h, p["we2"])
    ref = jnp.zeros_like(xf)
    for kk in range(2):
        ref = ref + jnp.take_along_axis(
            all_out, top_idx[:, kk][:, None, None].repeat(32, -1), axis=1
        )[:, 0] * top_p[:, kk:kk + 1]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 32)),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)
