"""RolloutEngine: bitwise sync equivalence, async-vs-sync learning parity,
mesh path consistency, and TrajectorySink round trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.drl import networks, rollout
from repro.drl import engine as engine_mod
from repro.drl.engine import (EngineConfig, FileSink, MemorySink,
                              RolloutEngine, SinkSpec,
                              broadcast_env_state, make_sink)
from repro.drl.gae import gae_batch
from repro.drl.ppo import Batch, PPOConfig
from repro.launch.mesh import make_debug_mesh


class _Out:
    def __init__(self, obs, reward):
        self.obs, self.reward = obs, reward
        self.cd = jnp.float32(0)
        self.cl = jnp.float32(0)


def _toy_step(st, a):
    new = st * 0.8 + jnp.array([0.5, 0.0, 0.0]) * a
    return new, _Out(new, -jnp.sum(new[:1] ** 2))


N, T = 8, 24
PCFG = networks.PolicyConfig(obs_dim=3, act_dim=1)
PPO = PPOConfig(lr=1e-3, epochs=4, minibatches=4)


def _setup():
    st0 = jnp.ones((N, 3)) * 2.0
    params = networks.init_actor_critic(PCFG, jax.random.PRNGKey(0))
    engine = RolloutEngine(_toy_step, EngineConfig(n_envs=N, horizon=T))
    return engine, params, st0


# ---------------------------------------------------------------------------
# sync mode == the reference vmap pipeline, bitwise
# ---------------------------------------------------------------------------

def test_engine_collect_matches_rollout_batch_bitwise():
    engine, params, st0 = _setup()
    key = jax.random.PRNGKey(42)
    batch, traj = engine.collect(params, st0, st0, key)

    @jax.jit
    def reference(params, st_b, obs_b, key):
        _, traj = rollout.rollout_batch(_toy_step, params, st_b, obs_b,
                                        key, T, N)
        values = networks.value(params, traj.obs)
        last_v = networks.value(params, traj.last_obs)
        adv, ret = gae_batch(traj.reward, values, last_v,
                             gamma=PPO.gamma, lam=PPO.lam)
        flat = lambda x: x.reshape((-1,) + x.shape[2:])
        return Batch(obs=flat(traj.obs), act=flat(traj.act),
                     logp_old=flat(traj.logp), adv=flat(adv),
                     ret=flat(ret)), traj

    ref_batch, ref_traj = reference(params, st0, st0, key)
    for a, b in zip(traj, ref_traj):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(batch, ref_batch):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_mesh_path_matches_plain():
    engine, params, st0 = _setup()
    mesh = make_debug_mesh(1, 1)
    sharded = RolloutEngine(_toy_step, EngineConfig(n_envs=N, horizon=T),
                            mesh=mesh)
    key = jax.random.PRNGKey(3)
    b0, t0 = engine.collect(params, st0, st0, key)
    b1, t1 = sharded.collect(params, st0, st0, key)
    np.testing.assert_allclose(np.asarray(t0.reward), np.asarray(t1.reward),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(b0.adv), np.asarray(b1.adv),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# async mode: learns, and lands within noise of sync
# ---------------------------------------------------------------------------

def test_async_within_noise_of_sync():
    episodes = 25
    st0 = jnp.ones((N, 3)) * 2.0

    def run(mode):
        engine = RolloutEngine(_toy_step, EngineConfig(
            n_envs=N, horizon=T, gamma=PPO.gamma, lam=PPO.lam))
        params, optimizer, opt_state, key = engine.init(PCFG, PPO, seed=0)
        loop = engine.run_sync if mode == "sync" else engine.run_async
        _, _, returns = loop(params, opt_state, PPO, optimizer, st0, st0,
                             key, episodes)
        return returns

    sync = run("sync")
    asyn = run("async")
    # both learn ...
    assert np.mean(sync[-5:]) > np.mean(sync[:5]) + 0.1
    assert np.mean(asyn[-5:]) > np.mean(asyn[:5]) + 0.1
    # ... and the one-step staleness costs at most a noise-level gap on the
    # final performance (same seed, same number of env interactions)
    gap = abs(float(np.mean(sync[-5:]) - np.mean(asyn[-5:])))
    spread = float(np.std(sync[-10:]) + np.std(asyn[-10:])) + 0.05
    assert gap < 4 * spread, (gap, spread)


# ---------------------------------------------------------------------------
# trajectory sinks
# ---------------------------------------------------------------------------

def _collect_one():
    engine, params, st0 = _setup()
    _, traj = engine.collect(params, st0, st0, jax.random.PRNGKey(7))
    return traj


@pytest.mark.parametrize("codec", ["binary", "zstd"])
def test_file_sink_roundtrip(tmp_path, codec):
    sink = FileSink(str(tmp_path / codec), codec=codec)
    if codec == "zstd" and engine_mod.zstd is not None:
        assert sink.codec == "zstd"   # real zstd installed: no silent fallback
    traj = _collect_one()
    nb = sink.write(0, traj)
    assert nb > 0 and sink.bytes_written == nb and sink.episodes == 1
    back = sink.read(0)
    for a, b in zip(traj, back):
        if a is None or b is None:    # aux probe fields: absent both sides
            assert a is None and b is None
            continue
        np.testing.assert_allclose(np.asarray(a), b, rtol=1e-6, atol=1e-7)
    with pytest.raises(KeyError):
        sink.read(99)
    sink.close()                      # close never destroys spilled data
    assert sink.read(0).obs.shape == back.obs.shape
    sink.cleanup()
    assert not sink.dir.exists()


def test_memory_sink_eviction():
    sink = MemorySink(keep=2)
    traj = _collect_one()
    for ep in range(4):
        sink.write(ep, traj)
    assert sink.episodes == 4
    with pytest.raises(KeyError):
        sink.read(0)
    np.testing.assert_array_equal(sink.read(3).obs, np.asarray(traj.obs))


def test_engine_records_to_sink():
    sink = MemorySink()
    engine = RolloutEngine(_toy_step, EngineConfig(n_envs=N, horizon=T),
                           sink=sink)
    params = networks.init_actor_critic(PCFG, jax.random.PRNGKey(0))
    st0 = jnp.ones((N, 3)) * 2.0
    engine.collect(params, st0, st0, jax.random.PRNGKey(1))
    engine.collect(params, st0, st0, jax.random.PRNGKey(2))
    assert sink.episodes == 2
    assert sink.read(1).obs.shape == (N, T, 3)


def test_run_async_spills_every_episode(tmp_path):
    """Async mode defers each spill until after the next update dispatch
    (to preserve overlap) but must still persist ALL episodes."""
    episodes = 5
    sink = FileSink(str(tmp_path), codec="binary")
    engine = RolloutEngine(_toy_step, EngineConfig(n_envs=N, horizon=T),
                           sink=sink)
    params, optimizer, opt_state, key = engine.init(PCFG, PPO, seed=0)
    st0 = jnp.ones((N, 3)) * 2.0
    engine.run_async(params, opt_state, PPO, optimizer, st0, st0, key,
                     episodes)
    assert sink.episodes == episodes
    for ep in range(episodes):
        assert sink.read(ep).obs.shape == (N, T, 3)
    sink.cleanup()


def test_make_sink_modes(tmp_path):
    assert make_sink("none") is None
    assert isinstance(make_sink("memory"), MemorySink)
    fs = make_sink("binary", str(tmp_path))
    assert isinstance(fs, FileSink)
    fs.cleanup()


def test_make_sink_unknown_mode_and_missing_root(tmp_path):
    with pytest.raises(ValueError, match="unknown sink mode"):
        make_sink("parquet", str(tmp_path))
    with pytest.raises(ValueError, match="root directory"):
        make_sink("binary")                       # file sink needs a root


def test_file_sink_unknown_codec():
    with pytest.raises(ValueError, match="unknown trajectory-sink codec"):
        FileSink("/tmp/never_created", codec="gzip")


def test_file_sink_read_before_write(tmp_path):
    sink = FileSink(str(tmp_path / "empty"))
    with pytest.raises(KeyError, match="episode 0"):
        sink.read(0)
    assert sink.episodes == 0 and sink.bytes_written == 0


def test_file_sink_cleanup_idempotent(tmp_path):
    sink = FileSink(str(tmp_path / "c"))
    sink.write(0, _collect_one())
    sink.cleanup()
    assert not sink.dir.exists()
    sink.cleanup()                                # second cleanup: no error
    with pytest.raises(KeyError):
        sink.read(0)                              # spilled data is gone


def test_memory_sink_eviction_drops_lowest_episode():
    sink = MemorySink(keep=2)
    traj = _collect_one()
    for ep in (5, 3, 7):                          # out-of-order arrivals
        sink.write(ep, traj)
    with pytest.raises(KeyError):
        sink.read(3)                              # lowest id evicted first
    assert sink.read(5).obs.shape == sink.read(7).obs.shape
    sink_one = MemorySink(keep=1)
    sink_one.write(0, traj)
    sink_one.write(1, traj)
    with pytest.raises(KeyError):
        sink_one.read(0)
    np.testing.assert_array_equal(sink_one.read(1).obs, np.asarray(traj.obs))


def test_broadcast_env_state():
    st = {"a": jnp.zeros((3,)), "b": jnp.float32(1.0)}
    obs = jnp.zeros((5,))
    st_b, obs_b = broadcast_env_state(st, obs, 4)
    assert st_b["a"].shape == (4, 3) and st_b["b"].shape == (4,)
    assert obs_b.shape == (4, 5)


# ---------------------------------------------------------------------------
# SinkSpec: the declarative sink config (make_sink's replacement)
# ---------------------------------------------------------------------------

def test_sink_spec_parse_and_build(tmp_path):
    from repro.data.trajectory_dataset import DatasetSink
    assert SinkSpec.parse(None).build() is None
    assert SinkSpec.parse("none").build() is None
    assert SinkSpec.parse("disabled").kind == "none"
    assert isinstance(SinkSpec.parse("memory").build(), MemorySink)
    fs = SinkSpec.parse(f"binary:{tmp_path}/b").build()
    assert isinstance(fs, FileSink) and fs.codec == "binary"
    ds = SinkSpec.parse(f"dataset:{tmp_path}/d").build()
    assert isinstance(ds, DatasetSink)
    assert SinkSpec(kind="memory", keep=3).build().keep == 3


def test_sink_spec_rejects_bad_specs(tmp_path):
    with pytest.raises(ValueError, match="unknown sink kind"):
        SinkSpec(kind="parquet", root=str(tmp_path)).build()
    with pytest.raises(ValueError, match="needs a root directory"):
        SinkSpec(kind="binary").build()
    with pytest.raises(ValueError, match="needs a root directory"):
        SinkSpec(kind="dataset").build()


def test_engine_builds_sink_from_config_spec(tmp_path):
    engine = RolloutEngine(
        _toy_step, EngineConfig(n_envs=N, horizon=T,
                                sink=SinkSpec(kind="memory", keep=2)))
    assert isinstance(engine.sink, MemorySink)
    # an explicit sink= always wins over the config spec
    mine = MemorySink()
    engine = RolloutEngine(
        _toy_step, EngineConfig(n_envs=N, horizon=T,
                                sink=SinkSpec(kind="memory")), sink=mine)
    assert engine.sink is mine


def test_make_sink_deprecation_blames_caller(tmp_path):
    import warnings
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sink = make_sink("memory")
    assert isinstance(sink, MemorySink)
    assert len(w) == 1 and issubclass(w[0].category, DeprecationWarning)
    assert "SinkSpec" in str(w[0].message)
    # stacklevel walks out of the engine module: the warning names THIS file
    assert w[0].filename == __file__


def test_sink_read_errors_are_actionable(tmp_path):
    from repro.drl.engine import SinkReadError
    mem = MemorySink(keep=2)
    traj = _collect_one()
    for ep in range(3):
        mem.write(ep, traj)
    with pytest.raises(SinkReadError, match=r"keep=2"):
        mem.read(0)                         # names the retention window
    fs = FileSink(str(tmp_path), codec="binary")
    fs.write(4, traj)
    with pytest.raises(SinkReadError) as ei:
        fs.read(99)
    msg = str(ei.value)
    assert str(tmp_path) in msg and "codec" in msg and "episode 99" in msg
    fs.cleanup()


def test_engine_timing_stats():
    engine = RolloutEngine(_toy_step,
                           EngineConfig(n_envs=N, horizon=T, timing=True))
    params, optimizer, opt_state, key = engine.init(PCFG, PPO, seed=0)
    st0 = jnp.ones((N, 3)) * 2.0
    engine.run_sync(params, opt_state, PPO, optimizer, st0, st0, key, 2)
    assert engine.stats["episodes"] == 2
    assert engine.stats["collect_s"] > 0 and engine.stats["update_s"] > 0
