"""Self-healing training: every recovery path exercised, not trusted.

Layers, cheapest first:
  * faults module + retry_io + watchdog + heartbeat skew: pure host-side
    unit tests, no JAX programs.
  * GAE mask / dual-path PPO loss / grad skip: the bitwise contract at the
    function level — an all-healthy mask must reproduce the unguarded
    program bit for bit, a poisoned gradient must reject the whole update.
  * sentinel quarantine on a real env batch: a NaN-poisoned env is reset
    from the warmup flow inside the vmapped program, its transition masked.
  * train()-level: guard-on vs guard-off bitwise identity (the acceptance
    gate), watchdog trip -> checkpoint rollback -> completed run, bounded
    retries -> actionable error.
  * durability: sink OSError retry + exhaustion, checkpoint-crash fallback,
    legacy checkpoints without health columns.
"""
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import GridConfig
from repro.ckpt import checkpoint as ck
from repro.ckpt.io import retry_io
from repro.drl import networks, train_state as ts_mod
from repro.drl.engine import EngineConfig, FileSink, RolloutEngine
from repro.drl.gae import gae, gae_batch
from repro.drl.health import Watchdog, WatchdogConfig
from repro.drl.ppo import (Batch, PPOConfig, make_optimizer, ppo_loss,
                           ppo_update)
from repro.drl.rollout import Trajectory
from repro.drl.train import TrainConfig, train
from repro.launch import distributed as dist_mod
from repro.testing import faults

GRID = GridConfig(res=5, dt=0.015, poisson_iters=20)


def _tiny_cfg(episodes, ckpt_dir=None, **kw):
    env_kw = {k: kw.pop(k) for k in ("guard",) if k in kw}
    return TrainConfig(
        env=EnvConfig(grid=GridConfig(res=6, dt=0.012, poisson_iters=30),
                      steps_per_action=3, actions_per_episode=3,
                      warmup_time=1.0, **env_kw),
        ppo=PPOConfig(epochs=2, minibatches=2),
        n_envs=2, episodes=episodes, seed=0, ckpt_dir=ckpt_dir,
        ckpt_every=1, **kw)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# fault-injection harness
# ---------------------------------------------------------------------------

def test_faults_configure_and_consume():
    faults.configure({"watchdog": {"episode": 3}})
    assert faults.active("watchdog") == {"episode": 3}
    assert faults.active("nan_env") is None
    assert not faults.consume("watchdog", episode=2)   # mismatch: not eaten
    assert faults.active("watchdog") is not None
    assert faults.consume("watchdog", episode=3)
    assert faults.active("watchdog") is None           # one-shot: consumed
    assert not faults.consume("watchdog", episode=3)


def test_faults_times_counter():
    faults.configure({"sink_oserror": {"times": 2}})
    assert faults.consume("sink_oserror")
    assert faults.consume("sink_oserror")
    assert not faults.consume("sink_oserror")


def test_faults_missing_keys_match_anything():
    faults.configure({"watchdog": {}})
    assert faults.consume("watchdog", episode=42)


def test_faults_env_var(monkeypatch):
    monkeypatch.setenv(faults.ENV_FAULTS,
                       json.dumps({"grad_nan": {"step": 7}}))
    faults.reset()                       # re-arm environment loading
    assert faults.active("grad_nan") == {"step": 7}
    monkeypatch.setenv(faults.ENV_FAULTS, "not json")
    faults.reset()
    with pytest.raises(ValueError, match="not valid JSON"):
        faults.active("grad_nan")
    monkeypatch.setenv(faults.ENV_FAULTS, "[1, 2]")
    faults.reset()
    with pytest.raises(ValueError, match="JSON object"):
        faults.active("grad_nan")


def test_retry_io_recovers_then_exhausts(tmp_path):
    calls, sleeps, retries = [], [], []
    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("disk hiccup")
        return "ok"
    out = retry_io(flaky, path=tmp_path / "f", sleep=sleeps.append,
                   on_retry=lambda n, e: retries.append(n))
    assert out == "ok" and len(calls) == 3
    assert sleeps == [0.05, 0.1]         # exponential backoff
    assert retries == [1, 2]
    with pytest.raises(OSError, match="after 4 attempts"):
        retry_io(lambda: (_ for _ in ()).throw(OSError("dead")),
                 path=tmp_path / "g", sleep=lambda s: None)


# ---------------------------------------------------------------------------
# watchdog (host-side anomaly screen)
# ---------------------------------------------------------------------------

def _metrics(**kw):
    base = {"policy_loss": 0.1, "value_loss": 1.0, "grad_norm": 0.5,
            "approx_kl": 0.01}
    base.update(kw)
    return base


def test_watchdog_nonfinite_and_kl_trip():
    wd = Watchdog()
    assert wd.observe(_metrics(), episode=0) is None
    assert "non-finite" in wd.observe(_metrics(value_loss=float("nan")),
                                      episode=1)
    assert "approx_kl" in wd.observe(_metrics(approx_kl=99.0), episode=2)


def test_watchdog_spike_needs_full_window():
    wd = Watchdog(WatchdogConfig(window=3, spike_factor=10.0))
    # window not full: a huge value is NOT a spike yet (no baseline)
    assert wd.observe(_metrics(value_loss=500.0), episode=0) is None
    for ep in (1, 2):
        assert wd.observe(_metrics(), episode=ep) is None
    reason = wd.observe(_metrics(value_loss=1e5), episode=3)
    assert reason is not None and "spiked" in reason
    # the anomalous episode was NOT folded into the baseline: a healthy
    # episode right after still passes against the old median
    assert wd.observe(_metrics(), episode=4) is None


def test_watchdog_injected_fault():
    faults.configure({"watchdog": {"episode": 1}})
    wd = Watchdog()
    assert wd.observe(_metrics(), episode=0) is None
    assert wd.observe(_metrics(), episode=1) == "injected watchdog fault"
    assert wd.observe(_metrics(), episode=1) is None   # consumed


# ---------------------------------------------------------------------------
# GAE mask + dual-path PPO loss: the bitwise contract at function level
# ---------------------------------------------------------------------------

def test_gae_mask_zeroes_and_cuts_recursion():
    r = jnp.array([1.0, 2.0, 3.0, 4.0])
    v = jnp.zeros(4)
    adv_m, _ = gae(r, v, jnp.float32(0.0), gamma=0.9, lam=0.9,
                   valid=jnp.array([1.0, 0.0, 1.0, 1.0]))
    assert float(adv_m[1]) == 0.0                      # quarantined: zeroed
    # the recursion is cut at the quarantine: step 0 sees NOTHING from the
    # future (its advantage is its own delta, as if the episode ended there)
    assert float(adv_m[0]) == pytest.approx(1.0)
    # downstream of the cut the recursion is intact
    adv_u, _ = gae(r, v, jnp.float32(0.0), gamma=0.9, lam=0.9)
    np.testing.assert_array_equal(np.asarray(adv_m[2:]),
                                  np.asarray(adv_u[2:]))


def test_gae_all_ones_mask_bitwise():
    key = jax.random.PRNGKey(0)
    r = jax.random.normal(key, (3, 8))
    v = jax.random.normal(jax.random.fold_in(key, 1), (3, 8))
    lv = jax.random.normal(jax.random.fold_in(key, 2), (3,))
    a0, ret0 = gae_batch(r, v, lv, gamma=0.99, lam=0.95)
    a1, ret1 = gae_batch(r, v, lv, gamma=0.99, lam=0.95,
                         valid=jnp.ones((3, 8)))
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))
    np.testing.assert_array_equal(np.asarray(ret0), np.asarray(ret1))


def _toy_batch(n=8, valid=None):
    k = jax.random.PRNGKey(3)
    ks = jax.random.split(k, 5)
    return Batch(obs=jax.random.normal(ks[0], (n, 3)),
                 act=jax.random.normal(ks[1], (n, 1)),
                 logp_old=jax.random.normal(ks[2], (n,)),
                 adv=jax.random.normal(ks[3], (n,)),
                 ret=jax.random.normal(ks[4], (n,)),
                 valid=valid)


PCFG = networks.PolicyConfig(obs_dim=3, act_dim=1, hidden=16)


def test_ppo_loss_all_valid_bitwise():
    """An all-ones validity mask must reproduce the unmasked loss AND its
    gradient bit for bit — the dual-path where(all_ok) select, not the
    masked reductions, is what guarantees this (sum(x*m)/n fuses differently
    from mean(x) inside the full loss graph)."""
    params = networks.init_actor_critic(PCFG, jax.random.PRNGKey(0))
    cfg = PPOConfig()
    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, b: ppo_loss(cfg, p, b)[0]))
    l0, g0 = grad_fn(params, _toy_batch())
    l1, g1 = grad_fn(params, _toy_batch(valid=jnp.ones(8)))
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    _leaves_equal(g0, g1)
    # and a genuinely masked batch differs (the mask is live, not ignored)
    l2, _ = grad_fn(params, _toy_batch(
        valid=jnp.array([1., 1., 0., 1., 1., 1., 0., 1.])))
    assert float(l2) != float(l0)


def test_grad_skip_rejects_poisoned_update():
    """With epochs=1/minibatches=1 the single update IS the poisoned one:
    the guard must leave params and optimizer moments bitwise untouched,
    count the skip, and report grad_norm=0 (a handled fault, not a live
    anomaly for the watchdog)."""
    cfg = PPOConfig(epochs=1, minibatches=1)
    params = networks.init_actor_critic(PCFG, jax.random.PRNGKey(0))
    optimizer = make_optimizer(cfg)
    opt_state = optimizer.init(params)
    key = jax.random.PRNGKey(7)

    faults.configure({"grad_nan": {"step": 0}})
    p1, o1, step1, m1 = ppo_update(cfg, optimizer, params, opt_state,
                                   _toy_batch(), key, jnp.int32(0))
    _leaves_equal(p1, params)
    _leaves_equal(o1, opt_state)
    assert int(step1) == 1               # step indexes the schedule anyway
    assert float(m1["grad_skips"]) == 1.0
    assert float(m1["grad_norm"]) == 0.0

    faults.reset()
    p2, o2, _, m2 = ppo_update(cfg, optimizer, params, opt_state,
                               _toy_batch(), key, jnp.int32(0))
    assert float(m2["grad_skips"]) == 0.0
    assert float(m2["grad_norm"]) > 0.0
    # the clean update actually moved the params
    moved = any(float(jnp.abs(a - b).max()) > 0
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert moved


# ---------------------------------------------------------------------------
# divergence sentinel on a real env batch
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def guarded_batch():
    env = CylinderEnv(EnvConfig(grid=GRID, steps_per_action=3,
                                actions_per_episode=3, warmup_time=1.0))
    st_b, obs_b = env.reset_batch(["cyl_re100"], n_envs=2)
    return env, st_b, obs_b


def test_sentinel_quarantines_poisoned_env(guarded_batch):
    env, st_b, _ = guarded_batch
    faults.configure({"nan_env": {"env": 1, "step": 1}})
    vstep = jax.jit(jax.vmap(env.env_step, axis_name="env"))
    acts = jnp.zeros(2, jnp.float32)

    st_b, out = vstep(st_b, acts)                     # t=0: healthy
    np.testing.assert_array_equal(np.asarray(out.valid), [1.0, 1.0])

    st_b, out = vstep(st_b, acts)                     # t=1: env 1 poisoned
    np.testing.assert_array_equal(np.asarray(out.valid), [1.0, 0.0])
    assert float(out.reward[1]) == 0.0 and float(out.cd[1]) == 0.0
    # the quarantined env was re-initialized from the cached warmup flow —
    # bitwise, so its next episode-from-reset is the standard one
    for got, ref in zip(jax.tree.leaves(st_b.flow),
                        jax.tree.leaves(st_b.reset_flow)):
        np.testing.assert_array_equal(np.asarray(got)[1], np.asarray(ref)[1])
    assert float(st_b.jet_vel[1]) == 0.0
    assert all(np.isfinite(np.asarray(a)).all()
               for a in jax.tree.leaves(st_b.flow))

    st_b, out = vstep(st_b, acts)                     # t=2: healed
    np.testing.assert_array_equal(np.asarray(out.valid), [1.0, 1.0])
    assert np.isfinite(np.asarray(out.reward)).all()


def test_guard_off_keeps_legacy_program(guarded_batch):
    env = CylinderEnv(EnvConfig(grid=GRID, steps_per_action=3,
                                actions_per_episode=3, warmup_time=1.0,
                                guard=False))
    st_b, _ = env.reset_batch(["cyl_re100"], n_envs=2)
    assert st_b.reset_flow is None
    _, out = jax.jit(jax.vmap(env.env_step, axis_name="env"))(
        st_b, jnp.zeros(2, jnp.float32))
    assert out.valid is None


def test_rollout_threads_valid_mask(guarded_batch):
    env, st_b, obs_b = guarded_batch
    faults.configure({"nan_env": {"env": 0, "step": 1}})
    engine = RolloutEngine.for_env(env, EngineConfig(n_envs=2, horizon=3))
    params = networks.init_actor_critic(
        networks.PolicyConfig(obs_dim=int(obs_b.shape[-1])),
        jax.random.PRNGKey(0))
    batch, traj = engine.collect(params, st_b, obs_b, jax.random.PRNGKey(1))
    assert traj.valid.shape == (2, 3)
    assert float(traj.valid.sum()) == 5.0             # exactly one masked
    assert float(traj.valid[0, 1]) == 0.0
    assert batch.valid.shape == (6,)
    assert float(batch.valid.sum()) == 5.0
    # the poisoned transition never leaks NaN into the learner's batch
    assert np.isfinite(np.asarray(batch.adv)).all()
    assert np.isfinite(np.asarray(batch.ret)).all()


# ---------------------------------------------------------------------------
# train() level: bitwise identity + watchdog rollback
# ---------------------------------------------------------------------------

def test_guarded_training_bitwise_identical_when_healthy():
    """The PR's acceptance gate: with no faults firing, guard=True training
    produces bitwise-identical params to guard=False (the pre-sentinel
    program)."""
    _, params_on = train(_tiny_cfg(2), log_fn=None)
    _, params_off = train(_tiny_cfg(2, guard=False), log_fn=None)
    _leaves_equal(params_on, params_off)


def test_watchdog_trip_rolls_back_and_completes(tmp_path):
    d = str(tmp_path / "rb")
    faults.configure({"watchdog": {"episode": 1}})
    logs, health = [], {}
    hist, params = train(_tiny_cfg(2, d), log_fn=logs.append, health=health)
    assert any("rolling back" in l for l in logs), logs
    assert any("resume:" in l for l in logs), logs    # replay from the ckpt
    assert len(hist["reward"]) == 2
    assert health["rollbacks"] == 1
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(params))
    # the replayed run's checkpoint metadata carries the health counters
    meta = ck.read_manifest(ck.latest_checkpoint(d))["metadata"]
    assert meta["health"]["rollbacks"] == 1


def test_watchdog_exhausts_rollbacks_actionable():
    # a fault that trips EVERY attempt: deterministic divergence -> the
    # bounded retries exhaust and the error says what to do about it
    faults.configure({"watchdog": {"times": 99}})
    with pytest.raises(RuntimeError, match="diverged.*rollback"):
        train(_tiny_cfg(1, watchdog=WatchdogConfig(max_rollbacks=1)),
              log_fn=None)


def test_async_train_rolls_back(tmp_path):
    from repro.drl.async_train import train_async

    def toy_step(st, a):
        new = st * 0.8 + jnp.array([0.5, 0.0, 0.0]) * a

        class Out:
            obs, reward = new, -jnp.sum(new[:1] ** 2)
            cd = cl = jnp.float32(0)
        return new, Out()

    st0 = jnp.ones((4, 3)) * 2.0
    d = str(tmp_path / "async")
    faults.configure({"watchdog": {"episode": 2}})
    pcfg = networks.PolicyConfig(obs_dim=3, act_dim=1, hidden=16)
    ppo = PPOConfig(lr=1e-3, epochs=2, minibatches=2)
    params, rs = train_async(toy_step, pcfg, ppo, st0, st0, n_envs=4,
                             horizon=8, episodes=4, seed=0, ckpt_dir=d,
                             ckpt_every=1)
    assert len(rs) == 4
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# durability: sink retries, checkpoint crashes, legacy checkpoints
# ---------------------------------------------------------------------------

def _toy_traj(T=3):
    z = jnp.zeros((2, T))
    return Trajectory(obs=jnp.zeros((2, T, 3)), act=jnp.zeros((2, T, 1)),
                      logp=z, reward=z, cd=z, cl=z,
                      last_obs=jnp.zeros((2, 3)))


def test_sink_retry_recovers_and_counts(tmp_path):
    sink = FileSink(str(tmp_path / "spill"))
    faults.configure({"sink_oserror": {"times": 2}})
    sink.write(0, _toy_traj())
    assert sink.retries == 2
    out = sink.read(0)                   # the retried write landed intact
    assert out.obs.shape == (2, 3, 3)


def test_sink_retry_exhaustion_is_actionable(tmp_path):
    sink = FileSink(str(tmp_path / "spill"))
    faults.configure({"sink_oserror": {"times": 99}})
    with pytest.raises(OSError, match="after 4 attempts"):
        sink.write(0, _toy_traj())
    assert not list((tmp_path / "spill").glob("traj_*"))


def test_dataset_sink_retry(tmp_path):
    from repro.data.trajectory_dataset import DatasetSink, TrajectoryReader
    sink = DatasetSink(str(tmp_path / "ds"))
    faults.configure({"sink_oserror": {"times": 1}})
    sink.write(0, _toy_traj())
    sink.close()
    assert sink.retries >= 1
    out = TrajectoryReader(str(tmp_path / "ds")).read(0)
    assert out.obs.shape == (2, 3, 3)


def test_ckpt_crash_falls_back_to_previous(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": np.arange(4, dtype=np.float32)}
    p1 = ck.save_step(d, 1, tree)
    faults.configure({"ckpt_crash": {"step": 2}})
    with pytest.raises(OSError, match="injected ckpt_crash"):
        ck.save_step(d, 2, tree)
    # the torn write left a .tmp but no destination: resume falls back
    assert not Path(ck.step_path(d, 2)).exists()
    assert ck.latest_checkpoint(d) == p1
    # the fault is consumed: the very next save of step 2 lands
    p2 = ck.save_step(d, 2, tree)
    assert ck.latest_checkpoint(d) == p2


def test_legacy_checkpoint_without_health_columns(tmp_path):
    """Checkpoints written before the health counters existed restore with
    zero-padded quarantine/skip columns instead of a KeyError."""
    d = str(tmp_path / "legacy")
    train(_tiny_cfg(1, d), log_fn=None)
    path = ck.latest_checkpoint(d)
    arrays, manifest = ck.restore(path)
    tree = ts_mod._nest(arrays)
    del tree["history"]["quarantines"], tree["history"]["grad_skips"]
    ck.save(path, tree, step=manifest["step"],
            metadata=manifest["metadata"])
    hist, _ = train(_tiny_cfg(2, d, resume=True), log_fn=None)
    assert len(hist["reward"]) == 2
    np.testing.assert_array_equal(hist["quarantines"], [0.0, 0.0])
    np.testing.assert_array_equal(hist["grad_skips"], [0.0, 0.0])


def test_train_state_reset_flow_roundtrip(guarded_batch):
    _, st_b, obs_b = guarded_batch
    ts = ts_mod.TrainState(
        params={"w": jnp.ones(3)}, opt_state={"m": jnp.zeros(3)},
        key=jax.random.PRNGKey(0), step=jnp.int32(5), episode=jnp.int32(2),
        env_state=st_b, obs=obs_b,
        history={f: np.zeros(2) for f in ts_mod.HISTORY_FIELDS})
    back = ts_mod.from_tree(ts_mod.to_tree(ts))
    assert back.env_state.reset_flow is not None
    _leaves_equal(back.env_state.reset_flow, st_b.reset_flow)
    # and a guard-off state (no reset_flow) round-trips to None, keeping
    # pre-sentinel checkpoints loadable
    st_off = st_b._replace(reset_flow=None)
    back2 = ts_mod.from_tree(ts_mod.to_tree(ts._replace(env_state=st_off)))
    assert back2.env_state.reset_flow is None


# ---------------------------------------------------------------------------
# heartbeat clock-skew hardening
# ---------------------------------------------------------------------------

def _stamp(root, process, payload_time):
    path = dist_mod.heartbeat_path(str(root), process)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps({"process": process, "episode": 1,
                                "pid": 1, "time": payload_time}))
    return path


def test_heartbeat_skew_tolerance(tmp_path):
    now = time.time()
    # runner clock lags 1000s behind: payload looks ancient, mtime is fresh
    p = _stamp(tmp_path, 0, now - 1000.0)
    assert dist_mod.stale_processes(str(tmp_path), 1, timeout=60.0,
                                    now=now) == []
    # supervisor clock leads (mtime looks ancient), payload is fresh
    p1 = _stamp(tmp_path, 1, now)
    os.utime(p1, (now - 1000.0, now - 1000.0))
    assert dist_mod.stale_processes(str(tmp_path), 2, timeout=60.0,
                                    now=now) == []
    # a truly hung runner ages on BOTH clocks -> stale
    os.utime(p, (now - 1000.0, now - 1000.0))
    assert dist_mod.stale_processes(str(tmp_path), 2, timeout=60.0,
                                    now=now) == [0]
