"""DRL substrate: GAE correctness, PPO invariants + learning on a toy env."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.drl import networks, rollout
from repro.drl.gae import gae, gae_batch
from repro.drl.ppo import Batch, PPOConfig, make_optimizer, ppo_loss, ppo_update


def test_gae_matches_naive():
    rng = np.random.RandomState(0)
    T = 20
    rewards = jnp.asarray(rng.randn(T), jnp.float32)
    values = jnp.asarray(rng.randn(T), jnp.float32)
    last_v = jnp.float32(rng.randn())
    gamma, lam = 0.97, 0.9
    adv, ret = gae(rewards, values, last_v, gamma=gamma, lam=lam)
    # naive O(T^2)
    v_next = np.concatenate([np.asarray(values)[1:], [float(last_v)]])
    deltas = np.asarray(rewards) + gamma * v_next - np.asarray(values)
    naive = np.zeros(T)
    for t in range(T):
        acc = 0.0
        for k_ in range(T - t):
            acc += (gamma * lam) ** k_ * deltas[t + k_]
        naive[t] = acc
    np.testing.assert_allclose(np.asarray(adv), naive, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ret), naive + np.asarray(values),
                               rtol=1e-5, atol=1e-5)


def test_gauss_logprob_consistency():
    pcfg = networks.PolicyConfig(obs_dim=5, act_dim=2)
    params = networks.init_actor_critic(pcfg, jax.random.PRNGKey(0))
    obs = jax.random.normal(jax.random.PRNGKey(1), (7, 5))
    act, logp = networks.sample_action(params, obs, jax.random.PRNGKey(2))
    logp2 = networks.log_prob(params, obs, act)
    np.testing.assert_allclose(np.asarray(logp), np.asarray(logp2),
                               rtol=1e-5, atol=1e-5)


def test_ppo_loss_zero_advantage_no_policy_gradient():
    """With adv == 0 the clipped surrogate contributes no policy gradient."""
    pcfg = networks.PolicyConfig(obs_dim=4, act_dim=1)
    params = networks.init_actor_critic(pcfg, jax.random.PRNGKey(0))
    obs = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
    act, logp = networks.sample_action(params, obs, jax.random.PRNGKey(2))
    batch = Batch(obs=obs, act=act, logp_old=logp,
                  adv=jnp.zeros(16), ret=networks.value(params, obs))
    cfg = PPOConfig(normalize_adv=False, entropy_coef=0.0, value_coef=0.0)
    grads = jax.grad(lambda p: ppo_loss(cfg, p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree.leaves(grads["actor"]))
    assert gnorm < 1e-4, gnorm


def test_ppo_ratio_one_at_old_policy():
    pcfg = networks.PolicyConfig(obs_dim=4, act_dim=1)
    params = networks.init_actor_critic(pcfg, jax.random.PRNGKey(0))
    obs = jax.random.normal(jax.random.PRNGKey(1), (8, 4))
    act, logp = networks.sample_action(params, obs, jax.random.PRNGKey(2))
    batch = Batch(obs=obs, act=act, logp_old=logp,
                  adv=jnp.ones(8), ret=jnp.zeros(8))
    cfg = PPOConfig()
    _, metrics = ppo_loss(cfg, params, batch)
    assert float(metrics["clip_frac"]) == 0.0


class _Out:
    def __init__(self, obs, reward):
        self.obs, self.reward = obs, reward
        self.cd = jnp.float32(0)
        self.cl = jnp.float32(0)


def _toy_step(st, a):
    new = st * 0.8 + jnp.array([0.5, 0.0, 0.0]) * a
    return new, _Out(new, -jnp.sum(new[:1] ** 2))


def test_ppo_improves_toy_control():
    pcfg = networks.PolicyConfig(obs_dim=3, act_dim=1)
    key = jax.random.PRNGKey(0)
    params = networks.init_actor_critic(pcfg, key)
    cfg = PPOConfig(lr=1e-3, epochs=4, minibatches=4)
    opt = make_optimizer(cfg)
    opt_state = opt.init(params)
    step = jnp.int32(0)
    N, T = 8, 24

    @jax.jit
    def iteration(params, opt_state, step, key):
        k1, k2 = jax.random.split(key)
        st0 = jnp.ones((N, 3)) * 2.0
        _, traj = rollout.rollout_batch(_toy_step, params, st0, st0, k1, T, N)
        values = networks.value(params, traj.obs)
        last_v = networks.value(params, traj.last_obs)
        adv, ret = gae_batch(traj.reward, values, last_v)
        flat = lambda x: x.reshape((-1,) + x.shape[2:])
        batch = Batch(flat(traj.obs), flat(traj.act), flat(traj.logp),
                      flat(adv), flat(ret))
        params, opt_state, step, _ = ppo_update(cfg, opt, params, opt_state,
                                                batch, k2, step)
        return params, opt_state, step, jnp.mean(jnp.sum(traj.reward, 1))

    rets = []
    for i in range(25):
        key, k = jax.random.split(key)
        params, opt_state, step, r = iteration(params, opt_state, step, k)
        rets.append(float(r))
    assert np.mean(rets[-5:]) > np.mean(rets[:5]) + 0.1, \
        (np.mean(rets[:5]), np.mean(rets[-5:]))
