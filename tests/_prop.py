"""Property-testing shim: real hypothesis when installed (CI does
``pip install -e .[test]``), otherwise a tiny deterministic fallback that
runs each property over a fixed pseudo-random sample so the tier-1 suite
stays runnable in minimal containers.

Only the subset used by this repo's tests is emulated: ``@settings`` /
``@given`` with keyword strategies ``st.integers`` and ``st.floats``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # deterministic fallback
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    st = _St()

    def given(**strats):
        def deco(fn):
            def wrapper():
                rng = random.Random(0)
                for _ in range(getattr(wrapper, "_max_examples", 25)):
                    fn(**{k: s.example(rng) for k, s in strats.items()})
            # no functools.wraps: pytest must see the ZERO-arg signature,
            # not the original one (whose params would look like fixtures)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = 25
            return wrapper
        return deco

    def settings(max_examples=25, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
