"""Golden physics regression: pin the solver's Re=100 shedding physics.

The checked-in reference (``tests/golden/cyl_re100_res8.npz``, produced by
``tools/gen_golden.py``) stores a developed uncontrolled flow state plus the
Strouhal number, mean C_D and C_L oscillation amplitude measured over a
fixed window.  The test restarts the solver from that state, re-measures the
same window, and compares within tight tolerances — so any solver/kernel
change that shifts the physics (discretization, penalization, projection,
Poisson convergence) fails loudly instead of silently corrupting training.

If a physics change is INTENTIONAL, regenerate with
``PYTHONPATH=src python tools/gen_golden.py`` and commit the new npz with
the old -> new numbers in the message (see README).
"""
from pathlib import Path

import numpy as np
import pytest

from repro.cfd import solver
from repro.cfd.grid import GridConfig
from repro.cfd.validation import measure_shedding, run_uncontrolled

GOLDEN = Path(__file__).parent / "golden" / "cyl_re100_res8.npz"

# Relative tolerances.  On the generating platform the re-measurement is
# bit-exact (0.0% on all three), so the slack only needs to cover
# cross-platform float drift over the ~1600-step window of a stable limit
# cycle.  Measured mutation sensitivities (development, restart window):
#   upwind_blend 0.2->0.25:  St -1.6%          -> caught by TOL_ST
#   upwind_blend 0.2->0.3:   St -3.0%, amp +2% -> caught by TOL_ST
#   effective Re off by 10%: amp +9.6%         -> caught by TOL_AMP
TOL_ST = 0.015
TOL_CD = 0.01
TOL_AMP = 0.05


@pytest.fixture(scope="module")
def remeasured():
    ref = np.load(GOLDEN)
    cfg = GridConfig(res=int(ref["res"]), dt=float(ref["dt"]),
                     poisson_iters=int(ref["poisson_iters"]))
    state = solver.FlowState(u=ref["u"], v=ref["v"], p=ref["p"])
    _, cds, cls = run_uncontrolled(cfg, state, int(ref["meas_steps"]))
    return ref, measure_shedding(cds, cls, cfg.dt), cds, cls


def test_strouhal_number(remeasured):
    ref, stats, _, _ = remeasured
    assert stats["strouhal"] == pytest.approx(float(ref["strouhal"]),
                                              rel=TOL_ST)


def test_mean_drag_coefficient(remeasured):
    ref, stats, _, _ = remeasured
    assert stats["cd_mean"] == pytest.approx(float(ref["cd_mean"]),
                                             rel=TOL_CD)


def test_lift_oscillation_amplitude(remeasured):
    ref, stats, _, _ = remeasured
    assert stats["cl_amp"] == pytest.approx(float(ref["cl_amp"]),
                                            rel=TOL_AMP)


def test_shedding_is_developed(remeasured):
    """The reference window must contain genuine periodic shedding — guards
    against a silently-decayed golden state after a regeneration."""
    _, stats, cds, cls = remeasured
    assert stats["n_periods"] >= 3
    assert stats["cl_amp"] > 0.1            # oscillating, not steady
    assert np.isfinite(cds).all() and np.isfinite(cls).all()
    # coarse-IB confined-cylinder ballpark (Schäfer: CD~3.2, St~0.30)
    assert 2.5 < stats["cd_mean"] < 6.0
    assert 0.15 < stats["strouhal"] < 0.40
