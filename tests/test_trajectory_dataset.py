"""Trajectory dataset: shard/manifest round trips, crash-tail recovery,
corruption detection, and the record -> replay bitwise gate."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.data.trajectory_dataset as ds_mod
from repro.data.trajectory_dataset import (DatasetError, DatasetSink,
                                           TrajectoryReader)
from repro.drl import networks
from repro.drl.engine import EngineConfig, RolloutEngine, SinkReadError
from repro.drl.ppo import PPOConfig
from repro.drl.rollout import Trajectory


class _Out:
    def __init__(self, obs, reward):
        self.obs, self.reward = obs, reward
        self.cd = jnp.float32(0)
        self.cl = jnp.float32(0)


def _toy_step(st, a):
    new = st * 0.8 + jnp.array([0.5, 0.0, 0.0]) * a
    return new, _Out(new, -jnp.sum(new[:1] ** 2))


N, T = 4, 8
PCFG = networks.PolicyConfig(obs_dim=3, act_dim=1)
PPO = PPOConfig(lr=1e-3, epochs=2, minibatches=2)


def _setup():
    st0 = jnp.ones((N, 3)) * 2.0
    engine = RolloutEngine(_toy_step, EngineConfig(n_envs=N, horizon=T))
    params = networks.init_actor_critic(PCFG, jax.random.PRNGKey(0))
    return engine, params, st0


def _record(root, episodes=3, **sink_kw):
    """Collect `episodes` through a DatasetSink; returns the trajectories."""
    engine, params, st0 = _setup()
    sink = DatasetSink(str(root), **sink_kw)
    trajs = []
    for ep in range(episodes):
        _, traj = engine.collect(params, st0, st0, jax.random.PRNGKey(ep))
        sink.write(ep, traj)
        trajs.append(traj)
    return sink, trajs


# ---------------------------------------------------------------------------
# round trip, rotation, resume
# ---------------------------------------------------------------------------

def test_dataset_roundtrip(tmp_path):
    sink, trajs = _record(tmp_path / "ds", episodes=3)
    sink.annotate(run="unit", seed=7)
    reader = TrajectoryReader(tmp_path / "ds")
    assert reader.episodes == [0, 1, 2] and len(reader) == 3
    assert reader.metadata["run"] == "unit" and reader.metadata["seed"] == 7
    for ep, traj in enumerate(trajs):
        back = reader.read(ep)
        assert isinstance(back, Trajectory)
        for a, b in zip(traj, back):
            if a is None or b is None:    # aux probe fields absent both ways
                assert a is None and b is None
                continue
            # the codec stores fp32 — bitwise for already-fp32 trajectories
            np.testing.assert_array_equal(np.asarray(a, np.float32), b)
    assert [t.obs.shape for t in reader] == [(N, T, 3)] * 3


def test_shard_rotation_and_read_across_shards(tmp_path):
    root = tmp_path / "ds"
    sink, trajs = _record(root, episodes=4, shard_max_bytes=1)
    # 1-byte budget: every record rotates into its own shard
    assert sorted(p.name for p in root.glob("shard_*.bin")) == [
        f"shard_{i:05d}.bin" for i in range(4)]
    reader = TrajectoryReader(root)
    for ep, traj in enumerate(trajs):
        np.testing.assert_array_equal(np.asarray(traj.obs, np.float32),
                                      reader.read(ep).obs)


def test_reopen_resumes_and_overwrites_crash_tail(tmp_path):
    root = tmp_path / "ds"
    sink, trajs = _record(root, episodes=2)
    shard = root / "shard_00000.bin"
    committed = shard.stat().st_size
    # simulate a SIGKILL mid-append: un-indexed tail garbage past the
    # committed byte count must be ignored by readers and overwritten by
    # the next append
    with open(shard, "ab") as f:
        f.write(b"\xde\xad\xbe\xef" * 8)
    reader = TrajectoryReader(root)                 # tail is invisible
    assert reader.episodes == [0, 1]

    engine, params, st0 = _setup()
    sink2 = DatasetSink(str(root))                  # reopen = resume
    _, traj2 = engine.collect(params, st0, st0, jax.random.PRNGKey(9))
    sink2.write(2, traj2)
    reader = TrajectoryReader(root)
    assert reader.episodes == [0, 1, 2]
    np.testing.assert_array_equal(np.asarray(traj2.obs, np.float32),
                                  reader.read(2).obs)
    man = json.loads((root / "manifest.json").read_text())
    assert man["episodes"]["2"]["offset"] == committed


# ---------------------------------------------------------------------------
# corruption paths: every failure mode is a loud, named error
# ---------------------------------------------------------------------------

def test_missing_manifest_and_wrong_schema(tmp_path):
    with pytest.raises(DatasetError, match="missing manifest.json"):
        TrajectoryReader(tmp_path / "nowhere")
    root = tmp_path / "notds"
    root.mkdir()
    (root / "manifest.json").write_text(json.dumps({"schema": "other/v9"}))
    with pytest.raises(DatasetError, match="not a trajectory dataset"):
        TrajectoryReader(root)


@pytest.mark.parametrize("cut", [1, 8, 100])
def test_truncated_shard_detected(tmp_path, cut):
    root = tmp_path / "ds"
    _record(root, episodes=2)
    shard = root / "shard_00000.bin"
    with open(shard, "r+b") as f:
        f.truncate(max(0, shard.stat().st_size - cut))
    with pytest.raises(DatasetError, match="truncated shard"):
        TrajectoryReader(root)
    # validate=False defers to read time, which still refuses to hand back
    # short bytes
    reader = TrajectoryReader(root, validate=False)
    with pytest.raises(DatasetError):
        for ep in reader.episodes:
            reader.read(ep)


def test_crc_bit_flip_detected(tmp_path):
    root = tmp_path / "ds"
    _record(root, episodes=1)
    shard = root / "shard_00000.bin"
    with open(shard, "r+b") as f:
        f.seek(shard.stat().st_size // 2)       # well inside the payload
        b = f.read(1)
        f.seek(-1, 1)
        f.write(bytes([b[0] ^ 0x01]))
    reader = TrajectoryReader(root)             # sizes intact: validate OK
    with pytest.raises(DatasetError, match="crc32 mismatch"):
        reader.read(0)


def test_manifest_shard_table_mismatch(tmp_path):
    root = tmp_path / "ds"
    _record(root, episodes=1)
    mpath = root / "manifest.json"
    man = json.loads(mpath.read_text())
    man["episodes"]["0"]["shard"] = "shard_00042.bin"
    mpath.write_text(json.dumps(man))
    with pytest.raises(DatasetError, match="manifest/shard-count mismatch"):
        TrajectoryReader(root)


def test_missing_shard_file_detected(tmp_path):
    root = tmp_path / "ds"
    _record(root, episodes=1)
    (root / "shard_00000.bin").unlink()
    with pytest.raises(DatasetError, match="missing shard"):
        TrajectoryReader(root)


def test_missing_episode_is_actionable_keyerror(tmp_path):
    root = tmp_path / "ds"
    _record(root, episodes=2)
    reader = TrajectoryReader(root)
    with pytest.raises(KeyError):               # SinkReadError is a KeyError
        reader.read(99)
    with pytest.raises(SinkReadError) as ei:
        reader.read(99)
    msg = str(ei.value)
    assert str(root) in msg and "episodes 0..1" in msg and "codec" in msg


def test_zstd_gating(tmp_path, monkeypatch):
    root = tmp_path / "ds"
    if ds_mod.zstd is None:
        # zstandard absent (the CI image): requesting zstd degrades to
        # binary on a FRESH dataset instead of failing the run
        sink = DatasetSink(str(root), codec="zstd")
        assert sink.codec == "binary"
        return
    _record(root, episodes=1, codec="zstd")
    monkeypatch.setattr(ds_mod, "zstd", None)
    with pytest.raises(DatasetError, match="zstandard is not installed"):
        TrajectoryReader(root)                  # actionable, not ImportError
    with pytest.raises(DatasetError, match="cannot append"):
        DatasetSink(str(root))                  # resuming it: same story


def test_unknown_codec_rejected(tmp_path):
    with pytest.raises(ValueError, match="unknown trajectory-sink codec"):
        DatasetSink(str(tmp_path / "ds"), codec="gzip")


# ---------------------------------------------------------------------------
# offline replay: the bitwise gate
# ---------------------------------------------------------------------------

def test_replay_reproduces_live_run_bitwise(tmp_path):
    """run_sync with a dataset sink, then replay_sync from the same init:
    identical params, opt state leaves, and per-episode returns."""
    episodes = 4
    engine, _, st0 = _setup()
    engine.sink = DatasetSink(str(tmp_path / "ds"))
    params0, optimizer, opt_state0, key0 = engine.init(PCFG, PPO, seed=3)
    params_live, opt_live, ret_live = engine.run_sync(
        params0, opt_state0, PPO, optimizer, st0, st0, key0, episodes)

    reader = TrajectoryReader(tmp_path / "ds")
    replayer = RolloutEngine(_toy_step, EngineConfig(n_envs=N, horizon=T))
    params_r, opt_r, ret_r = replayer.replay_sync(
        reader, params0, opt_state0, PPO, optimizer, key0, episodes)

    for a, b in zip(jax.tree.leaves(params_live), jax.tree.leaves(params_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt_live), jax.tree.leaves(opt_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(ret_live, ret_r)


def test_replay_from_memory_sink(tmp_path):
    """replay_sync accepts any reader with read(ep) -> Trajectory —
    including the in-memory sink (keep must cover the run)."""
    from repro.drl.engine import MemorySink
    episodes = 3
    engine, _, st0 = _setup()
    engine.sink = MemorySink(keep=episodes)
    params0, optimizer, opt_state0, key0 = engine.init(PCFG, PPO, seed=1)
    params_live, _, _ = engine.run_sync(
        params0, opt_state0, PPO, optimizer, st0, st0, key0, episodes)
    params_r, _, _ = engine.replay_sync(
        engine.sink, params0, opt_state0, PPO, optimizer, key0, episodes)
    for a, b in zip(jax.tree.leaves(params_live), jax.tree.leaves(params_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_replay_start_offset(tmp_path):
    """start= replays a suffix: PRNG splits for the skipped prefix must be
    burned exactly as run_sync would have."""
    engine, _, st0 = _setup()
    engine.sink = DatasetSink(str(tmp_path / "ds"))
    params0, optimizer, opt_state0, key0 = engine.init(PCFG, PPO, seed=5)
    # live: 3 episodes; carry after 1 episode captured via on_state
    carries = []
    params_live, _, _ = engine.run_sync(
        params0, opt_state0, PPO, optimizer, st0, st0, key0, 3,
        on_state=lambda c: carries.append(c))
    c1 = carries[0]
    reader = TrajectoryReader(tmp_path / "ds")
    params_r, _, _ = engine.replay_sync(
        reader, c1.params, c1.opt_state, PPO, optimizer, c1.key, 2,
        step=c1.step, start=1)
    for a, b in zip(jax.tree.leaves(params_live), jax.tree.leaves(params_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
