"""CFD solver physics validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import poisson, probes, solver
from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import GridConfig, build_geometry, probe_positions

CFG = GridConfig(res=8, dt=0.01, poisson_iters=60)


@pytest.fixture(scope="module")
def geom():
    return build_geometry(CFG)


@pytest.fixture(scope="module")
def developed(geom):
    """~8 t.u. of uncontrolled flow (module-scoped: shared by tests)."""
    ga = solver.geom_to_arrays(geom)
    st = solver.init_state(CFG, geom)

    def body(flow, _):
        flow, out = solver.step(CFG, ga, flow, jnp.float32(0.0))
        return flow, (out.cd, out.cl)

    st, (cds, cls) = jax.jit(
        lambda s: jax.lax.scan(body, s, None, length=800))(st)
    return st, np.asarray(cds), np.asarray(cls), ga


def test_poisson_residual_reduction():
    rhs = jax.random.normal(jax.random.PRNGKey(0), (40, 176))
    p0 = jnp.zeros_like(rhs)
    r0 = float(jnp.linalg.norm(poisson.residual(p0, rhs, 0.125, 0.125)))
    p = poisson.solve(rhs, 0.125, 0.125, iters=200)
    r = float(jnp.linalg.norm(poisson.residual(p, rhs, 0.125, 0.125)))
    assert r < 0.05 * r0, (r, r0)


def test_divergence_free_interior(developed):
    st, _, _, _ = developed
    div = np.asarray(solver.divergence(st.u, st.v, CFG))
    from repro.cfd.grid import CYL_X, CYL_Y, cell_centers
    xc, yc = cell_centers(CFG)
    xx, yy = np.meshgrid(xc, yc)
    r = np.sqrt((xx - CYL_X) ** 2 + (yy - CYL_Y) ** 2)
    interior = (r > 0.5 + 2 * CFG.dx) & (xx < 18.0)
    assert np.abs(div[interior]).max() < 0.05


def test_drag_in_confined_cylinder_range(developed):
    _, cds, _, _ = developed
    cd = cds[-200:].mean()
    # Schäfer confined benchmark: C_D ~ 3.2; coarse IB overestimates somewhat
    assert 2.5 < cd < 4.5, cd


def test_no_nan_and_bounded_velocity(developed):
    st, _, _, _ = developed
    assert not np.isnan(np.asarray(st.u)).any()
    assert np.abs(np.asarray(st.u)).max() < 3.5   # < ~2.3 x U_m physically


def test_mass_conservation(developed):
    st, _, _, _ = developed
    influx = float(jnp.sum(st.u[:, 0]) * CFG.dy)
    outflux = float(jnp.sum(st.u[:, -1]) * CFG.dy)
    assert abs(outflux - influx) / abs(influx) < 0.02


def test_jets_alter_lift(developed, geom):
    """Blowing from the top jet should push lift measurably."""
    st, _, _, ga = developed

    def run(jet):
        def body(flow, _):
            flow, out = solver.step(CFG, ga, flow, jet)
            return flow, out.cl
        _, cls = jax.lax.scan(body, st, None, length=100)
        return float(jnp.mean(cls[-50:]))

    cl_neutral = run(jnp.float32(0.0))
    cl_blow = run(jnp.float32(1.0))
    assert abs(cl_blow - cl_neutral) > 0.05, (cl_neutral, cl_blow)


def test_probe_layout_149():
    pts = probe_positions()
    assert pts.shape == (149, 2)
    # all probes inside the domain, outside the cylinder
    assert (pts[:, 0] > -2).all() and (pts[:, 0] < 20).all()
    r = np.sqrt(pts[:, 0] ** 2 + (pts[:, 1] - 0.05) ** 2)
    assert (r > 0.5).all()


def test_probe_sampling_matches_bilinear(geom):
    p = jnp.asarray(np.random.RandomState(0).randn(CFG.ny, CFG.nx),
                    jnp.float32)
    vals = probes.sample_pressure(geom.probe_ij, p)
    assert vals.shape == (149,)
    assert not bool(jnp.any(jnp.isnan(vals)))


def test_env_step_api():
    env = CylinderEnv(EnvConfig(grid=GridConfig(res=6, dt=0.012,
                                                poisson_iters=40),
                                steps_per_action=10, warmup_time=5.0))
    st, obs = env.reset()
    assert obs.shape == (149,)
    assert env.cfg.cd0 > 0  # cd0=None default -> calibrated in warmup
    st2, out = jax.jit(env.env_step)(st, jnp.float32(0.5))
    # eq. (11): V_1 = V_0 + beta*(a*Um - V_0)
    expect = 0.4 * 0.5 * env.cfg.action_max
    assert abs(float(st2.jet_vel) - expect) < 1e-5
    assert not bool(jnp.isnan(out.reward))


def test_cd0_explicit_vs_calibrated():
    """cd0=None calibrates from warmup; any float (even 0.0) is used as-is."""
    grid = GridConfig(res=6, dt=0.012, poisson_iters=30)
    base = dict(grid=grid, steps_per_action=5, warmup_time=1.0)

    env_cal = CylinderEnv(EnvConfig(**base))            # cd0=None default
    st_cal, _ = env_cal.reset()
    assert env_cal.cfg.cd0 is not None and env_cal.cfg.cd0 > 0
    assert float(st_cal.scn.cd0) == pytest.approx(env_cal.cfg.cd0)

    env_fix = CylinderEnv(EnvConfig(**base, cd0=3.205))  # paper's value
    st_fix, _ = env_fix.reset()
    assert env_fix.cfg.cd0 == 3.205                      # NOT recalibrated
    assert float(st_fix.scn.cd0) == pytest.approx(3.205)

    env_zero = CylinderEnv(EnvConfig(**base, cd0=0.0))   # explicit zero
    env_zero.reset()
    assert env_zero.cfg.cd0 == 0.0                       # kept, not a flag


def test_momentum_force_measured_from_predictor(developed):
    """_momentum contract: fx/fy are the momentum the penalization removed,
    measured against the PREDICTOR u_star/v_star before the fused BC/mass
    pass touches the fields (the post-BC fields are the separate u_bc/v_bc
    names).  Recompute the predictor chain independently and require exact
    f32 agreement — a refactor that moves the force measurement after the
    BCs (or reorders the chain) breaks this."""
    st, _, _, ga = developed
    jet = jnp.float32(0.1)

    up, vp = solver._pad_u(st.u), solver._pad_v(st.v)
    u_star = st.u + CFG.dt * solver._advect_diffuse_u(up, vp, CFG, CFG.re)
    v_star = st.v + CFG.dt * solver._advect_diffuse_v(up, vp, CFG, CFG.re)
    lam = CFG.dt / CFG.penal_eta
    pen_u = jnp.maximum(ga.chi_u, ga.jmask_u)
    pen_v = jnp.maximum(ga.chi_v, ga.jmask_v)
    u_pen = (u_star + lam * pen_u * (jet * (ga.jet_u[0] - ga.jet_u[1]))) \
        / (1 + lam * pen_u)
    v_pen = (v_star + lam * pen_v * (jet * (ga.jet_v[0] - ga.jet_v[1]))) \
        / (1 + lam * pen_v)
    fx_pred = -jnp.sum((u_pen - u_star) / CFG.dt) * CFG.dx * CFG.dy
    fy_pred = -jnp.sum((v_pen - v_star) / CFG.dt) * CFG.dx * CFG.dy

    u_bc, v_bc, fx, fy = solver._momentum(CFG, ga, st.u, st.v, jet,
                                          CFG.re, None)
    assert float(fx) == float(fx_pred)
    assert float(fy) == float(fy_pred)
    # the BC/mass pass runs AFTER the measurement: it edits only the inlet
    # and outlet columns of u (and the walls of v), and it does edit them
    assert float(jnp.max(jnp.abs(u_bc[:, 1:-1] - u_pen[:, 1:-1]))) == 0.0
    assert float(jnp.max(jnp.abs(u_bc - u_pen))) > 0.0
    # measuring from the post-BC field would give a different force
    fx_post = -jnp.sum((u_bc - u_star) / CFG.dt) * CFG.dx * CFG.dy
    assert float(fx) != float(fx_post)
