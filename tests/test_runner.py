"""core/runner: distributed collect builds + runs on a 1-device mesh."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import GridConfig
from repro.core import runner
from repro.drl import networks
from repro.launch.mesh import make_debug_mesh


def test_distributed_collect_runs():
    env = CylinderEnv(EnvConfig(
        grid=GridConfig(res=6, dt=0.012, poisson_iters=30),
        steps_per_action=5, actions_per_episode=4, warmup_time=2.0))
    st, obs = env.reset()
    mesh = make_debug_mesh(1, 1)
    n_envs, T = 2, 4
    st_b = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_envs,) + a.shape),
                        st)
    obs_b = jnp.broadcast_to(obs, (n_envs,) + obs.shape)
    pcfg = networks.PolicyConfig()
    params = networks.init_actor_critic(pcfg, jax.random.PRNGKey(0))
    jitted, _ = runner.make_distributed_collect(env, mesh, n_envs, T)
    batch, traj = jitted(params, st_b, obs_b, jax.random.PRNGKey(1))
    assert batch.obs.shape == (n_envs * T, 149)
    assert batch.adv.shape == (n_envs * T,)
    assert not bool(jnp.any(jnp.isnan(batch.adv)))
    assert traj.cd.shape == (n_envs, T)


def test_sharded_cfd_step():
    env = CylinderEnv(EnvConfig(
        grid=GridConfig(res=6, dt=0.012, poisson_iters=30), warmup_time=0.0))
    from repro.cfd import solver
    st = solver.init_state(env.cfg.grid, env.geom)
    mesh = make_debug_mesh(1, 1)
    step = runner.make_sharded_cfd_step(env, mesh)
    st2, out = step(st, jnp.float32(0.1))
    assert st2.u.shape == st.u.shape
    assert not bool(jnp.isnan(out.cd))
