"""Per-architecture smoke tests: reduced variant (2 layers, d_model<=512,
<=4 experts) runs one forward/train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, list_configs
from repro.launch import steps
from repro.models import frontend as fe_mod
from repro.models import model as M

ARCHS = list_configs()


def _inputs(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend:
        T = fe_mod.num_frontend_tokens(cfg, S)
        fe = jax.random.normal(key, (B, T, fe_mod.frontend_dim(cfg)))
    return tokens, fe


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens, fe = _inputs(cfg)
    logits, aux = M.forward_train(cfg, params, tokens, fe)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss_shape(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = steps.make_opt(cfg)
    opt_state = opt.init(params)
    train_step = jax.jit(steps.make_train_step(cfg))
    B, S = 2, 16
    tokens, fe = _inputs(cfg)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if fe is not None:
        batch["frontend_embeds"] = fe
    step = jnp.int32(0)
    losses = []
    for _ in range(3):
        params, opt_state, step, metrics = train_step(params, opt_state,
                                                      step, batch)
        losses.append(float(metrics["loss"]))
    assert all(not jnp.isnan(l) for l in losses)
    assert losses[-1] < losses[0], losses  # memorizes a fixed tiny batch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill matches teacher-forced forward logits."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    tokens, fe = _inputs(cfg)
    full_logits, _ = M.forward_train(cfg, params, tokens, fe)
    lp, cache = M.prefill(cfg, params, tokens[:, :S - 1], cache_len=S + 2,
                          frontend_embeds=fe)
    # decode the last token: should match the forward pass at position S-1
    lg, cache = M.decode_step(cfg, params, cache, tokens[:, S - 1:S],
                              jnp.int32(S - 1))
    ref = full_logits[:, S - 1]
    err = float(jnp.max(jnp.abs(lg - ref)))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert err / scale < 0.05, (arch, err, scale)


# ---------------------------------------------------------------------------
# backend= selection (use_pallas= deprecation shim)
# ---------------------------------------------------------------------------

def _one_arch():
    cfg = get_config(ARCHS[0]).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens, fe = _inputs(cfg)
    return cfg, params, tokens, fe


def test_backend_reference_equals_use_pallas_false():
    cfg, params, tokens, fe = _one_arch()
    import warnings
    ref, _ = M.forward_train(cfg, params, tokens, fe, backend="reference")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old, _ = M.forward_train(cfg, params, tokens, fe, use_pallas=False)
    assert bool(jnp.all(ref == old))


def test_use_pallas_deprecation_blames_this_file():
    cfg, params, tokens, fe = _one_arch()
    import warnings
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        M.forward_train(cfg, params, tokens, fe, use_pallas=False)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)
           and "use_pallas" in str(x.message)]
    assert len(dep) == 1                 # resolved ONCE at the entry point
    assert dep[0].filename == __file__   # stacklevel walks out of models/


def test_backend_conflict_and_unknown_raise():
    cfg, params, tokens, fe = _one_arch()
    with pytest.raises(ValueError, match="conflicting kernel selection"):
        M.forward_train(cfg, params, tokens, fe, backend="reference",
                        use_pallas=True)
    with pytest.raises(ValueError, match="unknown kernel backend"):
        M.forward_train(cfg, params, tokens, fe, backend="tpu")
    with pytest.raises(ValueError, match="unknown kernel backend"):
        steps.make_train_step(cfg, backend="tpu")
