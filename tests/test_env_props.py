"""Property-based environment invariants (via tests/_prop.py: hypothesis
when installed, deterministic fallback otherwise).

These pin the contracts the DRL stack relies on for ANY valid action/state:
bounded post-projection divergence, finite observations/rewards, the
eq. (11) actuation-smoothing bound |V_jet| <= action_max, and pytree
structure stability under vmap (the batching contract of the engine).
"""
import jax
import jax.numpy as jnp
import numpy as np

from _prop import given, settings, st
from repro.cfd import probes, solver
from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import CYL_X, CYL_Y, GridConfig, cell_centers

_env = None
_step = None


def get_env() -> CylinderEnv:
    """Module-cached tiny env (hypothesis forbids function-scoped fixtures)."""
    global _env, _step
    if _env is None:
        _env = CylinderEnv(EnvConfig(
            grid=GridConfig(res=6, dt=0.012, poisson_iters=25),
            steps_per_action=2, warmup_time=2.0))
        _env.reset()
        _step = jax.jit(_env.env_step)   # one cache for all examples
    return _env


def get_step():
    get_env()
    return _step


@settings(max_examples=15, deadline=None)
@given(action=st.floats(min_value=-3.0, max_value=3.0),
       jet0=st.floats(min_value=-1.0, max_value=1.0))
def test_action_smoothing_respects_bound(action, jet0):
    """|V_jet| <= action_max after env_step, from any in-range prior jet
    velocity and any (even out-of-range) commanded action."""
    env = get_env()
    amax = env.cfg.action_max
    st0, _ = env.reset()
    st0 = st0._replace(jet_vel=jnp.float32(jet0 * amax))
    st1, out = get_step()(st0, jnp.float32(action))
    assert abs(float(st1.jet_vel)) <= amax + 1e-5
    # eq. (11) contraction: the new jet velocity lies between the old one
    # and the clipped scaled action
    a = np.clip(action, -1.0, 1.0) * amax
    lo, hi = min(jet0 * amax, a), max(jet0 * amax, a)
    assert lo - 1e-5 <= float(st1.jet_vel) <= hi + 1e-5


@settings(max_examples=10, deadline=None)
@given(action=st.floats(min_value=-1.0, max_value=1.0),
       seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_reward_and_obs_finite(action, seed):
    """Finite reward/obs/forces for random valid states (reset flow plus a
    modest random smooth perturbation)."""
    env = get_env()
    st0, _ = env.reset()
    key = jax.random.PRNGKey(seed)
    ku, kv = jax.random.split(key)
    flow = st0.flow
    flow = solver.FlowState(
        u=flow.u + 0.05 * jax.random.normal(ku, flow.u.shape),
        v=flow.v + 0.05 * jax.random.normal(kv, flow.v.shape),
        p=flow.p)
    st1, out = get_step()(st0._replace(flow=flow), jnp.float32(action))
    assert bool(jnp.isfinite(out.reward))
    assert bool(jnp.all(jnp.isfinite(out.obs)))
    assert bool(jnp.isfinite(out.cd)) and bool(jnp.isfinite(out.cl))
    assert bool(jnp.all(jnp.isfinite(st1.flow.u)))


@settings(max_examples=10, deadline=None)
@given(action=st.floats(min_value=-1.0, max_value=1.0),
       seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_divergence_bounded_post_projection(action, seed):
    """An env_step (which ends in a projection) contracts the interior
    divergence of a randomly perturbed state by a large factor AND leaves
    it under an absolute cap (measured: ratio 0.07-0.21, post 0.15-0.40 at
    this resolution/iteration budget)."""
    env = get_env()
    cfg = env.cfg.grid
    st0, _ = env.reset()
    ku, kv = jax.random.split(jax.random.PRNGKey(seed))
    flow = solver.FlowState(
        u=st0.flow.u + 0.05 * jax.random.normal(ku, st0.flow.u.shape),
        v=st0.flow.v + 0.05 * jax.random.normal(kv, st0.flow.v.shape),
        p=st0.flow.p)
    xc, yc = cell_centers(cfg)
    xx, yy = np.meshgrid(xc, yc)
    r = np.sqrt((xx - CYL_X) ** 2 + (yy - CYL_Y) ** 2)
    interior = (r > 0.5 + 2 * cfg.dx) & (xx < 18.0)

    pre = np.abs(np.asarray(
        solver.divergence(flow.u, flow.v, cfg))[interior]).max()
    st1, _ = get_step()(st0._replace(flow=flow), jnp.float32(action))
    post = np.abs(np.asarray(
        solver.divergence(st1.flow.u, st1.flow.v, cfg))[interior]).max()
    assert post < 0.4 * pre, (pre, post)
    assert post < 1.0, post


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       scale=st.floats(min_value=0.01, max_value=100.0))
def test_probe_observations_finite(seed, scale):
    """Probe sampling is finite for arbitrary (even huge) pressure fields,
    and padded probe slots read exactly zero."""
    env = get_env()
    cfg = env.cfg.grid
    p = scale * jax.random.normal(jax.random.PRNGKey(seed),
                                  (cfg.ny, cfg.nx))
    st0, _ = env.reset()
    vals = probes.sample_pressure(st0.scn.probe_ij, p, st0.scn.probe_mask)
    assert bool(jnp.all(jnp.isfinite(vals)))
    # with a mask that pads the tail, padded slots are exactly zero
    mask = st0.scn.probe_mask.at[-5:].set(0.0)
    vals = probes.sample_pressure(st0.scn.probe_ij, p, mask)
    assert bool(jnp.all(vals[-5:] == 0.0))


def test_env_step_pytree_stable_under_vmap():
    """vmapped env_step preserves the pytree structure and broadcasts every
    leaf shape with the batch axis — the contract RolloutEngine's scan/vmap
    nesting relies on."""
    env = get_env()
    st0, obs0 = env.reset()
    n = 3
    st_b = jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), st0)
    acts = jnp.array([0.1, -0.5, 1.0], jnp.float32)

    st1, out1 = get_step()(st0, acts[0])
    st_b1, out_b = jax.jit(jax.vmap(env.env_step))(st_b, acts)

    assert (jax.tree.structure(st_b1) == jax.tree.structure(st1))
    assert (jax.tree.structure(out_b) == jax.tree.structure(out1))
    for single, batched in zip(jax.tree.leaves(st1), jax.tree.leaves(st_b1)):
        assert batched.shape == (n,) + single.shape
        assert batched.dtype == single.dtype
    # env 0 of the batch integrates exactly like the unbatched program
    np.testing.assert_allclose(np.asarray(out_b.reward[0]),
                               np.asarray(out1.reward), rtol=2e-5, atol=1e-6)
