"""Multi-body geometries: fluidic pinball scenarios, per-body actuation,
and mixed cylinder+pinball batches through one vmapped program."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import scenarios as S
from repro.cfd import solver
from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import (GEOMETRIES, GridConfig, build_geometry,
                            geometry_index, geometry_names, max_bodies)

GRID = GridConfig(res=5, dt=0.015, poisson_iters=20)


@pytest.fixture(scope="module")
def env():
    return CylinderEnv(EnvConfig(grid=GRID, steps_per_action=4,
                                 actions_per_episode=3, warmup_time=1.0))


@pytest.fixture(scope="module")
def pinball_env():
    cfg = EnvConfig.for_scenario("pinball_re100", grid=GRID,
                                 steps_per_action=4, actions_per_episode=3,
                                 warmup_time=1.0)
    return CylinderEnv(cfg)


# ---------------------------------------------------------------------------
# geometry registry + per-body fields
# ---------------------------------------------------------------------------

def test_geometry_registry():
    assert set(geometry_names()) >= {"cylinder", "pinball", "tandem"}
    assert len(GEOMETRIES["pinball"]) == 3
    assert len(GEOMETRIES["tandem"]) == 2
    assert max_bodies() >= 3
    assert geometry_index("cylinder") != geometry_index("pinball")


def test_pinball_geometry_fields():
    geom = build_geometry(GRID, "pinball")
    assert geom.n_bodies == 3
    assert geom.rotb_u.shape[0] == 3
    # the legacy aggregate rotary target is exactly the per-body sum
    np.testing.assert_array_equal(geom.rot_u, geom.rotb_u.sum(0))
    # ownership partitions every solid-adjacent cell to exactly one body
    own = np.asarray(geom.own_u)
    assert own.min() >= 0 and own.max() <= 1
    np.testing.assert_array_equal(own.sum(0)[own.sum(0) > 0],
                                  np.ones(int((own.sum(0) > 0).sum())))


def test_cylinder_geometry_unchanged():
    """The 1-body path must produce byte-identical arrays to the pre-PR
    builder (chi via maximum.reduce over one body == that body's chi)."""
    geom = build_geometry(GRID)
    assert geom.name == "cylinder" and geom.n_bodies == 1
    np.testing.assert_array_equal(geom.rot_u, geom.rotb_u[0])
    assert np.asarray(geom.jmask_u).max() > 0     # jets exist on the cylinder


def test_pinball_has_no_jets():
    geom = build_geometry(GRID, "pinball")
    assert float(np.asarray(geom.jmask_u).max()) == 0.0
    assert float(np.asarray(geom.jmask_v).max()) == 0.0


# ---------------------------------------------------------------------------
# scenario registry
# ---------------------------------------------------------------------------

def test_pinball_scenarios_registered():
    s = S.get_scenario("pinball_re100")
    assert s.geometry == "pinball" and s.actuation == "rotary"
    assert s.n_bodies == 3 and s.act_dim == 3
    assert s.obs_dim == 59
    assert S.get_scenario("pinball_re130").re == 130.0
    t = S.get_scenario("tandem_re100")
    assert t.geometry == "tandem" and t.act_dim == 2 and t.obs_dim == 40


def test_jets_require_cylinder():
    with pytest.raises(ValueError, match="jets"):
        S.Scenario(name="x", actuation="jets", geometry="pinball",
                   probes="pinball")
    with pytest.raises(ValueError, match="geometry"):
        S.Scenario(name="x", geometry="hexagon")


def test_batch_params_action_padding():
    p = S.batch_params(["cyl_re100", "pinball_re100"], GRID,
                       cd0s=["nan", "nan"])
    assert p.act_mask.shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(p.act_mask),
                                  [[1, 0, 0], [1, 1, 1]])
    np.testing.assert_array_equal(np.asarray(p.geom_id),
                                  [geometry_index("cylinder"),
                                   geometry_index("pinball")])
    with pytest.raises(ValueError, match="act_dim"):
        S.batch_params(["pinball_re100"], GRID, act_dim=2)


# ---------------------------------------------------------------------------
# solver: per-body (vector) actuation
# ---------------------------------------------------------------------------

def test_vector_action_matches_scalar_on_cylinder():
    """A length-1 action vector through the per-body branch must reproduce
    the scalar rotary path to summation-order accuracy."""
    cfg = GRID
    geom = build_geometry(cfg)
    ga = solver.geom_to_arrays(geom)
    st = solver.init_state(cfg, geom)
    m = jnp.float32(1.0)
    st_s, out_s = jax.jit(lambda s: solver.step(
        cfg, ga, s, jnp.float32(0.7), act_mode=m))(st)
    st_v, out_v = jax.jit(lambda s: solver.step(
        cfg, ga, s, jnp.array([0.7], jnp.float32), act_mode=m))(st)
    np.testing.assert_allclose(np.asarray(st_s.u), np.asarray(st_v.u),
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(float(out_s.cd), float(np.sum(out_v.cd)),
                               rtol=1e-5)


def test_per_body_actuation_is_independent():
    """Spinning different pinball cylinders produces different flows."""
    cfg = GRID
    geom = build_geometry(cfg, "pinball")
    ga = solver.geom_to_arrays(geom)
    st = solver.init_state(cfg, geom)
    m = jnp.float32(1.0)
    step = jax.jit(lambda s, a: solver.step(cfg, ga, s, a, act_mode=m))
    a_front = jnp.array([1.0, 0.0, 0.0], jnp.float32)
    a_back = jnp.array([0.0, 1.0, 0.0], jnp.float32)
    st_f, out_f = step(st, a_front)
    st_b, out_b = step(st, a_back)
    assert out_f.cd.shape == (3,)            # per-body forces
    assert float(jnp.abs(st_f.u - st_b.u).max()) > 1e-6
    assert not np.allclose(np.asarray(out_f.cd), np.asarray(out_b.cd))


def test_fused_backend_falls_back_for_vector_actions():
    cfg = GRID
    geom = build_geometry(cfg, "pinball")
    ga = solver.geom_to_arrays(geom)
    st = solver.init_state(cfg, geom)
    with pytest.warns(RuntimeWarning, match="per-body"):
        solver.step_interval(cfg, ga, st, jnp.array([1.0, 0.0, 0.0],
                                                    jnp.float32),
                             n_steps=2, act_mode=jnp.float32(1.0),
                             backend="fused")


# ---------------------------------------------------------------------------
# env: pinball-native and mixed-geometry batches
# ---------------------------------------------------------------------------

def test_pinball_env_native(pinball_env):
    st0, obs0 = pinball_env.reset()
    assert obs0.shape == (59,)
    assert st0.jet_vel.shape == (3,)
    st, out = jax.jit(pinball_env.env_step)(
        st0, jnp.array([0.5, -0.5, 0.0], jnp.float32))
    assert np.isfinite(float(out.reward))
    assert np.isfinite(float(out.cd)) and float(out.cd) > 0


def test_mixed_geometry_batch_runs_one_program(env):
    """Cylinder + pinball envs reset and step as ONE vmapped program."""
    st_b, obs_b = env.reset_batch(["cyl_re100", "pinball_re100"], 2)
    assert st_b.jet_vel.shape == (2, 3)       # padded to the widest act_dim
    assert obs_b.shape == (2, 149)            # padded to the widest layout
    vstep = jax.jit(jax.vmap(env.env_step))
    acts = jnp.array([[0.4, 99.0, -99.0],     # garbage in masked slots
                      [0.4, 0.2, -0.2]], jnp.float32)
    st_b, out = vstep(st_b, acts)
    assert np.isfinite(np.asarray(out.reward)).all()
    # the cylinder env's masked action slots must be inert
    st_b2, _ = env.reset_batch(["cyl_re100", "pinball_re100"], 2)
    acts2 = jnp.array([[0.4, 0.0, 0.0], [0.4, 0.2, -0.2]], jnp.float32)
    _, out2 = vstep(st_b2, acts2)
    np.testing.assert_array_equal(np.asarray(out.cd[0]),
                                  np.asarray(out2.cd[0]))


def test_mixed_batch_matches_standalone_pinball(env, pinball_env):
    """The pinball env inside a mixed batch must integrate the same physics
    as the standalone pinball env: same warmup, same steps, same rewards to
    summation-order accuracy (the mixed path gathers its geometry from the
    stacked bank and sums per-body forces, so bitwise equality is NOT the
    contract — allclose is)."""
    st_s, obs_s = pinball_env.reset()
    st_m, obs_m = env.reset_batch(["cyl_re100", "pinball_re100"], 2)

    np.testing.assert_allclose(np.asarray(obs_m[1, :59]), np.asarray(obs_s),
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(float(st_m.scn.cd0[1]), float(st_s.scn.cd0),
                               rtol=1e-5)

    vstep = jax.jit(jax.vmap(env.env_step))
    sstep = jax.jit(pinball_env.env_step)
    act = jnp.array([0.6, -0.3, 0.1], jnp.float32)
    acts_b = jnp.stack([jnp.array([0.2, 0.0, 0.0], jnp.float32), act])
    for _ in range(3):
        st_s, out_s = sstep(st_s, act)
        st_m, out_m = vstep(st_m, acts_b)
        np.testing.assert_allclose(float(out_m.cd[1]), float(out_s.cd),
                                   rtol=1e-4)
        np.testing.assert_allclose(float(out_m.reward[1]),
                                   float(out_s.reward), rtol=1e-4,
                                   atol=1e-4)


def test_homogeneous_cylinder_batch_keeps_scalar_actions(env):
    """A cylinder-only batch must keep the historical scalar jet_vel (the
    bitwise-stability contract: no vector program unless a multi-body
    scenario is present)."""
    st_b, _ = env.reset_batch(["cyl_re100", "cyl_re200"], 2)
    assert st_b.jet_vel.shape == (2,)
    assert st_b.scn.geom_id is not None       # ids ride along regardless


def test_obs_aux_exposes_probe_layout(env):
    st_b, obs_b = env.reset_batch(["cyl_re100", "pinball_re100"], 2)
    aux = env.obs_aux(st_b)
    assert aux["xy"].shape == (2, 149, 2)
    assert aux["mask"].shape == (2, 149)
    np.testing.assert_array_equal(np.asarray(aux["mask"].sum(1)),
                                  [149.0, 59.0])
    assert float(jnp.abs(aux["xy"]).max()) <= 1.0
