"""Async (stale-gradient) PPO prototype: learning + modeled systems gain."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import CostModel, ParallelPlan
from repro.core.scaling_model import calibrate_to_paper
from repro.drl import networks
from repro.drl.async_train import async_speedup, train_async
from repro.drl.ppo import PPOConfig


class _Out:
    def __init__(self, obs, reward):
        self.obs, self.reward = obs, reward
        self.cd = jnp.float32(0)
        self.cl = jnp.float32(0)


def _toy_step(st, a):
    new = st * 0.8 + jnp.array([0.5, 0.0, 0.0]) * a
    return new, _Out(new, -jnp.sum(new[:1] ** 2))


def test_async_ppo_still_learns():
    N, T = 8, 24
    st0 = jnp.ones((N, 3)) * 2.0
    params, returns = train_async(
        _toy_step, networks.PolicyConfig(obs_dim=3, act_dim=1),
        PPOConfig(lr=1e-3, epochs=4, minibatches=4),
        st0, st0, n_envs=N, horizon=T, episodes=25)
    assert np.mean(returns[-5:]) > np.mean(returns[:5]) + 0.1, \
        (np.mean(returns[:5]), np.mean(returns[-5:]))


def test_async_speedup_modeled():
    m = calibrate_to_paper()
    res = async_speedup(m, ParallelPlan(60, 60, 1), io_bytes=1.2e6)
    # the update is a small share of an episode, so the gain is modest but
    # strictly positive and grows when episodes shrink
    assert 1.0 < res["speedup"] < 1.5, res


# ---------------------------------------------------------------------------
# cost-model edge cases feeding async_speedup
# ---------------------------------------------------------------------------

def test_async_speedup_io_bytes_none_uses_model_default():
    """io_bytes=None must fall back to the model's calibrated baseline
    volume, i.e. match passing it explicitly."""
    m = calibrate_to_paper()
    p = ParallelPlan(60, 60, 1)
    res_none = async_speedup(m, p, io_bytes=None)
    res_expl = async_speedup(m, p, io_bytes=m.io_bytes_per_actuation)
    for k in res_none:
        assert res_none[k] == res_expl[k], (k, res_none, res_expl)
    assert res_none["speedup"] > 1.0
    assert res_none["t_async_h"] < res_none["t_sync_h"]


def test_async_speedup_nondividing_envs_rounds_up():
    """n_envs that doesn't divide n_episodes: the last round still runs a
    full episode wall-time, so t_async uses ceil(n_episodes / n_envs)."""
    m = calibrate_to_paper()
    p = ParallelPlan(7, 7, 1)
    res = async_speedup(m, p, n_episodes=100, io_bytes=0.0)   # 15 rounds
    t_collect = m.t_episode(p, io_bytes=0.0) - m.t_update
    expected = (15 * max(t_collect, m.t_update) + m.t_update) / 3600
    assert abs(res["t_async_h"] - expected) < 1e-12
    # one extra (partial) round vs the exact-divisor episode count
    res_98 = async_speedup(m, p, n_episodes=98, io_bytes=0.0)  # 14 rounds
    assert res["t_async_h"] > res_98["t_async_h"]


def test_t_episode_io_bytes_none_matches_default_volume():
    m = CostModel()
    p = ParallelPlan(4, 4, 1)
    assert m.t_episode(p, io_bytes=None) == \
        m.t_episode(p, io_bytes=m.io_bytes_per_actuation)
    # and zero I/O is strictly cheaper
    assert m.t_episode(p, io_bytes=0.0) < m.t_episode(p, io_bytes=None)


def test_t_training_ceils_rounds_when_envs_dont_divide():
    m = CostModel()
    p = ParallelPlan(7, 7, 1)
    t_ep = m.t_episode(p)
    assert m.t_training(p, 10) == 2 * t_ep     # ceil(10/7)  = 2
    assert m.t_training(p, 14) == 2 * t_ep     # exact
    assert m.t_training(p, 15) == 3 * t_ep     # ceil(15/7)  = 3
    assert m.t_training(p, 1) == t_ep
