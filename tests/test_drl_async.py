"""Async (stale-gradient) PPO prototype: learning + modeled systems gain."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import ParallelPlan
from repro.core.scaling_model import calibrate_to_paper
from repro.drl import networks
from repro.drl.async_train import async_speedup, train_async
from repro.drl.ppo import PPOConfig


class _Out:
    def __init__(self, obs, reward):
        self.obs, self.reward = obs, reward
        self.cd = jnp.float32(0)
        self.cl = jnp.float32(0)


def _toy_step(st, a):
    new = st * 0.8 + jnp.array([0.5, 0.0, 0.0]) * a
    return new, _Out(new, -jnp.sum(new[:1] ** 2))


def test_async_ppo_still_learns():
    N, T = 8, 24
    st0 = jnp.ones((N, 3)) * 2.0
    params, returns = train_async(
        _toy_step, networks.PolicyConfig(obs_dim=3, act_dim=1),
        PPOConfig(lr=1e-3, epochs=4, minibatches=4),
        st0, st0, n_envs=N, horizon=T, episodes=25)
    assert np.mean(returns[-5:]) > np.mean(returns[:5]) + 0.1, \
        (np.mean(returns[:5]), np.mean(returns[-5:]))


def test_async_speedup_modeled():
    m = calibrate_to_paper()
    res = async_speedup(m, ParallelPlan(60, 60, 1), io_bytes=1.2e6)
    # the update is a small share of an episode, so the gain is modest but
    # strictly positive and grows when episodes shrink
    assert 1.0 < res["speedup"] < 1.5, res
