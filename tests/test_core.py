"""Core (paper-contribution) tests: planner properties, scaling-model fit,
I/O interface round trips.  Includes hypothesis property tests (via the
_prop shim, which degrades to a deterministic sampler without hypothesis)."""
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.interface import ExchangeRecord, FileInterface
from repro.core.plan import CostModel, ParallelPlan, enumerate_plans, \
    optimize_plan
from repro.core.scaling_model import (PAPER_TABLE2, calibrate_to_paper,
                                      fig7_rows, table1_rows, table2_rows)


# ---------------------------------------------------------------------------
# planner properties
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(n_total=st.integers(min_value=1, max_value=128))
def test_optimal_plan_is_brute_force_minimum(n_total):
    m = CostModel()
    best = optimize_plan(n_total, m)
    for p in enumerate_plans(n_total):
        assert m.t_training(best, 300) <= m.t_training(p, 300) + 1e-9


@settings(max_examples=30, deadline=None)
@given(n=st.integers(min_value=1, max_value=64))
def test_cfd_efficiency_decreasing(n):
    m = CostModel()
    assert m.cfd_efficiency(n) <= 1.0 + 1e-9
    if n > 1:
        assert m.cfd_efficiency(n) <= m.cfd_efficiency(n - 1) + 1e-9


@settings(max_examples=30, deadline=None)
@given(n_envs=st.integers(min_value=1, max_value=60),
       io=st.floats(min_value=0, max_value=2e7))
def test_more_io_never_faster(n_envs, io):
    m = CostModel()
    p = ParallelPlan(n_envs, n_envs, 1)
    assert m.t_episode(p, io_bytes=io) >= m.t_episode(p, io_bytes=0.0) - 1e-9


def test_plan_utilization_and_validation():
    assert ParallelPlan(6, 6, 1).utilization == 1.0
    assert ParallelPlan(6, 1, 4).utilization == pytest.approx(4 / 6)
    with pytest.raises(ValueError, match="over-subscribed"):
        ParallelPlan(4, 4, 2)
    with pytest.raises(ValueError, match=">= 1"):
        ParallelPlan(4, 0, 1)


def test_enumerate_plans_orders_full_utilization_first():
    plans = enumerate_plans(6)
    utils = [p.utilization for p in plans]
    assert utils == sorted(utils, reverse=True)
    assert plans[0].utilization == 1.0
    # the partial plans are still enumerated (n_ranks = 4 -> 1 env idle 2)
    assert any(p.utilization < 1.0 for p in plans)


def test_optimize_plan_prefers_full_utilization_on_ties():
    """A degenerate zero-cost model makes every split cost 0.0: the
    tie-break must pick a no-idle-workers plan (and the paper's default
    n_ranks = 1 among those)."""
    free = CostModel(t_step_1=0.0, t_update=0.0, t_policy=0.0,
                     io_bytes_per_actuation=0.0, mgmt_log_s=0.0)
    for n_total in (4, 6, 12, 30):
        best = optimize_plan(n_total, free)
        assert free.t_training(best, 300) == 0.0
        assert best.utilization == 1.0, (n_total, best)
        assert best.n_ranks == 1


def test_paper_finding_nranks1_optimal():
    """The paper's central claim: at 60 workers the optimum is 60 x 1."""
    m = calibrate_to_paper()
    best = optimize_plan(60, m)
    assert best.n_ranks == 1 and best.n_envs == 60


def test_calibration_fits_paper_tables():
    m = calibrate_to_paper()
    errs = []
    for r in table2_rows(m):
        pb, pd, po = r["paper"]
        errs += [abs(r["t_baseline_h"] - pb) / pb,
                 abs(r["t_disabled_h"] - pd) / pd,
                 abs(r["t_optimized_h"] - po) / po]
    assert np.mean(errs) < 0.10, np.mean(errs)   # <10% mean error on Table II
    assert np.max(errs) < 0.25


def test_fig7_shape_matches_paper():
    m = calibrate_to_paper()
    rows = {r["n_ranks"]: r["efficiency"] for r in fig7_rows(m)}
    assert rows[2] > 0.75                 # paper: ~90%
    assert rows[16] < 0.30                # paper: <20%


def test_io_optimization_recovers_efficiency():
    """Paper: optimized I/O lifts 60-core efficiency from ~49% to ~78%."""
    m = calibrate_to_paper()
    p = ParallelPlan(60, 60, 1)
    base = m.efficiency(p)
    opt = m.efficiency(p, io_bytes=1.2e6)
    assert opt > base * 1.2
    assert 0.3 < base < 0.7
    assert opt > 0.55


# ---------------------------------------------------------------------------
# I/O interface
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["file_baseline", "optimized",
                                  "optimized_zstd"])
def test_interface_roundtrip(tmp_path, mode):
    fi = FileInterface(mode, str(tmp_path), 0, flowfield_floats=1000)
    obs = np.random.RandomState(0).randn(149)
    rec = ExchangeRecord(obs=obs, forces=np.random.randn(10, 2), action=0.25)
    fi.inject_action(0.25)
    nb = fi.write_actuation(3, rec)
    assert nb > 0
    back = fi.read_actuation(3)
    np.testing.assert_allclose(np.asarray(back.obs, np.float64).ravel(),
                               obs, rtol=1e-4, atol=1e-5)
    assert abs(fi.read_action() - 0.25) < 1e-9
    fi.cleanup()


def test_interface_sizes_match_paper():
    """Baseline ~5 MB / actuation, optimized ~1.2 MB (-76%), paper §III.D."""
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        base = FileInterface("file_baseline", td + "/b", 0)
        opt = FileInterface("optimized", td + "/o", 0)
        rec = ExchangeRecord(obs=np.zeros(149), forces=np.zeros((10, 2)),
                             action=0.0)
        nb = base.write_actuation(0, rec)
        no = opt.write_actuation(0, rec)
        assert 4.0e6 < nb < 6.5e6, nb
        assert 1.0e6 < no < 1.5e6, no
        assert no < 0.35 * nb            # >= 65% reduction
        base.cleanup(); opt.cleanup()


def test_interface_action_regex_injection(tmp_path):
    fi = FileInterface("file_baseline", str(tmp_path), 0,
                       flowfield_floats=10)
    for a in (0.0, -1.25, 0.37281):
        fi.inject_action(a)
        assert abs(fi.read_action() - a) < 1e-7
    text = (fi.dir / "jetVelocity").read_text()
    assert "jet2" in text  # antisymmetric jet written too
    fi.cleanup()
