"""Launch-layer tests: step builders, shardings, roofline math (1 device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, get_config, list_configs
from repro.launch import roofline, steps
from repro.launch.mesh import make_abstract_mesh, make_debug_mesh
from repro.models import model as M
from repro.models.sharding import param_specs


def test_all_configs_registered():
    assert len(list_configs()) == 10


def test_input_shapes_pool():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288
    assert INPUT_SHAPES["long_500k"].global_batch == 1
    assert INPUT_SHAPES["decode_32k"].kind == "decode"


def test_param_specs_cover_big_dims():
    """Every >=1M-element parameter of every arch must be sharded on the
    production mesh shape (16,16) — nothing big may stay replicated."""
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    for arch in list_configs():
        cfg = get_config(arch)
        shapes = steps.abstract_params(cfg)
        specs = param_specs(mesh, shapes)
        flat_sh = jax.tree_util.tree_flatten_with_path(shapes)[0]
        flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        for (kp, leaf), spec in zip(flat_sh, flat_sp):
            n = int(np.prod(leaf.shape))
            if n >= 4_000_000:
                assert any(a is not None for a in spec), \
                    (arch, kp, leaf.shape, spec)


def test_opt_state_specs_mirror_params():
    mesh = make_debug_mesh(1, 1)
    cfg = get_config("phi4-mini-3.8b")
    pshape = steps.abstract_params(cfg)
    oshape = steps.abstract_opt_state(cfg, pshape)
    ospecs = steps.opt_state_specs(mesh, pshape, oshape)
    # structure must match the state tree exactly
    jax.tree.map(lambda s, sp: None, oshape, ospecs,
                 is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))


def test_vocab_padding():
    assert get_config("seamless-m4t-large-v2").vocab_padded % 256 == 0
    assert get_config("hymba-1.5b").vocab_padded == 32256
    assert get_config("llama3-405b").vocab_padded == 128256  # already /256


def test_model_flops_sane():
    cfg = get_config("phi4-mini-3.8b")
    pshape = steps.abstract_params(cfg)
    n = roofline.param_count(cfg, pshape)
    assert 3.0e9 < n < 6.0e9, n
    fl = roofline.model_flops(cfg, INPUT_SHAPES["train_4k"], pshape)
    assert abs(fl - 6 * n * 256 * 4096) / fl < 1e-6


def test_model_flops_moe_active():
    cfg = get_config("deepseek-v3-671b")
    pshape = steps.abstract_params(cfg)
    n_total = roofline.param_count(cfg, pshape)
    n_active = roofline.active_param_count(cfg, pshape)
    assert 6.3e11 < n_total < 7.2e11, n_total      # ~671B
    assert 3.0e10 < n_active < 5.0e10, n_active     # ~37B active


def test_roofline_terms():
    hw = roofline.HARDWARE_PRESETS["tpu_v5e"]
    rl = roofline.Roofline(
        arch="x", shape="train_4k", mesh="m", n_devices=256,
        flops_per_dev=hw.peak_flops, bytes_per_dev=hw.hbm_bw,
        coll_bytes_per_dev=hw.ici_bw,
        model_flops=hw.peak_flops * 256, coll_by_kind={}, hw=hw)
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 1.0) < 1e-9
    assert abs(rl.collective_s - 1.0) < 1e-9
    assert abs(rl.useful_ratio - 1.0) < 1e-9


def test_hardware_spec_presets_and_resolution(monkeypatch):
    # explicit preset name and passthrough of a spec object
    assert roofline.hardware_spec("tpu_v5e").peak_flops == 197e12
    custom = roofline.HardwareSpec("lab_gpu", 1e12, 1e11, 1e10)
    assert roofline.hardware_spec(custom) is custom
    # environment override beats platform detection
    monkeypatch.setenv(roofline.HW_SPEC_ENV, "tpu_v5e")
    assert roofline.hardware_spec().name == "tpu_v5e"
    monkeypatch.delenv(roofline.HW_SPEC_ENV)
    # this suite pins JAX_PLATFORMS=cpu -> detection lands on cpu_generic
    assert roofline.hardware_spec().name == "cpu_generic"


def test_hardware_spec_unknown_is_actionable(monkeypatch):
    with pytest.raises(ValueError, match="cpu_generic.*tpu_v5e"):
        roofline.hardware_spec("tpu_v9000")
    # a bad env override fails the same way instead of silently defaulting
    monkeypatch.setenv(roofline.HW_SPEC_ENV, "nonsense")
    with pytest.raises(ValueError, match="unknown hardware spec"):
        roofline.hardware_spec()


def test_roofline_prices_against_its_spec():
    cpu = roofline.HARDWARE_PRESETS["cpu_generic"]
    rl = roofline.build("x", "s", "m", 1,
                        {"flops": cpu.peak_flops, "bytes": cpu.hbm_bw / 2,
                         "coll_bytes": 0.0}, cpu.peak_flops, hw="cpu_generic")
    assert rl.hw.name == "cpu_generic"
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 0.5) < 1e-9
    assert rl.dominant == "compute"
    assert rl.to_dict()["hw"]["name"] == "cpu_generic"


def test_train_step_on_debug_mesh():
    """make_train_step with a real (1,1) mesh: runs and decreases loss."""
    mesh = make_debug_mesh(1, 1)
    cfg = get_config("qwen2-vl-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = steps.make_opt(cfg)
    opt_state = opt.init(params)
    ts = jax.jit(steps.make_train_step(cfg, mesh))
    from repro.models import frontend as fe_mod
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1),
             "frontend_embeds": jnp.zeros(
                 (B, fe_mod.num_frontend_tokens(cfg, S),
                  fe_mod.frontend_dim(cfg)))}
    step = jnp.int32(0)
    losses = []
    for _ in range(3):
        params, opt_state, step, metrics = ts(params, opt_state, step, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_microbatch_clamp():
    """Microbatches clamp so B/mb divides the dp axes (multi-pod bug fix)."""
    import dataclasses
    mesh = make_debug_mesh(1, 1)
    cfg = dataclasses.replace(get_config("phi4-mini-3.8b").reduced(),
                              train_microbatches=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = steps.make_opt(cfg)
    ts = jax.jit(steps.make_train_step(cfg, mesh))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                cfg.vocab_size)   # B=4 < 8 microbatches
    batch = {"tokens": tokens, "labels": tokens}
    params, _, _, metrics = ts(params, opt.init(params), jnp.int32(0), batch)
    assert not bool(jnp.isnan(metrics["loss"]))


def test_cache_specs_structure():
    mesh = make_debug_mesh(1, 1)
    for arch in ("llama3-405b", "deepseek-v3-671b", "rwkv6-3b",
                 "hymba-1.5b", "seamless-m4t-large-v2"):
        cfg = get_config(arch)
        specs, shapes = steps.cache_specs(cfg, mesh, 8, 1024)
        jax.tree.map(lambda s, sp: None, shapes, specs,
                     is_leaf=lambda x: isinstance(
                         x, (jax.ShapeDtypeStruct, P)))


def test_fp8_cache_dtype():
    cfg = get_config("llama3-405b")
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 2, 64))
    assert cache["k"].dtype == jnp.float8_e4m3fn
    cfg2 = get_config("phi4-mini-3.8b")
    cache2 = jax.eval_shape(lambda: M.init_cache(cfg2, 2, 64))
    assert cache2["k"].dtype == jnp.bfloat16
