"""Scenario registry + mixed-scenario batching through the RolloutEngine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import scenarios as S
from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import GridConfig
from repro.drl import networks
from repro.drl.engine import EngineConfig, RolloutEngine, broadcast_env_state

GRID = GridConfig(res=6, dt=0.012, poisson_iters=25)


@pytest.fixture(scope="module")
def env():
    return CylinderEnv(EnvConfig(grid=GRID, steps_per_action=4,
                                 actions_per_episode=3, warmup_time=2.0))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_builtins():
    names = S.list_scenarios()
    assert {"cyl_re100", "cyl_re200", "cyl_re500",
            "cyl_re100_rotary", "cyl_re100_sparse8"} <= set(names)
    s = S.get_scenario("cyl_re200_sparse24")
    assert s.re == 200.0 and s.probes == "sparse24" and s.obs_dim == 24


def test_registry_errors():
    with pytest.raises(KeyError, match="unknown scenario"):
        S.get_scenario("nope")
    with pytest.raises(ValueError, match="already registered"):
        S.register_scenario(S.Scenario(name="cyl_re100"))
    with pytest.raises(ValueError, match="unknown actuation"):
        S.Scenario(name="x", actuation="telekinesis")
    with pytest.raises(KeyError, match="unknown probe layout"):
        S.Scenario(name="x", probes="nope")


def test_register_custom_scenario():
    scn = S.Scenario(name="test_re300", re=300.0, probes="sparse8",
                     description="test-only")
    S.register_scenario(scn)
    try:
        assert S.get_scenario("test_re300").obs_dim == 8
        S.register_scenario(S.Scenario(name="test_re300", re=350.0),
                            overwrite=True)
        assert S.get_scenario("test_re300").re == 350.0
    finally:
        del S._REGISTRY["test_re300"]


def test_obs_dim_derived_from_layout():
    assert EnvConfig(probe_layout="ring149").obs_dim == 149
    assert EnvConfig(probe_layout="sparse24").obs_dim == 24
    assert EnvConfig(probe_layout="sparse8").obs_dim == 8


def test_env_config_for_scenario():
    cfg = EnvConfig.for_scenario("cyl_re200_sparse24", grid=GRID,
                                 warmup_time=1.0)
    assert cfg.grid.re == 200.0
    assert cfg.probe_layout == "sparse24" and cfg.obs_dim == 24
    assert cfg.warmup_time == 1.0


def test_batch_params_padding():
    params = S.batch_params(["cyl_re100_sparse8", "cyl_re100"], GRID,
                            cd0s=["nan", "nan"])
    assert params.probe_ij.shape == (2, 149, 2)
    np.testing.assert_array_equal(np.asarray(params.probe_mask).sum(1),
                                  [8.0, 149.0])
    # the explicit cd0="nan" escape hatch: an intentionally uncalibrated
    # baseline stays NaN (so rewards against it fail loudly, not as cd0=0)
    assert np.isnan(np.asarray(params.cd0)).all()
    with pytest.raises(ValueError, match="obs_dim"):
        S.batch_params(["cyl_re100"], GRID, obs_dim=10)


def test_missing_cd0_raises_actionable_error():
    # no cd0 pinned on the scenario and no caller override: an actionable
    # error naming the scenario, instead of the old silent-NaN footgun
    with pytest.raises(ValueError, match="cyl_re100.*no cd0"):
        S.batch_params(["cyl_re100_sparse8", "cyl_re100"], GRID)
    with pytest.raises(ValueError, match='cd0 must be a float'):
        S.scenario_params(S.get_scenario("cyl_re100"), GRID, cd0="whoops")


# ---------------------------------------------------------------------------
# mixed-scenario physics through the engine (ISSUE 2 acceptance test)
# ---------------------------------------------------------------------------

def test_mixed_batch_collect_matches_single_path(env):
    """3 distinct scenarios (3 Re's, 2 probe layouts) through ONE vmapped
    RolloutEngine.collect: batch shape/dtype identical to the homogeneous
    single-scenario path, per-env physics genuinely different."""
    mix = ("cyl_re100", "cyl_re200_sparse24", "cyl_re500")
    n_envs, T = 3, 3
    engine = RolloutEngine.for_env(env, EngineConfig(n_envs=n_envs,
                                                     horizon=T))
    params = networks.init_actor_critic(
        networks.PolicyConfig(obs_dim=149), jax.random.PRNGKey(0))

    st0, obs0 = env.reset()
    st_h, obs_h = broadcast_env_state(st0, obs0, n_envs)
    batch_h, traj_h = engine.collect(params, st_h, obs_h,
                                     jax.random.PRNGKey(1))

    st_m, obs_m = env.reset_batch(mix, n_envs, obs_dim=149)
    batch_m, traj_m = engine.collect(params, st_m, obs_m,
                                     jax.random.PRNGKey(1))

    # same program contract: identical shapes and dtypes everywhere
    for a, b in zip(jax.tree.leaves(batch_h), jax.tree.leaves(batch_m)):
        assert a.shape == b.shape and a.dtype == b.dtype
    for a, b in zip(jax.tree.leaves(traj_h), jax.tree.leaves(traj_m)):
        assert a.shape == b.shape and a.dtype == b.dtype
    assert batch_m.obs.shape == (n_envs * T, 149)
    assert not bool(jnp.any(jnp.isnan(batch_m.adv)))

    # sparse24 env: padded probe slots observe exactly zero
    assert bool(jnp.all(traj_m.obs[1, :, 24:] == 0.0))
    assert bool(jnp.any(traj_m.obs[1, :, :24] != 0.0))


def test_mixed_batch_per_env_physics_differ(env):
    """Same action sequence, different Re -> distinct C_D trajectories."""
    mix = ("cyl_re100", "cyl_re200", "cyl_re500")
    st_b, _ = env.reset_batch(mix)
    vstep = jax.jit(jax.vmap(env.env_step))
    cds = []
    acts = jnp.zeros(3, jnp.float32)
    for _ in range(3):
        st_b, out = vstep(st_b, acts)
        cds.append(np.asarray(out.cd))
    cds = np.stack(cds)                      # (T, 3)
    assert np.isfinite(cds).all()
    for i in range(3):
        for j in range(i + 1, 3):
            assert np.abs(cds[:, i] - cds[:, j]).max() > 1e-3, (i, j, cds)


def test_same_scenario_same_physics(env):
    """Two envs assigned the same scenario integrate identically."""
    st_b, _ = env.reset_batch(["cyl_re100"], n_envs=2)
    vstep = jax.jit(jax.vmap(env.env_step))
    st_b, out = vstep(st_b, jnp.zeros(2, jnp.float32))
    np.testing.assert_allclose(np.asarray(out.cd[0]), np.asarray(out.cd[1]),
                               rtol=0, atol=0)


def test_rotary_actuation_differs_from_jets(env):
    """Rotary control produces different lift response than jets at the
    same commanded amplitude (Magnus effect vs. jet blowing)."""
    st_b, _ = env.reset_batch(["cyl_re100", "cyl_re100_rotary"])
    vstep = jax.jit(jax.vmap(env.env_step))
    cls = []
    for _ in range(4):
        st_b, out = vstep(st_b, jnp.ones(2, jnp.float32))
        cls.append(np.asarray(out.cl))
    cls = np.stack(cls)
    assert np.isfinite(cls).all()
    assert np.abs(cls[:, 0] - cls[:, 1]).max() > 0.05, cls


def test_per_scenario_cd0_calibration(env):
    """Warmup calibrates a distinct C_D0 per (Re, actuation) group."""
    st_b, _ = env.reset_batch(["cyl_re100", "cyl_re200", "cyl_re100",
                               "cyl_re100_rotary"])
    cd0 = np.asarray(st_b.scn.cd0)
    assert cd0[0] != cd0[1]          # Re matters
    assert cd0[0] == cd0[2]          # same group -> same calibration
    assert cd0[0] != cd0[3]          # actuation operator matters too
    assert (cd0 > 0.5).all(), cd0


def test_zero_action_reward_unbiased(env):
    """Each env starts at its OWN operator's equilibrium: a zero-action
    first step must give a near-zero reward for jets AND rotary scenarios
    (pre-fix, rotary warmed up under the jets operator and opened with a
    spurious drag transient, reward ~ -2.8)."""
    st_b, _ = env.reset_batch(["cyl_re100", "cyl_re100_rotary"])
    vstep = jax.jit(jax.vmap(env.env_step))
    st_b, out = vstep(st_b, jnp.zeros(2, jnp.float32))
    assert np.abs(np.asarray(out.reward)).max() < 0.5, out.reward


def test_single_env_rotary_warmup_unbiased():
    """The single-env path (EnvConfig.for_scenario -> reset) must also warm
    up under its own actuation operator (pre-fix: jets warmup gave the
    rotary env a zero-action first reward of ~ -5.4)."""
    cfg = EnvConfig.for_scenario("cyl_re100_rotary", grid=GRID,
                                 steps_per_action=4, warmup_time=2.0)
    env2 = CylinderEnv(cfg)
    st0, _ = env2.reset()
    assert float(st0.scn.act_mode) == 1.0
    _, out = jax.jit(env2.env_step)(st0, jnp.float32(0.0))
    assert abs(float(out.reward)) < 0.5, float(out.reward)


def test_assign_envs_rejects_dropped_scenarios():
    with pytest.raises(ValueError, match="n_envs=1 < 2"):
        S.assign_envs(["cyl_re100", "cyl_re200"], 1)


def test_round_robin_assignment():
    scns = S.assign_envs(["cyl_re100", "cyl_re200"], 5)
    assert [s.name for s in scns] == ["cyl_re100", "cyl_re200", "cyl_re100",
                                      "cyl_re200", "cyl_re100"]
