"""Permutation-invariant attention policy + probe-mask threading.

Covers the PR-9 bug cluster: policies must consume the probe mask (padded
slots in a mixed batch carry NO information and must not leak garbage into
actions), the attention encoder must be a genuine set function over the
live probe tokens, and the policy architecture must resume strictly
(MLP params cannot silently restore into an attention run).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import GridConfig
from repro.drl import networks
from repro.drl import train_state as ts_mod
from repro.drl.ppo import PPOConfig
from repro.drl.train import TrainConfig, train

GRID = GridConfig(res=3, dt=0.02, poisson_iters=12)


def _aux(key, P, live):
    xy = jax.random.uniform(key, (P, 2), minval=-1.0, maxval=1.0)
    mask = jnp.concatenate([jnp.ones(live), jnp.zeros(P - live)])
    return {"xy": xy, "mask": mask}


def _params(policy, obs_dim=16, act_dim=3):
    cfg = networks.PolicyConfig(obs_dim=obs_dim, act_dim=act_dim,
                                policy=policy, d_model=32, heads=4,
                                kv_heads=2, layers=2)
    return networks.init_actor_critic(cfg, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# masked-slot invariance (the garbage-leak bug)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", networks.POLICIES)
def test_masked_slots_cannot_leak(policy):
    """Filling PADDED observation slots with garbage must not change the
    policy distribution, the value, or sampled actions — for both
    architectures (pre-fix, the MLP read padded slots as real signal)."""
    P, live = 16, 10
    params = _params(policy)
    aux = _aux(jax.random.PRNGKey(1), P, live)
    obs = jax.random.normal(jax.random.PRNGKey(2), (P,))
    obs = obs * aux["mask"]                       # honest padded zeros
    garbage = obs + (1.0 - aux["mask"]) * 1e3     # poison the dead slots

    mu0, std0 = networks.policy_dist(params, obs, aux)
    mu1, std1 = networks.policy_dist(params, garbage, aux)
    np.testing.assert_array_equal(np.asarray(mu0), np.asarray(mu1))
    np.testing.assert_array_equal(np.asarray(std0), np.asarray(std1))
    v0 = networks.value(params, obs, aux)
    v1 = networks.value(params, garbage, aux)
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    a0, lp0 = networks.sample_action(params, obs, jax.random.PRNGKey(3),
                                     aux=aux)
    a1, lp1 = networks.sample_action(params, garbage, jax.random.PRNGKey(3),
                                     aux=aux)
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))
    np.testing.assert_array_equal(np.asarray(lp0), np.asarray(lp1))


def test_mlp_without_aux_is_the_historical_program():
    """aux=None keeps the MLP feature path byte-for-byte: no mask multiply
    enters the trace, so pre-PR params/behavior are untouched."""
    params = _params("mlp")
    obs = jax.random.normal(jax.random.PRNGKey(2), (16,))
    mu_none, _ = networks.policy_dist(params, obs, None)
    live_aux = {"xy": jnp.zeros((16, 2)), "mask": jnp.ones(16)}
    mu_live, _ = networks.policy_dist(params, obs, live_aux)
    # all-live mask multiplies by exactly 1.0 -> IEEE-identical
    np.testing.assert_array_equal(np.asarray(mu_none), np.asarray(mu_live))


# ---------------------------------------------------------------------------
# set-function structure of the attention encoder
# ---------------------------------------------------------------------------

def test_attention_is_permutation_invariant():
    """Shuffling the live probe tokens (coords + values together) must not
    change the policy output: the encoder pools over an unordered set."""
    P, live = 16, 10
    params = _params("attention")
    aux = _aux(jax.random.PRNGKey(1), P, live)
    obs = jax.random.normal(jax.random.PRNGKey(2), (P,)) * aux["mask"]

    perm = np.concatenate([np.random.RandomState(0).permutation(live),
                           np.arange(live, P)])
    aux_p = {"xy": aux["xy"][perm], "mask": aux["mask"][perm]}
    obs_p = obs[perm]

    mu0, _ = networks.policy_dist(params, obs, aux)
    mu1, _ = networks.policy_dist(params, obs_p, aux_p)
    np.testing.assert_allclose(np.asarray(mu0), np.asarray(mu1),
                               rtol=0, atol=1e-5)
    v0 = networks.value(params, obs, aux)
    v1 = networks.value(params, obs_p, aux_p)
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1),
                               rtol=0, atol=1e-5)


def test_attention_handles_batched_leading_dims():
    """(N, T, P) observations with broadcast aux — the engine's
    postprocess shape — evaluate without reshaping at the call site."""
    N, T, P = 2, 3, 12
    params = _params("attention", obs_dim=P)
    aux1 = _aux(jax.random.PRNGKey(1), P, 8)
    obs = jax.random.normal(jax.random.PRNGKey(2), (N, T, P))
    aux = {"xy": jnp.broadcast_to(aux1["xy"], (N, T, P, 2)),
           "mask": jnp.broadcast_to(aux1["mask"], (N, T, P))}
    v = networks.value(params, obs, aux)
    assert v.shape == (N, T)
    assert np.isfinite(np.asarray(v)).all()
    mu, std = networks.policy_dist(params, obs, aux)
    assert mu.shape == (N, T, 3)
    grads = jax.grad(lambda p: jnp.sum(networks.value(p, obs, aux)))(params)
    assert all(np.isfinite(np.asarray(g)).all()
               for g in jax.tree.leaves(grads))


def test_policy_config_validation():
    with pytest.raises(ValueError, match="policy"):
        networks.init_actor_critic(
            networks.PolicyConfig(obs_dim=8, policy="transformer"),
            jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="d_model"):
        networks.init_actor_critic(
            networks.PolicyConfig(obs_dim=8, policy="attention", d_model=30,
                                  heads=4), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# end-to-end: attention PPO on the pinball + architecture fingerprint
# ---------------------------------------------------------------------------

def _tiny_cfg(**kw):
    return TrainConfig(
        env=EnvConfig(grid=GRID, steps_per_action=4, actions_per_episode=4,
                      warmup_time=0.2),
        ppo=PPOConfig(lr=3e-4, epochs=2, minibatches=2),
        n_envs=2, episodes=2, seed=0, **kw)


def test_attention_ppo_smoke_with_resume(tmp_path):
    """Attention policy trains on the pinball (finite losses/rewards) and
    the full TrainState round-trips through a checkpoint resume."""
    d = str(tmp_path / "ckpt")
    cfg = _tiny_cfg(scenarios=("pinball_re100",), policy="attention",
                    ckpt_dir=d, ckpt_every=1)
    hist, params = train(cfg, log_fn=None)
    assert np.isfinite(np.asarray(hist["reward"])).all()
    assert networks.is_attention(params)

    cfg2 = dataclasses.replace(cfg, episodes=3, resume="auto")
    hist2, params2 = train(cfg2, log_fn=None)
    assert len(hist2["reward"]) == 3
    np.testing.assert_array_equal(np.asarray(hist2["reward"][:2]),
                                  np.asarray(hist["reward"]))


def test_policy_fingerprint_resume_strict():
    meta = {f: 1 for f in ts_mod.RESUME_STRICT_FIELDS}
    meta["policy"] = {"policy": "mlp", "obs_dim": 59, "act_dim": 3}
    cur = dict(meta)
    cur["policy"] = {"policy": "attention", "obs_dim": 59, "act_dim": 3}
    with pytest.raises(Exception, match="policy"):
        ts_mod.check_resume_compatible(meta, cur)


def test_policy_fingerprint_legacy_grace():
    """Checkpoints written before the fingerprint existed resume with a
    note instead of an error (those runs could only have been MLP)."""
    meta = {f: 1 for f in ts_mod.RESUME_STRICT_FIELDS if f != "policy"}
    cur = dict(meta)
    cur["policy"] = {"policy": "mlp"}
    notes = ts_mod.check_resume_compatible(meta, cur)
    assert any("policy fingerprint" in n for n in notes)


def test_obs_dim_mismatch_is_actionable(monkeypatch):
    """When the reset batch and the scenario registry disagree on the padded
    observation width, train() names BOTH values instead of dying with an
    opaque shape error inside jit (the obs-dim bug)."""
    orig = CylinderEnv.reset_batch

    def padded(self, scenarios, n_envs, **kw):
        st, obs = orig(self, scenarios, n_envs, **kw)
        return st, jnp.pad(obs, ((0, 0), (0, 3)))

    monkeypatch.setattr(CylinderEnv, "reset_batch", padded)
    with pytest.raises(ValueError, match=r"common_obs_dim=\d+.*obs_dim=\d+"):
        train(_tiny_cfg(scenarios=("cyl_re100_sparse8",)), log_fn=None)
