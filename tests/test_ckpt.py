"""Checkpointer: property-based pytree roundtrips (structure, dtype, bits),
manifest validation errors, corruption/truncation handling, the on-disk
step/LATEST/retention layout, and the AsyncCheckpointer overlap semantics."""
import dataclasses
import os
import threading
from typing import Any

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from tests._prop import given, settings, st


# ---------------------------------------------------------------------------
# random pytrees: bf16/f32/int32/bool leaves, 0-d and 0-length arrays,
# dict/tuple/list/dataclass nesting
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Block:
    w: Any
    b: Any


_DTYPES = (np.dtype(np.float32), np.dtype(np.int32), np.dtype(np.bool_),
           np.dtype(ml_dtypes.bfloat16))
_SHAPES = ((), (1,), (3,), (0,), (2, 3), (4, 1, 2))


def _rand_leaf(rng: np.random.Generator):
    dt = _DTYPES[rng.integers(len(_DTYPES))]
    shape = _SHAPES[rng.integers(len(_SHAPES))]
    if dt == np.bool_:
        return rng.integers(0, 2, size=shape).astype(np.bool_)
    if np.issubdtype(dt, np.integer):
        return rng.integers(-1000, 1000, size=shape).astype(dt)
    return rng.standard_normal(size=shape).astype(dt)


def _rand_tree(rng: np.random.Generator, depth: int = 0):
    kind = rng.integers(5 if depth < 2 else 1)
    if kind == 1:
        return {f"k{i}": _rand_tree(rng, depth + 1)
                for i in range(rng.integers(1, 4))}
    if kind == 2:
        return tuple(_rand_tree(rng, depth + 1)
                     for _ in range(rng.integers(1, 4)))
    if kind == 3:
        return [_rand_tree(rng, depth + 1)
                for _ in range(rng.integers(1, 4))]
    if kind == 4:
        return Block(w=_rand_leaf(rng), b=_rand_tree(rng, depth + 1))
    return _rand_leaf(rng)


def _assert_same_bits(tree_a, tree_b):
    la, ta = jax.tree_util.tree_flatten(tree_a)
    lb, tb = jax.tree_util.tree_flatten(tree_b)
    assert ta == tb, f"structure changed: {ta} != {tb}"
    for a, b in zip(la, lb):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape, (a.shape, b.shape)
        assert a.dtype == b.dtype, (a.dtype, b.dtype)
        assert a.tobytes() == b.tobytes()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_roundtrip_preserves_structure_dtype_bits(seed):
    rng = np.random.default_rng(seed)
    tree = _rand_tree(rng)
    path = f"/tmp/repro_ckpt_prop_{os.getpid()}.ckpt"
    ck.save(path, tree, step=seed, compress=bool(seed % 2))
    back = ck.restore(path, target=tree)
    _assert_same_bits(tree, back)
    os.unlink(path)


@pytest.mark.parametrize("compress", [False, True])
def test_roundtrip_edge_trees(tmp_path, compress):
    cases = {
        "empty_dict": {},
        "zero_d": {"x": jnp.float32(2.5), "y": np.int32(-7)},
        "zero_len": {"x": np.zeros((0, 4), np.float32)},
        "nested": {"a": (Block(w=np.ones((2,), ml_dtypes.bfloat16),
                               b=[np.bool_(True), jnp.zeros(())]),),
                   "b": {"c": np.arange(3, dtype=np.int32)}},
    }
    for name, tree in cases.items():
        p = str(tmp_path / f"{name}.ckpt")
        ck.save(p, tree, compress=compress)
        _assert_same_bits(tree, ck.restore(p, target=tree))


def test_restore_without_target_returns_arrays_and_manifest(tmp_path):
    tree = {"a": np.float32([1, 2]), "b": {"c": np.int32(3)}}
    p = str(tmp_path / "x.ckpt")
    ck.save(p, tree, step=7, metadata={"note": "hi"})
    arrays, manifest = ck.restore(p)
    assert set(arrays) == {"a", "b/c"}
    assert manifest["step"] == 7 and manifest["metadata"]["note"] == "hi"
    assert manifest["arrays"]["a"]["dtype"] == "float32"
    assert manifest["arrays"]["b/c"]["shape"] == []


def test_zstd_missing_fallback(tmp_path, monkeypatch):
    """compress=True must silently degrade to raw when zstandard is absent,
    and the manifest must record it so restore never guesses."""
    monkeypatch.setattr(ck, "zstd", None)
    tree = {"a": np.arange(10, dtype=np.float32)}
    p = str(tmp_path / "nozstd.ckpt")
    ck.save(p, tree, compress=True)
    arrays, manifest = ck.restore(p)
    assert manifest["compressed"] is False
    np.testing.assert_array_equal(arrays["a"], tree["a"])


@pytest.mark.skipif(ck.zstd is None, reason="zstandard not installed")
def test_compressed_checkpoint_without_zstd_errors(tmp_path, monkeypatch):
    tree = {"a": np.zeros((64,), np.float32)}
    p = str(tmp_path / "z.ckpt")
    ck.save(p, tree, compress=True)
    assert ck.read_manifest(p)["compressed"] is True
    monkeypatch.setattr(ck, "zstd", None)
    with pytest.raises(ck.CheckpointError, match="zstandard"):
        ck.restore(p)


# ---------------------------------------------------------------------------
# validation: dtype/shape/structure mismatches, truncation, corruption
# ---------------------------------------------------------------------------

def _one(tmp_path, tree=None):
    tree = tree if tree is not None else {
        "w": np.float32([[1, 2], [3, 4]]), "n": np.int32(5)}
    p = str(tmp_path / "one.ckpt")
    ck.save(p, tree)
    return p, tree


def test_restore_dtype_mismatch_names_leaf(tmp_path):
    p, tree = _one(tmp_path)
    bad = dict(tree, n=np.float32(0))
    with pytest.raises(ck.CheckpointError) as ei:
        ck.restore(p, target=bad)
    msg = str(ei.value)
    assert "'n'" in msg and "int32" in msg and "float32" in msg
    # explicit opt-in converts instead
    out = ck.restore(p, target=bad, cast=True)
    assert np.asarray(out["n"]).dtype == np.float32
    assert float(np.asarray(out["n"])) == 5.0


def test_restore_shape_and_structure_mismatch(tmp_path):
    p, tree = _one(tmp_path)
    with pytest.raises(ck.CheckpointError, match="'w'"):
        ck.restore(p, target=dict(tree, w=np.zeros((3, 2), np.float32)))
    with pytest.raises(ck.CheckpointError, match="missing"):
        ck.restore(p, target=dict(tree, extra=np.zeros(1, np.float32)))
    with pytest.raises(ck.CheckpointError, match="extra"):
        ck.restore(p, target={"w": tree["w"]})


def test_truncated_file_raises_clean_error(tmp_path):
    p, tree = _one(tmp_path)
    blob = open(p, "rb").read()
    for frac in (0.2, 0.6, 0.95):
        bad = str(tmp_path / f"trunc_{frac}.ckpt")
        open(bad, "wb").write(blob[:int(len(blob) * frac)])
        with pytest.raises(ck.CheckpointError,
                           match="truncated|corrupted|manifest"):
            ck.restore(bad)
        with pytest.raises(ck.CheckpointError):
            ck.validate(bad)


def test_corrupted_payload_fails_crc_not_garbage(tmp_path):
    p, tree = _one(tmp_path)
    blob = bytearray(open(p, "rb").read())
    blob[-2] ^= 0x5A                     # flip bits inside the last leaf
    bad = str(tmp_path / "bitflip.ckpt")
    open(bad, "wb").write(bytes(blob))
    with pytest.raises(ck.CheckpointError, match="crc32"):
        ck.restore(bad)
    ck.validate(bad)                     # shallow: lengths still consistent
    with pytest.raises(ck.CheckpointError, match="crc32"):
        ck.validate(bad, deep=True)


def test_wrong_magic(tmp_path):
    bad = str(tmp_path / "not.ckpt")
    open(bad, "wb").write(b"definitely not a checkpoint")
    with pytest.raises(ck.CheckpointError, match="not a repro checkpoint"):
        ck.restore(bad)


def test_save_is_atomic_no_tmp_left(tmp_path):
    p, _ = _one(tmp_path)
    assert not list(tmp_path.glob("*.tmp"))
    assert os.path.exists(p)


def test_restore_onto_shardings(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_debug_mesh
    p, tree = _one(tmp_path)
    mesh = make_debug_mesh(1, 1)
    sh = NamedSharding(mesh, P())
    out = ck.restore(p, target=tree, shardings=sh)   # one sharding for all
    _assert_same_bits(tree, out)
    assert out["w"].sharding == sh


# ---------------------------------------------------------------------------
# directory layout: step files, LATEST pointer, retention
# ---------------------------------------------------------------------------

def test_save_step_latest_and_retention(tmp_path):
    d = str(tmp_path / "run")
    for step in (2, 4, 6, 8):
        ck.save_step(d, step, {"x": np.int32(step)}, keep=2)
    names = sorted(os.path.basename(f) for f in os.listdir(d)
                   if f.endswith(".ckpt"))
    assert names == ["step_00000006.ckpt", "step_00000008.ckpt"]
    latest = ck.latest_checkpoint(d)
    assert latest.endswith("step_00000008.ckpt")
    arrays, manifest = ck.restore(latest)
    assert int(arrays["x"]) == 8 and manifest["step"] == 8
    assert ck.latest_step(d) == latest          # back-compat alias


def test_latest_checkpoint_skips_corrupt_newest(tmp_path):
    d = str(tmp_path / "run")
    ck.save_step(d, 1, {"x": np.int32(1)})
    good = ck.save_step(d, 2, {"x": np.int32(2)})
    # newest gets truncated (e.g. external damage); pointer still names it
    blob = open(good, "rb").read()
    open(good, "wb").write(blob[:len(blob) // 2])
    latest = ck.latest_checkpoint(d)
    assert latest is not None and latest.endswith("step_00000001.ckpt")
    assert int(ck.restore(latest)[0]["x"]) == 1


def test_latest_checkpoint_skips_bitflipped_newest(tmp_path):
    """Deep (crc) validation in latest_checkpoint: damage that preserves
    segment lengths must still be skipped, not returned then crashed on."""
    d = str(tmp_path / "run")
    ck.save_step(d, 1, {"x": np.int32(1)})
    good = ck.save_step(d, 2, {"x": np.int32(2)})
    blob = bytearray(open(good, "rb").read())
    blob[-2] ^= 0x5A
    open(good, "wb").write(bytes(blob))
    latest = ck.latest_checkpoint(d)
    assert latest is not None and latest.endswith("step_00000001.ckpt")


def test_latest_checkpoint_prefers_newer_step_over_stale_pointer(tmp_path):
    """Crash window between writing step N and repointing LATEST: the newer
    complete step file must win over the stale pointer target."""
    d = str(tmp_path / "run")
    ck.save_step(d, 1, {"x": np.int32(1)})
    ck.save_step(d, 2, {"x": np.int32(2)})
    (tmp_path / "run" / ck.LATEST_NAME).write_text("step_00000001.ckpt\n")
    latest = ck.latest_checkpoint(d)
    assert latest.endswith("step_00000002.ckpt")


def test_latest_checkpoint_empty_and_missing_dir(tmp_path):
    assert ck.latest_checkpoint(str(tmp_path / "nope")) is None
    (tmp_path / "empty").mkdir()
    assert ck.latest_checkpoint(str(tmp_path / "empty")) is None


# ---------------------------------------------------------------------------
# AsyncCheckpointer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("background", [False, True])
def test_async_checkpointer_saves_land(tmp_path, background):
    d = str(tmp_path / "acp")
    with ck.AsyncCheckpointer(d, keep=2, background=background) as acp:
        for step in (1, 2, 3):
            acp.save(step, {"p": jnp.full((4,), float(step)),
                            "s": jnp.int32(step)},
                     metadata={"episode": step})
        acp.wait()
    assert acp.saves == 3 and acp.bytes_written > 0
    latest = ck.latest_checkpoint(d)
    arrays, manifest = ck.restore(latest)
    assert manifest["metadata"]["episode"] == 3
    np.testing.assert_array_equal(arrays["p"], np.full((4,), 3.0))
    ckpts = [f for f in os.listdir(d) if f.endswith(".ckpt")]
    assert len(ckpts) == 2                      # retention applied


def test_async_checkpointer_snapshot_isolated_from_training(tmp_path):
    """save() snapshots device arrays to host before returning, so the
    training loop may immediately rebind/donate its state without racing
    the background write."""
    d = str(tmp_path / "snap")
    acp = ck.AsyncCheckpointer(d)
    y = jnp.arange(8, dtype=jnp.float32)
    acp.save(1, {"x": y})
    y = y + 100.0                      # training moves on mid-write
    acp.close()
    arrays, _ = ck.restore(ck.latest_checkpoint(d))
    np.testing.assert_array_equal(arrays["x"],
                                  np.arange(8, dtype=np.float32))


def test_async_checkpointer_error_surfaces_on_next_call(tmp_path):
    d = str(tmp_path / "err")
    acp = ck.AsyncCheckpointer(d, background=True)
    acp.save(1, {"x": np.zeros(2)}, metadata={"bad": object()})  # unpackable
    with pytest.raises(Exception):
        acp.save(2, {"x": np.zeros(2)})
    acp.close()


def test_async_checkpointer_overlaps_writer_thread(tmp_path):
    """The write really happens off-thread: save() returns while a slow
    (event-gated) serialization is still in flight."""
    d = str(tmp_path / "olap")
    acp = ck.AsyncCheckpointer(d, background=True)
    gate = threading.Event()
    inner = ck.save

    def slow_save(*a, **kw):
        gate.wait(timeout=30)
        return inner(*a, **kw)

    orig = ck.save
    ck.save = slow_save
    try:
        acp.save(1, {"x": np.zeros(4)})
        assert acp._inflight is not None and not acp._inflight.done()
        gate.set()
        acp.wait()
    finally:
        ck.save = orig
        acp.close()
    assert ck.latest_checkpoint(d) is not None
