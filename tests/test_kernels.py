"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import poisson as cfd_poisson
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.poisson import ops as poisson_ops
from repro.kernels.poisson.kernel import rb_sor_slabs, rb_sor_slabs_packed
from repro.kernels.poisson.ref import rb_sor_slabs_packed_ref, \
    rb_sor_slabs_ref
from repro.kernels.rwkv6 import ops as rwkv_ops
from repro.kernels.rwkv6.kernel import wkv6_bhsn
from repro.kernels.rwkv6.ref import wkv6_ref


# ---------------------------------------------------------------------------
# poisson
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ny,nx,nslabs", [(16, 64, 2), (48, 256, 4),
                                          (32, 128, 1), (40, 160, 5),
                                          # non-square (wide ny) + odd ny
                                          (33, 64, 2), (17, 48, 3),
                                          (64, 32, 2), (7, 16, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_poisson_kernel_matches_ref(ny, nx, nslabs, dtype):
    key = jax.random.PRNGKey(ny * nx)
    rhs = jax.random.normal(key, (ny, nx), dtype)
    p0 = jax.random.normal(jax.random.fold_in(key, 1), (ny, nx), dtype)
    a = rb_sor_slabs(p0, rhs, dx=0.05, dy=0.04, omega=1.6, nslabs=nslabs,
                     inner_iters=3)
    b = rb_sor_slabs_ref(p0, rhs, dx=0.05, dy=0.04, omega=1.6, nslabs=nslabs,
                         inner_iters=3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("ny,nx,nslabs,inner", [(16, 64, 2, 3),
                                                (48, 256, 4, 4),
                                                (40, 160, 5, 2),
                                                # odd ny + single slab
                                                (33, 64, 2, 1), (7, 16, 1, 2)])
def test_poisson_packed_kernel_matches_refs(ny, nx, nslabs, inner):
    """The packed-plane slab kernel matches both the plane-level oracle and
    the unpacked full-grid slab kernel (same block-Jacobi schedule)."""
    key = jax.random.PRNGKey(ny * nx)
    rhs = jax.random.normal(key, (ny, nx))
    p0 = jax.random.normal(jax.random.fold_in(key, 1), (ny, nx))
    planes = cfd_poisson.pack_checkerboard(p0)
    rplanes = cfd_poisson.pack_checkerboard(rhs)
    kw = dict(dx=0.05, dy=0.04, omega=1.6, nslabs=nslabs, inner_iters=inner)
    out_r, out_b = rb_sor_slabs_packed(*planes, *rplanes, **kw)
    ref_r, ref_b = rb_sor_slabs_packed_ref(*planes, *rplanes, **kw)
    np.testing.assert_allclose(np.asarray(out_r), np.asarray(ref_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(ref_b),
                               rtol=1e-5, atol=1e-5)
    full = rb_sor_slabs(p0, rhs, **kw)
    unpacked = cfd_poisson.unpack_checkerboard(out_r, out_b)
    np.testing.assert_allclose(np.asarray(unpacked), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_poisson_rb_sor_packed_matches_unpacked():
    """ops.rb_sor's packed default reproduces the original full-grid slab
    path at identical iteration schedules."""
    rhs = jax.random.normal(jax.random.PRNGKey(9), (34, 176))
    kw = dict(iters=40, omega=1.7, nslabs=2, inner_iters=2, interpret=True)
    a = poisson_ops.rb_sor(rhs, 0.125, 0.12, **kw)
    b = poisson_ops.rb_sor(rhs, 0.125, 0.12, packed=False, **kw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_poisson_kernel_solver_converges():
    rhs = jax.random.normal(jax.random.PRNGKey(0), (48, 256))
    sol = poisson_ops.rb_sor(rhs, 0.05, 0.05, iters=800, inner_iters=4,
                             interpret=True)
    r = cfd_poisson.residual(sol, rhs, 0.05, 0.05)
    r0 = cfd_poisson.residual(jnp.zeros_like(rhs), rhs, 0.05, 0.05)
    assert float(jnp.linalg.norm(r)) < 0.05 * float(jnp.linalg.norm(r0))


@pytest.mark.parametrize("ny,nx", [(24, 64), (33, 48)])
def test_poisson_kernel_batch_dim_parity(ny, nx):
    """vmapping the slab smoother over a batch axis matches per-item calls
    (the engine's N_envs axis runs the kernel exactly like this)."""
    B = 3
    ks = jax.random.split(jax.random.PRNGKey(ny), 2)
    p0 = jax.random.normal(ks[0], (B, ny, nx))
    rhs = jax.random.normal(ks[1], (B, ny, nx))
    kern = lambda p, r: rb_sor_slabs(p, r, dx=0.05, dy=0.04, omega=1.6,
                                     nslabs=2, inner_iters=3)
    batched = jax.vmap(kern)(p0, rhs)
    for b in range(B):
        np.testing.assert_allclose(np.asarray(batched[b]),
                                   np.asarray(kern(p0[b], rhs[b])),
                                   rtol=1e-5, atol=1e-5)


def test_poisson_pallas_exact_vs_global_sweeps():
    """With nslabs=1 and inner_iters=1 the halo columns are refreshed every
    red+black pair and the Neumann/Dirichlet ghosts are invariant under the
    opposite-color half-sweep, so the Pallas path is EXACTLY the globally
    coupled SOR iteration of cfd.poisson.solve (polish disabled)."""
    rhs = jax.random.normal(jax.random.PRNGKey(3), (34, 176))
    a = poisson_ops.rb_sor(rhs, 0.125, 0.12, iters=24, omega=1.7,
                           nslabs=1, inner_iters=1, interpret=True)
    b = cfd_poisson.solve(rhs, 0.125, 0.12, iters=24, omega=1.7, polish=0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("ny,nx", [(34, 176), (40, 130)])
def test_poisson_pallas_full_solve_with_polish(ny, nx):
    """solve(use_pallas=True) — Pallas SOR + the PR-1 Gauss-Seidel polish
    sweeps — converges like the jnp path on equal iteration budget, and the
    polish improves the residual exactly as it does on the jnp path."""
    rhs = jax.random.normal(jax.random.PRNGKey(ny * nx), (ny, nx))
    r0 = float(jnp.linalg.norm(cfd_poisson.residual(
        jnp.zeros_like(rhs), rhs, 0.1, 0.1)))

    def rnorm(**kw):
        sol = cfd_poisson.solve(rhs, 0.1, 0.1, iters=120, **kw)
        return float(jnp.linalg.norm(cfd_poisson.residual(sol, rhs, 0.1,
                                                          0.1)))

    r_jnp = rnorm(use_pallas=False)
    r_pal = rnorm(use_pallas=True)
    r_pal_nopolish = rnorm(use_pallas=True, polish=0)
    assert r_pal < 0.1 * r0, (r_pal, r0)
    assert r_pal < 3.0 * r_jnp, (r_pal, r_jnp)       # same convergence class
    assert r_pal < 0.7 * r_pal_nopolish              # polish helps here too


def test_poisson_odd_width_gating():
    """Odd nx: ops.rb_sor refuses loudly, cfd.poisson.solve silently falls
    back to the jnp path and still converges."""
    rhs = jax.random.normal(jax.random.PRNGKey(5), (24, 33))
    with pytest.raises(ValueError, match="even grid width"):
        poisson_ops.rb_sor(rhs, 0.1, 0.1, iters=8, interpret=True)
    sol = cfd_poisson.solve(rhs, 0.1, 0.1, iters=200, use_pallas=True)
    r = float(jnp.linalg.norm(cfd_poisson.residual(sol, rhs, 0.1, 0.1)))
    r0 = float(jnp.linalg.norm(cfd_poisson.residual(jnp.zeros_like(rhs),
                                                    rhs, 0.1, 0.1)))
    assert r < 0.1 * r0


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("BH,S,dh", [(2, 128, 64), (4, 256, 64),
                                     (1, 256, 128), (2, 512, 32)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(BH, S, dh, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + dh), 3)
    q = jax.random.normal(ks[0], (BH, S, dh), dtype)
    k = jax.random.normal(ks[1], (BH, S, dh), dtype)
    v = jax.random.normal(ks[2], (BH, S, dh), dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, sliding_window=window,
                               block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal, sliding_window=window)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 5)


def test_flash_attention_gqa_wrapper():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    out = flash_ops.flash_attention(q, k, v, interpret=True)
    from repro.models.attention import causal_mask, gqa_attend
    ref = gqa_attend(q, k, v, causal_mask(128, 128))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("BH,S,N,chunk", [(2, 64, 16, 16), (4, 128, 32, 32),
                                          (1, 96, 64, 16), (3, 256, 32, 64)])
def test_wkv6_kernel_sweep(BH, S, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(S * N), 6)
    r = jax.random.normal(ks[0], (BH, S, N)) * 0.5
    k = jax.random.normal(ks[1], (BH, S, N)) * 0.5
    v = jax.random.normal(ks[2], (BH, S, N)) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (BH, S, N)) - 2.0))
    u = jax.random.normal(ks[4], (BH, 1, N)) * 0.3
    s0 = jax.random.normal(ks[5], (BH, N, N)) * 0.1
    out, s_fin = wkv6_bhsn(r, k, v, w, u, s0, chunk=chunk)
    ref_out, ref_s = wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(ref_s),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_ops_layout():
    from repro.models.ssm import wkv6_scan
    B, S, H, N = 2, 64, 3, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    r = jax.random.normal(ks[0], (B, S, H, N)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, N)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, N)) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) - 2.0))
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    st = jnp.zeros((B, H, N, N))
    o1, s1 = rwkv_ops.wkv6(r, k, v, w, u, st, interpret=True)
    o2, s2 = wkv6_scan(r, k, v, w, u, st)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_chunked_jnp_matches_scan():
    from repro.models.ssm import wkv6_chunked, wkv6_scan
    B, S, H, N = 2, 256, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    r = jax.random.normal(ks[0], (B, S, H, N)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, N)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, N)) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) - 2.0))
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, N, N)) * 0.1
    o1, s1 = wkv6_chunked(r, k, v, w, u, s0)
    o2, s2 = wkv6_scan(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)
