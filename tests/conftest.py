import os
import sys
from pathlib import Path

# src layout without an editable install: bare ``python -m pytest`` must
# still find the ``repro`` package, with or without PYTHONPATH=src.
_SRC = str(Path(__file__).resolve().parent.parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Tests must see 1 CPU device (the 512-device override is dryrun-only).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def _reset_warning_caches():
    """Warn-once caches are process-global; without this reset, any test
    asserting a once-per-shape warning depends on execution order."""
    from repro.core import backend as backend_mod
    from repro.testing import faults
    backend_mod.reset_warning_caches()
    faults.reset()
    yield
    faults.reset()      # a test that armed faults must not leak them
