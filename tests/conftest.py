import os

# Tests must see 1 CPU device (the 512-device override is dryrun-only).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
