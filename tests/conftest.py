import os

# Tests must see 1 CPU device (the 512-device override is dryrun-only).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def _reset_warning_caches():
    """Warn-once caches are process-global; without this reset, any test
    asserting a once-per-shape warning depends on execution order."""
    from repro.core import backend as backend_mod
    backend_mod.reset_warning_caches()
    yield
