"""core.autotune: plan resolution, measured refit, artifact schema — plus
the decomposition validation errors the halo backend relies on."""
import json

import pytest

from repro.cfd.decomp import validate_decomposition
from repro.cfd.grid import GridConfig
from repro.core.autotune import (AUTOTUNE_SCHEMA, ResolvedPlan, autotune,
                                 default_backend, refit_cost_model,
                                 resolve_plan, validate_artifact)
from repro.core.plan import CostModel, ParallelPlan, optimize_plan
from repro.launch.mesh import make_abstract_mesh


# ---------------------------------------------------------------------------
# plan resolution
# ---------------------------------------------------------------------------

def test_resolve_explicit_parallel_plan():
    rp = resolve_plan(ParallelPlan(4, 2, 2))
    assert isinstance(rp, ResolvedPlan)
    assert rp.source == "explicit"
    assert rp.backend == "halo"               # n_ranks > 1 => decomposed
    assert rp.mesh_shape == (2, 2)
    assert rp.n_envs == 2 and rp.n_ranks == 2


def test_resolve_single_rank_plan_has_undecomposed_backend():
    rp = resolve_plan(ParallelPlan(4, 4, 1))
    assert rp.backend in ("reference", "pallas")
    assert rp.n_ranks == 1


def test_resolve_tuple_convenience():
    rp = resolve_plan((3, 2))
    assert rp.plan == ParallelPlan(6, 3, 2)


def test_resolve_passthrough_and_errors():
    rp = resolve_plan(ParallelPlan(2, 2, 1))
    assert resolve_plan(rp) is rp
    with pytest.raises(ValueError, match="unknown plan spec"):
        resolve_plan("fastest")
    with pytest.raises(ValueError, match="cannot resolve plan"):
        resolve_plan(3.14)


def test_default_backend_ranks():
    assert default_backend(2, 88) == "halo"
    assert default_backend(1, 88) in ("reference", "pallas")


# ---------------------------------------------------------------------------
# refit: synthetic measurements from a known model are recovered
# ---------------------------------------------------------------------------

def _synthetic_measured(truth: CostModel, ranks=(1, 2, 4)):
    horizon, n_envs = 32, 4
    vol = truth.io_bytes_per_actuation * n_envs * horizon
    return {
        "n_total": max(ranks),
        "n_devices": max(ranks),
        "t_step_ranks": {r: truth.t_step(r) for r in ranks},
        "t_policy": truth.t_policy,
        "t_update": truth.t_update,
        "io": {"bytes_per_episode_env": vol / n_envs,
               "bytes_per_actuation": truth.io_bytes_per_actuation,
               "stream_bandwidth": truth.io_stream_bandwidth,
               "write_seconds": vol / truth.io_stream_bandwidth},
        "t_interhost": {"processes": 1, "bytes": vol,
                        "seconds": truth.interhost_latency
                        + vol / truth.interhost_bandwidth,
                        "bandwidth": truth.interhost_bandwidth,
                        "estimated": True},
    }


def test_refit_recovers_step_scaling():
    truth = CostModel()
    fit = refit_cost_model(_synthetic_measured(truth, ranks=(1, 2, 4, 8)))
    for r in (1, 2, 4, 8, 16):
        assert fit.t_step(r) == pytest.approx(truth.t_step(r), rel=0.05), r
    assert fit.t_update == pytest.approx(truth.t_update)
    assert fit.t_policy == pytest.approx(truth.t_policy)
    assert fit.io_bytes_per_actuation == pytest.approx(
        truth.io_bytes_per_actuation)


def test_refit_two_point_fallback():
    truth = CostModel()
    fit = refit_cost_model(_synthetic_measured(truth, ranks=(1, 2)))
    assert fit.t_step(1) == pytest.approx(truth.t_step(1), rel=1e-6)
    assert fit.t_step(2) == pytest.approx(truth.t_step(2), rel=0.05)


def test_refit_preserves_paper_optimum():
    """Acceptance: optimize_plan on the refit model still lands on the
    paper's 'n_ranks = 1 until I/O saturates' optimum."""
    truth = CostModel()
    fit = refit_cost_model(_synthetic_measured(truth))
    best = optimize_plan(60, fit)
    assert best.n_ranks == 1 and best.n_envs == 60


def test_refit_single_rank_only():
    truth = CostModel()
    measured = _synthetic_measured(truth, ranks=(1,))
    fit = refit_cost_model(measured)
    assert fit.t_step_1 == pytest.approx(truth.t_step_1)
    # unmeasurable scaling constants fall back to the base model's
    assert fit.serial_frac == truth.serial_frac


def test_refit_interhost_bandwidth():
    """A REAL cross-process gather timing refits the inter-host bandwidth;
    the flagged single-process estimate leaves the default untouched."""
    truth = CostModel()
    m = _synthetic_measured(truth)
    assert refit_cost_model(m).interhost_bandwidth \
        == truth.interhost_bandwidth          # estimate: default kept
    m["t_interhost"] = {"processes": 2, "bytes": 1e8, "seconds": 0.05,
                        "bandwidth": 2.0e9, "estimated": False}
    assert refit_cost_model(m).interhost_bandwidth == pytest.approx(2.0e9)


# ---------------------------------------------------------------------------
# fleet (multi-host) plans in the cost model and optimizer
# ---------------------------------------------------------------------------

def test_fleet_plan_validation():
    p = ParallelPlan(8, 4, 2, n_processes=2)      # 4 workers/host, whole envs
    assert p.n_processes == 2
    with pytest.raises(ValueError, match="must divide n_total"):
        ParallelPlan(8, 8, 1, n_processes=3)
    with pytest.raises(ValueError, match="whole envs"):
        ParallelPlan(8, 2, 4, n_processes=4)      # 2 workers/host < 1 env


def test_interhost_term_and_host_count_optimum():
    m = CostModel()
    single = ParallelPlan(8, 8, 1)
    fleet = ParallelPlan(8, 8, 1, n_processes=2)
    assert m.t_interhost(single) == 0.0
    assert m.t_interhost(fleet) > 0.0
    assert m.t_episode(fleet) > m.t_episode(single)
    # same budget on more hosts is pure comm cost -> the optimizer keeps
    # every worker on one host when one host can hold them
    best = optimize_plan(8, m, max_processes=4)
    assert best.n_processes == 1
    assert best.n_ranks == 1                      # the paper's optimum


def test_enumerate_plans_fleet_layouts():
    from repro.core.plan import enumerate_plans
    plans = enumerate_plans(8, max_processes=4)
    procs = {(p.n_ranks, p.n_processes) for p in plans}
    assert (1, 4) in procs and (2, 2) in procs
    assert (8, 2) not in procs       # 4 workers/host cannot hold an 8-rank env
    assert all(p.n_processes == 1 for p in enumerate_plans(8))  # default


# ---------------------------------------------------------------------------
# the measured autotune on this (1-device) host + artifact schema
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tuned(tmp_path_factory):
    out = tmp_path_factory.mktemp("autotune") / "artifact.json"
    rp = autotune(grid=GridConfig(res=4, dt=0.01, poisson_iters=20),
                  smoke=True, seed=0, artifact=str(out))
    return rp, json.loads(out.read_text())


def test_autotune_resolves_executable_plan(tuned):
    rp, rec = tuned
    assert rp.source == "auto"
    assert rp.plan.n_envs * rp.plan.n_ranks <= rec["plan"]["n_total"]
    assert rp.plan.utilization == 1.0
    mesh = rp.build_mesh()
    assert dict(mesh.shape) == {"data": rp.n_envs, "model": rp.n_ranks}


def test_autotune_artifact_schema(tuned):
    _, rec = tuned
    validate_artifact(rec)
    assert rec["schema"] == AUTOTUNE_SCHEMA
    assert "1" in rec["measured"]["t_step_ranks"] \
        or 1 in rec["measured"]["t_step_ranks"]
    assert all(v > 0 for v in rec["measured"]["t_step_ranks"].values())
    # measured-vs-predicted present for every measured rank
    assert set(rec["predicted"]["t_step_ranks"]) \
        == set(rec["measured"]["t_step_ranks"])
    # only EXECUTABLE candidates compete: every rank divides the grid and
    # fits the host (an unmeasurable rank can't run either)
    nx, n_dev = rec["measured"]["grid"]["nx"], rec["measured"]["n_devices"]
    for c in rec["candidates"]:
        assert nx % c["n_ranks"] == 0 and c["n_ranks"] <= n_dev, c


def test_autotune_artifact_rejects_corruption(tuned):
    _, rec = tuned
    bad = dict(rec)
    bad["schema"] = "repro.autotune/v0"
    with pytest.raises(ValueError, match="bad schema"):
        validate_artifact(bad)
    bad = {k: v for k, v in rec.items() if k != "candidates"}
    with pytest.raises(ValueError, match="candidates"):
        validate_artifact(bad)
    bad = json.loads(json.dumps(rec))
    bad["plan"]["n_envs"] = 10 ** 6
    with pytest.raises(ValueError, match="over-subscribed"):
        validate_artifact(bad)


def test_resolve_auto_goes_through_autotune(tmp_path):
    rp = resolve_plan("auto", grid=GridConfig(res=4, dt=0.01,
                                              poisson_iters=20), smoke=True)
    assert rp.source == "auto"
    assert rp.measurements is not None


# ---------------------------------------------------------------------------
# decomposition validation (ValueError, not assert: survives python -O)
# ---------------------------------------------------------------------------

def test_validate_decomposition_wrong_axis():
    mesh = make_abstract_mesh((2, 2), ("data", "model"))
    with pytest.raises(ValueError, match="no 'spatial' axis"):
        validate_decomposition(mesh, 88, axis="spatial")


def test_validate_decomposition_indivisible_width():
    mesh = make_abstract_mesh((1, 4), ("data", "model"))
    with pytest.raises(ValueError, match="does not split"):
        validate_decomposition(mesh, 89)
    # the error carries the fix
    with pytest.raises(ValueError, match="nx=88 or nx=92"):
        validate_decomposition(mesh, 89)


def test_validate_decomposition_ok():
    mesh = make_abstract_mesh((1, 4), ("data", "model"))
    assert validate_decomposition(mesh, 88) == 4
