"""backend="fused" actuation-interval path: parity, tiers, fallback,
long-horizon stability (repro.kernels.actuation)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import solver
from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import GridConfig, build_geometry
from repro.core import backend as backend_mod
from repro.kernels.actuation import ops

CFG = GridConfig(res=4, dt=0.01, poisson_iters=12)


@pytest.fixture(scope="module")
def developed():
    """A mildly developed flow on the small grid (shared by parity tests)."""
    geom = build_geometry(CFG)
    ga = solver.geom_to_arrays(geom)
    st = solver.init_state(CFG, geom)
    st, _ = jax.jit(lambda s: solver.step_interval(
        CFG, ga, s, jnp.float32(0.0), 30, backend="reference"))(st)
    return ga, st


def _maxabs(a, b):
    return float(jnp.max(jnp.abs(a - b)))


def test_step_interval_reference_is_scan_of_step(developed):
    """The reference arm of step_interval is literally a scan of step():
    bitwise against an explicit lax.scan of step compiled the same way, and
    ulp-close to eagerly chained step() calls (each eager call compiles in
    its own context, so XLA may reassociate within ~1 ulp)."""
    ga, st = developed
    jet = jnp.float32(0.06)
    st_i, outs_i = jax.jit(lambda s: solver.step_interval(
        CFG, ga, s, jet, 5, backend="reference"))(st)

    def manual_scan(s):
        return jax.lax.scan(
            lambda flow, _: solver.step(CFG, ga, flow, jet,
                                        backend="reference"),
            s, None, length=5)
    st_m, outs_m = jax.jit(manual_scan)(st)
    assert _maxabs(st_i.u, st_m.u) == 0.0
    assert _maxabs(st_i.p, st_m.p) == 0.0
    assert _maxabs(outs_i.cd, outs_m.cd) == 0.0

    flow = st
    cds = []
    for _ in range(5):
        flow, o = solver.step(CFG, ga, flow, jet, backend="reference")
        cds.append(o.cd)
    np.testing.assert_allclose(np.asarray(st_i.u), np.asarray(flow.u),
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs_i.cd), np.asarray(cds),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("act_mode", [0.0, 1.0])
def test_fused_matches_reference_bitwise(developed, act_mode):
    """Interval fusion reorders nothing: same ops, same f32 results."""
    ga, st = developed
    jet = jnp.float32(0.08)
    run = lambda b: jax.jit(lambda s: solver.step_interval(
        CFG, ga, s, jet, 8, act_mode=jnp.float32(act_mode), backend=b))(st)
    st_r, out_r = run("reference")
    st_f, out_f = run("fused")
    assert _maxabs(st_f.u, st_r.u) == 0.0
    assert _maxabs(st_f.v, st_r.v) == 0.0
    assert _maxabs(st_f.p, st_r.p) == 0.0
    assert _maxabs(out_f.cd, out_r.cd) == 0.0
    assert _maxabs(out_f.cl, out_r.cl) == 0.0


def test_pallas_tier_matches_jnp_tier(developed):
    """The Pallas megakernel (interpret mode off-TPU) computes the same
    per-dt body as the fused XLA scan tier."""
    ga, st = developed
    jet = jnp.float32(0.05)
    run = lambda tier: ops.fused_interval(
        CFG, tuple(ga), st, jet, 2, re=CFG.re,
        act_mode=jnp.float32(0.0), tier=tier)
    st_j, out_j = run("jnp")
    st_p, out_p = run("pallas")
    np.testing.assert_allclose(np.asarray(st_p.u), np.asarray(st_j.u),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_p.p), np.asarray(st_j.p),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_p.cd), np.asarray(out_j.cd),
                               rtol=1e-6, atol=1e-6)


def test_env_vmapped_mixed_scenarios_parity():
    """Jet + rotary scenarios vmapped into one batch: the fused env path
    must match the reference scan within a couple of f32 ulp (measured
    bitwise on CPU; the tolerance leaves room for fused-multiply-add
    contraction differences on other backends)."""
    cfg = EnvConfig(grid=CFG, steps_per_action=5, warmup_time=0.3)
    scns = ["cyl_re100", "cyl_re200_rotary"]
    acts = jnp.asarray([0.4, -0.3], jnp.float32)
    out = {}
    for b in ("reference", "fused"):
        env = CylinderEnv(cfg, backend=b)
        st_b, _ = env.reset_batch(scns)
        out[b] = jax.jit(jax.vmap(env.env_step))(st_b, acts)
    (st_r, o_r), (st_f, o_f) = out["reference"], out["fused"]
    eps = np.finfo(np.float32).eps
    scale = float(jnp.max(jnp.abs(st_r.flow.u)))
    assert _maxabs(st_f.flow.u, st_r.flow.u) <= 2 * eps * scale
    assert _maxabs(st_f.flow.p, st_r.flow.p) <= 2 * eps * max(
        1.0, float(jnp.max(jnp.abs(st_r.flow.p))))
    assert _maxabs(o_f.cd, o_r.cd) <= 2 * eps * max(
        1.0, float(jnp.max(jnp.abs(o_r.cd))))
    assert _maxabs(o_f.reward, o_r.reward) <= 2 * eps * max(
        1.0, float(jnp.max(jnp.abs(o_r.reward))))


def test_long_horizon_stability_re100():
    """2000 dt at Re 100 (20 t.u., many shedding periods): the fused carry
    must not accumulate drift vs the reference scan, and both must stay
    physical (finite fields, bounded velocity, bounded divergence)."""
    cfg = GridConfig(res=6, dt=0.01, poisson_iters=30)
    geom = build_geometry(cfg)
    ga = solver.geom_to_arrays(geom)
    st0 = solver.init_state(cfg, geom)
    run = jax.jit(lambda s, b: solver.step_interval(
        cfg, ga, s, jnp.float32(0.0), 2000, backend=b),
        static_argnames="b")
    st_r, out_r = run(st0, "reference")
    st_f, out_f = run(st0, "fused")
    for st, outs in ((st_r, out_r), (st_f, out_f)):
        assert np.isfinite(np.asarray(st.u)).all()
        assert np.isfinite(np.asarray(st.p)).all()
        assert float(jnp.max(jnp.abs(st.u))) < 5.0
        assert np.isfinite(np.asarray(outs.cd)).all()
        div = np.asarray(solver.divergence(st.u, st.v, cfg))
        assert np.abs(div[2:-2, 2:-2]).max() < 0.5
    # per-dt bodies are identical f32 programs -> no divergence to amplify
    assert _maxabs(st_f.u, st_r.u) == 0.0
    assert _maxabs(st_f.p, st_r.p) == 0.0
    assert _maxabs(out_f.cd[-1], out_r.cd[-1]) == 0.0


class _OddGrid:
    """select_tier duck type: GridConfig can't express an odd width
    (nx = 22*res), but external grids can."""
    ny, nx = 8, 7


def test_fused_fallback_warns_once_per_shape_and_after_reset():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert ops.select_tier(_OddGrid) == "reference"
        assert len(w) == 1 and "falls back" in str(w[0].message)
        # second hit on the same shape: silent
        assert ops.select_tier(_OddGrid) == "reference"
        assert len(w) == 1
        # the registry reset re-arms the warning (test isolation hook)
        backend_mod.reset_warning_caches()
        assert ops.select_tier(_OddGrid) == "reference"
        assert len(w) == 2


def test_select_tier_even_grid_off_tpu():
    assert ops.select_tier(CFG) == "jnp"


def test_vmem_budget_env_override(monkeypatch):
    monkeypatch.setenv(ops.VMEM_BUDGET_ENV, "12345")
    assert ops.vmem_budget() == 12345
    assert ops.vmem_bytes(CFG) > 0
