"""The "halo" Poisson backend on a forced multi-device CPU host: parity vs
the reference solver and the Pallas kernel, mixed-scenario engine collection,
golden-physics tolerances at n_ranks=2, and the executable-plan train() path.

Subprocess pattern follows tests/test_distributed.py: the parent test run
must see 1 device, so everything needing a real mesh runs in a child with
XLA_FLAGS=--xla_force_host_platform_device_count=4.

NOTE on comparisons: results of the decomposed solve are pulled to host
(np.asarray) before any further math — eager op-by-op computation on a
mesh-sharded array is miscompiled by jax 0.4.x (see cfd/decomp.py).
"""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")
GOLDEN = str(Path(__file__).resolve().parent / "golden" / "cyl_re100_res8.npz")


def _run(code: str, timeout: int = 420) -> str:
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "JAX_PLATFORMS": "cpu",   # never probe TPU/GPU in the child
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
           "HOME": "/tmp"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_halo_rank1_exact_equivalence():
    """n_ranks=1: the decomposed path IS the reference iteration for ANY
    inner_iters — edge ghosts are live, no neighbour halos exist, exactly
    ``iters`` sweep pairs run (the last outer round masks its tail), and
    the omega / polish schedule matches sweep for sweep."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.cfd import poisson
        from repro.launch.mesh import mesh_for_plan
        rhs = jax.random.normal(jax.random.PRNGKey(3), (34, 176))
        mesh = mesh_for_plan((1, 1))
        for iters, polish, inner in ((24, 6, 1), (60, 10, 1), (7, 0, 1),
                                     (50, 10, 4), (24, 6, 3)):
            a = np.asarray(poisson.solve(rhs, 0.125, 0.12, iters=iters,
                                         polish=polish))
            b = np.asarray(poisson.solve(rhs, 0.125, 0.12, iters=iters,
                                         polish=polish, backend="halo",
                                         mesh=mesh, halo_inner=inner))
            np.testing.assert_array_equal(a, b)
        print("EXACT_OK")
    """)
    assert "EXACT_OK" in out


def test_halo_multirank_parity_vs_reference_and_pallas():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.cfd import decomp, poisson
        from repro.kernels.poisson import ops as poisson_ops
        from repro.launch.mesh import mesh_for_plan
        rhs = jax.random.normal(jax.random.PRNGKey(3), (34, 176))
        res0 = float(np.linalg.norm(np.asarray(
            poisson.residual(jnp.zeros_like(rhs), rhs, 0.125, 0.12))))
        ref = np.asarray(poisson.solve(rhs, 0.125, 0.12, iters=400))
        scale = np.abs(ref).max()
        for r in (2, 4):
            # packed halo_inner=1 exchanges the updated parity before every
            # half-sweep, so the decomposed iteration IS the monolithic
            # red-black sweep — ulp-level agreement at ANY rank count
            mesh = mesh_for_plan((1, r))
            h = np.asarray(poisson.solve(rhs, 0.125, 0.12, iters=400,
                                         backend="halo", mesh=mesh,
                                         halo_inner=1))
            res = float(np.linalg.norm(np.asarray(poisson.residual(
                jnp.asarray(h), rhs, 0.125, 0.12))))
            assert res < 0.05 * res0, (r, res / res0)
            rel = np.abs(h - ref).max() / scale
            assert rel < 1e-5, (r, rel)      # calibrated: ~4e-7 (1 ulp)
        # the legacy full-grid path keeps the old block-Jacobi semantics of
        # the Pallas slab smoother: 2 slabs, refresh every pair, no polish
        # -> near-identical iterates
        pal = np.asarray(poisson_ops.rb_sor(rhs, 0.125, 0.12, iters=200,
                                            omega=1.7, nslabs=2,
                                            inner_iters=1, interpret=True,
                                            packed=False))
        h2 = np.asarray(decomp.decomposed_solve(
            rhs, mesh=mesh_for_plan((1, 2)), dx=0.125, dy=0.12, iters=200,
            polish=0, inner_iters=1, packed=False))
        rel = np.abs(h2 - pal).max() / np.abs(pal).max()
        assert rel < 1e-4, rel               # calibrated: 2.6e-5
        # and the packed slab kernel agrees with the unpacked one
        pal_p = np.asarray(poisson_ops.rb_sor(rhs, 0.125, 0.12, iters=200,
                                              omega=1.7, nslabs=2,
                                              inner_iters=1, interpret=True))
        rel = np.abs(pal_p - pal).max() / np.abs(pal).max()
        assert rel < 1e-4, rel               # calibrated: 7.2e-7
        print("PARITY_OK")
    """)
    assert "PARITY_OK" in out


def test_halo_packed_exchange_bytes_halved():
    """Acceptance criterion: the packed halo backend's per-exchange message
    is half-width — every ppermute operand in the traced program ships
    ceil(ny/2) scalars, where the legacy full-grid path ships ny — and the
    loose-coupling (inner_iters > 1) rounds keep the full-column volume in
    ONE message pair per round."""
    out = _run("""
        import jax, numpy as np
        from repro.cfd import decomp
        from repro.launch.mesh import mesh_for_plan
        rhs = jax.random.normal(jax.random.PRNGKey(0), (34, 176))
        mesh = mesh_for_plan((1, 4))

        def shapes(**kw):
            return set(decomp.ppermute_message_shapes(
                lambda r: decomp.decomposed_solve(
                    r, mesh=mesh, dx=0.125, dy=0.12, iters=60, **kw), rhs))

        packed = shapes(inner_iters=1)
        legacy = shapes(inner_iters=1, packed=False)
        jacobi = shapes(inner_iters=4)
        assert packed == {(17, 1)}, packed       # ny//2: bytes halved
        assert legacy == {(34, 1)}, legacy       # ny: the old full column
        assert jacobi == {(34, 1)}, jacobi       # both parities, one message
        assert decomp.halo_exchange_values(34) * 2 \\
            == decomp.halo_exchange_values(34, packed=False)
        print("BYTES_OK")
    """)
    assert "BYTES_OK" in out


def test_halo_engine_mixed_scenario_batch():
    """A heterogeneous scenario batch stepped through the engine's compute
    core (vmap of env_step over the batch, halo backend, (2, 2) hybrid
    mesh, batch placed by shard_env_batch) matches the reference backend
    within solver tolerance.  Actions are a FIXED shared sequence — a
    stochastic policy would chaos-amplify the tiny solver differences into
    trajectory divergence, which is physics, not a defect."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.cfd.env import CylinderEnv, EnvConfig
        from repro.cfd.grid import GridConfig
        from repro.core.plan import ParallelPlan
        from repro.drl.engine import shard_env_batch
        from repro.launch.mesh import mesh_for_plan

        cfg = EnvConfig(grid=GridConfig(res=6, dt=0.012, poisson_iters=40),
                        steps_per_action=5, warmup_time=2.0)
        scenarios = ("cyl_re100", "cyl_re100_rotary", "cyl_re200",
                     "cyl_re100")
        actions = jnp.array([0.3, -0.2, 0.1])

        def rollout(backend, mesh, n_ranks):
            env = CylinderEnv(cfg, backend=backend, mesh=mesh)
            st_b, obs_b = env.reset_batch(scenarios, 4)
            if mesh is not None:
                st_b = shard_env_batch(mesh, st_b, n_ranks)

            def period(st_b, a):
                st_b, out = jax.vmap(env.env_step, in_axes=(0, None))(st_b,
                                                                      a)
                return st_b, out

            _, outs = jax.jit(lambda s: jax.lax.scan(period, s, actions))(
                st_b)
            return outs

        mesh = mesh_for_plan(ParallelPlan(4, 2, 2))
        o_ref = rollout(None, None, 1)
        o_halo = rollout("halo", mesh, 2)
        for f in ("reward", "cd", "cl", "obs"):
            a = np.asarray(getattr(o_ref, f))
            b = np.asarray(getattr(o_halo, f))
            assert np.isfinite(b).all(), f
            d = np.abs(a - b).max()
            assert d < 0.05, (f, d)
        print("MIXED_OK")
    """)
    assert "MIXED_OK" in out


def test_halo_golden_physics_at_two_ranks():
    """Acceptance criterion: trajectories integrated through the halo
    backend at n_ranks=2 stay inside the golden-physics tolerances
    (same constants as tests/test_golden_physics.py)."""
    out = _run(f"""
        import numpy as np
        from repro.cfd import solver
        from repro.cfd.grid import GridConfig
        from repro.cfd.validation import measure_shedding, run_uncontrolled
        from repro.launch.mesh import mesh_for_plan

        ref = np.load({GOLDEN!r})
        cfg = GridConfig(res=int(ref["res"]), dt=float(ref["dt"]),
                         poisson_iters=int(ref["poisson_iters"]))
        state = solver.FlowState(u=ref["u"], v=ref["v"], p=ref["p"])
        mesh = mesh_for_plan((1, 2))
        _, cds, cls = run_uncontrolled(cfg, state, int(ref["meas_steps"]),
                                       backend="halo", mesh=mesh)
        stats = measure_shedding(cds, cls, cfg.dt)
        TOL_ST, TOL_CD, TOL_AMP = 0.015, 0.01, 0.05   # = golden test gates
        def rel(a, b):
            return abs(a - b) / abs(b)
        errs = dict(st=rel(stats["strouhal"], float(ref["strouhal"])),
                    cd=rel(stats["cd_mean"], float(ref["cd_mean"])),
                    amp=rel(stats["cl_amp"], float(ref["cl_amp"])))
        assert errs["st"] < TOL_ST, errs
        assert errs["cd"] < TOL_CD, errs
        assert errs["amp"] < TOL_AMP, errs
        print("GOLDEN_OK", errs)
    """)
    assert "GOLDEN_OK" in out


def test_train_plan_auto_measures_selects_executes():
    """Acceptance criterion: one train(TrainConfig(plan="auto")) call on a
    forced 4-device host measures, selects and EXECUTES a plan; and
    optimize_plan on the refit model keeps the paper's n_ranks=1 optimum."""
    out = _run("""
        import numpy as np
        from repro.cfd.env import EnvConfig
        from repro.cfd.grid import GridConfig
        from repro.core.autotune import autotune
        from repro.core.plan import optimize_plan
        from repro.drl.ppo import PPOConfig
        from repro.drl.train import TrainConfig, train

        logs = []
        hist, params = train(TrainConfig(
            env=EnvConfig(grid=GridConfig(res=6, dt=0.012,
                                          poisson_iters=40),
                          steps_per_action=4, actions_per_episode=4,
                          warmup_time=1.5),
            ppo=PPOConfig(epochs=2, minibatches=2),
            n_envs=4, episodes=2, plan="auto"), log_fn=logs.append)
        assert any("plan[auto]" in l for l in logs), logs
        assert len(hist["reward"]) == 2
        assert np.isfinite(hist["reward"]).all()
        print("LOG:", [l for l in logs if "plan[auto]" in l][0])

        # the refit cost model keeps the paper's headline optimum
        rp = autotune(grid=GridConfig(res=4, dt=0.01, poisson_iters=20),
                      smoke=True)
        best60 = optimize_plan(60, rp.model)
        assert best60.n_ranks == 1, best60
        assert rp.plan.n_ranks == 1, rp.plan
        print("AUTO_OK")
    """)
    assert "AUTO_OK" in out
    assert "plan[auto]" in out


def test_train_forced_hybrid_plan_runs_halo():
    """train() with an explicit hybrid ParallelPlan executes the halo
    backend (n_ranks=2) end to end with finite physics."""
    out = _run("""
        import numpy as np
        from repro.cfd.env import EnvConfig
        from repro.cfd.grid import GridConfig
        from repro.core.plan import ParallelPlan
        from repro.drl.ppo import PPOConfig
        from repro.drl.train import TrainConfig, train

        logs = []
        hist, _ = train(TrainConfig(
            env=EnvConfig(grid=GridConfig(res=6, dt=0.012,
                                          poisson_iters=40),
                          steps_per_action=4, actions_per_episode=4,
                          warmup_time=1.5),
            ppo=PPOConfig(epochs=2, minibatches=2),
            n_envs=4, episodes=2, plan=ParallelPlan(4, 2, 2)),
            log_fn=logs.append)
        plan_line = [l for l in logs if "plan[explicit]" in l][0]
        assert "'halo'" in plan_line, plan_line
        assert "2 x 2" in plan_line, plan_line
        assert np.isfinite(hist["reward"]).all()
        assert np.isfinite(hist["cd"]).all()
        print("HYBRID_OK", plan_line)
    """)
    assert "HYBRID_OK" in out
