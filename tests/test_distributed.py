"""Multi-device semantics tests (subprocess: tests must normally see 1 device,
so anything needing a real mesh runs in a child process with forced host
devices)."""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(code: str) -> str:
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "JAX_PLATFORMS": "cpu",   # never probe TPU/GPU in the child
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
           "HOME": "/tmp"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_shard_map_matches_reference():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs.base import ModelConfig, MoEConfig
        from repro.models import moe as moe_mod, act_sharding
        from repro.models.moe_shard_map import apply_moe_expert_parallel
        cfg = ModelConfig(name="t", family="moe", source="", num_layers=1,
                          d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                          vocab_size=100,
                          moe=MoEConfig(num_experts=8, top_k=2,
                                        d_ff_expert=32, num_shared_experts=1,
                                        capacity_factor=8.0),
                          param_dtype="float32", compute_dtype="float32")
        p = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
        ref, _ = moe_mod._moe_dispatch(cfg, p, x)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        with mesh, act_sharding.activation_mesh(mesh):
            out, _ = jax.jit(lambda p, x: apply_moe_expert_parallel(
                cfg, p, x))(p, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, err
        print("MOE_OK", err)
    """)
    assert "MOE_OK" in out


def test_decomposed_poisson_converges():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.cfd.decomp import make_decomposed_poisson
        from repro.cfd import poisson
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        ny, nx = 48, 256
        rhs = jax.random.normal(jax.random.PRNGKey(0), (ny, nx))
        solve = make_decomposed_poisson(mesh, nx, dx=0.05, dy=0.05,
                                        inner_iters=4)
        with mesh:
            sol = solve(rhs, iters=400)
        r = poisson.residual(sol, rhs, 0.05, 0.05)
        r0 = poisson.residual(jnp.zeros_like(rhs), rhs, 0.05, 0.05)
        frac = float(jnp.linalg.norm(r) / jnp.linalg.norm(r0))
        assert frac < 0.10, frac
        # the MPI-analogue message pattern: exactly 2 halo ppermutes
        with mesh:
            txt = jax.jit(lambda r: solve(r, iters=400)
                          ).lower(rhs).compile().as_text()
        n = txt.count("collective-permute(")
        assert n == 2, n
        print("POISSON_OK", frac, n)
    """)
    assert "POISSON_OK" in out


def test_train_step_lowers_on_multidevice_mesh():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs.base import get_config, INPUT_SHAPES, InputShape
        from repro.launch import steps
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_config("phi4-mini-3.8b").reduced()
        shape = InputShape("t", 64, 8, "train")
        with mesh:
            jitted, args = steps.lowering_for(cfg, shape, mesh)
            compiled = jitted.lower(*args).compile()
        print("LOWER_OK", compiled.memory_analysis().temp_size_in_bytes)
    """)
    assert "LOWER_OK" in out
