"""Fault-tolerant resumable training: the bitwise-resume gate.

Layers, cheapest first:
  * engine-level: run_sync's TrainCarry (params/opt/step/key) is sufficient
    state — re-entering with a mid-run carry reproduces the remainder bit
    for bit on a toy env (no CFD).
  * train()-level single-host: train(episodes=N) vs train(episodes=k) ->
    resume -> episodes=N gives identical params, PRNG carry, opt state, env
    batch and history (reward/cd/cl; wall is wall-clock and excluded).
  * forced 4-device subprocesses (pattern of tests/test_halo_backend.py):
    the same gate under an n_ranks=2 halo plan, plus cross-plan resume
    (single-host ckpt -> halo mesh and back).
  * crash injection: SIGKILL a training subprocess mid-run, resume from the
    latest valid checkpoint in-process, and match an uninterrupted run.
"""
import os
import signal
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd.env import EnvConfig
from repro.cfd.grid import GridConfig
from repro.ckpt import checkpoint as ck
from repro.drl import networks, train_state as ts_mod
from repro.drl.engine import EngineConfig, RolloutEngine
from repro.drl.ppo import PPOConfig
from repro.drl.train import TrainConfig, train

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _tiny_cfg(episodes, ckpt_dir=None, *, resume=None, n_envs=2, res=6,
              ckpt_every=1, plan=None, seed=0):
    return TrainConfig(
        env=EnvConfig(grid=GridConfig(res=res, dt=0.012, poisson_iters=30),
                      steps_per_action=3, actions_per_episode=3,
                      warmup_time=1.0),
        ppo=PPOConfig(epochs=2, minibatches=2),
        n_envs=n_envs, episodes=episodes, seed=seed, plan=plan,
        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, resume=resume)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# engine level: the carry IS the resume state (fast, toy env)
# ---------------------------------------------------------------------------

class _Out:
    def __init__(self, obs, reward):
        self.obs, self.reward = obs, reward
        self.cd = jnp.float32(0)
        self.cl = jnp.float32(0)


def _toy_step(st, a):
    new = st * 0.8 + jnp.array([0.5, 0.0, 0.0]) * a
    return new, _Out(new, -jnp.sum(new[:1] ** 2))


N, T = 4, 8
PCFG = networks.PolicyConfig(obs_dim=3, act_dim=1, hidden=16)
PPO = PPOConfig(lr=1e-3, epochs=2, minibatches=2)


def _toy_engine():
    return RolloutEngine(_toy_step, EngineConfig(
        n_envs=N, horizon=T, gamma=PPO.gamma, lam=PPO.lam))


def test_run_sync_carry_resumes_bitwise():
    st0 = jnp.ones((N, 3)) * 2.0
    engine = _toy_engine()
    params, optimizer, opt_state, key = engine.init(PCFG, PPO, seed=0)

    carries = []
    p_straight, _, r_straight = engine.run_sync(
        params, opt_state, PPO, optimizer, st0, st0, key, 6,
        on_state=carries.append)
    assert len(carries) == 6
    # steps thread through: PPO does epochs*minibatches updates per episode
    steps = [int(c.step) for c in carries]
    assert steps == [4 * (i + 1) for i in range(6)]

    # re-enter from the episode-3 carry: the remaining 3 episodes replay
    c3 = carries[2]
    engine2 = _toy_engine()
    p_res, _, r_res = engine2.run_sync(
        c3.params, c3.opt_state, PPO, optimizer, st0, st0, c3.key, 3,
        step=c3.step)
    _assert_trees_equal(p_straight, p_res)
    np.testing.assert_array_equal(r_straight[3:], r_res)


def test_run_async_on_state_cadence_and_resume():
    st0 = jnp.ones((N, 3)) * 2.0
    engine = _toy_engine()
    params, optimizer, opt_state, key = engine.init(PCFG, PPO, seed=0)
    carries = []
    # snapshot at capture: async mode DONATES opt_state to the next update,
    # so a live carry's buffers die as training continues — the same reason
    # AsyncCheckpointer.save() device_gets before returning
    engine.run_async(params, opt_state, PPO, optimizer, st0, st0, key, 7,
                     on_state=lambda c: carries.append(jax.device_get(c)),
                     state_every=3)
    # episodes 3 and 6, plus the final post-drain carry (no in-flight work)
    assert len(carries) == 3
    assert int(carries[-1].step) > int(carries[-2].step)
    # a resumed async run keeps learning from the carry (not bitwise: the
    # one in-flight update is deliberately dropped — see run_async)
    c = carries[-1]
    engine2 = _toy_engine()
    p2, _, r2 = engine2.run_async(c.params, c.opt_state, PPO, optimizer,
                                  st0, st0, c.key, 2, step=c.step)
    assert len(r2) == 2
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(p2))


def test_train_async_checkpoints_and_resumes(tmp_path):
    from repro.drl.async_train import train_async
    st0 = jnp.ones((N, 3)) * 2.0
    d = str(tmp_path / "async")
    p1, r1 = train_async(_toy_step, PCFG, PPO, st0, st0, n_envs=N,
                         horizon=T, episodes=4, seed=0, ckpt_dir=d,
                         ckpt_every=2)
    assert len(r1) == 4
    latest = ck.latest_checkpoint(d)
    assert latest is not None
    ts, meta = ts_mod.load_train_state(latest)
    assert int(ts.episode) == 4 and len(ts.history["reward"]) == 4

    # resume without re-supplying the env batch: the checkpoint carries it
    p2, r2 = train_async(_toy_step, PCFG, PPO, None, None, n_envs=N,
                         horizon=T, episodes=7, seed=0, ckpt_dir=d,
                         ckpt_every=2, resume="auto")
    assert len(r2) == 7
    np.testing.assert_array_equal(r2[:4], r1)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(p2))
    with pytest.raises(ck.CheckpointError, match="no valid checkpoint"):
        train_async(_toy_step, PCFG, PPO, st0, st0, n_envs=N, horizon=T,
                    episodes=2, ckpt_dir=str(tmp_path / "void"),
                    resume=True)
    # shape facts are validated: resuming with a different n_envs is an
    # actionable error, not a vmap axis crash mid-collect
    with pytest.raises(ck.CheckpointError, match="n_envs"):
        train_async(_toy_step, PCFG, PPO, None, None, n_envs=2 * N,
                    horizon=T, episodes=9, ckpt_dir=d, resume=True)


# ---------------------------------------------------------------------------
# train() level, single-host plan: the acceptance gate
# ---------------------------------------------------------------------------

def test_train_bitwise_resume_single_host(tmp_path):
    dA, dB = str(tmp_path / "A"), str(tmp_path / "B")
    hist_a, params_a = train(_tiny_cfg(4, dA, ckpt_every=2), log_fn=None)

    hist_k, _ = train(_tiny_cfg(2, dB, ckpt_every=2), log_fn=None)
    logs = []
    hist_b, params_b = train(_tiny_cfg(4, dB, ckpt_every=2, resume=True),
                             log_fn=logs.append)
    assert any("resume:" in l for l in logs), logs

    _assert_trees_equal(params_a, params_b)                 # exact equality
    for f in ("reward", "cd", "cl"):
        np.testing.assert_array_equal(hist_a[f], hist_b[f])
        np.testing.assert_array_equal(hist_k[f], hist_b[f][:2])
    assert len(hist_b["reward"]) == 4

    # the full checkpointed state matches too: PRNG carry, PPO step,
    # optimizer moments, env batch
    ts_a, _ = ts_mod.load_train_state(ck.latest_checkpoint(dA))
    ts_b, _ = ts_mod.load_train_state(ck.latest_checkpoint(dB))
    np.testing.assert_array_equal(ts_a.key, ts_b.key)
    assert int(ts_a.step) == int(ts_b.step)
    assert int(ts_a.episode) == int(ts_b.episode) == 4
    _assert_trees_equal(ts_a.opt_state, ts_b.opt_state)
    _assert_trees_equal(ts_a.env_state, ts_b.env_state)
    for f in ts_mod.HISTORY_FIELDS:
        assert len(ts_a.history[f]) == 4


def test_train_resume_skips_warmup_and_respects_target(tmp_path):
    d = str(tmp_path / "c")
    train(_tiny_cfg(2, d), log_fn=None)
    # target already reached: returns immediately with the stored history
    logs = []
    hist, params = train(_tiny_cfg(2, d, resume=True), log_fn=logs.append)
    assert len(hist["reward"]) == 2
    assert any("nothing to train" in l for l in logs), logs
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(params))


def test_train_resume_auto_is_fresh_when_empty(tmp_path):
    d = str(tmp_path / "fresh")
    # ckpt_every=0 must not divide-by-zero: treated as every episode
    hist, _ = train(_tiny_cfg(1, d, resume="auto", ckpt_every=0),
                    log_fn=None)
    assert len(hist["reward"]) == 1
    assert ck.latest_checkpoint(d) is not None


# ---------------------------------------------------------------------------
# resume validation: actionable errors, never silent physics changes
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ckpt_run(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("ckpt_run"))
    train(_tiny_cfg(1, d), log_fn=None)
    return d


def test_resume_requires_ckpt_dir():
    with pytest.raises(ValueError, match="ckpt_dir"):
        train(_tiny_cfg(2, None, resume=True), log_fn=None)


def test_resume_missing_checkpoint(tmp_path):
    with pytest.raises(ck.CheckpointError, match="no valid checkpoint"):
        train(_tiny_cfg(2, str(tmp_path / "void"), resume=True), log_fn=None)
    with pytest.raises(ck.CheckpointError, match="not found"):
        train(_tiny_cfg(2, None, resume=str(tmp_path / "nope.ckpt")),
              log_fn=None)


def test_resume_grid_mismatch_is_actionable(ckpt_run):
    with pytest.raises(ck.CheckpointError, match="grid"):
        train(_tiny_cfg(2, ckpt_run, resume=True, res=8), log_fn=None)


def test_resume_n_envs_mismatch_is_actionable(ckpt_run):
    with pytest.raises(ck.CheckpointError, match="n_envs"):
        train(_tiny_cfg(2, ckpt_run, resume=True, n_envs=4), log_fn=None)


def test_resume_seed_mismatch_is_allowed_but_noted(ckpt_run):
    logs = []
    hist, _ = train(_tiny_cfg(2, ckpt_run, resume=True, seed=123),
                    log_fn=logs.append)
    assert len(hist["reward"]) == 2
    assert any("seed differs" in l for l in logs), logs


def test_resume_explicit_path_and_directory(ckpt_run, tmp_path):
    path = ck.latest_checkpoint(ckpt_run)
    d2 = str(tmp_path / "out")
    hist, _ = train(_tiny_cfg(2, d2, resume=path), log_fn=None)
    assert len(hist["reward"]) == 2
    hist2, _ = train(_tiny_cfg(2, str(tmp_path / "out2"), resume=ckpt_run),
                     log_fn=None)
    np.testing.assert_array_equal(hist["reward"], hist2["reward"])


# ---------------------------------------------------------------------------
# forced 4-device host: halo-plan bitwise resume + cross-plan resume
# ---------------------------------------------------------------------------

def _run_forced(code: str, timeout: int = 420) -> str:
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/tmp"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_CHILD_PRELUDE = textwrap.dedent("""
    import tempfile
    import jax, numpy as np
    from repro.cfd.env import EnvConfig
    from repro.cfd.grid import GridConfig
    from repro.ckpt import checkpoint as ck
    from repro.core.plan import ParallelPlan
    from repro.drl import train_state as ts_mod
    from repro.drl.ppo import PPOConfig
    from repro.drl.train import TrainConfig, train

    def cfg(episodes, ckpt_dir, resume=None, plan=None):
        return TrainConfig(
            env=EnvConfig(grid=GridConfig(res=6, dt=0.012,
                                          poisson_iters=30),
                          steps_per_action=3, actions_per_episode=3,
                          warmup_time=1.0),
            ppo=PPOConfig(epochs=2, minibatches=2),
            n_envs=2, episodes=episodes, seed=0, plan=plan,
            ckpt_dir=ckpt_dir, ckpt_every=1, resume=resume)
""")


def test_train_bitwise_resume_forced_halo_plan():
    """Acceptance gate, hybrid half: under a forced 4-device n_ranks=2 halo
    plan, checkpoint-at-k-then-resume equals the straight run exactly."""
    out = _run_forced(_CHILD_PRELUDE + textwrap.dedent("""
        plan = ParallelPlan(4, 2, 2)
        dA, dB = tempfile.mkdtemp(), tempfile.mkdtemp()
        hist_a, params_a = train(cfg(3, dA, plan=plan), log_fn=None)
        train(cfg(1, dB, plan=plan), log_fn=None)
        logs = []
        hist_b, params_b = train(cfg(3, dB, resume=True, plan=plan),
                                 log_fn=logs.append)
        assert any("resume:" in l for l in logs), logs
        for a, b in zip(jax.tree.leaves(params_a), jax.tree.leaves(params_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for f in ("reward", "cd", "cl"):
            np.testing.assert_array_equal(hist_a[f], hist_b[f])
        ta, _ = ts_mod.load_train_state(ck.latest_checkpoint(dA))
        tb, _ = ts_mod.load_train_state(ck.latest_checkpoint(dB))
        np.testing.assert_array_equal(ta.key, tb.key)
        for a, b in zip(jax.tree.leaves(ta.env_state),
                        jax.tree.leaves(tb.env_state)):
            np.testing.assert_array_equal(a, b)
        print("HALO_RESUME_OK")
    """))
    assert "HALO_RESUME_OK" in out


def test_train_cross_plan_resume_both_directions():
    """A checkpoint taken under one plan restores onto another: single-host
    -> (2 envs x 2 ranks) halo mesh, and halo -> single-host.  Physics stays
    finite and the history simply continues (bitwise equality is only
    promised same-plan: the halo solver is a different backend)."""
    out = _run_forced(_CHILD_PRELUDE + textwrap.dedent("""
        plan = ParallelPlan(4, 2, 2)
        d = tempfile.mkdtemp()
        train(cfg(2, d), log_fn=None)                      # single-host
        logs = []
        hist, params = train(cfg(4, d, resume=True, plan=plan),
                             log_fn=logs.append)           # onto halo mesh
        assert any("cross-plan resume" in l for l in logs), logs
        assert len(hist["reward"]) == 4
        assert np.isfinite(hist["reward"]).all()
        assert np.isfinite(hist["cd"]).all()

        logs2 = []
        hist2, _ = train(cfg(6, d, resume=True), log_fn=logs2.append)
        assert any("cross-plan resume" in l for l in logs2), logs2
        assert len(hist2["reward"]) == 6
        assert np.isfinite(hist2["reward"]).all()
        print("CROSS_PLAN_OK")
    """))
    assert "CROSS_PLAN_OK" in out


# ---------------------------------------------------------------------------
# crash injection: SIGKILL mid-run, resume from the latest valid checkpoint
# ---------------------------------------------------------------------------

def test_crash_injection_resume_matches_uninterrupted(tmp_path):
    d = str(tmp_path / "crash")
    # the child trains "forever" with a checkpoint every episode; the parent
    # SIGKILLs it once >= 2 checkpoints exist (mid-episode, mid-write —
    # wherever the kill lands, atomic tmp+replace keeps every *.ckpt valid)
    child = textwrap.dedent(f"""
        from repro.cfd.env import EnvConfig
        from repro.cfd.grid import GridConfig
        from repro.drl.ppo import PPOConfig
        from repro.drl.train import TrainConfig, train
        train(TrainConfig(
            env=EnvConfig(grid=GridConfig(res=6, dt=0.012,
                                          poisson_iters=30),
                          steps_per_action=3, actions_per_episode=3,
                          warmup_time=1.0),
            ppo=PPOConfig(epochs=2, minibatches=2),
            n_envs=2, episodes=1000, seed=0,
            ckpt_dir={d!r}, ckpt_every=1), log_fn=None)
    """)
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    # stderr to a file, not a pipe: an undrained pipe could block a chatty
    # child (jax warnings) before it ever writes a checkpoint
    errlog = tmp_path / "child_stderr.log"
    with open(errlog, "wb") as errf:
        proc = subprocess.Popen([sys.executable, "-c", child], env=env,
                                stdout=subprocess.DEVNULL, stderr=errf)
        try:
            deadline = time.time() + 300
            while time.time() < deadline:
                if len(list(Path(d).glob("step_*.ckpt"))) >= 2:
                    break
                if proc.poll() is not None:
                    raise AssertionError(
                        "training child exited early:\n"
                        + errlog.read_text()[-2000:])
                time.sleep(0.1)
            else:
                raise AssertionError("no checkpoints appeared within 300s")
        finally:
            proc.send_signal(signal.SIGKILL)
            proc.wait()

    latest = ck.latest_checkpoint(d)
    assert latest is not None, sorted(os.listdir(d))
    _, meta = ts_mod.load_train_state(latest)
    k = meta["episode"]
    assert k >= 2
    target = k + 2

    # resume past the crash ...
    hist_r, params_r = train(_tiny_cfg(target, d, resume=True), log_fn=None)
    assert len(hist_r["reward"]) == target
    # ... and it matches a run that never crashed
    hist_s, params_s = train(_tiny_cfg(target, str(tmp_path / "straight"),
                                       ckpt_every=max(1, target)),
                             log_fn=None)
    _assert_trees_equal(params_s, params_r)
    for f in ("reward", "cd", "cl"):
        np.testing.assert_array_equal(hist_s[f], hist_r[f])
