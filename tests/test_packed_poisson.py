"""Packed-checkerboard Poisson parity: the packed layout must reproduce the
full-grid oracle sweep for sweep (ulp-level), at every grid parity, with warm
starts, under vmap, and through every backend that embeds it — plus the new
odd-width warning/dispatch contract of ``poisson.solve``."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import poisson
from tests._prop import given, settings, st

# calibrated: observed <= ~2e-7 (XLA fuses the packed and masked sweeps
# differently, so agreement is 1-2 ulp rather than bitwise)
TOL = dict(rtol=2e-5, atol=2e-6)


def _rhs(ny, nx, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed * 7919 + ny * nx),
                             (ny, nx))


# ---------------------------------------------------------------------------
# layout round-trip
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(ny=st.integers(min_value=2, max_value=40),
       w=st.integers(min_value=1, max_value=40))
def test_pack_unpack_roundtrip(ny, w):
    a = _rhs(ny, 2 * w)
    red, black = poisson.pack_checkerboard(a)
    assert red.shape == black.shape == (ny, w)
    np.testing.assert_array_equal(
        np.asarray(poisson.unpack_checkerboard(red, black)), np.asarray(a))


def test_pack_layout_indexing():
    """red[j, k] = p[j, 2k + j%2] — pin the documented index map."""
    a = np.arange(5 * 8, dtype=np.float32).reshape(5, 8)
    red, black = map(np.asarray, poisson.pack_checkerboard(jnp.asarray(a)))
    for j in range(5):
        for k in range(4):
            assert red[j, k] == a[j, 2 * k + j % 2]
            assert black[j, k] == a[j, 2 * k + 1 - j % 2]


def test_pack_odd_width_raises():
    with pytest.raises(ValueError, match="even grid width"):
        poisson.pack_checkerboard(jnp.zeros((4, 7)))


# ---------------------------------------------------------------------------
# packed vs full-grid oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ny,nx", [(32, 176), (33, 64), (7, 16), (34, 176),
                                   (16, 88), (2, 4)])
@pytest.mark.parametrize("iters,polish", [(60, 10), (24, 0), (7, 3)])
def test_packed_matches_full_oracle(ny, nx, iters, polish):
    rhs = _rhs(ny, nx)
    p0 = _rhs(ny, nx, seed=1)        # warm start exercises the packed p0
    a = poisson.solve(rhs, 0.125, 0.12, iters=iters, polish=polish,
                      p0=p0, backend="full")
    b = poisson.solve(rhs, 0.125, 0.12, iters=iters, polish=polish,
                      p0=p0, backend="packed")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


def test_reference_default_is_packed_on_even_widths():
    rhs = _rhs(34, 176)
    ref = poisson.solve(rhs, 0.125, 0.12, iters=40)
    packed = poisson.solve(rhs, 0.125, 0.12, iters=40, backend="packed")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(packed))


def test_packed_residual_not_worse_at_equal_iters():
    """The packed layout is the same iteration, so at equal sweep counts its
    residual norm must match the oracle's (never regress the hot path's
    convergence per FLOP)."""
    rhs = _rhs(40, 176, seed=2)
    for iters in (20, 60, 120):
        r = {}
        for backend in ("full", "packed"):
            sol = poisson.solve(rhs, 0.125, 0.125, iters=iters,
                                backend=backend)
            r[backend] = float(jnp.linalg.norm(
                poisson.residual(sol, rhs, 0.125, 0.125)))
        assert r["packed"] <= r["full"] * (1 + 1e-4), (iters, r)


@pytest.mark.parametrize("backend", ["packed", "pallas"])
def test_packed_vmapped_batch_parity(backend):
    """vmapping over a batch axis matches per-item solves (the engine's
    N_envs axis runs the solver exactly like this)."""
    B, ny, nx = 3, 24, 64
    rhs = jax.random.normal(jax.random.PRNGKey(0), (B, ny, nx))
    p0 = jax.random.normal(jax.random.PRNGKey(1), (B, ny, nx))
    fn = lambda r, p: poisson.solve(r, 0.125, 0.12, iters=30, p0=p,
                                    backend=backend)
    batched = jax.vmap(fn)(rhs, p0)
    for b in range(B):
        np.testing.assert_allclose(np.asarray(batched[b]),
                                   np.asarray(fn(rhs[b], p0[b])),
                                   rtol=1e-5, atol=1e-5)


def test_pallas_backend_matches_oracle_class():
    """solve(backend='pallas') — packed slab smoother + packed polish —
    stays in the oracle's convergence class at equal iteration budget."""
    rhs = _rhs(34, 176, seed=3)
    r0 = float(jnp.linalg.norm(poisson.residual(jnp.zeros_like(rhs), rhs,
                                                0.1, 0.1)))
    sols = {b: poisson.solve(rhs, 0.1, 0.1, iters=120, backend=b)
            for b in ("full", "pallas")}
    res = {b: float(jnp.linalg.norm(poisson.residual(s, rhs, 0.1, 0.1)))
           for b, s in sols.items()}
    assert res["pallas"] < 0.1 * r0, res
    assert res["pallas"] < 3.0 * res["full"], res


# ---------------------------------------------------------------------------
# odd-width dispatch and warning contract
# ---------------------------------------------------------------------------

def test_packed_backend_odd_width_raises():
    with pytest.raises(ValueError, match="even grid width"):
        poisson.solve(_rhs(24, 33), 0.1, 0.1, iters=8, backend="packed")


def test_reference_odd_width_uses_full_oracle():
    rhs = _rhs(24, 33, seed=4)
    a = poisson.solve(rhs, 0.1, 0.1, iters=40)
    b = poisson.solve(rhs, 0.1, 0.1, iters=40, backend="full")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pallas_odd_width_fallback_warns_once_naming_shape():
    """The silent pallas -> reference fallback now warns, once per shape,
    naming the grid."""
    poisson._ODD_NX_WARNED.clear()
    rhs = _rhs(26, 35, seed=5)
    with pytest.warns(RuntimeWarning, match=r"ny=26, nx=35"):
        poisson.solve(rhs, 0.1, 0.1, iters=8, backend="pallas")
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)   # second call: silent
        poisson.solve(rhs, 0.1, 0.1, iters=8, backend="pallas")
        # ... but a NEW odd shape warns again
        with pytest.raises(RuntimeWarning, match=r"ny=28, nx=35"):
            poisson.solve(_rhs(28, 35), 0.1, 0.1, iters=8, backend="pallas")


def test_use_pallas_deprecation_points_at_caller_under_jit():
    """The deprecated-alias warning must blame the user's call site, not jax
    trace machinery, even when ``solve`` runs under ``jax.jit``."""
    rhs = _rhs(8, 12, seed=6)

    @jax.jit
    def jitted(r):
        return poisson.solve(r, 0.1, 0.1, iters=4, use_pallas=False)

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always", DeprecationWarning)
        jitted(rhs)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and "use_pallas" in str(w.message)]
    assert dep, [str(w.message) for w in rec]
    assert dep[0].filename == __file__, dep[0].filename


def test_use_pallas_conflict_raises():
    with pytest.raises(ValueError, match="conflicting solver selection"):
        poisson.resolve_backend("reference", use_pallas=True)


def test_traced_omega_on_jnp_backends():
    """Seed behavior kept: omega may be a traced jnp scalar on the jnp
    backends (the pallas kernel alone specializes on it and says so)."""
    rhs = _rhs(8, 12, seed=7)
    a = poisson.solve(rhs, 0.1, 0.1, iters=6, omega=jnp.float32(1.5))
    b = poisson.solve(rhs, 0.1, 0.1, iters=6, omega=1.5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-7)
    with pytest.raises(TypeError, match="concrete Python-float omega"):
        poisson.solve(rhs, 0.1, 0.1, iters=6, omega=jnp.float32(1.5),
                      backend="pallas")
