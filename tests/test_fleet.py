"""Multi-process fleet: launcher-driven bitwise parity across fleet sizes,
elastic kill -> shrink -> resume, process-suffixed sinks, and the
distributed bootstrap helpers.

The heavyweight tests drive the REAL entry point — ``tools/launch_fleet.py``
forking runner processes into a ``jax.distributed`` (gloo) fleet — because
the bitwise contract lives in the launcher's pinned
``--xla_force_host_platform_device_count``: XLA CPU codegen differs between
forced device counts even for single-device programs, so only runs whose
runners all pin the plan's ``n_total`` are comparable.  Checkpoints written
by each fleet are compared array-for-array.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

ROOT = Path(__file__).resolve().parent.parent
SRC = str(ROOT / "src")
LAUNCHER = str(ROOT / "tools" / "launch_fleet.py")


def _launch(workdir, *extra, processes=1, episodes=2, timeout=600):
    env = {"JAX_PLATFORMS": "cpu", "PYTHONPATH": SRC,
           "PATH": "/usr/bin:/bin", "HOME": "/tmp"}
    out = subprocess.run(
        [sys.executable, LAUNCHER, "--processes", str(processes),
         "--episodes", str(episodes), "--workdir", str(workdir),
         "--heartbeat-timeout", "300", *map(str, extra)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


def _final_state(workdir):
    from repro.ckpt.checkpoint import latest_checkpoint
    from repro.drl.train_state import load_train_state
    path = latest_checkpoint(str(Path(workdir) / "ckpt"))
    assert path is not None, f"no checkpoint under {workdir}/ckpt"
    return load_train_state(path)


# ---------------------------------------------------------------------------
# the bitwise contract: N-process training == 1-process training
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_two_process_training_bitwise_matches_single(tmp_path):
    """Same plan, same seed: a 2-process fleet and a 1-process fleet write
    IDENTICAL final checkpoints (params, optimizer state, PRNG carry, env
    batch, history) — the distributed rollout + replicated-learner design
    is bitwise-invariant in the fleet size."""
    out1 = _launch(tmp_path / "p1", processes=1)
    out2 = _launch(tmp_path / "p2", processes=2)
    assert "FLEET_DONE episodes=2" in out1
    assert "FLEET_DONE episodes=2" in out2

    ts1, meta1 = _final_state(tmp_path / "p1")
    ts2, meta2 = _final_state(tmp_path / "p2")
    assert meta1["episode"] == meta2["episode"] == 2
    assert meta1["plan"]["n_processes"] == 1
    assert meta2["plan"]["n_processes"] == 2
    import jax
    l1, l2 = jax.tree.leaves(ts1.params), jax.tree.leaves(ts2.params)
    assert len(l1) == len(l2) and len(l1) > 0
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(ts1.opt_state),
                    jax.tree.leaves(ts2.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ts1.key), np.asarray(ts2.key))
    for f, v in ts1.history.items():
        if f == "wall":                   # wall-clock seconds: not bitwise
            continue
        np.testing.assert_array_equal(v, ts2.history[f])


@pytest.mark.slow
def test_killed_runner_shrinks_and_resumes(tmp_path):
    """SIGKILL one runner mid-run: the supervisor detects the death, shrinks
    the fleet to the next viable size, and the relaunched fleet resumes from
    the latest checkpoint to the full episode target."""
    out = _launch(tmp_path / "elastic", "--kill-process", 1,
                  "--kill-episode", 1, processes=2, episodes=3)
    assert "FLEET_SHRINK gen=1 procs=2->1 reason=exit" in out, out
    assert "FLEET_DONE episodes=3" in out, out
    ts, meta = _final_state(tmp_path / "elastic")
    assert meta["episode"] == 3
    assert len(ts.history["reward"]) == 3


# ---------------------------------------------------------------------------
# per-process sink sharding (no cross-host write contention)
# ---------------------------------------------------------------------------

def test_file_sink_process_suffix(tmp_path):
    from repro.drl.engine import FileSink
    from repro.drl.rollout import Trajectory

    def traj(v):
        z = lambda *s: np.full(s, v, np.float32)
        return Trajectory(obs=z(2, 3, 4), act=z(2, 3, 1), logp=z(2, 3),
                          reward=z(2, 3), cd=z(2, 3), cl=z(2, 3),
                          last_obs=z(2, 4))

    s0 = FileSink(str(tmp_path), process=0)
    s1 = FileSink(str(tmp_path), process=1)
    s0.write(0, traj(0.0))
    s1.write(0, traj(1.0))
    names = sorted(p.name for p in tmp_path.glob("*.bin"))
    assert names == ["traj_000000.p000.bin", "traj_000000.p001.bin"]
    # each sink reads back its own shard only
    np.testing.assert_array_equal(s1.read(0).obs,
                                  np.full((2, 3, 4), 1.0, np.float32))
    np.testing.assert_array_equal(s0.read(0).obs,
                                  np.zeros((2, 3, 4), np.float32))
    # a process-less sink in the same dir sees no suffixed shards
    plain = FileSink(str(tmp_path))
    with pytest.raises(KeyError):
        plain.read(0)


def test_dataset_sink_process_partition(tmp_path):
    from repro.data.trajectory_dataset import DatasetSink, TrajectoryReader
    from repro.drl.rollout import Trajectory

    z = lambda *s: np.zeros(s, np.float32)
    traj = Trajectory(obs=z(2, 3, 4), act=z(2, 3, 1), logp=z(2, 3),
                      reward=z(2, 3), cd=z(2, 3), cl=z(2, 3),
                      last_obs=z(2, 4))
    for p in (0, 1):
        sink = DatasetSink(str(tmp_path), process=p)
        sink.write(0, traj)
        assert sink.metadata["process"] == p
    parts = sorted(d.name for d in tmp_path.iterdir() if d.is_dir())
    assert parts == ["part000", "part001"]
    for part in parts:
        reader = TrajectoryReader(str(tmp_path / part))
        assert reader.episodes == [0]


def test_sink_spec_process_defaults_to_jax(tmp_path):
    """Single-process: SinkSpec resolves process=None (no suffix churn for
    the historical layout); an explicit process wins."""
    from repro.drl.engine import SinkSpec
    spec = SinkSpec(kind="binary", root=str(tmp_path))
    assert spec._process() is None
    spec = SinkSpec(kind="binary", root=str(tmp_path), process=7)
    assert spec._process() == 7


# ---------------------------------------------------------------------------
# bootstrap helpers (no fleet needed)
# ---------------------------------------------------------------------------

def test_fleet_env_pins_device_count():
    from repro.launch.distributed import (ENV_COORDINATOR, ENV_FLEET,
                                          ENV_NUM_PROCESSES, ENV_PROCESS_ID,
                                          fleet_env)
    base = {"XLA_FLAGS": "--xla_force_host_platform_device_count=2 "
                         "--xla_dump_to=/tmp/d"}
    env = fleet_env("127.0.0.1:1234", 2, 1, n_total_devices=8, base=base)
    # the stale forced count is REPLACED (pinned to the plan), other flags kept
    assert env["XLA_FLAGS"].count("--xla_force_host_platform_device_count") \
        == 1
    assert "--xla_force_host_platform_device_count=8" in env["XLA_FLAGS"]
    assert "--xla_dump_to=/tmp/d" in env["XLA_FLAGS"]
    assert env[ENV_COORDINATOR] == "127.0.0.1:1234"
    assert env[ENV_NUM_PROCESSES] == "2" and env[ENV_PROCESS_ID] == "1"
    assert env[ENV_FLEET] == "1"


def test_initialize_fleet_single_process_noop():
    from repro.launch.distributed import initialize_fleet
    info = initialize_fleet(num_processes=1)
    assert info.num_processes == 1 and info.is_coordinator


def test_heartbeats_roundtrip_and_staleness(tmp_path):
    from repro.launch.distributed import (read_heartbeats, stale_processes,
                                          write_heartbeat)
    write_heartbeat(str(tmp_path), 0, episode=3)
    write_heartbeat(str(tmp_path), 1, episode=2)
    beats = read_heartbeats(str(tmp_path))
    assert beats[0]["episode"] == 3 and beats[1]["pid"] == os.getpid()
    now = beats[1]["time"]
    assert stale_processes(str(tmp_path), 2, timeout=60, now=now) == []
    assert stale_processes(str(tmp_path), 2, timeout=60,
                           now=now + 120) == [0, 1]
    # a runner that never heartbeated is the launcher's child-exit path,
    # not a staleness signal
    assert stale_processes(str(tmp_path), 3, timeout=60,
                           now=now + 120) == [0, 1]


def test_launch_fleet_shrink_ladder():
    sys.path.insert(0, str(ROOT / "tools"))
    try:
        from launch_fleet import _shrink
    finally:
        sys.path.pop(0)
    assert _shrink(8, 1, 4) == 2          # 3 doesn't divide 8 devices
    assert _shrink(4, 1, 4) == 2          # next divisor of 4 below 4
    assert _shrink(4, 2, 4) == 2          # 2 procs x 2-rank envs still fit
    assert _shrink(4, 4, 2) == 1
    assert _shrink(4, 1, 1) == 0          # nowhere left to shrink


def test_plan_json_roundtrip_with_processes(tmp_path):
    """run_metadata's plan dict (with n_processes) survives the checkpoint
    manifest JSON round trip the resume-compat check reads."""
    from repro.drl.train_state import run_metadata
    from repro.cfd.grid import GridConfig
    meta = run_metadata(n_envs=4, obs_dim=8, seed=0, grid=GridConfig(res=6),
                        horizon=3, steps_per_action=3, scenarios=None,
                        plan={"n_envs": 4, "n_ranks": 1, "backend": "ref",
                              "n_processes": 2})
    back = json.loads(json.dumps(meta))
    assert back["plan"]["n_processes"] == 2
