"""Golden physics regression for the fluidic pinball (3-cylinder geometry).

Same contract as ``test_golden_physics.py``: the checked-in reference
(``tests/golden/pinball_re100_res8.npz``, from ``tools/gen_golden.py
--geometry pinball``) stores the saturated uncontrolled flow state plus
Strouhal / mean C_D / C_L amplitude of the TOTAL (all-body) forces over a
fixed window; the test restarts from that state and re-measures.  The
pinball develops slowly — it passes through an asymmetric deflected state
(mean C_L ~ -0.25 near t=100) before symmetric shedding saturates around
t~400 — so the fixture is the expensive part and regeneration takes ~44k
solver steps.

The full re-measure (2000 steps) is marked ``slow``; a short smoke variant
pins the mean drag over 200 steps so every CI run still exercises the
multi-body penalization path against the committed reference.
"""
from pathlib import Path

import numpy as np
import pytest

from repro.cfd import solver
from repro.cfd.grid import GridConfig
from repro.cfd.validation import measure_shedding, run_uncontrolled

GOLDEN = Path(__file__).parent / "golden" / "pinball_re100_res8.npz"

# Relative tolerances, mutation-calibrated on the re-measure window (the
# re-measurement itself is bit-exact on the generating platform):
#   upwind_blend 0.2->0.25:  CD +1.2%, amp -11.8%      -> TOL_CD / TOL_AMP
#   upwind_blend 0.2->0.3:   St -1.7%, CD +2.3%        -> TOL_ST / TOL_CD
#   effective Re off by 10%: CD -1.8%, amp +12.7%      -> TOL_CD / TOL_AMP
# (penal_eta x2 moves nothing above 0.6% — penalization stiffness is not
# a physics knob at this resolution)
TOL_ST = 0.015
TOL_CD = 0.01
TOL_AMP = 0.06


def _restart():
    ref = np.load(GOLDEN)
    cfg = GridConfig(res=int(ref["res"]), dt=float(ref["dt"]),
                     poisson_iters=int(ref["poisson_iters"]))
    state = solver.FlowState(u=ref["u"], v=ref["v"], p=ref["p"])
    return ref, cfg, state


@pytest.fixture(scope="module")
def remeasured():
    ref, cfg, state = _restart()
    _, cds, cls = run_uncontrolled(cfg, state, int(ref["meas_steps"]),
                                   geometry=str(ref["geometry"]))
    return ref, measure_shedding(cds, cls, cfg.dt), cds, cls


@pytest.mark.slow
def test_pinball_strouhal_number(remeasured):
    ref, stats, _, _ = remeasured
    assert stats["strouhal"] == pytest.approx(float(ref["strouhal"]),
                                              rel=TOL_ST)


@pytest.mark.slow
def test_pinball_mean_drag_coefficient(remeasured):
    ref, stats, _, _ = remeasured
    assert stats["cd_mean"] == pytest.approx(float(ref["cd_mean"]),
                                             rel=TOL_CD)


@pytest.mark.slow
def test_pinball_lift_oscillation_amplitude(remeasured):
    ref, stats, _, _ = remeasured
    assert stats["cl_amp"] == pytest.approx(float(ref["cl_amp"]),
                                            rel=TOL_AMP)


@pytest.mark.slow
def test_pinball_shedding_is_developed(remeasured):
    """The stored state must hold genuine saturated symmetric shedding, not
    the transient deflected state the pinball passes through first."""
    _, stats, cds, cls = remeasured
    assert stats["n_periods"] >= 3
    assert np.isfinite(cds).all() and np.isfinite(cls).all()
    assert abs(float(cls.mean())) < 0.1       # symmetric regime, not deflected
    assert 15.0 < stats["cd_mean"] < 25.0     # 3 confined bodies, total drag
    assert 0.25 < stats["strouhal"] < 0.45


def test_pinball_golden_smoke():
    """CI-speed variant: 200 restarted steps must stay finite and hold the
    stored mean drag within TOL_CD — catches a broken multi-body
    penalization path without paying the full re-measure window."""
    ref, cfg, state = _restart()
    _, cds, cls = run_uncontrolled(cfg, state, 200, geometry="pinball")
    assert np.isfinite(cds).all() and np.isfinite(cls).all()
    assert cds.mean() == pytest.approx(float(ref["cd_mean"]), rel=TOL_CD)
    assert np.abs(cls).max() < 1.0            # no penalization blow-up
