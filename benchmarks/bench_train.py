"""End-to-end training-loop benchmark: the observability artifact.

Runs the paper's Fig. 4 loop (collect -> PPO update -> trajectory sink) on
the cylinder env with ``EngineConfig(timing=True)`` so the engine reports
real phase times, and measures:

- **throughput**: environment steps (solver steps x envs) per second,
- **phase shares**: collect / update / sink-write fractions of wall time
  (the paper's ">95% of time is CFD" claim, Fig. 10),
- **projected parallel efficiency**: a strong-scaling projection of this
  host's phase split to the paper's 60-core point (collect parallelizes,
  update + sink stay serial) against the paper's measured 78% / 47x,
- **golden-physics drift**: Strouhal / mean C_D / C_L amplitude re-measured
  from the checked-in golden state vs the stored reference — the dashboard
  sees solver drift next to the perf numbers that might have caused it.

Writes ``artifacts/BENCH_train.json`` (``BENCH_train_smoke.json`` with
``--smoke`` — smoke artifacts never overwrite committed measurements).

    PYTHONPATH=src python benchmarks/bench_train.py [--smoke]
"""
import argparse
import json
import shutil
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import numpy as np

from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import GridConfig
from repro.drl import networks
from repro.drl.engine import (EngineConfig, RolloutEngine, SinkSpec,
                              broadcast_env_state)
from repro.drl.ppo import PPOConfig
from repro.drl.train_state import code_fingerprint

BENCH_SCHEMA = "repro.bench_train/v1"
PAPER_EFFICIENCY_60 = 0.78      # paper Fig. 7: parallel efficiency, 60 cores
PAPER_SPEEDUP_60 = 47.0         # paper: 47x at 60 cores
GOLDEN = Path(__file__).resolve().parent.parent / "tests" / "golden" \
    / "cyl_re100_res8.npz"


def measure_training(smoke: bool) -> dict:
    """One timed training run with a dataset sink; returns the perf record."""
    # non-smoke uses the paper's 50 solver steps per actuation so the phase
    # split reflects the regime the scaling claims are about (CFD-dominated)
    res, p_iters = (6, 30) if smoke else (8, 50)
    spa = 3 if smoke else 50
    horizon = 3 if smoke else 20
    n_envs = 2 if smoke else 4
    episodes = 3 if smoke else 5
    env = CylinderEnv(EnvConfig(
        grid=GridConfig(res=res, dt=0.01, poisson_iters=p_iters),
        steps_per_action=spa, actions_per_episode=horizon,
        warmup_time=1.0 if smoke else 5.0))
    st, obs = env.reset()
    pcfg = networks.PolicyConfig(obs_dim=int(obs.shape[-1]))
    ppo = PPOConfig(epochs=2 if smoke else 6,
                    minibatches=2 if smoke else 4)

    root = tempfile.mkdtemp(prefix="bench_train_sink_")
    engine = RolloutEngine.for_env(
        env, EngineConfig(n_envs=n_envs, horizon=horizon, gamma=ppo.gamma,
                          lam=ppo.lam, timing=True,
                          sink=SinkSpec(kind="dataset", root=root)))
    st_b, obs_b = broadcast_env_state(st, obs, n_envs)
    params, optimizer, opt_state, key = engine.init(pcfg, ppo, seed=0)

    # one untimed episode: compile collect + postprocess + update outside
    # the measured window (throughput, not compile latency)
    engine.run_sync(params, opt_state, ppo, optimizer, st_b, obs_b, key, 1)
    engine.stats = {"collect_s": 0.0, "update_s": 0.0, "episodes": 0}
    sink = engine.sink
    write0, bytes0 = sink.time_spent, sink.bytes_written

    t0 = time.perf_counter()
    engine.run_sync(params, opt_state, ppo, optimizer, st_b, obs_b, key,
                    episodes)
    wall = time.perf_counter() - t0

    collect_s = engine.stats["collect_s"]
    update_s = engine.stats["update_s"]
    sink_s = sink.time_spent - write0
    sink_bytes = sink.bytes_written - bytes0
    shutil.rmtree(root, ignore_errors=True)

    env_steps = n_envs * horizon * spa * episodes
    per_ep = {"collect_s": collect_s / episodes,
              "update_s": update_s / episodes,
              "sink_write_s": sink_s / episodes}

    # strong-scaling projection of THIS host's phase split: collect (the CFD
    # side) parallelizes over cores, update + sink stay serial — the Amdahl
    # shape behind the paper's Fig. 7 curve.  t(n) = collect/n + serial.
    serial = per_ep["update_s"] + per_ep["sink_write_s"]
    t1 = per_ep["collect_s"] + serial

    def eff(n):
        return t1 / (n * (per_ep["collect_s"] / n + serial))

    return {
        "config": {"res": res, "poisson_iters": p_iters, "n_envs": n_envs,
                   "horizon": horizon, "steps_per_action": spa,
                   "episodes": episodes, "smoke": smoke,
                   "ppo_epochs": ppo.epochs,
                   "ppo_minibatches": ppo.minibatches},
        "wall_s": wall,
        "env_steps": env_steps,
        "env_steps_per_s": env_steps / wall,
        "episodes_per_s": episodes / wall,
        "shares": {"collect": collect_s / wall, "update": update_s / wall,
                   "sink_write": sink_s / wall,
                   "other": max(0.0, 1.0 - (collect_s + update_s + sink_s)
                                / wall)},
        "per_episode_s": per_ep,
        "sink": {"kind": "dataset", "bytes_written": sink_bytes,
                 "bytes_per_episode": sink_bytes / episodes,
                 "write_bandwidth": sink_bytes / sink_s if sink_s else None},
        "scaling_projection": {
            "model": "t(n) = collect/n + update + sink (strong scaling)",
            "projected_speedup_60": 60.0 * eff(60),
            "projected_efficiency_60": eff(60),
            "projected_efficiency_8": eff(8),
            "paper_efficiency_60": PAPER_EFFICIENCY_60,
            "paper_speedup_60": PAPER_SPEEDUP_60,
        },
    }


def measure_golden_drift(smoke: bool) -> dict:
    """Re-measure the golden Re=100 shedding window; relative drift vs the
    checked-in reference (tools/gen_golden.py).  Mirrors
    tests/test_golden_physics.py, but reports magnitudes instead of
    asserting — the dashboard tracks drift as a trajectory."""
    from repro.cfd import solver
    from repro.cfd.validation import measure_shedding, run_uncontrolled
    if not GOLDEN.exists():
        return {"error": f"golden reference missing: {GOLDEN}"}
    ref = np.load(GOLDEN)
    cfg = GridConfig(res=int(ref["res"]), dt=float(ref["dt"]),
                     poisson_iters=int(ref["poisson_iters"]))
    steps = int(ref["meas_steps"]) // (2 if smoke else 1)
    state = solver.FlowState(u=ref["u"], v=ref["v"], p=ref["p"])
    _, cds, cls = run_uncontrolled(cfg, state, steps)
    try:
        stats = measure_shedding(cds, cls, cfg.dt)
    except ValueError as exc:           # smoke window too short for periods
        return {"error": str(exc), "window_steps": steps}
    rel = lambda k: stats[k] / float(ref[k]) - 1.0
    return {"window_steps": steps,
            "strouhal": stats["strouhal"],
            "cd_mean": stats["cd_mean"],
            "cl_amp": stats["cl_amp"],
            "strouhal_rel_drift": rel("strouhal"),
            "cd_mean_rel_drift": rel("cd_mean"),
            "cl_amp_rel_drift": rel("cl_amp")}


def run(smoke: bool = False, out: str = None) -> dict:
    record = {"schema": BENCH_SCHEMA,
              "code": code_fingerprint(),
              "jax_devices": jax.device_count()}
    record.update(measure_training(smoke))
    record["golden_drift"] = measure_golden_drift(smoke)

    root = Path(__file__).resolve().parent.parent / "artifacts"
    name = "BENCH_train_smoke.json" if smoke else "BENCH_train.json"
    path = Path(out) if out else root / name
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(record, indent=1, sort_keys=True))

    sh, proj = record["shares"], record["scaling_projection"]
    print(f"train: {record['env_steps_per_s']:.1f} env-steps/s "
          f"({record['wall_s']:.2f}s wall)")
    print(f"shares: collect {sh['collect']:.1%}  update {sh['update']:.1%}  "
          f"sink {sh['sink_write']:.1%}  other {sh['other']:.1%}")
    print(f"projected efficiency @60 cores: "
          f"{proj['projected_efficiency_60']:.1%} "
          f"(paper: {PAPER_EFFICIENCY_60:.0%}, {PAPER_SPEEDUP_60:.0f}x)")
    gd = record["golden_drift"]
    if "error" in gd:
        print(f"golden drift: skipped ({gd['error']})")
    else:
        print(f"golden drift: St {gd['strouhal_rel_drift']:+.3%}  "
              f"CD {gd['cd_mean_rel_drift']:+.3%}  "
              f"|CL| {gd['cl_amp_rel_drift']:+.3%}")
    print(f"artifact -> {path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI; writes BENCH_train_smoke.json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out)
