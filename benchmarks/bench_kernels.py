"""Pallas kernel microbenchmarks (interpret mode = correctness-oriented
timing on CPU; the TPU-target numbers come from the roofline analysis)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.cfd import poisson
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.poisson import ops as poisson_ops
from repro.kernels.rwkv6 import ops as rwkv_ops
from repro.models.ssm import wkv6_scan


def run(smoke: bool = False) -> None:
    it_ref, it_ker = (1, 1) if smoke else (5, 3)
    # poisson: jnp global SOR vs pallas slab kernel (same iteration count)
    p_it = 20 if smoke else 100
    rhs = jax.random.normal(jax.random.PRNGKey(0), (48, 256))
    t_ref = time_fn(lambda r: poisson.solve(r, 0.05, 0.05, iters=p_it), rhs,
                    iters=it_ref)
    t_ker = time_fn(lambda r: poisson_ops.rb_sor(r, 0.05, 0.05, iters=p_it,
                                                 interpret=True), rhs,
                    iters=it_ker)
    emit(f"poisson_jnp_{p_it}it", t_ref * 1e6, "48x256")
    emit(f"poisson_pallas_interp_{p_it}it", t_ker * 1e6,
         "48x256;interpret_mode")

    # flash attention vs naive ref
    S_att = 128 if smoke else 512
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (8, S_att, 64))
    k = jax.random.normal(ks[1], (8, S_att, 64))
    v = jax.random.normal(ks[2], (8, S_att, 64))
    t_ref = time_fn(lambda a, b, c: attention_ref(a, b, c), q, k, v,
                    iters=it_ref)
    from repro.kernels.flash_attention.kernel import flash_attention_bhsd
    t_ker = time_fn(lambda a, b, c: flash_attention_bhsd(
        a, b, c, interpret=True), q, k, v, iters=it_ker)
    emit("attention_ref_naive", t_ref * 1e6, f"BH8_S{S_att}_dh64")
    emit("attention_pallas_interp", t_ker * 1e6, f"BH8_S{S_att}_dh64")

    # wkv6: sequential scan vs chunked kernel
    B, S, H, N = (1, 128, 2, 64) if smoke else (2, 512, 4, 64)
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    r = jax.random.normal(ks[0], (B, S, H, N)) * 0.5
    kk = jax.random.normal(ks[1], (B, S, H, N)) * 0.5
    vv = jax.random.normal(ks[2], (B, S, H, N)) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) - 2.0))
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    st = jnp.zeros((B, H, N, N))
    t_scan = time_fn(jax.jit(wkv6_scan), r, kk, vv, w, u, st, iters=it_ker)
    t_ker = time_fn(lambda *a: rwkv_ops.wkv6(*a, interpret=True),
                    r, kk, vv, w, u, st, iters=it_ker)
    emit("wkv6_seq_scan", t_scan * 1e6, f"B{B}_S{S}_H{H}_N{N}")
    emit("wkv6_pallas_interp", t_ker * 1e6, f"B{B}_S{S}_H{H}_N{N}")


if __name__ == "__main__":
    run()
