"""Pallas kernel microbenchmarks (interpret mode = correctness-oriented
timing on CPU; the TPU-target numbers come from the roofline analysis).

The Poisson section is the PR-5 hot-path measurement: packed-checkerboard
vs full-grid sweep storage at equal iterations on the production grid, plus
the halo backend's per-exchange message volume.  It lands in
``artifacts/BENCH_poisson.json`` so the perf trajectory accumulates across
PRs (aggregate with ``tools/bench_report.py``).

Standalone:  PYTHONPATH=src python benchmarks/bench_kernels.py [--smoke]
"""
import json
import sys
from pathlib import Path

if __name__ == "__main__":  # standalone: make benchmarks.* / repro.* importable
    _ROOT = Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_ROOT / "src"))
    sys.path.insert(0, str(_ROOT))

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.cfd import poisson
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.poisson import ops as poisson_ops
from repro.kernels.rwkv6 import ops as rwkv_ops
from repro.models.ssm import wkv6_scan

_ART_DIR = Path(__file__).resolve().parent.parent / "artifacts"
ARTIFACT = _ART_DIR / "BENCH_poisson.json"
# smoke runs land in a separate file: the committed BENCH_poisson.json is a
# full res-8 measurement (the perf-trajectory record README cites) and must
# not be clobbered by every CI smoke pass
ARTIFACT_SMOKE = _ART_DIR / "BENCH_poisson_smoke.json"

POISSON_SCHEMA = "repro.bench_poisson/v1"


def bench_poisson_layouts(smoke: bool = False, artifact: str = None) -> dict:
    """Packed vs full-grid sweep storage on the production pressure grid.

    Equal iteration counts and (up to ulp noise) equal residuals — the
    speedup is pure layout: no masked half-updates, no full-grid padding,
    half the touched bytes.  Also records the halo backend's per-exchange
    message volume (single-parity half column vs legacy full column).
    """
    from repro.cfd.decomp import halo_exchange_values
    from repro.cfd.grid import GridConfig

    if artifact is None:
        artifact = str(ARTIFACT_SMOKE if smoke else ARTIFACT)
    grid = GridConfig(res=4 if smoke else 8)
    iters = 40 if smoke else 120
    t_iters = 2 if smoke else 7
    rhs = jax.random.normal(jax.random.PRNGKey(0), (grid.ny, grid.nx))

    times, residuals, sols = {}, {}, {}
    backends = ("full", "packed", "pallas")
    for backend in backends:
        fn = lambda r, b=backend: poisson.solve(r, grid.dx, grid.dy,
                                                iters=iters, backend=b)
        times[backend] = time_fn(fn, rhs, iters=t_iters)
        sols[backend] = fn(rhs)
        residuals[backend] = float(jnp.linalg.norm(
            poisson.residual(sols[backend], rhs, grid.dx, grid.dy)))
        emit(f"poisson_{backend}_{iters}it", times[backend] * 1e6,
             f"{grid.ny}x{grid.nx};res={residuals[backend]:.4g}")

    speedup = times["full"] / times["packed"]
    max_diff = float(jnp.max(jnp.abs(sols["packed"] - sols["full"])))
    emit("poisson_packed_speedup", 0.0,
         f"packed_vs_full={speedup:.2f}x;max_abs_diff={max_diff:.3g}")

    record = {
        "schema": POISSON_SCHEMA,
        "grid": {"res": grid.res, "ny": grid.ny, "nx": grid.nx,
                 "smoke": smoke},
        "iters": iters,
        "timing_iters": t_iters,
        "t_us": {b: times[b] * 1e6 for b in backends},
        "speedup_packed_vs_full": speedup,
        "residual_norm": residuals,
        "max_abs_diff_packed_vs_full": max_diff,
        "halo_exchange": {
            "values_per_message_packed": halo_exchange_values(grid.ny),
            "values_per_message_full": halo_exchange_values(grid.ny,
                                                            packed=False),
            "note": "inner_iters=1 packed halos ship one parity per "
                    "half-sweep: bytes per ppermute halved vs the legacy "
                    "full-column exchange",
        },
    }
    if artifact:
        path = Path(artifact)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(record, indent=1))
    return record


def run(smoke: bool = False) -> None:
    it_ref, it_ker = (1, 1) if smoke else (5, 3)
    # poisson: packed vs full-grid jnp sweeps + pallas slab kernel, with the
    # BENCH_poisson.json artifact
    bench_poisson_layouts(smoke)
    # legacy CSV rows: jnp global SOR vs pallas slab kernel (same iteration
    # count, interpret mode)
    p_it = 20 if smoke else 100
    rhs = jax.random.normal(jax.random.PRNGKey(0), (48, 256))
    t_ref = time_fn(lambda r: poisson.solve(r, 0.05, 0.05, iters=p_it), rhs,
                    iters=it_ref)
    t_ker = time_fn(lambda r: poisson_ops.rb_sor(r, 0.05, 0.05, iters=p_it,
                                                 interpret=True), rhs,
                    iters=it_ker)
    emit(f"poisson_jnp_{p_it}it", t_ref * 1e6, "48x256")
    emit(f"poisson_pallas_interp_{p_it}it", t_ker * 1e6,
         "48x256;interpret_mode")

    # flash attention vs naive ref
    S_att = 128 if smoke else 512
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (8, S_att, 64))
    k = jax.random.normal(ks[1], (8, S_att, 64))
    v = jax.random.normal(ks[2], (8, S_att, 64))
    t_ref = time_fn(lambda a, b, c: attention_ref(a, b, c), q, k, v,
                    iters=it_ref)
    from repro.kernels.flash_attention.kernel import flash_attention_bhsd
    t_ker = time_fn(lambda a, b, c: flash_attention_bhsd(
        a, b, c, interpret=True), q, k, v, iters=it_ker)
    emit("attention_ref_naive", t_ref * 1e6, f"BH8_S{S_att}_dh64")
    emit("attention_pallas_interp", t_ker * 1e6, f"BH8_S{S_att}_dh64")

    # wkv6: sequential scan vs chunked kernel
    B, S, H, N = (1, 128, 2, 64) if smoke else (2, 512, 4, 64)
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    r = jax.random.normal(ks[0], (B, S, H, N)) * 0.5
    kk = jax.random.normal(ks[1], (B, S, H, N)) * 0.5
    vv = jax.random.normal(ks[2], (B, S, H, N)) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) - 2.0))
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    st = jnp.zeros((B, H, N, N))
    t_scan = time_fn(jax.jit(wkv6_scan), r, kk, vv, w, u, st, iters=it_ker)
    t_ker = time_fn(lambda *a: rwkv_ops.wkv6(*a, interpret=True),
                    r, kk, vv, w, u, st, iters=it_ker)
    emit("wkv6_seq_scan", t_scan * 1e6, f"B{B}_S{S}_H{H}_N{N}")
    emit("wkv6_pallas_interp", t_ker * 1e6, f"B{B}_S{S}_H{H}_N{N}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 timing iteration (CI smoke)")
    ap.add_argument("--only-poisson", action="store_true",
                    help="run just the Poisson layout bench + artifact")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.only_poisson:
        bench_poisson_layouts(args.smoke)
    else:
        run(smoke=args.smoke)
