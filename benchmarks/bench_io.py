"""Paper Table II — I/O strategies, REAL measured file I/O on this host.

Writes+reads one actuation period's files per mode (ascii 5 MB baseline vs
1.2 MB binary vs zstd), then feeds the measured per-actuation costs into the
calibrated scaling model to produce the Table II analogue.
"""
import dataclasses
import tempfile

import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.interface import ExchangeRecord, FileInterface
from repro.core.plan import ParallelPlan
from repro.core.scaling_model import calibrate_to_paper


def _measure_mode(mode: str, tmp: str, iters: int = 5):
    fi = FileInterface(mode, f"{tmp}/{mode}", 0)
    rng = np.random.RandomState(0)
    rec = ExchangeRecord(obs=rng.randn(149), forces=rng.randn(10, 2),
                         action=0.3,
                         flow_field=rng.randn(fi.flowfield_floats))
    import time
    sizes, times = [], []
    for i in range(iters):
        t0 = time.perf_counter()
        fi.inject_action(0.3 + i * 0.01)
        nb = fi.write_actuation(i, rec)
        fi.read_actuation(i)
        times.append(time.perf_counter() - t0)
        sizes.append(nb)
    fi.cleanup()
    times.sort()
    return times[len(times) // 2], float(np.mean(sizes))


def run() -> None:
    with tempfile.TemporaryDirectory() as tmp:
        measured = {}
        for mode in ("file_baseline", "optimized", "optimized_zstd"):
            t, nb = _measure_mode(mode, tmp)
            measured[mode] = (t, nb)
            emit(f"io_{mode}", t * 1e6, f"bytes={nb:.0f}")

    base_t, base_b = measured["file_baseline"]
    opt_t, opt_b = measured["optimized"]
    emit("io_reduction", 0.0,
         f"size_ratio={opt_b / base_b:.3f};paper=0.24;time_ratio="
         f"{opt_t / base_t:.3f}")

    # Table II analogue from the calibrated model with MEASURED io bytes
    m = calibrate_to_paper()
    for n_envs in (1, 10, 30, 60):
        p = ParallelPlan(n_envs, n_envs, 1)
        tb = m.t_training(p, 3000, io_bytes=base_b) / 3600
        td = m.t_training(p, 3000, io_bytes=0.0) / 3600
        to = m.t_training(p, 3000, io_bytes=opt_b) / 3600
        emit(f"table2_envs{n_envs}", tb * 3600 * 1e6 / 3000,
             f"baseline_h={tb:.1f};disabled_h={td:.1f};optimized_h={to:.1f};"
             f"eff_base={m.efficiency(p, io_bytes=base_b):.3f};"
             f"eff_opt={m.efficiency(p, io_bytes=opt_b):.3f}")


if __name__ == "__main__":
    run()
