"""Paper Table II — I/O strategies, REAL measured file I/O on this host.

Writes+reads one actuation period's files per mode (ascii 5 MB baseline vs
1.2 MB binary vs zstd), then feeds the measured per-actuation costs into the
calibrated scaling model to produce the Table II analogue.  Also measures the
engine-side trajectory spill (``drl.engine.TrajectorySink``), which reuses the
same binary codec for whole-episode dumps (§IV refinement).
"""
import tempfile

import numpy as np

from benchmarks.common import emit
from repro.core.interface import ExchangeRecord, FileInterface
from repro.core.plan import ParallelPlan
from repro.core.scaling_model import calibrate_to_paper
from repro.drl.engine import FileSink, MemorySink
from repro.drl.rollout import Trajectory


def _measure_mode(mode: str, tmp: str, iters: int = 5,
                  flowfield_floats=None):
    fi = FileInterface(mode, f"{tmp}/{mode}", 0,
                       flowfield_floats=flowfield_floats)
    rng = np.random.RandomState(0)
    rec = ExchangeRecord(obs=rng.randn(149), forces=rng.randn(10, 2),
                         action=0.3,
                         flow_field=rng.randn(fi.flowfield_floats))
    import time
    sizes, times = [], []
    for i in range(iters):
        t0 = time.perf_counter()
        fi.inject_action(0.3 + i * 0.01)
        nb = fi.write_actuation(i, rec)
        fi.read_actuation(i)
        times.append(time.perf_counter() - t0)
        sizes.append(nb)
    fi.cleanup()
    times.sort()
    return times[len(times) // 2], float(np.mean(sizes))


def _synthetic_traj(n_envs: int, horizon: int) -> Trajectory:
    rng = np.random.RandomState(1)
    return Trajectory(
        obs=rng.randn(n_envs, horizon, 149).astype(np.float32),
        act=rng.randn(n_envs, horizon, 1).astype(np.float32),
        logp=rng.randn(n_envs, horizon).astype(np.float32),
        reward=rng.randn(n_envs, horizon).astype(np.float32),
        cd=rng.randn(n_envs, horizon).astype(np.float32),
        cl=rng.randn(n_envs, horizon).astype(np.float32),
        last_obs=rng.randn(n_envs, 149).astype(np.float32))


def _measure_sinks(tmp: str, smoke: bool) -> None:
    n_envs, horizon = (2, 8) if smoke else (16, 100)
    traj = _synthetic_traj(n_envs, horizon)
    sinks = [("memory", MemorySink()),
             ("binary", FileSink(f"{tmp}/sink_bin", codec="binary")),
             ("zstd", FileSink(f"{tmp}/sink_zstd", codec="zstd"))]
    for name, sink in sinks:
        episodes = 1 if smoke else 3
        for ep in range(episodes):
            sink.write(ep, traj)
        per_ep = sink.time_spent / sink.episodes
        emit(f"sink_{name}", per_ep * 1e6,
             f"bytes_per_episode={sink.bytes_written // sink.episodes};"
             f"n_envs={n_envs};horizon={horizon};codec="
             f"{getattr(sink, 'codec', 'ram')}")
        sink.cleanup()


def run(smoke: bool = False) -> None:
    iters = 1 if smoke else 5
    ff = 1000 if smoke else None       # smoke: skip the 5 MB ascii payload
    with tempfile.TemporaryDirectory() as tmp:
        measured = {}
        for mode in ("file_baseline", "optimized", "optimized_zstd"):
            t, nb = _measure_mode(mode, tmp, iters=iters, flowfield_floats=ff)
            measured[mode] = (t, nb)
            emit(f"io_{mode}", t * 1e6, f"bytes={nb:.0f}")
        _measure_sinks(tmp, smoke)

    base_t, base_b = measured["file_baseline"]
    opt_t, opt_b = measured["optimized"]
    emit("io_reduction", 0.0,
         f"size_ratio={opt_b / base_b:.3f};paper=0.24;time_ratio="
         f"{opt_t / base_t:.3f}")

    # Table II analogue from the calibrated model with MEASURED io bytes
    m = calibrate_to_paper()
    for n_envs in (1, 30) if smoke else (1, 10, 30, 60):
        p = ParallelPlan(n_envs, n_envs, 1)
        tb = m.t_training(p, 3000, io_bytes=base_b) / 3600
        td = m.t_training(p, 3000, io_bytes=0.0) / 3600
        to = m.t_training(p, 3000, io_bytes=opt_b) / 3600
        emit(f"table2_envs{n_envs}", tb * 3600 * 1e6 / 3000,
             f"baseline_h={tb:.1f};disabled_h={td:.1f};optimized_h={to:.1f};"
             f"eff_base={m.efficiency(p, io_bytes=base_b):.3f};"
             f"eff_opt={m.efficiency(p, io_bytes=opt_b):.3f}")


if __name__ == "__main__":
    run()
