"""Fused actuation-interval benchmark: the megakernel's gate artifact.

Measures the environment hot loop — ``CylinderEnv.env_step`` (one actuation
interval: ``steps_per_action`` solver dt's + probes/reward), jitted and
vmapped over the batch — for the reference scan and the fused interval path
(``backend="fused"``), and gates the fused end-to-end env-steps/s against
the committed PR-6 training baseline (``artifacts/BENCH_train.json``):

- **gate**: fused env-steps/s >= ``REQUIRED_SPEEDUP`` x the baseline's
  ``env_steps_per_s`` (``tools/bench_report.py --check`` fails on
  ``gate.passed == false``),
- **parity**: max |fused - reference| over the flow state and outputs after
  one interval on a *mixed* vmapped scenario batch (jets + rotary, two
  Reynolds numbers),
- **golden drift**: Strouhal / C_D / C_L re-measured from the checked-in
  golden state (reuses ``bench_train.measure_golden_drift``),
- **roofline gap**: measured interval time vs the roofline bound priced
  against this host's :class:`~repro.launch.roofline.HardwareSpec` (CPU
  hosts price against ``cpu_generic``, not silently against TPU numbers).

Throughput is the best of ``REPS`` timed repetitions: the artifact records
the machine's capability, not the co-tenancy noise of a shared host (each
rep is itself a full interval batch, ~0.2 s of work).

Writes ``artifacts/BENCH_megakernel.json`` (``_smoke`` variant under
``--smoke`` — smoke artifacts never overwrite committed measurements).

    PYTHONPATH=src python benchmarks/bench_megakernel.py [--smoke]
"""
import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp

from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import GridConfig
from repro.drl.engine import broadcast_env_state
from repro.drl.train_state import code_fingerprint
from repro.launch.roofline import Roofline, hardware_spec

BENCH_SCHEMA = "repro.bench_megakernel/v1"
BASELINE = Path(__file__).resolve().parent.parent / "artifacts" \
    / "BENCH_train.json"
REQUIRED_SPEEDUP = 2.0
REPS = 7
# the mixed batch the parity check integrates: both actuation modes and two
# Reynolds numbers, vmapped into one program
PARITY_SCENARIOS = ("cyl_re100", "cyl_re200_rotary", "cyl_re100_rotary",
                    "cyl_re200")


def measure_throughput(smoke: bool) -> dict:
    """Best-of-reps env-steps/s for reference vs fused on the gate config
    (the res/iteration budget BENCH_train measured the baseline at)."""
    res, p_iters = (6, 30) if smoke else (8, 50)
    spa = 5 if smoke else 50
    n_envs = 2 if smoke else 4
    cfg = EnvConfig(grid=GridConfig(res=res, dt=0.01, poisson_iters=p_iters),
                    steps_per_action=spa, warmup_time=1.0 if smoke else 5.0)

    out = {"config": {"res": res, "poisson_iters": p_iters, "n_envs": n_envs,
                      "steps_per_action": spa, "smoke": smoke, "reps": REPS},
           "backends": {}}
    for backend in ("reference", "fused"):
        env = CylinderEnv(cfg, backend=backend)
        st, obs = env.reset()
        stb, _ = broadcast_env_state(st, obs, n_envs)
        act = jnp.zeros((n_envs,), jnp.float32)
        step = jax.jit(jax.vmap(env.env_step))
        jax.block_until_ready(step(stb, act))            # compile
        ts = []
        for _ in range(REPS):
            t0 = time.perf_counter()
            jax.block_until_ready(step(stb, act))
            ts.append(time.perf_counter() - t0)
        best = min(ts)
        ts.sort()
        out["backends"][backend] = {
            "interval_s_best": best,
            "interval_s_median": ts[len(ts) // 2],
            "env_steps_per_s": n_envs * spa / best,
        }
    ref = out["backends"]["reference"]["env_steps_per_s"]
    fus = out["backends"]["fused"]["env_steps_per_s"]
    out["env_steps_per_s"] = fus                 # the dashboard headline
    out["speedup_fused_vs_reference"] = fus / ref
    return out


def measure_parity(smoke: bool) -> dict:
    """Max |fused - reference| after one env interval on the mixed batch."""
    res, p_iters = (4, 12) if smoke else (6, 30)
    cfg = EnvConfig(grid=GridConfig(res=res, dt=0.01, poisson_iters=p_iters),
                    steps_per_action=5 if smoke else 20, warmup_time=0.5)
    acts = jnp.asarray([0.3, -0.2, 0.1, 0.0][:len(PARITY_SCENARIOS)],
                       jnp.float32)
    states = {}
    for backend in ("reference", "fused"):
        env = CylinderEnv(cfg, backend=backend)
        st_b, _ = env.reset_batch(list(PARITY_SCENARIOS))
        states[backend] = jax.jit(jax.vmap(env.env_step))(st_b, acts)
    (st_r, out_r), (st_f, out_f) = states["reference"], states["fused"]
    mx = lambda a, b: float(jnp.max(jnp.abs(a - b)))
    return {"scenarios": list(PARITY_SCENARIOS),
            "u_maxabs": mx(st_f.flow.u, st_r.flow.u),
            "v_maxabs": mx(st_f.flow.v, st_r.flow.v),
            "p_maxabs": mx(st_f.flow.p, st_r.flow.p),
            "cd_maxabs": mx(out_f.cd, out_r.cd),
            "reward_maxabs": mx(out_f.reward, out_r.reward)}


def roofline_gap(throughput: dict) -> dict:
    """Measured fused interval vs the roofline bound on this host.

    Analytic per-interval work (one env), rough but stated: the packed SOR
    pair touches every cell twice per iteration (~11 flops/cell/half-sweep,
    3 reads + 1 write per cell), the momentum predictor ~60 flops over both
    staggered fields with ~10 array passes, projection/correction ~15
    flops/cell.  The bound uses this host's HardwareSpec — on the CPU
    hosts that run this bench that is ``cpu_generic``, not TPU numbers.
    """
    c = throughput["config"]
    grid = GridConfig(res=c["res"], dt=0.01, poisson_iters=c["poisson_iters"])
    ny, nx, spa = grid.ny, grid.nx, c["steps_per_action"]
    n_cells = ny * nx
    n_faces = ny * (nx + 1) + (ny + 1) * nx
    per_dt_flops = (grid.poisson_iters * 11 * 2 * n_cells   # SOR pair
                    + 60 * n_faces                          # momentum
                    + 15 * n_cells)                         # rhs + correction
    per_dt_bytes = 4 * (grid.poisson_iters * 4 * 2 * n_cells
                        + 10 * n_faces + 6 * n_cells)
    n_envs = c["n_envs"]
    hw = hardware_spec()
    rl = Roofline(arch="fused_interval", shape=f"res{c['res']}", mesh="1",
                  n_devices=1,
                  flops_per_dev=float(per_dt_flops) * spa * n_envs,
                  bytes_per_dev=float(per_dt_bytes) * spa * n_envs,
                  coll_bytes_per_dev=0.0,
                  model_flops=float(per_dt_flops) * spa * n_envs,
                  coll_by_kind={}, hw=hw)
    measured_s = throughput["backends"]["fused"]["interval_s_best"]
    return {"hw": hw.to_dict(),
            "bound_s": rl.bound_s,
            "compute_s": rl.compute_s,
            "memory_s": rl.memory_s,
            "dominant": rl.dominant,
            "measured_s": measured_s,
            "gap": measured_s / rl.bound_s if rl.bound_s else None,
            # these grids are cache-resident on CPU (working set ~hundreds
            # of KiB), so the memory term priced at DRAM bandwidth
            # overestimates its cost and gap-vs-bound can dip below 1;
            # the compute-term gap is the binding comparison there
            "gap_vs_compute": (measured_s / rl.compute_s
                               if rl.compute_s else None)}


def run(smoke: bool = False, out: str = None) -> dict:
    from benchmarks.bench_train import measure_golden_drift

    record = {"schema": BENCH_SCHEMA,
              "code": code_fingerprint(),
              "jax_devices": jax.device_count()}
    record.update(measure_throughput(smoke))
    record["parity"] = measure_parity(smoke)
    record["golden_drift"] = measure_golden_drift(smoke)
    record["roofline"] = roofline_gap(record)

    baseline = None
    if BASELINE.exists():
        base = json.loads(BASELINE.read_text())
        baseline = base.get("env_steps_per_s")
    speedup = (record["env_steps_per_s"] / baseline) if baseline else None
    record["gate"] = {
        "baseline_env_steps_per_s": baseline,
        "required_speedup": REQUIRED_SPEEDUP,
        "speedup_vs_baseline": speedup,
        # the gate is judged on the full-size measurement; smoke runs use
        # tiny shapes whose throughput says nothing about the baseline
        "passed": bool(smoke or (speedup is not None
                                 and speedup >= REQUIRED_SPEEDUP)),
    }

    root = Path(__file__).resolve().parent.parent / "artifacts"
    name = "BENCH_megakernel_smoke.json" if smoke else "BENCH_megakernel.json"
    path = Path(out) if out else root / name
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(record, indent=1, sort_keys=True))

    b = record["backends"]
    print(f"megakernel: fused {record['env_steps_per_s']:.1f} env-steps/s "
          f"(reference {b['reference']['env_steps_per_s']:.1f}, "
          f"fused/reference {record['speedup_fused_vs_reference']:.2f}x)")
    g = record["gate"]
    if g["speedup_vs_baseline"] is not None:
        print(f"gate: {g['speedup_vs_baseline']:.2f}x vs BENCH_train "
              f"baseline {g['baseline_env_steps_per_s']:.1f} "
              f"(need {g['required_speedup']:.1f}x) -> "
              f"{'PASS' if g['passed'] else 'FAIL'}")
    p = record["parity"]
    print(f"parity (mixed vmapped batch): u {p['u_maxabs']:.2e}  "
          f"p {p['p_maxabs']:.2e}  cd {p['cd_maxabs']:.2e}")
    r = record["roofline"]
    print(f"roofline[{r['hw']['name']}]: bound {r['bound_s']*1e3:.1f} ms "
          f"({r['dominant']}), measured {r['measured_s']*1e3:.1f} ms, "
          f"gap {r['gap']:.1f}x (vs compute term "
          f"{r['gap_vs_compute']:.1f}x)")
    print(f"artifact -> {path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI; writes "
                         "BENCH_megakernel_smoke.json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out)
