"""Roofline summary from the dry-run artifacts (§Roofline of EXPERIMENTS.md).

One CSV row per (arch x shape) on the single-pod mesh: the three terms,
dominant bottleneck, and the useful-compute ratio.
"""
import glob
import json
from pathlib import Path

from benchmarks.common import emit

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def run(smoke: bool = False) -> None:
    # reads pre-computed dry-run artifacts — nothing to shrink in smoke mode
    del smoke
    files = sorted(glob.glob(str(ART / "*__pod16x16.json")))
    if not files:
        emit("roofline_missing", 0.0, "run repro.launch.dryrun first")
        return
    for f in files:
        r = json.loads(Path(f).read_text())
        if r.get("status") != "ok":
            emit(f"roofline_{Path(f).stem}", 0.0, "status=fail")
            continue
        rl = r["roofline"]
        mem = r["memory"]["peak_per_device_bytes"] / 2 ** 30
        emit(f"roofline_{r['arch']}_{r['shape']}",
             rl["bound_s"] * 1e6 if "bound_s" in rl else
             max(rl["compute_s"], rl["memory_s"], rl["collective_s"]) * 1e6,
             f"dom={rl['dominant']};C_s={rl['compute_s']:.3f};"
             f"M_s={rl['memory_s']:.3f};X_s={rl['collective_s']:.3f};"
             f"useful={rl['useful_ratio']:.2f};mem_GiB={mem:.2f}")


if __name__ == "__main__":
    run()
