"""Shared benchmark utilities: timing + CSV output."""
import time

import jax


def time_fn(fn, *args, warmup: int = 1, iters: int = 5, **kw):
    """Median wall time (seconds) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
