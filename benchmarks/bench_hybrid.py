"""Paper Table I / Figs 8-9 — hybrid (N_envs x N_ranks) parallelization.

Two halves:

  * model: the cost model calibrated to the paper's Table II (<10% mean
    error, tests/test_core.py) generates all three Table I blocks and the
    optimizer reproduces the paper's headline finding (N_ranks=1,
    N_envs=N optimal).
  * measured: ``core.autotune`` times the real components on THIS host
    (solver step, halo exchange per feasible rank count, PPO update, sink
    write), refits the model, and picks the executable plan.  The full
    record lands in ``artifacts/BENCH_hybrid.json`` so the perf trajectory
    accumulates across PRs.

Standalone:  PYTHONPATH=src python benchmarks/bench_hybrid.py [--smoke]
"""
import sys
from pathlib import Path  # noqa: E402 — path bootstrap must precede imports

if __name__ == "__main__":  # standalone: make benchmarks.* / repro.* importable
    _ROOT = Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_ROOT / "src"))
    sys.path.insert(0, str(_ROOT))

from benchmarks.common import emit
from repro.core.plan import ParallelPlan, optimize_plan
from repro.core.scaling_model import calibrate_to_paper, fig10_breakdown, \
    table1_rows

ARTIFACT = Path(__file__).resolve().parent.parent / "artifacts" \
    / "BENCH_hybrid.json"
# smoke runs must not clobber the committed full measurement (see
# bench_kernels.ARTIFACT_SMOKE for the same split)
ARTIFACT_SMOKE = ARTIFACT.with_name("BENCH_hybrid_smoke.json")


def run(smoke: bool = False, artifact: str = None) -> None:
    # ---- cost-model half (pure evaluation — cheap at any size) ------------
    m = calibrate_to_paper()
    for r in table1_rows(m):
        if r["n_envs"] in (1, 2, 10, 30, 60) or \
                (r["n_ranks"] == 5 and r["n_envs"] == 12):
            emit(f"table1_r{r['n_ranks']}_e{r['n_envs']}",
                 r["t_hours"] * 3600 * 1e6 / 3000,
                 f"model_h={r['t_hours']:.1f};paper_h={r['paper_t_hours']};"
                 f"speedup={r['speedup']:.1f};eff={r['efficiency']:.3f}")

    best = optimize_plan(60, m)
    emit("optimal_plan_60cpu", 0.0,
         f"n_envs={best.n_envs};n_ranks={best.n_ranks};paper=(60;1)")
    t1 = m.t_training(ParallelPlan(1, 1, 1), 3000)
    tb = m.t_training(best, 3000)
    emit("headline_speedup", tb * 1e6 / 3000,
         f"speedup={t1 / tb:.1f}x;paper=29.6x_baseline_io")

    for r in fig10_breakdown(m):
        emit(f"fig10_breakdown_e{r['n_envs']}", r["total_s"] * 1e6,
             f"cfd_s={r['cfd_s']:.0f};io_s={r['io_s']:.1f};"
             f"drl_s={r['drl_s']:.1f}")

    # ---- measured half: autotune this host --------------------------------
    from repro.cfd.grid import GridConfig
    from repro.core.autotune import autotune, validate_artifact

    if artifact is None:
        artifact = str(ARTIFACT_SMOKE if smoke else ARTIFACT)
    grid = GridConfig(res=4 if smoke else 8, dt=0.01,
                      poisson_iters=20 if smoke else 50)
    rp = autotune(grid=grid, smoke=smoke, artifact=artifact)
    rec = rp.measurements
    validate_artifact(rec)
    for r, t in sorted(rec["measured"]["t_step_ranks"].items(),
                       key=lambda kv: int(kv[0])):
        err = rec["predicted"]["rel_err_t_step"][r]
        emit(f"autotune_t_step_r{r}", float(t) * 1e6,
             f"predicted_us={rec['predicted']['t_step_ranks'][r]*1e6:.1f};"
             f"rel_err={err:+.3f}")
    emit("autotune_t_update", rec["measured"]["t_update"] * 1e6, "")
    emit("autotune_io_write",
         rec["measured"]["io"]["write_seconds"] * 1e6,
         f"bytes_per_act={rec['measured']['io']['bytes_per_actuation']:.0f};"
         f"stream_bw={rec['measured']['io']['stream_bandwidth']:.3g}")
    emit("autotune_plan", 0.0,
         f"n_envs={rp.n_envs};n_ranks={rp.n_ranks};backend={rp.backend};"
         f"util={rp.plan.utilization:.2f};artifact={artifact}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid, 1 timing iteration (CI)")
    ap.add_argument("--artifact", default=None,
                    help="default: BENCH_hybrid.json, or "
                         "BENCH_hybrid_smoke.json under --smoke")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, artifact=args.artifact)
