"""Paper Table I / Figs 8-9 — hybrid (N_envs x N_ranks) parallelization.

The calibrated cost model (fit to the paper's Table II with <10% mean error,
tests/test_core.py) generates all three Table I blocks; the optimizer
reproduces the paper's headline finding (N_ranks=1, N_envs=N optimal).
Measured single-env episode cost on this host anchors an alternative
'this-host' column.
"""
import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.plan import CostModel, ParallelPlan, optimize_plan
from repro.core.scaling_model import calibrate_to_paper, fig10_breakdown, \
    table1_rows


def run(smoke: bool = False) -> None:
    # pure cost-model evaluation — already cheap; smoke changes nothing
    del smoke
    m = calibrate_to_paper()
    for r in table1_rows(m):
        if r["n_envs"] in (1, 2, 10, 30, 60) or \
                (r["n_ranks"] == 5 and r["n_envs"] == 12):
            emit(f"table1_r{r['n_ranks']}_e{r['n_envs']}",
                 r["t_hours"] * 3600 * 1e6 / 3000,
                 f"model_h={r['t_hours']:.1f};paper_h={r['paper_t_hours']};"
                 f"speedup={r['speedup']:.1f};eff={r['efficiency']:.3f}")

    best = optimize_plan(60, m)
    emit("optimal_plan_60cpu", 0.0,
         f"n_envs={best.n_envs};n_ranks={best.n_ranks};paper=(60;1)")
    t1 = m.t_training(ParallelPlan(1, 1, 1), 3000)
    tb = m.t_training(best, 3000)
    emit("headline_speedup", tb * 1e6 / 3000,
         f"speedup={t1 / tb:.1f}x;paper=29.6x_baseline_io")

    for r in fig10_breakdown(m):
        emit(f"fig10_breakdown_e{r['n_envs']}", r["total_s"] * 1e6,
             f"cfd_s={r['cfd_s']:.0f};io_s={r['io_s']:.1f};"
             f"drl_s={r['drl_s']:.1f}")


if __name__ == "__main__":
    run()
