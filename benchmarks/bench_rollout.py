"""DRL component benchmarks: policy inference, PPO update, env actuation,
and the unified RolloutEngine collect path — the per-component costs of
paper Fig. 10 measured for the JAX stack."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import GridConfig
from repro.drl import networks
from repro.drl.engine import EngineConfig, RolloutEngine, broadcast_env_state
from repro.drl.ppo import Batch, PPOConfig, make_optimizer, ppo_update


def run(smoke: bool = False) -> None:
    iters = 1 if smoke else 3
    pcfg = networks.PolicyConfig()
    params = networks.init_actor_critic(pcfg, jax.random.PRNGKey(0))
    obs = jax.random.normal(jax.random.PRNGKey(1), (16, 149))

    sample = jax.jit(lambda p, o, k: networks.sample_action(p, o, k))
    t = time_fn(sample, params, obs, jax.random.PRNGKey(2),
                iters=1 if smoke else 5)
    emit("policy_sample_16envs", t * 1e6, "2x512_mlp")

    # PPO update on one episode of 16 envs x 100 actuations
    N = 16 * (4 if smoke else 100)
    batch = Batch(obs=jax.random.normal(jax.random.PRNGKey(3), (N, 149)),
                  act=jax.random.normal(jax.random.PRNGKey(4), (N, 1)),
                  logp_old=jax.random.normal(jax.random.PRNGKey(5), (N,)),
                  adv=jax.random.normal(jax.random.PRNGKey(6), (N,)),
                  ret=jax.random.normal(jax.random.PRNGKey(7), (N,)))
    cfg = PPOConfig()
    opt = make_optimizer(cfg)
    opt_state = opt.init(params)
    upd = jax.jit(lambda p, s, b, k, st: ppo_update(cfg, opt, p, s, b, k, st))
    t = time_fn(upd, params, opt_state, batch, jax.random.PRNGKey(8),
                jnp.int32(0), iters=iters)
    emit(f"ppo_update_{N}samples", t * 1e6,
         f"epochs={cfg.epochs};minibatches={cfg.minibatches}")

    # one actuation period of the environment (50 solver steps)
    res, p_iters = (6, 30) if smoke else (12, 60)
    env = CylinderEnv(EnvConfig(grid=GridConfig(res=res, dt=0.006,
                                                poisson_iters=p_iters),
                                steps_per_action=5 if smoke else 50,
                                warmup_time=0.5 if smoke else 2.0))
    st, obs0 = env.reset()
    step = jax.jit(env.env_step)
    t = time_fn(step, st, jnp.float32(0.2), iters=iters)
    emit("env_actuation_period", t * 1e6,
         f"{env.cfg.steps_per_action}_solver_steps;res{res}")
    emit("cfd_share_estimate", 0.0,
         f"paper_claim=>95%;policy+update_vs_cfd="
         f"{(t):.3f}s_per_actuation")

    # unified engine: full collect -> GAE -> flatten round for N_envs
    n_envs, horizon = (2, 2) if smoke else (4, 4)
    engine = RolloutEngine.for_env(
        env, EngineConfig(n_envs=n_envs, horizon=horizon))
    st_b, obs_b = broadcast_env_state(st, obs0, n_envs)
    t = time_fn(lambda p, k: engine.collect(p, st_b, obs_b, k),
                params, jax.random.PRNGKey(9), iters=iters)
    emit("engine_collect_round", t * 1e6,
         f"n_envs={n_envs};horizon={horizon};res{res}")


if __name__ == "__main__":
    run()
