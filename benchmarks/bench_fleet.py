"""Fleet scaling benchmark: env-steps/sec and parallel efficiency at
1 / 2 / 4 processes through the real launcher.

Each point shells out to ``tools/launch_fleet.py --mode bench``, which
forks that many runner processes into one ``jax.distributed`` fleet (the
"data" axis spanning processes, exactly the cluster layout) and times
distributed collects; this script parses the coordinator's ``FLEET_STATS``
line.  The pinned forced device count keeps the numerical work identical at
every fleet size, so throughput ratios compare like with like.

Efficiency is reported three ways:

- **raw**: ``tp_n / (n * tp_1)`` — the paper's definition.  On a CI box
  with fewer cores than processes this is bounded by ``cores/n`` no matter
  how good the communication layer is (the processes time-slice the cores).
- **vs_cores**: ``tp_n / (min(n, cores) * tp_1)`` — efficiency against
  ideal core scaling.  Still conflates the fleet's communication cost with
  time-slicing contention (cache/context-switch tax of co-running n full
  JAX runtimes), which p INDEPENDENT jobs on the same host would also pay.
- **comm** (the gate): ``tp_n / tp_n^(no-gather)`` — the same fleet, same
  pinned program, same process count, but with the trajectory all-gather
  disabled (``--no-gather``: each process times only its own env shard).
  The denominator is the best this host can do running the fleet's exact
  per-process compute with zero communication, so the ratio isolates the
  one thing the fleet layer adds: inter-process collectives + sync.

The gate (``gate.passed``, enforced by ``tools/bench_report.py --check``)
requires comm efficiency >= 70% at the largest fleet, reported beside the
paper's measured 78% at 60 cores (arXiv 2402.11515 Fig. 7 — measured on
dedicated cores, where raw and comm efficiency coincide).

Writes ``artifacts/BENCH_fleet.json`` (``BENCH_fleet_smoke.json`` with
``--smoke`` — smoke artifacts never overwrite committed measurements).

    PYTHONPATH=src python benchmarks/bench_fleet.py [--smoke]
"""
import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))

BENCH_SCHEMA = "repro.bench_fleet/v1"
PAPER_EFFICIENCY_60 = 0.78      # paper Fig. 7: parallel efficiency, 60 cores
GATE_EFFICIENCY = 0.70          # comm efficiency floor at the max fleet
LAUNCHER = _ROOT / "tools" / "launch_fleet.py"


def run_fleet_point(processes: int, *, plan: str, n_envs: int,
                    measure_episodes: int, res: int, dt: float,
                    poisson_iters: int, steps_per_action: int,
                    actions_per_episode: int, timeout: float,
                    no_gather: bool = False) -> dict:
    """One launcher invocation; returns the parsed FLEET_STATS record."""
    tag = f"bench_fleet_p{processes}{'_nogather' if no_gather else ''}_"
    workdir = tempfile.mkdtemp(prefix=tag)
    cmd = [sys.executable, str(LAUNCHER),
           "--processes", str(processes), "--mode", "bench",
           "--plan", plan, "--n-envs", str(n_envs),
           "--measure-episodes", str(measure_episodes),
           "--res", str(res), "--dt", str(dt),
           "--poisson-iters", str(poisson_iters),
           "--steps-per-action", str(steps_per_action),
           "--actions-per-episode", str(actions_per_episode),
           "--workdir", workdir,
           "--launch-timeout", str(timeout),
           "--heartbeat-timeout", str(timeout)]
    if no_gather:
        cmd.append("--no-gather")
    t0 = time.perf_counter()
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout + 120, cwd=str(_ROOT))
    wall = time.perf_counter() - t0
    if proc.returncode != 0:
        raise RuntimeError(
            f"fleet bench at {processes} process(es) failed "
            f"(exit {proc.returncode}); logs in {workdir}\n"
            f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    stats_lines = [line for line in proc.stdout.splitlines()
                   if line.startswith("FLEET_STATS ")]
    if not stats_lines:
        raise RuntimeError(f"no FLEET_STATS line from {processes}-process "
                           f"bench:\n{proc.stdout[-2000:]}")
    stats = json.loads(stats_lines[-1].split(" ", 1)[1])
    stats["launcher_wall_s"] = wall
    return stats


def run(smoke: bool = False, out: str = None) -> dict:
    from repro.drl.train_state import code_fingerprint

    fleet_sizes = (1, 2) if smoke else (1, 2, 4)
    # non-smoke episodes use the paper's 50 solver steps per actuation so
    # each measured collect carries seconds of CFD — the regime the
    # efficiency claim is about; the per-collect fleet overhead (gloo
    # rendezvous + host gather, ~tens of ms) must amortize, not dominate
    cfg = {
        "plan": "4,4,1",
        "n_envs": 4,
        "measure_episodes": 2 if smoke else 3,
        "res": 6 if smoke else 8,
        "dt": 0.012 if smoke else 0.01,
        "poisson_iters": 30 if smoke else 50,
        "steps_per_action": 10 if smoke else 50,
        "actions_per_episode": 3 if smoke else 10,
        "timeout": 600.0 if smoke else 900.0,
    }
    cores = os.cpu_count() or 1
    points, baselines = {}, {}
    for n in fleet_sizes:
        points[n] = run_fleet_point(n, **cfg)
        if n > 1:
            # the no-comms twin: same fleet size, gather disabled
            baselines[n] = run_fleet_point(n, no_gather=True, **cfg)

    tp1 = points[fleet_sizes[0]]["env_steps_per_sec"]
    scaling = []
    for n in fleet_sizes:
        tp = points[n]["env_steps_per_sec"]
        tp_base = baselines[n]["env_steps_per_sec"] if n in baselines else tp
        scaling.append({
            "processes": n,
            "env_steps_per_sec": tp,
            "env_steps_per_sec_no_gather": tp_base,
            "elapsed_s": points[n]["elapsed_s"],
            "launcher_wall_s": points[n]["launcher_wall_s"],
            "speedup": tp / tp1,
            "efficiency_raw": tp / (n * tp1),
            "efficiency_vs_cores": tp / (min(n, cores) * tp1),
            "efficiency_comm": tp / tp_base,
        })
    top = scaling[-1]
    record = {
        "schema": BENCH_SCHEMA,
        "code": code_fingerprint(),
        "host": {"cores": cores},
        "config": dict(cfg, smoke=smoke, fleet_sizes=list(fleet_sizes)),
        "scaling": scaling,
        "paper": {"efficiency_60cores": PAPER_EFFICIENCY_60},
        "gate": {
            "metric": "efficiency_comm",
            "processes": top["processes"],
            "measured_efficiency": top["efficiency_comm"],
            "required_efficiency": GATE_EFFICIENCY,
            "passed": top["efficiency_comm"] >= GATE_EFFICIENCY,
        },
    }

    root = _ROOT / "artifacts"
    name = "BENCH_fleet_smoke.json" if smoke else "BENCH_fleet.json"
    path = Path(out) if out else root / name
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(record, indent=1, sort_keys=True))

    for s in scaling:
        print(f"fleet x{s['processes']}: {s['env_steps_per_sec']:.1f} "
              f"env-steps/s, speedup {s['speedup']:.2f}x, efficiency "
              f"raw {s['efficiency_raw']:.1%} / vs-cores "
              f"{s['efficiency_vs_cores']:.1%} / comm "
              f"{s['efficiency_comm']:.1%}")
    g = record["gate"]
    print(f"gate: comm efficiency {g['measured_efficiency']:.1%} at "
          f"{g['processes']} processes (requires "
          f">= {GATE_EFFICIENCY:.0%}; paper: {PAPER_EFFICIENCY_60:.0%} at "
          f"60 cores) -> {'PASS' if g['passed'] else 'FAIL'}")
    print(f"artifact -> {path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="1/2-process points only, tiny shapes; writes "
                         "BENCH_fleet_smoke.json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out)
