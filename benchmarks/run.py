# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_cfd_scaling, bench_hybrid, bench_io,
                            bench_kernels, bench_roofline, bench_rollout)
    print("name,us_per_call,derived")
    suites = [
        ("fig7_cfd_scaling", bench_cfd_scaling.run),
        ("table1_hybrid", bench_hybrid.run),
        ("table2_io", bench_io.run),
        ("fig10_components", bench_rollout.run),
        ("kernels", bench_kernels.run),
        ("roofline", bench_roofline.run),
    ]
    failures = []
    for name, fn in suites:
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
