# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   python benchmarks/run.py            full measurements
#   python benchmarks/run.py --smoke    tiny grids, 1 timing iteration — the
#                                       CI job that keeps these scripts alive
import argparse
import sys
import traceback
from pathlib import Path

# make `benchmarks.*` and `repro.*` importable for plain-script runs
# (no pip install -e, no PYTHONPATH)
_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 iteration per bench (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="run a single suite by name (e.g. table2_io)")
    args = ap.parse_args()

    from benchmarks import (bench_cfd_scaling, bench_hybrid, bench_io,
                            bench_kernels, bench_roofline, bench_rollout,
                            bench_scenarios)
    suites = [
        ("fig7_cfd_scaling", bench_cfd_scaling.run),
        ("table1_hybrid", bench_hybrid.run),
        ("table2_io", bench_io.run),
        ("fig10_components", bench_rollout.run),
        ("kernels", bench_kernels.run),
        ("roofline", bench_roofline.run),
        ("scenarios", bench_scenarios.run),
    ]
    if args.only and args.only not in {n for n, _ in suites}:
        names = ", ".join(n for n, _ in suites)
        raise SystemExit(f"unknown suite {args.only!r}; choose from: {names}")
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        if args.only and name != args.only:
            continue
        try:
            fn(smoke=args.smoke)
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
