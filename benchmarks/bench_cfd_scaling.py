"""Paper Fig. 7 — CFD intra-instance scaling.

Measured: single-device solver step cost on this host (real).
Modeled: speedup/efficiency vs N_ranks from the calibrated cost model
(one physical core here, so multi-rank wall time cannot be *measured*; the
model is calibrated to the paper's own curve and to the measured t_step_1 —
DESIGN.md §2 'assumptions that changed').
"""
import dataclasses

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.cfd import solver
from repro.cfd.grid import GridConfig, build_geometry
from repro.core.plan import CostModel
from repro.core.scaling_model import calibrate_to_paper, fig7_rows


def run(smoke: bool = False) -> None:
    iters = 1 if smoke else 10
    cfg = (GridConfig(res=6, dt=0.012, poisson_iters=20) if smoke
           else GridConfig(res=12, dt=0.006, poisson_iters=60))
    geom = build_geometry(cfg)
    ga = solver.geom_to_arrays(geom)
    st = solver.init_state(cfg, geom)
    jet = jnp.float32(0.0)

    t_step = time_fn(lambda s: solver.step(cfg, ga, s, jet)[0], st,
                     iters=iters)
    emit("cfd_step_single_device", t_step * 1e6,
         f"grid={cfg.nx}x{cfg.ny};poisson_iters={cfg.poisson_iters}")

    t_poisson = time_fn(
        lambda s: __import__("repro.cfd.poisson", fromlist=["solve"]).solve(
            solver.divergence(s.u, s.v, cfg) / cfg.dt, cfg.dx, cfg.dy,
            iters=cfg.poisson_iters), st, iters=iters)
    emit("cfd_poisson_solve", t_poisson * 1e6,
         f"share_of_step={t_poisson / t_step:.2f}")

    # paper-calibrated scaling curve, re-anchored at the measured t_step_1
    m = dataclasses.replace(calibrate_to_paper(), t_step_1=t_step)
    for r in fig7_rows(m, ranks=(1, 2, 4, 8, 16)):
        emit(f"cfd_scaling_nranks{r['n_ranks']}",
             m.t_step(r["n_ranks"]) * 1e6,
             f"speedup={r['speedup']:.2f};efficiency={r['efficiency']:.3f}")


if __name__ == "__main__":
    run()
